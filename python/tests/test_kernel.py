"""L1 correctness: the Pallas convolution kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the compiled artifact: whatever
these tests pass is exactly what gets lowered into the HLO the Rust
runtime executes.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.convmul import conv_digits, conv_digits_batched
from compile.kernels.ref import ref_conv, ref_mul_digits, carry_normalize_ref


def rand_digits(rng, k, lo=0, hi=256):
    return rng.integers(lo, hi, size=k, dtype=np.int32)


@pytest.mark.parametrize("k", [8, 32, 128, 256, 512])
def test_conv_matches_ref(k):
    rng = np.random.default_rng(k)
    a = rand_digits(rng, k)
    b = rand_digits(rng, k)
    got = np.asarray(conv_digits(a, b))
    want = np.asarray(ref_conv(a, b))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("block", [8, 16, 64, 128])
def test_conv_block_sizes(block):
    k = 128
    rng = np.random.default_rng(block)
    a = rand_digits(rng, k)
    b = rand_digits(rng, k)
    got = np.asarray(conv_digits(a, b, block=block))
    np.testing.assert_array_equal(got, np.asarray(ref_conv(a, b)))


def test_conv_signed_inputs():
    # The Karatsuba cross term feeds signed digit differences.
    k = 64
    rng = np.random.default_rng(7)
    a = rand_digits(rng, k, lo=-255, hi=256)
    b = rand_digits(rng, k, lo=-255, hi=256)
    got = np.asarray(conv_digits(a, b))
    np.testing.assert_array_equal(got, np.asarray(ref_conv(a, b)))


def test_conv_batched():
    k, batch = 128, 5
    rng = np.random.default_rng(9)
    a = rng.integers(0, 256, size=(batch, k), dtype=np.int32)
    b = rng.integers(0, 256, size=(batch, k), dtype=np.int32)
    got = np.asarray(conv_digits_batched(a, b))
    for i in range(batch):
        np.testing.assert_array_equal(got[i], np.asarray(ref_conv(a[i], b[i])))


def test_conv_identity_and_zero():
    k = 32
    one = np.zeros(k, np.int32)
    one[0] = 1
    x = np.arange(k, dtype=np.int32)
    np.testing.assert_array_equal(
        np.asarray(conv_digits(x, one))[:k], x
    )
    zero = np.zeros(k, np.int32)
    np.testing.assert_array_equal(
        np.asarray(conv_digits(x, zero)), np.zeros(2 * k, np.int32)
    )


@settings(max_examples=30, deadline=None)
@given(
    k_log=st.integers(min_value=3, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    signed=st.booleans(),
)
def test_conv_hypothesis_sweep(k_log, seed, signed):
    """Hypothesis sweep over shapes and digit ranges."""
    k = 1 << k_log
    rng = np.random.default_rng(seed)
    lo = -255 if signed else 0
    a = rand_digits(rng, k, lo=lo)
    b = rand_digits(rng, k, lo=lo)
    got = np.asarray(conv_digits(a, b))
    np.testing.assert_array_equal(got, np.asarray(ref_conv(a, b)))


def test_conv_plus_carry_is_exact_product():
    """conv + carry normalization == exact bignum product."""
    k = 256
    rng = np.random.default_rng(77)
    a = rand_digits(rng, k)
    b = rand_digits(rng, k)
    conv = np.asarray(conv_digits(a, b), dtype=np.int64)
    got = carry_normalize_ref(conv)
    np.testing.assert_array_equal(got, ref_mul_digits(a, b))

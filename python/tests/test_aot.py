"""AOT path: lowering to HLO text and the artifact manifest."""

import json
import os

from compile import aot, model


def test_lower_school_to_hlo_text():
    lowered = model.lowered("school", 1, 64)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "s32[" in text  # int32 tensors present
    # The kernel convolution must have been inlined (interpret mode):
    # no Mosaic/custom-call the CPU client could not execute.
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


def test_lower_karatsuba_to_hlo_text():
    lowered = model.lowered("karatsuba", 2, 64)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text


def test_build_writes_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    entries = aot.build(out, matrix=[("school", 1, 32), ("karatsuba", 1, 32)])
    assert len(entries) == 2
    manifest = json.load(open(os.path.join(out, "manifest.json")))
    assert manifest["format"] == "hlo-text"
    for e in manifest["artifacts"]:
        p = os.path.join(out, e["file"])
        assert os.path.exists(p)
        assert os.path.getsize(p) > 0
        assert e["base_log2"] == 8

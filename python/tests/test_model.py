"""L2 correctness: batched school/Karatsuba models vs exact oracles."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import ref_mul_digits


def rand_pairs(seed, batch, k):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 256, size=(batch, k), dtype=np.int32)
    b = rng.integers(0, 256, size=(batch, k), dtype=np.int32)
    return a, b


@pytest.mark.parametrize("batch,k", [(1, 64), (3, 128), (8, 256)])
def test_school_exact(batch, k):
    a, b = rand_pairs(batch * 1000 + k, batch, k)
    got = np.asarray(model.mul_school_batched(a, b))
    assert got.shape == (batch, 2 * k)
    for i in range(batch):
        np.testing.assert_array_equal(got[i], ref_mul_digits(a[i], b[i]))


@pytest.mark.parametrize("batch,k", [(1, 64), (4, 256)])
def test_karatsuba_exact(batch, k):
    a, b = rand_pairs(batch * 7 + k, batch, k)
    got = np.asarray(model.mul_karatsuba_batched(a, b))
    for i in range(batch):
        np.testing.assert_array_equal(got[i], ref_mul_digits(a[i], b[i]))


def test_karatsuba_equals_school():
    a, b = rand_pairs(42, 6, 128)
    s = np.asarray(model.mul_school_batched(a, b))
    kk = np.asarray(model.mul_karatsuba_batched(a, b))
    np.testing.assert_array_equal(s, kk)


def test_edge_values():
    # all-max digits (worst-case carries) and tiny values.
    k = 128
    ff = np.full((1, k), 255, dtype=np.int32)
    one = np.zeros((1, k), dtype=np.int32)
    one[0, 0] = 1
    got = np.asarray(model.mul_school_batched(ff, ff))[0]
    np.testing.assert_array_equal(got, ref_mul_digits(ff[0], ff[0]))
    got = np.asarray(model.mul_karatsuba_batched(ff, one))[0]
    np.testing.assert_array_equal(got, ref_mul_digits(ff[0], one[0]))


@settings(max_examples=15, deadline=None)
@given(
    k_log=st.integers(min_value=4, max_value=8),
    batch=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_model_hypothesis_sweep(k_log, batch, seed):
    k = 1 << k_log
    a, b = rand_pairs(seed, batch, k)
    s = np.asarray(model.mul_school_batched(a, b))
    kk = np.asarray(model.mul_karatsuba_batched(a, b))
    for i in range(batch):
        want = ref_mul_digits(a[i], b[i])
        np.testing.assert_array_equal(s[i], want)
        np.testing.assert_array_equal(kk[i], want)

"""Model-side evidence for the PR-6 LEAF_WIDTH re-tune.

Bit-exact Python replica of the Rust charging model for the sequential
multipliers (`rust/src/bignum/{core,mul}.rs`) and of `util::Rng`
(xoshiro256++ seeded by SplitMix64), so the charged-T consequences of a
leaf-width change can be computed exactly in an environment without a
Rust toolchain. The numbers printed by this script are the ones recorded
in DESIGN.md ("Leaf-width re-tune" re-bless record); any drift between
this replica and the Rust side is itself a bug (the Rng constants are
pinned by `theorem_properties`' seed-stability test).

Usage:  python3 python/tools/leaf_tune_model.py
"""

MASK64 = (1 << 64) - 1


def _splitmix64(state):
    state = (state + 0x9E3779B97F4A7C15) & MASK64
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
    return state, (z ^ (z >> 31))


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK64


class Rng:
    """util::Rng replica (xoshiro256++)."""

    def __init__(self, seed):
        s = []
        sm = seed & MASK64
        for _ in range(4):
            sm, v = _splitmix64(sm)
            s.append(v)
        self.s = s

    def next_u64(self):
        s = self.s
        result = (_rotl((s[0] + s[3]) & MASK64, 23) + s[0]) & MASK64
        t = (s[1] << 17) & MASK64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def below(self, bound):
        # Lemire's method, as in rng.rs.
        while True:
            x = self.next_u64()
            m = x * bound
            lo = m & MASK64
            if lo >= bound or lo >= ((1 << 64) - bound) % bound:
                return m >> 64

    def range(self, lo, hi):
        return lo + self.below(hi - lo + 1)

    def digits(self, n, log2_base):
        base = 1 << log2_base
        v = [self.below(base) for _ in range(n)]
        if n > 0 and v[n - 1] == 0:
            v[n - 1] = self.range(1, base - 1)
        return v


class Ops:
    def __init__(self):
        self.n = 0

    def charge(self, k):
        self.n += k


def mul_school(a, b, base_log2, ops):
    """Closed-form charge 2·|a|·|b|; exact product digits."""
    na, nb = len(a), len(b)
    ops.charge(2 * na * nb)
    mask = (1 << base_log2) - 1
    out = [0] * (na + nb)
    for i, ai in enumerate(a):
        if ai == 0:
            continue
        carry = 0
        for j, bj in enumerate(b):
            t = out[i + j] + ai * bj + carry
            out[i + j] = t & mask
            carry = t >> base_log2
        k = i + nb
        while carry != 0:
            t = out[k] + (carry & mask)
            out[k] = t & mask
            carry = (carry >> base_log2) + (t >> base_log2)
            k += 1
    return out


def cmp_digits(a, b, ops):
    w = len(a)
    for i in range(w - 1, -1, -1):
        ops.charge(1)
        if a[i] != b[i]:
            return 1 if a[i] > b[i] else -1
    return 0


def sub_with_borrow(a, b, borrow_in, base_log2, ops):
    ops.charge(len(a))
    s = 1 << base_log2
    out = []
    borrow = borrow_in
    for x, y in zip(a, b):
        t = x - y - borrow
        if t < 0:
            t += s
            borrow = 1
        else:
            borrow = 0
        out.append(t)
    return out, borrow


def add_into_width(dst, src, off, base_log2, ops):
    mask = (1 << base_log2) - 1
    carry = 0
    i = 0
    while i < len(src) or carry != 0:
        d = off + i
        add = src[i] if i < len(src) else 0
        t = dst[d] + add + carry
        dst[d] = t & mask
        carry = t >> base_log2
        i += 1
    ops.charge(i)


def sub_into_width(dst, src, off, base_log2, ops):
    s = 1 << base_log2
    borrow = 0
    i = 0
    while i < len(src) or borrow != 0:
        d = off + i
        sub = src[i] if i < len(src) else 0
        t = dst[d] - sub - borrow
        if t < 0:
            t += s
            borrow = 1
        else:
            borrow = 0
        dst[d] = t
        i += 1
    ops.charge(i)


def abs_diff(x, y, base_log2, ops):
    c = cmp_digits(x, y, ops)
    if c == 0:
        return 0, [0] * len(x)
    if c > 0:
        d, _ = sub_with_borrow(x, y, 0, base_log2, ops)
        return 1, d
    d, _ = sub_with_borrow(y, x, 0, base_log2, ops)
    return -1, d


def slim(a, b, base_log2, ops, leaf):
    n = len(a)
    if n <= max(leaf, 1):
        return mul_school(a, b, base_log2, ops)
    h = n // 2
    a0, a1, b0, b1 = a[:h], a[h:], b[:h], b[h:]
    c0 = slim(a0, b0, base_log2, ops, leaf)
    c1 = slim(a0, b1, base_log2, ops, leaf)
    c2 = slim(a1, b0, base_log2, ops, leaf)
    c3 = slim(a1, b1, base_log2, ops, leaf)
    out = [0] * (2 * n)
    out[: 2 * h] = c0
    add_into_width(out, c1, h, base_log2, ops)
    add_into_width(out, c2, h, base_log2, ops)
    add_into_width(out, c3, n, base_log2, ops)
    return out


def skim(a, b, base_log2, ops, leaf):
    n = len(a)
    if n <= max(leaf, 1):
        return mul_school(a, b, base_log2, ops)
    h = n // 2
    a0, a1, b0, b1 = a[:h], a[h:], b[:h], b[h:]
    fa, ad = abs_diff(a0, a1, base_log2, ops)
    fb, bd = abs_diff(b1, b0, base_log2, ops)
    c0 = skim(a0, b0, base_log2, ops, leaf)
    c2 = skim(a1, b1, base_log2, ops, leaf)
    cp = skim(ad, bd, base_log2, ops, leaf)
    sign = fa * fb
    out = [0] * (2 * n)
    out[: 2 * h] = c0
    add_into_width(out, c0, h, base_log2, ops)
    add_into_width(out, c2, h, base_log2, ops)
    add_into_width(out, c2, n, base_log2, ops)
    if sign > 0:
        add_into_width(out, cp, h, base_log2, ops)
    elif sign < 0:
        sub_into_width(out, cp, h, base_log2, ops)
    return out


def value(digits, base_log2):
    v = 0
    for d in reversed(digits):
        v = (v << base_log2) | d
    return v


def fact13_bound(n):
    import math

    return math.ceil(16.0 * n ** (math.log2(3)))


def fact10_bound(n):
    return 8 * n * n


def main():
    # --- Pinned-test margins at the applied widths -------------------
    print("== skim_op_bound_fact13 (seed 0x513, base 2^16) ==")
    rng = Rng(0x513)
    for n in (16, 64, 256, 1024):
        a = rng.digits(n, 16)
        b = rng.digits(n, 16)
        for leaf in (64, 128):
            ops = Ops()
            c = skim(a, b, 16, ops, leaf)
            assert value(c, 16) == value(a, 16) * value(b, 16)
            bound = fact13_bound(n)
            ok = "OK " if ops.n <= bound else "FAIL"
            print(f"  n={n:5d} leaf={leaf:4d}: T={ops.n:9d}  bound={bound:9d}  {ok}")

    print("== slim_op_bound_fact10 (seed 0x510, base 2^16) ==")
    rng = Rng(0x510)
    for n in (16, 64, 256):
        a = rng.digits(n, 16)
        b = rng.digits(n, 16)
        for leaf in (64, 256):
            ops = Ops()
            c = slim(a, b, 16, ops, leaf)
            assert value(c, 16) == value(a, 16) * value(b, 16)
            bound = fact10_bound(n)
            ok = "OK " if ops.n <= bound else "FAIL"
            print(f"  n={n:5d} leaf={leaf:4d}: T={ops.n:9d}  bound={bound:9d}  {ok}")

    print("== skim_cheaper_than_slim_at_scale (seed 0x333, n=1024) ==")
    rng = Rng(0x333)
    a = rng.digits(1024, 16)
    b = rng.digits(1024, 16)
    o_slim, o_skim = Ops(), Ops()
    slim(a, b, 16, o_slim, 256)
    skim(a, b, 16, o_skim, 128)
    print(f"  slim(leaf 256)={o_slim.n}  skim(leaf 128)={o_skim.n}  "
          f"{'OK' if o_skim.n < o_slim.n else 'FAIL'}")

    print("== skim_charges sanity (seed 0x51C): tiny-leaf >= std/4 ==")
    rng = Rng(0x51C)
    for n in (64, 256):
        a = rng.digits(n, 16)
        b = rng.digits(n, 16)
        o_std, o_tiny = Ops(), Ops()
        p_std = skim(a, b, 16, o_std, 128)
        p_tiny = skim(a, b, 16, o_tiny, 4)
        assert p_std == p_tiny
        ok = "OK" if o_tiny.n >= o_std.n // 4 else "FAIL"
        print(f"  n={n}: std(128)={o_std.n} tiny(4)={o_tiny.n}  {ok}")

    # --- DESIGN.md re-bless record: before/after charged T ----------
    print("== re-tune before/after charged T (seed 0x1EAF operands) ==")
    for log2 in (4, 8, 16):
        for n in (1024, 4096):
            rng = Rng(0x1EAF ^ n ^ log2)
            a = rng.digits(n, log2)
            b = rng.digits(n, log2)
            o_sk_old, o_sk_new = Ops(), Ops()
            skim(a, b, log2, o_sk_old, 64)
            skim(a, b, log2, o_sk_new, 128)
            o_sl_old, o_sl_new = Ops(), Ops()
            slim(a, b, log2, o_sl_old, 64)
            slim(a, b, log2, o_sl_new, 256)
            print(
                f"  base=2^{log2:<2d} n={n:5d}  "
                f"skim T 64->{128}: {o_sk_old.n} -> {o_sk_new.n} "
                f"({100.0 * o_sk_new.n / o_sk_old.n - 100:+.1f}%)   "
                f"slim T 64->{256}: {o_sl_old.n} -> {o_sl_new.n} "
                f"({100.0 * o_sl_new.n / o_sl_old.n - 100:+.1f}%)"
            )


if __name__ == "__main__":
    main()

"""AOT lowering: JAX models -> HLO *text* artifacts for the Rust runtime.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version behind the
published ``xla`` Rust crate) rejects; the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts

Emits one ``.hlo.txt`` per (entry, batch, K) in the artifact matrix plus
``manifest.json`` describing them for ``runtime::artifacts`` on the Rust
side.  Python never runs again after this step.
"""

from __future__ import annotations

import argparse
import json
import os

from jax._src.lib import xla_client as xc

from . import model

# (entry kind, batch, K-digits) matrix compiled into artifacts/.
# K=256 base-256 digits = 2048-bit leaf operands; B=8 is the
# coordinator's default dynamic-batching width.
DEFAULT_MATRIX = [
    ("school", 1, 64),
    ("school", 8, 64),
    ("school", 1, 128),
    ("school", 8, 128),
    ("school", 1, 256),
    ("school", 8, 256),
    ("school", 1, 1024),
    ("karatsuba", 1, 256),
    ("karatsuba", 8, 256),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side unwraps with ``to_tuple1``)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def artifact_name(kind: str, batch: int, k: int) -> str:
    return f"mul_{kind}_b{batch}_k{k}.hlo.txt"


def build(out_dir: str, matrix=None) -> list[dict]:
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for kind, batch, k in matrix or DEFAULT_MATRIX:
        lowered = model.lowered(kind, batch, k)
        text = to_hlo_text(lowered)
        name = artifact_name(kind, batch, k)
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        entries.append(
            {
                "file": name,
                "entry": kind,
                "batch": batch,
                "k": k,
                "base_log2": model.BASE_LOG2,
                "bytes": len(text),
            }
        )
        print(f"wrote {path} ({len(text)} bytes)")
    manifest = {
        "format": "hlo-text",
        "dtype": "int32",
        "artifacts": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {out_dir}/manifest.json ({len(entries)} artifacts)")
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--quick",
        action="store_true",
        help="only the smallest artifact (CI smoke)",
    )
    args = ap.parse_args()
    matrix = [DEFAULT_MATRIX[0]] if args.quick else DEFAULT_MATRIX
    build(args.out_dir, matrix)


if __name__ == "__main__":
    main()

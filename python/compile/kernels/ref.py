"""Pure reference oracles for the L1 kernel and the L2 models.

Two layers of truth:

* :func:`ref_conv` — pure ``jnp.convolve`` digit convolution (the
  "pure-jnp oracle" the Pallas kernel is tested against).
* :func:`ref_mul_digits` / :func:`ref_mul_int` — exact big-integer
  products via Python arbitrary-precision ints, independent of JAX
  entirely (the oracle the whole model is tested against).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

BASE = 256


def ref_conv(a, b):
    """Full digit convolution, padded to 2K entries (pure jnp)."""
    c = jnp.convolve(jnp.asarray(a, jnp.int32), jnp.asarray(b, jnp.int32))
    return jnp.pad(c, (0, 2 * len(a) - len(c))).astype(jnp.int32)


def digits_to_int(digits, base: int = BASE) -> int:
    """LSB-first digit vector -> Python int (exact)."""
    v = 0
    for d in reversed(list(np.asarray(digits, dtype=np.int64))):
        v = v * base + int(d)
    return v


def int_to_digits(v: int, width: int, base: int = BASE) -> np.ndarray:
    """Python int -> LSB-first digit vector of exactly ``width`` digits."""
    out = np.zeros(width, dtype=np.int32)
    for i in range(width):
        out[i] = v % base
        v //= base
    assert v == 0, "value does not fit in the requested width"
    return out


def ref_mul_digits(a, b, base: int = BASE) -> np.ndarray:
    """Exact product of two K-digit vectors as a 2K-digit vector."""
    k = len(a)
    prod = digits_to_int(a, base) * digits_to_int(b, base)
    return int_to_digits(prod, 2 * k, base)


def carry_normalize_ref(conv, base: int = BASE) -> np.ndarray:
    """Exact carry propagation of raw convolution sums (python ints,
    overflow-proof)."""
    out = np.zeros(len(conv), dtype=np.int32)
    carry = 0
    for i, v in enumerate(np.asarray(conv, dtype=np.int64)):
        t = int(v) + carry
        out[i] = t % base
        carry = t // base
    assert carry == 0, f"residual carry {carry}"
    return out

"""L1 — Pallas digit-convolution kernel.

The compute hot-spot of the leaf schoolbook multiply is the digit
convolution  ``c[k] = sum_{i+j=k} a[i] * b[j]``  over base-256 digit
vectors (int32 lanes).  This kernel computes it blocked:

* the grid ranges over output blocks of ``BK`` digits;
* for each output block the kernel loops over the input blocks of ``a``
  and gathers the matching window of ``b`` as a ``BK x BK`` Toeplitz
  slice, reducing it with an einsum — i.e. each (output-block,
  input-block) pair is one small mat-vec, which is exactly the schedule
  an MXU systolic pass would execute for the Toeplitz-matrix formulation
  of convolution (see DESIGN.md §Hardware-Adaptation).

Digits are *signed* int32 on purpose: the L2 Karatsuba variant feeds the
kernel digit-wise differences (a0-a1), whose convolution is still exact
in int32 for K <= 2^15 (|conv| <= K * 255^2 < 2^31).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowering inlines the kernel into plain HLO,
which is what the AOT artifact ships.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default output-block width. 128 int32 lanes = one 512-byte VMEM row
# per operand block; the BK x BK gather window is 64 KiB — comfortably
# inside a TPU core's ~16 MiB VMEM with double buffering.
DEFAULT_BLOCK = 128


def _conv_block_kernel(a_ref, b_ref, o_ref, *, k: int, bk: int):
    """Compute one BK-wide block of the full 2K-digit convolution."""
    ob = pl.program_id(0)
    t = ob * bk + jax.lax.iota(jnp.int32, bk)  # global output indices
    acc = jnp.zeros((bk,), jnp.int32)

    def body(ib, acc):
        # a block [ib*bk, (ib+1)*bk)
        a_blk = jax.lax.dynamic_slice(a_ref[...], (ib * bk,), (bk,))
        i = ib * bk + jax.lax.iota(jnp.int32, bk)
        # j[t_row, i_col] = t - i  (index into b), masked to [0, K)
        j = t[:, None] - i[None, :]
        valid = (j >= 0) & (j < k)
        jc = jnp.clip(j, 0, k - 1)
        b_win = jnp.where(valid, b_ref[...][jc], 0)
        # One BK x BK mat-vec per (output, input) block pair.
        return acc + jnp.einsum(
            "ti,i->t", b_win, a_blk, preferred_element_type=jnp.int32
        )

    acc = jax.lax.fori_loop(0, k // bk, body, acc)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block",))
def conv_digits(a: jax.Array, b: jax.Array, *, block: int | None = None) -> jax.Array:
    """Full convolution of two length-K int32 digit vectors -> length 2K.

    (The true convolution has 2K-1 entries; entry 2K-1 is identically
    zero and kept for power-of-two alignment.)
    """
    (k,) = a.shape
    assert b.shape == (k,), f"shape mismatch {a.shape} vs {b.shape}"
    bk = min(block or DEFAULT_BLOCK, k)
    assert k % bk == 0, f"K={k} must be a multiple of the block {bk}"
    kernel = functools.partial(_conv_block_kernel, k=k, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=(2 * k // bk,),
        in_specs=[
            # Whole operands resident per grid step (K int32 = 4K bytes;
            # the HBM->VMEM schedule is expressed by the output BlockSpec).
            pl.BlockSpec((k,), lambda ob: (0,)),
            pl.BlockSpec((k,), lambda ob: (0,)),
        ],
        out_specs=pl.BlockSpec((bk,), lambda ob: (ob,)),
        out_shape=jax.ShapeDtypeStruct((2 * k,), jnp.int32),
        interpret=True,
    )(a, b)


def conv_digits_batched(a: jax.Array, b: jax.Array, *, block: int | None = None) -> jax.Array:
    """vmap of :func:`conv_digits` over a leading batch axis."""
    return jax.vmap(lambda x, y: conv_digits(x, y, block=block))(a, b)

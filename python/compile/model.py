"""L2 — JAX model: batched big-integer products over base-256 digits.

Two compute graphs, both calling the L1 Pallas kernel
(:mod:`compile.kernels.convmul`) and both AOT-lowered by
:mod:`compile.aot` for the Rust runtime:

* :func:`mul_school_batched` — one full-width digit convolution per
  pair, then carry normalization (a ``lax.scan``): the leaf SLIM
  product.
* :func:`mul_karatsuba_batched` — one level of Karatsuba *inside the
  graph*, mirroring the paper's recursion step: three half-width kernel
  convolutions (on signed digit differences — no abs/sign bookkeeping
  is needed at this layer because convolution is bilinear and int32
  digits are signed), recombined and carry-normalized once.

Shapes are static per artifact: ``int32[B, K] x int32[B, K] ->
int32[B, 2K]`` with digits in ``[0, 256)`` (LSB first).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.convmul import conv_digits, conv_digits_batched

BASE_LOG2 = 8
BASE = 1 << BASE_LOG2


def carry_normalize(conv: jax.Array) -> jax.Array:
    """Propagate carries over raw convolution sums (batched, exact).

    ``conv`` is int32[B, 2K] with entries < 2^31; the scan carries an
    int32 per batch lane (carry <= max_conv / 255 stays well inside
    int32).
    """

    def step(carry, col):
        t = col + carry
        return t >> BASE_LOG2, t & (BASE - 1)

    # Scan over the digit axis; batch rides along in the carry/slice.
    carry0 = jnp.zeros(conv.shape[0], jnp.int32)
    _, digits = jax.lax.scan(step, carry0, conv.T)
    return digits.T


def mul_school(a: jax.Array, b: jax.Array) -> jax.Array:
    """Single-pair product: conv kernel + carry normalization."""
    conv = conv_digits(a, b)
    return carry_normalize(conv[None, :])[0]


@jax.jit
def mul_school_batched(a: jax.Array, b: jax.Array) -> jax.Array:
    """Batched leaf product via one full-width kernel convolution."""
    conv = conv_digits_batched(a, b)
    return carry_normalize(conv)


@jax.jit
def mul_karatsuba_batched(a: jax.Array, b: jax.Array) -> jax.Array:
    """Batched product with one in-graph Karatsuba level (paper §6).

    ``c = c0 + s^(K/2)·(c0 + c2 + conv(a0-a1, b1-b0)) + s^K·c2``
    assembled on the raw convolution sums (bilinearity keeps everything
    exact in signed int32), then carry-normalized once.
    """
    batch, k = a.shape
    assert k % 2 == 0, "Karatsuba level needs even K"
    h = k // 2
    a0, a1 = a[:, :h], a[:, h:]
    b0, b1 = b[:, :h], b[:, h:]

    c0 = conv_digits_batched(a0, b0)            # int32[B, K]
    c2 = conv_digits_batched(a1, b1)
    cx = conv_digits_batched(a0 - a1, b1 - b0)  # signed cross term
    c1 = c0 + c2 + cx                           # = conv(a0,b1) + conv(a1,b0)

    conv = jnp.zeros((batch, 2 * k), jnp.int32)
    conv = conv.at[:, :k].add(c0)
    conv = conv.at[:, h : h + k].add(c1)
    conv = conv.at[:, k : 2 * k].add(c2)
    return carry_normalize(conv)


def entry(kind: str):
    """AOT entry point by name (static shape specialization happens at
    lowering time in :mod:`compile.aot`)."""
    return {
        "school": mul_school_batched,
        "karatsuba": mul_karatsuba_batched,
    }[kind]


@functools.lru_cache(maxsize=None)
def lowered(kind: str, batch: int, k: int):
    """Lower an entry for static (batch, K); returns the jax Lowered."""
    spec = jax.ShapeDtypeStruct((batch, k), jnp.int32)
    fn = entry(kind)
    # Tuple return for a stable rust-side unwrap (see aot.py).
    wrapped = jax.jit(lambda x, y: (fn(x, y),))
    return wrapped.lower(spec, spec)

//! # copmul — Communication-Optimal Parallel Integer Multiplication
//!
//! Reproduction of *"Communication-Optimal Parallel Standard and Karatsuba
//! Integer Multiplication in the Distributed Memory Model"*
//! (Lorenzo De Stefani, 2020).
//!
//! The paper's machine model is an abstract distributed-memory parallel
//! computer: `P` processors, each with a private memory of `M` words,
//! exchanging point-to-point messages. Its contributions — the `COPSIM`
//! and `COPK` algorithms plus the parallel `SUM`/`COMPARE`/`DIFF`
//! subroutines — are *coordination* algorithms, so the bulk of this
//! reproduction lives in the Rust layer:
//!
//! * [`bignum`] — exact base-`s` big-integer arithmetic (the digit model of
//!   §2.1) including the sequential `SLIM` (Fact 10) and `SKIM` (Fact 13)
//!   leaf multipliers, with per-call digit-operation counting.
//! * [`sim`] — the machine-model layer behind the [`sim::MachineApi`]
//!   trait: a deterministic cost-model simulator ([`sim::Machine`], with
//!   critical-path accounting per §2.2, Yang–Miller, and per-processor
//!   memory ledgers), a real-threads executor
//!   ([`sim::ThreadedMachine`], one OS thread per simulated processor
//!   with point-to-point message channels), a real-network executor
//!   ([`sim::SocketMachine`], one OS worker process per group of
//!   simulated processors, speaking length-prefixed little-endian
//!   frames over Unix-domain — or optionally TCP — sockets, with the
//!   same clock/ledger semantics as the threaded engine), a seeded
//!   deterministic fault-injection wrapper over any engine
//!   ([`sim::FaultyMachine`] — dropped/duplicated/reordered messages,
//!   stalls, alloc/compute failures, recoverable processor crashes),
//!   the shared collective-communication layer ([`sim::collectives`] —
//!   binomial-tree broadcast/gather/scatter/carry-aware reduce,
//!   pairwise shift/fanout, coalesced all-to-all), and pluggable
//!   network topologies ([`sim::topology`] — fully-connected, 2D
//!   torus, hierarchical two-level cluster, with hop-by-hop routing
//!   and per-link charging in every engine).
//! * [`primitives`] — parallel `SUM`, `COMPARE`, `DIFF` (§4), including the
//!   speculative carry/borrow pre-calculation the paper uses to break the
//!   sequential carry chain.
//! * [`algorithms`] — `COPSIM` (§5) and `COPK` (§6) in both the
//!   memory-independent (all-BFS) and main (DFS→MI) execution modes, plus
//!   the §7 hybrid.
//! * [`baselines`] — the related-work comparison points (naive all-gather
//!   schoolbook; Cesari–Maeder-style master–slave Karatsuba).
//! * [`theory`] — the paper's closed-form upper bounds (Lemmas 7–9,
//!   Theorems 11/12/14/15) and lower bounds (Theorems 3–6) used by the
//!   experiment harness.
//! * [`runtime`] — PJRT/XLA client: loads the AOT-compiled JAX+Pallas leaf
//!   multiplier (`artifacts/*.hlo.txt`) and executes it from the hot path.
//! * [`coordinator`] — the serving layer: a multi-threaded job router
//!   (one machine per job), a sharded multi-job scheduler (ONE shared
//!   machine carved into per-job shards sized by the paper's memory
//!   requirements, with admission control, work-stealing, and fault
//!   recovery — per-job retries with shard-size backoff, safe-mode
//!   final attempts, processor quarantine with probation-based
//!   de-quarantine via verified canary probes, and socket worker
//!   respawn), a dynamic batcher dispatching leaf products to the XLA
//!   runtime, and an always-on serving daemon ([`coordinator::Daemon`]
//!   — seeded open-loop arrivals, per-job deadlines, SLO-aware early
//!   shedding scaled to the live processor count when the machine is
//!   degraded).
//! * [`experiments`] — one module per paper result (E1–E21), each printing
//!   a `paper bound | measured | ratio` table; E15 compares the
//!   cost-model and threaded execution engines, E16 measures the sharded
//!   scheduler's throughput and per-job cost inflation, E17 measures
//!   throughput and cost inflation under injected faults, E18 measures
//!   vs per-topology predictions on both engines, E19 measures the
//!   serving daemon's latency/goodput vs offered open-loop load and the
//!   zero-fault per-job cost identity under that load, E20 measures
//!   strong scaling at fixed per-processor memory across the BFS/DFS
//!   execution modes, and E21 measures goodput recovery under a
//!   rolling-kill soak (worker respawn + probation de-quarantine).
//!
//! See `rust/DESIGN.md` for the architecture notes (including the
//! three-backend execution-engine split) and the experiment index.

pub mod algorithms;
pub mod baselines;
pub mod bignum;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod experiments;
pub mod metrics;
pub mod perf;
pub mod primitives;
pub mod runtime;
pub mod sim;
pub mod theory;
pub mod util;

pub use config::{EngineKind, RunConfig};
pub use sim::{Clock, Machine, MachineApi, Seq, SocketMachine, ThreadedMachine, TopologyKind};

//! Wire-frame plumbing shared by every socket-facing codec: the
//! bounds-checked little-endian [`FrameCursor`] that both the serving
//! daemon's `Request::{encode,decode}` frame (`coordinator::daemon`)
//! and the socket engine's command/reply/net frames (`sim::socket`)
//! parse with.
//!
//! Every read is bounds-checked against the frame buffer *before* any
//! memory is reserved, so a hostile length field on the wire can make a
//! decode fail but never make it allocate: [`FrameCursor::digits`] caps
//! the claimed element count against the remaining bytes first — a
//! `u32::MAX` length costs the attacker a frame rejection, not 16 GiB
//! of reservation on the server (regression-tested below and in
//! `tests/wire_fuzz.rs`).

use crate::error::{anyhow, ensure, Result};

/// Bounds-checked little-endian reader over one frame buffer.
pub struct FrameCursor<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> FrameCursor<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        FrameCursor { buf, at: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.at
    }

    /// Consume the next `n` bytes.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .at
            .checked_add(n)
            .ok_or_else(|| anyhow!("frame length overflow"))?;
        let s = self.buf.get(self.at..end).ok_or_else(|| {
            anyhow!("truncated frame: need {end} bytes, have {}", self.buf.len())
        })?;
        self.at = end;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read `len` little-endian u32 digits. The claimed count is capped
    /// against the remaining buffer BEFORE the output vector is sized:
    /// `len` comes straight off the wire, and a hostile value must cost
    /// a rejection, not an attacker-controlled allocation.
    pub fn digits(&mut self, len: usize) -> Result<Vec<u32>> {
        ensure!(
            len <= self.remaining() / 4,
            "digit count {len} exceeds the {} bytes left in the frame",
            self.remaining()
        );
        let bytes = self.take(len * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Read a `u32` length-prefixed UTF-8 string (same cap discipline
    /// as [`FrameCursor::digits`]).
    pub fn str_lp(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        ensure!(
            len <= self.remaining(),
            "string length {len} exceeds the {} bytes left in the frame",
            self.remaining()
        );
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|e| anyhow!("bad frame string: {e}"))
    }

    /// Assert the whole buffer was consumed (rejects trailing garbage).
    pub fn expect_end(&self) -> Result<()> {
        ensure!(
            self.at == self.buf.len(),
            "trailing garbage: frame ends at {}, buffer has {}",
            self.at,
            self.buf.len()
        );
        Ok(())
    }
}

/// Append a `u32` length-prefixed UTF-8 string (the writer half of
/// [`FrameCursor::str_lp`]).
pub fn push_str_lp(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

/// Append `digits.len()` little-endian u32 digits with a `u32` count
/// prefix (the writer half of a counted [`FrameCursor::digits`] read).
pub fn push_digits_lp(out: &mut Vec<u8>, digits: &[u32]) {
    out.extend_from_slice(&(digits.len() as u32).to_le_bytes());
    for d in digits {
        out.extend_from_slice(&d.to_le_bytes());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reads_are_bounds_checked_and_ordered() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&0xAABBCCDDu32.to_le_bytes());
        buf.push(7);
        buf.extend_from_slice(&42u64.to_le_bytes());
        let mut f = FrameCursor::new(&buf);
        assert_eq!(f.u32().unwrap(), 0xAABBCCDD);
        assert_eq!(f.u8().unwrap(), 7);
        assert_eq!(f.u64().unwrap(), 42);
        f.expect_end().unwrap();
        assert!(f.u8().is_err(), "reading past the end must fail");
    }

    #[test]
    fn hostile_digit_count_is_rejected_before_allocating() {
        // Regression test for the length sanity cap: a frame claiming
        // u32::MAX digits over a 12-byte body must be rejected by the
        // remaining-bytes cap up front — this test would OOM (or page
        // in gigabytes) if `digits` sized its output from the claimed
        // count instead.
        let buf = [0u8; 12];
        let mut f = FrameCursor::new(&buf);
        let err = f.digits(u32::MAX as usize).unwrap_err();
        assert!(
            err.to_string().contains("exceeds"),
            "want the cap error, got: {err}"
        );
        // usize::MAX would overflow a naive len*4; the cap rejects it
        // before any multiply.
        assert!(f.digits(usize::MAX).is_err());
        // The cursor is still usable at its old position.
        assert_eq!(f.digits(3).unwrap(), vec![0, 0, 0]);
    }

    #[test]
    fn string_roundtrip_and_hostile_length() {
        let mut buf = Vec::new();
        push_str_lp(&mut buf, "unix:/tmp/x.sock");
        let mut f = FrameCursor::new(&buf);
        assert_eq!(f.str_lp().unwrap(), "unix:/tmp/x.sock");
        f.expect_end().unwrap();

        let mut bad = Vec::new();
        bad.extend_from_slice(&u32::MAX.to_le_bytes());
        let mut f = FrameCursor::new(&bad);
        assert!(f.str_lp().is_err());
    }

    #[test]
    fn trailing_garbage_is_detected() {
        let mut buf = Vec::new();
        push_digits_lp(&mut buf, &[1, 2]);
        buf.push(0xFF);
        let mut f = FrameCursor::new(&buf);
        let n = f.u32().unwrap() as usize;
        assert_eq!(f.digits(n).unwrap(), vec![1, 2]);
        assert!(f.expect_end().is_err());
    }
}

//! Minimal JSON parser (offline replacement for `serde_json`).
//!
//! Supports the full JSON grammar minus exotic number forms; enough for
//! `artifacts/manifest.json` and config files. Strict where it matters
//! (strings, nesting, commas), permissive about whitespace.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: src.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as u64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse failure with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) => out.push(c as char),
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let src = r#"{
            "format": "hlo-text",
            "artifacts": [
                {"file": "a.hlo.txt", "batch": 8, "k": 256},
                {"file": "b.hlo.txt", "batch": 1, "k": 1024}
            ]
        }"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("format").unwrap().as_str(), Some("hlo-text"));
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 2);
        assert_eq!(arts[0].get("batch").unwrap().as_u64(), Some(8));
        assert_eq!(arts[1].get("k").unwrap().as_u64(), Some(1024));
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn nested_roundtrip_values() {
        let j = Json::parse(r#"{"a": [1, {"b": [true, null]}]}"#).unwrap();
        let a = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_u64(), Some(1));
    }
}

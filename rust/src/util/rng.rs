//! Deterministic PRNG: xoshiro256++ seeded by SplitMix64.
//!
//! Self-contained replacement for the `rand` crate (not available in this
//! offline environment). All experiments and tests use fixed seeds so runs
//! are reproducible bit-for-bit.

/// xoshiro256++ PRNG (Blackman & Vigna). Deterministic, fast, and good
/// enough statistically for workload generation and property tests.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator deterministically from a single u64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's method (bound > 0).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // 128-bit multiply rejection-free-enough variant; for simulation
        // workloads the tiny modulo bias of the simple method would also
        // be fine, but do it properly.
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (u64::MAX - bound + 1) % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Random boolean.
    #[inline]
    pub fn coin(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Random f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Pick a random element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Random digit vector of length `n` in base `2^log2_base`
    /// (LSB-first, last digit forced nonzero unless n == 0).
    pub fn digits(&mut self, n: usize, log2_base: u32) -> Vec<u32> {
        let base = 1u64 << log2_base;
        let mut v: Vec<u32> = (0..n).map(|_| self.below(base) as u32).collect();
        if n > 0 && v[n - 1] == 0 {
            v[n - 1] = self.range(1, base - 1) as u32;
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
        }
        for _ in 0..1000 {
            let x = r.range(5, 9);
            assert!((5..=9).contains(&x));
        }
    }

    #[test]
    fn digits_shape() {
        let mut r = Rng::new(3);
        let d = r.digits(32, 16);
        assert_eq!(d.len(), 32);
        assert!(*d.last().unwrap() > 0);
        assert!(d.iter().all(|&x| (x as u64) < (1u64 << 16)));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }
}

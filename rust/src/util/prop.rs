//! Micro property-testing harness (offline replacement for `proptest`).
//!
//! `check(name, cases, |rng| ...)` runs a closure over `cases` random
//! seeds; on failure it reports the failing seed so the case can be
//! replayed exactly with `replay(seed, f)`.
//!
//! `check_shrink(name, cases, gen, shrink, f)` additionally minimizes a
//! failing case: the property is split into a *generator* (draws the
//! case shape from the rng) and a *shrink hook* (proposes smaller
//! shapes, e.g. smaller `n`, then smaller `P`); on failure the harness
//! greedily walks the shrink candidates and reports the smallest shape
//! that still fails alongside the original seed.

use super::rng::Rng;

/// Outcome of a single property case.
pub type CaseResult = Result<(), String>;

/// Case-count knob for expensive property suites: returns the
/// `COPMUL_PROP_CASES` environment variable when it is set and parses,
/// the suite's default otherwise. Tier-1 CI keeps the fast defaults;
/// the dedicated differential CI job raises it (and a developer can
/// lower it for a quick local iteration).
pub fn cases(default: u64) -> u64 {
    std::env::var("COPMUL_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Run `f` for `cases` deterministic seeds derived from `name`.
/// Panics with the failing seed embedded in the message.
pub fn check<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> CaseResult,
{
    let base = fnv1a(name.as_bytes());
    for i in 0..cases {
        let seed = base ^ (i.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property `{name}` failed at case {i} (seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<F>(seed: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> CaseResult,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("replay(seed {seed:#x}) failed: {msg}");
    }
}

/// Hard ceiling on shrink iterations: the hooks propose strictly
/// smaller cases, so real searches terminate long before this; the cap
/// only guards against a buggy non-shrinking hook.
const MAX_SHRINK_STEPS: usize = 256;

/// Greedy minimization (see module docs): starting from the failing
/// `case`, repeatedly move to the first candidate from `shrink` that
/// still fails, until no candidate fails. Each candidate is re-run with
/// a fresh rng from the case's own seed, so the search is fully
/// deterministic. Returns `(smallest failing case, its message, steps)`.
pub fn shrink_failure<T, S, F>(
    seed: u64,
    case: T,
    msg: String,
    shrink: S,
    f: &mut F,
) -> (T, String, usize)
where
    T: Clone,
    S: Fn(&T) -> Vec<T>,
    F: FnMut(&mut Rng, &T) -> CaseResult,
{
    let mut cur = case;
    let mut cur_msg = msg;
    let mut steps = 0;
    while steps < MAX_SHRINK_STEPS {
        let mut advanced = false;
        for cand in shrink(&cur) {
            let mut rng = Rng::new(seed);
            if let Err(m) = f(&mut rng, &cand) {
                cur = cand;
                cur_msg = m;
                steps += 1;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    (cur, cur_msg, steps)
}

/// [`check`] with failing-case minimization. `gen` draws the case shape
/// from the rng; `f` runs the property for a given shape (drawing any
/// further randomness — operands — from the same rng); `shrink`
/// proposes smaller shapes in preference order (convention: shrink the
/// problem size `n` first, then the processor count `P`). On failure
/// the panic reports the original seed AND the smallest still-failing
/// shape, so the replay starts from the minimal reproduction.
pub fn check_shrink<T, G, S, F>(name: &str, cases: u64, mut gen: G, shrink: S, mut f: F)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    S: Fn(&T) -> Vec<T>,
    F: FnMut(&mut Rng, &T) -> CaseResult,
{
    let base = fnv1a(name.as_bytes());
    for i in 0..cases {
        let seed = base ^ (i.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        let case = gen(&mut rng);
        if let Err(msg) = f(&mut rng, &case) {
            let (small, small_msg, steps) =
                shrink_failure(seed, case.clone(), msg.clone(), &shrink, &mut f);
            panic!(
                "property `{name}` failed at case {i} (seed {seed:#x}): {msg}\n\
                 original case: {case:?}\n\
                 shrunk in {steps} step(s) to: {small:?} ({small_msg})"
            );
        }
    }
}

/// Assert-like helper producing `CaseResult`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Equality helper producing `CaseResult` with both sides in the message.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes() {
        check("always-true", 16, |rng| {
            let x = rng.below(100);
            crate::prop_assert!(x < 100, "x = {x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property `always-false`")]
    fn check_reports_failure() {
        check("always-false", 4, |_| Err("nope".into()));
    }

    #[test]
    fn fnv_distinct() {
        assert_ne!(fnv1a(b"a"), fnv1a(b"b"));
    }

    /// The shrink hook used by the shrinker's own tests: smaller n
    /// first (halve, then decrement), then smaller p (halve).
    fn shrink_np(c: &(usize, usize)) -> Vec<(usize, usize)> {
        let (n, p) = *c;
        let mut out = Vec::new();
        if n > 1 {
            out.push((n / 2, p));
            out.push((n - 1, p));
        }
        if p > 1 {
            out.push((n, p / 2));
        }
        out
    }

    #[test]
    fn shrinker_finds_the_minimal_failing_case() {
        // Property fails iff n >= 10 && p >= 2: the minimum failing
        // case reachable by the hook is exactly (10, 2).
        let mut f = |_rng: &mut Rng, c: &(usize, usize)| -> CaseResult {
            if c.0 >= 10 && c.1 >= 2 {
                Err(format!("boom at {c:?}"))
            } else {
                Ok(())
            }
        };
        let (small, msg, steps) = shrink_failure(7, (96, 8), "boom".into(), shrink_np, &mut f);
        assert_eq!(small, (10, 2), "after {steps} steps: {msg}");
        assert!(steps > 0);
        // Shrinking a case the hook cannot reduce reports it unchanged.
        let (small, _, steps) = shrink_failure(7, (10, 2), "boom".into(), shrink_np, &mut f);
        assert_eq!(small, (10, 2));
        assert_eq!(steps, 0);
    }

    #[test]
    fn shrinker_respawn_count_is_linear_in_steps() {
        // Models the socket-engine differential leg, where every
        // property evaluation boots a worker-process fleet (here: bumps
        // a counter). The greedy shrinker evaluates at most one
        // candidate sweep per step plus one final non-advancing sweep,
        // so a seeded failure that minimizes to the smallest (n, P) in
        // `steps` steps respawns O(steps) fleets — not the exponential
        // blowup a branching search over the candidate tree would cost.
        let respawns = std::cell::Cell::new(0usize);
        let mut f = |_rng: &mut Rng, c: &(usize, usize)| -> CaseResult {
            respawns.set(respawns.get() + 1);
            if c.0 >= 10 && c.1 >= 2 {
                Err(format!("socket leg diverged at {c:?}"))
            } else {
                Ok(())
            }
        };
        let (small, _, steps) =
            shrink_failure(0x50C, (96usize, 8usize), "seed failure".into(), shrink_np, &mut f);
        assert_eq!(small, (10, 2), "must minimize to the smallest failing (n, P)");
        // shrink_np proposes at most 3 candidates per shape; each of the
        // `steps` advancing rounds stops at its first failing candidate,
        // and the one terminal round runs the full sweep.
        let bound = 3 * (steps + 1);
        assert!(
            respawns.get() <= bound,
            "{} fleet respawns over {steps} shrink steps (bound {bound}): \
             the shrinker is re-running cases superlinearly",
            respawns.get()
        );
    }

    #[test]
    fn shrinker_terminates_on_non_shrinking_hooks() {
        // A pathological hook that proposes the same case forever must
        // hit the step ceiling, not loop.
        let mut f = |_: &mut Rng, _: &(usize, usize)| -> CaseResult { Err("always".into()) };
        let same = |c: &(usize, usize)| vec![*c];
        let (_, _, steps) = shrink_failure(1, (4, 4), "always".into(), same, &mut f);
        assert_eq!(steps, MAX_SHRINK_STEPS);
    }

    #[test]
    #[should_panic(expected = "shrunk in")]
    fn check_shrink_reports_original_and_minimal() {
        check_shrink(
            "shrinking-property",
            4,
            |rng| (rng.range(50, 100) as usize, 4usize),
            shrink_np,
            |_rng, c| {
                crate::prop_assert!(c.0 < 10, "n = {} too big", c.0);
                Ok(())
            },
        );
    }

    #[test]
    fn check_shrink_passes_quiet_properties() {
        check_shrink(
            "shrinking-property-ok",
            8,
            |rng| (rng.range(1, 8) as usize, 2usize),
            shrink_np,
            |_rng, c| {
                crate::prop_assert!(c.0 <= 8, "impossible");
                let _ = c;
                Ok(())
            },
        );
    }

    #[test]
    fn cases_defaults_without_env() {
        // The test runner may export COPMUL_PROP_CASES; only assert the
        // default path when it is absent.
        if std::env::var("COPMUL_PROP_CASES").is_err() {
            assert_eq!(cases(17), 17);
        } else {
            let _ = cases(17); // must not panic on any env value
        }
    }
}

//! Micro property-testing harness (offline replacement for `proptest`).
//!
//! `check(name, cases, |rng| ...)` runs a closure over `cases` random
//! seeds; on failure it reports the failing seed so the case can be
//! replayed exactly with `replay(seed, f)`.

use super::rng::Rng;

/// Outcome of a single property case.
pub type CaseResult = Result<(), String>;

/// Case-count knob for expensive property suites: returns the
/// `COPMUL_PROP_CASES` environment variable when it is set and parses,
/// the suite's default otherwise. Tier-1 CI keeps the fast defaults;
/// the dedicated differential CI job raises it (and a developer can
/// lower it for a quick local iteration).
pub fn cases(default: u64) -> u64 {
    std::env::var("COPMUL_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Run `f` for `cases` deterministic seeds derived from `name`.
/// Panics with the failing seed embedded in the message.
pub fn check<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> CaseResult,
{
    let base = fnv1a(name.as_bytes());
    for i in 0..cases {
        let seed = base ^ (i.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property `{name}` failed at case {i} (seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<F>(seed: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> CaseResult,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("replay(seed {seed:#x}) failed: {msg}");
    }
}

/// Assert-like helper producing `CaseResult`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Equality helper producing `CaseResult` with both sides in the message.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes() {
        check("always-true", 16, |rng| {
            let x = rng.below(100);
            crate::prop_assert!(x < 100, "x = {x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property `always-false`")]
    fn check_reports_failure() {
        check("always-false", 4, |_| Err("nope".into()));
    }

    #[test]
    fn fnv_distinct() {
        assert_ne!(fnv1a(b"a"), fnv1a(b"b"));
    }

    #[test]
    fn cases_defaults_without_env() {
        // The test runner may export COPMUL_PROP_CASES; only assert the
        // default path when it is absent.
        if std::env::var("COPMUL_PROP_CASES").is_err() {
            assert_eq!(cases(17), 17);
        } else {
            let _ = cases(17); // must not panic on any env value
        }
    }
}

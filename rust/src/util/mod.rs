//! Small self-contained utilities: deterministic PRNG, integer helpers,
//! and a micro property-testing harness.
//!
//! This build environment is fully offline with only the `xla` crate's
//! dependency closure vendored, so the usual ecosystem crates (`rand`,
//! `proptest`, `criterion`) are replaced by the minimal implementations in
//! this module.

pub mod frame;
pub mod json;
pub mod prop;
pub mod rng;

pub use rng::Rng;

/// `ceil(a / b)` for unsigned integers.
#[inline]
pub fn div_ceil(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// `true` iff `x` is a power of two (0 is not).
#[inline]
pub fn is_pow2(x: u64) -> bool {
    x != 0 && (x & (x - 1)) == 0
}

/// Round `x` up to the next power of two (identity on powers of two).
#[inline]
pub fn next_pow2(x: u64) -> u64 {
    if x <= 1 {
        return 1;
    }
    1u64 << (64 - (x - 1).leading_zeros())
}

/// `floor(log2 x)` for `x >= 1`.
#[inline]
pub fn ilog2(x: u64) -> u32 {
    debug_assert!(x >= 1);
    63 - x.leading_zeros()
}

/// `log2(x)` for an exact power of two.
#[inline]
pub fn exact_log2(x: u64) -> u32 {
    debug_assert!(is_pow2(x), "{x} is not a power of two");
    x.trailing_zeros()
}

/// `n^(log2 3)`, the Karatsuba exponent, as f64.
#[inline]
pub fn pow_log2_3(n: f64) -> f64 {
    n.powf(3f64.log2())
}

/// `p^(log3 2)` as f64 (appears in the COPK memory bounds).
#[inline]
pub fn pow_log3_2(p: f64) -> f64 {
    p.powf(2f64.log(3.0))
}

/// `true` iff `p` is of the form `4 * 3^i` (the COPK processor-count shape).
pub fn is_copk_procs(p: u64) -> bool {
    if p % 4 != 0 {
        return false;
    }
    let mut q = p / 4;
    while q % 3 == 0 {
        q /= 3;
    }
    q == 1
}

/// Number of BFS levels for COPK: `i` such that `p = 4 * 3^i`.
pub fn copk_bfs_levels(p: u64) -> u32 {
    debug_assert!(is_copk_procs(p));
    let mut q = p / 4;
    let mut i = 0;
    while q > 1 {
        q /= 3;
        i += 1;
    }
    i
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_div_ceil() {
        assert_eq!(div_ceil(10, 3), 4);
        assert_eq!(div_ceil(9, 3), 3);
        assert_eq!(div_ceil(1, 8), 1);
    }

    #[test]
    fn test_pow2_helpers() {
        assert!(is_pow2(1));
        assert!(is_pow2(64));
        assert!(!is_pow2(0));
        assert!(!is_pow2(48));
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(3), 4);
        assert_eq!(next_pow2(64), 64);
        assert_eq!(next_pow2(65), 128);
        assert_eq!(ilog2(1), 0);
        assert_eq!(ilog2(1024), 10);
        assert_eq!(exact_log2(256), 8);
    }

    #[test]
    fn test_copk_procs() {
        assert!(is_copk_procs(4));
        assert!(is_copk_procs(12));
        assert!(is_copk_procs(36));
        assert!(is_copk_procs(108));
        assert!(!is_copk_procs(8));
        assert!(!is_copk_procs(6));
        assert!(!is_copk_procs(16));
        assert_eq!(copk_bfs_levels(4), 0);
        assert_eq!(copk_bfs_levels(12), 1);
        assert_eq!(copk_bfs_levels(108), 3);
    }

    #[test]
    fn test_karatsuba_exponent() {
        let v = pow_log2_3(2.0);
        assert!((v - 3.0).abs() < 1e-12);
        let w = pow_log3_2(3.0);
        assert!((w - 2.0).abs() < 1e-12);
    }
}

//! Run configuration: defaults, `key=value` overrides (CLI), and a
//! minimal config-file format (same `key = value` lines, `#` comments)
//! — serde/toml are not available in this offline build.

use crate::algorithms::{Algorithm, ExecPolicy};
use crate::bignum::Base;
use crate::error::{bail, Context, Result};
use crate::sim::TopologyKind;
use crate::theory::TimeModel;

/// Which execution engine runs the machine model (see `sim::MachineApi`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EngineKind {
    /// Deterministic cost-model simulator (single host thread,
    /// critical-path logical clocks).
    #[default]
    Sim,
    /// Real execution: one OS thread per simulated processor.
    Threads,
    /// Real network: one OS worker process per group of simulated
    /// processors, commands and messages over socket frames.
    Sockets,
}

impl std::str::FromStr for EngineKind {
    type Err = crate::error::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "sim" | "cost" | "cost-model" => EngineKind::Sim,
            "threads" | "threaded" => EngineKind::Threads,
            "sockets" | "socket" => EngineKind::Sockets,
            _ => bail!("unknown engine `{s}` (sim|threads|sockets)"),
        })
    }
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineKind::Sim => write!(f, "sim"),
            EngineKind::Threads => write!(f, "threads"),
            EngineKind::Sockets => write!(f, "sockets"),
        }
    }
}

/// Which sequential leaf backend the recursion bottoms out on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LeafKind {
    Slim,
    Skim,
    School,
    Hybrid,
    /// AOT-compiled JAX+Pallas artifact via PJRT.
    Xla,
    /// XLA with coordinator-level dynamic batching.
    XlaBatched,
}

impl std::str::FromStr for LeafKind {
    type Err = crate::error::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "slim" => LeafKind::Slim,
            "skim" => LeafKind::Skim,
            "school" => LeafKind::School,
            "hybrid" => LeafKind::Hybrid,
            "xla" => LeafKind::Xla,
            "xla-batched" => LeafKind::XlaBatched,
            _ => bail!("unknown leaf backend `{s}` (slim|skim|school|hybrid|xla|xla-batched)"),
        })
    }
}

/// Top-level run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Input size in machine-base digits.
    pub n: usize,
    /// Simulated processors.
    pub procs: usize,
    /// Per-processor memory cap (words); None = unbounded.
    pub mem_cap: Option<u64>,
    /// Digit base = 2^base_log2.
    pub base_log2: u32,
    /// Forced algorithm; None = hybrid dispatch.
    pub algo: Option<Algorithm>,
    /// Execution-mode policy: DFS (paper default), auto (spend surplus
    /// memory on BFS when it cuts BW), or explicit BFS.
    pub exec_mode: ExecPolicy,
    pub leaf: LeafKind,
    /// Execution engine: cost-model simulator or real threads.
    pub engine: EngineKind,
    /// Network topology the machine(s) simulate/route over.
    pub topology: TopologyKind,
    pub seed: u64,
    pub artifacts_dir: String,
    pub time_model: TimeModel,
    /// Coordinator worker threads.
    pub workers: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            n: 4096,
            procs: 16,
            mem_cap: None,
            base_log2: 16,
            algo: None,
            exec_mode: ExecPolicy::Dfs,
            leaf: LeafKind::Skim,
            engine: EngineKind::Sim,
            topology: TopologyKind::FullyConnected,
            seed: 42,
            artifacts_dir: "artifacts".into(),
            time_model: TimeModel::default(),
            workers: 4,
        }
    }
}

impl RunConfig {
    pub fn base(&self) -> Base {
        Base::new(self.base_log2)
    }

    /// Apply one `key=value` override.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "n" => self.n = value.parse().context("n")?,
            "procs" | "p" => self.procs = value.parse().context("procs")?,
            "mem" | "mem_cap" => {
                self.mem_cap = if value == "unbounded" {
                    None
                } else {
                    Some(value.parse().context("mem_cap")?)
                }
            }
            "base_log2" => self.base_log2 = value.parse().context("base_log2")?,
            "algo" => {
                self.algo = match value {
                    "copsim" => Some(Algorithm::Copsim),
                    "copk" => Some(Algorithm::Copk),
                    "hybrid" | "auto" => None,
                    _ => bail!("unknown algo `{value}` (copsim|copk|hybrid)"),
                }
            }
            "leaf" => self.leaf = value.parse()?,
            // Accepted both as `engine=threads` and as the CLI flag
            // spelling `--engine=threads` (likewise `topology` and
            // `exec-mode`).
            "engine" | "--engine" => self.engine = value.parse()?,
            "topology" | "--topology" => self.topology = value.parse()?,
            "exec-mode" | "exec_mode" | "--exec-mode" => {
                self.exec_mode = ExecPolicy::parse(value)?
            }
            "seed" => self.seed = value.parse().context("seed")?,
            "artifacts" | "artifacts_dir" => self.artifacts_dir = value.to_string(),
            "workers" => self.workers = value.parse().context("workers")?,
            "alpha_ns" => self.time_model.alpha_ns = value.parse().context("alpha_ns")?,
            "beta_ns" => self.time_model.beta_ns = value.parse().context("beta_ns")?,
            "gamma_ns" => self.time_model.gamma_ns = value.parse().context("gamma_ns")?,
            _ => bail!("unknown config key `{key}`"),
        }
        Ok(())
    }

    /// Apply a list of `key=value` strings (CLI tail arguments).
    pub fn apply_args(&mut self, args: &[String]) -> Result<()> {
        for arg in args {
            let (k, v) = arg
                .split_once('=')
                .with_context(|| format!("expected key=value, got `{arg}`"))?;
            self.set(k.trim(), v.trim())?;
        }
        Ok(())
    }

    /// Load `key = value` lines from a file (`#` comments allowed).
    pub fn load_file(&mut self, path: &str) -> Result<()> {
        let src = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        for (lineno, line) in src.lines().enumerate() {
            let line = line.split('#').next().unwrap().trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("{path}:{}: expected key = value", lineno + 1))?;
            self.set(k.trim(), v.trim())
                .with_context(|| format!("{path}:{}", lineno + 1))?;
        }
        Ok(())
    }

    /// Validate the (n, P, M) shape against the paper's requirements.
    pub fn validate(&self) -> Result<()> {
        use crate::algorithms::copsim::is_pow4;
        use crate::util::is_copk_procs;
        let p = self.procs as u64;
        match self.algo {
            Some(Algorithm::Copsim) if !is_pow4(self.procs) => {
                bail!("COPSIM needs procs = 4^k, got {p}")
            }
            Some(Algorithm::Copk) if !(p == 1 || is_copk_procs(p)) => {
                bail!("COPK needs procs = 4·3^i, got {p}")
            }
            None if !is_pow4(self.procs) && !is_copk_procs(p) && p != 1 => {
                bail!("procs = {p} fits neither COPSIM (4^k) nor COPK (4·3^i)")
            }
            _ => {}
        }
        if let Some(m) = self.mem_cap {
            if m < (self.n as u64) * 2 / (self.procs as u64).max(1) {
                bail!(
                    "mem_cap {m} cannot even hold the input chunks \
                     (need >= 2n/P = {})",
                    2 * self.n as u64 / self.procs as u64
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overrides_parse() {
        let mut c = RunConfig::default();
        c.apply_args(&[
            "n=1024".into(),
            "procs=64".into(),
            "algo=copsim".into(),
            "leaf=school".into(),
            "mem=4096".into(),
        ])
        .unwrap();
        assert_eq!(c.n, 1024);
        assert_eq!(c.procs, 64);
        assert_eq!(c.algo, Some(Algorithm::Copsim));
        assert_eq!(c.leaf, LeafKind::School);
        assert_eq!(c.mem_cap, Some(4096));
        c.validate().unwrap();
    }

    #[test]
    fn engine_flag_parses_both_spellings() {
        let mut c = RunConfig::default();
        assert_eq!(c.engine, EngineKind::Sim);
        c.apply_args(&["engine=threads".into()]).unwrap();
        assert_eq!(c.engine, EngineKind::Threads);
        c.apply_args(&["--engine=sim".into()]).unwrap();
        assert_eq!(c.engine, EngineKind::Sim);
        c.apply_args(&["engine=sockets".into()]).unwrap();
        assert_eq!(c.engine, EngineKind::Sockets);
        c.apply_args(&["--engine=socket".into()]).unwrap();
        assert_eq!(c.engine, EngineKind::Sockets);
        assert!(c.set("engine", "gpu").is_err());
    }

    #[test]
    fn topology_flag_parses_both_spellings() {
        let mut c = RunConfig::default();
        assert_eq!(c.topology, TopologyKind::FullyConnected);
        c.apply_args(&["topology=torus".into()]).unwrap();
        assert_eq!(c.topology, TopologyKind::Torus);
        c.apply_args(&["--topology=hier".into()]).unwrap();
        assert_eq!(c.topology, TopologyKind::Hier);
        c.apply_args(&["--topology=fully-connected".into()]).unwrap();
        assert_eq!(c.topology, TopologyKind::FullyConnected);
        assert!(c.set("topology", "hypercube").is_err());
    }

    #[test]
    fn exec_mode_flag_parses_both_spellings() {
        let mut c = RunConfig::default();
        assert_eq!(c.exec_mode, ExecPolicy::Dfs);
        c.apply_args(&["exec-mode=auto".into()]).unwrap();
        assert_eq!(c.exec_mode, ExecPolicy::Auto);
        c.apply_args(&["--exec-mode=bfs".into()]).unwrap();
        assert_eq!(c.exec_mode, ExecPolicy::Bfs);
        c.apply_args(&["exec_mode=dfs".into()]).unwrap();
        assert_eq!(c.exec_mode, ExecPolicy::Dfs);
        assert!(c.set("exec-mode", "breadth").is_err());
    }

    #[test]
    fn rejects_bad_keys_and_shapes() {
        let mut c = RunConfig::default();
        assert!(c.set("bogus", "1").is_err());
        assert!(c.set("algo", "toomcook").is_err());
        c.procs = 8;
        c.algo = Some(Algorithm::Copsim);
        assert!(c.validate().is_err());
    }

    #[test]
    fn loads_file() {
        let path = std::env::temp_dir().join("copmul-config-test.conf");
        std::fs::write(&path, "# comment\nn = 2048\nprocs = 12\nalgo = copk\n").unwrap();
        let mut c = RunConfig::default();
        c.load_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c.n, 2048);
        assert_eq!(c.procs, 12);
        assert_eq!(c.algo, Some(Algorithm::Copk));
        c.validate().unwrap();
    }
}

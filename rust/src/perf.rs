//! `copmul bench` — the wall-clock measurement harness behind the
//! repo's `BENCH_*.json` perf trajectory.
//!
//! Seven sections, all recorded per run into one JSON artifact
//! (`BENCH_10.json` by default; CI's `perf-smoke` and `serve-soak` jobs
//! upload it and `BENCH_HISTORY.md` tracks the dated in-tree trail):
//!
//! * **engine grid** — end-to-end wall-clock of both execution engines
//!   across (scheme × n × P) at the default base 2^16, with the cost
//!   triple alongside (the triple is engine- and layout-invariant; the
//!   wall-clock is what this PR series moves).
//! * **kernels** — every rung of the kernel ladder
//!   ([`bignum::arch::ladder`]) at identical closed-form charges:
//!   reference vs packed64 vs generic vs (where detected) simd, across
//!   widths and bases — the microscopic source of the macroscopic wins,
//!   and the per-host evidence behind the dispatch default.
//! * **leaf-width sweep** — [`bignum::slim_with_leaf`] and
//!   [`bignum::skim_with_leaf`] across leaf widths per base: the
//!   evidence the applied PR-6 `leaf_widths` table rests on (wall
//!   *and* charged T per width — see [`bignum::mul::leaf_widths`] and
//!   DESIGN.md's "Leaf-width re-tune" re-bless record).
//! * **serving** — the open-loop serving curve (`copmul daemon`): per
//!   engine and arrival process, offered load vs goodput with latency
//!   percentiles and shed/retry counts — the section PR 7's always-on
//!   daemon reports its trajectory through.
//! * **socket** — measured socket-engine wall-clock vs the §2.2 model
//!   prediction `α·T + β·L + γ·BW` on the same cost-model clocks: real
//!   worker processes over Unix-domain sockets, cross-checked for
//!   product and cost-triple identity against the simulator. Empty
//!   when no worker binary is resolvable on the host.
//! * **strong_scaling** — the E20 fixed-(n, M) sweep: per (P, topology)
//!   cell, the auto-selected execution mode with DFS / auto / predicted
//!   charged bandwidth, including the memory-bound cliff rows where no
//!   schedule fits the cap (PR 9's memory-adaptive BFS/DFS execution).
//! * **recovery** — the E21 rolling-kill soak: goodput under sustained
//!   processor loss vs the clean run per engine, with the self-healing
//!   counters (quarantine events, probation re-admissions, probes,
//!   socket worker respawns). The soak's own assertions (capacity
//!   re-admitted, goodput within [`RECOVERY_FACTOR`]) gate the bench —
//!   a report is only written when the machine actually self-healed.
//!
//! [`RECOVERY_FACTOR`]: crate::experiments::rolling_chaos::RECOVERY_FACTOR

use crate::algorithms::leaf::{leaf_ref, LeafRef, SchoolLeaf, SkimLeaf};
use crate::algorithms::{copk_mi, copsim_mi, Algorithm, ExecPolicy};
use crate::experiments::rolling_chaos::{soak_cells, RecoveryCell};
use crate::experiments::strong_scaling::{sweep_cells, ScalingCell};
use crate::bignum::{self, arch, Base, Ops};
use crate::config::EngineKind;
use crate::coordinator::{
    run_open_loop, ArrivalGen, Daemon, DaemonConfig, OpenLoop, SchedulerConfig, Workload,
};
use crate::error::{ensure, Result};
use crate::metrics::{fmt_u64, Table};
use crate::sim::{
    socket_available, Clock, DistInt, Machine, MachineApi, Seq, SocketMachine, ThreadedMachine,
};
use crate::theory::TimeModel;
use crate::util::Rng;
use std::time::{Duration, Instant};

/// Bench configuration (CLI: `copmul bench [--smoke] [seed=...]`).
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// CI-sized grid: smaller n ceilings, fewer kernel widths.
    pub smoke: bool,
    pub seed: u64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            smoke: false,
            seed: 42,
        }
    }
}

/// One engine-grid measurement.
#[derive(Clone, Debug)]
pub struct EngineCell {
    pub scheme: &'static str,
    pub engine: &'static str,
    pub n: usize,
    pub procs: usize,
    pub base_log2: u32,
    pub wall: Duration,
    pub clock: Clock,
    pub mem_peak: u64,
}

/// One kernel micro-benchmark measurement.
#[derive(Clone, Debug)]
pub struct KernelCell {
    pub kernel: &'static str,
    pub n: usize,
    pub base_log2: u32,
    pub iters: u64,
    pub ns_per_iter: f64,
}

/// One leaf-width sweep point.
#[derive(Clone, Debug)]
pub struct LeafCell {
    /// Which recursive multiplier was swept (`slim` or `skim`).
    pub scheme: &'static str,
    pub leaf_width: usize,
    pub n: usize,
    pub base_log2: u32,
    pub wall: Duration,
    /// Charged digit ops at this width — the model-side cost of moving
    /// the constant (bit-exact, so any change is a golden re-bless).
    pub ops: u64,
}

/// One serving-curve measurement: a seeded open-loop run against the
/// daemon at one offered rate.
#[derive(Clone, Debug)]
pub struct ServingCell {
    pub engine: &'static str,
    /// Arrival process (`poisson` or `bursty`).
    pub arrival: &'static str,
    /// Offered arrival rate, jobs/s (bursty: the on-phase rate).
    pub offered_rate: f64,
    pub offered: u64,
    pub completed: u64,
    /// Load-regulation sheds (SLO-early + queue-full + deadline-expired).
    pub shed: u64,
    pub retries: u64,
    pub goodput_per_s: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub p999_us: u64,
    pub wall_ms: u64,
}

/// One socket-engine measured-vs-predicted point: real worker
/// processes over UDS, with the §2.2 prediction from the (identical)
/// cost-model clock alongside.
#[derive(Clone, Debug)]
pub struct SocketCell {
    pub scheme: &'static str,
    pub n: usize,
    pub procs: usize,
    pub base_log2: u32,
    /// Measured wall-clock over real sockets.
    pub wall: Duration,
    /// Cost triple (asserted identical to the simulator's).
    pub clock: Clock,
    /// §2.2 predicted time `α·T + β·L + γ·BW` in ms.
    pub predicted_ms: f64,
}

/// The full bench report; serializes to the `BENCH_*.json` schema.
#[derive(Clone, Debug, Default)]
pub struct BenchReport {
    /// The ladder rung [`arch::active`] picked on this host (records
    /// the `COPMUL_KERNEL` pin when CI sets one).
    pub kernel_selected: &'static str,
    /// The SIMD instruction set detected at runtime (`none` if absent).
    pub simd_isa: &'static str,
    pub engine_grid: Vec<EngineCell>,
    pub kernels: Vec<KernelCell>,
    pub leaf_sweep: Vec<LeafCell>,
    pub serving: Vec<ServingCell>,
    /// Empty when no worker binary resolves on this host.
    pub socket: Vec<SocketCell>,
    /// The E20 fixed-(n, M) strong-scaling sweep (memory-adaptive
    /// execution modes); infeasible cells are the memory-bound cliff.
    pub strong_scaling: Vec<ScalingCell>,
    /// The E21 rolling-kill soak: goodput under sustained processor
    /// loss vs clean, plus the self-healing counters (socket leg
    /// present only when a worker binary resolves).
    pub recovery: Vec<RecoveryCell>,
}

/// Run one multiplication end to end on an engine (mirrors the E15
/// runner): scatter → MI scheme → gather, timed around the whole span
/// (the gather synchronizes with all in-flight worker activity).
fn run_once<M: MachineApi>(
    m: &mut M,
    scheme: &'static str,
    seq: &Seq,
    a: &[u32],
    b: &[u32],
    leaf: &LeafRef,
) -> Result<(Vec<u32>, Duration)> {
    let w = a.len() / seq.len();
    let t0 = Instant::now();
    let da = DistInt::scatter(m, seq, a, w)?;
    let db = DistInt::scatter(m, seq, b, w)?;
    let c = match scheme {
        "copsim" => copsim_mi(m, seq, da, db, leaf)?,
        _ => copk_mi(m, seq, da, db, leaf)?,
    };
    let product = c.gather(m)?;
    Ok((product, t0.elapsed()))
}

fn engine_grid(cfg: &BenchConfig, report: &mut BenchReport) -> Result<()> {
    let base = Base::default();
    // Scheme-natural leaves, as in E15: schoolbook keeps COPSIM's
    // comparison about execution, COPK keeps its Karatsuba leaf.
    // COPK's n are multiples of its P = 4·3^i processor shapes.
    let copsim_n: &[usize] = if cfg.smoke {
        &[1024, 4096]
    } else {
        &[1024, 4096, 16384]
    };
    let copk_n: &[usize] = if cfg.smoke { &[3072] } else { &[3072, 12288] };
    let schemes = [
        ("copsim", 16usize, copsim_n, leaf_ref(SchoolLeaf)),
        ("copk", 12, copk_n, leaf_ref(SkimLeaf)),
    ];
    for (scheme, procs, n_list, leaf) in &schemes {
        let (scheme, procs, n_list) = (*scheme, *procs, *n_list);
        for &n in n_list {
            let mut rng = Rng::new(cfg.seed ^ (n as u64) ^ ((procs as u64) << 32));
            let a = rng.digits(n, base.log2);
            let b = rng.digits(n, base.log2);
            // Reference product once per cell, via the packed kernel.
            let mut ops = Ops::default();
            let want = bignum::mul_school(&a, &b, base, &mut ops);
            let seq = Seq::range(procs);

            let mut sim = Machine::unbounded(procs, base);
            let (p_sim, wall_sim) = run_once(&mut sim, scheme, &seq, &a, &b, leaf)?;
            ensure!(p_sim == want, "bench: sim product mismatch at n={n}");
            report.engine_grid.push(EngineCell {
                scheme,
                engine: "sim",
                n,
                procs,
                base_log2: base.log2,
                wall: wall_sim,
                clock: sim.critical(),
                mem_peak: sim.mem_peak_max(),
            });

            let mut thr = ThreadedMachine::unbounded(procs, base);
            let (p_thr, wall_thr) = run_once(&mut thr, scheme, &seq, &a, &b, leaf)?;
            ensure!(p_thr == want, "bench: threaded product mismatch at n={n}");
            let fin = thr.finish()?;
            ensure!(
                fin.critical == sim.critical(),
                "bench: engines disagree on the cost triple at n={n}"
            );
            report.engine_grid.push(EngineCell {
                scheme,
                engine: "threads",
                n,
                procs,
                base_log2: base.log2,
                wall: wall_thr,
                clock: fin.critical,
                mem_peak: fin.mem_peak_max,
            });
        }
    }
    Ok(())
}

/// Socket-engine measured-vs-predicted: the same cells E15 runs, but
/// over real worker processes, with the simulator alongside purely to
/// supply the (asserted-identical) cost triple the §2.2 prediction is
/// computed from. Records nothing when no worker binary resolves.
fn socket_grid(cfg: &BenchConfig, report: &mut BenchReport) -> Result<()> {
    if !socket_available() {
        return Ok(());
    }
    let base = Base::default();
    let model = TimeModel::default();
    let copsim_n: &[usize] = if cfg.smoke { &[1024] } else { &[1024, 4096] };
    let copk_n: &[usize] = if cfg.smoke { &[1536] } else { &[1536, 3072] };
    let schemes = [
        ("copsim", 4usize, copsim_n, leaf_ref(SchoolLeaf)),
        ("copk", 12, copk_n, leaf_ref(SkimLeaf)),
    ];
    for (scheme, procs, n_list, leaf) in &schemes {
        let (scheme, procs, n_list) = (*scheme, *procs, *n_list);
        for &n in n_list {
            let mut rng = Rng::new(cfg.seed ^ 0x50C ^ (n as u64) ^ ((procs as u64) << 32));
            let a = rng.digits(n, base.log2);
            let b = rng.digits(n, base.log2);
            let seq = Seq::range(procs);

            let mut sim = Machine::unbounded(procs, base);
            let (p_sim, _) = run_once(&mut sim, scheme, &seq, &a, &b, leaf)?;
            let clock = sim.critical();

            let mut sock = SocketMachine::unbounded(procs, base)?;
            let (p_sock, wall) = run_once(&mut sock, scheme, &seq, &a, &b, leaf)?;
            let fin = sock.finish()?;
            ensure!(
                p_sock == p_sim,
                "bench: socket product mismatch at {scheme} n={n}"
            );
            ensure!(
                fin.critical == clock,
                "bench: socket cost triple diverges at {scheme} n={n}: \
                 sim {clock} vs sockets {}",
                fin.critical
            );
            report.socket.push(SocketCell {
                scheme,
                n,
                procs,
                base_log2: base.log2,
                wall,
                clock,
                predicted_ms: model.time_ns(&clock) / 1e6,
            });
        }
    }
    Ok(())
}

/// Time `f` adaptively: enough iterations to cover ~20ms, at least one.
fn time_kernel(mut f: impl FnMut()) -> (u64, f64) {
    let budget = Duration::from_millis(20);
    let mut iters = 0u64;
    let t0 = Instant::now();
    loop {
        f();
        iters += 1;
        if t0.elapsed() >= budget || iters >= 10_000 {
            break;
        }
    }
    (iters, t0.elapsed().as_nanos() as f64 / iters as f64)
}

/// Every available ladder rung on identical operands. The smoke grid
/// keeps n = 4096 so even CI's record-only artifact witnesses the
/// headline comparison (generic vs packed64 at n ≥ 4096, base 2^16).
fn kernel_table(cfg: &BenchConfig, report: &mut BenchReport) {
    let n_list: &[usize] = if cfg.smoke {
        &[1024, 4096]
    } else {
        &[256, 1024, 4096]
    };
    for &log2 in &[4u32, 8, 16] {
        let base = Base::new(log2);
        for &n in n_list {
            let mut rng = Rng::new(cfg.seed ^ ((log2 as u64) << 48) ^ n as u64);
            let a = rng.digits(n, log2);
            let b = rng.digits(n, log2);
            for rung in arch::ladder() {
                let (iters, ns) = time_kernel(|| {
                    std::hint::black_box((rung.mul)(
                        std::hint::black_box(&a),
                        std::hint::black_box(&b),
                        base,
                    ));
                });
                report.kernels.push(KernelCell {
                    kernel: rung.name,
                    n,
                    base_log2: log2,
                    iters,
                    ns_per_iter: ns,
                });
            }
        }
    }
}

/// Both recursive multipliers across leaf widths, per base — the sweep
/// whose full-grid output is the evidence behind `leaf_widths` (slim's
/// charged T falls monotonically with the width; skim's rises, capped
/// by Fact 13 at 128 — see DESIGN.md, "Leaf-width re-tune").
fn leaf_sweep(cfg: &BenchConfig, report: &mut BenchReport) {
    type SweepFn = fn(&[u32], &[u32], Base, &mut Ops, usize) -> Vec<u32>;
    let n = if cfg.smoke { 1024 } else { 4096 };
    let widths: &[usize] = if cfg.smoke {
        &[32, 64, 128, 256]
    } else {
        &[16, 32, 64, 128, 256, 512, 1024]
    };
    let schemes: [(&'static str, SweepFn); 2] = [
        ("slim", bignum::slim_with_leaf),
        ("skim", bignum::skim_with_leaf),
    ];
    for &log2 in &[4u32, 8, 16] {
        let base = Base::new(log2);
        let mut rng = Rng::new(cfg.seed ^ 0x1EAF ^ ((log2 as u64) << 40));
        let a = rng.digits(n, log2);
        let b = rng.digits(n, log2);
        for (scheme, f) in schemes {
            for &lw in widths {
                let mut ops = Ops::default();
                let t0 = Instant::now();
                std::hint::black_box(f(&a, &b, base, &mut ops, lw));
                report.leaf_sweep.push(LeafCell {
                    scheme,
                    leaf_width: lw,
                    n,
                    base_log2: log2,
                    wall: t0.elapsed(),
                    ops: ops.get(),
                });
            }
        }
    }
}

/// The open-loop serving curve (`copmul daemon` / CI `serve-soak`):
/// per engine, seeded Poisson runs across offered rates plus one
/// bursty run at the top rate, all through [`run_open_loop`] against a
/// shared 16-processor daemon. The deadline keeps the overloaded legs
/// shedding (reject-early) instead of queueing without bound, so the
/// curve shows goodput saturating while offered load keeps growing.
pub fn serving_curve(cfg: &BenchConfig, report: &mut BenchReport) -> Result<()> {
    let jobs: u64 = if cfg.smoke { 160 } else { 512 };
    let rates: &[f64] = if cfg.smoke {
        &[400.0, 1600.0]
    } else {
        &[400.0, 1600.0, 6400.0]
    };
    let workload = Workload {
        seed: cfg.seed ^ 0x5E21,
        n: 256,
        base_log2: 16,
        procs: 4,
        algo: Some(Algorithm::Copsim),
        exec_mode: ExecPolicy::Dfs,
    };
    for (engine, name) in [(EngineKind::Sim, "sim"), (EngineKind::Threads, "threads")] {
        let daemon = Daemon::start(
            DaemonConfig {
                sched: SchedulerConfig {
                    procs: 16,
                    runners: 4,
                    engine,
                    max_queue: 4096,
                    ..Default::default()
                },
                default_deadline: Some(Duration::from_millis(250)),
                ..Default::default()
            },
            leaf_ref(SchoolLeaf),
        )?;
        let mut legs: Vec<(&'static str, ArrivalGen, f64)> = Vec::new();
        for &r in rates {
            legs.push(("poisson", ArrivalGen::poisson(cfg.seed ^ r as u64, r)?, r));
        }
        let top = *rates.last().unwrap();
        legs.push((
            "bursty",
            ArrivalGen::bursty(cfg.seed ^ 0xB0, top, 32, Duration::from_millis(20))?,
            top,
        ));
        for (arrival, arrivals, rate) in legs {
            let rep = run_open_loop(
                &daemon,
                &OpenLoop {
                    arrivals,
                    jobs,
                    workload,
                    verify: false,
                    collect: false,
                },
            )?;
            report.serving.push(ServingCell {
                engine: name,
                arrival,
                offered_rate: rate,
                offered: rep.offered,
                completed: rep.completed,
                shed: rep.shed_total(),
                retries: rep.retries,
                goodput_per_s: rep.goodput_per_s(),
                p50_us: rep.percentile_us(0.50),
                p99_us: rep.percentile_us(0.99),
                p999_us: rep.percentile_us(0.999),
                wall_ms: rep.wall.as_millis() as u64,
            });
        }
        daemon.shutdown()?;
    }
    Ok(())
}

/// Run the full bench and collect the report.
pub fn run(cfg: &BenchConfig) -> Result<BenchReport> {
    let mut report = BenchReport {
        kernel_selected: arch::active().name,
        simd_isa: arch::simd::isa(),
        ..Default::default()
    };
    engine_grid(cfg, &mut report)?;
    kernel_table(cfg, &mut report);
    leaf_sweep(cfg, &mut report);
    serving_curve(cfg, &mut report)?;
    socket_grid(cfg, &mut report)?;
    // The E20 sweep cross-checks every feasible cell on all available
    // engines before recording it, so the section doubles as a
    // mode-differential wall in the perf job.
    report.strong_scaling = sweep_cells(cfg.seed)?;
    // E21: the soak asserts capacity re-admission and the goodput
    // bound internally — reaching this line means the machine healed.
    report.recovery = soak_cells(cfg.smoke)?;
    Ok(report)
}

impl BenchReport {
    /// Human-readable tables for the terminal.
    pub fn tables(&self) -> Vec<Table> {
        let mut t1 = Table::new(
            "engine grid (wall-clock; cost triple is layout-invariant)",
            &["scheme", "engine", "n", "P", "wall µs", "T", "BW", "L", "M"],
        );
        for c in &self.engine_grid {
            t1.row(vec![
                c.scheme.into(),
                c.engine.into(),
                c.n.to_string(),
                c.procs.to_string(),
                fmt_u64(c.wall.as_micros() as u64),
                fmt_u64(c.clock.ops),
                fmt_u64(c.clock.words),
                fmt_u64(c.clock.msgs),
                fmt_u64(c.mem_peak),
            ]);
        }
        let mut t2 = Table::new(
            "kernel ladder (wall-clock at identical closed-form charges)",
            &["kernel", "base", "n", "iters", "ns/iter"],
        );
        for c in &self.kernels {
            t2.row(vec![
                c.kernel.into(),
                format!("2^{}", c.base_log2),
                c.n.to_string(),
                c.iters.to_string(),
                format!("{:.0}", c.ns_per_iter),
            ]);
        }
        let mut t3 = Table::new(
            "leaf-width sweep (wall vs charged T; shipped table: leaf_widths)",
            &["scheme", "base", "leaf_width", "n", "wall µs", "ops"],
        );
        for c in &self.leaf_sweep {
            t3.row(vec![
                c.scheme.into(),
                format!("2^{}", c.base_log2),
                c.leaf_width.to_string(),
                c.n.to_string(),
                fmt_u64(c.wall.as_micros() as u64),
                fmt_u64(c.ops),
            ]);
        }
        let mut t4 = Table::new(
            "serving curve (open-loop offered load vs goodput; copmul daemon)",
            &[
                "engine", "arrival", "rate/s", "offered", "done", "shed", "retry", "goodput/s",
                "p50 µs", "p99 µs", "p999 µs", "wall ms",
            ],
        );
        for c in &self.serving {
            t4.row(vec![
                c.engine.into(),
                c.arrival.into(),
                format!("{:.0}", c.offered_rate),
                c.offered.to_string(),
                c.completed.to_string(),
                c.shed.to_string(),
                c.retries.to_string(),
                format!("{:.1}", c.goodput_per_s),
                fmt_u64(c.p50_us),
                fmt_u64(c.p99_us),
                fmt_u64(c.p999_us),
                c.wall_ms.to_string(),
            ]);
        }
        let mut t5 = Table::new(
            "socket engine: measured wall vs predicted α·T + β·L + γ·BW \
             (empty when no worker binary resolves)",
            &[
                "scheme",
                "n",
                "P",
                "T",
                "BW",
                "L",
                "predicted ms",
                "wall ms",
                "ratio",
            ],
        );
        for c in &self.socket {
            let wall_ms = c.wall.as_secs_f64() * 1e3;
            t5.row(vec![
                c.scheme.into(),
                c.n.to_string(),
                c.procs.to_string(),
                fmt_u64(c.clock.ops),
                fmt_u64(c.clock.words),
                fmt_u64(c.clock.msgs),
                format!("{:.3}", c.predicted_ms),
                format!("{wall_ms:.3}"),
                format!("{:.2}", wall_ms / c.predicted_ms.max(1e-9)),
            ]);
        }
        let mut t6 = Table::new(
            "strong scaling at fixed per-proc memory (E20 sweep; \
             `memory-bound` rows are the cliff, BW in charged words)",
            &[
                "algo", "topology", "P", "n", "M", "mode", "T", "BW dfs", "BW auto", "pred BW",
            ],
        );
        for c in &self.strong_scaling {
            t6.row(vec![
                c.algo.to_string(),
                c.topology.to_string(),
                c.p.to_string(),
                c.n.to_string(),
                fmt_u64(c.mem_cap),
                c.mode.map_or("memory-bound".into(), |m| m.to_string()),
                c.ops.map_or("-".into(), fmt_u64),
                c.dfs_bw.map_or("-".into(), fmt_u64),
                c.auto_bw.map_or("-".into(), fmt_u64),
                c.predicted_bw.map_or("-".into(), fmt_u64),
            ]);
        }
        let mut t7 = Table::new(
            "self-healing soak (E21: rolling kills; goodput ratio vs clean run, \
             socket leg only with a worker binary)",
            &[
                "engine",
                "offered",
                "done",
                "kills",
                "quarantined",
                "probed back",
                "probes",
                "respawns",
                "clean gp/s",
                "chaos gp/s",
                "ratio",
            ],
        );
        for c in &self.recovery {
            t7.row(vec![
                c.engine.into(),
                c.offered.to_string(),
                c.completed.to_string(),
                c.kills.to_string(),
                c.quarantine_events.to_string(),
                c.dequarantined.to_string(),
                c.probes_sent.to_string(),
                c.respawns.to_string(),
                format!("{:.1}", c.clean_goodput_per_s),
                format!("{:.1}", c.chaos_goodput_per_s),
                format!("{:.2}", c.recovery_ratio),
            ]);
        }
        vec![t1, t2, t3, t4, t5, t6, t7]
    }

    /// Serialize to the `BENCH_*.json` schema (hand-rolled — no serde
    /// in the offline build; `util::json` parses this back).
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(4096);
        s.push_str(&format!(
            "{{\n  \"bench\": 10,\n  \"kernel_selected\": \"{}\",\n  \
             \"simd_isa\": \"{}\",\n  \"engine_grid\": [\n",
            self.kernel_selected, self.simd_isa
        ));
        for (i, c) in self.engine_grid.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"scheme\": \"{}\", \"engine\": \"{}\", \"n\": {}, \"procs\": {}, \
                 \"base_log2\": {}, \"wall_us\": {}, \"ops\": {}, \"words\": {}, \
                 \"msgs\": {}, \"mem_peak\": {}}}{}\n",
                c.scheme,
                c.engine,
                c.n,
                c.procs,
                c.base_log2,
                c.wall.as_micros(),
                c.clock.ops,
                c.clock.words,
                c.clock.msgs,
                c.mem_peak,
                if i + 1 < self.engine_grid.len() { "," } else { "" },
            ));
        }
        s.push_str("  ],\n  \"kernels\": [\n");
        for (i, c) in self.kernels.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"kernel\": \"{}\", \"base_log2\": {}, \"n\": {}, \"iters\": {}, \
                 \"ns_per_iter\": {:.1}}}{}\n",
                c.kernel,
                c.base_log2,
                c.n,
                c.iters,
                c.ns_per_iter,
                if i + 1 < self.kernels.len() { "," } else { "" },
            ));
        }
        s.push_str("  ],\n  \"leaf_width_sweep\": [\n");
        for (i, c) in self.leaf_sweep.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"scheme\": \"{}\", \"leaf_width\": {}, \"n\": {}, \"base_log2\": {}, \
                 \"wall_us\": {}, \"ops\": {}}}{}\n",
                c.scheme,
                c.leaf_width,
                c.n,
                c.base_log2,
                c.wall.as_micros(),
                c.ops,
                if i + 1 < self.leaf_sweep.len() { "," } else { "" },
            ));
        }
        s.push_str("  ],\n  \"serving\": [\n");
        for (i, c) in self.serving.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"engine\": \"{}\", \"arrival\": \"{}\", \"offered_rate\": {:.1}, \
                 \"offered\": {}, \"completed\": {}, \"shed\": {}, \"retries\": {}, \
                 \"goodput_per_s\": {:.1}, \"p50_us\": {}, \"p99_us\": {}, \"p999_us\": {}, \
                 \"wall_ms\": {}}}{}\n",
                c.engine,
                c.arrival,
                c.offered_rate,
                c.offered,
                c.completed,
                c.shed,
                c.retries,
                c.goodput_per_s,
                c.p50_us,
                c.p99_us,
                c.p999_us,
                c.wall_ms,
                if i + 1 < self.serving.len() { "," } else { "" },
            ));
        }
        s.push_str("  ],\n  \"socket\": [\n");
        for (i, c) in self.socket.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"scheme\": \"{}\", \"n\": {}, \"procs\": {}, \"base_log2\": {}, \
                 \"wall_us\": {}, \"ops\": {}, \"words\": {}, \"msgs\": {}, \
                 \"predicted_ms\": {:.3}}}{}\n",
                c.scheme,
                c.n,
                c.procs,
                c.base_log2,
                c.wall.as_micros(),
                c.clock.ops,
                c.clock.words,
                c.clock.msgs,
                c.predicted_ms,
                if i + 1 < self.socket.len() { "," } else { "" },
            ));
        }
        s.push_str("  ],\n  \"strong_scaling\": [\n");
        for (i, c) in self.strong_scaling.iter().enumerate() {
            // Infeasible (memory-bound) cells record zeros with the
            // sentinel mode string; `feasible` disambiguates.
            s.push_str(&format!(
                "    {{\"algo\": \"{}\", \"topology\": \"{}\", \"p\": {}, \"n\": {}, \
                 \"mem_cap\": {}, \"feasible\": {}, \"mode\": \"{}\", \"ops\": {}, \
                 \"dfs_words\": {}, \"auto_words\": {}, \"pred_words\": {}}}{}\n",
                c.algo,
                c.topology,
                c.p,
                c.n,
                c.mem_cap,
                c.mode.is_some(),
                c.mode.map_or("memory-bound".into(), |m| m.to_string()),
                c.ops.unwrap_or(0),
                c.dfs_bw.unwrap_or(0),
                c.auto_bw.unwrap_or(0),
                c.predicted_bw.unwrap_or(0),
                if i + 1 < self.strong_scaling.len() { "," } else { "" },
            ));
        }
        s.push_str("  ],\n  \"recovery\": [\n");
        for (i, c) in self.recovery.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"engine\": \"{}\", \"offered\": {}, \"completed\": {}, \"shed\": {}, \
                 \"kills\": {}, \"quarantine_events\": {}, \"dequarantined\": {}, \
                 \"probes_sent\": {}, \"respawns\": {}, \"clean_goodput_per_s\": {:.1}, \
                 \"chaos_goodput_per_s\": {:.1}, \"recovery_ratio\": {:.3}}}{}\n",
                c.engine,
                c.offered,
                c.completed,
                c.shed,
                c.kills,
                c.quarantine_events,
                c.dequarantined,
                c.probes_sent,
                c.respawns,
                c.clean_goodput_per_s,
                c.chaos_goodput_per_s,
                c.recovery_ratio,
                if i + 1 < self.recovery.len() { "," } else { "" },
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn smoke_report_is_complete_and_valid_json() {
        // A tiny one-cell pass through every section keeps this test
        // fast while exercising the whole reporting pipeline.
        let cfg = BenchConfig {
            smoke: true,
            seed: 7,
        };
        let mut report = BenchReport {
            kernel_selected: arch::active().name,
            simd_isa: arch::simd::isa(),
            ..Default::default()
        };
        kernel_table(&cfg, &mut report);
        leaf_sweep(&cfg, &mut report);
        // A synthetic serving cell exercises the section's JSON and
        // table paths without a multi-second open-loop run here (the
        // live path is covered by the daemon tests and serve_soak).
        report.serving.push(ServingCell {
            engine: "sim",
            arrival: "poisson",
            offered_rate: 800.0,
            offered: 160,
            completed: 150,
            shed: 10,
            retries: 0,
            goodput_per_s: 750.0,
            p50_us: 900,
            p99_us: 4200,
            p999_us: 9800,
            wall_ms: 200,
        });
        // Likewise a synthetic socket cell: the JSON/table paths must
        // hold whether or not a worker binary resolves on this host
        // (the live path is covered by socket_grid in `copmul bench`
        // and the engine differential suite).
        report.socket.push(SocketCell {
            scheme: "copsim",
            n: 1024,
            procs: 4,
            base_log2: 16,
            wall: Duration::from_micros(1500),
            clock: Clock {
                ops: 70_000,
                words: 2_048,
                msgs: 24,
            },
            predicted_ms: 0.5,
        });
        // One feasible and one memory-bound synthetic strong-scaling
        // cell pin the section's JSON/table rendering (the live sweep
        // runs in `copmul bench` and the strong-scaling CI job).
        report.strong_scaling.push(ScalingCell {
            algo: Algorithm::Copsim,
            topology: crate::sim::TopologyKind::FullyConnected,
            p: 256,
            n: 1024,
            mem_cap: 2048,
            mode: Some(crate::algorithms::ExecMode::Bfs { levels: 4 }),
            dfs_bw: Some(9000),
            auto_bw: Some(7000),
            predicted_bw: Some(8000),
            ops: Some(123_456),
        });
        report.strong_scaling.push(ScalingCell {
            algo: Algorithm::Copsim,
            topology: crate::sim::TopologyKind::Torus,
            p: 4,
            n: 1024,
            mem_cap: 2048,
            mode: None,
            dfs_bw: None,
            auto_bw: None,
            predicted_bw: None,
            ops: None,
        });
        // A synthetic recovery cell pins the E21 section's JSON/table
        // rendering (the live soak runs in `copmul bench` and the
        // rolling-chaos CI job).
        report.recovery.push(RecoveryCell {
            engine: "sockets",
            offered: 80,
            completed: 74,
            shed: 4,
            kills: 3,
            quarantine_events: 24,
            dequarantined: 24,
            probes_sent: 52,
            respawns: 3,
            clean_goodput_per_s: 400.0,
            chaos_goodput_per_s: 160.0,
            recovery_ratio: 0.4,
        });
        assert!(!report.kernels.is_empty());
        assert!(!report.leaf_sweep.is_empty());
        // Every available ladder rung shows up in the kernel table, and
        // both sweep schemes per base.
        for rung in arch::ladder() {
            assert!(
                report.kernels.iter().any(|c| c.kernel == rung.name),
                "rung {} missing from the kernel table",
                rung.name
            );
        }
        for scheme in ["slim", "skim"] {
            assert!(report.leaf_sweep.iter().any(|c| c.scheme == scheme));
        }
        let j = Json::parse(&report.to_json()).expect("BENCH json must parse");
        assert_eq!(j.get("bench").and_then(Json::as_u64), Some(10));
        assert!(j.get("kernel_selected").and_then(Json::as_str).is_some());
        assert!(j.get("kernels").and_then(Json::as_arr).is_some());
        assert!(j.get("leaf_width_sweep").and_then(Json::as_arr).is_some());
        let serving = j.get("serving").and_then(Json::as_arr).expect("serving arr");
        assert_eq!(serving.len(), 1);
        assert_eq!(serving[0].get("completed").and_then(Json::as_u64), Some(150));
        let socket = j.get("socket").and_then(Json::as_arr).expect("socket arr");
        assert_eq!(socket.len(), 1);
        assert_eq!(socket[0].get("wall_us").and_then(Json::as_u64), Some(1500));
        let ss = j
            .get("strong_scaling")
            .and_then(Json::as_arr)
            .expect("strong_scaling arr");
        assert_eq!(ss.len(), 2);
        assert_eq!(ss[0].get("auto_words").and_then(Json::as_u64), Some(7000));
        assert_eq!(ss[0].get("mode").and_then(Json::as_str), Some("bfs(4)"));
        assert_eq!(ss[1].get("mode").and_then(Json::as_str), Some("memory-bound"));
        let rec = j.get("recovery").and_then(Json::as_arr).expect("recovery arr");
        assert_eq!(rec.len(), 1);
        assert_eq!(rec[0].get("respawns").and_then(Json::as_u64), Some(3));
        assert_eq!(rec[0].get("engine").and_then(Json::as_str), Some("sockets"));
        assert_eq!(report.tables().len(), 7, "recovery table renders");
    }

    #[test]
    fn engine_grid_cells_agree_across_engines() {
        // One small end-to-end cell per scheme (kept tiny for tier-1).
        let base = Base::default();
        let n = 256;
        for (scheme, procs) in [("copsim", 4usize), ("copk", 4)] {
            let mut rng = Rng::new(3);
            let a = rng.digits(n, base.log2);
            let b = rng.digits(n, base.log2);
            let leaf: LeafRef = leaf_ref(SkimLeaf);
            let seq = Seq::range(procs);
            let mut sim = Machine::unbounded(procs, base);
            let (ps, _) = run_once(&mut sim, scheme, &seq, &a, &b, &leaf).unwrap();
            let mut thr = ThreadedMachine::unbounded(procs, base);
            let (pt, _) = run_once(&mut thr, scheme, &seq, &a, &b, &leaf).unwrap();
            assert_eq!(ps, pt, "{scheme}: engines disagree on the product");
            assert_eq!(
                thr.finish().unwrap().critical,
                sim.critical(),
                "{scheme}: engines disagree on the cost triple"
            );
        }
    }
}

//! §7 hybridization of COPSIM and COPK.
//!
//! The paper observes that, because of the constant factors in the cost
//! bounds, "COPK allows for overall improved performance over COPSIM for
//! large input size, while when multiplying integers with fewer digits,
//! COPSIM may actually achieve lower execution time", and that the
//! common BFS/DFS framework lets the two schemes combine seamlessly.
//!
//! Our hybridization operates at two levels:
//!
//! 1. **Machine level** ([`choose_algorithm`], [`hybrid_mul`]): given
//!    `(n, P, M)` and a [`TimeModel`], evaluate the paper's closed-form
//!    cost bounds under the model and dispatch the whole multiplication
//!    to the cheaper scheme. Because COPSIM needs `P = 4^k` and COPK
//!    needs `P = 4·3^i`, the dispatch also respects the processor-count
//!    shape (both shapes intersect only at `P ∈ {1, 4}`).
//! 2. **Leaf level** (`leaf::HybridLeaf`): inside either scheme, the
//!    sequential leaves switch from Karatsuba to schoolbook below the
//!    classical crossover width — the same trade at the bottom of the
//!    recursion tree.

use super::copk::copk;
use super::copsim::{copsim, is_pow4};
use super::exec::{mul_with_mode, resolve_mode, ExecMode, ExecPolicy};
use super::leaf::LeafRef;
use crate::error::{bail, Result};
use crate::sim::{DistInt, MachineApi, Seq};
use crate::theory::{self, TimeModel};
use crate::util::is_copk_procs;

/// Which top-level scheme a multiplication is dispatched to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    Copsim,
    Copk,
}

impl std::fmt::Display for Algorithm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Algorithm::Copsim => write!(f, "COPSIM"),
            Algorithm::Copk => write!(f, "COPK"),
        }
    }
}

/// Predict the modeled execution time of each scheme from the paper's
/// upper bounds (Theorems 12/15, falling back to 11/14 when the MI mode
/// applies) and return the cheaper one. `None` for a scheme whose
/// processor-count shape `p` cannot run.
pub fn predict_times(n: u64, p: u64, m: u64, tm: &TimeModel) -> (Option<f64>, Option<f64>) {
    let copsim_t = if is_pow4(p as usize) {
        let mi_ok = (n as f64) <= m as f64 * (p as f64).sqrt() / 12.0;
        let c = if mi_ok {
            theory::thm11_copsim_mi(n, p)
        } else {
            theory::thm12_copsim(n, p, m)
        };
        Some(tm.time_ns(&c))
    } else {
        None
    };
    let copk_t = if p == 1 || is_copk_procs(p) {
        let mi_ok = (n as f64) <= m as f64 * crate::util::pow_log3_2(p as f64) / 10.0;
        let c = if mi_ok {
            theory::thm14_copk_mi(n, p)
        } else {
            theory::thm15_copk(n, p, m)
        };
        Some(tm.time_ns(&c))
    } else {
        None
    };
    (copsim_t, copk_t)
}

/// Pick the scheme with the lower predicted modeled time.
pub fn choose_algorithm(n: u64, p: u64, m: u64, tm: &TimeModel) -> Result<Algorithm> {
    match predict_times(n, p, m, tm) {
        (Some(s), Some(k)) => Ok(if k < s { Algorithm::Copk } else { Algorithm::Copsim }),
        (Some(_), None) => Ok(Algorithm::Copsim),
        (None, Some(_)) => Ok(Algorithm::Copk),
        (None, None) => bail!(
            "P = {p} fits neither COPSIM (4^k) nor COPK (4·3^i); \
             choose a compatible processor count"
        ),
    }
}

/// Multiply via the scheme selected by [`choose_algorithm`].
/// Returns the product and the scheme used.
pub fn hybrid_mul<M: MachineApi>(
    m: &mut M,
    seq: &Seq,
    a: DistInt,
    b: DistInt,
    leaf: &LeafRef,
    tm: &TimeModel,
) -> Result<(DistInt, Algorithm)> {
    let n = a.total_width() as u64;
    let algo = choose_algorithm(n, seq.len() as u64, m.mem_cap(), tm)?;
    let c = match algo {
        Algorithm::Copsim => copsim(m, seq, a, b, leaf)?,
        Algorithm::Copk => copk(m, seq, a, b, leaf)?,
    };
    Ok((c, algo))
}

/// [`hybrid_mul`] with an execution-mode policy: the scheme is chosen
/// as before, then the policy resolves against the machine's
/// per-processor memory ([`resolve_mode`]). Returns the product, the
/// scheme, and the *resolved* mode (what the run actually executed).
/// `ExecPolicy::Dfs` is bit-identical to [`hybrid_mul`].
pub fn hybrid_mul_with_mode<M: MachineApi>(
    m: &mut M,
    seq: &Seq,
    a: DistInt,
    b: DistInt,
    leaf: &LeafRef,
    tm: &TimeModel,
    policy: ExecPolicy,
) -> Result<(DistInt, Algorithm, ExecMode)> {
    let n = a.total_width() as u64;
    let p = seq.len() as u64;
    let algo = choose_algorithm(n, p, m.mem_cap(), tm)?;
    let mode = resolve_mode(policy, algo, n, p, m.mem_cap());
    let c = mul_with_mode(m, seq, a, b, leaf, algo, mode)?;
    Ok((c, algo, mode))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::leaf::{leaf_ref, HybridLeaf};
    use crate::bignum::{mul, Base, Ops};
    use crate::sim::Machine;
    use crate::util::Rng;

    #[test]
    fn shape_dispatch() {
        let tm = TimeModel::default();
        // 16 = 4^2: only COPSIM fits.
        assert_eq!(choose_algorithm(1 << 14, 16, 1 << 20, &tm).unwrap(), Algorithm::Copsim);
        // 12 = 4·3: only COPK fits.
        assert_eq!(choose_algorithm(1 << 14, 12, 1 << 20, &tm).unwrap(), Algorithm::Copk);
        // 8 fits neither.
        assert!(choose_algorithm(1 << 14, 8, 1 << 20, &tm).is_err());
    }

    #[test]
    fn crossover_exists_at_p4() {
        // At P = 4 both run; the bound-predicted times must cross:
        // COPSIM cheaper for small n, COPK for large n.
        let tm = TimeModel::default();
        let m = u64::MAX / 4;
        let small = choose_algorithm(1 << 4, 4, m, &tm).unwrap();
        let large = choose_algorithm(1 << 22, 4, m, &tm).unwrap();
        assert_eq!(small, Algorithm::Copsim);
        assert_eq!(large, Algorithm::Copk);
    }

    #[test]
    fn hybrid_mul_correct_both_ways() {
        let tm = TimeModel::default();
        for &(p, n) in &[(4usize, 64usize), (12, 384), (16, 256)] {
            let mut rng = Rng::new(0x4B1D);
            let mut m = Machine::unbounded(p, Base::new(16));
            let seq = Seq::range(p);
            let a = rng.digits(n, 16);
            let b = rng.digits(n, 16);
            let da = DistInt::scatter(&mut m, &seq, &a, n / p).unwrap();
            let db = DistInt::scatter(&mut m, &seq, &b, n / p).unwrap();
            let leaf = leaf_ref(HybridLeaf { threshold: 32 });
            let (c, _algo) = hybrid_mul(&mut m, &seq, da, db, &leaf, &tm).unwrap();
            let mut ops = Ops::default();
            let want = mul::mul_school(&a, &b, Base::new(16), &mut ops);
            assert_eq!(c.gather(&m).unwrap(), want, "p={p} n={n}");
        }
    }
}

//! COPK — Communication-Optimal Parallel Karatsuba (paper §6).
//!
//! Karatsuba's three-product scheme
//! `C = C0 + s^(n/2)·C1 + s^n·C2` with `C0 = A0·B0`, `C2 = A1·B1`,
//! `C1 = C0 + C2 + f_A·f_B·C'`, `C' = |A0−A1|·|B1−B0|`,
//! where `f_A, f_B` are the sign flags produced by the parallel DIFF.
//!
//! * **MI mode** ([`copk_mi`], §6.1): `|P| = 4·3^i`. Each BFS level
//!   computes the operand differences with DIFF on the two halves,
//!   splits the processors into three groups (`seq.copk_groups()`), and
//!   recurses in parallel; the `|P| = 4` base case runs the three
//!   subproducts on single processors (`P[3]` assists only in the
//!   recombination, exactly as the paper uses 3 of the 4 processors).
//!   Theorem 14: `T ≤ 173·n^lg3/P`, `BW ≤ 174·n/P^(log₃2)`,
//!   `L ≤ 25·log₂²P`, memory `10n/P^(log₃2)`.
//! * **Main mode** ([`copk`], §6.2): while `n > M·P^(log₃2)/10`, a
//!   depth-first step computes `C0`, `C2`, then the differences, then
//!   `C'` — each sequentially on all `P` processors (interleaved
//!   re-ranking, halved chunk width) — and recombines. Theorem 15:
//!   `T ≤ 675·n^lg3/P`, `BW ≤ 1708·(n/M)^lg3·M/P`, requiring
//!   `M ≥ 40n/P` and `M ≥ log₂P`.
//!
//! Recombination: the high `3n/2` digits of `C` are
//! `C0≫n/2 + C0 + C2 + f_A·f_B·C' + C2≪n/2`, computed with four SUMs
//! (or three SUMs and one DIFF when the cross term is negative) on
//! `P* = seq[P/4..P]`, ordered so every partial sum stays in
//! `[0, s^(3n/2))` (the paper's ⌈3/s⌉ top-digit bookkeeping is avoided
//! by applying `±C'` before the `C2≪n/2` term).

use super::leaf::LeafRef;
use super::leaf_multiply;
use crate::error::{ensure, Result};
use crate::primitives::{diff, sum};
use crate::sim::{DistInt, MachineApi, Seq};
use crate::util::{is_copk_procs, pow_log3_2};

/// Karatsuba recombination (see module docs). Each of `c0`, `cp`, `c2`
/// holds `n = |seq|·w` digits (any layout); result is `2n` digits on
/// `seq` with chunk width `2w`. `sign = f_A·f_B ∈ {-1, 0, 1}`.
pub(crate) fn recompose_karatsuba<M: MachineApi>(
    m: &mut M,
    seq: &Seq,
    c0: DistInt,
    cp: DistInt,
    sign: i32,
    c2: DistInt,
    w: usize,
) -> Result<DistInt> {
    let p = seq.len();
    let w2 = 2 * w;
    let lo_half = seq.lower_half();
    let hi_half = seq.upper_half();
    let mid = Seq(seq.ids()[p / 4..3 * p / 4].to_vec());
    let pstar = Seq(seq.ids()[p / 4..].to_vec());

    // Redistribute: C0 -> P', C2 -> P'', C' -> middle.
    let c0 = c0.repartition(m, &lo_half, w2)?;
    let c2 = c2.repartition(m, &hi_half, w2)?;
    let cp = cp.repartition(m, &mid, w2)?;

    // C0's low n/2 digits are final.
    let (c0_lo, c0_hi) = c0.split_half();

    // 3n/2-digit summands over P*:
    //   X0  = C0 >> n/2          (high half of C0)
    //   XC0 = C0                 (the C0 term inside C1; needs a copy —
    //                             paper step 8: "P[0] sends P[1] a copy")
    //   XC2 = C2                 (the C2 term inside C1; copy)
    //   XCP = ±C'                (the cross term)
    //   X3  = C2 << n/2
    let x0 = c0_hi.extend_zero(m, &seq.ids()[p / 2..])?;
    let xc0 = {
        // The full C0 value currently lives on the lower half (c0_lo ++
        // the low p/4 chunks of x0); copy it onto `mid` for the P* sums.
        let view = DistInt {
            chunk_width: c0_lo.chunk_width,
            chunks: c0_lo
                .chunks
                .iter()
                .chain(x0.chunks[..p / 4].iter())
                .copied()
                .collect(),
        };
        let moved = view.copy_to(m, &mid, w2)?;
        moved.extend_zero(m, &seq.ids()[3 * p / 4..])?
    };
    let xc2 = {
        let moved = c2.copy_to(m, &mid, w2)?;
        moved.extend_zero(m, &seq.ids()[3 * p / 4..])?
    };
    let xcp = cp.extend_zero(m, &seq.ids()[3 * p / 4..])?;
    let x3 = c2.prepend_zero(m, &seq.ids()[p / 4..p / 2])?;

    // Ordered accumulation; every partial stays in [0, s^(3n/2)).
    let (s1, v1) = sum(m, &pstar, &x0, &xc0)?;
    ensure!(v1 == 0, "recompose_k: carry in X0+XC0");
    let (s2, v2) = sum(m, &pstar, &s1, &xc2)?;
    ensure!(v2 == 0, "recompose_k: carry in +XC2");
    s1.free(m);
    let s3 = match sign {
        1 => {
            let (s, v) = sum(m, &pstar, &s2, &xcp)?;
            ensure!(v == 0, "recompose_k: carry in +C'");
            s2.free(m);
            s
        }
        -1 => {
            let (s, f) = diff(m, &pstar, &s2, &xcp)?;
            ensure!(f >= 0, "recompose_k: C1 partial went negative");
            s2.free(m);
            s
        }
        _ => s2, // C' = 0
    };
    let (s4, v4) = sum(m, &pstar, &s3, &x3)?;
    ensure!(v4 == 0, "recompose_k: carry in +C2<<n/2");
    s3.free(m);

    // Release summand scaffolding (x0/xcp/x3 wrap the original
    // c0_hi/cp/c2 chunks plus zero padding; xc0/xc2 are copies).
    x0.free(m);
    xc0.free(m);
    xc2.free(m);
    xcp.free(m);
    x3.free(m);

    Ok(DistInt::concat(c0_lo, s4))
}

/// COPK in the MI execution mode (§6.1). Consumes `a`, `b`
/// (`n = |seq|·w` digits partitioned in `seq`, `|P| = 4·3^i` or 1);
/// returns the `2n`-digit product on `seq` in `2w`-digit chunks.
pub fn copk_mi<M: MachineApi>(
    m: &mut M,
    seq: &Seq,
    a: DistInt,
    b: DistInt,
    leaf: &LeafRef,
) -> Result<DistInt> {
    let p = seq.len();
    assert!(
        p == 1 || is_copk_procs(p as u64),
        "COPK_MI requires |P| = 4·3^i (got {p})"
    );
    assert_eq!(a.total_width(), b.total_width());
    let w = a.chunk_width;

    if p == 1 {
        return leaf_multiply(m, seq.at(0), a, b, leaf);
    }

    let lo_half = seq.lower_half();
    let hi_half = seq.upper_half();
    let (a0, a1) = a.split_half();
    let (b0, b1) = b.split_half();

    // --- Differences (phase 1a / base steps 1-3) ----------------------
    // A' = |A0 - A1| with flag f_A on the lower half; B' = |B1 - B0|
    // with f_B on the upper half (one replicated copy each).
    let a1rep = a1.replicate(m, &lo_half)?;
    let (adiff, fa) = diff(m, &lo_half, &a0, &a1rep)?;
    a1rep.free(m);
    let b0rep = b0.replicate(m, &hi_half)?;
    let (bdiff, fb) = diff(m, &hi_half, &b1, &b0rep)?;
    b0rep.free(m);
    let sign = fa * fb;

    if p == 4 {
        // --- Base case: three single-processor products ----------------
        let s0 = Seq(vec![seq.at(0)]);
        let s1 = Seq(vec![seq.at(1)]);
        let s2 = Seq(vec![seq.at(2)]);
        let w2 = 2 * w;
        // Consolidate operands (steps 4-6): P[0] gets A0,B0; P[1] gets
        // A',B'; P[2] gets A1,B1; P[3] assists in recombination only.
        let a0s = a0.repartition(m, &s0, w2)?;
        let b0s = b0.repartition(m, &s0, w2)?;
        let ads = adiff.repartition(m, &s1, w2)?;
        let bds = bdiff.repartition(m, &s1, w2)?;
        let a1s = a1.repartition(m, &s2, w2)?;
        let b1s = b1.repartition(m, &s2, w2)?;
        // Step 7: parallel sequential products.
        let c0 = leaf_multiply(m, seq.at(0), a0s, b0s, leaf)?;
        let cp = leaf_multiply(m, seq.at(1), ads, bds, leaf)?;
        let c2 = leaf_multiply(m, seq.at(2), a1s, b1s, leaf)?;
        // Steps 8-10 + SUM/DIFF chain.
        return recompose_karatsuba(m, seq, c0, cp, sign, c2, w);
    }

    // --- Splitting (phase 1b-1e): three groups of |P|/3 ----------------
    let [g0, g1, g2] = seq.copk_groups();
    ensure!(
        (3 * w) % 2 == 0,
        "COPK_MI: chunk width {w} not divisible for |P| = {p} (pad n)"
    );
    let w3 = 3 * w / 2;
    let a0g = a0.repartition(m, &g0, w3)?;
    let b0g = b0.repartition(m, &g0, w3)?;
    let adg = adiff.repartition(m, &g1, w3)?;
    let bdg = bdiff.repartition(m, &g1, w3)?;
    let a1g = a1.repartition(m, &g2, w3)?;
    let b1g = b1.repartition(m, &g2, w3)?;

    // --- Recursive multiplication (three groups in parallel) -----------
    let c0 = copk_mi(m, &g0, a0g, b0g, leaf)?;
    let cp = copk_mi(m, &g1, adg, bdg, leaf)?;
    let c2 = copk_mi(m, &g2, a1g, b1g, leaf)?;

    // --- Recomposition --------------------------------------------------
    recompose_karatsuba(m, seq, c0, cp, sign, c2, w)
}

/// COPK in the main execution mode (§6.2): depth-first steps until
/// `n ≤ M·P^(log₃2)/10`, then [`copk_mi`]. Theorem 15 requires
/// `M ≥ max(40n/P, log₂P)`.
pub fn copk<M: MachineApi>(
    m: &mut M,
    seq: &Seq,
    a: DistInt,
    b: DistInt,
    leaf: &LeafRef,
) -> Result<DistInt> {
    let p = seq.len();
    assert!(
        p == 1 || is_copk_procs(p as u64),
        "COPK requires |P| = 4·3^i (got {p})"
    );
    let n = a.total_width() as u64;
    let mcap = m.mem_cap();

    let mi_ok = (n as f64) <= mcap as f64 * pow_log3_2(p as f64) / 10.0;
    if p == 1 || mi_ok {
        return copk_mi(m, seq, a, b, leaf);
    }

    let w = a.chunk_width;
    ensure!(
        w >= 2 && w % 2 == 0,
        "COPK DFS cannot halve chunk width {w}: memory constraints violated (n={n}, P={p}, M={mcap})"
    );

    // --- Depth-first step (steps 1-7): subproblems on ALL processors ---
    let pt = seq.interleave_halves();
    let (a0, a1) = a.split_half();
    let (b0, b1) = b.split_half();
    let half_w = w / 2;
    let lo_half = seq.lower_half();
    let hi_half = seq.upper_half();
    let mid = Seq(seq.ids()[p / 4..3 * p / 4].to_vec());

    // Step 3: C0 = A0 x B0, stashed on the lower half.
    let a0c = a0.copy_to(m, &pt, half_w)?;
    let b0c = b0.copy_to(m, &pt, half_w)?;
    let c0 = copk(m, &pt, a0c, b0c, leaf)?;
    let c0 = c0.repartition(m, &lo_half, 2 * w)?;

    // Step 4: C2 = A1 x B1, stashed on the upper half.
    let a1c = a1.copy_to(m, &pt, half_w)?;
    let b1c = b1.copy_to(m, &pt, half_w)?;
    let c2 = copk(m, &pt, a1c, b1c, leaf)?;
    let c2 = c2.repartition(m, &hi_half, 2 * w)?;

    // Steps 5-6: A' = |A0 - A1|, B' = |B1 - B0| on the re-ranked
    // sequence; inputs are deleted afterwards ("then each processor
    // removes the digits ... from its local memory").
    let a0c = a0.copy_to(m, &pt, half_w)?;
    let a1c = a1.copy_to(m, &pt, half_w)?;
    let (adiff, fa) = diff(m, &pt, &a0c, &a1c)?;
    a0c.free(m);
    a1c.free(m);
    let b1c = b1.copy_to(m, &pt, half_w)?;
    let b0c = b0.copy_to(m, &pt, half_w)?;
    let (bdiff, fb) = diff(m, &pt, &b1c, &b0c)?;
    b1c.free(m);
    b0c.free(m);
    a0.free(m);
    a1.free(m);
    b0.free(m);
    b1.free(m);
    let sign = fa * fb;

    // Step 7: C' = A' x B' (zero operands multiply to zero and keep the
    // uniform control flow; the paper short-circuits f_A·f_B = 0).
    let cp = copk(m, &pt, adiff, bdiff, leaf)?;
    let cp = cp.repartition(m, &mid, 2 * w)?;

    // Steps 8-17: recombination.
    recompose_karatsuba(m, seq, c0, cp, sign, c2, w)
}

/// COPK with up to `levels` memory-hungry breadth-first levels
/// (`ExecMode::Bfs`). Only the *stepping* regime changes: each DFS
/// step copies every operand half to the re-ranked sequence ONCE and
/// forks the DIFF operands as free same-layout clones (charged memory
/// only), halving the step's charged copy rounds (8 → 4; saving
/// ≥ n/P words per processor, `theory::copk_bfs_step`). The MI regime
/// is mode-invariant: COPK_MI's splits already move every digit
/// exactly once and its DIFF replicas carry data the receiving half
/// genuinely lacks, so there is no redundant round for surplus memory
/// to elide (DESIGN.md decision 15). Products and T are bit-identical
/// to [`copk`]; `levels = 0` IS [`copk`].
pub fn copk_bfs<M: MachineApi>(
    m: &mut M,
    seq: &Seq,
    a: DistInt,
    b: DistInt,
    leaf: &LeafRef,
    levels: u32,
) -> Result<DistInt> {
    let p = seq.len();
    assert!(
        p == 1 || is_copk_procs(p as u64),
        "COPK requires |P| = 4·3^i (got {p})"
    );
    let n = a.total_width() as u64;
    let mcap = m.mem_cap();

    let mi_ok = (n as f64) <= mcap as f64 * pow_log3_2(p as f64) / 10.0;
    if p == 1 || mi_ok {
        return copk_mi(m, seq, a, b, leaf);
    }
    if levels == 0 {
        return copk(m, seq, a, b, leaf);
    }

    let w = a.chunk_width;
    ensure!(
        w >= 2 && w % 2 == 0,
        "COPK BFS cannot halve chunk width {w}: memory constraints violated (n={n}, P={p}, M={mcap})"
    );

    // --- Clone-elided depth-first step --------------------------------
    let pt = seq.interleave_halves();
    let (a0, a1) = a.split_half();
    let (b0, b1) = b.split_half();
    let half_w = w / 2;
    let lo_half = seq.lower_half();
    let hi_half = seq.upper_half();
    let mid = Seq(seq.ids()[p / 4..3 * p / 4].to_vec());

    // Step 3: C0 = A0 x B0; the DIFF's operands fork off as free
    // same-layout clones before the recursion consumes the copies.
    let a0c = a0.copy_to(m, &pt, half_w)?;
    let b0c = b0.copy_to(m, &pt, half_w)?;
    let a0d = a0c.copy_to(m, &pt, half_w)?; // clone for the diff: zero words/msgs
    let b0d = b0c.copy_to(m, &pt, half_w)?; // clone for the diff: zero words/msgs
    a0.free(m);
    b0.free(m);
    let c0 = copk_bfs(m, &pt, a0c, b0c, leaf, levels - 1)?;
    let c0 = c0.repartition(m, &lo_half, 2 * w)?;

    // Step 4: C2 = A1 x B1.
    let a1c = a1.copy_to(m, &pt, half_w)?;
    let b1c = b1.copy_to(m, &pt, half_w)?;
    let a1d = a1c.copy_to(m, &pt, half_w)?;
    let b1d = b1c.copy_to(m, &pt, half_w)?;
    a1.free(m);
    b1.free(m);
    let c2 = copk_bfs(m, &pt, a1c, b1c, leaf, levels - 1)?;
    let c2 = c2.repartition(m, &hi_half, 2 * w)?;

    // Steps 5-6: the differences, on the cloned operands, preserving
    // the DFS step's operand order (A' = |A0 - A1|, B' = |B1 - B0|).
    let (adiff, fa) = diff(m, &pt, &a0d, &a1d)?;
    a0d.free(m);
    a1d.free(m);
    let (bdiff, fb) = diff(m, &pt, &b1d, &b0d)?;
    b1d.free(m);
    b0d.free(m);
    let sign = fa * fb;

    // Step 7: C' = A' x B'.
    let cp = copk_bfs(m, &pt, adiff, bdiff, leaf, levels - 1)?;
    let cp = cp.repartition(m, &mid, 2 * w)?;

    recompose_karatsuba(m, seq, c0, cp, sign, c2, w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::leaf::{leaf_ref, SchoolLeaf, SkimLeaf};
    use crate::bignum::{mul, Base, Ops};
    use crate::sim::Machine;
    use crate::theory;
    use crate::util::Rng;

    fn verify_product(a: &[u32], b: &[u32], c: &[u32]) {
        let mut ops = Ops::default();
        let want = mul::mul_school(a, b, Base::new(16), &mut ops);
        assert_eq!(c, &want[..], "product mismatch");
    }

    fn run_mi(p: usize, n: usize, seed: u64) -> (Machine, Vec<u32>, Vec<u32>, Vec<u32>) {
        let mut rng = Rng::new(seed);
        let mut m = Machine::unbounded(p, Base::new(16));
        let seq = Seq::range(p);
        let a = rng.digits(n, 16);
        let b = rng.digits(n, 16);
        let da = DistInt::scatter(&mut m, &seq, &a, n / p).unwrap();
        let db = DistInt::scatter(&mut m, &seq, &b, n / p).unwrap();
        let c = copk_mi(&mut m, &seq, da, db, &leaf_ref(SkimLeaf)).unwrap();
        let cd = c.gather(&m).unwrap();
        (m, a, b, cd)
    }

    #[test]
    fn copk_mi_correct_base4() {
        for &n in &[16usize, 64, 256] {
            let (_, a, b, c) = run_mi(4, n, 0x4B + n as u64);
            verify_product(&a, &b, &c);
        }
    }

    #[test]
    fn copk_mi_correct_deeper() {
        // |P| = 12 (one BFS level), 36 (two), 108 (three).
        for &(p, n) in &[(12usize, 96usize), (12, 384), (36, 1728), (108, 1728)] {
            let (_, a, b, c) = run_mi(p, n, 0xC0 + p as u64);
            verify_product(&a, &b, &c);
        }
    }

    #[test]
    fn copk_mi_cost_within_thm14() {
        for &(p, n) in &[(4usize, 256usize), (12, 768), (36, 1728), (108, 5184)] {
            let (m, ..) = run_mi(p, n, 0x714);
            let c = m.critical();
            let bound = theory::thm14_copk_mi(n as u64, p as u64);
            assert!(c.ops <= bound.ops, "T p={p} n={n}: {} > {}", c.ops, bound.ops);
            assert!(
                c.words <= bound.words + bound.words / 4,
                "BW p={p} n={n}: {} > 1.25x{}",
                c.words,
                bound.words
            );
            // Latency shape O(log^2 P) with an empirically safe constant
            // (see copsim.rs for why the paper's 25·log2^2P constant is
            // not self-consistent with its own per-level recurrence).
            let lg = (p as f64).log2();
            let l_shape = (30.0 * lg * lg + 40.0) as u64;
            assert!(c.msgs <= l_shape, "L p={p} n={n}: {} > {}", c.msgs, l_shape);
        }
    }

    #[test]
    fn copk_main_mode_correct_under_memory_pressure() {
        // Cap memory at 40n/P (Theorem 15's requirement) to force DFS.
        // DFS engages only when 40n/P < 10n/P^(log3 2), i.e. P > 4^(1/0.369)
        // ≈ 43, so P = 108 is the smallest COPK-shaped count that
        // exercises it ((108, 10368) takes two DFS levels).
        for &(p, n) in &[(108usize, 5184usize), (108, 10368)] {
            let cap = (40 * n / p) as u64;
            let mi_need = theory::thm14_copk_mi_mem(n as u64, p as u64);
            assert!(
                cap < mi_need,
                "test must exercise the DFS path (cap {cap} >= {mi_need})"
            );
            let mut rng = Rng::new(0xD0);
            let mut m = Machine::new(p, cap, Base::new(16));
            let seq = Seq::range(p);
            let a = rng.digits(n, 16);
            let b = rng.digits(n, 16);
            let da = DistInt::scatter(&mut m, &seq, &a, n / p).unwrap();
            let db = DistInt::scatter(&mut m, &seq, &b, n / p).unwrap();
            let c = copk(&mut m, &seq, da, db, &leaf_ref(SchoolLeaf))
                .unwrap_or_else(|e| panic!("p={p} n={n} cap={cap}: {e}"));
            verify_product(&a, &b, &c.gather(&m).unwrap());
            let crit = m.critical();
            let bound = theory::thm15_copk(n as u64, p as u64, cap);
            assert!(crit.ops <= bound.ops, "T: {} > {}", crit.ops, bound.ops);
            assert!(crit.words <= bound.words, "BW: {} > {}", crit.words, bound.words);
            assert!(crit.msgs <= bound.msgs, "L: {} > {}", crit.msgs, bound.msgs);
            assert!(m.mem_peak_max() <= cap);
        }
    }

    #[test]
    fn copk_randomized_vs_reference() {
        crate::util::prop::check("copk-vs-ref", 20, |rng| {
            let p = [4usize, 12][rng.below(2) as usize];
            // chunk width: even, divisible by 2^levels.
            let w = 4usize << rng.range(0, 3);
            let n = p * w;
            let a = rng.digits(n, 16);
            let b = rng.digits(n, 16);
            let mut m = Machine::unbounded(p, Base::new(16));
            let seq = Seq::range(p);
            let da = DistInt::scatter(&mut m, &seq, &a, w).unwrap();
            let db = DistInt::scatter(&mut m, &seq, &b, w).unwrap();
            let c = copk_mi(&mut m, &seq, da, db, &leaf_ref(SkimLeaf)).unwrap();
            let mut ops = Ops::default();
            let want = mul::mul_school(&a, &b, Base::new(16), &mut ops);
            crate::prop_assert_eq!(c.gather(&m).unwrap(), want);
            crate::prop_assert_eq!(m.mem_used_total(), 2 * n as u64);
            Ok(())
        });
    }

    #[test]
    fn copk_beats_copsim_ops_at_scale() {
        // The whole point of Karatsuba: fewer digit operations. Compare
        // critical-path T at matching (n, P=4).
        let n = 4096;
        let (mk, ..) = run_mi(4, n, 5);
        let mut rng = Rng::new(5);
        let mut ms = Machine::unbounded(4, Base::new(16));
        let seq = Seq::range(4);
        let a = rng.digits(n, 16);
        let b = rng.digits(n, 16);
        let da = DistInt::scatter(&mut ms, &seq, &a, n / 4).unwrap();
        let db = DistInt::scatter(&mut ms, &seq, &b, n / 4).unwrap();
        crate::algorithms::copsim::copsim_mi(
            &mut ms,
            &seq,
            da,
            db,
            &leaf_ref(crate::algorithms::leaf::SlimLeaf),
        )
        .unwrap();
        assert!(
            mk.critical().ops < ms.critical().ops,
            "COPK {} !< COPSIM {}",
            mk.critical().ops,
            ms.critical().ops
        );
    }
}

//! The paper's parallel multiplication algorithms.
//!
//! * [`leaf`] — pluggable sequential leaf multipliers (SLIM/SKIM/hybrid/
//!   XLA) used once the recursion reaches a single processor.
//! * [`copsim`] — COPSIM (§5): MI mode (all-BFS over `P = 4^k`
//!   processors) and the main mode (DFS steps until the subproblem fits
//!   the MI memory requirement).
//! * [`copk`] — COPK (§6): MI mode (BFS over `P = 4·3^i` processors with
//!   the special `|P| = 4` base case) and the main DFS mode.
//! * [`hybrid`] — §7 hybridization: cost-model-driven choice between the
//!   two schemes (and the classical sequential crossover at the leaves).
//!
//! All entry points consume their [`DistInt`] inputs (the paper's
//! processors delete input digits as soon as they are no longer needed)
//! and return the full `2n`-digit product partitioned across the same
//! processor sequence.

pub mod copk;
pub mod copsim;
pub mod hybrid;
pub mod leaf;

pub use copk::{copk, copk_mi};
pub use copsim::{copsim, copsim_mi};
pub use hybrid::{choose_algorithm, hybrid_mul, Algorithm};
pub use leaf::{LeafMultiplier, SchoolLeaf, SkimLeaf, SlimLeaf};

use crate::sim::{DistInt, Machine, ProcId};
use anyhow::Result;

/// Multiply the single-processor leaf case: reads both operands, runs
/// the sequential leaf multiplier (charging its exact digit ops and —
/// per Facts 10/13 — a transient scratch allocation so the 8n-word
/// sequential space requirement shows up in the memory ledger), and
/// allocates the `2w`-digit product. Consumes the operands.
pub(crate) fn leaf_multiply(
    m: &mut Machine,
    pid: ProcId,
    a: DistInt,
    b: DistInt,
    leaf: &dyn leaf::LeafMultiplier,
) -> Result<DistInt> {
    debug_assert_eq!(a.chunks.len(), 1);
    debug_assert_eq!(b.chunks.len(), 1);
    let w = a.chunk_width;
    let mut av = m.read(pid, a.chunks[0].1).to_vec();
    let mut bv = m.read(pid, b.chunks[0].1).to_vec();
    // COPK's 3/2 width scaling produces non-power-of-two leaf widths;
    // SLIM/SKIM recurse on power-of-two operands, so pad (the product's
    // digits beyond 2w are provably zero and are truncated below).
    let wp = w.next_power_of_two();
    av.resize(wp, 0);
    bv.resize(wp, 0);
    // Model the sequential algorithm's working space (Facts 10/13: 8n
    // words total; inputs 2w + output 2w are ledgered explicitly, the
    // recursion scratch is a transient block). Charged on the TRUE
    // operand width w: the pow2 padding above is an artifact of reusing
    // SLIM/SKIM's power-of-two recursion, not of the paper's algorithm.
    let scratch = m.alloc(pid, vec![0u32; leaf.scratch_words(w)])?;
    let prod = m.local(pid, |base, ops| leaf.mul(&av, &bv, *base, ops));
    m.free(pid, scratch);
    let mut prod = prod;
    if prod.len() > 2 * w {
        debug_assert!(prod[2 * w..].iter().all(|&d| d == 0));
        prod.truncate(2 * w);
    }
    debug_assert_eq!(prod.len(), 2 * w);
    a.free(m);
    b.free(m);
    let slot = m.alloc(pid, prod)?;
    Ok(DistInt {
        chunk_width: 2 * w,
        chunks: vec![(pid, slot)],
    })
}

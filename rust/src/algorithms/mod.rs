//! The paper's parallel multiplication algorithms.
//!
//! * [`leaf`] — pluggable sequential leaf multipliers (SLIM/SKIM/hybrid/
//!   XLA) used once the recursion reaches a single processor.
//! * [`copsim`] — COPSIM (§5): MI mode (all-BFS over `P = 4^k`
//!   processors) and the main mode (DFS steps until the subproblem fits
//!   the MI memory requirement).
//! * [`copk`] — COPK (§6): MI mode (BFS over `P = 4·3^i` processors with
//!   the special `|P| = 4` base case) and the main DFS mode.
//! * [`hybrid`] — §7 hybridization: cost-model-driven choice between the
//!   two schemes (and the classical sequential crossover at the leaves).
//! * [`exec`] — memory-adaptive execution modes: the CAPS-style BFS/DFS
//!   tradeoff (`ExecMode`), spending surplus per-processor memory to
//!   elide repartition rounds at unchanged T and bit-identical products.
//!
//! All entry points consume their [`DistInt`] inputs (the paper's
//! processors delete input digits as soon as they are no longer needed)
//! and return the full `2n`-digit product partitioned across the same
//! processor sequence.

pub mod copk;
pub mod copsim;
pub mod exec;
pub mod hybrid;
pub mod leaf;

pub use copk::{copk, copk_bfs, copk_mi};
pub use copsim::{copsim, copsim_bfs, copsim_mi};
pub use exec::{mul_with_mode, resolve_mode, ExecMode, ExecPolicy};
pub use hybrid::{choose_algorithm, hybrid_mul, hybrid_mul_with_mode, Algorithm};
pub use leaf::{leaf_ref, LeafMultiplier, LeafRef, SchoolLeaf, SkimLeaf, SlimLeaf};

use crate::error::Result;
use crate::sim::{DistInt, MachineApi, ProcId};
use std::sync::Arc;

/// Multiply the single-processor leaf case: runs the sequential leaf
/// multiplier on the owning processor via `compute_slot` — charging its
/// exact digit ops and, per Facts 10/13, a transient scratch allocation
/// so the 8n-word sequential space requirement shows up in the memory
/// ledger — and produces the `2w`-digit product. Consumes the operands
/// (they are freed as the product materializes, like the paper's
/// processors delete input digits).
///
/// Going through `compute_slot` rather than `local` is what lets the
/// threaded engine run sibling leaves on their processors'
/// threads *concurrently* — the dominant digit work overlaps instead of
/// serializing on the host.
pub(crate) fn leaf_multiply<M: MachineApi>(
    m: &mut M,
    pid: ProcId,
    a: DistInt,
    b: DistInt,
    leaf: &LeafRef,
) -> Result<DistInt> {
    debug_assert_eq!(a.chunks.len(), 1);
    debug_assert_eq!(b.chunks.len(), 1);
    let w = a.chunk_width;
    // Model the sequential algorithm's working space (Facts 10/13: 8n
    // words total; inputs 2w + output 2w are ledgered explicitly, the
    // recursion scratch is a transient block). Charged on the TRUE
    // operand width w: the pow2 padding below is an artifact of reusing
    // SLIM/SKIM's power-of-two recursion, not of the paper's algorithm.
    let scratch = m.alloc(pid, vec![0u32; leaf.scratch_words(w)])?;
    let leaf = Arc::clone(leaf);
    let slot = m.compute_slot(
        pid,
        &[a.chunks[0].1, b.chunks[0].1],
        true, // operands are consumed as the product materializes
        Box::new(move |inputs, base, ops| {
            // COPK's 3/2 width scaling produces non-power-of-two leaf
            // widths; SLIM/SKIM recurse on power-of-two operands, so pad
            // (the product's digits beyond 2w are provably zero and are
            // truncated below).
            let wp = w.next_power_of_two();
            let mut av = inputs[0].to_vec();
            let mut bv = inputs[1].to_vec();
            av.resize(wp, 0);
            bv.resize(wp, 0);
            let mut prod = leaf.mul(&av, &bv, *base, ops);
            if prod.len() > 2 * w {
                debug_assert!(prod[2 * w..].iter().all(|&d| d == 0));
                prod.truncate(2 * w);
            }
            debug_assert_eq!(prod.len(), 2 * w);
            prod
        }),
    )?;
    m.free(pid, scratch);
    Ok(DistInt {
        chunk_width: 2 * w,
        chunks: vec![(pid, slot)],
    })
}

//! Memory-adaptive execution modes: the BFS/DFS tradeoff made explicit.
//!
//! The paper's analyses fix a per-processor memory footprint (Theorems
//! 11/12/14/15), but the memory-independent-lower-bound line in the
//! related work (arXiv 1202.3177; CAPS' BFS/DFS interleaving for
//! Strassen, arXiv 1202.3173) shows that when a processor's memory `M`
//! exceeds the MI minimum, the surplus can be traded for bandwidth:
//! replicate operands, take breadth-first steps, and skip repartition
//! rounds.
//!
//! This module defines the per-job mode vocabulary and the dispatcher:
//!
//! * [`ExecMode`] — the *resolved* mode a run executes under.
//!   `Dfs` is exactly today's entry points ([`copsim`]/[`copk`]);
//!   `Bfs { levels }` lets up to `levels` top recursion levels run the
//!   memory-hungry variants ([`copsim_bfs`]/[`copk_bfs`]).
//! * [`ExecPolicy`] — how a job *requests* a mode (`--exec-mode=` on
//!   the CLI, `JobSpec::exec_mode`, the daemon wire tag): a fixed mode,
//!   or `Auto`, resolved against the shard's memory by
//!   [`theory::best_mode`] at execution time.
//!
//! The modes change *which* communication rounds are charged, never the
//! values computed: products are bit-identical across modes and
//! engines, and T is mode-invariant (every processor performs the same
//! local digit operations in the same per-processor order; the elided
//! rounds only remove max-plus join edges that never carry the
//! critical ops chain). See DESIGN.md "Memory-adaptive execution".

use super::copk::{copk, copk_bfs};
use super::copsim::{copsim, copsim_bfs};
use super::hybrid::Algorithm;
use super::leaf::LeafRef;
use crate::error::{bail, Result};
use crate::sim::{DistInt, MachineApi, Seq};
use crate::theory;

/// The resolved per-job execution mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// The paper-default schedule: depth-first steps while memory is
    /// tight, then the plain MI recursion. Identical to the pre-mode
    /// entry points by construction.
    Dfs,
    /// Memory-hungry schedule: up to `levels` top recursion levels
    /// spend surplus memory to elide repartition rounds (fused operand
    /// distribution in the MI regime, clone-elided copies in the
    /// stepping regime). `levels = 0` is exactly [`ExecMode::Dfs`].
    Bfs { levels: u32 },
}

impl std::fmt::Display for ExecMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecMode::Dfs => write!(f, "dfs"),
            ExecMode::Bfs { levels } => write!(f, "bfs({levels})"),
        }
    }
}

/// How a job requests its execution mode. `Dfs` is the default
/// everywhere (CLI, `JobSpec`, wire frames) so existing invocations and
/// blessed cost tables are unchanged byte-for-byte.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ExecPolicy {
    /// Always the paper-default DFS schedule.
    #[default]
    Dfs,
    /// Pick the cheapest mode that fits the machine's per-processor
    /// memory ([`theory::best_mode`]).
    Auto,
    /// Request BFS; the affordable level count is resolved from memory
    /// ([`theory::bfs_levels`]), and a shard that cannot afford any
    /// level is rejected distinctly at admission (`RejectKind`).
    Bfs,
}

impl ExecPolicy {
    /// Parse a `--exec-mode=` value.
    pub fn parse(s: &str) -> Result<ExecPolicy> {
        match s {
            "dfs" => Ok(ExecPolicy::Dfs),
            "auto" => Ok(ExecPolicy::Auto),
            "bfs" => Ok(ExecPolicy::Bfs),
            _ => bail!("unknown exec mode '{s}' (expected auto|dfs|bfs)"),
        }
    }

    /// Wire tag for the daemon's `Request` frame (the u16 field that
    /// was reserved-zero before schema-aware decoding: 0 decodes to
    /// `Dfs`, so pre-mode frames keep their meaning).
    pub fn tag(self) -> u16 {
        match self {
            ExecPolicy::Dfs => 0,
            ExecPolicy::Auto => 1,
            ExecPolicy::Bfs => 2,
        }
    }

    /// Inverse of [`ExecPolicy::tag`].
    pub fn from_tag(t: u16) -> Result<ExecPolicy> {
        match t {
            0 => Ok(ExecPolicy::Dfs),
            1 => Ok(ExecPolicy::Auto),
            2 => Ok(ExecPolicy::Bfs),
            _ => bail!("bad exec-mode tag {t}"),
        }
    }
}

impl std::fmt::Display for ExecPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecPolicy::Dfs => write!(f, "dfs"),
            ExecPolicy::Auto => write!(f, "auto"),
            ExecPolicy::Bfs => write!(f, "bfs"),
        }
    }
}

/// Resolve a policy to a concrete mode for `algo` on `(n, p)` with
/// per-processor memory `mem`. Deterministic in its arguments, so every
/// engine resolves the same mode for the same job and shard.
pub fn resolve_mode(policy: ExecPolicy, algo: Algorithm, n: u64, p: u64, mem: u64) -> ExecMode {
    match policy {
        ExecPolicy::Dfs => ExecMode::Dfs,
        ExecPolicy::Auto => theory::best_mode(algo, n, p, mem),
        ExecPolicy::Bfs => ExecMode::Bfs {
            levels: theory::bfs_levels(algo, n, p, mem),
        },
    }
}

/// Run `algo` under `mode`. Consumes `a`, `b` like the underlying entry
/// points; `ExecMode::Dfs` dispatches to exactly the pre-mode code
/// paths (zero-diff by construction).
pub fn mul_with_mode<M: MachineApi>(
    m: &mut M,
    seq: &Seq,
    a: DistInt,
    b: DistInt,
    leaf: &LeafRef,
    algo: Algorithm,
    mode: ExecMode,
) -> Result<DistInt> {
    match (algo, mode) {
        (Algorithm::Copsim, ExecMode::Dfs) => copsim(m, seq, a, b, leaf),
        (Algorithm::Copsim, ExecMode::Bfs { levels }) => copsim_bfs(m, seq, a, b, leaf, levels),
        (Algorithm::Copk, ExecMode::Dfs) => copk(m, seq, a, b, leaf),
        (Algorithm::Copk, ExecMode::Bfs { levels }) => copk_bfs(m, seq, a, b, leaf, levels),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::leaf::{leaf_ref, SchoolLeaf};
    use crate::bignum::{mul, Base, Ops};
    use crate::sim::{Clock, Machine};
    use crate::util::Rng;

    /// Run one (algo, mode) cell on a capped machine, verify the
    /// product against the sequential reference, and return the
    /// critical-path cost triple.
    fn run_mode(algo: Algorithm, mode: ExecMode, p: usize, n: usize, cap: u64, seed: u64) -> Clock {
        let mut rng = Rng::new(seed);
        let mut m = Machine::new(p, cap, Base::new(16));
        let seq = Seq::range(p);
        let a = rng.digits(n, 16);
        let b = rng.digits(n, 16);
        let da = DistInt::scatter(&mut m, &seq, &a, n / p).unwrap();
        let db = DistInt::scatter(&mut m, &seq, &b, n / p).unwrap();
        let leaf = leaf_ref(SchoolLeaf);
        let c = mul_with_mode(&mut m, &seq, da, db, &leaf, algo, mode)
            .unwrap_or_else(|e| panic!("{algo} {mode} p={p} n={n} cap={cap}: {e}"));
        let cd = c.gather(&m).unwrap();
        let mut ops = Ops::default();
        let want = mul::mul_school(&a, &b, Base::new(16), &mut ops);
        assert_eq!(cd, want, "product mismatch {algo} {mode} p={p} n={n}");
        assert!(m.mem_peak_max() <= cap, "{algo} {mode}: peak over cap");
        m.critical()
    }

    #[test]
    fn exec_policy_parses_and_round_trips() {
        assert_eq!(ExecPolicy::parse("auto").unwrap(), ExecPolicy::Auto);
        assert_eq!(ExecPolicy::parse("dfs").unwrap(), ExecPolicy::Dfs);
        assert_eq!(ExecPolicy::parse("bfs").unwrap(), ExecPolicy::Bfs);
        assert!(ExecPolicy::parse("breadth").is_err());
        for p in [ExecPolicy::Dfs, ExecPolicy::Auto, ExecPolicy::Bfs] {
            assert_eq!(ExecPolicy::from_tag(p.tag()).unwrap(), p);
        }
        assert!(ExecPolicy::from_tag(7).is_err());
        assert_eq!(ExecPolicy::default(), ExecPolicy::Dfs);
    }

    /// Acceptance cell (COPSIM, roomy): shard M = 2x the MI footprint.
    /// The fused distribution must cut charged BW strictly below DFS at
    /// bit-equal T, within the predicted `theory::copsim_bfs_mi` bound.
    #[test]
    fn copsim_bfs_roomy_cuts_bw_at_equal_t() {
        let (p, n) = (16usize, 1024usize);
        let mi_need = crate::theory::thm11_copsim_mi_mem(n as u64, p as u64);
        let cap = 2 * mi_need; // the acceptance qualifier: M >= 2x MI
        let mode = crate::theory::best_mode(Algorithm::Copsim, n as u64, p as u64, cap);
        assert_eq!(mode, ExecMode::Bfs { levels: 2 }, "auto must pick full-depth BFS");
        let dfs = run_mode(Algorithm::Copsim, ExecMode::Dfs, p, n, cap, 0xE0);
        let bfs = run_mode(Algorithm::Copsim, mode, p, n, cap, 0xE0);
        assert_eq!(bfs.ops, dfs.ops, "T must be mode-invariant");
        assert!(bfs.words < dfs.words, "BFS BW {} !< DFS BW {}", bfs.words, dfs.words);
        assert!(bfs.msgs <= dfs.msgs, "BFS L {} > DFS L {}", bfs.msgs, dfs.msgs);
        // Predicted ordering matches the charged ordering.
        let (bp, bm) = crate::theory::exec_mode_bounds(Algorithm::Copsim, n as u64, p as u64, cap, mode);
        let (dp, _) = crate::theory::exec_mode_bounds(Algorithm::Copsim, n as u64, p as u64, cap, ExecMode::Dfs);
        assert!(bp.words < dp.words, "predicted BW not lower");
        assert_eq!(bp.ops, dp.ops, "predicted T not mode-invariant");
        assert!(bm <= cap, "predicted footprint must fit the cell");
        // Charged BW within the predicted bound (same 25% polylog slack
        // as the Theorem 11 gate in copsim.rs).
        assert!(
            bfs.words <= bp.words + bp.words / 4,
            "BW {} > 1.25x predicted {}",
            bfs.words,
            bp.words
        );
    }

    /// COPSIM stepping regime: clone-elided DFS steps at a cap below
    /// the MI requirement but above `copsim_bfs_step_mem`.
    #[test]
    fn copsim_bfs_stepping_cuts_bw_at_equal_t() {
        let (p, n) = (256usize, 4096usize);
        let cap = 2048u64; // 128n/P: < 12n/sqrt(P) = 3072, >= 96n/P = 1536
        assert!(cap < crate::theory::thm11_copsim_mi_mem(n as u64, p as u64));
        let mode = crate::theory::best_mode(Algorithm::Copsim, n as u64, p as u64, cap);
        assert_eq!(mode, ExecMode::Bfs { levels: 1 }, "auto must elide the one DFS step");
        let dfs = run_mode(Algorithm::Copsim, ExecMode::Dfs, p, n, cap, 0xE1);
        let bfs = run_mode(Algorithm::Copsim, mode, p, n, cap, 0xE1);
        assert_eq!(bfs.ops, dfs.ops, "T must be mode-invariant");
        assert!(bfs.words < dfs.words, "BFS BW {} !< DFS BW {}", bfs.words, dfs.words);
        assert!(bfs.msgs <= dfs.msgs);
        let (bp, _) = crate::theory::exec_mode_bounds(Algorithm::Copsim, n as u64, p as u64, cap, mode);
        let (dp, _) = crate::theory::exec_mode_bounds(Algorithm::Copsim, n as u64, p as u64, cap, ExecMode::Dfs);
        assert!(bp.words < dp.words && bp.ops == dp.ops);
        assert!(bfs.words <= bp.words, "BW {} > predicted {}", bfs.words, bp.words);
    }

    /// Acceptance cell (COPK): stepping regime at `copk_bfs_step_mem`.
    #[test]
    fn copk_bfs_stepping_cuts_bw_at_equal_t() {
        let (p, n) = (108usize, 5184usize);
        let cap = crate::theory::copk_bfs_step_mem(n as u64, p as u64); // 48n/P = 2304
        assert!(cap < crate::theory::thm14_copk_mi_mem(n as u64, p as u64));
        let mode = crate::theory::best_mode(Algorithm::Copk, n as u64, p as u64, cap);
        assert_eq!(mode, ExecMode::Bfs { levels: 1 }, "auto must elide the one DFS step");
        let dfs = run_mode(Algorithm::Copk, ExecMode::Dfs, p, n, cap, 0xE2);
        let bfs = run_mode(Algorithm::Copk, mode, p, n, cap, 0xE2);
        assert_eq!(bfs.ops, dfs.ops, "T must be mode-invariant");
        assert!(bfs.words < dfs.words, "BFS BW {} !< DFS BW {}", bfs.words, dfs.words);
        assert!(bfs.msgs <= dfs.msgs);
        let (bp, _) = crate::theory::exec_mode_bounds(Algorithm::Copk, n as u64, p as u64, cap, mode);
        let (dp, _) = crate::theory::exec_mode_bounds(Algorithm::Copk, n as u64, p as u64, cap, ExecMode::Dfs);
        assert!(bp.words < dp.words && bp.ops == dp.ops);
        assert!(bfs.words <= bp.words, "BW {} > predicted {}", bfs.words, bp.words);
    }

    /// COPK's MI regime has no redundant round to elide (decision 15):
    /// with roomy memory, BFS and DFS are the *same* schedule, and the
    /// cost triple must be bit-identical.
    #[test]
    fn copk_bfs_roomy_is_mode_invariant() {
        let (p, n) = (12usize, 384usize);
        let cap = u64::MAX / 4;
        assert_eq!(
            crate::theory::best_mode(Algorithm::Copk, n as u64, p as u64, cap),
            ExecMode::Dfs,
            "auto must not claim a BFS win COPK-MI cannot deliver"
        );
        let dfs = run_mode(Algorithm::Copk, ExecMode::Dfs, p, n, cap, 0xE3);
        let bfs = run_mode(Algorithm::Copk, ExecMode::Bfs { levels: 8 }, p, n, cap, 0xE3);
        assert_eq!(bfs, dfs, "COPK-MI must be mode-invariant");
    }

    /// `Bfs { levels: 0 }` is exactly DFS — the zero-diff invariant the
    /// scheduler's downgrade path relies on.
    #[test]
    fn bfs_zero_levels_is_exactly_dfs() {
        for &(algo, p, n, cap) in &[
            (Algorithm::Copsim, 16usize, 256usize, u64::MAX / 4),
            (Algorithm::Copsim, 64, 4096, 80 * 4096 / 64),
            (Algorithm::Copk, 12, 384, u64::MAX / 4),
        ] {
            let dfs = run_mode(algo, ExecMode::Dfs, p, n, cap, 0xE4);
            let bfs0 = run_mode(algo, ExecMode::Bfs { levels: 0 }, p, n, cap, 0xE4);
            assert_eq!(bfs0, dfs, "{algo} p={p} n={n}: Bfs{{0}} diverged from Dfs");
        }
    }

    #[test]
    fn resolve_mode_honors_policy_and_memory() {
        let (n, p) = (1024u64, 16u64);
        let roomy = 2 * crate::theory::thm11_copsim_mi_mem(n, p);
        let tight = crate::theory::thm11_copsim_mi_mem(n, p);
        // Dfs policy never upgrades.
        assert_eq!(resolve_mode(ExecPolicy::Dfs, Algorithm::Copsim, n, p, roomy), ExecMode::Dfs);
        // Auto picks BFS only when the footprint fits.
        assert_eq!(
            resolve_mode(ExecPolicy::Auto, Algorithm::Copsim, n, p, roomy),
            ExecMode::Bfs { levels: 2 }
        );
        assert_eq!(resolve_mode(ExecPolicy::Auto, Algorithm::Copsim, n, p, tight), ExecMode::Dfs);
        // Explicit Bfs degrades to zero affordable levels (the scheduler
        // surfaces this as a distinct rejection at admission).
        assert_eq!(
            resolve_mode(ExecPolicy::Bfs, Algorithm::Copsim, n, p, tight),
            ExecMode::Bfs { levels: 0 }
        );
    }
}

//! COPSIM — Communication-Optimal Parallel Standard Integer
//! Multiplication (paper §5).
//!
//! Recursive 4-way splitting of the schoolbook scheme
//! `C = C0 + s^(n/2)(C1 + C2) + s^n·C3` with
//! `C0 = A0·B0, C1 = A0·B1, C2 = A1·B0, C3 = A1·B1`.
//!
//! * **MI (memory-independent) mode** ([`copsim_mi`], §5.1): `log₄ P`
//!   breadth-first steps; at each level the four subproblems are computed
//!   *in parallel* by four disjoint processor groups (evens/odds of each
//!   half of the sequence); the leaves run the sequential leaf
//!   multiplier. Theorem 11: `T ≤ 38n²/P + 3log₂²P`,
//!   `BW ≤ 14n/√P + 6log₂²P`, `L ≤ 3log₂²P`, memory `12n/√P`.
//! * **Main mode** ([`copsim`], §5.2): while the subproblem is too large
//!   for MI (`n > M√P/12`), a depth-first step runs the four subproblems
//!   *sequentially on all P processors* (interleaved re-ranking, halved
//!   chunk width), stashing each output; then the same recomposition.
//!   Theorem 12: `T ≤ 196n²/P`, `BW ≤ 3530n²/(MP)`,
//!   `L ≤ 7012·n²log₂²P/(M²P)`, requiring `M ≥ 80n/P` and `M ≥ log₂P`.
//!
//! The recomposition follows the paper's §5.1 phase (3): redistribute
//! `C0 → P'`, `C3 → P''`, `C1, C2 → middle`, then three SUM invocations
//! on `P* = seq[P/4..P]` (3P/4 processors) add the overlapping windows
//! `C0≫n/2, C1, C2, C3≪n/2` as `3n/2`-digit values. All data movement
//! goes through the `sim::collectives` layer — the repartitions compile
//! to its coalesced all-to-all (each digit moves once; DESIGN.md
//! decision 4), the operand replication to its `shift`, and the SUM
//! flag exchanges to its `fanout` — so the `O(log P)` tree structure
//! behind Theorem 1's latency claim is explicit, not implicit in ad-hoc
//! send loops.

use super::leaf::LeafRef;
use super::leaf_multiply;
use crate::error::{ensure, Result};
use crate::primitives::sum;
use crate::sim::{DistInt, MachineApi, Seq};

/// `true` iff `p` is a power of four (COPSIM's processor-count shape).
pub fn is_pow4(p: usize) -> bool {
    p.is_power_of_two() && p.trailing_zeros() % 2 == 0
}

/// Shared recomposition: combine subproducts
/// `C = C0 + s^(n/2)(C1+C2) + s^n·C3` onto `seq` with chunk width `2w`,
/// where each `C_i` holds `n = |seq|·w` digits (in any current layout).
pub(crate) fn recompose<M: MachineApi>(
    m: &mut M,
    seq: &Seq,
    c0: DistInt,
    c1: DistInt,
    c2: DistInt,
    c3: DistInt,
    w: usize,
) -> Result<DistInt> {
    let p = seq.len();
    let w2 = 2 * w;
    let lo_half = seq.lower_half();
    let hi_half = seq.upper_half();
    let mid = Seq(seq.ids()[p / 4..3 * p / 4].to_vec());
    let pstar = Seq(seq.ids()[p / 4..].to_vec());

    // Phase 3a-3e equivalents: redistribute the subproducts.
    let c0 = c0.repartition(m, &lo_half, w2)?;
    let c3 = c3.repartition(m, &hi_half, w2)?;
    let c1 = c1.repartition(m, &mid, w2)?;
    let c2 = c2.repartition(m, &mid, w2)?;

    // C0's low n/2 digits are final; its high half joins the sum.
    let (c0_lo, c0_hi) = c0.split_half();

    // Build the four 3n/2-digit summands over P* (chunk width 2w):
    //   X0 = C0 >> n/2, X1 = C1, X2 = C2, X3 = C3 << n/2.
    let x0 = c0_hi.extend_zero(m, &seq.ids()[p / 2..])?;
    let x1 = c1.extend_zero(m, &seq.ids()[3 * p / 4..])?;
    let x2 = c2.extend_zero(m, &seq.ids()[3 * p / 4..])?;
    let x3 = c3.prepend_zero(m, &seq.ids()[p / 4..p / 2])?;

    // Three consecutive SUMs on P*; every carry must vanish because the
    // running total is < s^(3n/2) (C < s^(2n)).
    let (s1, v1) = sum(m, &pstar, &x0, &x1)?;
    ensure!(v1 == 0, "recompose: unexpected carry in X0+X1");
    let (s2, v2) = sum(m, &pstar, &s1, &x2)?;
    ensure!(v2 == 0, "recompose: unexpected carry in +X2");
    s1.free(m);
    let (s3, v3) = sum(m, &pstar, &s2, &x3)?;
    ensure!(v3 == 0, "recompose: unexpected carry in +X3");
    s2.free(m);
    x0.free(m);
    x1.free(m);
    x2.free(m);
    x3.free(m);

    Ok(DistInt::concat(c0_lo, s3))
}

/// COPSIM in the MI execution mode (§5.1). Consumes `a`, `b`
/// (each `n = |seq|·w` digits partitioned in `seq`); returns the
/// `2n`-digit product partitioned in `seq` in `2w`-digit chunks.
pub fn copsim_mi<M: MachineApi>(
    m: &mut M,
    seq: &Seq,
    a: DistInt,
    b: DistInt,
    leaf: &LeafRef,
) -> Result<DistInt> {
    let p = seq.len();
    assert!(is_pow4(p), "COPSIM_MI requires |P| = 4^k (got {p})");
    assert_eq!(a.total_width(), b.total_width());
    let w = a.chunk_width;
    assert!(w.is_power_of_two(), "chunk width must be a power of two");

    if p == 1 {
        return leaf_multiply(m, seq.at(0), a, b, leaf);
    }

    // --- Splitting (phase 1) -----------------------------------------
    let [g0, g1, g2, g3] = seq.copsim_groups();
    let (a0, a1) = a.split_half();
    let (b0, b1) = b.split_half();
    let w2 = 2 * w;

    // Phase 1a: concentrate each operand half on the even/odd groups
    // (each digit moves once); phases 1b/1c: replicate to the second
    // group that needs it (one parallel message round of 2w words).
    let a0_g0 = a0.repartition(m, &g0, w2)?;
    let a0_g1 = a0_g0.replicate(m, &g1)?;
    let b0_g0 = b0.repartition(m, &g0, w2)?;
    let b0_g2 = b0_g0.replicate(m, &g2)?;
    let a1_g2 = a1.repartition(m, &g2, w2)?;
    let a1_g3 = a1_g2.replicate(m, &g3)?;
    let b1_g3 = b1.repartition(m, &g3, w2)?;
    let b1_g1 = b1_g3.replicate(m, &g1)?;

    // --- Recursive multiplication (phase 2), four groups in parallel --
    let c0 = copsim_mi(m, &g0, a0_g0, b0_g0, leaf)?;
    let c1 = copsim_mi(m, &g1, a0_g1, b1_g1, leaf)?;
    let c2 = copsim_mi(m, &g2, a1_g2, b0_g2, leaf)?;
    let c3 = copsim_mi(m, &g3, a1_g3, b1_g3, leaf)?;

    // --- Recomposition (phase 3) --------------------------------------
    recompose(m, seq, c0, c1, c2, c3, w)
}

/// COPSIM_MI with the BFS fused operand distribution
/// (`ExecMode::Bfs` in the MI regime): when the machine has at least
/// twice the Theorem 11 footprint (`n ≤ M√P/24`, checked per level),
/// each operand half is copied *directly* from its original layout to
/// both groups that need it, replacing the repartition-then-replicate
/// pair of [`copsim_mi`]. Destination layouts — and therefore products,
/// recursion structure, and every processor's local op sequence — are
/// identical; only the sender charges change: the per-level maximum
/// drops from `4w` (even-low processors pay two 2w replicates) to `3w`
/// words, giving `BW ≤ 13n/√P + 6log₂²P` (`theory::copsim_bfs_mi`)
/// at unchanged T and L.
///
/// The gate is level-invariant (`n` and `√P` halve together down the
/// MI recursion), so a failed gate fails at every deeper level and the
/// fallback to [`copsim_mi`] is total, not partial.
pub(crate) fn copsim_mi_fused<M: MachineApi>(
    m: &mut M,
    seq: &Seq,
    a: DistInt,
    b: DistInt,
    leaf: &LeafRef,
    levels: u32,
) -> Result<DistInt> {
    let p = seq.len();
    assert!(is_pow4(p), "COPSIM_MI requires |P| = 4^k (got {p})");
    if p == 1 {
        return leaf_multiply(m, seq.at(0), a, b, leaf);
    }
    let n = a.total_width() as u64;
    let fused_ok = levels > 0 && (n as f64) <= m.mem_cap() as f64 * (p as f64).sqrt() / 24.0;
    if !fused_ok {
        return copsim_mi(m, seq, a, b, leaf);
    }
    assert_eq!(a.total_width(), b.total_width());
    let w = a.chunk_width;
    assert!(w.is_power_of_two(), "chunk width must be a power of two");

    let [g0, g1, g2, g3] = seq.copsim_groups();
    let (a0, a1) = a.split_half();
    let (b0, b1) = b.split_half();
    let w2 = 2 * w;

    // Fused phase 1: both copies of each half leave from the ORIGINAL
    // half layout (every source chunk already sits on a processor of
    // one destination group, so one of the two copies is half-free),
    // then the source is deleted — no replicate round.
    let a0_g0 = a0.copy_to(m, &g0, w2)?;
    let a0_g1 = a0.copy_to(m, &g1, w2)?;
    a0.free(m);
    let b0_g0 = b0.copy_to(m, &g0, w2)?;
    let b0_g2 = b0.copy_to(m, &g2, w2)?;
    b0.free(m);
    let a1_g2 = a1.copy_to(m, &g2, w2)?;
    let a1_g3 = a1.copy_to(m, &g3, w2)?;
    a1.free(m);
    let b1_g3 = b1.copy_to(m, &g3, w2)?;
    let b1_g1 = b1.copy_to(m, &g1, w2)?;
    b1.free(m);

    let c0 = copsim_mi_fused(m, &g0, a0_g0, b0_g0, leaf, levels - 1)?;
    let c1 = copsim_mi_fused(m, &g1, a0_g1, b1_g1, leaf, levels - 1)?;
    let c2 = copsim_mi_fused(m, &g2, a1_g2, b0_g2, leaf, levels - 1)?;
    let c3 = copsim_mi_fused(m, &g3, a1_g3, b1_g3, leaf, levels - 1)?;

    recompose(m, seq, c0, c1, c2, c3, w)
}

/// COPSIM with up to `levels` memory-hungry breadth-first levels
/// (`ExecMode::Bfs`). In the MI regime this is [`copsim_mi_fused`]; in
/// the stepping regime each DFS step copies every operand half to the
/// re-ranked sequence ONCE and forks its second use as a same-layout
/// clone — charged memory only (`repartition_same_layout_is_free`) —
/// halving the step's charged copy rounds (8 → 4, saving ≥ n/P words
/// on every processor; `theory::copsim_bfs_step`). Products and T are
/// bit-identical to [`copsim`]; `levels = 0` IS [`copsim`].
pub fn copsim_bfs<M: MachineApi>(
    m: &mut M,
    seq: &Seq,
    a: DistInt,
    b: DistInt,
    leaf: &LeafRef,
    levels: u32,
) -> Result<DistInt> {
    let p = seq.len();
    assert!(is_pow4(p), "COPSIM requires |P| = 4^k (got {p})");
    let n = a.total_width() as u64;
    let mcap = m.mem_cap();

    let mi_ok = (n as f64) <= mcap as f64 * (p as f64).sqrt() / 12.0;
    if p == 1 || mi_ok {
        return copsim_mi_fused(m, seq, a, b, leaf, levels);
    }
    if levels == 0 {
        return copsim(m, seq, a, b, leaf);
    }

    let w = a.chunk_width;
    ensure!(
        w >= 2 && w % 2 == 0,
        "COPSIM BFS cannot halve chunk width {w}: M ≥ 80n/P / M ≥ 24√P violated (n={n}, P={p}, M={mcap})"
    );

    // --- Clone-elided depth-first step --------------------------------
    let pt = seq.interleave_halves();
    let (a0, a1) = a.split_half();
    let (b0, b1) = b.split_half();
    let half_w = w / 2;
    let lo_half = seq.lower_half();
    let hi_half = seq.upper_half();
    let mid = Seq(seq.ids()[p / 4..3 * p / 4].to_vec());

    // C0 = A0 x B0. Each half is copied once; the second user's operand
    // is a free same-layout clone taken before the recursion dirties it.
    let a0c = a0.copy_to(m, &pt, half_w)?;
    let b0c = b0.copy_to(m, &pt, half_w)?;
    let a0c2 = a0c.copy_to(m, &pt, half_w)?; // clone for C1: zero words/msgs
    let b0c2 = b0c.copy_to(m, &pt, half_w)?; // clone for C2: zero words/msgs
    a0.free(m);
    b0.free(m);
    let c0 = copsim_bfs(m, &pt, a0c, b0c, leaf, levels - 1)?;
    let c0 = c0.repartition(m, &lo_half, 2 * w)?;

    // C1 = A0 x B1.
    let b1c = b1.copy_to(m, &pt, half_w)?;
    let b1c2 = b1c.copy_to(m, &pt, half_w)?; // clone for C3
    b1.free(m);
    let c1 = copsim_bfs(m, &pt, a0c2, b1c, leaf, levels - 1)?;
    let c1 = c1.repartition(m, &mid, 2 * w)?;

    // C2 = A1 x B0.
    let a1c = a1.copy_to(m, &pt, half_w)?;
    let a1c2 = a1c.copy_to(m, &pt, half_w)?; // clone for C3
    a1.free(m);
    let c2 = copsim_bfs(m, &pt, a1c, b0c2, leaf, levels - 1)?;
    let c2 = c2.repartition(m, &mid, 2 * w)?;

    // C3 = A1 x B1, entirely from clones.
    let c3 = copsim_bfs(m, &pt, a1c2, b1c2, leaf, levels - 1)?;
    let c3 = c3.repartition(m, &hi_half, 2 * w)?;

    recompose(m, seq, c0, c1, c2, c3, w)
}

/// COPSIM in the main execution mode (§5.2): depth-first steps until the
/// subproblem satisfies the MI memory requirement `n ≤ M√P/12`, then
/// [`copsim_mi`]. The machine's per-processor capacity `M` is taken from
/// `m`; Theorem 12 requires `M ≥ max(80n/P, log₂P)` (and `M ≥ 24√P` for
/// the DFS chunk widths to stay integral — Theorem 1's condition).
pub fn copsim<M: MachineApi>(
    m: &mut M,
    seq: &Seq,
    a: DistInt,
    b: DistInt,
    leaf: &LeafRef,
) -> Result<DistInt> {
    let p = seq.len();
    assert!(is_pow4(p), "COPSIM requires |P| = 4^k (got {p})");
    let n = a.total_width() as u64;
    let mcap = m.mem_cap();

    // MI eligibility: n <= M·sqrt(P)/12.
    let mi_ok = (n as f64) <= mcap as f64 * (p as f64).sqrt() / 12.0;
    if p == 1 || mi_ok {
        return copsim_mi(m, seq, a, b, leaf);
    }

    let w = a.chunk_width;
    ensure!(
        w >= 2 && w % 2 == 0,
        "COPSIM DFS cannot halve chunk width {w}: M ≥ 80n/P / M ≥ 24√P violated (n={n}, P={p}, M={mcap})"
    );

    // --- Depth-first step: four subproblems on ALL processors ---------
    let pt = seq.interleave_halves();
    let (a0, a1) = a.split_half();
    let (b0, b1) = b.split_half();
    let half_w = w / 2;
    let lo_half = seq.lower_half();
    let hi_half = seq.upper_half();
    let mid = Seq(seq.ids()[p / 4..3 * p / 4].to_vec());

    // C0 = A0 x B0.
    let a0c = a0.copy_to(m, &pt, half_w)?;
    let b0c = b0.copy_to(m, &pt, half_w)?;
    let c0 = copsim(m, &pt, a0c, b0c, leaf)?;
    let c0 = c0.repartition(m, &lo_half, 2 * w)?; // stash on the lower half

    // C1 = A0 x B1.
    let a0c = a0.copy_to(m, &pt, half_w)?;
    let b1c = b1.copy_to(m, &pt, half_w)?;
    let c1 = copsim(m, &pt, a0c, b1c, leaf)?;
    let c1 = c1.repartition(m, &mid, 2 * w)?;

    // C2 = A1 x B0.
    let a1c = a1.copy_to(m, &pt, half_w)?;
    let b0c = b0.copy_to(m, &pt, half_w)?;
    let c2 = copsim(m, &pt, a1c, b0c, leaf)?;
    let c2 = c2.repartition(m, &mid, 2 * w)?;

    // C3 = A1 x B1 — the originals are no longer needed afterwards, so
    // free them before recursing (the paper deletes copies eagerly).
    let a1c = a1.copy_to(m, &pt, half_w)?;
    let b1c = b1.copy_to(m, &pt, half_w)?;
    a0.free(m);
    a1.free(m);
    b0.free(m);
    b1.free(m);
    let c3 = copsim(m, &pt, a1c, b1c, leaf)?;
    let c3 = c3.repartition(m, &hi_half, 2 * w)?;

    // --- Recomposition, identical to the MI mode ----------------------
    // Each C_i holds n = |seq|·w digits; the result comes back on `seq`
    // with chunk width 2w (2n digits total).
    recompose(m, seq, c0, c1, c2, c3, w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::leaf::{leaf_ref, SchoolLeaf, SlimLeaf};
    use crate::bignum::{mul, Base, Ops};
    use crate::sim::Machine;
    use crate::theory;
    use crate::util::Rng;

    fn verify_product(a: &[u32], b: &[u32], c: &[u32]) {
        let mut ops = Ops::default();
        let want = mul::mul_school(a, b, Base::new(16), &mut ops);
        assert_eq!(c, &want[..], "product mismatch");
    }

    fn run_mi(p: usize, n: usize, seed: u64) -> (Machine, Vec<u32>, Vec<u32>, Vec<u32>) {
        let mut rng = Rng::new(seed);
        let mut m = Machine::unbounded(p, Base::new(16));
        let seq = Seq::range(p);
        let a = rng.digits(n, 16);
        let b = rng.digits(n, 16);
        let da = DistInt::scatter(&mut m, &seq, &a, n / p).unwrap();
        let db = DistInt::scatter(&mut m, &seq, &b, n / p).unwrap();
        let c = copsim_mi(&mut m, &seq, da, db, &leaf_ref(SlimLeaf)).unwrap();
        let cd = c.gather(&m).unwrap();
        (m, a, b, cd)
    }

    #[test]
    fn copsim_mi_correct() {
        for &(p, n) in &[(1usize, 16usize), (4, 16), (4, 64), (16, 64), (16, 256), (64, 256)] {
            let (_, a, b, c) = run_mi(p, n, 0xC0D + p as u64 + n as u64);
            verify_product(&a, &b, &c);
        }
    }

    #[test]
    fn copsim_mi_cost_within_thm11() {
        for &(p, n) in &[(4usize, 64usize), (16, 256), (64, 1024), (64, 4096)] {
            let (m, ..) = run_mi(p, n, 0x711);
            let c = m.critical();
            let bound = theory::thm11_copsim_mi(n as u64, p as u64);
            assert!(c.ops <= bound.ops, "T p={p} n={n}: {} > {}", c.ops, bound.ops);
            // Bandwidth: the leading 14n/sqrt(P) term holds; our SUM
            // runs on the uneven 3P/4-processor sequence via fanout
            // relays, which adds a slightly larger polylog term than the
            // paper's 6·log2^2 P. Allow 25% headroom on the total and
            // validate the asymptotic shape in copsim_mi_bw_shape.
            assert!(
                c.words <= bound.words + bound.words / 4,
                "BW p={p} n={n}: {} > 1.25x{}",
                c.words,
                bound.words
            );
            // Latency: Theorem 11 claims 3·log2^2 P, but the paper's own
            // recurrence (8 + 6(log2(3P/4)-1) per level plus 3 SUMs at
            // 2·log2(3P/4) messages each) already exceeds that at P = 4;
            // the substantive claim (Thm 1) is L = O(log^2 P). We assert
            // the shape with an empirically safe constant and report the
            // measured/paper ratio in E4.
            let lg = (p as f64).log2();
            let l_shape = (8.0 * lg * lg + 16.0) as u64;
            assert!(c.msgs <= l_shape, "L p={p} n={n}: {} > {}", c.msgs, l_shape);
        }
    }

    #[test]
    fn copsim_mi_latency_is_polylog() {
        // L(P)/log2^2(P) must stay bounded as P grows with n scaled to
        // keep n/P fixed — the O(log^2 P) latency claim of Theorem 1.
        let mut ratios = Vec::new();
        for &(p, n) in &[(4usize, 256usize), (16, 1024), (64, 4096), (256, 16384)] {
            let (m, ..) = run_mi(p, n, 0x1A7);
            let lg = (p as f64).log2();
            ratios.push(m.critical().msgs as f64 / (lg * lg));
        }
        let max = ratios.iter().cloned().fold(0.0, f64::max);
        assert!(max <= 8.0, "latency/log^2P ratio grew: {ratios:?}");
        // And the ratio must not be exploding across the sweep.
        assert!(
            ratios.last().unwrap() / ratios.first().unwrap() < 3.0,
            "ratio not bounded: {ratios:?}"
        );
    }

    #[test]
    fn copsim_mi_bw_shape() {
        // BW·sqrt(P)/n must stay bounded by the paper's constant regime
        // (14 + polylog slack) as n and P scale — the Theorem 1
        // bandwidth-optimality shape.
        for &(p, n) in &[(4usize, 1024usize), (16, 4096), (64, 16384)] {
            let (m, ..) = run_mi(p, n, 0xB3);
            let ratio = m.critical().words as f64 * (p as f64).sqrt() / n as f64;
            assert!(ratio <= 18.0, "BW·sqrt(P)/n = {ratio:.2} at p={p} n={n}");
        }
    }

    #[test]
    fn copsim_mi_memory_within_thm11() {
        for &(p, n) in &[(4usize, 64usize), (16, 256), (64, 1024)] {
            // Run on a machine capped at the theorem's 12n/sqrt(P): the
            // allocation ledger must never overflow.
            let cap = theory::thm11_copsim_mi_mem(n as u64, p as u64);
            let mut rng = Rng::new(1);
            let mut m = Machine::new(p, cap, Base::new(16));
            let seq = Seq::range(p);
            let a = rng.digits(n, 16);
            let b = rng.digits(n, 16);
            let da = DistInt::scatter(&mut m, &seq, &a, n / p).unwrap();
            let db = DistInt::scatter(&mut m, &seq, &b, n / p).unwrap();
            let c = copsim_mi(&mut m, &seq, da, db, &leaf_ref(SlimLeaf))
                .unwrap_or_else(|e| panic!("p={p} n={n} cap={cap}: {e}"));
            let cd = c.gather(&m).unwrap();
            verify_product(&a, &b, &cd);
        }
    }

    #[test]
    fn copsim_main_mode_correct_under_memory_pressure() {
        // Force DFS: cap memory at 80n/P (Theorem 12's requirement),
        // well below the MI requirement 12n/sqrt(P).
        for &(p, n) in &[(64usize, 4096usize), (256, 4096)] {
            let cap = (80 * n / p) as u64;
            let mi_need = theory::thm11_copsim_mi_mem(n as u64, p as u64);
            assert!(cap < mi_need, "test must exercise the DFS path");
            let mut rng = Rng::new(0xDF5);
            let mut m = Machine::new(p, cap, Base::new(16));
            let seq = Seq::range(p);
            let a = rng.digits(n, 16);
            let b = rng.digits(n, 16);
            let da = DistInt::scatter(&mut m, &seq, &a, n / p).unwrap();
            let db = DistInt::scatter(&mut m, &seq, &b, n / p).unwrap();
            let c = copsim(&mut m, &seq, da, db, &leaf_ref(SchoolLeaf))
                .unwrap_or_else(|e| panic!("p={p} n={n} cap={cap}: {e}"));
            let cd = c.gather(&m).unwrap();
            verify_product(&a, &b, &cd);
            // Costs within Theorem 12.
            let crit = m.critical();
            let bound = theory::thm12_copsim(n as u64, p as u64, cap);
            assert!(crit.ops <= bound.ops, "T: {} > {}", crit.ops, bound.ops);
            assert!(crit.words <= bound.words, "BW: {} > {}", crit.words, bound.words);
            assert!(crit.msgs <= bound.msgs, "L: {} > {}", crit.msgs, bound.msgs);
            // Theorem 12 memory: peak within the cap is enforced by the
            // ledger itself (alloc would have failed); double-check.
            assert!(m.mem_peak_max() <= cap);
        }
    }

    #[test]
    fn copsim_randomized_vs_reference() {
        crate::util::prop::check("copsim-vs-ref", 25, |rng| {
            let p = [1usize, 4, 16][rng.below(3) as usize];
            let w = 1usize << rng.range(0, 3);
            let n = (p * w).max(p) * 4; // keep n >= 4p and power of two
            let n = n.next_power_of_two();
            let a = rng.digits(n, 16);
            let b = rng.digits(n, 16);
            let mut m = Machine::unbounded(p, Base::new(16));
            let seq = Seq::range(p);
            let da = DistInt::scatter(&mut m, &seq, &a, n / p).unwrap();
            let db = DistInt::scatter(&mut m, &seq, &b, n / p).unwrap();
            let c = copsim_mi(&mut m, &seq, da, db, &leaf_ref(SlimLeaf)).unwrap();
            let mut ops = Ops::default();
            let want = mul::mul_school(&a, &b, Base::new(16), &mut ops);
            crate::prop_assert_eq!(c.gather(&m).unwrap(), want);
            // All intermediates freed: only the product remains.
            crate::prop_assert_eq!(m.mem_used_total(), 2 * n as u64);
            Ok(())
        });
    }
}

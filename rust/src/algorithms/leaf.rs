//! Pluggable sequential leaf multipliers.
//!
//! Once COPSIM/COPK assign a subproblem to a single processor it is
//! solved locally "using the sequential algorithm SLIM [SKIM]. Clearly,
//! any sequential algorithm can be used in place of it" (§5/§6). This
//! trait is that plug-in point; besides the paper's SLIM/SKIM the
//! coordinator installs an XLA-backed leaf (`runtime::XlaLeaf`) that
//! executes the AOT-compiled JAX+Pallas digit-convolution kernel.

use crate::bignum::{mul, Base, Ops};
use std::sync::Arc;

/// Shared handle to a leaf multiplier. The algorithms take this (rather
/// than `&dyn LeafMultiplier`) because the threaded execution engine
/// ships leaf products to per-processor worker threads, which requires
/// an owned, thread-safe handle inside the shipped closure.
pub type LeafRef = Arc<dyn LeafMultiplier + Send + Sync>;

/// Wrap a concrete leaf into a [`LeafRef`].
pub fn leaf_ref(l: impl LeafMultiplier + 'static) -> LeafRef {
    Arc::new(l)
}

/// A sequential multiplier for equal-width power-of-two operands.
pub trait LeafMultiplier: Send + Sync {
    /// Human-readable name for reports.
    fn name(&self) -> &'static str;

    /// Multiply `a·b` (both `w` digits), returning `2w` digits and
    /// charging digit operations to `ops`.
    fn mul(&self, a: &[u32], b: &[u32], base: Base, ops: &mut Ops) -> Vec<u32>;

    /// Transient working space beyond inputs and output, in words.
    /// Facts 10/13 allot `8n` words to SLIM/SKIM; inputs (2n) and output
    /// (2n) are ledgered by the caller, so the default scratch is `4n`.
    fn scratch_words(&self, w: usize) -> usize {
        4 * w
    }
}

/// The paper's recursive long multiplication (Fact 10: ≤ 8n² ops).
pub struct SlimLeaf;

impl LeafMultiplier for SlimLeaf {
    fn name(&self) -> &'static str {
        "slim"
    }
    fn mul(&self, a: &[u32], b: &[u32], base: Base, ops: &mut Ops) -> Vec<u32> {
        mul::slim(a, b, base, ops)
    }
}

/// The paper's sequential Karatsuba (Fact 13: ≤ 16·n^lg3 ops).
pub struct SkimLeaf;

impl LeafMultiplier for SkimLeaf {
    fn name(&self) -> &'static str {
        "skim"
    }
    fn mul(&self, a: &[u32], b: &[u32], base: Base, ops: &mut Ops) -> Vec<u32> {
        mul::skim(a, b, base, ops)
    }
}

/// Iterative schoolbook (operand scanning): same O(n²) op count as SLIM
/// with a smaller constant. Runs on the active rung of the kernel
/// ladder for wide operands (`bignum::arch` — u128 or SIMD column
/// accumulation, dispatched once per process), which makes it the
/// fastest pure-Rust leaf below the Karatsuba crossover. Scratch is
/// leaf-width-independent, so leaf choice never moves the M ledger.
pub struct SchoolLeaf;

impl LeafMultiplier for SchoolLeaf {
    fn name(&self) -> &'static str {
        "school"
    }
    fn mul(&self, a: &[u32], b: &[u32], base: Base, ops: &mut Ops) -> Vec<u32> {
        mul::mul_school(a, b, base, ops)
    }
    fn scratch_words(&self, _w: usize) -> usize {
        0
    }
}

/// §7-style sequential hybrid: Karatsuba above the threshold, schoolbook
/// below (the classical crossover).
pub struct HybridLeaf {
    pub threshold: usize,
}

impl LeafMultiplier for HybridLeaf {
    fn name(&self) -> &'static str {
        "hybrid"
    }
    fn mul(&self, a: &[u32], b: &[u32], base: Base, ops: &mut Ops) -> Vec<u32> {
        mul::mul_hybrid(a, b, self.threshold, base, ops)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn all_leaves_agree() {
        let base = Base::new(16);
        let mut rng = Rng::new(0x1EAF);
        let leaves: Vec<Box<dyn LeafMultiplier>> = vec![
            Box::new(SlimLeaf),
            Box::new(SkimLeaf),
            Box::new(SchoolLeaf),
            Box::new(HybridLeaf { threshold: 16 }),
        ];
        for &w in &[8usize, 32, 64] {
            let a = rng.digits(w, 16);
            let b = rng.digits(w, 16);
            let mut want: Option<Vec<u32>> = None;
            for leaf in &leaves {
                let mut ops = Ops::default();
                let got = leaf.mul(&a, &b, base, &mut ops);
                assert!(ops.get() > 0, "{} charged no ops", leaf.name());
                match &want {
                    None => want = Some(got),
                    Some(w0) => assert_eq!(&got, w0, "{} diverges", leaf.name()),
                }
            }
        }
    }
}

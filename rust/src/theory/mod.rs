//! Closed-form bounds from the paper, used by tests and the experiment
//! harness to build `paper bound | measured | ratio` tables.
//!
//! Upper bounds (what the algorithms must stay under):
//! Lemmas 7–9 (primitives), Theorem 11 (COPSIM_MI), Theorem 12 (COPSIM),
//! Theorem 14 (COPK_MI), Theorem 15 (COPK), Facts 10/13 (SLIM/SKIM).
//!
//! Lower bounds (what no algorithm can beat; Theorems 3–6): used to form
//! the optimality *ratios* of Theorems 1 and 2. These are Ω-bounds; the
//! functions return the bound expression with constant 1, so the
//! measured/lower ratio being bounded by a constant over sweeps is the
//! reproduction of "asymptotically optimal".

use crate::algorithms::{Algorithm, ExecMode};
use crate::sim::topology::Topology;
use crate::sim::Clock;
use crate::util::{div_ceil, exact_log2, pow_log2_3, pow_log3_2};

const LOG2_3: f64 = 1.584962500721156; // log2(3)

#[inline]
fn lg(p: u64) -> f64 {
    if p <= 1 {
        0.0
    } else {
        (p as f64).log2()
    }
}

#[inline]
fn ceil_u64(x: f64) -> u64 {
    if x <= 0.0 {
        0
    } else {
        x.ceil() as u64
    }
}

fn clock(ops: f64, words: f64, msgs: f64) -> Clock {
    Clock {
        ops: ceil_u64(ops),
        words: ceil_u64(words),
        msgs: ceil_u64(msgs),
    }
}

// ---------------------------------------------------------------- upper

/// Lemma 7 — parallel SUM: `T ≤ 6n/P + 4log₂P`, `BW ≤ 4log₂P`,
/// `L ≤ 2log₂P`.
pub fn lemma7_sum(n: u64, p: u64) -> Clock {
    let (n, l) = (n as f64, lg(p));
    clock(6.0 * n / p as f64 + 4.0 * l, 4.0 * l, 2.0 * l)
}

/// Lemma 7 — SUM memory requirement per processor: `4(n/P + 1)`.
pub fn lemma7_sum_mem(n: u64, p: u64) -> u64 {
    4 * (n / p + 1)
}

/// Lemma 8 — parallel COMPARE: `T ≤ n/P + log₂P`, `BW, L ≤ log₂P`.
pub fn lemma8_compare(n: u64, p: u64) -> Clock {
    let (n, l) = (n as f64, lg(p));
    clock(n / p as f64 + l, l, l)
}

/// Lemma 9 — parallel DIFF: `T ≤ 7n/P + 5log₂P`, `BW ≤ 5log₂P`,
/// `L ≤ 3log₂P`.
pub fn lemma9_diff(n: u64, p: u64) -> Clock {
    let (n, l) = (n as f64, lg(p));
    clock(7.0 * n / p as f64 + 5.0 * l, 5.0 * l, 3.0 * l)
}

/// Fact 10 — SLIM sequential op bound `8n²` (space `8n`).
pub fn fact10_slim_ops(n: u64) -> u64 {
    8 * n * n
}

/// Fact 13 — SKIM sequential op bound `16·n^(log₂3)` (space `8n`).
pub fn fact13_skim_ops(n: u64) -> u64 {
    ceil_u64(16.0 * pow_log2_3(n as f64))
}

/// Theorem 11 — COPSIM in the MI execution mode:
/// `T ≤ 38n²/P + 3log₂²P`, `BW ≤ 14n/√P + 6log₂²P`, `L ≤ 3log₂²P`.
pub fn thm11_copsim_mi(n: u64, p: u64) -> Clock {
    let (nf, pf, l) = (n as f64, p as f64, lg(p));
    clock(
        38.0 * nf * nf / pf + 3.0 * l * l,
        14.0 * nf / pf.sqrt() + 6.0 * l * l,
        3.0 * l * l,
    )
}

/// Theorem 11 — COPSIM_MI memory requirement per processor: `12n/√P`.
pub fn thm11_copsim_mi_mem(n: u64, p: u64) -> u64 {
    ceil_u64(12.0 * n as f64 / (p as f64).sqrt()).max(8 * n / p)
}

/// Theorem 12 — COPSIM (main / limited-memory mode):
/// `T ≤ 196n²/P`, `BW ≤ 3530n²/(MP)`, `L ≤ 7012·n²log₂²P/(M²P)`.
pub fn thm12_copsim(n: u64, p: u64, m: u64) -> Clock {
    let (nf, pf, mf, l) = (n as f64, p as f64, m as f64, lg(p));
    clock(
        196.0 * nf * nf / pf,
        3530.0 * nf * nf / (mf * pf),
        7012.0 * nf * nf * l * l / (mf * mf * pf),
    )
}

/// Theorem 14 — COPK in the MI execution mode:
/// `T ≤ 173·n^lg3/P`, `BW ≤ 174·n/P^(log₃2)`, `L ≤ 25log₂²P`.
pub fn thm14_copk_mi(n: u64, p: u64) -> Clock {
    let (nf, pf, l) = (n as f64, p as f64, lg(p));
    clock(
        173.0 * pow_log2_3(nf) / pf,
        174.0 * nf / pow_log3_2(pf),
        25.0 * l * l,
    )
}

/// Theorem 14 — COPK_MI memory requirement per processor:
/// `10n/P^(log₃2)`.
pub fn thm14_copk_mi_mem(n: u64, p: u64) -> u64 {
    ceil_u64(10.0 * n as f64 / pow_log3_2(p as f64)).max(8 * n / p)
}

/// Theorem 15 — COPK (main / limited-memory mode):
/// `T ≤ 675·n^lg3/P`, `BW ≤ 1708·(n/M)^lg3·M/P`,
/// `L ≤ 8728·n^lg3·log₂²P/(P·M^lg3)`.
pub fn thm15_copk(n: u64, p: u64, m: u64) -> Clock {
    let (nf, pf, mf, l) = (n as f64, p as f64, m as f64, lg(p));
    clock(
        675.0 * pow_log2_3(nf) / pf,
        1708.0 * pow_log2_3(nf / mf) * mf / pf,
        8728.0 * pow_log2_3(nf) * l * l / (pf * pow_log2_3(mf)),
    )
}

// ------------------------------------------------- execution modes (BFS)
//
// The memory-adaptive BFS variants (algorithms::exec; arXiv 1202.3177's
// memory-independent lower bounds and CAPS' BFS/DFS interleaving,
// 1202.3173) trade surplus per-processor memory for bandwidth. T and L
// keep the paper's constants in every mode — the variants only remove
// charged communication rounds, never local work — so each BFS bound
// below is its DFS twin with a strictly smaller BW term and a larger
// memory requirement.

/// COPSIM_MI under the fused operand distribution (BFS, roomy regime):
/// the per-level maximum sender charge drops from `4w` (a
/// repartition-then-replicate pair) to `3w` (two direct copies from the
/// original half layout), so the geometric level sum `4n/√P` becomes
/// `3n/√P`: `BW ≤ 13n/√P + 6log₂²P`; T and L as Theorem 11.
pub fn copsim_bfs_mi(n: u64, p: u64) -> Clock {
    let (nf, pf, l) = (n as f64, p as f64, lg(p));
    clock(
        38.0 * nf * nf / pf + 3.0 * l * l,
        13.0 * nf / pf.sqrt() + 6.0 * l * l,
        3.0 * l * l,
    )
}

/// Memory requirement of the fused MI mode: both operand copies of a
/// level coexist with their source, doubling the Theorem 11 footprint.
/// (`n ≤ M√P/24`, the per-level gate in `copsim_mi_fused`.)
pub fn copsim_bfs_mi_mem(n: u64, p: u64) -> u64 {
    2 * thm11_copsim_mi_mem(n, p)
}

/// COPSIM stepping regime with clone-elided DFS steps (BFS): each
/// step's 8 charged operand copies become 4 charged copies plus 4 free
/// same-layout clones, saving at least `n/P` charged words on every
/// processor at the top step alone: `BW ≤ 3530n²/(MP) − n/P`;
/// T and L as Theorem 12.
pub fn copsim_bfs_step(n: u64, p: u64, m: u64) -> Clock {
    let c = thm12_copsim(n, p, m);
    Clock {
        words: c.words.saturating_sub(n / p),
        ..c
    }
}

/// Per-processor memory requirement of clone-elided COPSIM steps:
/// Theorem 12's `80n/P` plus the live clones, bounded by `96n/P`.
pub fn copsim_bfs_step_mem(n: u64, p: u64) -> u64 {
    div_ceil(96 * n, p)
}

/// COPK stepping regime with clone-elided DFS steps (BFS): the step's
/// 8 charged copies (C0, C2, and four DIFF operands) become 4, saving
/// at least `n/P` charged words per processor: `BW ≤ Thm 15 − n/P`.
/// The COPK MI regime is mode-invariant (its splits move every digit
/// once; DESIGN.md decision 15), so there is no roomy COPK entry here.
pub fn copk_bfs_step(n: u64, p: u64, m: u64) -> Clock {
    let c = thm15_copk(n, p, m);
    Clock {
        words: c.words.saturating_sub(n / p),
        ..c
    }
}

/// Per-processor memory requirement of clone-elided COPK steps:
/// Theorem 15's `40n/P` plus the live clones, bounded by `48n/P`.
pub fn copk_bfs_step_mem(n: u64, p: u64) -> u64 {
    div_ceil(48 * n, p)
}

/// Number of depth-first steps `algo` takes on `(n, P)` before the MI
/// condition holds with per-processor memory `mem` (0 = starts in the
/// MI regime). Mirrors the `mi_ok` gates in `copsim`/`copk` exactly.
pub fn dfs_steps(algo: Algorithm, n: u64, p: u64, mem: u64) -> u32 {
    if p <= 1 {
        return 0;
    }
    let thresh = match algo {
        Algorithm::Copsim => mem as f64 * (p as f64).sqrt() / 12.0,
        Algorithm::Copk => mem as f64 * pow_log3_2(p as f64) / 10.0,
    };
    let mut nf = n as f64;
    let mut k = 0;
    while nf > thresh && k < 64 {
        nf /= 2.0;
        k += 1;
    }
    k
}

/// Maximum number of BFS levels `algo` can afford on `(n, P)` with
/// per-processor memory `mem` — 0 when BFS buys nothing (COPK's MI
/// regime) or the BFS footprint does not fit.
pub fn bfs_levels(algo: Algorithm, n: u64, p: u64, mem: u64) -> u32 {
    if p <= 1 {
        return 0;
    }
    let steps = dfs_steps(algo, n, p, mem);
    match algo {
        Algorithm::Copsim => {
            if steps == 0 {
                // MI regime: the fused gate n <= M*sqrt(P)/24 is
                // level-invariant, so either every level fuses or none.
                if mem >= copsim_bfs_mi_mem(n, p) {
                    exact_log2(p) / 2 // log4 P split levels
                } else {
                    0
                }
            } else if mem >= copsim_bfs_step_mem(n, p) {
                steps
            } else {
                0
            }
        }
        Algorithm::Copk => {
            if steps > 0 && mem >= copk_bfs_step_mem(n, p) {
                steps
            } else {
                0
            }
        }
    }
}

/// The cheapest fitting execution mode: BFS wherever it strictly
/// lowers the predicted BW and its footprint fits `mem`, DFS otherwise.
pub fn best_mode(algo: Algorithm, n: u64, p: u64, mem: u64) -> ExecMode {
    match bfs_levels(algo, n, p, mem) {
        0 => ExecMode::Dfs,
        levels => ExecMode::Bfs { levels },
    }
}

/// Predicted `(T, BW, L)` bound and per-processor memory requirement
/// of running `algo` on `(n, P)` with memory `mem` under `mode`.
/// `Bfs { levels: 0 }` is DFS (the scheduler's downgrade invariant).
pub fn exec_mode_bounds(algo: Algorithm, n: u64, p: u64, mem: u64, mode: ExecMode) -> (Clock, u64) {
    let bfs = matches!(mode, ExecMode::Bfs { levels } if levels > 0);
    let stepping = dfs_steps(algo, n, p, mem) > 0;
    match algo {
        Algorithm::Copsim => match (stepping, bfs) {
            (false, false) => (thm11_copsim_mi(n, p), thm11_copsim_mi_mem(n, p)),
            (false, true) => (copsim_bfs_mi(n, p), copsim_bfs_mi_mem(n, p)),
            (true, false) => (thm12_copsim(n, p, mem), div_ceil(80 * n, p)),
            (true, true) => (copsim_bfs_step(n, p, mem), copsim_bfs_step_mem(n, p)),
        },
        Algorithm::Copk => match (stepping, bfs) {
            (false, _) => (thm14_copk_mi(n, p), thm14_copk_mi_mem(n, p)),
            (true, false) => (thm15_copk(n, p, mem), div_ceil(40 * n, p)),
            (true, true) => (copk_bfs_step(n, p, mem), copk_bfs_step_mem(n, p)),
        },
    }
}

// ---------------------------------------------------------------- lower

/// Theorem 3 — memory-dependent lower bounds for *standard* integer
/// multiplication (constant-1 Ω expressions):
/// `BW = Ω(n²/(MP))`, `L = Ω(n²/(M²P))`.
pub fn thm3_lower_standard(n: u64, p: u64, m: u64) -> (f64, f64) {
    let (nf, pf, mf) = (n as f64, p as f64, m as f64);
    (nf * nf / (mf * pf), nf * nf / (mf * mf * pf))
}

/// Theorem 4 — memory-independent lower bound for standard multiplication
/// with balanced input: `BW = Ω(n/(B_m·√P))` with `B_m = 1` word here
/// (the simulator counts words, so the bandwidth bound is `n/√P`).
pub fn thm4_lower_standard_mi(n: u64, p: u64) -> f64 {
    n as f64 / (p as f64).sqrt()
}

/// Theorem 5 — memory-dependent lower bounds for Karatsuba-strategy
/// algorithms: `BW = Ω((n/M)^lg3·M/P)`, `L = Ω((n/M)^lg3/P)`.
pub fn thm5_lower_karatsuba(n: u64, p: u64, m: u64) -> (f64, f64) {
    let (nf, pf, mf) = (n as f64, p as f64, m as f64);
    let r = pow_log2_3(nf / mf);
    (r * mf / pf, r / pf)
}

/// Theorem 6 — memory-independent lower bound for Karatsuba with
/// balanced input: `BW = Ω(n/P^(1/log₂3))`.
pub fn thm6_lower_karatsuba_mi(n: u64, p: u64) -> f64 {
    n as f64 / (p as f64).powf(1.0 / LOG2_3)
}

// ------------------------------------------------------------ topology

/// Per-topology inflation factors `(bw, lat)` applied to the paper's
/// fully-connected bounds: a logical message crosses at most
/// `diameter` physical links, each word charged at most
/// `max_link_bw_weight` per link, so `BW_topo ≤ bw · BW_fc` and
/// `L_topo ≤ lat · L_fc` along any dependency chain. (Link congestion
/// at shared relays can push a *measured* critical path above the
/// chain bound; E18 reports measured/predicted so that slack is
/// visible, and `tests/theorem_properties.rs` asserts the latency
/// stays in the `O(log²P)` class per topology.)
pub fn topology_inflation(topo: &dyn Topology) -> (u64, u64) {
    let d = topo.diameter().max(1);
    (d * topo.max_link_bw_weight().max(1), d)
}

/// A fully-connected cost bound re-predicted for a topology: compute
/// is unchanged, bandwidth and latency scale by
/// [`topology_inflation`]'s factors.
pub fn predicted_for_topology(fc_bound: Clock, topo: &dyn Topology) -> Clock {
    let (bw, lat) = topology_inflation(topo);
    Clock {
        ops: fc_bound.ops,
        words: fc_bound.words.saturating_mul(bw),
        msgs: fc_bound.msgs.saturating_mul(lat),
    }
}

/// §2.2 execution-time model: `α·T + β·L + γ·BW`.
/// Defaults model a commodity cluster: 1 ns/digit-op, 1 µs message
/// latency, 10 ns/word.
#[derive(Clone, Copy, Debug)]
pub struct TimeModel {
    pub alpha_ns: f64,
    pub beta_ns: f64,
    pub gamma_ns: f64,
}

impl Default for TimeModel {
    fn default() -> Self {
        TimeModel {
            alpha_ns: 1.0,
            beta_ns: 1000.0,
            gamma_ns: 10.0,
        }
    }
}

impl TimeModel {
    /// Modeled execution time in nanoseconds for a measured cost triple.
    pub fn time_ns(&self, c: &Clock) -> f64 {
        self.alpha_ns * c.ops as f64 + self.beta_ns * c.msgs as f64 + self.gamma_ns * c.words as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounds_positive_and_monotone_in_n() {
        let a = thm11_copsim_mi(1 << 10, 16);
        let b = thm11_copsim_mi(1 << 12, 16);
        assert!(b.ops > a.ops && b.words > a.words);
        let a = thm14_copk_mi(1 << 10, 12);
        let b = thm14_copk_mi(1 << 12, 12);
        assert!(b.ops > a.ops && b.words > a.words);
    }

    #[test]
    fn single_processor_degenerates() {
        // With P = 1 all log terms vanish; SUM bound is the local cost.
        let c = lemma7_sum(64, 1);
        assert_eq!(c.words, 0);
        assert_eq!(c.msgs, 0);
        assert_eq!(c.ops, 6 * 64);
    }

    #[test]
    fn lower_bounds_scale() {
        let (bw1, l1) = thm3_lower_standard(1 << 12, 16, 256);
        let (bw2, l2) = thm3_lower_standard(1 << 13, 16, 256);
        assert!((bw2 / bw1 - 4.0).abs() < 1e-9);
        assert!((l2 / l1 - 4.0).abs() < 1e-9);
        // Karatsuba lower bound grows as n^lg3.
        let (k1, _) = thm5_lower_karatsuba(1 << 12, 16, 256);
        let (k2, _) = thm5_lower_karatsuba(1 << 13, 16, 256);
        assert!((k2 / k1 - 3.0).abs() < 1e-6);
    }

    #[test]
    fn facts_match_formulae() {
        assert_eq!(fact10_slim_ops(100), 80_000);
        let k = fact13_skim_ops(64);
        // 16 * 64^lg3 = 16 * 3^6 = 11664
        assert_eq!(k, 11_664);
    }

    #[test]
    fn topology_predictions_scale_bw_and_lat_only() {
        use crate::sim::topology::TopologyKind;
        let fc = thm11_copsim_mi(1 << 10, 16);
        // Fully connected: identity.
        let t = TopologyKind::FullyConnected.build(16);
        assert_eq!(predicted_for_topology(fc, t.as_ref()), fc);
        // 4x4 torus: diameter 4, unit links.
        let t = TopologyKind::Torus.build(16);
        assert_eq!(topology_inflation(t.as_ref()), (4, 4));
        let p = predicted_for_topology(fc, t.as_ref());
        assert_eq!(p.ops, fc.ops);
        assert_eq!(p.words, fc.words * 4);
        assert_eq!(p.msgs, fc.msgs * 4);
        // Hierarchical: 3 hops worst case, backbone weight 2.
        let t = TopologyKind::Hier.build(16);
        assert_eq!(topology_inflation(t.as_ref()), (6, 3));
    }

    #[test]
    fn time_model_combines() {
        let tm = TimeModel::default();
        let c = Clock { ops: 1000, words: 10, msgs: 2 };
        assert!((tm.time_ns(&c) - (1000.0 + 2000.0 + 100.0)).abs() < 1e-9);
    }

    #[test]
    fn dfs_steps_mirror_the_mi_gates() {
        // Roomy: starts in the MI regime.
        assert_eq!(dfs_steps(Algorithm::Copsim, 1024, 16, 1 << 20), 0);
        assert_eq!(dfs_steps(Algorithm::Copk, 5184, 108, 1 << 20), 0);
        // The test cells used across the suite.
        assert_eq!(dfs_steps(Algorithm::Copsim, 4096, 256, 2048), 1);
        assert_eq!(dfs_steps(Algorithm::Copsim, 4096, 256, 80 * 4096 / 256), 2);
        assert_eq!(dfs_steps(Algorithm::Copk, 5184, 108, 2304), 1);
        // (108, 10368) at 40n/P: one step reaches n' = 5184 <= M*P^(log3 2)/10.
        assert_eq!(dfs_steps(Algorithm::Copk, 10368, 108, 40 * 10368 / 108), 1);
    }

    #[test]
    fn best_mode_picks_bfs_only_when_it_pays() {
        // COPSIM roomy at 2x the MI footprint: full-depth fused BFS.
        let mi = thm11_copsim_mi_mem(1024, 16);
        assert_eq!(
            best_mode(Algorithm::Copsim, 1024, 16, 2 * mi),
            ExecMode::Bfs { levels: 2 }
        );
        // At exactly the MI footprint the fused copies don't fit: DFS.
        assert_eq!(best_mode(Algorithm::Copsim, 1024, 16, mi), ExecMode::Dfs);
        // Stepping with clone headroom: elide the steps.
        assert_eq!(
            best_mode(Algorithm::Copsim, 4096, 256, 2048),
            ExecMode::Bfs { levels: 1 }
        );
        // Stepping at Theorem 12's bare 80n/P: no clone headroom, DFS.
        assert_eq!(best_mode(Algorithm::Copsim, 4096, 256, 80 * 4096 / 256), ExecMode::Dfs);
        // COPK MI regime is mode-invariant: never claim a BFS win.
        assert_eq!(best_mode(Algorithm::Copk, 5184, 108, 1 << 20), ExecMode::Dfs);
        // COPK stepping with clone headroom.
        assert_eq!(
            best_mode(Algorithm::Copk, 5184, 108, copk_bfs_step_mem(5184, 108)),
            ExecMode::Bfs { levels: 1 }
        );
        assert_eq!(best_mode(Algorithm::Copk, 5184, 108, 40 * 5184 / 108), ExecMode::Dfs);
        // Single processor: nothing to communicate.
        assert_eq!(best_mode(Algorithm::Copsim, 1024, 1, 1 << 30), ExecMode::Dfs);
    }

    #[test]
    fn bfs_bounds_cut_bw_at_equal_t_and_cost_memory() {
        // Roomy COPSIM: BW strictly lower, T/L identical, M doubled.
        let dfs = thm11_copsim_mi(1 << 12, 64);
        let bfs = copsim_bfs_mi(1 << 12, 64);
        assert_eq!(bfs.ops, dfs.ops);
        assert_eq!(bfs.msgs, dfs.msgs);
        assert!(bfs.words < dfs.words);
        assert_eq!(copsim_bfs_mi_mem(1 << 12, 64), 2 * thm11_copsim_mi_mem(1 << 12, 64));
        // Stepping COPSIM and COPK: same shape.
        let (n, p, m) = (4096u64, 256u64, 2048u64);
        let dfs = thm12_copsim(n, p, m);
        let bfs = copsim_bfs_step(n, p, m);
        assert_eq!(bfs.ops, dfs.ops);
        assert_eq!(bfs.msgs, dfs.msgs);
        assert!(bfs.words < dfs.words);
        assert!(copsim_bfs_step_mem(n, p) > div_ceil(80 * n, p));
        let (n, p, m) = (5184u64, 108u64, 2304u64);
        let dfs = thm15_copk(n, p, m);
        let bfs = copk_bfs_step(n, p, m);
        assert_eq!(bfs.ops, dfs.ops);
        assert_eq!(bfs.msgs, dfs.msgs);
        assert!(bfs.words < dfs.words);
        assert!(copk_bfs_step_mem(n, p) > div_ceil(40 * n, p));
    }

    #[test]
    fn exec_mode_bounds_consistent_with_selectors() {
        // Bfs{0} is DFS in the bound table too.
        let (n, p, mem) = (4096u64, 256u64, 2048u64);
        let (d, dm) = exec_mode_bounds(Algorithm::Copsim, n, p, mem, ExecMode::Dfs);
        let (z, zm) = exec_mode_bounds(Algorithm::Copsim, n, p, mem, ExecMode::Bfs { levels: 0 });
        assert_eq!((d, dm), (z, zm));
        // best_mode's pick always fits the memory it was given.
        for &(algo, n, p, mem) in &[
            (Algorithm::Copsim, 1024u64, 16u64, 6144u64),
            (Algorithm::Copsim, 4096, 256, 2048),
            (Algorithm::Copk, 5184, 108, 2304),
            (Algorithm::Copk, 5184, 108, 1 << 20),
        ] {
            let mode = best_mode(algo, n, p, mem);
            let (_, need) = exec_mode_bounds(algo, n, p, mem, mode);
            assert!(need <= mem, "{algo:?} {mode}: footprint {need} > mem {mem}");
        }
    }
}

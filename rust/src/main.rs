//! `copmul` — CLI for the COPSIM/COPK reproduction.
//!
//! Subcommands (hand-rolled parsing; clap is not vendored offline):
//!
//! ```text
//! copmul mul <a_hex> <b_hex> [key=value ...]   multiply two hex integers
//! copmul experiment <id|all> [--csv]           run paper experiments E1-E21
//! copmul serve [key=value ...]                 fixed-batch coordinator workload
//! copmul daemon [--rate=R ...]                 always-on serving, open-loop load
//! copmul bench [--json] [--smoke]              wall-clock bench -> BENCH_10.json
//! copmul info [artifacts=DIR]                  runtime + artifact info
//! copmul selftest                              quick end-to-end check
//! ```
//!
//! Common `key=value` options: `n`, `procs`, `mem`, `algo`
//! (copsim|copk|hybrid), `leaf` (slim|skim|school|hybrid|xla|xla-batched),
//! `engine` (sim|threads|sockets; also spelled `--engine=...`), `topology`
//! (fully-connected|torus|hier; also `--topology=...`), `exec-mode`
//! (dfs|auto|bfs; also `--exec-mode=...`), `seed`, `workers`,
//! `artifacts`, `alpha_ns`, `beta_ns`, `gamma_ns`.
//! `serve` additionally takes `--jobs=N` (request count), `--shards=K`
//! (run the sharded scheduler: ONE shared machine of `procs` processors
//! carved into up to `K` concurrent shards, instead of one dedicated
//! machine per job) and `--fault-rate=R`/`--fault-seed=S` (sharded
//! only: deterministic fault injection with scheduler recovery).

use copmul::algorithms::leaf::{HybridLeaf, LeafMultiplier, SchoolLeaf, SkimLeaf, SlimLeaf};
use copmul::bignum::convert::{parse_hex, to_hex};
use copmul::config::{LeafKind, RunConfig};
use copmul::coordinator::{
    run_open_loop, ArrivalGen, BatchingXlaLeaf, Coordinator, CoordinatorConfig, Daemon,
    DaemonConfig, JobSpec, OpenLoop, Scheduler, SchedulerConfig, Workload,
};
use copmul::error::{bail, Context, Error, Result};
use copmul::experiments;
use copmul::metrics::fmt_u64;
use copmul::runtime::{XlaLeaf, XlaRuntime};
use copmul::sim::{FaultConfig, SocketConfig};
use copmul::util::Rng;
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // Worker-process entry for the socket engine: a host SocketMachine
    // spawns `copmul --socket-worker` with its wiring in the
    // environment (COPMUL_SOCKET_HOST/GROUP/DIR), so this dispatches
    // before any normal CLI parsing and never prints the help text.
    if args.first().map(String::as_str) == Some("--socket-worker") {
        if let Err(e) = copmul::sim::socket_worker_main() {
            eprintln!("socket worker: {e:#}");
            std::process::exit(1);
        }
        return;
    }
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("mul") => cmd_mul(&args[1..]),
        Some("experiment") => cmd_experiment(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("daemon") => cmd_daemon(&args[1..]),
        Some("bench") => cmd_bench(&args[1..]),
        Some("info") => cmd_info(&args[1..]),
        Some("selftest") => cmd_selftest(),
        Some("help") | None => {
            print!("{}", HELP);
            Ok(())
        }
        Some(other) => bail!("unknown subcommand `{other}` (try `copmul help`)"),
    }
}

const HELP: &str = "\
copmul — communication-optimal parallel integer multiplication (COPSIM/COPK)

USAGE:
  copmul mul <a_hex> <b_hex> [key=value ...]
  copmul experiment <E1..E21|all> [--csv] [key=value ...]
  copmul serve [--jobs=N] [--shards=K] [--fault-rate=R] [--daemon] [key=value ...]
  copmul daemon [--jobs=N] [--rate=R] [--arrival=A] [--deadline-ms=D] [key=value ...]
  copmul bench [--json] [--out=PATH] [--smoke] [seed=N]
  copmul info [artifacts=DIR]
  copmul selftest

KEYS: n procs mem algo(copsim|copk|hybrid) leaf(slim|skim|school|hybrid|xla|xla-batched)
      --engine=(sim|threads|sockets) --topology=(fully-connected|torus|hier)
      --exec-mode=(dfs|auto|bfs) seed workers artifacts alpha_ns beta_ns gamma_ns

EXEC MODES: dfs = the paper-default schedule (DFS steps, then the MI
            recursion; bit-identical to pre-mode builds); auto = spend
            surplus per-processor memory on breadth-first variants when
            the predicted bandwidth is strictly lower (E20); bfs =
            demand BFS — rejected distinctly when no level fits memory.

ENGINES: sim = deterministic cost-model simulator (critical-path clocks);
         threads = one OS thread per simulated processor (wall-clock speedup);
         sockets = one OS worker process per group of simulated processors,
         commands and messages over Unix-domain sockets (COPMUL_SOCKET_TCP=1
         for TCP loopback; COPMUL_SOCKET_GROUPS sets the process count;
         COPMUL_SOCKET_TIMEOUT_MS bounds each reply wait, default 30000;
         COPMUL_SOCKET_HEARTBEAT_MS turns on host-side liveness probing).
         The internal `copmul --socket-worker` entry is exec'd by the host.

TOPOLOGIES: fully-connected (the paper's implicit network; default),
            torus (2D wraparound grid, hop-by-hop routing and charging),
            hier (two-level clusters over a half-bandwidth backbone).

BENCH:   wall-clock harness (engine grid, kernel-ladder table, per-base
         leaf-width sweep, open-loop serving curve, strong-scaling sweep,
         self-healing rolling-kill soak).
         --json writes the BENCH_10.json artifact (--out overrides the
         path); --smoke runs the CI-sized grid.
         COPMUL_KERNEL=(reference|packed64|generic|simd) pins the
         dispatched rung. Cost triples shown are layout-invariant;
         wall-clock is the quantity the perf PRs move.

SERVE:   fixed batch, closed-loop (submits everything, waits for all).
         --jobs=N   number of requests (default 64)
         --shards=K sharded scheduler: one shared `procs`-processor machine,
                    up to K jobs running concurrently on disjoint shards
                    (omit for the classic one-machine-per-job coordinator)
         --fault-rate=R --fault-seed=S (sharded only) deterministic fault
                    injection: each eligible machine operation faults with
                    probability R from seed S (default 0 / 42); failed jobs
                    are retried with shard-size backoff and the run reports
                    injected faults, retries and quarantined processors
         --socket-timeout-ms=T (sharded only; sockets engine) bound on any
                    single socket reply wait (default 30000; must be > 0;
                    COPMUL_SOCKET_TIMEOUT_MS sets the same knob)
         --daemon   forward to `copmul daemon` (open-loop serving)

DAEMON:  always-on serving under seeded open-loop load: arrivals follow
         the generator's schedule and never wait for completions; per-job
         deadlines + SLO-aware early shedding bound latency instead of
         the queue growing forever. Reports p50/p99/p999 + jobs/s, shed
         and retry counts. Always sharded (one shared machine).
         --jobs=N        arrivals to offer (default 256); soak example:
                         copmul daemon --jobs=1000000 --rate=20000
                         --deadline-ms=250 n=256
         --rate=R        offered arrival rate, jobs/s (default 800)
         --arrival=A     poisson | bursty (default poisson)
         --burst=N       bursty: arrivals per on-phase (default 32)
         --idle-ms=D     bursty: off-phase gap between bursts (default 50)
         --deadline-ms=D per-job deadline; 0 = none (default 100)
         --max-shed=F    fail the run if > F of offered jobs are shed
         --verify        bignum-verify every completed product
         --shards=K      concurrent shards of the shared machine (default 4)
         --queue=N       admission bound, queued+running (default 1024)
         --fault-rate=R --fault-seed=S   as in serve
         --socket-timeout-ms=T           as in serve
         --batch-threshold=W  coalesce jobs of <= W digits on the batch
                         lane (bypasses the machine model; batched
                         results carry zero cost triples); 0 = off
         --smoke [--json --out=PATH]     CI serving curve -> BENCH_10.json
";

/// Build the leaf backend the config names.
fn make_leaf(cfg: &RunConfig) -> Result<Arc<dyn LeafMultiplier + Send + Sync>> {
    Ok(match cfg.leaf {
        LeafKind::Slim => Arc::new(SlimLeaf),
        LeafKind::Skim => Arc::new(SkimLeaf),
        LeafKind::School => Arc::new(SchoolLeaf),
        LeafKind::Hybrid => Arc::new(HybridLeaf { threshold: 32 }),
        LeafKind::Xla => {
            let rt = Arc::new(XlaRuntime::new(&cfg.artifacts_dir)?);
            Arc::new(XlaLeaf::new(rt, "school"))
        }
        LeafKind::XlaBatched => {
            let rt = Arc::new(XlaRuntime::new(&cfg.artifacts_dir)?);
            Arc::new(BatchingXlaLeaf::new(rt, "school"))
        }
    })
}

fn cmd_mul(args: &[String]) -> Result<()> {
    let (pos, kv): (Vec<&String>, Vec<&String>) = args.iter().partition(|a| !a.contains('='));
    let [a_hex, b_hex] = pos.as_slice() else {
        bail!("usage: copmul mul <a_hex> <b_hex> [key=value ...]");
    };
    let mut cfg = RunConfig::default();
    cfg.apply_args(&kv.iter().map(|s| s.to_string()).collect::<Vec<_>>())?;
    cfg.validate()?;
    let base = cfg.base();
    let a = parse_hex(a_hex, base).map_err(|e| copmul::error::anyhow!(e))?;
    let b = parse_hex(b_hex, base).map_err(|e| copmul::error::anyhow!(e))?;
    let leaf = make_leaf(&cfg)?;

    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 1,
            base,
            time_model: cfg.time_model,
        },
        leaf,
    );
    let mut spec = JobSpec::new(0, a, b);
    spec.procs = cfg.procs;
    spec.mem_cap = cfg.mem_cap;
    spec.algo = cfg.algo;
    spec.exec_mode = cfg.exec_mode;
    spec.engine = cfg.engine;
    spec.topology = cfg.topology;
    let res = coord.submit_blocking(spec)?;
    println!("product  = {}", to_hex(&res.product, base));
    println!("scheme   = {}", res.algo);
    println!("mode     = {}", res.exec_mode);
    println!("engine   = {}", res.engine);
    println!("topology = {}", cfg.topology);
    println!(
        "cost     = T={} BW={} L={} (critical path)",
        fmt_u64(res.cost.ops),
        fmt_u64(res.cost.words),
        fmt_u64(res.cost.msgs)
    );
    println!("mem/proc = {} words peak", fmt_u64(res.mem_peak));
    println!("wall     = {:?}", res.wall);
    coord.shutdown();
    Ok(())
}

fn cmd_experiment(args: &[String]) -> Result<()> {
    let id = args.first().context("usage: copmul experiment <id|all>")?;
    let csv = args.iter().any(|a| a == "--csv");
    let results = experiments::run_by_id(id)?;
    for (header, tables) in results {
        println!("\n## {header}\n");
        for t in tables {
            if csv {
                println!("{}", t.csv());
            } else {
                println!("{}", t.markdown());
            }
        }
    }
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    // `serve --daemon` is the open-loop service: strip the flag and
    // hand the rest to `copmul daemon` (shared flags keep their
    // meaning; daemon-only flags become available).
    if args.iter().any(|a| a == "--daemon") {
        let rest: Vec<String> = args.iter().filter(|a| *a != "--daemon").cloned().collect();
        return cmd_daemon(&rest);
    }
    let mut cfg = RunConfig::default();
    let mut jobs = 64usize;
    let mut shards: Option<usize> = None;
    let mut fault_rate = 0f64;
    let mut fault_seed: Option<u64> = None;
    let mut socket_timeout_ms: Option<u64> = None;
    let mut rest = Vec::new();
    for a in args {
        if let Some(v) = a.strip_prefix("jobs=").or_else(|| a.strip_prefix("--jobs=")) {
            jobs = v.parse().context("jobs")?;
        } else if let Some(v) = a
            .strip_prefix("shards=")
            .or_else(|| a.strip_prefix("--shards="))
        {
            shards = Some(v.parse().context("shards")?);
        } else if let Some(v) = a
            .strip_prefix("fault-rate=")
            .or_else(|| a.strip_prefix("--fault-rate="))
        {
            fault_rate = v.parse().context("fault-rate")?;
        } else if let Some(v) = a
            .strip_prefix("fault-seed=")
            .or_else(|| a.strip_prefix("--fault-seed="))
        {
            fault_seed = Some(v.parse().context("fault-seed")?);
        } else if let Some(v) = a
            .strip_prefix("socket-timeout-ms=")
            .or_else(|| a.strip_prefix("--socket-timeout-ms="))
        {
            socket_timeout_ms = Some(v.parse().context("socket-timeout-ms")?);
        } else {
            rest.push(a.clone());
        }
    }
    cfg.apply_args(&rest)?;
    if jobs == 0 {
        bail!("--jobs must be >= 1");
    }
    let fault = validate_fault_flags(fault_rate, fault_seed)?;
    let socket = socket_config(socket_timeout_ms)?;
    match shards {
        Some(k) => serve_sharded(&cfg, jobs, k, fault, socket),
        None => {
            if fault.is_some() {
                bail!("--fault-rate requires the sharded scheduler (--shards=K)");
            }
            if socket_timeout_ms.is_some() {
                bail!(
                    "--socket-timeout-ms requires the sharded scheduler (--shards=K); \
                     for the per-job coordinator set COPMUL_SOCKET_TIMEOUT_MS instead"
                );
            }
            serve_per_job(&cfg, jobs)
        }
    }
}

/// Shared `--socket-timeout-ms` handling for `serve` and `daemon`:
/// build the scheduler's [`SocketConfig`] with the override applied. A
/// zero timeout would fail every socket reply wait instantly, so it is
/// rejected here with the knob's name ([`SocketMachine::with_config`]
/// backstops the env-var path with the same rule).
///
/// [`SocketMachine::with_config`]: copmul::sim::SocketMachine::with_config
fn socket_config(timeout_ms: Option<u64>) -> Result<SocketConfig> {
    let mut socket = SocketConfig::default();
    match timeout_ms {
        Some(0) => bail!(
            "--socket-timeout-ms must be positive: a 0 timeout would fail every \
             socket reply wait instantly (default 30000; COPMUL_SOCKET_TIMEOUT_MS \
             sets the same knob)"
        ),
        Some(ms) => socket.reply_timeout = std::time::Duration::from_millis(ms),
        None => {}
    }
    Ok(socket)
}

/// Shared `--fault-rate`/`--fault-seed` validation for `serve` and
/// `daemon`: a seed without injection is a silently-dead knob — bail,
/// matching the `--fault-rate requires --shards` precedent.
fn validate_fault_flags(fault_rate: f64, fault_seed: Option<u64>) -> Result<Option<FaultConfig>> {
    if !(0.0..=1.0).contains(&fault_rate) {
        bail!("--fault-rate must be in [0, 1]");
    }
    if fault_rate == 0.0 {
        if let Some(seed) = fault_seed {
            bail!(
                "--fault-seed={seed} has no effect without --fault-rate > 0 \
                 (pass --fault-rate=R or drop the seed)"
            );
        }
    }
    Ok((fault_rate > 0.0).then(|| FaultConfig::new(fault_seed.unwrap_or(42), fault_rate)))
}

/// Classic path: one dedicated machine per job, `workers` in parallel.
fn serve_per_job(cfg: &RunConfig, jobs: usize) -> Result<()> {
    let base = cfg.base();
    let leaf = make_leaf(cfg)?;
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: cfg.workers,
            base,
            time_model: cfg.time_model,
        },
        leaf,
    );
    println!(
        "serving {jobs} jobs (n={}, procs={}, leaf={:?}, engine={}, topology={}, workers={})",
        cfg.n, cfg.procs, cfg.leaf, cfg.engine, cfg.topology, cfg.workers
    );
    let mut rng = Rng::new(cfg.seed);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for id in 0..jobs as u64 {
        let a = rng.digits(cfg.n, cfg.base_log2);
        let b = rng.digits(cfg.n, cfg.base_log2);
        let mut spec = JobSpec::new(id, a, b);
        spec.procs = cfg.procs;
        spec.mem_cap = cfg.mem_cap;
        spec.algo = cfg.algo;
        spec.exec_mode = cfg.exec_mode;
        spec.engine = cfg.engine;
        spec.topology = cfg.topology;
        pending.push(coord.submit(spec));
    }
    let mut lat_us: Vec<u64> = Vec::with_capacity(jobs);
    for rx in pending {
        let res = rx.recv().context("worker hung up")??;
        lat_us.push(res.wall.as_micros() as u64);
    }
    let wall = t0.elapsed();
    print_latency_summary(jobs, wall, &mut lat_us);
    coord.shutdown();
    Ok(())
}

/// Sharded path: ONE shared machine of `procs` processors; jobs request
/// `procs / shards` processors each and run concurrently on disjoint
/// shards, stealing freed processors as earlier jobs complete. With a
/// fault plan, the machine deterministically injects faults and the
/// scheduler's recovery (retries, backoff, quarantine) absorbs them.
fn serve_sharded(
    cfg: &RunConfig,
    jobs: usize,
    shards: usize,
    fault: Option<FaultConfig>,
    socket: SocketConfig,
) -> Result<()> {
    if shards == 0 {
        bail!("--shards must be >= 1");
    }
    if cfg.procs % shards != 0 {
        bail!("--shards={shards} must divide procs={}", cfg.procs);
    }
    let per_job = cfg.procs / shards;
    // procs/shards must be a shape the scheme ladder actually accepts
    // (4^k / 4·3^i / their union) — otherwise plan_shard silently
    // rounds every job UP and the run delivers less concurrency than
    // the banner claims. Probe with a representative job.
    {
        let mut probe = JobSpec::new(0, vec![1; cfg.n.max(1)], vec![1; cfg.n.max(1)]);
        probe.procs = per_job;
        probe.algo = cfg.algo;
        probe.mem_cap = cfg.mem_cap;
        let planned = copmul::coordinator::plan_shard(
            &probe,
            cfg.procs,
            cfg.mem_cap.unwrap_or(u64::MAX / 2),
        )?;
        if planned != per_job {
            bail!(
                "--shards={shards} gives {per_job} procs/job, but the smallest shard \
                 this workload can actually run on is {planned} (shapes are 4^k for \
                 copsim, 4·3^i for copk, their union for hybrid, within memory); \
                 pick shards so procs/shards is such a shape"
            );
        }
    }
    let base = cfg.base();
    let leaf = make_leaf(cfg)?;
    let faulty = fault.is_some();
    let sched = Scheduler::start(
        SchedulerConfig {
            procs: cfg.procs,
            mem_cap: cfg.mem_cap.unwrap_or(u64::MAX / 2),
            base,
            engine: cfg.engine,
            topology: cfg.topology,
            time_model: cfg.time_model,
            runners: shards,
            max_queue: jobs.max(1024),
            fault,
            socket,
            ..Default::default()
        },
        leaf,
    )?;
    println!(
        "serving {jobs} jobs on a shared {}-processor machine \
         ({shards} shards x {per_job} procs, n={}, leaf={:?}, engine={}, topology={})",
        cfg.procs, cfg.n, cfg.leaf, cfg.engine, cfg.topology
    );
    let mut rng = Rng::new(cfg.seed);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for id in 0..jobs as u64 {
        let a = rng.digits(cfg.n, cfg.base_log2);
        let b = rng.digits(cfg.n, cfg.base_log2);
        let mut spec = JobSpec::new(id, a, b);
        spec.procs = per_job;
        spec.algo = cfg.algo;
        spec.exec_mode = cfg.exec_mode;
        pending.push(sched.submit(spec)?);
    }
    // Collect tolerantly: a failed job must not abort the loop before
    // the summary prints (and the summary must cope with an empty
    // latency set if *every* job failed).
    let mut lat_us: Vec<u64> = Vec::with_capacity(jobs);
    let mut failed = 0usize;
    let mut first_err: Option<Error> = None;
    for rx in pending {
        match rx.recv().context("runner hung up")? {
            Ok(res) => lat_us.push(res.wall.as_micros() as u64),
            Err(e) => {
                failed += 1;
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
    }
    let wall = t0.elapsed();
    print_latency_summary(jobs, wall, &mut lat_us);
    println!(
        "scheduler: peak {} concurrent, {} shard acquisitions ({} after a wait)",
        sched
            .stats
            .peak_concurrent
            .load(std::sync::atomic::Ordering::Relaxed),
        sched
            .stats
            .shards_acquired
            .load(std::sync::atomic::Ordering::Relaxed),
        sched
            .stats
            .shards_stolen
            .load(std::sync::atomic::Ordering::Relaxed),
    );
    if faulty {
        println!(
            "faults: {} injected, {} attempt(s) retried, {} processor(s) quarantined",
            sched.faults_injected(),
            sched
                .stats
                .retries
                .load(std::sync::atomic::Ordering::Relaxed),
            sched.quarantined_procs(),
        );
    }
    sched.shutdown()?;
    if failed > 0 {
        bail!(
            "{failed}/{jobs} job(s) failed; first error: {}",
            first_err.expect("failed > 0 implies a recorded error")
        );
    }
    Ok(())
}

fn print_latency_summary(jobs: usize, wall: std::time::Duration, lat_us: &mut [u64]) {
    println!("{}", copmul::metrics::latency_summary(jobs, wall, lat_us));
}

/// `copmul daemon` — always-on serving under seeded open-loop load
/// (see the DAEMON section of [`HELP`] and `coordinator::daemon`).
fn cmd_daemon(args: &[String]) -> Result<()> {
    use std::time::Duration;

    let mut cfg = RunConfig::default();
    let mut jobs = 256u64;
    let mut rate = 800.0f64;
    let mut arrival = "poisson".to_string();
    let mut burst = 32u64;
    let mut idle_ms = 50u64;
    let mut deadline_ms = 100u64;
    let mut max_shed: Option<f64> = None;
    let mut verify = false;
    let mut shards = 4usize;
    let mut queue = 1024usize;
    let mut fault_rate = 0f64;
    let mut fault_seed: Option<u64> = None;
    let mut socket_timeout_ms: Option<u64> = None;
    let mut batch_threshold = 0usize;
    let mut smoke = false;
    let mut json = false;
    let mut out = "BENCH_10.json".to_string();
    let mut rest = Vec::new();
    for a in args {
        if let Some(v) = a.strip_prefix("--jobs=").or_else(|| a.strip_prefix("jobs=")) {
            jobs = v.parse().context("jobs")?;
        } else if let Some(v) = a.strip_prefix("--rate=") {
            rate = v.parse().context("rate")?;
        } else if let Some(v) = a.strip_prefix("--arrival=") {
            arrival = v.to_string();
        } else if let Some(v) = a.strip_prefix("--burst=") {
            burst = v.parse().context("burst")?;
        } else if let Some(v) = a.strip_prefix("--idle-ms=") {
            idle_ms = v.parse().context("idle-ms")?;
        } else if let Some(v) = a.strip_prefix("--deadline-ms=") {
            deadline_ms = v.parse().context("deadline-ms")?;
        } else if let Some(v) = a.strip_prefix("--max-shed=") {
            max_shed = Some(v.parse().context("max-shed")?);
        } else if a == "--verify" {
            verify = true;
        } else if let Some(v) = a
            .strip_prefix("--shards=")
            .or_else(|| a.strip_prefix("shards="))
        {
            shards = v.parse().context("shards")?;
        } else if let Some(v) = a.strip_prefix("--queue=") {
            queue = v.parse().context("queue")?;
        } else if let Some(v) = a.strip_prefix("--fault-rate=") {
            fault_rate = v.parse().context("fault-rate")?;
        } else if let Some(v) = a.strip_prefix("--fault-seed=") {
            fault_seed = Some(v.parse().context("fault-seed")?);
        } else if let Some(v) = a.strip_prefix("--socket-timeout-ms=") {
            socket_timeout_ms = Some(v.parse().context("socket-timeout-ms")?);
        } else if let Some(v) = a.strip_prefix("--batch-threshold=") {
            batch_threshold = v.parse().context("batch-threshold")?;
        } else if a == "--smoke" {
            smoke = true;
        } else if a == "--json" {
            json = true;
        } else if let Some(v) = a.strip_prefix("--out=") {
            out = v.to_string();
        } else {
            rest.push(a.clone());
        }
    }
    cfg.apply_args(&rest)?;

    if smoke {
        // CI serving curve: both engines, Poisson + bursty legs,
        // emitted in the BENCH_10.json `serving` section.
        let bench_cfg = copmul::perf::BenchConfig {
            smoke: true,
            seed: cfg.seed,
        };
        let mut report = copmul::perf::BenchReport {
            kernel_selected: copmul::bignum::arch::active().name,
            simd_isa: copmul::bignum::arch::simd::isa(),
            ..Default::default()
        };
        copmul::perf::serving_curve(&bench_cfg, &mut report)?;
        for t in report.tables() {
            if t.title.starts_with("serving curve") {
                println!("{}", t.markdown());
            }
        }
        if json {
            std::fs::write(&out, report.to_json()).with_context(|| format!("writing {out}"))?;
            println!("wrote {out}");
        }
        return Ok(());
    }

    if jobs == 0 {
        bail!("--jobs must be >= 1");
    }
    let fault = validate_fault_flags(fault_rate, fault_seed)?;
    if shards == 0 {
        bail!("--shards must be >= 1");
    }
    if cfg.procs % shards != 0 {
        bail!("--shards={shards} must divide procs={}", cfg.procs);
    }
    let per_job = cfg.procs / shards;
    // Same shape probe as `serve --shards` — procs/shards must be a
    // shape the scheme ladder accepts or every job silently rounds up.
    {
        let mut probe = JobSpec::new(0, vec![1; cfg.n.max(1)], vec![1; cfg.n.max(1)]);
        probe.procs = per_job;
        probe.algo = cfg.algo;
        probe.mem_cap = cfg.mem_cap;
        let planned = copmul::coordinator::plan_shard(
            &probe,
            cfg.procs,
            cfg.mem_cap.unwrap_or(u64::MAX / 2),
        )?;
        if planned != per_job {
            bail!(
                "--shards={shards} gives {per_job} procs/job, but the smallest shard \
                 this workload can actually run on is {planned} (shapes are 4^k for \
                 copsim, 4·3^i for copk, their union for hybrid, within memory); \
                 pick shards so procs/shards is such a shape"
            );
        }
    }

    let leaf = make_leaf(&cfg)?;
    let faulty = fault.is_some();
    let daemon = Daemon::start(
        DaemonConfig {
            sched: SchedulerConfig {
                procs: cfg.procs,
                mem_cap: cfg.mem_cap.unwrap_or(u64::MAX / 2),
                base: cfg.base(),
                engine: cfg.engine,
                topology: cfg.topology,
                time_model: cfg.time_model,
                runners: shards,
                max_queue: queue,
                fault,
                socket: socket_config(socket_timeout_ms)?,
                ..Default::default()
            },
            default_deadline: (deadline_ms > 0).then(|| Duration::from_millis(deadline_ms)),
            batch_threshold,
            ..Default::default()
        },
        leaf,
    )?;
    let arrivals = match arrival.as_str() {
        "poisson" => ArrivalGen::poisson(cfg.seed, rate)?,
        "bursty" => ArrivalGen::bursty(cfg.seed, rate, burst, Duration::from_millis(idle_ms))?,
        other => bail!("unknown arrival process `{other}` (poisson|bursty)"),
    };
    let load = OpenLoop {
        arrivals,
        jobs,
        workload: Workload {
            seed: cfg.seed,
            n: cfg.n,
            base_log2: cfg.base_log2,
            procs: per_job,
            algo: cfg.algo,
            exec_mode: cfg.exec_mode,
        },
        verify,
        collect: false,
    };
    println!(
        "daemon: {jobs} offered @ {rate:.0}/s ({arrival}), shared {}-processor machine \
         ({shards} shards x {per_job} procs, n={}, engine={}, deadline={})",
        cfg.procs,
        cfg.n,
        cfg.engine,
        if deadline_ms > 0 {
            format!("{deadline_ms}ms")
        } else {
            "none".to_string()
        },
    );
    let rep = run_open_loop(&daemon, &load)?;
    println!("{}", rep.summary());
    println!(
        "scheduler: peak {} concurrent, {} shard acquisitions ({} after a wait)",
        daemon
            .scheduler()
            .stats
            .peak_concurrent
            .load(std::sync::atomic::Ordering::Relaxed),
        daemon
            .scheduler()
            .stats
            .shards_acquired
            .load(std::sync::atomic::Ordering::Relaxed),
        daemon
            .scheduler()
            .stats
            .shards_stolen
            .load(std::sync::atomic::Ordering::Relaxed),
    );
    if faulty {
        println!(
            "faults: {} injected, {} attempt(s) retried, {} processor(s) quarantined",
            daemon.scheduler().faults_injected(),
            daemon
                .scheduler()
                .stats
                .retries
                .load(std::sync::atomic::Ordering::Relaxed),
            daemon.scheduler().quarantined_procs(),
        );
    }
    daemon.shutdown()?;
    if let Some(max_frac) = max_shed {
        rep.check_shed_budget(max_frac)?;
    }
    Ok(())
}

/// `copmul bench` — the wall-clock harness behind BENCH_*.json (see
/// `perf` module docs).
fn cmd_bench(args: &[String]) -> Result<()> {
    let mut cfg = copmul::perf::BenchConfig::default();
    let mut json = false;
    let mut out = "BENCH_10.json".to_string();
    for a in args {
        if a == "--json" {
            json = true;
        } else if a == "--smoke" {
            cfg.smoke = true;
        } else if let Some(v) = a.strip_prefix("--out=") {
            out = v.to_string();
        } else if let Some(v) = a.strip_prefix("seed=") {
            cfg.seed = v.parse().context("seed")?;
        } else {
            bail!("unknown bench option `{a}` (--json --out=PATH --smoke seed=N)");
        }
    }
    let report = copmul::perf::run(&cfg)?;
    for t in report.tables() {
        println!("{}", t.markdown());
    }
    if json {
        std::fs::write(&out, report.to_json()).with_context(|| format!("writing {out}"))?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_info(args: &[String]) -> Result<()> {
    let mut dir = "artifacts".to_string();
    for a in args {
        if let Some(v) = a.strip_prefix("artifacts=") {
            dir = v.to_string();
        }
    }
    match XlaRuntime::new(&dir) {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            println!("artifacts ({}):", dir);
            for a in &rt.manifest().artifacts {
                println!(
                    "  {:40} entry={:9} batch={} k={}",
                    a.file.file_name().unwrap().to_string_lossy(),
                    a.entry,
                    a.batch,
                    a.k
                );
            }
        }
        Err(e) => println!("no artifacts loaded ({e}); run `make artifacts`"),
    }
    Ok(())
}

fn cmd_selftest() -> Result<()> {
    // A quick end-to-end pass across schemes and leaf backends.
    let base = copmul::bignum::Base::default();
    let mut rng = Rng::new(7);
    let a = rng.digits(512, 16);
    let b = rng.digits(512, 16);
    let mut ops = copmul::bignum::Ops::default();
    let want = to_hex(
        &copmul::bignum::mul::mul_school(&a, &b, base, &mut ops),
        base,
    );
    let mut engines = vec![copmul::EngineKind::Sim, copmul::EngineKind::Threads];
    if copmul::sim::socket_available() {
        engines.push(copmul::EngineKind::Sockets);
    } else {
        println!("selftest: socket engine skipped (no worker binary resolvable)");
    }
    for (procs, algo) in [
        (16usize, Some(copmul::algorithms::Algorithm::Copsim)),
        (12, Some(copmul::algorithms::Algorithm::Copk)),
        (4, None),
    ] {
        let coord = Coordinator::start(CoordinatorConfig::default(), Arc::new(SkimLeaf));
        for &engine in &engines {
            let mut spec = JobSpec::new(0, a.clone(), b.clone());
            spec.procs = procs;
            spec.algo = algo;
            spec.engine = engine;
            let res = coord.submit_blocking(spec)?;
            copmul::error::ensure!(
                to_hex(&res.product, base) == want,
                "selftest mismatch at procs={procs} engine={engine}"
            );
        }
        coord.shutdown();
    }
    // XLA path, if artifacts are present.
    if let Ok(rt) = XlaRuntime::new("artifacts") {
        let leaf = Arc::new(XlaLeaf::new(Arc::new(rt), "school"));
        let coord = Coordinator::start(CoordinatorConfig::default(), leaf);
        let mut spec = JobSpec::new(1, a.clone(), b.clone());
        spec.procs = 4;
        let res = coord.submit_blocking(spec)?;
        copmul::error::ensure!(to_hex(&res.product, base) == want, "xla selftest mismatch");
        coord.shutdown();
        println!("selftest OK (incl. XLA leaf)");
    } else {
        println!("selftest OK (artifacts not built; XLA leaf skipped)");
    }
    Ok(())
}

//! Minimal error handling with an `anyhow`-compatible surface.
//!
//! This build environment is fully offline, so the `anyhow` crate the
//! code was written against is replaced by this self-contained module:
//! a string-backed [`Error`], the [`Result`] alias, the [`Context`]
//! extension trait, and the [`anyhow!`]/[`bail!`]/[`ensure!`] macros.
//! Call sites `use crate::error::...` exactly as they would
//! `use anyhow::...`.

use std::fmt;

/// A string-backed error. Context wrapping prepends `"{context}: "`,
/// matching `anyhow`'s `{:#}` rendering closely enough for logs.
pub struct Error(String);

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Self {
        Error(m.to_string())
    }

    /// Prepend a context layer.
    pub fn wrap(self, c: impl fmt::Display) -> Self {
        Error(format!("{c}: {}", self.0))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

/// Any std error converts losslessly (message-wise) into [`Error`],
/// so `?` works on `io::Result`, channel results, parses, etc.
/// ([`Error`] itself deliberately does not implement `std::error::Error`,
/// which keeps this blanket impl coherent — the same trick `anyhow`
/// uses.)
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error(e.to_string())
    }
}

/// Drop-in for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Drop-in for `anyhow::Context`: attach context to errors (or missing
/// `Option` values) while converting to [`Error`].
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error(format!("{c}: {e}")))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error(c.to_string()))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::error::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)+) => {
        $crate::error::Error::msg(format!($fmt, $($arg)+))
    };
    ($err:expr $(,)?) => {
        $crate::error::Error::msg($err)
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)+) => {
        return Err($crate::anyhow!($($t)+))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($rest:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($rest)+));
        }
    };
}

// Make the macros importable as `use crate::error::{anyhow, bail, ensure}`
// (mirroring `use anyhow::{anyhow, bail, ensure}`).
pub use crate::{anyhow, bail, ensure};

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("broke with code {}", 7)
    }

    #[test]
    fn macros_and_context() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "broke with code 7");
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: broke with code 7");
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(30).is_err());
    }

    #[test]
    fn std_errors_convert() {
        fn io_fail() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here")?;
            Ok(s)
        }
        assert!(io_fail().is_err());
        let e = Error::msg("inner").wrap("ctx");
        assert_eq!(format!("{e}"), "ctx: inner");
    }
}

//! Distributed integers: values partitioned across processor sequences
//! (§2.1 "A is partitioned among the processors in P in n' digits"),
//! plus the generic layout-change (`repartition`) helpers the
//! algorithms use for their redistribution phases. All data movement
//! compiles to the tree/coalesced schedules in
//! [`collectives`](super::collectives) — there are no ad-hoc send
//! loops left at this layer.
//!
//! Everything here is generic over [`MachineApi`], so the same layout
//! logic runs on the cost-model simulator and the threaded executor,
//! under any network topology.

use super::api::MachineApi;
use super::collectives::{self, ChunkPlan, Piece, Run};
use super::machine::{ProcId, Slot};
use super::seq::Seq;
use crate::error::Result;

/// An integer partitioned across processors: chunk `k` (LSB-first) holds
/// digits `[k·w, (k+1)·w)` of the value in the local memory of its owner.
#[derive(Clone, Debug)]
pub struct DistInt {
    /// Digits per chunk (the paper's n').
    pub chunk_width: usize,
    /// `(owner, slot)` per chunk, least-significant chunk first.
    pub chunks: Vec<(ProcId, Slot)>,
}

impl DistInt {
    /// Total number of digits.
    pub fn total_width(&self) -> usize {
        self.chunk_width * self.chunks.len()
    }

    /// Owners in chunk order.
    pub fn owners(&self) -> Vec<ProcId> {
        self.chunks.iter().map(|&(p, _)| p).collect()
    }

    /// Distribute `digits` (LSB-first, length exactly `width·|seq|`)
    /// across `seq` in `width`-digit chunks. Models the paper's initial
    /// input layout; charges memory but no communication (the input is
    /// assumed already balanced across processors, as both the
    /// algorithms and the memory-independent lower bounds require).
    pub fn scatter<M: MachineApi>(
        m: &mut M,
        seq: &Seq,
        digits: &[u32],
        width: usize,
    ) -> Result<DistInt> {
        assert_eq!(
            digits.len(),
            width * seq.len(),
            "scatter: digit count {} != width {} x |P| {}",
            digits.len(),
            width,
            seq.len()
        );
        let mut chunks = Vec::with_capacity(seq.len());
        for j in 0..seq.len() {
            let p = seq.at(j);
            let slot = m.alloc(p, digits[j * width..(j + 1) * width].to_vec())?;
            chunks.push((p, slot));
        }
        Ok(DistInt {
            chunk_width: width,
            chunks,
        })
    }

    /// Collect the full digit vector (verification / result extraction
    /// only — no cost; the costed tree collective is
    /// [`collectives::gather`]). Fails when a chunk owner's worker is
    /// dead or crashed.
    pub fn gather<M: MachineApi>(&self, m: &M) -> Result<Vec<u32>> {
        collectives::gather_host(m, &self.chunks)
    }

    /// Free every chunk.
    pub fn free<M: MachineApi>(self, m: &mut M) {
        for (p, slot) in self.chunks {
            m.free(p, slot);
        }
    }

    /// Split into (low, high) halves by chunk index. Both halves keep
    /// the chunk width; no data moves.
    pub fn split_half(&self) -> (DistInt, DistInt) {
        let h = self.chunks.len() / 2;
        (
            DistInt {
                chunk_width: self.chunk_width,
                chunks: self.chunks[..h].to_vec(),
            },
            DistInt {
                chunk_width: self.chunk_width,
                chunks: self.chunks[h..].to_vec(),
            },
        )
    }

    /// Concatenate `lo` (less significant) and `hi` (equal chunk width).
    pub fn concat(lo: DistInt, hi: DistInt) -> DistInt {
        assert_eq!(lo.chunk_width, hi.chunk_width);
        let mut chunks = lo.chunks;
        chunks.extend(hi.chunks);
        DistInt {
            chunk_width: lo.chunk_width,
            chunks,
        }
    }

    /// Change layout: repartition the same value onto `new_seq` in
    /// `new_width`-digit chunks (total width must be preserved).
    ///
    /// Every digit moves at most once — one message per maximal
    /// contiguous source-range → destination pair; ranges staying on
    /// their owner move for free — which keeps the charged communication
    /// within the per-phase budgets of the paper's redistribution steps
    /// (§5.1 phases 1a–1c / 3a–3e, §6.1 splitting/recomposition, §5.2 and
    /// §6.2 DFS input/output shuffles) — see DESIGN.md, decision 4.
    pub fn repartition<M: MachineApi>(
        self,
        m: &mut M,
        new_seq: &Seq,
        new_width: usize,
    ) -> Result<DistInt> {
        let new = self.copy_to(m, new_seq, new_width)?;
        self.free(m);
        Ok(new)
    }

    /// Pad with zero chunks at the most-significant end, placed on the
    /// given owners (memory charged, no communication).
    pub fn extend_zero<M: MachineApi>(mut self, m: &mut M, owners: &[ProcId]) -> Result<DistInt> {
        for &p in owners {
            let slot = m.alloc(p, vec![0u32; self.chunk_width])?;
            self.chunks.push((p, slot));
        }
        Ok(self)
    }

    /// Prepend zero chunks at the *least*-significant end (a `s^(k·w)`
    /// shift), placed on the given owners.
    pub fn prepend_zero<M: MachineApi>(self, m: &mut M, owners: &[ProcId]) -> Result<DistInt> {
        let mut chunks = Vec::with_capacity(owners.len() + self.chunks.len());
        for &p in owners {
            let slot = m.alloc(p, vec![0u32; self.chunk_width])?;
            chunks.push((p, slot));
        }
        chunks.extend(self.chunks);
        Ok(DistInt {
            chunk_width: self.chunk_width,
            chunks,
        })
    }

    /// Replicate chunk-wise onto another sequence of the same length:
    /// `chunks[j].owner` sends its chunk to `dst.at(j)` — one
    /// [`collectives::shift`] round of `chunk_width`-word messages
    /// (COPSIM §5.1 phases 1b/1c). The source layout is kept.
    pub fn replicate<M: MachineApi>(&self, m: &mut M, dst: &Seq) -> Result<DistInt> {
        assert_eq!(self.chunks.len(), dst.len(), "replicate: length mismatch");
        Ok(DistInt {
            chunk_width: self.chunk_width,
            chunks: collectives::shift(m, &self.chunks, dst)?,
        })
    }

    /// Non-consuming repartition: build a *copy* of this value laid out
    /// on `new_seq` in `new_width`-digit chunks; the source stays
    /// resident (the DFS execution modes copy subproblem inputs because
    /// the originals are still needed by later subproblems).
    ///
    /// Compiles the layout change into a [`collectives::all_to_all`]
    /// plan: for every destination chunk, the maximal runs of
    /// consecutive source pieces on one owner, each travelling as ONE
    /// message (the "one message per maximal contiguous range" rule the
    /// repartition cost argument relies on — DESIGN.md, decision 4).
    /// The collective keeps the received allocation as the destination
    /// chunk whenever a whole chunk arrives in a single message, so the
    /// destination is charged exactly once for it.
    ///
    /// The piece decomposition depends only on the (widths, counts)
    /// shape, so it comes from the shared compiled-plan cache
    /// ([`collectives::repartition_plan`]); owners, slots, and the
    /// run grouping — cheap and identity-dependent — are bound here,
    /// per execution. The executed plan is identical to what per-call
    /// compilation produced; the scheduler's repeated same-shape jobs
    /// just stop paying for the division arithmetic and plan vectors.
    pub fn copy_to<M: MachineApi>(
        &self,
        m: &mut M,
        new_seq: &Seq,
        new_width: usize,
    ) -> Result<DistInt> {
        let total = self.total_width();
        assert_eq!(
            total,
            new_width * new_seq.len(),
            "copy_to: total width {} != {} x |P| {}",
            total,
            new_width,
            new_seq.len()
        );
        let template = collectives::repartition_plan(collectives::PlanShape {
            old_width: self.chunk_width,
            old_chunks: self.chunks.len(),
            new_width,
            new_chunks: new_seq.len(),
        });
        let mut plan = Vec::with_capacity(new_seq.len());
        for (j, pieces) in template.iter().enumerate() {
            // Maximal runs of consecutive pieces on one owner.
            let mut runs: Vec<Run> = Vec::new();
            for t in pieces {
                let (src, slot) = self.chunks[t.chunk];
                let piece = Piece {
                    slot,
                    lo: t.lo,
                    hi: t.hi,
                    full: t.full,
                };
                match runs.last_mut() {
                    Some(run) if run.src == src => run.pieces.push(piece),
                    _ => runs.push(Run {
                        src,
                        pieces: vec![piece],
                    }),
                }
            }
            plan.push(ChunkPlan {
                dst: new_seq.at(j),
                width: new_width,
                runs,
            });
        }
        Ok(DistInt {
            chunk_width: new_width,
            chunks: collectives::all_to_all(m, &plan)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bignum::Base;
    use crate::sim::Machine;
    use crate::util::Rng;

    fn mk(p: usize) -> Machine {
        Machine::unbounded(p, Base::new(16))
    }

    #[test]
    fn scatter_gather_roundtrip() {
        let mut m = mk(4);
        let seq = Seq::range(4);
        let mut rng = Rng::new(5);
        let digits = rng.digits(16, 16);
        let d = DistInt::scatter(&mut m, &seq, &digits, 4).unwrap();
        assert_eq!(d.gather(&m).unwrap(), digits);
        assert_eq!(m.critical().words, 0, "scatter must not communicate");
    }

    #[test]
    fn split_concat() {
        let mut m = mk(4);
        let seq = Seq::range(4);
        let digits: Vec<u32> = (0..16).collect();
        let d = DistInt::scatter(&mut m, &seq, &digits, 4).unwrap();
        let (lo, hi) = d.split_half();
        assert_eq!(lo.gather(&m).unwrap(), (0..8).collect::<Vec<u32>>());
        assert_eq!(hi.gather(&m).unwrap(), (8..16).collect::<Vec<u32>>());
        let d = DistInt::concat(lo, hi);
        assert_eq!(d.gather(&m).unwrap(), digits);
    }

    #[test]
    fn repartition_preserves_value() {
        let mut m = mk(8);
        let seq = Seq::range(8);
        let mut rng = Rng::new(7);
        let digits = rng.digits(32, 16);
        let d = DistInt::scatter(&mut m, &seq, &digits, 4).unwrap();
        // 8 procs x 4 digits -> 4 procs x 8 digits (upper half owners).
        let target = Seq(vec![4, 5, 6, 7]);
        let d = d.repartition(&mut m, &target, 8).unwrap();
        assert_eq!(d.gather(&m).unwrap(), digits);
        assert_eq!(d.owners(), vec![4, 5, 6, 7]);
        // Each moved digit charged once; runs are coalesced, so at most
        // one message per (contiguous source range, destination) pair.
        assert!(m.stats.total_words <= 32);
        assert!(m.stats.total_msgs <= 7, "msgs = {}", m.stats.total_msgs);
    }

    #[test]
    fn repartition_same_layout_is_free() {
        let mut m = mk(4);
        let seq = Seq::range(4);
        let digits: Vec<u32> = (0..16).collect();
        let d = DistInt::scatter(&mut m, &seq, &digits, 4).unwrap();
        let d = d.repartition(&mut m, &seq, 4).unwrap();
        assert_eq!(d.gather(&m).unwrap(), digits);
        assert_eq!(m.stats.total_words, 0);
        assert_eq!(m.stats.total_msgs, 0);
    }

    #[test]
    fn repartition_interleave() {
        let mut m = mk(4);
        let seq = Seq::range(4);
        let digits: Vec<u32> = (100..116).collect();
        let d = DistInt::scatter(&mut m, &seq, &digits, 4).unwrap();
        let inter = seq.interleave_halves(); // [0, 2, 1, 3]
        let d = d.repartition(&mut m, &inter, 4).unwrap();
        assert_eq!(d.gather(&m).unwrap(), digits);
        assert_eq!(d.owners(), inter.ids().to_vec());
    }

    #[test]
    fn copy_to_coalesces_runs_and_charges_once() {
        // Two 4-digit source chunks per destination chunk, both on the
        // same owner: they must travel as ONE coalesced message, and the
        // received allocation must BE the destination chunk (charged
        // once, no transient doubling).
        let mut m = mk(4);
        let digits: Vec<u32> = (0..16).collect();
        let d = DistInt::scatter(&mut m, &Seq(vec![0, 0, 2, 2]), &digits, 4).unwrap();
        let c = d.copy_to(&mut m, &Seq(vec![0, 1]), 8).unwrap();
        assert_eq!(c.gather(&m).unwrap(), digits);
        // Chunk 0: owner 0 == dst 0 — free. Chunk 1: owner 2 -> dst 1 —
        // one coalesced 8-word message (the uncoalesced path charged 2).
        assert_eq!(m.stats.total_msgs, 1);
        assert_eq!(m.stats.total_words, 8);
        assert_eq!(
            m.proc(1).mem_peak(),
            8,
            "destination must be charged exactly once for the chunk"
        );
    }

    #[test]
    fn extend_zero_pads_high() {
        let mut m = mk(4);
        let digits: Vec<u32> = (1..9).collect();
        let d = DistInt::scatter(&mut m, &Seq(vec![0, 1]), &digits, 4).unwrap();
        let d = d.extend_zero(&mut m, &[2, 3]).unwrap();
        // Reuse `digits` as the expectation (scatter only borrowed it).
        let mut want = digits;
        want.extend([0u32; 8]);
        assert_eq!(d.gather(&m).unwrap(), want);
    }
}

//! Ordered processor sequences (the paper's **P** notation, §2.1).
//!
//! `seq[0]` (the paper's `P[0]`) owns the *least-significant* chunk of a
//! distributed integer; `seq[len-1]` the most significant. The paper's
//! standard splits are provided: halves (`P'`, `P''`), even/odd
//! interleavings (COPSIM's four groups), and the COPK three-way split.

use super::machine::ProcId;

/// An ordered sequence of processors.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Seq(pub Vec<ProcId>);

impl Seq {
    /// The canonical sequence `[0, 1, ..., p-1]`.
    pub fn range(p: usize) -> Self {
        Seq((0..p).collect())
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.0.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    /// The paper's `P[i]`.
    #[inline]
    pub fn at(&self, i: usize) -> ProcId {
        self.0[i]
    }

    pub fn ids(&self) -> &[ProcId] {
        &self.0
    }

    /// Lower half `P' = [P[|P|/2 - 1], ..., P[0]]` (least significant).
    pub fn lower_half(&self) -> Seq {
        Seq(self.0[..self.len() / 2].to_vec())
    }

    /// Upper half `P'' = [P[|P|-1], ..., P[|P|/2]]` (most significant).
    pub fn upper_half(&self) -> Seq {
        Seq(self.0[self.len() / 2..].to_vec())
    }

    /// Even-index subsequence `[P[0], P[2], ...]`.
    pub fn evens(&self) -> Seq {
        Seq(self.0.iter().copied().step_by(2).collect())
    }

    /// Odd-index subsequence `[P[1], P[3], ...]`.
    pub fn odds(&self) -> Seq {
        Seq(self.0.iter().skip(1).copied().step_by(2).collect())
    }

    /// COPSIM's four BFS groups (§5.1 "Splitting"): even/odd processors
    /// of each half — `P0` = evens of `P'`, `P1` = odds of `P'`,
    /// `P2` = evens of `P''`, `P3` = odds of `P''`.
    pub fn copsim_groups(&self) -> [Seq; 4] {
        let lo = self.lower_half();
        let hi = self.upper_half();
        [lo.evens(), lo.odds(), hi.evens(), hi.odds()]
    }

    /// COPK's three BFS groups (§6.1): with `|P| = 4·3^i`, assign
    /// `|P|/3` processors to each of `A0·B0`, `A'·B'`, `A1·B1`.
    ///
    /// The paper interleaves specific indices to economize particular
    /// communication phases; any fixed one-to-one assignment preserves
    /// the communication *costs* charged per phase (each processor still
    /// exchanges the same chunk sizes with a distinct peer). We use:
    /// `P0` = first 2/3 of the lower half thinned to |P|/3 by taking two
    /// of every three slots... — concretely, we deal processors round-
    /// robin: lower-half processors to groups (0,0,1), upper-half to
    /// (2,2,1), preserving LSB-first order inside every group.
    pub fn copk_groups(&self) -> [Seq; 3] {
        let p = self.len();
        assert!(p % 12 == 0 || p == 4, "COPK grouping expects |P| = 4·3^i, i >= 1");
        let third = p / 3;
        let lo = &self.0[..p / 2];
        let hi = &self.0[p / 2..];
        let mut g0 = Vec::with_capacity(third);
        let mut g1 = Vec::with_capacity(third);
        let mut g2 = Vec::with_capacity(third);
        // Deal the lower half: two slots to P0, one to P1 (so P0 keeps a
        // majority of the processors already holding A0/B0 digits).
        for (k, &pid) in lo.iter().enumerate() {
            if k % 3 == 2 {
                g1.push(pid);
            } else {
                g0.push(pid);
            }
        }
        // Deal the upper half symmetrically: two to P2, one to P1.
        for (k, &pid) in hi.iter().enumerate() {
            if k % 3 == 2 {
                g1.push(pid);
            } else {
                g2.push(pid);
            }
        }
        debug_assert_eq!(g0.len(), third);
        debug_assert_eq!(g1.len(), third);
        debug_assert_eq!(g2.len(), third);
        [Seq(g0), Seq(g1), Seq(g2)]
    }

    /// Interleaving used by the main (DFS) execution modes (§5.2's `P'`):
    /// re-rank the same processors so even ranks are the lower half and
    /// odd ranks the upper half — each subproblem then reuses *all*
    /// processors with halved chunk width.
    pub fn interleave_halves(&self) -> Seq {
        let lo = self.lower_half();
        let hi = self.upper_half();
        let mut out = Vec::with_capacity(self.len());
        for i in 0..lo.len() {
            out.push(lo.at(i));
            out.push(hi.at(i));
        }
        Seq(out)
    }

    /// Position of processor `pid` in this sequence, if present.
    pub fn rank_of(&self, pid: ProcId) -> Option<usize> {
        self.0.iter().position(|&x| x == pid)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn halves_follow_paper_orientation() {
        let s = Seq::range(8);
        assert_eq!(s.lower_half().ids(), &[0, 1, 2, 3]);
        assert_eq!(s.upper_half().ids(), &[4, 5, 6, 7]);
    }

    #[test]
    fn copsim_groups_partition() {
        let s = Seq::range(16);
        let [g0, g1, g2, g3] = s.copsim_groups();
        assert_eq!(g0.ids(), &[0, 2, 4, 6]);
        assert_eq!(g1.ids(), &[1, 3, 5, 7]);
        assert_eq!(g2.ids(), &[8, 10, 12, 14]);
        assert_eq!(g3.ids(), &[9, 11, 13, 15]);
        let mut all: Vec<_> = [&g0, &g1, &g2, &g3]
            .iter()
            .flat_map(|g| g.ids().iter().copied())
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..16).collect::<Vec<_>>());
        assert!(g0.len() == 4 && g1.len() == 4 && g2.len() == 4 && g3.len() == 4);
    }

    #[test]
    fn copk_groups_partition() {
        let s = Seq::range(12);
        let [g0, g1, g2] = s.copk_groups();
        let mut all: Vec<_> = [&g0, &g1, &g2]
            .iter()
            .flat_map(|g| g.ids().iter().copied())
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..12).collect::<Vec<_>>());
        assert_eq!(g0.len(), 4);
        assert_eq!(g1.len(), 4);
        assert_eq!(g2.len(), 4);
        // P0 ⊂ lower half, P2 ⊂ upper half.
        assert!(g0.ids().iter().all(|&p| p < 6));
        assert!(g2.ids().iter().all(|&p| p >= 6));
    }

    #[test]
    fn interleave_round_trips_membership() {
        let s = Seq::range(8);
        let t = s.interleave_halves();
        assert_eq!(t.ids(), &[0, 4, 1, 5, 2, 6, 3, 7]);
        let mut sorted = t.ids().to_vec();
        sorted.sort_unstable();
        assert_eq!(sorted, s.ids());
    }

    #[test]
    fn rank_of_finds() {
        let s = Seq(vec![5, 3, 9]);
        assert_eq!(s.rank_of(3), Some(1));
        assert_eq!(s.rank_of(7), None);
    }
}

//! [`SocketMachine`] — the real-network execution engine.
//!
//! One OS *process* per group of simulated processors, connected over
//! Unix-domain sockets (TCP behind [`SocketTransport::Tcp`] /
//! `COPMUL_SOCKET_TCP=1`). This is the engine that takes the paper's
//! "distributed memory" literally: every inter-group word genuinely
//! crosses a kernel socket, so the predicted (T, BW, L) bounds are
//! exercised against real message passing rather than shared memory.
//!
//! ## Cost contract
//!
//! The engine satisfies the exact contract the threaded engine does:
//! bit-identical products AND identical (T, BW, L, M) cost triples on
//! every topology (three-way differential in
//! `tests/engine_differential.rs`). Each worker process runs one
//! command loop per owned processor that is semantically byte-for-byte
//! the threaded engine's `Worker::run`: the same ledger sequence
//! (free inputs, charge ops, alloc output), the same clock-snapshot
//! piggybacking on every message, the same join-then-charge order on
//! relays, the same host-joined barrier clock.
//!
//! What differs is *where the digit work runs*: closures cannot cross
//! a process boundary, so `local` and `compute_slot` bodies execute in
//! the coordinator process (`compute_slot` round-trips the input
//! digits). Workers own everything cost-visible — memory ledgers,
//! clocks, and the wire — so model costs are unchanged; the engine
//! loses `compute_slot` overlap, a wall-clock (not model-cost) effect.
//! The threaded engine remains the wall-clock engine; this one is the
//! communication-measurement engine.
//!
//! ## Wiring
//!
//! Frames are length-prefixed little-endian messages (shared
//! [`crate::util::frame::FrameCursor`] reader, same hardened contract
//! as the serving daemon's `Request::{encode,decode}`; fuzzed in
//! `tests/wire_fuzz.rs`). Lifecycle: the host binds a listener, spawns
//! `copmul --socket-worker` once per group, and handshakes
//! Hello/Setup/Listening/Go/Ready; workers then build a full peer mesh
//! (lower group connects, higher accepts) for the data plane. Each
//! control link gets a host-side writer thread and reader thread; a
//! reader EOF marks the group dead and fails its pending calls, which
//! is how a real `SIGKILL` surfaces as per-call errors (kill-chaos in
//! `tests/chaos_soak.rs`) — backstopped by
//! [`SocketConfig::reply_timeout`] so a vanished worker can never hang
//! the coordinator.
//!
//! ## Self-healing
//!
//! Capacity loss is reversible: an optional heartbeat pump
//! ([`SocketConfig::heartbeat_interval`]) catches wedged-but-connected
//! workers that reader-EOF never would, and
//! [`SocketMachine::respawn_group`] replaces a dead worker process
//! outright — same handshake on the original host listener, live
//! workers told to dial the fresh peer listener (`Reconnect` frames),
//! jittered exponential backoff between attempts
//! ([`SocketConfig::respawn_backoff`]). Worker-side, mesh channels and
//! writer threads are permanent per remote group; only the stream gets
//! swapped, so in-flight jobs on *other* groups never notice. The
//! respawned group returns with empty arenas and zeroed clocks — the
//! scheduler's probation canary re-validates it before client work
//! lands there.

use super::api::{MachineApi, ProcView, SlotComputation};
use super::machine::{MachineStats, ProcId, Slot};
use super::threaded::{payload_into_vec, ThreadedReport, WorkerSnapshot};
use super::topology::{FullyConnected, TopologyRef};
use super::Clock;
use crate::bignum::{Base, Ops};
use crate::error::{anyhow, bail, ensure, Result};
use std::any::Any;
use std::collections::VecDeque;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A delivered point-to-point message: payload digits + sender clock
/// snapshot (the threaded engine's `NetMsg`, rebuilt per process).
type NetMsg = (Arc<Vec<u32>>, Clock);
/// Receiver mesh rows: `[local dst][global src]`.
type NetRxMesh = Vec<Vec<Option<Receiver<NetMsg>>>>;
/// Sender mesh rows: `[global src][local dst]`.
type NetTxMesh = Vec<Vec<Option<Sender<NetMsg>>>>;

pub mod wire {
    //! The socket engine's frame codec. Every frame is a little-endian
    //! body of `MAGIC`, `VERSION`, a one-byte opcode, and
    //! opcode-specific fields, shipped length-prefixed by a `u32`.
    //! Decoding uses the shared bounds-checked
    //! [`FrameCursor`](crate::util::frame::FrameCursor), so hostile
    //! length fields are rejected before any allocation and trailing
    //! garbage fails the frame (fuzzed in `tests/wire_fuzz.rs`).

    use crate::error::{bail, ensure, Result};
    use crate::sim::threaded::WorkerSnapshot;
    use crate::sim::Clock;
    use crate::util::frame::{push_digits_lp, push_str_lp, FrameCursor};
    use std::io::{Read, Write};
    use std::time::Duration;

    /// `"COPW"` — distinct from the serving daemon's `"COPM"`.
    pub const MAGIC: u32 = 0x434F_5057;
    pub const VERSION: u8 = 1;
    /// Upper bound on one frame body; the length prefix is validated
    /// against it before the body buffer is allocated.
    pub const MAX_FRAME: usize = 1 << 26;

    /// One message on a socket-engine link. Commands address a global
    /// processor id `p`; the worker process owning `p`'s group
    /// dispatches them to that processor's command loop.
    #[derive(Clone, Debug, PartialEq)]
    pub enum Frame {
        // -- lifecycle (host <-> worker control stream) ---------------
        Hello { group: u32 },
        Setup { procs: u32, groups: u32, mem_cap: u64, base_log2: u8, bounds: Vec<u32> },
        Listening { addr: String },
        Go { addrs: Vec<String> },
        Ready,
        Shutdown,
        /// Host-side liveness probe. The worker's command pump answers
        /// with [`Frame::HeartbeatAck`] directly (process-level, ahead
        /// of the per-processor queues, so a busy proc cannot delay it).
        Heartbeat { seq: u64 },
        /// Tell a live worker to dial a respawned peer group at `addr`
        /// and swap the fresh stream into its mesh (respawn handshake).
        Reconnect { group: u32, addr: String },
        // -- commands (host -> worker) --------------------------------
        Alloc { p: u32, slot: u64, data: Vec<u32> },
        Free { p: u32, slot: u64 },
        Replace { p: u32, slot: u64, data: Vec<u32> },
        Read { p: u32, slot: u64 },
        Compute { p: u32, ops: u64 },
        /// Charge a host-executed `local` closure at this queue point.
        LocalSync { p: u32, ops: u64, busy_ns: u64 },
        /// First half of `compute_slot`: free/borrow the inputs and
        /// ship their digits to the host.
        TakeInputs { p: u32, slots: Vec<u64>, consume: bool },
        /// Second half of `compute_slot`: charge ops, store the output.
        StoreOutput { p: u32, slot: u64, ops: u64, busy_ns: u64, data: Vec<u32> },
        SendOwned { p: u32, dst: u32, weight: u64, data: Vec<u32> },
        SendSlot {
            p: u32,
            dst: u32,
            weight: u64,
            slot: u64,
            range: Option<(u64, u64)>,
            free_after: bool,
        },
        Forward { p: u32, src: u32, dst: u32, weight: u64 },
        Recv { p: u32, src: u32, slot: u64 },
        BarrierCollect { p: u32 },
        BarrierRelease { p: u32, clock: Clock },
        Purge { p: u32 },
        Query { p: u32 },
        // -- replies (worker -> host) ---------------------------------
        Data { p: u32, payload: Vec<u32> },
        Ack { p: u32 },
        Inputs { p: u32, payloads: Vec<Vec<u32>> },
        Snapshot { p: u32, snap: WorkerSnapshot },
        BarrierClock { p: u32, clock: Clock },
        HeartbeatAck { seq: u64 },
        // -- peer data plane (worker <-> worker) ----------------------
        PeerHello { group: u32 },
        Net { src: u32, dst: u32, clock: Clock, payload: Vec<u32> },
    }

    fn push_u32(out: &mut Vec<u8>, v: u32) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    fn push_u64(out: &mut Vec<u8>, v: u64) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    fn push_clock(out: &mut Vec<u8>, c: &Clock) {
        push_u64(out, c.ops);
        push_u64(out, c.words);
        push_u64(out, c.msgs);
    }

    fn read_clock(f: &mut FrameCursor) -> Result<Clock> {
        let ops = f.u64()?;
        let words = f.u64()?;
        let msgs = f.u64()?;
        Ok(Clock { ops, words, msgs })
    }

    fn read_bool(f: &mut FrameCursor) -> Result<bool> {
        let b = f.u8()?;
        ensure!(b <= 1, "bad bool byte {b} in frame");
        Ok(b == 1)
    }

    /// Counted digit vector (writer half is `push_digits_lp`).
    fn read_digits_lp(f: &mut FrameCursor) -> Result<Vec<u32>> {
        let n = f.u32()? as usize;
        f.digits(n)
    }

    impl Frame {
        fn opcode(&self) -> u8 {
            match self {
                Frame::Hello { .. } => 0x01,
                Frame::Setup { .. } => 0x02,
                Frame::Listening { .. } => 0x03,
                Frame::Go { .. } => 0x04,
                Frame::Ready => 0x05,
                Frame::Shutdown => 0x06,
                Frame::Heartbeat { .. } => 0x07,
                Frame::Reconnect { .. } => 0x08,
                Frame::Alloc { .. } => 0x10,
                Frame::Free { .. } => 0x11,
                Frame::Replace { .. } => 0x12,
                Frame::Read { .. } => 0x13,
                Frame::Compute { .. } => 0x14,
                Frame::LocalSync { .. } => 0x15,
                Frame::TakeInputs { .. } => 0x16,
                Frame::StoreOutput { .. } => 0x17,
                Frame::SendOwned { .. } => 0x18,
                Frame::SendSlot { .. } => 0x19,
                Frame::Forward { .. } => 0x1A,
                Frame::Recv { .. } => 0x1B,
                Frame::BarrierCollect { .. } => 0x1C,
                Frame::BarrierRelease { .. } => 0x1D,
                Frame::Purge { .. } => 0x1E,
                Frame::Query { .. } => 0x1F,
                Frame::Data { .. } => 0x20,
                Frame::Ack { .. } => 0x21,
                Frame::Inputs { .. } => 0x22,
                Frame::Snapshot { .. } => 0x23,
                Frame::BarrierClock { .. } => 0x24,
                Frame::HeartbeatAck { .. } => 0x25,
                Frame::PeerHello { .. } => 0x30,
                Frame::Net { .. } => 0x31,
            }
        }

        /// Serialize the frame body (no length prefix).
        pub fn encode(&self) -> Vec<u8> {
            let mut out = Vec::with_capacity(32);
            push_u32(&mut out, MAGIC);
            out.push(VERSION);
            out.push(self.opcode());
            match self {
                Frame::Hello { group } | Frame::PeerHello { group } => {
                    push_u32(&mut out, *group);
                }
                Frame::Setup {
                    procs,
                    groups,
                    mem_cap,
                    base_log2,
                    bounds,
                } => {
                    push_u32(&mut out, *procs);
                    push_u32(&mut out, *groups);
                    push_u64(&mut out, *mem_cap);
                    out.push(*base_log2);
                    push_digits_lp(&mut out, bounds);
                }
                Frame::Listening { addr } => push_str_lp(&mut out, addr),
                Frame::Go { addrs } => {
                    push_u32(&mut out, addrs.len() as u32);
                    for a in addrs {
                        push_str_lp(&mut out, a);
                    }
                }
                Frame::Ready | Frame::Shutdown => {}
                Frame::Heartbeat { seq } | Frame::HeartbeatAck { seq } => {
                    push_u64(&mut out, *seq);
                }
                Frame::Reconnect { group, addr } => {
                    push_u32(&mut out, *group);
                    push_str_lp(&mut out, addr);
                }
                Frame::Alloc { p, slot, data } | Frame::Replace { p, slot, data } => {
                    push_u32(&mut out, *p);
                    push_u64(&mut out, *slot);
                    push_digits_lp(&mut out, data);
                }
                Frame::Free { p, slot } | Frame::Read { p, slot } => {
                    push_u32(&mut out, *p);
                    push_u64(&mut out, *slot);
                }
                Frame::Compute { p, ops } => {
                    push_u32(&mut out, *p);
                    push_u64(&mut out, *ops);
                }
                Frame::LocalSync { p, ops, busy_ns } => {
                    push_u32(&mut out, *p);
                    push_u64(&mut out, *ops);
                    push_u64(&mut out, *busy_ns);
                }
                Frame::TakeInputs { p, slots, consume } => {
                    push_u32(&mut out, *p);
                    push_u32(&mut out, slots.len() as u32);
                    for s in slots {
                        push_u64(&mut out, *s);
                    }
                    out.push(u8::from(*consume));
                }
                Frame::StoreOutput {
                    p,
                    slot,
                    ops,
                    busy_ns,
                    data,
                } => {
                    push_u32(&mut out, *p);
                    push_u64(&mut out, *slot);
                    push_u64(&mut out, *ops);
                    push_u64(&mut out, *busy_ns);
                    push_digits_lp(&mut out, data);
                }
                Frame::SendOwned { p, dst, weight, data } => {
                    push_u32(&mut out, *p);
                    push_u32(&mut out, *dst);
                    push_u64(&mut out, *weight);
                    push_digits_lp(&mut out, data);
                }
                Frame::SendSlot {
                    p,
                    dst,
                    weight,
                    slot,
                    range,
                    free_after,
                } => {
                    push_u32(&mut out, *p);
                    push_u32(&mut out, *dst);
                    push_u64(&mut out, *weight);
                    push_u64(&mut out, *slot);
                    match range {
                        Some((a, b)) => {
                            out.push(1);
                            push_u64(&mut out, *a);
                            push_u64(&mut out, *b);
                        }
                        None => out.push(0),
                    }
                    out.push(u8::from(*free_after));
                }
                Frame::Forward { p, src, dst, weight } => {
                    push_u32(&mut out, *p);
                    push_u32(&mut out, *src);
                    push_u32(&mut out, *dst);
                    push_u64(&mut out, *weight);
                }
                Frame::Recv { p, src, slot } => {
                    push_u32(&mut out, *p);
                    push_u32(&mut out, *src);
                    push_u64(&mut out, *slot);
                }
                Frame::BarrierCollect { p }
                | Frame::Ack { p }
                | Frame::Purge { p }
                | Frame::Query { p } => push_u32(&mut out, *p),
                Frame::BarrierRelease { p, clock } | Frame::BarrierClock { p, clock } => {
                    push_u32(&mut out, *p);
                    push_clock(&mut out, clock);
                }
                Frame::Data { p, payload } => {
                    push_u32(&mut out, *p);
                    push_digits_lp(&mut out, payload);
                }
                Frame::Inputs { p, payloads } => {
                    push_u32(&mut out, *p);
                    push_u32(&mut out, payloads.len() as u32);
                    for d in payloads {
                        push_digits_lp(&mut out, d);
                    }
                }
                Frame::Snapshot { p, snap } => {
                    push_u32(&mut out, *p);
                    push_clock(&mut out, &snap.clock);
                    push_u64(&mut out, snap.mem_used);
                    push_u64(&mut out, snap.mem_peak);
                    push_u64(&mut out, snap.total_ops);
                    push_u64(&mut out, snap.sent_words);
                    push_u64(&mut out, snap.sent_msgs);
                    push_u64(&mut out, snap.busy.as_nanos() as u64);
                    match &snap.error {
                        Some(e) => {
                            out.push(1);
                            push_str_lp(&mut out, e);
                        }
                        None => out.push(0),
                    }
                }
                Frame::Net {
                    src,
                    dst,
                    clock,
                    payload,
                } => {
                    push_u32(&mut out, *src);
                    push_u32(&mut out, *dst);
                    push_clock(&mut out, clock);
                    push_digits_lp(&mut out, payload);
                }
            }
            out
        }

        /// Parse one frame body. Rejects bad magic/version, unknown
        /// opcodes, hostile length fields, and trailing garbage.
        pub fn decode(buf: &[u8]) -> Result<Frame> {
            let mut f = FrameCursor::new(buf);
            let magic = f.u32()?;
            ensure!(magic == MAGIC, "bad socket frame magic {magic:#010x}");
            let version = f.u8()?;
            ensure!(version == VERSION, "unsupported socket frame version {version}");
            let op = f.u8()?;
            let frame = match op {
                0x01 => Frame::Hello { group: f.u32()? },
                0x02 => {
                    let procs = f.u32()?;
                    let groups = f.u32()?;
                    let mem_cap = f.u64()?;
                    let base_log2 = f.u8()?;
                    let bounds = read_digits_lp(&mut f)?;
                    Frame::Setup {
                        procs,
                        groups,
                        mem_cap,
                        base_log2,
                        bounds,
                    }
                }
                0x03 => Frame::Listening { addr: f.str_lp()? },
                0x04 => {
                    let n = f.u32()? as usize;
                    ensure!(
                        n <= f.remaining() / 4,
                        "address count {n} exceeds the {} bytes left in the frame",
                        f.remaining()
                    );
                    let mut addrs = Vec::with_capacity(n);
                    for _ in 0..n {
                        addrs.push(f.str_lp()?);
                    }
                    Frame::Go { addrs }
                }
                0x05 => Frame::Ready,
                0x06 => Frame::Shutdown,
                0x07 => Frame::Heartbeat { seq: f.u64()? },
                0x08 => {
                    let group = f.u32()?;
                    let addr = f.str_lp()?;
                    Frame::Reconnect { group, addr }
                }
                0x10 | 0x12 => {
                    let p = f.u32()?;
                    let slot = f.u64()?;
                    let data = read_digits_lp(&mut f)?;
                    if op == 0x10 {
                        Frame::Alloc { p, slot, data }
                    } else {
                        Frame::Replace { p, slot, data }
                    }
                }
                0x11 | 0x13 => {
                    let p = f.u32()?;
                    let slot = f.u64()?;
                    if op == 0x11 {
                        Frame::Free { p, slot }
                    } else {
                        Frame::Read { p, slot }
                    }
                }
                0x14 => {
                    let p = f.u32()?;
                    let ops = f.u64()?;
                    Frame::Compute { p, ops }
                }
                0x15 => {
                    let p = f.u32()?;
                    let ops = f.u64()?;
                    let busy_ns = f.u64()?;
                    Frame::LocalSync { p, ops, busy_ns }
                }
                0x16 => {
                    let p = f.u32()?;
                    let n = f.u32()? as usize;
                    ensure!(
                        n <= f.remaining() / 8,
                        "slot count {n} exceeds the {} bytes left in the frame",
                        f.remaining()
                    );
                    let mut slots = Vec::with_capacity(n);
                    for _ in 0..n {
                        slots.push(f.u64()?);
                    }
                    let consume = read_bool(&mut f)?;
                    Frame::TakeInputs { p, slots, consume }
                }
                0x17 => {
                    let p = f.u32()?;
                    let slot = f.u64()?;
                    let ops = f.u64()?;
                    let busy_ns = f.u64()?;
                    let data = read_digits_lp(&mut f)?;
                    Frame::StoreOutput {
                        p,
                        slot,
                        ops,
                        busy_ns,
                        data,
                    }
                }
                0x18 => {
                    let p = f.u32()?;
                    let dst = f.u32()?;
                    let weight = f.u64()?;
                    let data = read_digits_lp(&mut f)?;
                    Frame::SendOwned { p, dst, weight, data }
                }
                0x19 => {
                    let p = f.u32()?;
                    let dst = f.u32()?;
                    let weight = f.u64()?;
                    let slot = f.u64()?;
                    let range = if read_bool(&mut f)? {
                        let a = f.u64()?;
                        let b = f.u64()?;
                        Some((a, b))
                    } else {
                        None
                    };
                    let free_after = read_bool(&mut f)?;
                    Frame::SendSlot {
                        p,
                        dst,
                        weight,
                        slot,
                        range,
                        free_after,
                    }
                }
                0x1A => {
                    let p = f.u32()?;
                    let src = f.u32()?;
                    let dst = f.u32()?;
                    let weight = f.u64()?;
                    Frame::Forward { p, src, dst, weight }
                }
                0x1B => {
                    let p = f.u32()?;
                    let src = f.u32()?;
                    let slot = f.u64()?;
                    Frame::Recv { p, src, slot }
                }
                0x1C => Frame::BarrierCollect { p: f.u32()? },
                0x1D | 0x24 => {
                    let p = f.u32()?;
                    let clock = read_clock(&mut f)?;
                    if op == 0x1D {
                        Frame::BarrierRelease { p, clock }
                    } else {
                        Frame::BarrierClock { p, clock }
                    }
                }
                0x1E => Frame::Purge { p: f.u32()? },
                0x25 => Frame::HeartbeatAck { seq: f.u64()? },
                0x1F => Frame::Query { p: f.u32()? },
                0x20 => {
                    let p = f.u32()?;
                    let payload = read_digits_lp(&mut f)?;
                    Frame::Data { p, payload }
                }
                0x21 => Frame::Ack { p: f.u32()? },
                0x22 => {
                    let p = f.u32()?;
                    let n = f.u32()? as usize;
                    ensure!(
                        n <= f.remaining() / 4,
                        "payload count {n} exceeds the {} bytes left in the frame",
                        f.remaining()
                    );
                    let mut payloads = Vec::with_capacity(n);
                    for _ in 0..n {
                        payloads.push(read_digits_lp(&mut f)?);
                    }
                    Frame::Inputs { p, payloads }
                }
                0x23 => {
                    let p = f.u32()?;
                    let clock = read_clock(&mut f)?;
                    let mem_used = f.u64()?;
                    let mem_peak = f.u64()?;
                    let total_ops = f.u64()?;
                    let sent_words = f.u64()?;
                    let sent_msgs = f.u64()?;
                    let busy = Duration::from_nanos(f.u64()?);
                    let error = if read_bool(&mut f)? {
                        Some(f.str_lp()?)
                    } else {
                        None
                    };
                    Frame::Snapshot {
                        p,
                        snap: WorkerSnapshot {
                            clock,
                            mem_used,
                            mem_peak,
                            total_ops,
                            sent_words,
                            sent_msgs,
                            busy,
                            error,
                        },
                    }
                }
                0x30 => Frame::PeerHello { group: f.u32()? },
                0x31 => {
                    let src = f.u32()?;
                    let dst = f.u32()?;
                    let clock = read_clock(&mut f)?;
                    let payload = read_digits_lp(&mut f)?;
                    Frame::Net {
                        src,
                        dst,
                        clock,
                        payload,
                    }
                }
                other => bail!("unknown socket frame opcode {other:#04x}"),
            };
            f.expect_end()?;
            Ok(frame)
        }
    }

    /// Length-prefix and serialize one frame (the bytes `read_frame`
    /// expects on the wire).
    pub fn frame_bytes(frame: &Frame) -> Vec<u8> {
        let body = frame.encode();
        let mut out = Vec::with_capacity(body.len() + 4);
        push_u32(&mut out, body.len() as u32);
        out.extend_from_slice(&body);
        out
    }

    /// Write one length-prefixed frame and flush.
    pub fn write_frame(w: &mut impl Write, frame: &Frame) -> Result<()> {
        w.write_all(&frame_bytes(frame))?;
        w.flush()?;
        Ok(())
    }

    /// Read one length-prefixed frame. The length prefix is validated
    /// against [`MAX_FRAME`] before the body buffer is allocated.
    pub fn read_frame(r: &mut impl Read) -> Result<Frame> {
        let mut len4 = [0u8; 4];
        r.read_exact(&mut len4)?;
        let len = u32::from_le_bytes(len4) as usize;
        ensure!(len <= MAX_FRAME, "socket frame length {len} exceeds the {MAX_FRAME}-byte cap");
        let mut body = vec![0u8; len];
        r.read_exact(&mut body)?;
        Frame::decode(&body)
    }
}

// ---------------------------------------------------------------------
// Transport: Unix-domain sockets by default, TCP loopback behind a
// flag (and the fallback on platforms without UDS).
// ---------------------------------------------------------------------

/// Which socket family carries the engine's links.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SocketTransport {
    /// Unix-domain sockets in a per-machine scratch directory.
    Unix,
    /// TCP on 127.0.0.1 (ephemeral ports).
    Tcp,
}

/// One connected link of either family.
enum Stream {
    #[cfg(unix)]
    Unix(std::os::unix::net::UnixStream),
    Tcp(std::net::TcpStream),
}

impl Stream {
    fn connect(addr: &str) -> Result<Stream> {
        if let Some(path) = addr.strip_prefix("unix:") {
            #[cfg(unix)]
            return Ok(Stream::Unix(std::os::unix::net::UnixStream::connect(path)?));
            #[cfg(not(unix))]
            bail!("unix socket address {path:?} on a platform without UDS");
        }
        if let Some(hostport) = addr.strip_prefix("tcp:") {
            return Ok(Stream::Tcp(std::net::TcpStream::connect(hostport)?));
        }
        bail!("unrecognized socket address {addr:?}")
    }

    fn try_clone(&self) -> Result<Stream> {
        Ok(match self {
            #[cfg(unix)]
            Stream::Unix(s) => Stream::Unix(s.try_clone()?),
            Stream::Tcp(s) => Stream::Tcp(s.try_clone()?),
        })
    }

    fn set_read_timeout(&self, t: Option<Duration>) -> Result<()> {
        match self {
            #[cfg(unix)]
            Stream::Unix(s) => s.set_read_timeout(t)?,
            Stream::Tcp(s) => s.set_read_timeout(t)?,
        }
        Ok(())
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
            Stream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
            Stream::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
            Stream::Tcp(s) => s.flush(),
        }
    }
}

/// A bound accept socket of either family.
enum Listener {
    #[cfg(unix)]
    Unix(std::os::unix::net::UnixListener),
    Tcp(std::net::TcpListener),
}

impl Listener {
    /// Bind under `dir` (UDS) or on an ephemeral loopback port (TCP);
    /// returns the listener and the address string peers connect to.
    fn bind(transport: SocketTransport, dir: &Path, name: &str) -> Result<(Listener, String)> {
        match transport {
            #[cfg(unix)]
            SocketTransport::Unix => {
                let path = dir.join(format!("{name}.sock"));
                let l = std::os::unix::net::UnixListener::bind(&path)?;
                let addr = format!("unix:{}", path.display());
                Ok((Listener::Unix(l), addr))
            }
            #[cfg(not(unix))]
            SocketTransport::Unix => Listener::bind(SocketTransport::Tcp, dir, name),
            SocketTransport::Tcp => {
                let l = std::net::TcpListener::bind(("127.0.0.1", 0))?;
                let addr = format!("tcp:{}", l.local_addr()?);
                Ok((Listener::Tcp(l), addr))
            }
        }
    }

    fn set_nonblocking(&self, nb: bool) -> Result<()> {
        match self {
            #[cfg(unix)]
            Listener::Unix(l) => l.set_nonblocking(nb)?,
            Listener::Tcp(l) => l.set_nonblocking(nb)?,
        }
        Ok(())
    }

    /// One non-blocking accept attempt: `Ok(None)` means nothing is
    /// queued yet.
    fn accept_once(&self) -> Result<Option<Stream>> {
        let out = match self {
            #[cfg(unix)]
            Listener::Unix(l) => l.accept().map(|(s, _)| Stream::Unix(s)),
            Listener::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
        };
        match out {
            Ok(s) => Ok(Some(s)),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(None),
            Err(e) => Err(e.into()),
        }
    }

    /// Accept one connection, polling until `deadline` so a worker
    /// that never comes up fails the handshake instead of hanging it.
    fn accept_deadline(&self, deadline: Instant) -> Result<Stream> {
        self.set_nonblocking(true)?;
        let out = loop {
            match self.accept_once() {
                Ok(Some(s)) => break Ok(s),
                Ok(None) => {
                    if Instant::now() >= deadline {
                        break Err(anyhow!("timed out waiting for a socket connection"));
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => break Err(e),
            }
        };
        let _ = self.set_nonblocking(false);
        let s = out?;
        // Accepted sockets inherit non-blocking mode on some platforms.
        match &s {
            #[cfg(unix)]
            Stream::Unix(u) => u.set_nonblocking(false)?,
            Stream::Tcp(t) => t.set_nonblocking(false)?,
        }
        Ok(s)
    }
}

// ---------------------------------------------------------------------
// Configuration and worker-binary resolution.
// ---------------------------------------------------------------------

/// Socket-engine knobs. [`Default`] reads the `COPMUL_SOCKET_*`
/// environment; pass an explicit config from tests to avoid env races.
#[derive(Clone, Debug)]
pub struct SocketConfig {
    /// Worker processes to spawn; each owns a contiguous block of
    /// processors. `0` = auto (`min(procs, 2)`). Env:
    /// `COPMUL_SOCKET_GROUPS`.
    pub groups: usize,
    /// Socket family (env: `COPMUL_SOCKET_TCP=1` for TCP).
    pub transport: SocketTransport,
    /// Upper bound on any single reply wait, so a killed worker fails
    /// the call instead of hanging it (env: `COPMUL_SOCKET_TIMEOUT_MS`).
    /// Must be positive; `with_config` rejects zero.
    pub reply_timeout: Duration,
    /// Liveness-probe cadence on the control plane: the host sends a
    /// `Heartbeat` frame per link per tick and marks a group dead after
    /// three unanswered ticks. `Duration::ZERO` (the default) disables
    /// the pump — reader-EOF detection still covers process death.
    /// Env: `COPMUL_SOCKET_HEARTBEAT_MS`.
    pub heartbeat_interval: Duration,
    /// Base delay of the jittered exponential backoff between
    /// [`SocketMachine::respawn_group`] attempts (doubles per retry).
    /// Env: `COPMUL_SOCKET_RESPAWN_BACKOFF_MS`.
    pub respawn_backoff: Duration,
    /// Worker executable; `None` resolves via `COPMUL_WORKER_BIN`,
    /// then the current executable and its sibling directories.
    pub worker_bin: Option<PathBuf>,
}

impl Default for SocketConfig {
    fn default() -> Self {
        let groups = std::env::var("COPMUL_SOCKET_GROUPS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0);
        let transport = if std::env::var("COPMUL_SOCKET_TCP").as_deref() == Ok("1") {
            SocketTransport::Tcp
        } else {
            SocketTransport::Unix
        };
        let ms_env = |name: &str| {
            std::env::var(name)
                .ok()
                .and_then(|v| v.parse().ok())
                .map(Duration::from_millis)
        };
        let reply_timeout = ms_env("COPMUL_SOCKET_TIMEOUT_MS").unwrap_or(Duration::from_secs(30));
        let heartbeat_interval = ms_env("COPMUL_SOCKET_HEARTBEAT_MS").unwrap_or(Duration::ZERO);
        let respawn_backoff =
            ms_env("COPMUL_SOCKET_RESPAWN_BACKOFF_MS").unwrap_or(Duration::from_millis(50));
        SocketConfig {
            groups,
            transport,
            reply_timeout,
            heartbeat_interval,
            respawn_backoff,
            worker_bin: None,
        }
    }
}

/// Locate the `copmul` binary that serves as the worker executable.
/// Test harness binaries live in `target/<profile>/deps/`, so the real
/// binary is probed next to the current executable and one directory
/// up; integration tests pass `env!("CARGO_BIN_EXE_copmul")` through
/// [`SocketConfig::worker_bin`] instead.
pub fn resolve_worker_bin(cfg: &SocketConfig) -> Option<PathBuf> {
    if let Some(p) = &cfg.worker_bin {
        return Some(p.clone());
    }
    if let Ok(p) = std::env::var("COPMUL_WORKER_BIN") {
        return Some(PathBuf::from(p));
    }
    let exe = std::env::current_exe().ok()?;
    if exe.file_stem().map(|s| s == "copmul").unwrap_or(false) {
        return Some(exe);
    }
    let dirs = [exe.parent(), exe.parent().and_then(Path::parent)];
    for dir in dirs.into_iter().flatten() {
        for name in ["copmul", "copmul.exe"] {
            let cand = dir.join(name);
            if cand.is_file() {
                return Some(cand);
            }
        }
    }
    None
}

/// Whether this host can run the socket engine at all (a worker
/// binary is resolvable). The differential tests use this to skip the
/// socket leg unless `COPMUL_ENGINE_MATRIX` demands it.
pub fn socket_available() -> bool {
    resolve_worker_bin(&SocketConfig::default()).is_some()
}

/// Even contiguous split of `procs` processors over `groups` worker
/// processes: group `g` owns `[bounds[g], bounds[g+1])`.
pub(crate) fn group_bounds(procs: usize, groups: usize) -> Vec<usize> {
    (0..=groups).map(|g| g * procs / groups).collect()
}

fn group_of_bounds(bounds: &[usize], p: usize) -> usize {
    (0..bounds.len() - 1)
        .find(|&g| p < bounds[g + 1])
        .expect("processor within group bounds")
}

/// Per-machine scratch directory for UDS paths.
fn scratch_dir() -> Result<PathBuf> {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let n = SEQ.fetch_add(1, Ordering::SeqCst);
    let dir = std::env::temp_dir().join(format!("copmul-sock-{}-{n}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    Ok(dir)
}

// ---------------------------------------------------------------------
// Host side: the coordinator-resident engine.
// ---------------------------------------------------------------------

/// A reply the host is waiting for, queued per processor in command
/// order (workers answer their queue in order, so reply order matches).
enum Pending {
    Data(Sender<Vec<u32>>),
    /// `local` runs host-side; the worker's `Ack` releases the value
    /// at the correct queue point.
    Local {
        value: Option<Box<dyn Any + Send>>,
        tx: Sender<Box<dyn Any + Send>>,
    },
    Inputs(Sender<Vec<Vec<u32>>>),
    Snapshot(Sender<WorkerSnapshot>),
    Barrier(Sender<Clock>),
}

type PendingQueues = Arc<Vec<Mutex<VecDeque<Pending>>>>;

/// Host endpoint of one worker process's control stream.
struct GroupLink {
    /// Pre-framed bytes to the writer thread; `None` once finished.
    tx: Option<Sender<Vec<u8>>>,
    /// Set on writer error or reader EOF — i.e. the process is gone.
    dead: Arc<AtomicBool>,
    writer: Option<JoinHandle<()>>,
    reader: Option<JoinHandle<()>>,
}

/// Fail every call still waiting on a dead group's processors: their
/// reply senders are dropped, so the waiters' `recv` fails immediately.
fn drain_pending(pending: &PendingQueues, range: &std::ops::Range<usize>) {
    for p in range.clone() {
        pending[p].lock().unwrap().clear();
    }
}

fn writer_loop(
    mut stream: Stream,
    rx: Receiver<Vec<u8>>,
    dead: Arc<AtomicBool>,
    range: std::ops::Range<usize>,
    pending: PendingQueues,
) {
    while let Ok(buf) = rx.recv() {
        if stream.write_all(&buf).and_then(|_| stream.flush()).is_err() {
            dead.store(true, Ordering::SeqCst);
            drain_pending(&pending, &range);
            return;
        }
    }
}

/// Deliver one reply frame to the pending entry at the front of its
/// processor's queue. Any mismatch is a protocol violation and tears
/// the link down.
fn fulfill(frame: wire::Frame, range: &std::ops::Range<usize>, pending: &PendingQueues) -> bool {
    let p = match &frame {
        wire::Frame::Data { p, .. }
        | wire::Frame::Ack { p }
        | wire::Frame::Inputs { p, .. }
        | wire::Frame::Snapshot { p, .. }
        | wire::Frame::BarrierClock { p, .. } => *p as usize,
        _ => return false,
    };
    if !range.contains(&p) {
        return false;
    }
    let entry = pending[p].lock().unwrap().pop_front();
    match (frame, entry) {
        (wire::Frame::Data { payload, .. }, Some(Pending::Data(tx))) => {
            let _ = tx.send(payload);
            true
        }
        (wire::Frame::Ack { .. }, Some(Pending::Local { mut value, tx })) => {
            if let Some(v) = value.take() {
                let _ = tx.send(v);
            }
            true
        }
        (wire::Frame::Inputs { payloads, .. }, Some(Pending::Inputs(tx))) => {
            let _ = tx.send(payloads);
            true
        }
        (wire::Frame::Snapshot { snap, .. }, Some(Pending::Snapshot(tx))) => {
            let _ = tx.send(snap);
            true
        }
        (wire::Frame::BarrierClock { clock, .. }, Some(Pending::Barrier(tx))) => {
            let _ = tx.send(clock);
            true
        }
        _ => false,
    }
}

fn reader_loop(
    mut stream: Stream,
    range: std::ops::Range<usize>,
    pending: PendingQueues,
    dead: Arc<AtomicBool>,
    hb_acked: Arc<AtomicU64>,
) {
    loop {
        match wire::read_frame(&mut stream) {
            Ok(wire::Frame::HeartbeatAck { seq }) => {
                hb_acked.fetch_max(seq, Ordering::SeqCst);
            }
            Ok(frame) => {
                if !fulfill(frame, &range, &pending) {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    // EOF (worker exit or kill) or a corrupt link: the group is gone.
    dead.store(true, Ordering::SeqCst);
    drain_pending(&pending, &range);
}

/// Host-side heartbeat bookkeeping for one group link, shared with the
/// pump thread (the machine swaps an entry on respawn).
struct HbSlot {
    tx: Sender<Vec<u8>>,
    dead: Arc<AtomicBool>,
    /// Last heartbeat seq sent / last ack seen on this link.
    sent: Arc<AtomicU64>,
    acked: Arc<AtomicU64>,
    range: std::ops::Range<usize>,
}

type HbSlots = Arc<Mutex<Vec<HbSlot>>>;

/// Number of unanswered heartbeat ticks before a link is declared dead.
const HB_GRACE_TICKS: u64 = 3;

/// The heartbeat pump: one thread per machine, ticking every
/// `interval`. A link whose acks lag `HB_GRACE_TICKS` behind its sends
/// is marked dead and its pending calls drained — the liveness backstop
/// for a worker that is connected but wedged (reader EOF never fires).
fn heartbeat_pump(slots: HbSlots, pending: PendingQueues, interval: Duration, stop: Arc<AtomicBool>) {
    let mut seq = 0u64;
    'pump: loop {
        // Sleep in small slices so stop requests are honored promptly.
        let wake = Instant::now() + interval;
        while Instant::now() < wake {
            if stop.load(Ordering::SeqCst) {
                break 'pump;
            }
            std::thread::sleep(Duration::from_millis(5).min(interval));
        }
        seq += 1;
        for slot in slots.lock().unwrap().iter() {
            if slot.dead.load(Ordering::SeqCst) {
                continue;
            }
            let sent = slot.sent.load(Ordering::SeqCst);
            let acked = slot.acked.load(Ordering::SeqCst);
            if sent > 0 && sent.saturating_sub(acked) >= HB_GRACE_TICKS {
                slot.dead.store(true, Ordering::SeqCst);
                drain_pending(&pending, &slot.range);
                continue;
            }
            slot.sent.store(seq, Ordering::SeqCst);
            let _ = slot
                .tx
                .send(wire::frame_bytes(&wire::Frame::Heartbeat { seq }));
        }
    }
}

/// Spawn the writer + reader threads for one freshly-handshaken group
/// stream and return its link plus heartbeat slot.
fn spawn_link(
    s: Stream,
    range: std::ops::Range<usize>,
    pending: &PendingQueues,
) -> Result<(GroupLink, HbSlot)> {
    s.set_read_timeout(None)?;
    let dead = Arc::new(AtomicBool::new(false));
    let sent = Arc::new(AtomicU64::new(0));
    let acked = Arc::new(AtomicU64::new(0));
    let (tx, rx) = channel::<Vec<u8>>();
    let wstream = s.try_clone()?;
    let writer = {
        let dead = Arc::clone(&dead);
        let range = range.clone();
        let pending = Arc::clone(pending);
        std::thread::spawn(move || writer_loop(wstream, rx, dead, range, pending))
    };
    let reader = {
        let dead = Arc::clone(&dead);
        let pending = Arc::clone(pending);
        let range = range.clone();
        let acked = Arc::clone(&acked);
        std::thread::spawn(move || reader_loop(s, range, pending, dead, acked))
    };
    let hb = HbSlot {
        tx: tx.clone(),
        dead: Arc::clone(&dead),
        sent,
        acked,
        range,
    };
    Ok((
        GroupLink {
            tx: Some(tx),
            dead,
            writer: Some(writer),
            reader: Some(reader),
        },
        hb,
    ))
}

/// The real-network execution engine (see module docs).
pub struct SocketMachine {
    base: Base,
    mem_cap: u64,
    topo: TopologyRef,
    procs: usize,
    cfg: SocketConfig,
    /// Group boundaries: group `g` owns `[bounds[g], bounds[g+1])`.
    bounds: Vec<usize>,
    /// Per-processor next slot id (dense worker-arena indices).
    next_slot: Vec<Slot>,
    links: Vec<GroupLink>,
    pending: PendingQueues,
    children: Mutex<Vec<Option<Child>>>,
    /// Commands issued so far — the deterministic trigger for
    /// [`SocketMachine::arm_kill`].
    cmds_issued: AtomicU64,
    /// `(group, fire_at_command_count)` for a pending seeded kill.
    kill_plan: Mutex<Option<(usize, u64)>>,
    dir: PathBuf,
    started: Instant,
    /// The host accept socket, kept open past boot so respawned
    /// workers can re-handshake on the same address.
    listener: Listener,
    host_addr: String,
    /// Current peer-listener address per group (refreshed on respawn).
    peer_addrs: Vec<String>,
    hb_slots: HbSlots,
    hb_stop: Option<Arc<AtomicBool>>,
    hb_handle: Option<JoinHandle<()>>,
    respawns: AtomicU64,
}

impl SocketMachine {
    /// Spawn worker processes modelling `p` processors with `mem_cap`
    /// words of local memory each, on the default fully-connected
    /// interconnect. Unlike the in-process engines this can fail:
    /// process spawn or the socket handshake may be refused.
    pub fn new(p: usize, mem_cap: u64, base: Base) -> Result<Self> {
        SocketMachine::with_topology(p, mem_cap, base, Arc::new(FullyConnected))
    }

    /// Effectively unbounded local memories (MI execution mode).
    pub fn unbounded(p: usize, base: Base) -> Result<Self> {
        SocketMachine::new(p, u64::MAX / 2, base)
    }

    /// [`SocketMachine::new`] on an explicit network topology: relayed
    /// hops run through the relay processors' command loops exactly as
    /// on the threaded engine.
    pub fn with_topology(p: usize, mem_cap: u64, base: Base, topo: TopologyRef) -> Result<Self> {
        SocketMachine::with_config(p, mem_cap, base, topo, SocketConfig::default())
    }

    /// Fully explicit constructor.
    pub fn with_config(
        p: usize,
        mem_cap: u64,
        base: Base,
        topo: TopologyRef,
        cfg: SocketConfig,
    ) -> Result<Self> {
        assert!(p >= 1, "need at least one processor");
        ensure!(
            cfg.reply_timeout > Duration::ZERO,
            "socket reply timeout must be positive (a 0 timeout would fail every reply wait \
             instantly); set --socket-timeout-ms / COPMUL_SOCKET_TIMEOUT_MS to a positive value"
        );
        let dir = scratch_dir()?;
        let mut children: Vec<Option<Child>> = Vec::new();
        match SocketMachine::boot(p, mem_cap, base, topo, cfg, &dir, &mut children) {
            Ok(m) => Ok(m),
            Err(e) => {
                for c in children.iter_mut().filter_map(Option::as_mut) {
                    let _ = c.kill();
                    let _ = c.wait();
                }
                let _ = std::fs::remove_dir_all(&dir);
                Err(e)
            }
        }
    }

    /// Spawn + handshake. Children spawned so far are pushed into
    /// `children` as we go, so the caller can reap them on error.
    fn boot(
        procs: usize,
        mem_cap: u64,
        base: Base,
        topo: TopologyRef,
        cfg: SocketConfig,
        dir: &Path,
        children: &mut Vec<Option<Child>>,
    ) -> Result<SocketMachine> {
        let groups = if cfg.groups == 0 {
            procs.min(2)
        } else {
            cfg.groups.min(procs)
        };
        let bounds = group_bounds(procs, groups);
        let bin = resolve_worker_bin(&cfg).ok_or_else(|| {
            anyhow!("cannot locate the copmul worker binary (set COPMUL_WORKER_BIN)")
        })?;
        let (listener, host_addr) = Listener::bind(cfg.transport, dir, "host")?;
        for g in 0..groups {
            let child = Command::new(&bin)
                .arg("--socket-worker")
                .env("COPMUL_SOCKET_HOST", &host_addr)
                .env("COPMUL_SOCKET_GROUP", g.to_string())
                .env("COPMUL_SOCKET_DIR", dir)
                .stdin(Stdio::null())
                .stdout(Stdio::null())
                .stderr(Stdio::inherit())
                .spawn()
                .map_err(|e| anyhow!("spawning socket worker {g} ({}): {e}", bin.display()))?;
            children.push(Some(child));
        }
        // Accept each worker and identify it by its Hello.
        let deadline = Instant::now() + Duration::from_secs(10);
        let mut slots: Vec<Option<Stream>> = (0..groups).map(|_| None).collect();
        for _ in 0..groups {
            let mut s = listener.accept_deadline(deadline)?;
            s.set_read_timeout(Some(Duration::from_secs(10)))?;
            match wire::read_frame(&mut s)? {
                wire::Frame::Hello { group } => {
                    let g = group as usize;
                    ensure!(
                        g < groups && slots[g].is_none(),
                        "bad worker hello (group {group})"
                    );
                    slots[g] = Some(s);
                }
                other => bail!("expected Hello during handshake, got {other:?}"),
            }
        }
        let mut streams: Vec<Stream> = slots
            .into_iter()
            .map(|s| s.expect("all groups connected"))
            .collect();
        let setup = wire::Frame::Setup {
            procs: procs as u32,
            groups: groups as u32,
            mem_cap,
            base_log2: base.log2 as u8,
            bounds: bounds.iter().map(|&b| b as u32).collect(),
        };
        for s in &mut streams {
            wire::write_frame(s, &setup)?;
        }
        let mut peer_addrs = vec![String::new(); groups];
        for (g, s) in streams.iter_mut().enumerate() {
            match wire::read_frame(s)? {
                wire::Frame::Listening { addr } => peer_addrs[g] = addr,
                other => bail!("expected Listening from worker {g}, got {other:?}"),
            }
        }
        let go = wire::Frame::Go {
            addrs: peer_addrs.clone(),
        };
        for s in &mut streams {
            wire::write_frame(s, &go)?;
        }
        for (g, s) in streams.iter_mut().enumerate() {
            match wire::read_frame(s)? {
                wire::Frame::Ready => {}
                other => bail!("expected Ready from worker {g}, got {other:?}"),
            }
        }
        // Steady state: per-group writer + reader threads.
        let pending: PendingQueues =
            Arc::new((0..procs).map(|_| Mutex::new(VecDeque::new())).collect());
        let mut links = Vec::with_capacity(groups);
        let mut hb = Vec::with_capacity(groups);
        for (g, s) in streams.into_iter().enumerate() {
            let (link, slot) = spawn_link(s, bounds[g]..bounds[g + 1], &pending)?;
            links.push(link);
            hb.push(slot);
        }
        let hb_slots: HbSlots = Arc::new(Mutex::new(hb));
        let (hb_stop, hb_handle) = if cfg.heartbeat_interval > Duration::ZERO {
            let stop = Arc::new(AtomicBool::new(false));
            let handle = {
                let slots = Arc::clone(&hb_slots);
                let pending = Arc::clone(&pending);
                let interval = cfg.heartbeat_interval;
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || heartbeat_pump(slots, pending, interval, stop))
            };
            (Some(stop), Some(handle))
        } else {
            (None, None)
        };
        Ok(SocketMachine {
            base,
            mem_cap,
            topo,
            procs,
            cfg,
            bounds,
            next_slot: vec![1; procs],
            links,
            pending,
            children: Mutex::new(std::mem::take(children)),
            cmds_issued: AtomicU64::new(0),
            kill_plan: Mutex::new(None),
            dir: dir.to_path_buf(),
            started: Instant::now(),
            listener,
            host_addr,
            peer_addrs,
            hb_slots,
            hb_stop,
            hb_handle,
            respawns: AtomicU64::new(0),
        })
    }

    fn group_of(&self, p: ProcId) -> usize {
        debug_assert!(p < self.procs);
        group_of_bounds(&self.bounds, p)
    }

    /// Count one issued command and fire a pending armed kill when its
    /// trigger count is reached.
    fn tick(&self) {
        let n = self.cmds_issued.fetch_add(1, Ordering::SeqCst) + 1;
        let fire = {
            let mut plan = self.kill_plan.lock().unwrap();
            match *plan {
                Some((g, at)) if n >= at => {
                    *plan = None;
                    Some(g)
                }
                _ => None,
            }
        };
        if let Some(g) = fire {
            let _ = self.kill_worker(g);
        }
    }

    /// Enqueue one command frame on `p`'s group link. Returns an error
    /// when the worker process is dead — the socket twin of the
    /// threaded engine's "worker thread died".
    fn post(&self, p: ProcId, frame: &wire::Frame) -> Result<()> {
        self.tick();
        let g = self.group_of(p);
        let link = &self.links[g];
        if link.dead.load(Ordering::SeqCst) {
            bail!("processor {p}: worker process (group {g}) is dead");
        }
        let tx = link
            .tx
            .as_ref()
            .ok_or_else(|| anyhow!("socket engine already finished"))?;
        tx.send(wire::frame_bytes(frame))
            .map_err(|_| anyhow!("processor {p}: worker process (group {g}) is dead"))
    }

    /// [`SocketMachine::post`] for commands that expect a reply: the
    /// pending entry is queued first so the reader can never race it.
    fn post_with_reply(&self, p: ProcId, frame: &wire::Frame, entry: Pending) -> Result<()> {
        self.pending[p].lock().unwrap().push_back(entry);
        if let Err(e) = self.post(p, frame) {
            self.pending[p].lock().unwrap().pop_back();
            return Err(e);
        }
        Ok(())
    }

    fn fresh_slot(&mut self, p: ProcId) -> Slot {
        let s = self.next_slot[p];
        self.next_slot[p] += 1;
        s
    }

    /// Bounded reply wait (a dead worker fails the call, never hangs it).
    pub fn reply_timeout(&self) -> Duration {
        self.cfg.reply_timeout
    }

    /// Number of worker processes.
    pub fn n_groups(&self) -> usize {
        self.links.len()
    }

    /// OS pids of the live worker processes (`None` = exited/reaped).
    pub fn worker_pids(&self) -> Vec<Option<u32>> {
        self.children
            .lock()
            .unwrap()
            .iter()
            .map(|c| c.as_ref().map(Child::id))
            .collect()
    }

    /// Kill group `g`'s worker process now (SIGKILL on unix) — the
    /// kill-chaos tests' real-fault injector.
    pub fn kill_worker(&self, g: usize) -> Result<()> {
        {
            let mut kids = self.children.lock().unwrap();
            match kids.get_mut(g).and_then(Option::take) {
                Some(mut c) => {
                    let _ = c.kill();
                    let _ = c.wait();
                }
                None => bail!("group {g}: no live worker process"),
            }
        }
        self.links[g].dead.store(true, Ordering::SeqCst);
        drain_pending(&self.pending, &(self.bounds[g]..self.bounds[g + 1]));
        Ok(())
    }

    /// Arm a deterministic kill: group `g` dies once `after_cmds` more
    /// commands have been issued (seeded chaos schedules replayable by
    /// construction).
    pub fn arm_kill(&self, g: usize, after_cmds: u64) {
        let at = self.cmds_issued.load(Ordering::SeqCst) + after_cmds.max(1);
        *self.kill_plan.lock().unwrap() = Some((g, at));
    }

    /// Groups whose control links are currently dead.
    pub fn dead_groups(&self) -> Vec<usize> {
        (0..self.links.len())
            .filter(|&g| self.links[g].dead.load(Ordering::SeqCst))
            .collect()
    }

    /// Successful [`SocketMachine::respawn_group`] calls so far.
    pub fn respawns(&self) -> u64 {
        self.respawns.load(Ordering::Relaxed)
    }

    /// Re-spawn a dead group's worker process and splice it back into
    /// the machine: replay the Hello/Setup/Listening/Go/Ready handshake
    /// on the original host listener, tell every live worker to dial
    /// the fresh peer listener (`Reconnect` frames; the rejoining
    /// worker accepts one `PeerHello` per live peer), and stand up new
    /// writer/reader threads with a fresh liveness flag. The group's
    /// processors come back with empty arenas and zeroed clocks — the
    /// scheduler's probation canary re-validates them before any client
    /// job lands there. Retries with jittered exponential backoff
    /// ([`SocketConfig::respawn_backoff`], doubling per attempt).
    pub fn respawn_group(&mut self, g: usize) -> Result<()> {
        ensure!(g < self.links.len(), "group {g}: no such worker group");
        ensure!(
            self.links[g].dead.load(Ordering::SeqCst),
            "group {g}: worker is alive (respawn only replaces dead groups)"
        );
        // Reap whatever is left of the old process so a wedged-but-live
        // worker cannot race its replacement.
        if let Some(mut c) = self
            .children
            .lock()
            .unwrap()
            .get_mut(g)
            .and_then(Option::take)
        {
            let _ = c.kill();
            let _ = c.wait();
        }
        let mut delay = self.cfg.respawn_backoff.max(Duration::from_millis(1));
        const ATTEMPTS: u32 = 4;
        let mut last = None;
        for attempt in 0..ATTEMPTS {
            match self.try_respawn(g) {
                Ok(()) => {
                    self.respawns.fetch_add(1, Ordering::Relaxed);
                    return Ok(());
                }
                Err(e) => last = Some(e),
            }
            if attempt + 1 < ATTEMPTS {
                // Jitter in [0.5, 1.5) from a deterministic hash of
                // (group, attempt) — no wall clock or OS randomness, so
                // chaos schedules stay replayable.
                let jitter = 50 + (g as u64 * 7 + attempt as u64 * 13) % 101;
                std::thread::sleep(delay.mul_f64(jitter as f64 / 100.0));
                delay *= 2;
            }
        }
        Err(last.unwrap_or_else(|| anyhow!("group {g}: respawn failed")))
    }

    /// One respawn attempt: spawn, handshake, splice. Any failure reaps
    /// the half-born child and leaves the group dead.
    fn try_respawn(&mut self, g: usize) -> Result<()> {
        let bin = resolve_worker_bin(&self.cfg).ok_or_else(|| {
            anyhow!("cannot locate the copmul worker binary (set COPMUL_WORKER_BIN)")
        })?;
        let live: Vec<usize> = (0..self.links.len())
            .filter(|&h| h != g && !self.links[h].dead.load(Ordering::SeqCst))
            .collect();
        let mut child = Command::new(&bin)
            .arg("--socket-worker")
            .env("COPMUL_SOCKET_HOST", &self.host_addr)
            .env("COPMUL_SOCKET_GROUP", g.to_string())
            .env("COPMUL_SOCKET_DIR", &self.dir)
            .env("COPMUL_SOCKET_REJOIN", live.len().to_string())
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::inherit())
            .spawn()
            .map_err(|e| anyhow!("respawning socket worker {g} ({}): {e}", bin.display()))?;
        let handshake = (|| -> Result<Stream> {
            let deadline = Instant::now() + Duration::from_secs(10);
            let mut s = self.listener.accept_deadline(deadline)?;
            s.set_read_timeout(Some(Duration::from_secs(10)))?;
            match wire::read_frame(&mut s)? {
                wire::Frame::Hello { group } if group as usize == g => {}
                other => bail!("expected Hello({g}) during respawn, got {other:?}"),
            }
            let setup = wire::Frame::Setup {
                procs: self.procs as u32,
                groups: self.links.len() as u32,
                mem_cap: self.mem_cap,
                base_log2: self.base.log2 as u8,
                bounds: self.bounds.iter().map(|&b| b as u32).collect(),
            };
            wire::write_frame(&mut s, &setup)?;
            let addr = match wire::read_frame(&mut s)? {
                wire::Frame::Listening { addr } => addr,
                other => bail!("expected Listening from respawned worker {g}, got {other:?}"),
            };
            self.peer_addrs[g] = addr.clone();
            // Live workers dial the fresh peer listener; the rejoining
            // worker accepts exactly `live.len()` PeerHellos before
            // reporting Ready.
            for &h in &live {
                if let Some(tx) = self.links[h].tx.as_ref() {
                    let _ = tx.send(wire::frame_bytes(&wire::Frame::Reconnect {
                        group: g as u32,
                        addr: addr.clone(),
                    }));
                }
            }
            wire::write_frame(
                &mut s,
                &wire::Frame::Go {
                    addrs: self.peer_addrs.clone(),
                },
            )?;
            match wire::read_frame(&mut s)? {
                wire::Frame::Ready => {}
                other => bail!("expected Ready from respawned worker {g}, got {other:?}"),
            }
            Ok(s)
        })();
        let range = self.bounds[g]..self.bounds[g + 1];
        let spliced = handshake.and_then(|s| {
            drain_pending(&self.pending, &range);
            spawn_link(s, range.clone(), &self.pending)
        });
        match spliced {
            Ok((link, hb)) => {
                for p in range {
                    self.next_slot[p] = 1;
                }
                self.links[g] = link;
                self.hb_slots.lock().unwrap()[g] = hb;
                self.children.lock().unwrap()[g] = Some(child);
                Ok(())
            }
            Err(e) => {
                let _ = child.kill();
                let _ = child.wait();
                Err(e)
            }
        }
    }

    /// Stop the heartbeat pump (finish/Drop teardown).
    fn stop_heartbeat(&mut self) {
        if let Some(stop) = self.hb_stop.take() {
            stop.store(true, Ordering::SeqCst);
        }
        if let Some(h) = self.hb_handle.take() {
            let _ = h.join();
        }
    }

    // ----- two-phase (enqueue now, await later) variants --------------
    //
    // Same contract as the threaded engine's: the scheduler's shard
    // view enqueues under its machine lock and awaits after releasing
    // it. Program order is fixed at enqueue time.

    /// Enqueue a read; the reply channel delivers the slot's digits.
    /// If the worker process is dead the entry is dropped and the
    /// receiver's `recv` fails.
    pub fn read_request(&self, p: ProcId, slot: Slot) -> Receiver<Vec<u32>> {
        let (tx, rx) = channel();
        let frame = wire::Frame::Read { p: p as u32, slot };
        let _ = self.post_with_reply(p, &frame, Pending::Data(tx));
        rx
    }

    /// Run `f` host-side now (closures cannot cross the process
    /// boundary), charge its ops on worker `p` at this queue point,
    /// and deliver the boxed result once the worker acknowledges.
    pub fn local_request<R, F>(&self, p: ProcId, f: F) -> Receiver<Box<dyn Any + Send>>
    where
        R: Send + 'static,
        F: FnOnce(&Base, &mut Ops) -> R + Send + 'static,
    {
        let (tx, rx) = channel();
        let t0 = Instant::now();
        let mut ops = Ops::default();
        let out: Box<dyn Any + Send> = Box::new(f(&self.base, &mut ops));
        let busy_ns = t0.elapsed().as_nanos() as u64;
        let frame = wire::Frame::LocalSync {
            p: p as u32,
            ops: ops.get(),
            busy_ns,
        };
        let entry = Pending::Local {
            value: Some(out),
            tx,
        };
        let _ = self.post_with_reply(p, &frame, entry);
        rx
    }

    /// Enqueue a snapshot query; the reply channel delivers the
    /// worker-side processor state once its queue drains to it.
    pub fn snapshot_request(&self, p: ProcId) -> Receiver<WorkerSnapshot> {
        let (tx, rx) = channel();
        let frame = wire::Frame::Query { p: p as u32 };
        let _ = self.post_with_reply(p, &frame, Pending::Snapshot(tx));
        rx
    }

    /// Blocking snapshot of one processor (drains its queue first).
    pub fn snapshot(&self, p: ProcId) -> Result<WorkerSnapshot> {
        self.snapshot_request(p)
            .recv_timeout(self.cfg.reply_timeout)
            .map_err(|_| anyhow!("processor {p}: worker process unreachable"))
    }

    /// Snapshots of every processor still reachable (dead groups are
    /// skipped; `finish` reports them).
    fn snapshot_all(&self) -> Vec<WorkerSnapshot> {
        (0..self.procs).filter_map(|p| self.snapshot(p).ok()).collect()
    }

    /// First recorded worker-side error (memory overflow, peer loss).
    pub fn take_error(&self) -> Option<String> {
        self.snapshot_all().into_iter().find_map(|s| s.error)
    }

    /// Enqueue one logical transfer along the topology's route —
    /// identical command structure to the threaded engine, so the cost
    /// accounting is identical too.
    fn route_send(&mut self, src: ProcId, dst: ProcId, payload: HostPayload) -> Result<Slot> {
        assert_ne!(src, dst, "send to self is a local operation");
        if self.topo.hops(src, dst) == 1 {
            let slot = self.fresh_slot(dst);
            let w = self.topo.link_bw_weight(src, dst);
            self.post(src, &send_frame(src, dst, w, payload))?;
            let recv = wire::Frame::Recv {
                p: dst as u32,
                src: src as u32,
                slot,
            };
            self.post(dst, &recv)?;
            return Ok(slot);
        }
        let route = self.topo.route(src, dst);
        debug_assert!(route.len() >= 2, "route must span the endpoints");
        let slot = self.fresh_slot(dst);
        let w0 = self.topo.link_bw_weight(src, route[1]);
        self.post(src, &send_frame(src, route[1], w0, payload))?;
        for i in 1..route.len() - 1 {
            let fwd = wire::Frame::Forward {
                p: route[i] as u32,
                src: route[i - 1] as u32,
                dst: route[i + 1] as u32,
                weight: self.topo.link_bw_weight(route[i], route[i + 1]),
            };
            self.post(route[i], &fwd)?;
        }
        let recv = wire::Frame::Recv {
            p: dst as u32,
            src: route[route.len() - 2] as u32,
            slot,
        };
        self.post(dst, &recv)?;
        Ok(slot)
    }

    /// Reap every child, killing the stragglers after `patience`.
    fn reap_children(&self, patience: Duration) {
        let deadline = Instant::now() + patience;
        let mut kids = self.children.lock().unwrap();
        loop {
            let mut live = false;
            for slot in kids.iter_mut() {
                if let Some(c) = slot.as_mut() {
                    match c.try_wait() {
                        Ok(None) => live = true,
                        Ok(Some(_)) | Err(_) => *slot = None,
                    }
                }
            }
            if !live {
                return;
            }
            if Instant::now() >= deadline {
                for slot in kids.iter_mut() {
                    if let Some(c) = slot.as_mut() {
                        let _ = c.kill();
                        let _ = c.wait();
                    }
                    *slot = None;
                }
                return;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    /// Drain all queues, shut the worker processes down, and report.
    /// Consumes the engine's usefulness: further [`MachineApi`] calls
    /// error or no-op.
    pub fn finish(&mut self) -> Result<ThreadedReport> {
        self.stop_heartbeat();
        let expected = self.procs;
        // Snapshot first: it synchronizes every queue, so all replies
        // are home before the links close.
        let snaps = self.snapshot_all();
        let reps: Vec<usize> = self.bounds[..self.links.len()].to_vec();
        for &rep in &reps {
            let _ = self.post(rep, &wire::Frame::Shutdown);
        }
        for link in &mut self.links {
            link.tx = None; // writer flushes its queue, then exits
        }
        for link in &mut self.links {
            if let Some(h) = link.writer.take() {
                let _ = h.join();
            }
            if let Some(h) = link.reader.take() {
                let _ = h.join();
            }
        }
        self.reap_children(Duration::from_secs(5));
        let wall = self.started.elapsed();
        if snaps.len() < expected {
            bail!(
                "socket engine: {} processor(s) unreachable (worker process died)",
                expected - snaps.len()
            );
        }
        if let Some(e) = snaps.iter().find_map(|s| s.error.clone()) {
            bail!("socket engine: {e}");
        }
        let mut critical = Clock::default();
        let mut stats = MachineStats::default();
        let mut mem_peak_max = 0;
        let mut mem_peak_total = 0;
        let mut busy = Vec::with_capacity(snaps.len());
        for s in &snaps {
            critical = critical.join(&s.clock);
            stats.total_ops += s.total_ops;
            stats.total_words += s.sent_words;
            stats.total_msgs += s.sent_msgs;
            mem_peak_max = mem_peak_max.max(s.mem_peak);
            mem_peak_total += s.mem_peak;
            busy.push(s.busy);
        }
        Ok(ThreadedReport {
            wall,
            critical,
            stats,
            mem_peak_max,
            mem_peak_total,
            busy,
        })
    }
}

impl Drop for SocketMachine {
    fn drop(&mut self) {
        self.stop_heartbeat();
        // Kill first so blocked reader threads see EOF immediately.
        {
            let mut kids = self.children.lock().unwrap();
            for slot in kids.iter_mut() {
                if let Some(c) = slot.as_mut() {
                    let _ = c.kill();
                    let _ = c.wait();
                }
                *slot = None;
            }
        }
        for link in &mut self.links {
            link.tx = None;
        }
        for link in &mut self.links {
            if let Some(h) = link.writer.take() {
                let _ = h.join();
            }
            if let Some(h) = link.reader.take() {
                let _ = h.join();
            }
        }
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

/// Payload source for a send, resolved worker-side (same split as the
/// threaded engine's `Payload`).
enum HostPayload {
    Owned(Vec<u32>),
    FromSlot {
        slot: Slot,
        range: Option<std::ops::Range<usize>>,
        free_after: bool,
    },
}

fn send_frame(p: ProcId, dst: ProcId, weight: u64, payload: HostPayload) -> wire::Frame {
    match payload {
        HostPayload::Owned(data) => wire::Frame::SendOwned {
            p: p as u32,
            dst: dst as u32,
            weight,
            data,
        },
        HostPayload::FromSlot {
            slot,
            range,
            free_after,
        } => wire::Frame::SendSlot {
            p: p as u32,
            dst: dst as u32,
            weight,
            slot,
            range: range.map(|r| (r.start as u64, r.end as u64)),
            free_after,
        },
    }
}

impl MachineApi for SocketMachine {
    fn n_procs(&self) -> usize {
        self.procs
    }
    fn mem_cap(&self) -> u64 {
        self.mem_cap
    }
    fn base(&self) -> Base {
        self.base
    }
    fn topology(&self) -> TopologyRef {
        Arc::clone(&self.topo)
    }

    fn alloc(&mut self, p: ProcId, data: Vec<u32>) -> Result<Slot> {
        let slot = self.fresh_slot(p);
        let frame = wire::Frame::Alloc {
            p: p as u32,
            slot,
            data,
        };
        self.post(p, &frame)?;
        Ok(slot)
    }
    fn free(&mut self, p: ProcId, slot: Slot) {
        let frame = wire::Frame::Free { p: p as u32, slot };
        let _ = self.post(p, &frame);
    }
    fn read(&self, p: ProcId, slot: Slot) -> Result<Vec<u32>> {
        self.read_request(p, slot)
            .recv_timeout(self.cfg.reply_timeout)
            .map_err(|_| anyhow!("processor {p}: worker process died during read"))
    }
    fn read_into(&self, p: ProcId, slot: Slot, buf: &mut Vec<u32>) -> Result<()> {
        let data = self
            .read_request(p, slot)
            .recv_timeout(self.cfg.reply_timeout)
            .map_err(|_| anyhow!("processor {p}: worker process died during read"))?;
        buf.extend_from_slice(&data);
        Ok(())
    }
    fn replace(&mut self, p: ProcId, slot: Slot, data: Vec<u32>) -> Result<()> {
        let frame = wire::Frame::Replace {
            p: p as u32,
            slot,
            data,
        };
        self.post(p, &frame)
    }

    fn compute(&mut self, p: ProcId, ops: u64) {
        let frame = wire::Frame::Compute { p: p as u32, ops };
        let _ = self.post(p, &frame);
    }
    fn local<R, F>(&mut self, p: ProcId, f: F) -> Result<R>
    where
        R: Send + 'static,
        F: FnOnce(&Base, &mut Ops) -> R + Send + 'static,
    {
        let out = self
            .local_request::<R, F>(p, f)
            .recv_timeout(self.cfg.reply_timeout)
            .map_err(|_| anyhow!("processor {p}: worker process died during local"))?;
        Ok(*out.downcast::<R>().expect("local closure result type"))
    }
    fn compute_slot(
        &mut self,
        p: ProcId,
        inputs: &[Slot],
        consume: bool,
        f: SlotComputation,
    ) -> Result<Slot> {
        let out = self.fresh_slot(p);
        let (tx, rx) = channel();
        let take = wire::Frame::TakeInputs {
            p: p as u32,
            slots: inputs.to_vec(),
            consume,
        };
        self.post_with_reply(p, &take, Pending::Inputs(tx))?;
        let payloads = rx
            .recv_timeout(self.cfg.reply_timeout)
            .map_err(|_| anyhow!("processor {p}: worker process died during compute_slot"))?;
        let views: Vec<&[u32]> = payloads.iter().map(|v| v.as_slice()).collect();
        let t0 = Instant::now();
        let mut ops = Ops::default();
        let produced = f(&views, &self.base, &mut ops);
        let busy_ns = t0.elapsed().as_nanos() as u64;
        let store = wire::Frame::StoreOutput {
            p: p as u32,
            slot: out,
            ops: ops.get(),
            busy_ns,
            data: produced,
        };
        self.post(p, &store)?;
        Ok(out)
    }

    fn send(&mut self, src: ProcId, dst: ProcId, data: Vec<u32>) -> Result<Slot> {
        self.route_send(src, dst, HostPayload::Owned(data))
    }
    fn send_copy(&mut self, src: ProcId, dst: ProcId, slot: Slot) -> Result<Slot> {
        self.route_send(
            src,
            dst,
            HostPayload::FromSlot {
                slot,
                range: None,
                free_after: false,
            },
        )
    }
    fn send_move(&mut self, src: ProcId, dst: ProcId, slot: Slot) -> Result<Slot> {
        self.route_send(
            src,
            dst,
            HostPayload::FromSlot {
                slot,
                range: None,
                free_after: true,
            },
        )
    }
    fn send_range(
        &mut self,
        src: ProcId,
        dst: ProcId,
        slot: Slot,
        range: std::ops::Range<usize>,
    ) -> Result<Slot> {
        self.route_send(
            src,
            dst,
            HostPayload::FromSlot {
                slot,
                range: Some(range),
                free_after: false,
            },
        )
    }
    fn barrier(&mut self, procs: &[ProcId]) -> Result<()> {
        if procs.len() <= 1 {
            return Ok(());
        }
        // Collect every participant's clock, join host-side, release
        // everyone with the joined clock. A worker's queue naturally
        // blocks between its BarrierClock reply and the release — that
        // IS the rendezvous.
        let mut waits = Vec::with_capacity(procs.len());
        let mut dead = 0usize;
        for &p in procs {
            let (tx, rx) = channel();
            let frame = wire::Frame::BarrierCollect { p: p as u32 };
            match self.post_with_reply(p, &frame, Pending::Barrier(tx)) {
                Ok(()) => waits.push((p, rx)),
                Err(_) => dead += 1,
            }
        }
        let mut joined = Clock::default();
        let mut arrived = Vec::with_capacity(waits.len());
        for (p, rx) in waits {
            match rx.recv_timeout(self.cfg.reply_timeout) {
                Ok(c) => {
                    joined = joined.join(&c);
                    arrived.push(p);
                }
                Err(_) => dead += 1,
            }
        }
        for p in arrived {
            let frame = wire::Frame::BarrierRelease {
                p: p as u32,
                clock: joined,
            };
            if self.post(p, &frame).is_err() {
                dead += 1;
            }
        }
        if dead > 0 {
            bail!("barrier: {dead} worker process(es) dead");
        }
        Ok(())
    }

    fn proc_view(&self, p: ProcId) -> Result<ProcView> {
        let s = self.snapshot(p)?;
        Ok(ProcView {
            clock: s.clock,
            mem_used: s.mem_used,
            mem_peak: s.mem_peak,
        })
    }
    fn critical(&self) -> Clock {
        self.snapshot_all()
            .iter()
            .fold(Clock::default(), |acc, s| acc.join(&s.clock))
    }
    fn stats(&self) -> MachineStats {
        let mut st = MachineStats::default();
        for s in self.snapshot_all() {
            st.total_ops += s.total_ops;
            st.total_words += s.sent_words;
            st.total_msgs += s.sent_msgs;
        }
        st
    }
    fn mem_peak_max(&self) -> u64 {
        self.snapshot_all().iter().map(|s| s.mem_peak).max().unwrap_or(0)
    }
    fn mem_peak_total(&self) -> u64 {
        self.snapshot_all().iter().map(|s| s.mem_peak).sum()
    }
    fn mem_used_total(&self) -> u64 {
        self.snapshot_all().iter().map(|s| s.mem_used).sum()
    }
    fn purge(&mut self, p: ProcId) {
        let frame = wire::Frame::Purge { p: p as u32 };
        let _ = self.post(p, &frame);
    }
}

// ---------------------------------------------------------------------
// Worker side: the `copmul --socket-worker` process.
// ---------------------------------------------------------------------

/// Outgoing edge of one worker-side processor, indexed by global
/// destination.
enum NetTx {
    /// Self, or an edge this processor never sends on.
    None,
    /// Destination lives in this process: a plain channel.
    Local(Sender<NetMsg>),
    /// Destination lives in another worker process: pre-framed
    /// `Frame::Net` bytes to that group's peer-writer thread.
    Remote(Sender<Vec<u8>>),
}

/// Decoded command for one worker-side processor (the threaded
/// engine's `Cmd`, minus closures — those ran host-side).
enum WCmd {
    Alloc { slot: Slot, data: Vec<u32> },
    Free { slot: Slot },
    Replace { slot: Slot, data: Vec<u32> },
    Read { slot: Slot },
    Compute { ops: u64 },
    LocalSync { ops: u64, busy_ns: u64 },
    TakeInputs { slots: Vec<Slot>, consume: bool },
    StoreOutput { slot: Slot, ops: u64, busy_ns: u64, data: Vec<u32> },
    SendOwned { dst: usize, weight: u64, data: Vec<u32> },
    SendSlot {
        dst: usize,
        weight: u64,
        slot: Slot,
        range: Option<(u64, u64)>,
        free_after: bool,
    },
    Forward { src: usize, dst: usize, weight: u64 },
    Recv { src: usize, slot: Slot },
    BarrierCollect,
    BarrierRelease { clock: Clock },
    Purge,
    Query,
}

/// Map a command frame to `(global processor id, command)`.
fn to_wcmd(frame: wire::Frame) -> Option<(usize, WCmd)> {
    Some(match frame {
        wire::Frame::Alloc { p, slot, data } => (p as usize, WCmd::Alloc { slot, data }),
        wire::Frame::Free { p, slot } => (p as usize, WCmd::Free { slot }),
        wire::Frame::Replace { p, slot, data } => (p as usize, WCmd::Replace { slot, data }),
        wire::Frame::Read { p, slot } => (p as usize, WCmd::Read { slot }),
        wire::Frame::Compute { p, ops } => (p as usize, WCmd::Compute { ops }),
        wire::Frame::LocalSync { p, ops, busy_ns } => {
            (p as usize, WCmd::LocalSync { ops, busy_ns })
        }
        wire::Frame::TakeInputs { p, slots, consume } => {
            (p as usize, WCmd::TakeInputs { slots, consume })
        }
        wire::Frame::StoreOutput {
            p,
            slot,
            ops,
            busy_ns,
            data,
        } => (
            p as usize,
            WCmd::StoreOutput {
                slot,
                ops,
                busy_ns,
                data,
            },
        ),
        wire::Frame::SendOwned { p, dst, weight, data } => (
            p as usize,
            WCmd::SendOwned {
                dst: dst as usize,
                weight,
                data,
            },
        ),
        wire::Frame::SendSlot {
            p,
            dst,
            weight,
            slot,
            range,
            free_after,
        } => (
            p as usize,
            WCmd::SendSlot {
                dst: dst as usize,
                weight,
                slot,
                range,
                free_after,
            },
        ),
        wire::Frame::Forward { p, src, dst, weight } => (
            p as usize,
            WCmd::Forward {
                src: src as usize,
                dst: dst as usize,
                weight,
            },
        ),
        wire::Frame::Recv { p, src, slot } => (
            p as usize,
            WCmd::Recv {
                src: src as usize,
                slot,
            },
        ),
        wire::Frame::BarrierCollect { p } => (p as usize, WCmd::BarrierCollect),
        wire::Frame::BarrierRelease { p, clock } => (p as usize, WCmd::BarrierRelease { clock }),
        wire::Frame::Purge { p } => (p as usize, WCmd::Purge),
        wire::Frame::Query { p } => (p as usize, WCmd::Query),
        _ => return None,
    })
}

/// One worker-side processor: the same per-processor arena, ledgers,
/// and clock as the threaded engine's `Worker`, with wire replies and
/// a mixed local/remote network fabric.
struct WorkerProc {
    pid: usize,
    base: Base,
    mem_cap: u64,
    arena: Vec<Option<Arc<Vec<u32>>>>,
    clock: Clock,
    mem_used: u64,
    mem_peak: u64,
    total_ops: u64,
    sent_words: u64,
    sent_msgs: u64,
    busy: Duration,
    error: Option<String>,
    net_tx: Vec<NetTx>,
    net_rx: Vec<Option<Receiver<NetMsg>>>,
    /// Liveness flag of the peer group owning each global source
    /// (`None` for in-process sources). Remote mesh channels stay open
    /// across peer death so a respawned peer can reuse them; a blocked
    /// recv polls this flag instead of waiting on channel disconnect.
    down_of: Vec<Option<Arc<AtomicBool>>>,
    reply_tx: Sender<Vec<u8>>,
}

impl WorkerProc {
    fn fail(&mut self, msg: String) {
        if self.error.is_none() {
            self.error = Some(msg);
        }
    }

    fn charge_alloc(&mut self, words: u64) {
        if self.mem_used + words > self.mem_cap {
            self.fail(format!(
                "processor {}: local memory exceeded (used {} + {} > cap {})",
                self.pid, self.mem_used, words, self.mem_cap
            ));
        }
        self.mem_used += words;
        self.mem_peak = self.mem_peak.max(self.mem_used);
    }

    fn store(&mut self, slot: Slot, data: Vec<u32>) {
        self.store_shared(slot, Arc::new(data));
    }

    fn store_shared(&mut self, slot: Slot, data: Arc<Vec<u32>>) {
        self.charge_alloc(data.len() as u64);
        let idx = slot as usize;
        if idx >= self.arena.len() {
            self.arena.resize_with(idx + 1, || None);
        }
        debug_assert!(self.arena[idx].is_none(), "slot {slot} already in use");
        self.arena[idx] = Some(data);
    }

    fn take(&mut self, slot: Slot) -> Arc<Vec<u32>> {
        let data = self
            .arena
            .get_mut(slot as usize)
            .and_then(Option::take)
            .unwrap_or_else(|| panic!("processor {}: free of unknown slot {slot}", self.pid));
        self.mem_used -= data.len() as u64;
        while matches!(self.arena.last(), Some(None)) {
            self.arena.pop();
        }
        data
    }

    fn get(&self, slot: Slot) -> &Arc<Vec<u32>> {
        self.arena
            .get(slot as usize)
            .and_then(Option::as_ref)
            .unwrap_or_else(|| panic!("processor {}: read of unknown slot {slot}", self.pid))
    }

    fn snapshot(&self) -> WorkerSnapshot {
        WorkerSnapshot {
            clock: self.clock,
            mem_used: self.mem_used,
            mem_peak: self.mem_peak,
            total_ops: self.total_ops,
            sent_words: self.sent_words,
            sent_msgs: self.sent_msgs,
            busy: self.busy,
            error: self.error.clone(),
        }
    }

    fn reply(&self, frame: &wire::Frame) {
        let _ = self.reply_tx.send(wire::frame_bytes(frame));
    }

    fn send_net(&mut self, dst: usize, data: Arc<Vec<u32>>, snapshot: Clock) {
        match &self.net_tx[dst] {
            NetTx::None => {}
            NetTx::Local(tx) => {
                let _ = tx.send((data, snapshot));
            }
            NetTx::Remote(tx) => {
                let frame = wire::Frame::Net {
                    src: self.pid as u32,
                    dst: dst as u32,
                    clock: snapshot,
                    payload: (*data).clone(),
                };
                let _ = tx.send(wire::frame_bytes(&frame));
            }
        }
    }

    fn recv_net(&mut self, src: usize) -> Option<NetMsg> {
        let rx = self.net_rx[src].as_ref()?;
        let down = self.down_of[src].as_ref();
        loop {
            match rx.recv_timeout(Duration::from_millis(50)) {
                Ok(m) => return Some(m),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    // Queued messages always win over the down flag: a
                    // delivered payload outlives its sender's death.
                    if down.map(|d| d.load(Ordering::SeqCst)).unwrap_or(false) {
                        return None;
                    }
                }
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return None,
            }
        }
    }

    /// Charge a message leaving this processor, then put it on the
    /// wire — the exact charging sequence of the threaded engine.
    fn charged_send(&mut self, dst: usize, weight: u64, data: Arc<Vec<u32>>) {
        let words = data.len() as u64 * weight;
        self.clock.words += words;
        self.clock.msgs += 1;
        self.sent_words += words;
        self.sent_msgs += 1;
        let snapshot = self.clock;
        self.send_net(dst, data, snapshot);
    }

    fn run(mut self, rx: Receiver<WCmd>) {
        while let Ok(cmd) = rx.recv() {
            match cmd {
                WCmd::Alloc { slot, data } => self.store(slot, data),
                WCmd::Free { slot } => {
                    self.take(slot);
                }
                WCmd::Replace { slot, data } => {
                    let old = self.take(slot);
                    drop(old);
                    self.store(slot, data);
                }
                WCmd::Read { slot } => {
                    let payload = self.get(slot).as_slice().to_vec();
                    let frame = wire::Frame::Data {
                        p: self.pid as u32,
                        payload,
                    };
                    self.reply(&frame);
                }
                WCmd::Compute { ops } => {
                    self.clock.ops += ops;
                    self.total_ops += ops;
                }
                WCmd::LocalSync { ops, busy_ns } => {
                    self.busy += Duration::from_nanos(busy_ns);
                    self.clock.ops += ops;
                    self.total_ops += ops;
                    self.reply(&wire::Frame::Ack { p: self.pid as u32 });
                }
                WCmd::TakeInputs { slots, consume } => {
                    // Same ledger order as the threaded engine's
                    // ComputeSlot: consumed inputs are freed before
                    // the (host-side) digit work runs.
                    let payloads: Vec<Vec<u32>> = if consume {
                        slots.iter().map(|&s| payload_into_vec(self.take(s))).collect()
                    } else {
                        slots.iter().map(|&s| self.get(s).as_slice().to_vec()).collect()
                    };
                    let frame = wire::Frame::Inputs {
                        p: self.pid as u32,
                        payloads,
                    };
                    self.reply(&frame);
                }
                WCmd::StoreOutput {
                    slot,
                    ops,
                    busy_ns,
                    data,
                } => {
                    self.busy += Duration::from_nanos(busy_ns);
                    self.clock.ops += ops;
                    self.total_ops += ops;
                    self.store(slot, data);
                }
                WCmd::SendOwned { dst, weight, data } => {
                    self.charged_send(dst, weight, Arc::new(data));
                }
                WCmd::SendSlot {
                    dst,
                    weight,
                    slot,
                    range,
                    free_after,
                } => {
                    let data: Arc<Vec<u32>> = if free_after {
                        let d = self.take(slot);
                        match range {
                            Some((a, b)) => Arc::new(d[a as usize..b as usize].to_vec()),
                            None => d,
                        }
                    } else {
                        let d = self.get(slot);
                        match range {
                            Some((a, b)) => Arc::new(d[a as usize..b as usize].to_vec()),
                            None => Arc::clone(d),
                        }
                    };
                    self.charged_send(dst, weight, data);
                }
                WCmd::Forward { src, dst, weight } => match self.recv_net(src) {
                    Some((data, snapshot)) => {
                        // Join the inbound hop, then charge the
                        // outbound link — same order as both other
                        // engines.
                        self.clock = self.clock.join(&snapshot);
                        self.charged_send(dst, weight, data);
                    }
                    None => self.fail(format!(
                        "processor {}: peer {src} hung up mid-relay",
                        self.pid
                    )),
                },
                WCmd::Recv { src, slot } => match self.recv_net(src) {
                    Some((data, snapshot)) => {
                        self.store_shared(slot, data);
                        self.clock = self.clock.join(&snapshot);
                    }
                    None => self.fail(format!(
                        "processor {}: peer {src} hung up mid-message",
                        self.pid
                    )),
                },
                WCmd::BarrierCollect => {
                    let frame = wire::Frame::BarrierClock {
                        p: self.pid as u32,
                        clock: self.clock,
                    };
                    self.reply(&frame);
                    // The queue now blocks until the host's
                    // BarrierRelease arrives — that is the rendezvous.
                }
                WCmd::BarrierRelease { clock } => self.clock = clock,
                WCmd::Purge => {
                    self.arena.clear();
                    self.mem_used = 0;
                }
                WCmd::Query => {
                    let frame = wire::Frame::Snapshot {
                        p: self.pid as u32,
                        snap: self.snapshot(),
                    };
                    self.reply(&frame);
                }
            }
        }
    }
}

/// Entry point for `copmul --socket-worker`: one group's OS process.
/// Wiring comes from `COPMUL_SOCKET_{HOST,GROUP,DIR}`. Runs on the
/// main thread until Shutdown (or coordinator death), so process exit
/// reaps every helper thread.
pub fn socket_worker_main() -> Result<()> {
    let host_addr = std::env::var("COPMUL_SOCKET_HOST")
        .map_err(|_| anyhow!("COPMUL_SOCKET_HOST not set"))?;
    let group: usize = std::env::var("COPMUL_SOCKET_GROUP")
        .ok()
        .and_then(|v| v.parse().ok())
        .ok_or_else(|| anyhow!("COPMUL_SOCKET_GROUP missing or invalid"))?;
    let dir = PathBuf::from(
        std::env::var("COPMUL_SOCKET_DIR").map_err(|_| anyhow!("COPMUL_SOCKET_DIR not set"))?,
    );
    let mut host = Stream::connect(&host_addr)?;
    host.set_read_timeout(Some(Duration::from_secs(10)))?;
    wire::write_frame(&mut host, &wire::Frame::Hello { group: group as u32 })?;
    let (procs, groups, mem_cap, base, bounds) = match wire::read_frame(&mut host)? {
        wire::Frame::Setup {
            procs,
            groups,
            mem_cap,
            base_log2,
            bounds,
        } => (
            procs as usize,
            groups as usize,
            mem_cap,
            Base::new(base_log2 as u32),
            bounds.iter().map(|&b| b as usize).collect::<Vec<_>>(),
        ),
        other => bail!("expected Setup, got {other:?}"),
    };
    ensure!(
        group < groups && bounds.len() == groups + 1,
        "inconsistent Setup for group {group}"
    );
    let transport = if host_addr.starts_with("unix:") {
        SocketTransport::Unix
    } else {
        SocketTransport::Tcp
    };
    // A respawned worker binds a fresh (pid-unique) listener path —
    // the dead predecessor's socket file may still exist.
    let (listener, my_addr) = Listener::bind(
        transport,
        &dir,
        &format!("peer{group}-{}", std::process::id()),
    )?;
    wire::write_frame(&mut host, &wire::Frame::Listening { addr: my_addr })?;
    let addrs = match wire::read_frame(&mut host)? {
        wire::Frame::Go { addrs } => addrs,
        other => bail!("expected Go, got {other:?}"),
    };
    ensure!(addrs.len() == groups, "expected {groups} peer addresses");
    // `COPMUL_SOCKET_REJOIN=<live peers>` marks a respawn handshake:
    // every live peer dials us (the host told them to via Reconnect),
    // so accept that many hellos instead of the boot-time mesh build.
    let rejoin: Option<usize> = std::env::var("COPMUL_SOCKET_REJOIN")
        .ok()
        .and_then(|v| v.parse().ok());
    let mut peers: Vec<Option<Stream>> = (0..groups).map(|_| None).collect();
    match rejoin {
        None => {
            // Boot-time peer mesh: connect to every lower group, accept
            // from every higher one — a fixed direction per pair, so
            // the handshake cannot deadlock.
            for (h, addr) in addrs.iter().enumerate().take(group) {
                let mut s = Stream::connect(addr)?;
                wire::write_frame(&mut s, &wire::Frame::PeerHello { group: group as u32 })?;
                peers[h] = Some(s);
            }
            let deadline = Instant::now() + Duration::from_secs(10);
            for _ in group + 1..groups {
                let s = listener.accept_deadline(deadline)?;
                s.set_read_timeout(Some(Duration::from_secs(10)))?;
                let mut s = s;
                match wire::read_frame(&mut s)? {
                    wire::Frame::PeerHello { group: h } => {
                        let h = h as usize;
                        ensure!(
                            h > group && h < groups && peers[h].is_none(),
                            "bad peer hello (group {h})"
                        );
                        s.set_read_timeout(None)?;
                        peers[h] = Some(s);
                    }
                    other => bail!("expected PeerHello, got {other:?}"),
                }
            }
        }
        Some(expected) => {
            ensure!(
                expected < groups,
                "rejoin peer count {expected} exceeds group count {groups}"
            );
            let deadline = Instant::now() + Duration::from_secs(10);
            for _ in 0..expected {
                let s = listener.accept_deadline(deadline)?;
                s.set_read_timeout(Some(Duration::from_secs(10)))?;
                let mut s = s;
                match wire::read_frame(&mut s)? {
                    wire::Frame::PeerHello { group: h } => {
                        let h = h as usize;
                        ensure!(
                            h != group && h < groups && peers[h].is_none(),
                            "bad rejoin peer hello (group {h})"
                        );
                        s.set_read_timeout(None)?;
                        peers[h] = Some(s);
                    }
                    other => bail!("expected PeerHello, got {other:?}"),
                }
            }
        }
    }
    wire::write_frame(&mut host, &wire::Frame::Ready)?;
    host.set_read_timeout(None)?;
    run_worker(host, peers, group, procs, mem_cap, base, &bounds)
}

/// Worker-side endpoint of one remote peer group, respawn-tolerant:
/// the writer thread and mesh channels are permanent; only the stream
/// inside `slot` (and its reader thread) is replaced on reconnect.
struct PeerLink {
    /// Outbound pre-framed `Net` bytes to the persistent writer thread.
    tx: Sender<Vec<u8>>,
    /// The live stream, if any. Writer discards frames while `None`
    /// (their job is doomed anyway and retries after respawn).
    slot: Arc<Mutex<Option<Stream>>>,
    /// What blocked receivers poll ([`WorkerProc::recv_net`]).
    down: Arc<AtomicBool>,
    /// Bumped per reconnect so a stale reader's teardown is ignored.
    epoch: Arc<AtomicU64>,
    /// Inbound demux: `[src - h_lo][local dst]` senders, Arc'd so each
    /// reconnect's fresh reader thread gets the same rows.
    demux: Arc<Vec<Vec<Option<Sender<NetMsg>>>>>,
    /// First global processor of the peer group.
    h_lo: usize,
}

/// Spawn the reader thread for one (re)connected peer stream: demux
/// inbound `Net` frames onto the local mesh; on EOF mark the peer down
/// unless a newer reconnect has already superseded this reader.
fn spawn_peer_reader(mut rs: Stream, link_epoch: u64, link: &PeerLink, lo: usize) {
    let demux = Arc::clone(&link.demux);
    let down = Arc::clone(&link.down);
    let epoch = Arc::clone(&link.epoch);
    let slot = Arc::clone(&link.slot);
    let h_lo = link.h_lo;
    std::thread::spawn(move || {
        loop {
            match wire::read_frame(&mut rs) {
                Ok(wire::Frame::Net {
                    src,
                    dst,
                    clock,
                    payload,
                }) => {
                    let si = (src as usize).wrapping_sub(h_lo);
                    let di = (dst as usize).wrapping_sub(lo);
                    let tx = demux.get(si).and_then(|row| row.get(di)).and_then(Option::as_ref);
                    match tx {
                        Some(tx) => {
                            let _ = tx.send((Arc::new(payload), clock));
                        }
                        None => break,
                    }
                }
                _ => break,
            }
        }
        if epoch.load(Ordering::SeqCst) == link_epoch {
            down.store(true, Ordering::SeqCst);
            *slot.lock().unwrap() = None;
        }
    });
}

/// Steady-state service loop of one worker process.
fn run_worker(
    host: Stream,
    mut peers: Vec<Option<Stream>>,
    group: usize,
    procs: usize,
    mem_cap: u64,
    base: Base,
    bounds: &[usize],
) -> Result<()> {
    let lo = bounds[group];
    let hi = bounds[group + 1];
    let locals = hi - lo;
    let groups = bounds.len() - 1;

    // Reply path to the host: processors enqueue pre-framed bytes, one
    // writer thread owns the stream's write half.
    let (reply_tx, reply_rx) = channel::<Vec<u8>>();
    let mut host_w = host.try_clone()?;
    let host_writer = std::thread::spawn(move || {
        while let Ok(buf) = reply_rx.recv() {
            if host_w.write_all(&buf).and_then(|_| host_w.flush()).is_err() {
                return;
            }
        }
    });

    // One channel per (global source -> local destination) ordered
    // pair — the threaded engine's mesh, restricted to the rows this
    // process owns.
    let mut net_rx: NetRxMesh = (0..locals).map(|_| (0..procs).map(|_| None).collect()).collect();
    let mut to_local: NetTxMesh =
        (0..procs).map(|_| (0..locals).map(|_| None).collect()).collect();
    for di in 0..locals {
        let d = lo + di;
        for s in 0..procs {
            if s == d {
                continue;
            }
            let (tx, rx) = channel();
            net_rx[di][s] = Some(rx);
            to_local[s][di] = Some(tx);
        }
    }

    // Peer links: one per remote group, stream or not. The writer
    // thread and the demux rows are permanent (so mesh channels survive
    // a peer death); only the stream in `slot` comes and goes. Remote
    // demux rows CLONE the `to_local` senders — the masters stay alive
    // in `to_local`, so a reconnect's fresh reader reuses them.
    let mut peer_links: Vec<Option<PeerLink>> = Vec::with_capacity(groups);
    let mut writer_threads = Vec::new();
    for h in 0..groups {
        if h == group {
            peer_links.push(None);
            continue;
        }
        let h_lo = bounds[h];
        let h_hi = bounds[h + 1];
        let demux: Vec<Vec<Option<Sender<NetMsg>>>> =
            (h_lo..h_hi).map(|s| to_local[s].clone()).collect();
        let (tx, rx) = channel::<Vec<u8>>();
        let slot = Arc::new(Mutex::new(None::<Stream>));
        let down = Arc::new(AtomicBool::new(true));
        {
            let slot = Arc::clone(&slot);
            let down = Arc::clone(&down);
            writer_threads.push(std::thread::spawn(move || {
                while let Ok(buf) = rx.recv() {
                    let mut guard = slot.lock().unwrap();
                    if let Some(s) = guard.as_mut() {
                        if s.write_all(&buf).and_then(|_| s.flush()).is_err() {
                            *guard = None;
                            down.store(true, Ordering::SeqCst);
                        }
                    }
                }
            }));
        }
        let link = PeerLink {
            tx,
            slot,
            down,
            epoch: Arc::new(AtomicU64::new(0)),
            demux: Arc::new(demux),
            h_lo,
        };
        if let Some(s) = peers[h].take() {
            let rs = s.try_clone()?;
            *link.slot.lock().unwrap() = Some(s);
            link.down.store(false, Ordering::SeqCst);
            spawn_peer_reader(rs, 0, &link, lo);
        }
        peer_links.push(Some(link));
    }

    // Spawn the processor command loops.
    let mut cmd_txs = Vec::with_capacity(locals);
    let mut proc_handles = Vec::with_capacity(locals);
    for (di, rx_row) in net_rx.iter_mut().enumerate() {
        let pid = lo + di;
        let net_tx_row: Vec<NetTx> = (0..procs)
            .map(|dst| {
                if dst == pid {
                    return NetTx::None;
                }
                let dg = group_of_bounds(bounds, dst);
                if dg == group {
                    match to_local[pid][dst - lo].take() {
                        Some(tx) => NetTx::Local(tx),
                        None => NetTx::None,
                    }
                } else {
                    match &peer_links[dg] {
                        Some(link) => NetTx::Remote(link.tx.clone()),
                        None => NetTx::None,
                    }
                }
            })
            .collect();
        let down_of: Vec<Option<Arc<AtomicBool>>> = (0..procs)
            .map(|src| {
                let sg = group_of_bounds(bounds, src);
                if sg == group {
                    None
                } else {
                    peer_links[sg].as_ref().map(|l| Arc::clone(&l.down))
                }
            })
            .collect();
        let proc = WorkerProc {
            pid,
            base,
            mem_cap,
            arena: Vec::new(),
            clock: Clock::default(),
            mem_used: 0,
            mem_peak: 0,
            total_ops: 0,
            sent_words: 0,
            sent_msgs: 0,
            busy: Duration::ZERO,
            error: None,
            net_tx: net_tx_row,
            net_rx: std::mem::take(rx_row),
            down_of,
            reply_tx: reply_tx.clone(),
        };
        let (ctx, crx) = channel::<WCmd>();
        cmd_txs.push(ctx);
        proc_handles.push(std::thread::spawn(move || proc.run(crx)));
    }

    // Command pump: the process's main loop. EOF or Shutdown ends it.
    // Heartbeats are acked here (process-level liveness, ahead of any
    // per-processor queue); Reconnect splices a respawned peer's fresh
    // stream into the permanent link without touching the mesh.
    let mut host_r = host;
    loop {
        let frame = match wire::read_frame(&mut host_r) {
            Ok(f) => f,
            Err(_) => break,
        };
        match frame {
            wire::Frame::Shutdown => break,
            wire::Frame::Heartbeat { seq } => {
                let _ = reply_tx.send(wire::frame_bytes(&wire::Frame::HeartbeatAck { seq }));
            }
            wire::Frame::Reconnect { group: h, addr } => {
                let Some(link) = peer_links.get(h as usize).and_then(Option::as_ref) else {
                    break;
                };
                // Dial the respawned peer. A failed dial leaves the
                // link down; the host's next respawn attempt sends a
                // fresh Reconnect.
                if let Ok(mut s) = Stream::connect(&addr) {
                    let hello = wire::Frame::PeerHello {
                        group: group as u32,
                    };
                    if wire::write_frame(&mut s, &hello).is_ok() {
                        if let Ok(rs) = s.try_clone() {
                            let e = link.epoch.fetch_add(1, Ordering::SeqCst) + 1;
                            *link.slot.lock().unwrap() = Some(s);
                            link.down.store(false, Ordering::SeqCst);
                            spawn_peer_reader(rs, e, link, lo);
                        }
                    }
                }
            }
            frame => {
                let Some((p, cmd)) = to_wcmd(frame) else { break };
                if p < lo || p >= hi {
                    break;
                }
                if cmd_txs[p - lo].send(cmd).is_err() {
                    break;
                }
            }
        }
    }
    drop(cmd_txs);
    for h in proc_handles {
        let _ = h.join();
    }
    drop(reply_tx);
    let _ = host_writer.join();
    drop(writer_threads);
    // Peer threads are reaped by process exit.
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::wire::{frame_bytes, read_frame, write_frame, Frame, MAGIC, MAX_FRAME, VERSION};
    use super::*;

    /// One instance of every frame variant, with non-trivial fields.
    fn corpus() -> Vec<Frame> {
        vec![
            Frame::Hello { group: 1 },
            Frame::Setup {
                procs: 8,
                groups: 2,
                mem_cap: 1 << 40,
                base_log2: 16,
                bounds: vec![0, 4, 8],
            },
            Frame::Listening {
                addr: "unix:/tmp/copmul-sock-1/peer0.sock".into(),
            },
            Frame::Go {
                addrs: vec!["unix:/tmp/a.sock".into(), "tcp:127.0.0.1:4100".into()],
            },
            Frame::Ready,
            Frame::Shutdown,
            Frame::Alloc {
                p: 3,
                slot: 7,
                data: vec![1, 2, 3],
            },
            Frame::Free { p: 3, slot: 7 },
            Frame::Replace {
                p: 0,
                slot: 2,
                data: vec![9],
            },
            Frame::Read { p: 1, slot: 4 },
            Frame::Compute { p: 2, ops: 99 },
            Frame::LocalSync {
                p: 2,
                ops: 5,
                busy_ns: 1234,
            },
            Frame::TakeInputs {
                p: 6,
                slots: vec![1, 2, 3],
                consume: true,
            },
            Frame::StoreOutput {
                p: 6,
                slot: 4,
                ops: 12,
                busy_ns: 88,
                data: vec![5, 6],
            },
            Frame::SendOwned {
                p: 0,
                dst: 5,
                weight: 2,
                data: vec![7, 8],
            },
            Frame::SendSlot {
                p: 0,
                dst: 5,
                weight: 1,
                slot: 9,
                range: Some((2, 6)),
                free_after: true,
            },
            Frame::SendSlot {
                p: 1,
                dst: 2,
                weight: 1,
                slot: 3,
                range: None,
                free_after: false,
            },
            Frame::Forward {
                p: 4,
                src: 0,
                dst: 5,
                weight: 3,
            },
            Frame::Recv { p: 5, src: 4, slot: 11 },
            Frame::BarrierCollect { p: 7 },
            Frame::BarrierRelease {
                p: 7,
                clock: Clock {
                    ops: 1,
                    words: 2,
                    msgs: 3,
                },
            },
            Frame::Purge { p: 7 },
            Frame::Query { p: 7 },
            Frame::Data {
                p: 1,
                payload: vec![4, 5, 6],
            },
            Frame::Ack { p: 1 },
            Frame::Inputs {
                p: 2,
                payloads: vec![vec![1], vec![], vec![2, 3]],
            },
            Frame::Snapshot {
                p: 3,
                snap: WorkerSnapshot {
                    clock: Clock {
                        ops: 10,
                        words: 20,
                        msgs: 30,
                    },
                    mem_used: 40,
                    mem_peak: 50,
                    total_ops: 60,
                    sent_words: 70,
                    sent_msgs: 80,
                    busy: Duration::from_nanos(90),
                    error: Some("processor 3: local memory exceeded".into()),
                },
            },
            Frame::BarrierClock {
                p: 4,
                clock: Clock {
                    ops: 9,
                    words: 8,
                    msgs: 7,
                },
            },
            Frame::Heartbeat { seq: 7 },
            Frame::HeartbeatAck { seq: u64::MAX },
            Frame::Reconnect {
                group: 1,
                addr: "unix:/tmp/copmul-sock-1/peer1-4242.sock".into(),
            },
            Frame::PeerHello { group: 0 },
            Frame::Net {
                src: 2,
                dst: 6,
                clock: Clock {
                    ops: 1,
                    words: 1,
                    msgs: 1,
                },
                payload: vec![0xFFFF, 0],
            },
        ]
    }

    #[test]
    fn every_variant_roundtrips() {
        for f in corpus() {
            let bytes = f.encode();
            assert_eq!(Frame::decode(&bytes).unwrap(), f, "variant {f:?}");
        }
    }

    #[test]
    fn truncation_at_every_offset_is_an_error() {
        for f in corpus() {
            let bytes = f.encode();
            for cut in 0..bytes.len() {
                assert!(
                    Frame::decode(&bytes[..cut]).is_err(),
                    "prefix of {} bytes of {f:?} decoded",
                    cut
                );
            }
        }
    }

    #[test]
    fn bad_magic_version_opcode_and_trailing_garbage_are_rejected() {
        let good = Frame::Ready.encode();
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        assert!(Frame::decode(&bad).is_err(), "magic");
        let mut bad = good.clone();
        bad[4] = VERSION + 1;
        assert!(Frame::decode(&bad).is_err(), "version");
        let mut bad = good.clone();
        bad[5] = 0x7F;
        assert!(Frame::decode(&bad).is_err(), "opcode");
        let mut bad = good.clone();
        bad.push(0);
        assert!(Frame::decode(&bad).is_err(), "trailing garbage");
        assert_eq!(Frame::decode(&good).unwrap(), Frame::Ready);
    }

    #[test]
    fn hostile_length_fields_are_rejected_before_allocating() {
        // An Alloc frame claiming u32::MAX digits with an empty body:
        // the shared cursor's remaining-bytes cap must reject it
        // without sizing a buffer from the claimed count.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC.to_le_bytes());
        bytes.push(VERSION);
        bytes.push(0x10); // Alloc
        bytes.extend_from_slice(&0u32.to_le_bytes()); // p
        bytes.extend_from_slice(&1u64.to_le_bytes()); // slot
        bytes.extend_from_slice(&u32::MAX.to_le_bytes()); // digit count
        assert!(Frame::decode(&bytes).is_err());
        // Same for a TakeInputs slot count and an Inputs payload count.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC.to_le_bytes());
        bytes.push(VERSION);
        bytes.push(0x16); // TakeInputs
        bytes.extend_from_slice(&0u32.to_le_bytes());
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(Frame::decode(&bytes).is_err());
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC.to_le_bytes());
        bytes.push(VERSION);
        bytes.push(0x22); // Inputs
        bytes.extend_from_slice(&0u32.to_le_bytes());
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(Frame::decode(&bytes).is_err());
    }

    #[test]
    fn bad_bool_bytes_are_rejected() {
        let f = Frame::SendSlot {
            p: 0,
            dst: 1,
            weight: 1,
            slot: 2,
            range: None,
            free_after: false,
        };
        let mut bytes = f.encode();
        let n = bytes.len();
        bytes[n - 1] = 7; // free_after must be 0 or 1
        assert!(Frame::decode(&bytes).is_err());
        bytes[n - 1] = 1;
        assert!(matches!(
            Frame::decode(&bytes).unwrap(),
            Frame::SendSlot { free_after: true, .. }
        ));
    }

    #[test]
    fn stream_framing_roundtrips_and_caps_length() {
        let mut buf = Vec::new();
        for f in corpus() {
            write_frame(&mut buf, &f).unwrap();
        }
        let mut r = std::io::Cursor::new(buf);
        for f in corpus() {
            assert_eq!(read_frame(&mut r).unwrap(), f);
        }
        // A hostile length prefix past MAX_FRAME fails before the body
        // buffer is allocated.
        let mut evil = Vec::new();
        evil.extend_from_slice(&((MAX_FRAME + 1) as u32).to_le_bytes());
        evil.extend_from_slice(&[0; 16]);
        let mut r = std::io::Cursor::new(evil);
        assert!(read_frame(&mut r).is_err());
        // frame_bytes is exactly what read_frame consumes.
        let f = Frame::Query { p: 3 };
        let mut r = std::io::Cursor::new(frame_bytes(&f));
        assert_eq!(read_frame(&mut r).unwrap(), f);
    }

    #[test]
    fn group_bounds_partition_every_processor() {
        for procs in 1..=17 {
            for groups in 1..=procs {
                let b = group_bounds(procs, groups);
                assert_eq!(b.len(), groups + 1);
                assert_eq!(b[0], 0);
                assert_eq!(b[groups], procs);
                for g in 0..groups {
                    assert!(b[g] < b[g + 1], "group {g} empty for {procs}/{groups}");
                }
                for p in 0..procs {
                    let g = group_of_bounds(&b, p);
                    assert!(b[g] <= p && p < b[g + 1]);
                }
            }
        }
    }

    #[test]
    fn worker_bin_resolution_prefers_explicit_config() {
        let cfg = SocketConfig {
            worker_bin: Some(PathBuf::from("/nonexistent/copmul")),
            ..SocketConfig::default()
        };
        assert_eq!(
            resolve_worker_bin(&cfg),
            Some(PathBuf::from("/nonexistent/copmul"))
        );
    }
}

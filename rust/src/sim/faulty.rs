//! [`FaultyMachine`] — deterministic fault injection over any execution
//! engine.
//!
//! The paper's cost theorems assume a machine that never fails; the
//! serving layer cannot. This wrapper implements [`MachineApi`] over any
//! inner engine and injects faults from a **seeded plan**: every
//! eligible operation draws a decision from a hash of
//! `(seed, processor, per-processor op index)`, so a given seed produces
//! the same fault sequence on every run of the same program — on the
//! cost-model engine *and* on the threaded engine, whose hosts issue the
//! identical operation stream.
//!
//! ## Injectable faults ([`FaultKind`])
//!
//! * `DropMsg` — a point-to-point message is lost: the send is not
//!   performed and the call errors (the coordination algorithms cannot
//!   survive a lost message, so the job fails and is retried).
//! * `DupMsg` — the message is delivered twice; the duplicate is
//!   discarded at the receiver. The product is unaffected but the
//!   sender's clock is charged for both copies — cost inflation, not
//!   failure.
//! * `ReorderMsg` — the message arrives out of sequence: the wire cost
//!   is charged and the payload discarded, and the call errors (the
//!   machine model's channels are ordered by construction, so a
//!   reordered message is detected, like a sequence-number mismatch).
//! * `Stall` — transient processor stall: extra digit-op clock skew is
//!   charged to the processor at a `send` or `barrier`. Cost inflation,
//!   not failure.
//! * `AllocFail` — an `alloc`/`replace` fails (transient memory
//!   pressure); surfaces as the same recoverable `Err` a real
//!   over-capacity allocation produces.
//! * `ComputeFail` — a `compute_slot` (leaf product) fails.
//! * `Crash` — the processor dies: the triggering call errors and every
//!   later fallible operation involving the processor errors too, until
//!   [`FaultyMachine::heal`] restarts it (the scheduler heals a shard's
//!   processors when it reclaims the shard).
//!
//! Every injected fault is recorded as a [`FaultEvent`], so tests can
//! assert exact fault counts and the scheduler can report how many
//! faults a job survived.
//!
//! ## Zero-fault transparency
//!
//! When no fault fires (rate 0, suppressed processors, or simply no
//! draw below the rate), every operation passes straight through to the
//! inner engine with **no extra cost charged** — products and cost
//! triples are bit-identical to an unwrapped run. The chaos suite
//! asserts this invariant end to end.
//!
//! ## Determinism boundary
//!
//! The per-processor op index is advanced by every state-changing
//! `MachineApi` call involving the processor (immutable observers —
//! `read`, `proc_view` — check crash state but do not advance it).
//! [`FaultyMachine::reset_op_index`] rewinds chosen processors to index
//! zero; the scheduler calls it when a shard is acquired, so a job's
//! fault pattern depends only on `(seed, shard processors, the job's own
//! operation stream)` — not on which jobs ran on the shard before it.

use super::api::{MachineApi, ProcView, SlotComputation};
use super::machine::{MachineStats, ProcId, Slot};
use super::topology::TopologyRef;
use super::Clock;
use crate::bignum::{Base, Ops};
use crate::error::{anyhow, Result};
use std::ops::Range;

/// One injectable fault category (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    DropMsg,
    DupMsg,
    ReorderMsg,
    Stall,
    AllocFail,
    ComputeFail,
    Crash,
}

/// All fault kinds, in the order used for deterministic kind selection.
pub const ALL_FAULT_KINDS: [FaultKind; 7] = [
    FaultKind::DropMsg,
    FaultKind::DupMsg,
    FaultKind::ReorderMsg,
    FaultKind::Stall,
    FaultKind::AllocFail,
    FaultKind::ComputeFail,
    FaultKind::Crash,
];

/// A recorded injection: what fired, where, and at which per-processor
/// operation index.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    pub kind: FaultKind,
    pub proc: ProcId,
    pub op_index: u64,
}

/// The seeded fault plan.
#[derive(Clone, Debug)]
pub struct FaultConfig {
    /// Seed of the deterministic decision stream.
    pub seed: u64,
    /// Probability that an eligible operation injects a fault. The
    /// paper-scale programs issue thousands of operations per job, so
    /// useful soak rates are small (1e-4..1e-2).
    pub rate: f64,
    /// Clock skew (digit ops) charged by a `Stall`.
    pub stall_ops: u64,
    /// Kinds this plan may inject (defaults to all).
    pub kinds: Vec<FaultKind>,
}

impl FaultConfig {
    pub fn new(seed: u64, rate: f64) -> Self {
        FaultConfig {
            seed,
            rate,
            stall_ops: 64,
            kinds: ALL_FAULT_KINDS.to_vec(),
        }
    }

    /// Restrict the plan to the given kinds.
    pub fn only(mut self, kinds: &[FaultKind]) -> Self {
        self.kinds = kinds.to_vec();
        self
    }
}

/// Interception site: determines which fault kinds are applicable.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Site {
    Alloc,
    Compute,
    Send,
    Barrier,
    /// Counter-advancing but never injecting (free, compute charges,
    /// local control-flow results).
    Neutral,
}

impl Site {
    fn applicable(self) -> &'static [FaultKind] {
        match self {
            Site::Alloc => &[FaultKind::AllocFail, FaultKind::Crash],
            Site::Compute => &[FaultKind::ComputeFail, FaultKind::Crash],
            Site::Send => &[
                FaultKind::DropMsg,
                FaultKind::DupMsg,
                FaultKind::ReorderMsg,
                FaultKind::Stall,
                FaultKind::Crash,
            ],
            Site::Barrier => &[FaultKind::Stall],
            Site::Neutral => &[],
        }
    }

    fn salt(self) -> u64 {
        match self {
            Site::Alloc => 0xA110C,
            Site::Compute => 0xC09901E,
            Site::Send => 0x5E4D,
            Site::Barrier => 0xBA221E2,
            Site::Neutral => 0,
        }
    }
}

/// SplitMix64-style mixer: the decision hash.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Deterministic fault injection over any execution engine (see module
/// docs). `FaultyMachine::passthrough` builds a transparent wrapper
/// with no plan — zero overhead beyond the delegation.
pub struct FaultyMachine<E: MachineApi> {
    inner: E,
    plan: Option<FaultConfig>,
    /// Per-processor operation index (the deterministic "time" axis).
    op_index: Vec<u64>,
    /// Injected-crash state per processor.
    crashed: Vec<bool>,
    /// Injection suppressed per processor (the scheduler's safe-mode
    /// escape hatch for a job's final attempt).
    suppressed: Vec<bool>,
    /// Every injected fault, in injection order.
    events: Vec<FaultEvent>,
    /// Injected-fault count per processor (cheap delta queries).
    per_proc_events: Vec<u64>,
}

impl<E: MachineApi> FaultyMachine<E> {
    /// Wrap `inner` with a seeded fault plan.
    pub fn new(inner: E, plan: FaultConfig) -> Self {
        Self::with(inner, Some(plan))
    }

    /// Wrap `inner` with an optional plan (`None` = fully transparent).
    pub fn with(inner: E, plan: Option<FaultConfig>) -> Self {
        let p = inner.n_procs();
        FaultyMachine {
            inner,
            plan,
            op_index: vec![0; p],
            crashed: vec![false; p],
            suppressed: vec![false; p],
            events: Vec::new(),
            per_proc_events: vec![0; p],
        }
    }

    /// Transparent wrapper: no faults ever fire.
    pub fn passthrough(inner: E) -> Self {
        Self::with(inner, None)
    }

    pub fn inner(&self) -> &E {
        &self.inner
    }

    pub fn inner_mut(&mut self) -> &mut E {
        &mut self.inner
    }

    pub fn into_inner(self) -> E {
        self.inner
    }

    /// `true` while an injected crash holds the processor down.
    pub fn is_crashed(&self, p: ProcId) -> bool {
        self.crashed[p]
    }

    /// Restart a crashed processor (recovery: the scheduler heals a
    /// shard's processors while reclaiming the shard; the inner
    /// engine's state survives because injected crashes never reached
    /// it).
    pub fn heal(&mut self, p: ProcId) {
        self.crashed[p] = false;
    }

    /// Suppress (or re-enable) injection on a processor. Crash state is
    /// unaffected; suppression only stops *new* faults.
    pub fn set_suppressed(&mut self, p: ProcId, on: bool) {
        self.suppressed[p] = on;
    }

    /// Rewind a processor's op index to zero (see module docs,
    /// "Determinism boundary").
    pub fn reset_op_index(&mut self, procs: &[ProcId]) {
        for &p in procs {
            self.op_index[p] = 0;
        }
    }

    /// Every injected fault so far, in injection order.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Number of injected faults involving processor `p`.
    pub fn fault_count(&self, p: ProcId) -> u64 {
        self.per_proc_events[p]
    }

    /// Total injected faults.
    pub fn total_injected(&self) -> u64 {
        self.events.len() as u64
    }

    /// Fallible-path crash gate: error out while `p` is held down.
    /// Public so wrappers that bypass this impl for two-phase blocking
    /// operations (the scheduler's `ShardView`) can apply the same
    /// gate before enqueuing on the inner engine.
    pub fn check_alive(&self, p: ProcId) -> Result<()> {
        if self.crashed[p] {
            Err(anyhow!("processor {p}: crashed (injected fault)"))
        } else {
            Ok(())
        }
    }

    /// The interception `local` performs, without the inner call:
    /// crash gate plus the counter-advancing neutral draw. For callers
    /// that run the actual computation through the inner engine's
    /// two-phase request path.
    pub fn precheck_local(&mut self, p: ProcId) -> Result<()> {
        self.check_alive(p)?;
        let _ = self.draw(p, Site::Neutral);
        Ok(())
    }

    fn record(&mut self, kind: FaultKind, p: ProcId, op_index: u64) {
        self.events.push(FaultEvent {
            kind,
            proc: p,
            op_index,
        });
        self.per_proc_events[p] += 1;
    }

    /// Advance `p`'s op index and decide — *without recording* —
    /// whether a fault fires at this site. Pure function of
    /// `(seed, p, index, site)` — independent of wall-clock,
    /// scheduling, or prior draws. Returns the kind plus the index it
    /// fired at, so the caller can record exactly the decisions it
    /// materializes (multi-hop sends mask all but the first
    /// delivery-changing draw).
    fn decide(&mut self, p: ProcId, site: Site) -> Option<(FaultKind, u64)> {
        let plan = self.plan.as_ref()?;
        let idx = self.op_index[p];
        self.op_index[p] += 1;
        if self.suppressed[p] || plan.rate <= 0.0 {
            return None;
        }
        // Rate-reject before touching the kind tables: the hash does
        // not depend on them, and ~all draws of a realistic plan return
        // here — keep the per-operation hot path allocation-free.
        let h = mix(
            plan.seed ^ mix((p as u64) ^ site.salt()) ^ idx.wrapping_mul(0x9E3779B97F4A7C15),
        );
        let u = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        if u >= plan.rate {
            return None;
        }
        let applicable: Vec<FaultKind> = site
            .applicable()
            .iter()
            .copied()
            .filter(|k| plan.kinds.contains(k))
            .collect();
        if applicable.is_empty() {
            return None;
        }
        Some((applicable[(mix(h) % applicable.len() as u64) as usize], idx))
    }

    /// [`FaultyMachine::decide`] + record — for single-decision sites,
    /// where every drawn fault materializes.
    fn draw(&mut self, p: ProcId, site: Site) -> Option<FaultKind> {
        match self.decide(p, site) {
            Some((kind, idx)) => {
                self.record(kind, p, idx);
                Some(kind)
            }
            None => None,
        }
    }

    /// Shared handler for the four send flavours. `deliver` performs the
    /// real transfer on the inner engine; `duplicate` performs one extra
    /// delivery whose slot is discarded at `dst`.
    ///
    /// Injection is **per physical hop**: one decision draw per link of
    /// the topology's `(src, dst)` route, all charged to the sending
    /// processor's deterministic op-index stream (a route is part of
    /// one logical operation; keying relay draws on the relays would
    /// make a job's fault pattern depend on who else routes through
    /// them). Among delivery-changing kinds the first drawn hop wins
    /// and is the only one recorded; stalled hops materialize (skew
    /// charged, event recorded) only when the message actually travels
    /// the wire — i.e. never when the decisive fault is a `DropMsg` or
    /// `Crash` (the message then traverses no link at all), always
    /// under `DupMsg`/`ReorderMsg` (the wire is used end to end). The
    /// event log therefore counts *materialized* faults exactly. On
    /// the fully-connected default every route is one hop, reproducing
    /// the single-draw behaviour bit for bit.
    fn faulty_send(
        &mut self,
        src: ProcId,
        dst: ProcId,
        deliver: impl FnOnce(&mut E) -> Result<Slot>,
        duplicate: impl FnOnce(&mut E) -> Result<Slot>,
    ) -> Result<Slot> {
        self.check_alive(src)?;
        self.check_alive(dst)?;
        let hops = self.inner.topology().hops(src, dst).max(1);
        let mut stall_draws: Vec<u64> = Vec::new();
        let mut decisive: Option<FaultKind> = None;
        for _ in 0..hops {
            match self.decide(src, Site::Send) {
                None => {}
                Some((FaultKind::Stall, idx)) => stall_draws.push(idx),
                Some((k, idx)) => {
                    if decisive.is_none() {
                        decisive = Some(k);
                        self.record(k, src, idx);
                    }
                }
            }
        }
        let message_travels =
            !matches!(decisive, Some(FaultKind::DropMsg) | Some(FaultKind::Crash));
        if message_travels && !stall_draws.is_empty() {
            let skew = self.plan.as_ref().map(|p| p.stall_ops).unwrap_or(0);
            self.inner.compute(src, skew * stall_draws.len() as u64);
            for idx in stall_draws {
                self.record(FaultKind::Stall, src, idx);
            }
        }
        match decisive {
            None => deliver(&mut self.inner),
            Some(FaultKind::DupMsg) => {
                let dup = duplicate(&mut self.inner)?;
                self.inner.free(dst, dup);
                deliver(&mut self.inner)
            }
            Some(FaultKind::ReorderMsg) => {
                // The wire is used (cost charged) but the payload lands
                // out of sequence and is rejected.
                let slot = deliver(&mut self.inner)?;
                self.inner.free(dst, slot);
                Err(anyhow!(
                    "message {src} -> {dst}: arrived out of order (injected fault)"
                ))
            }
            Some(FaultKind::DropMsg) => Err(anyhow!(
                "message {src} -> {dst}: dropped (injected fault)"
            )),
            Some(FaultKind::Crash) => {
                self.crashed[src] = true;
                Err(anyhow!("processor {src}: crashed (injected fault)"))
            }
            Some(k) => unreachable!("{k:?} not applicable at a send site"),
        }
    }
}

impl<E: MachineApi> MachineApi for FaultyMachine<E> {
    fn n_procs(&self) -> usize {
        self.inner.n_procs()
    }
    fn mem_cap(&self) -> u64 {
        self.inner.mem_cap()
    }
    fn base(&self) -> Base {
        self.inner.base()
    }
    fn topology(&self) -> TopologyRef {
        self.inner.topology()
    }

    fn alloc(&mut self, p: ProcId, data: Vec<u32>) -> Result<Slot> {
        self.check_alive(p)?;
        match self.draw(p, Site::Alloc) {
            None => self.inner.alloc(p, data),
            Some(FaultKind::AllocFail) => Err(anyhow!(
                "processor {p}: allocation failed (injected fault)"
            )),
            Some(FaultKind::Crash) => {
                self.crashed[p] = true;
                Err(anyhow!("processor {p}: crashed (injected fault)"))
            }
            Some(k) => unreachable!("{k:?} not applicable at an alloc site"),
        }
    }

    fn free(&mut self, p: ProcId, slot: Slot) {
        let _ = self.draw(p, Site::Neutral);
        self.inner.free(p, slot);
    }

    fn read(&self, p: ProcId, slot: Slot) -> Result<Vec<u32>> {
        self.check_alive(p)?;
        self.inner.read(p, slot)
    }

    fn replace(&mut self, p: ProcId, slot: Slot, data: Vec<u32>) -> Result<()> {
        self.check_alive(p)?;
        match self.draw(p, Site::Alloc) {
            None => self.inner.replace(p, slot, data),
            Some(FaultKind::AllocFail) => Err(anyhow!(
                "processor {p}: replace failed (injected fault)"
            )),
            Some(FaultKind::Crash) => {
                self.crashed[p] = true;
                Err(anyhow!("processor {p}: crashed (injected fault)"))
            }
            Some(k) => unreachable!("{k:?} not applicable at an alloc site"),
        }
    }

    fn compute(&mut self, p: ProcId, ops: u64) {
        let _ = self.draw(p, Site::Neutral);
        self.inner.compute(p, ops);
    }

    fn local<R, F>(&mut self, p: ProcId, f: F) -> Result<R>
    where
        R: Send + 'static,
        F: FnOnce(&Base, &mut Ops) -> R + Send + 'static,
    {
        self.check_alive(p)?;
        let _ = self.draw(p, Site::Neutral);
        self.inner.local(p, f)
    }

    fn compute_slot(
        &mut self,
        p: ProcId,
        inputs: &[Slot],
        consume: bool,
        f: SlotComputation,
    ) -> Result<Slot> {
        self.check_alive(p)?;
        match self.draw(p, Site::Compute) {
            None => self.inner.compute_slot(p, inputs, consume, f),
            Some(FaultKind::ComputeFail) => Err(anyhow!(
                "processor {p}: leaf computation failed (injected fault)"
            )),
            Some(FaultKind::Crash) => {
                self.crashed[p] = true;
                Err(anyhow!("processor {p}: crashed (injected fault)"))
            }
            Some(k) => unreachable!("{k:?} not applicable at a compute site"),
        }
    }

    fn send(&mut self, src: ProcId, dst: ProcId, data: Vec<u32>) -> Result<Slot> {
        // The duplicate closure needs its own payload copy, but a
        // cloned payload only matters when a plan can actually draw
        // DupMsg. Without a plan (the scheduler's fault-free default)
        // skip straight to the inner engine: `decide` would draw
        // nothing and advance nothing, so this is exactly equivalent —
        // minus one whole-payload clone per send.
        if self.plan.is_none() {
            self.check_alive(src)?;
            self.check_alive(dst)?;
            return self.inner.send(src, dst, data);
        }
        let dup = data.clone();
        self.faulty_send(
            src,
            dst,
            move |m| m.send(src, dst, data),
            move |m| m.send(src, dst, dup),
        )
    }

    fn send_copy(&mut self, src: ProcId, dst: ProcId, slot: Slot) -> Result<Slot> {
        self.faulty_send(
            src,
            dst,
            move |m| m.send_copy(src, dst, slot),
            move |m| m.send_copy(src, dst, slot),
        )
    }

    fn send_move(&mut self, src: ProcId, dst: ProcId, slot: Slot) -> Result<Slot> {
        // The duplicate of a move is a copy — the real delivery then
        // moves the slot.
        self.faulty_send(
            src,
            dst,
            move |m| m.send_move(src, dst, slot),
            move |m| m.send_copy(src, dst, slot),
        )
    }

    fn send_range(
        &mut self,
        src: ProcId,
        dst: ProcId,
        slot: Slot,
        range: Range<usize>,
    ) -> Result<Slot> {
        let dup_range = range.clone();
        self.faulty_send(
            src,
            dst,
            move |m| m.send_range(src, dst, slot, range),
            move |m| m.send_range(src, dst, slot, dup_range),
        )
    }

    fn barrier(&mut self, procs: &[ProcId]) -> Result<()> {
        // Draw first (op indices advance for every participant, crashed
        // or not — the deterministic stream must not depend on crash
        // state), then gate: a rendezvous including a crashed processor
        // reports it instead of silently joining around the corpse.
        for &p in procs {
            if let Some(FaultKind::Stall) = self.draw(p, Site::Barrier) {
                let skew = self.plan.as_ref().map(|c| c.stall_ops).unwrap_or(0);
                self.inner.compute(p, skew);
            }
        }
        for &p in procs {
            self.check_alive(p)?;
        }
        self.inner.barrier(procs)
    }

    fn proc_view(&self, p: ProcId) -> Result<ProcView> {
        self.check_alive(p)?;
        self.inner.proc_view(p)
    }
    fn critical(&self) -> Clock {
        self.inner.critical()
    }
    fn stats(&self) -> MachineStats {
        self.inner.stats()
    }
    fn mem_peak_max(&self) -> u64 {
        self.inner.mem_peak_max()
    }
    fn mem_peak_total(&self) -> u64 {
        self.inner.mem_peak_total()
    }
    fn mem_used_total(&self) -> u64 {
        self.inner.mem_used_total()
    }
    fn purge(&mut self, p: ProcId) {
        self.inner.purge(p);
    }
    fn event(&mut self, msg: &str) {
        self.inner.event(msg);
    }
    // Buffer recycling is purely physical — no fault draw, straight
    // delegation so the inner engine's pool stays reachable. read_into
    // mirrors `read` exactly (check_alive + delegate, no draw), so the
    // fault stream is identical while the inner engine's zero-copy
    // append path stays reachable.
    fn read_into(&self, p: ProcId, slot: Slot, buf: &mut Vec<u32>) -> Result<()> {
        self.check_alive(p)?;
        self.inner.read_into(p, slot, buf)
    }
    fn take_buffer(&mut self, cap: usize) -> Vec<u32> {
        self.inner.take_buffer(cap)
    }
    fn give_buffer(&mut self, buf: Vec<u32>) {
        self.inner.give_buffer(buf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Machine;

    fn mk(p: usize) -> Machine {
        Machine::unbounded(p, Base::new(16))
    }

    /// A fixed little program touching every site category.
    fn drive(m: &mut FaultyMachine<Machine>) -> Result<Vec<u32>> {
        let a = m.alloc(0, vec![1, 2, 3])?;
        let s = m.send_copy(0, 1, a)?;
        m.compute(1, 5);
        let out = m.compute_slot(
            1,
            &[s],
            true,
            Box::new(|inp, _b, ops| {
                ops.charge(inp[0].len() as u64);
                inp[0].iter().map(|d| d + 1).collect()
            }),
        )?;
        m.barrier(&[0, 1])?;
        let got = m.read(1, out)?;
        m.free(1, out);
        m.free(0, a);
        Ok(got)
    }

    #[test]
    fn passthrough_is_transparent() {
        // Same program, wrapped and unwrapped: identical products AND
        // identical cost triples (the zero-fault identity invariant).
        let mut plain = FaultyMachine::passthrough(mk(2));
        let got = drive(&mut plain).unwrap();
        assert_eq!(got, vec![2, 3, 4]);

        let mut zero_rate = FaultyMachine::new(mk(2), FaultConfig::new(7, 0.0));
        let got2 = drive(&mut zero_rate).unwrap();
        assert_eq!(got, got2);
        assert_eq!(plain.critical(), zero_rate.critical());
        assert_eq!(plain.total_injected(), 0);
        assert_eq!(zero_rate.total_injected(), 0);
    }

    #[test]
    fn injection_is_deterministic_and_recorded_exactly() {
        // Rate 1 on a Stall-only plan: every send and barrier slot
        // stalls, nothing fails, and two runs record identical event
        // logs (the "exact fault counts" contract).
        let plan = FaultConfig::new(0xFA17, 1.0).only(&[FaultKind::Stall]);
        let run = |plan: FaultConfig| {
            let mut m = FaultyMachine::new(mk(2), plan);
            let got = drive(&mut m).unwrap();
            (got, m.events().to_vec(), m.critical())
        };
        let (g1, e1, c1) = run(plan.clone());
        let (g2, e2, c2) = run(plan);
        assert_eq!(g1, vec![2, 3, 4]);
        assert_eq!(g1, g2);
        assert_eq!(e1, e2, "fault plans must replay bit-identically");
        assert_eq!(c1, c2);
        // drive() has one send (proc 0) and one 2-proc barrier: exactly
        // three Stall slots.
        assert_eq!(e1.len(), 3, "events: {e1:?}");
        assert!(e1.iter().all(|e| e.kind == FaultKind::Stall));
        // Stalls inflate the clock by stall_ops each.
        let mut clean = FaultyMachine::passthrough(mk(2));
        drive(&mut clean).unwrap();
        assert!(c1.ops > clean.critical().ops);
    }

    #[test]
    fn drop_fails_the_call_and_records() {
        let plan = FaultConfig::new(3, 1.0).only(&[FaultKind::DropMsg]);
        let mut m = FaultyMachine::new(mk(2), plan);
        let a = m.alloc(0, vec![9]).unwrap();
        let err = m.send_copy(0, 1, a).unwrap_err();
        assert!(err.to_string().contains("dropped"), "{err}");
        assert_eq!(m.total_injected(), 1);
        assert_eq!(m.fault_count(0), 1);
        assert_eq!(m.fault_count(1), 0);
        // The wire was never used and the receiver holds nothing.
        assert_eq!(m.inner().stats.total_msgs, 0);
        assert_eq!(m.inner().proc(1).mem_used(), 0);
    }

    #[test]
    fn duplicate_inflates_cost_but_not_product() {
        let plan = FaultConfig::new(11, 1.0).only(&[FaultKind::DupMsg]);
        let mut m = FaultyMachine::new(mk(2), plan);
        let a = m.alloc(0, vec![4, 5]).unwrap();
        let s = m.send_copy(0, 1, a).unwrap();
        assert_eq!(m.read(1, s).unwrap(), vec![4, 5]);
        // Two deliveries on the wire, one resident copy.
        assert_eq!(m.inner().stats.total_msgs, 2);
        assert_eq!(m.inner().stats.total_words, 4);
        assert_eq!(m.inner().proc(1).mem_used(), 2);
    }

    #[test]
    fn reorder_charges_wire_and_fails() {
        let plan = FaultConfig::new(5, 1.0).only(&[FaultKind::ReorderMsg]);
        let mut m = FaultyMachine::new(mk(2), plan);
        let a = m.alloc(0, vec![8; 4]).unwrap();
        let err = m.send_copy(0, 1, a).unwrap_err();
        assert!(err.to_string().contains("out of order"), "{err}");
        assert_eq!(m.inner().stats.total_msgs, 1, "wire cost is charged");
        assert_eq!(m.inner().proc(1).mem_used(), 0, "payload discarded");
    }

    #[test]
    fn crash_sticks_until_heal() {
        let plan = FaultConfig::new(0xDEAD, 1.0).only(&[FaultKind::Crash]);
        let mut m = FaultyMachine::new(mk(2), plan);
        assert!(m.alloc(0, vec![1]).is_err());
        assert!(m.is_crashed(0));
        // Every fallible op on the crashed proc errors, including reads
        // and sends *to* it.
        assert!(m.read(0, 1).is_err());
        assert!(m.proc_view(0).is_err());
        assert!(m.send(1, 0, vec![2]).is_err());
        // Other processors are unaffected (suppress further injection
        // to observe the healthy path).
        m.set_suppressed(1, true);
        let b = m.alloc(1, vec![7]).unwrap();
        assert_eq!(m.read(1, b).unwrap(), vec![7]);
        // Heal: the processor serves again (suppressed here so the
        // rate-1.0 plan does not immediately re-crash it).
        m.heal(0);
        m.set_suppressed(0, true);
        let c = m.alloc(0, vec![3]).unwrap();
        assert_eq!(m.read(0, c).unwrap(), vec![3]);
    }

    #[test]
    fn alloc_and_compute_failures_fire_on_chosen_sites() {
        let plan = FaultConfig::new(1, 1.0).only(&[FaultKind::AllocFail]);
        let mut m = FaultyMachine::new(mk(1), plan);
        let err = m.alloc(0, vec![1]).unwrap_err();
        assert!(err.to_string().contains("allocation failed"), "{err}");

        let plan = FaultConfig::new(1, 1.0).only(&[FaultKind::ComputeFail]);
        let mut m = FaultyMachine::new(mk(1), plan);
        m.set_suppressed(0, true);
        let a = m.alloc(0, vec![1]).unwrap();
        m.set_suppressed(0, false);
        let err = m
            .compute_slot(0, &[a], false, Box::new(|_, _, _| vec![0]))
            .unwrap_err();
        assert!(err.to_string().contains("computation failed"), "{err}");
        // The event log names the (proc, op-index) pair that fired.
        let e = *m.events().last().unwrap();
        assert_eq!(e.proc, 0);
        assert_eq!(e.kind, FaultKind::ComputeFail);
    }

    #[test]
    fn per_hop_injection_draws_once_per_link() {
        use crate::sim::topology::Torus2D;
        use std::sync::Arc;
        // Stall-every-draw plan on the 4x4 torus: a 4-hop send draws
        // four stall events (one per physical link) and charges the
        // sender four times the skew; the payload still arrives.
        let plan = FaultConfig::new(1, 1.0).only(&[FaultKind::Stall]);
        let inner = Machine::with_topology(
            16,
            u64::MAX / 2,
            Base::new(16),
            Arc::new(Torus2D::for_procs(16)),
        );
        let mut m = FaultyMachine::new(inner, plan);
        let a = m.alloc(0, vec![5]).unwrap();
        let s = m.send_copy(0, 10, a).unwrap();
        assert_eq!(m.read(10, s).unwrap(), vec![5]);
        assert_eq!(m.total_injected(), 4, "events: {:?}", m.events());
        assert!(m.events().iter().all(|e| e.kind == FaultKind::Stall));
        assert_eq!(m.fault_count(0), 4, "all hop draws key on the sender");
        assert_eq!(m.inner().proc(0).clock.ops, 4 * 64);
    }

    #[test]
    fn barrier_errors_on_crashed_processor() {
        let plan = FaultConfig::new(0xDEAD, 1.0).only(&[FaultKind::Crash]);
        let mut m = FaultyMachine::new(mk(2), plan);
        assert!(m.alloc(0, vec![1]).is_err());
        assert!(m.is_crashed(0));
        let err = m.barrier(&[0, 1]).unwrap_err();
        assert!(err.to_string().contains("crashed"), "{err}");
        m.heal(0);
        m.set_suppressed(0, true);
        m.set_suppressed(1, true);
        m.barrier(&[0, 1]).unwrap();
    }

    #[test]
    fn reset_op_index_replays_the_same_pattern() {
        // Two identical programs separated by a reset draw identical
        // fault decisions — the scheduler's per-job epoch argument.
        let plan = FaultConfig::new(0xEE, 0.5).only(&[FaultKind::Stall]);
        let mut m = FaultyMachine::new(mk(2), plan);
        drive(&mut m).ok();
        let first: Vec<FaultEvent> = m.events().to_vec();
        let n_first = first.len();
        m.reset_op_index(&[0, 1]);
        drive(&mut m).ok();
        let second = &m.events()[n_first..];
        assert_eq!(first.as_slice(), second, "epoch replay must match");
    }

    #[test]
    fn suppression_stops_injection_without_touching_counters() {
        let plan = FaultConfig::new(9, 1.0).only(&[FaultKind::DropMsg]);
        let mut m = FaultyMachine::new(mk(2), plan);
        m.set_suppressed(0, true);
        m.set_suppressed(1, true);
        let got = drive(&mut m).unwrap();
        assert_eq!(got, vec![2, 3, 4]);
        assert_eq!(m.total_injected(), 0);
    }
}

//! The machine-model layer: the paper's distributed-memory machine (§2)
//! behind the pluggable [`MachineApi`] trait, with three execution
//! engines — the deterministic cost-model simulator ([`Machine`],
//! critical-path accounting per §2.2), the real-threads executor
//! ([`ThreadedMachine`], one OS thread per processor), and the
//! real-network executor ([`SocketMachine`], one OS process per group
//! of processors over length-prefixed socket frames, with optional
//! heartbeat liveness and dead-group respawn for self-healing fleets)
//! — plus
//! [`FaultyMachine`], a deterministic seeded fault-injection wrapper
//! over any engine (the chaos/soak layer). Above the engines,
//! [`collectives`] provides the shared tree-structured communication
//! schedules every algorithm goes through; below them, [`topology`]
//! maps logical sends onto a pluggable physical interconnect
//! (fully-connected / 2D torus / hierarchical cluster) with per-hop
//! charging. See DESIGN.md, "Collectives & topologies".
//!
//! ## Model
//!
//! `P` processors, each with a private memory of `M` words, connected
//! point-to-point. A memory word holds one base-`s` digit. Processors
//! exchange messages; in any step a processor either sends or receives
//! (not both). Performance metrics, counted along the *critical execution
//! path* (Yang & Miller):
//!
//! * `T` — digit-wise computations,
//! * `BW` — memory words transferred ("sent or received by at least one
//!   processor", i.e. each transfer counted once),
//! * `L` — number of messages,
//! * `M(n,P)` — peak words resident in any single local memory.
//!
//! ## Critical-path accounting via logical clocks
//!
//! Every processor carries a [`Clock`] `{ops, words, msgs}`. Local
//! computation adds to `ops`. A send adds the payload size to the
//! sender's `words` and 1 to its `msgs`; the message carries a snapshot
//! of the sender's clock, and on delivery the receiver's clock becomes
//! the component-wise maximum of its own clock and the snapshot. The
//! component-wise max over all processors at the end of the run is
//! exactly the per-metric critical-path count the paper defines:
//! operations executed in parallel by distinct processors are counted
//! once, and a transfer is charged once even though two processors take
//! part in it.
//!
//! Because costs accrue on per-processor clocks, *parallel* recursive
//! calls on disjoint processor sequences may be executed sequentially by
//! the host program: their costs land on disjoint clocks and combine by
//! `max` at the next synchronizing message, which is precisely the
//! parallel semantics. Depth-first (sequential) steps on the *same*
//! processors accumulate on the same clocks. This is what makes every
//! theorem in the paper directly measurable.
//!
//! ## Memory ledger
//!
//! Every value a processor stores is an explicit allocation against its
//! capacity `M`; exceeding `M` is a hard error (`MemoryExceeded`). Peak
//! usage is recorded per processor, making the paper's memory-requirement
//! statements (e.g. Theorem 11's `12n/√P`) checkable rather than assumed.

pub mod api;
pub mod collectives;
pub mod dist;
pub mod faulty;
pub mod machine;
pub mod seq;
pub mod socket;
pub mod threaded;
pub mod topology;

pub use api::{MachineApi, ProcView, SlotComputation};
pub use collectives::{all_to_all, broadcast, fanout, gather, reduce, scatter, shift};
pub use dist::DistInt;
pub use faulty::{FaultConfig, FaultEvent, FaultKind, FaultyMachine};
pub use machine::{Machine, MachineStats, ProcId, Slot};
pub use seq::Seq;
pub use socket::{
    resolve_worker_bin, socket_available, socket_worker_main, SocketConfig, SocketMachine,
    SocketTransport,
};
pub use threaded::{payload_into_vec, ThreadedMachine, ThreadedReport};
pub use topology::{FullyConnected, HierCluster, Topology, TopologyKind, TopologyRef, Torus2D};

/// Per-processor logical clock; component-wise max is the merge operator.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Clock {
    /// Digit-wise computations (the paper's `T`).
    pub ops: u64,
    /// Memory words transferred (the paper's `BW`).
    pub words: u64,
    /// Messages (the paper's `L`).
    pub msgs: u64,
}

impl Clock {
    /// Component-wise maximum (the merge applied at message delivery).
    #[inline]
    pub fn join(&self, other: &Clock) -> Clock {
        Clock {
            ops: self.ops.max(other.ops),
            words: self.words.max(other.words),
            msgs: self.msgs.max(other.msgs),
        }
    }

    /// Component-wise difference assuming `self >= earlier` per component.
    /// Used by experiments to isolate a phase's cost.
    pub fn since(&self, earlier: &Clock) -> Clock {
        Clock {
            ops: self.ops.saturating_sub(earlier.ops),
            words: self.words.saturating_sub(earlier.words),
            msgs: self.msgs.saturating_sub(earlier.msgs),
        }
    }
}

impl std::fmt::Display for Clock {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "T={} BW={} L={}",
            self.ops, self.words, self.msgs
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_join_is_componentwise_max() {
        let a = Clock { ops: 10, words: 1, msgs: 5 };
        let b = Clock { ops: 3, words: 9, msgs: 5 };
        let j = a.join(&b);
        assert_eq!(j, Clock { ops: 10, words: 9, msgs: 5 });
    }

    #[test]
    fn clock_since() {
        let a = Clock { ops: 10, words: 4, msgs: 5 };
        let b = Clock { ops: 3, words: 9, msgs: 5 };
        let d = a.since(&b);
        assert_eq!(d, Clock { ops: 7, words: 0, msgs: 0 });
    }
}

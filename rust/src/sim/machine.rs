//! The machine: processors, memory ledgers, message transport.
//!
//! Physical storage is a machine-wide **slab** — a dense `Vec` of
//! cells indexed directly by `Slot`, with freed indices recycled
//! through a free list — plus a **buffer pool** that cycles retired
//! payload backing stores back into the alloc/send/assembly paths.
//! Both are invisible to the cost model: the ledger charges payload
//! *lengths* against `M`, and slot identity is opaque to every caller,
//! so the golden cost grid is bit-identical to the old hash-map store.

use super::api::{MachineApi, ProcView, SlotComputation};
use super::topology::{FullyConnected, TopologyRef};
use super::Clock;
use crate::bignum::{Base, Ops};
use crate::error::{bail, Result};
use std::sync::Arc;

/// Processor identifier: index into the machine's processor table.
pub type ProcId = usize;

/// Handle to a value resident in some processor's local memory.
/// On the cost-model engine this encodes a slab index (plus one, so 0
/// stays invalid — low 32 bits) and the cell's generation (high 32
/// bits); freed indices are recycled through a free list, so the
/// slab's footprint tracks *live* values, while the generation keeps
/// stale handles failing loudly after a cell is reused.
pub type Slot = u64;

/// One simulated processor: logical clock + memory ledger. The stored
/// payloads live in the machine-wide slab (slots are slab indices), so
/// per-slot access is an array index, not a hash probe.
#[derive(Debug)]
pub struct Processor {
    pub clock: Clock,
    mem_used: u64,
    mem_peak: u64,
    mem_cap: u64,
    /// Total ops executed by this processor (aggregate work, not
    /// critical path): used by the speedup/efficiency experiments.
    pub total_ops: u64,
}

impl Processor {
    fn new(mem_cap: u64) -> Self {
        Processor {
            clock: Clock::default(),
            mem_used: 0,
            mem_peak: 0,
            mem_cap,
            total_ops: 0,
        }
    }

    #[inline]
    pub fn mem_used(&self) -> u64 {
        self.mem_used
    }
    #[inline]
    pub fn mem_peak(&self) -> u64 {
        self.mem_peak
    }
}

/// Aggregate (whole-machine) statistics, complementing the critical-path
/// clock: total communicated volume, total messages, total work.
#[derive(Clone, Copy, Debug, Default)]
pub struct MachineStats {
    pub total_words: u64,
    pub total_msgs: u64,
    pub total_ops: u64,
}

/// One slab cell: either a live value with its owning processor, or a
/// vacant cell waiting on the free list. The generation counter bumps
/// on every free, and the cell's current generation is baked into the
/// `Slot` handle — so a stale handle to a recycled cell fails as
/// loudly as it did under the old never-reused numbering, instead of
/// silently aliasing the cell's next occupant.
#[derive(Debug)]
enum SlabEntry {
    Vacant {
        gen: u32,
    },
    Full {
        owner: ProcId,
        gen: u32,
        data: Vec<u32>,
    },
}

/// Recycles payload buffers between the slab and the send/assembly
/// paths so steady-state alloc/free/send traffic stops round-tripping
/// the global allocator. Purely physical: the ledger charges `len()`,
/// never capacity, so pooling is cost-invisible.
#[derive(Debug, Default)]
struct BufPool {
    bufs: Vec<Vec<u32>>,
}

/// Retention caps: enough buffers for the deepest recursion's transient
/// population, without hoarding arbitrarily large backing stores (the
/// per-buffer word cap also bounds how much invisible capacity an
/// unsized `take_buffer(0)` request can pin under a small slot).
const POOL_MAX_BUFS: usize = 64;
const POOL_MAX_WORDS: usize = 1 << 18;

impl BufPool {
    fn take(&mut self, cap: usize) -> Vec<u32> {
        match self.bufs.pop() {
            // A grossly oversized buffer handed to a *sized* tiny
            // request would stay pinned in the slab under a small
            // long-lived slot (the ledger charges lengths, so the
            // overshoot would be invisible dark memory) — drop it back
            // to the allocator instead of recycling it. `cap == 0`
            // means "size unknown" (assembly loops that discover their
            // payload as they read): any recycled capacity is welcome
            // there, and the per-buffer retention cap bounds the
            // worst-case overshoot.
            Some(b) if cap > 0 && b.capacity() > (cap.max(64)).saturating_mul(8) => {
                drop(b);
                Vec::with_capacity(cap)
            }
            Some(mut b) => {
                b.reserve(cap);
                b
            }
            None => Vec::with_capacity(cap),
        }
    }

    fn give(&mut self, mut b: Vec<u32>) {
        if self.bufs.len() < POOL_MAX_BUFS && b.capacity() > 0 && b.capacity() <= POOL_MAX_WORDS {
            b.clear();
            self.bufs.push(b);
        }
    }
}

/// The distributed-memory machine (see module docs for the model).
#[derive(Debug)]
pub struct Machine {
    procs: Vec<Processor>,
    pub base: Base,
    topo: TopologyRef,
    /// Dense value store: `Slot` encodes (index + 1, generation).
    /// Vacant cells are chained through `free_list` and reused by the
    /// next alloc, which bumps nothing — the bump happened at free.
    slab: Vec<SlabEntry>,
    free_list: Vec<usize>,
    pool: BufPool,
    pub stats: MachineStats,
    /// When true, messages passed to [`Machine::event`] are recorded in
    /// `trace_log` (retrievable via [`Machine::trace_log`]). The flag
    /// only gates that recording; it does not change error behaviour —
    /// allocation failures return `Err` either way. Default false.
    pub trace: bool,
    trace_log: Vec<String>,
}

impl Machine {
    /// Create a machine with `p` processors, each with `mem_cap` words of
    /// local memory, computing over digits of `base`, on the default
    /// fully-connected interconnect (the paper's implicit network).
    pub fn new(p: usize, mem_cap: u64, base: Base) -> Self {
        Machine::with_topology(p, mem_cap, base, Arc::new(FullyConnected))
    }

    /// [`Machine::new`] on an explicit network topology: sends are
    /// charged hop by hop along `topo.route(src, dst)` with per-link
    /// bandwidth weights (see the `topology` module docs).
    pub fn with_topology(p: usize, mem_cap: u64, base: Base, topo: TopologyRef) -> Self {
        assert!(p >= 1, "need at least one processor");
        Machine {
            procs: (0..p).map(|_| Processor::new(mem_cap)).collect(),
            base,
            topo,
            slab: Vec::new(),
            free_list: Vec::new(),
            pool: BufPool::default(),
            stats: MachineStats::default(),
            trace: false,
            trace_log: Vec::new(),
        }
    }

    /// Convenience: effectively unbounded local memories (for the MI
    /// execution mode, which by definition ignores M).
    pub fn unbounded(p: usize, base: Base) -> Self {
        Machine::new(p, u64::MAX / 2, base)
    }

    #[inline]
    pub fn n_procs(&self) -> usize {
        self.procs.len()
    }

    #[inline]
    pub fn mem_cap(&self) -> u64 {
        self.procs[0].mem_cap
    }

    pub fn proc(&self, p: ProcId) -> &Processor {
        &self.procs[p]
    }

    // ----- memory ledger ---------------------------------------------

    /// Encode a slab index + cell generation as a `Slot` handle
    /// (index+1 in the low 32 bits so 0 stays invalid, generation in
    /// the high 32).
    #[inline]
    fn encode_slot(idx: usize, gen: u32) -> Slot {
        debug_assert!(idx < u32::MAX as usize, "slab index overflows slot encoding");
        ((gen as u64) << 32) | (idx as u64 + 1)
    }

    /// Slab index of `slot` if the cell is live, owned by `p`, and of
    /// the handle's generation (a stale handle to a recycled cell
    /// panics exactly like the old never-reused numbering did).
    #[inline]
    fn slot_idx(&self, p: ProcId, slot: Slot, what: &str) -> usize {
        let idx = ((slot & u32::MAX as u64) as usize).wrapping_sub(1);
        let gen = (slot >> 32) as u32;
        match self.slab.get(idx) {
            Some(SlabEntry::Full { owner, gen: g, .. }) if *owner == p && *g == gen => idx,
            _ => panic!("processor {p}: {what} of unknown slot {slot}"),
        }
    }

    /// Allocate `data` in `p`'s local memory. Fails if the capacity `M`
    /// would be exceeded — this is the mechanism that makes the paper's
    /// memory-requirement statements falsifiable.
    pub fn alloc(&mut self, p: ProcId, data: Vec<u32>) -> Result<Slot> {
        let words = data.len() as u64;
        let proc = &mut self.procs[p];
        if proc.mem_used + words > proc.mem_cap {
            bail!(
                "processor {p}: local memory exceeded (used {} + {} > cap {})",
                proc.mem_used,
                words,
                proc.mem_cap
            );
        }
        proc.mem_used += words;
        proc.mem_peak = proc.mem_peak.max(proc.mem_used);
        let (idx, gen) = match self.free_list.pop() {
            Some(idx) => {
                let &SlabEntry::Vacant { gen } = &self.slab[idx] else {
                    unreachable!("free list held a live cell");
                };
                self.slab[idx] = SlabEntry::Full { owner: p, gen, data };
                (idx, gen)
            }
            None => {
                self.slab.push(SlabEntry::Full { owner: p, gen: 0, data });
                (self.slab.len() - 1, 0)
            }
        };
        Ok(Machine::encode_slot(idx, gen))
    }

    /// Allocate a single scalar word (flags, carries).
    pub fn alloc_scalar(&mut self, p: ProcId, v: u32) -> Result<Slot> {
        self.alloc(p, vec![v])
    }

    /// Free a slot, returning its contents. The cell's generation bumps
    /// so any handle still pointing at it is dead from here on.
    pub fn free(&mut self, p: ProcId, slot: Slot) -> Vec<u32> {
        let idx = self.slot_idx(p, slot, "free");
        let gen = (slot >> 32) as u32;
        let entry = std::mem::replace(
            &mut self.slab[idx],
            SlabEntry::Vacant { gen: gen.wrapping_add(1) },
        );
        let SlabEntry::Full { data, .. } = entry else {
            unreachable!("slot_idx returned a vacant cell");
        };
        self.free_list.push(idx);
        self.procs[p].mem_used -= data.len() as u64;
        data
    }

    /// Read a slot's contents.
    pub fn read(&self, p: ProcId, slot: Slot) -> &[u32] {
        let idx = self.slot_idx(p, slot, "read");
        match &self.slab[idx] {
            SlabEntry::Full { data, .. } => data,
            SlabEntry::Vacant { .. } => unreachable!(),
        }
    }

    /// Read a scalar slot.
    pub fn read_scalar(&self, p: ProcId, slot: Slot) -> u32 {
        let d = self.read(p, slot);
        debug_assert_eq!(d.len(), 1);
        d[0]
    }

    /// Overwrite a slot in place (same or different width; ledger updated).
    pub fn replace(&mut self, p: ProcId, slot: Slot, data: Vec<u32>) -> Result<()> {
        let idx = self.slot_idx(p, slot, "replace");
        let SlabEntry::Full { data: old, .. } = &mut self.slab[idx] else {
            unreachable!()
        };
        let old_len = old.len() as u64;
        let new_len = data.len() as u64;
        let proc = &mut self.procs[p];
        if proc.mem_used - old_len + new_len > proc.mem_cap {
            bail!(
                "processor {p}: local memory exceeded on replace ({} -> {} words, cap {})",
                old_len,
                new_len,
                proc.mem_cap
            );
        }
        proc.mem_used = proc.mem_used - old_len + new_len;
        proc.mem_peak = proc.mem_peak.max(proc.mem_used);
        let retired = std::mem::replace(old, data);
        self.pool.give(retired);
        Ok(())
    }

    // ----- computation ------------------------------------------------

    /// Charge `ops` digit operations to `p`'s clock.
    pub fn compute(&mut self, p: ProcId, ops: u64) {
        self.procs[p].clock.ops += ops;
        self.procs[p].total_ops += ops;
        self.stats.total_ops += ops;
    }

    /// Run a local computation whose digit-op count is tracked by an
    /// [`Ops`] counter, charging the result to `p`.
    pub fn local<R>(&mut self, p: ProcId, f: impl FnOnce(&Base, &mut Ops) -> R) -> R {
        let mut ops = Ops::default();
        let base = self.base;
        let r = f(&base, &mut ops);
        self.compute(p, ops.get());
        r
    }

    // ----- communication ----------------------------------------------

    /// Send `data` from `src` to `dst` as one logical message;
    /// allocates the payload in `dst`'s memory and returns the new slot.
    ///
    /// Cost semantics (see module docs): each physical hop of the
    /// topology's route is charged to its link sender's clock (payload
    /// words × link weight, plus one message), and the next hop's clock
    /// joins the post-charge snapshot, so every processor on the route
    /// ends at least at the transfer's completion time on every metric.
    /// On the fully-connected default the route is the direct edge and
    /// this degenerates to the paper's charge-once-to-the-sender rule.
    /// Relays never touch their memory ledgers (wire forwarding); only
    /// `dst` allocates — exactly mirroring the threaded engine's
    /// store-and-forward, so the engines stay cost-identical on every
    /// topology.
    pub fn send(&mut self, src: ProcId, dst: ProcId, data: Vec<u32>) -> Result<Slot> {
        assert_ne!(src, dst, "send to self is a local operation");
        // Direct-edge fast path: `hops` is O(1) on every shipped
        // topology, so single-link transfers (ALL transfers on the
        // fully-connected default) never materialize a route vector —
        // the hot path stays allocation-free beyond the payload.
        if self.topo.hops(src, dst) == 1 {
            self.hop_charge(src, dst, data.len() as u64);
            return self.alloc(dst, data);
        }
        let route = self.topo.route(src, dst);
        debug_assert!(route.len() >= 2, "route must span the endpoints");
        let words = data.len() as u64;
        for hop in route.windows(2) {
            self.hop_charge(hop[0], hop[1], words);
        }
        self.alloc(dst, data)
    }

    /// Charge one physical hop `a → b` of `words` payload words: link
    /// sender pays `words × link weight` and one message, `b`'s clock
    /// joins the post-charge snapshot.
    fn hop_charge(&mut self, a: ProcId, b: ProcId, words: u64) {
        let hop_words = words * self.topo.link_bw_weight(a, b);
        self.procs[a].clock.words += hop_words;
        self.procs[a].clock.msgs += 1;
        self.stats.total_words += hop_words;
        self.stats.total_msgs += 1;
        let snapshot = self.procs[a].clock;
        let bclock = &mut self.procs[b].clock;
        *bclock = bclock.join(&snapshot);
    }

    /// Send a copy of an existing slot (source keeps its copy). The
    /// payload is staged in a pooled buffer, so steady-state copy
    /// traffic reuses retired backing stores instead of allocating.
    pub fn send_copy(&mut self, src: ProcId, dst: ProcId, slot: Slot) -> Result<Slot> {
        let idx = self.slot_idx(src, slot, "send_copy");
        let len = match &self.slab[idx] {
            SlabEntry::Full { data, .. } => data.len(),
            SlabEntry::Vacant { .. } => unreachable!(),
        };
        let mut data = self.pool.take(len);
        if let SlabEntry::Full { data: d, .. } = &self.slab[idx] {
            data.extend_from_slice(d);
        }
        self.send(src, dst, data)
    }

    /// Send an existing slot and free it at the source ("...and then
    /// removes it from its local memory", as the paper repeatedly does).
    pub fn send_move(&mut self, src: ProcId, dst: ProcId, slot: Slot) -> Result<Slot> {
        let data = self.free(src, slot);
        self.send(src, dst, data)
    }

    /// Send a sub-range of a slot's digits (copy; pooled staging as in
    /// [`Machine::send_copy`]).
    pub fn send_range(
        &mut self,
        src: ProcId,
        dst: ProcId,
        slot: Slot,
        range: std::ops::Range<usize>,
    ) -> Result<Slot> {
        let idx = self.slot_idx(src, slot, "send_range");
        let mut data = self.pool.take(range.len());
        if let SlabEntry::Full { data: d, .. } = &self.slab[idx] {
            data.extend_from_slice(&d[range]);
        }
        self.send(src, dst, data)
    }

    /// Drop every slot resident on `p`; the ledger returns to zero used
    /// words (peak is kept — it already happened). Scheduler support:
    /// reclaims a shard whose job failed and leaked its working set.
    /// O(slab) — acceptable for the rare failure path.
    pub fn purge(&mut self, p: ProcId) {
        for idx in 0..self.slab.len() {
            let gen = match &self.slab[idx] {
                SlabEntry::Full { owner, gen, .. } if *owner == p => *gen,
                _ => continue,
            };
            let entry = std::mem::replace(
                &mut self.slab[idx],
                SlabEntry::Vacant { gen: gen.wrapping_add(1) },
            );
            let SlabEntry::Full { data, .. } = entry else {
                unreachable!()
            };
            self.free_list.push(idx);
            self.pool.give(data);
        }
        self.procs[p].mem_used = 0;
    }

    /// Synchronize a set of processors (a barrier): all clocks join.
    /// The paper's algorithms are bulk-synchronous within each phase;
    /// explicit barriers are only used by the experiment harness between
    /// phases, not inside the algorithms (which synchronize via their
    /// actual messages).
    pub fn barrier(&mut self, procs: &[ProcId]) {
        let mut j = Clock::default();
        for &p in procs {
            j = j.join(&self.procs[p].clock);
        }
        for &p in procs {
            self.procs[p].clock = j;
        }
    }

    // ----- reporting ----------------------------------------------------

    /// Critical-path cost: component-wise max over all processors.
    pub fn critical(&self) -> Clock {
        let mut j = Clock::default();
        for p in &self.procs {
            j = j.join(&p.clock);
        }
        j
    }

    /// Peak local-memory usage over all processors (the paper's M(n,P)).
    pub fn mem_peak_max(&self) -> u64 {
        self.procs.iter().map(|p| p.mem_peak).max().unwrap_or(0)
    }

    /// Sum of peak local-memory usage (the "total memory O(n)" claim).
    pub fn mem_peak_total(&self) -> u64 {
        self.procs.iter().map(|p| p.mem_peak).sum()
    }

    /// Current resident words across all processors.
    pub fn mem_used_total(&self) -> u64 {
        self.procs.iter().map(|p| p.mem_used).sum()
    }

    /// Record a trace event (no cost) when tracing is enabled.
    pub fn event(&mut self, msg: impl Into<String>) {
        if self.trace {
            self.trace_log.push(msg.into());
        }
    }

    pub fn trace_log(&self) -> &[String] {
        &self.trace_log
    }
}

/// The cost-model execution engine: [`Machine`]'s inherent operations
/// *are* the [`MachineApi`] contract; this impl adapts the borrowed
/// return types (`read`) and runs `compute_slot` synchronously in
/// program order, which is exactly the deterministic reference
/// semantics the threaded backend is property-tested against.
impl MachineApi for Machine {
    fn n_procs(&self) -> usize {
        Machine::n_procs(self)
    }
    fn mem_cap(&self) -> u64 {
        Machine::mem_cap(self)
    }
    fn base(&self) -> Base {
        self.base
    }
    fn topology(&self) -> TopologyRef {
        Arc::clone(&self.topo)
    }

    fn alloc(&mut self, p: ProcId, data: Vec<u32>) -> Result<Slot> {
        Machine::alloc(self, p, data)
    }
    fn free(&mut self, p: ProcId, slot: Slot) {
        let retired = Machine::free(self, p, slot);
        self.pool.give(retired);
    }
    fn read(&self, p: ProcId, slot: Slot) -> Result<Vec<u32>> {
        Ok(Machine::read(self, p, slot).to_vec())
    }
    fn read_into(&self, p: ProcId, slot: Slot, buf: &mut Vec<u32>) -> Result<()> {
        buf.extend_from_slice(Machine::read(self, p, slot));
        Ok(())
    }
    fn replace(&mut self, p: ProcId, slot: Slot, data: Vec<u32>) -> Result<()> {
        Machine::replace(self, p, slot, data)
    }

    fn compute(&mut self, p: ProcId, ops: u64) {
        Machine::compute(self, p, ops);
    }
    fn local<R, F>(&mut self, p: ProcId, f: F) -> Result<R>
    where
        R: Send + 'static,
        F: FnOnce(&Base, &mut Ops) -> R + Send + 'static,
    {
        Ok(Machine::local(self, p, f))
    }
    fn compute_slot(
        &mut self,
        p: ProcId,
        inputs: &[Slot],
        consume: bool,
        f: SlotComputation,
    ) -> Result<Slot> {
        let base = self.base;
        let mut ops = Ops::default();
        let out = if consume {
            // Inputs are moved out of the slab (ledger freed before the
            // output allocates, as the paper's leaves require) and the
            // closure borrows them in place — no copies either way.
            let held: Vec<Vec<u32>> = inputs.iter().map(|&s| Machine::free(self, p, s)).collect();
            let views: Vec<&[u32]> = held.iter().map(|v| v.as_slice()).collect();
            let out = f(&views, &base, &mut ops);
            drop(views);
            for v in held {
                self.pool.give(v);
            }
            out
        } else {
            let views: Vec<&[u32]> = inputs.iter().map(|&s| Machine::read(self, p, s)).collect();
            f(&views, &base, &mut ops)
        };
        Machine::compute(self, p, ops.get());
        Machine::alloc(self, p, out)
    }

    fn send(&mut self, src: ProcId, dst: ProcId, data: Vec<u32>) -> Result<Slot> {
        Machine::send(self, src, dst, data)
    }
    fn send_copy(&mut self, src: ProcId, dst: ProcId, slot: Slot) -> Result<Slot> {
        Machine::send_copy(self, src, dst, slot)
    }
    fn send_move(&mut self, src: ProcId, dst: ProcId, slot: Slot) -> Result<Slot> {
        Machine::send_move(self, src, dst, slot)
    }
    fn send_range(
        &mut self,
        src: ProcId,
        dst: ProcId,
        slot: Slot,
        range: std::ops::Range<usize>,
    ) -> Result<Slot> {
        Machine::send_range(self, src, dst, slot, range)
    }
    fn barrier(&mut self, procs: &[ProcId]) -> Result<()> {
        Machine::barrier(self, procs);
        Ok(())
    }

    fn proc_view(&self, p: ProcId) -> Result<ProcView> {
        let proc = &self.procs[p];
        Ok(ProcView {
            clock: proc.clock,
            mem_used: proc.mem_used,
            mem_peak: proc.mem_peak,
        })
    }
    fn critical(&self) -> Clock {
        Machine::critical(self)
    }
    fn stats(&self) -> MachineStats {
        self.stats
    }
    fn mem_peak_max(&self) -> u64 {
        Machine::mem_peak_max(self)
    }
    fn mem_peak_total(&self) -> u64 {
        Machine::mem_peak_total(self)
    }
    fn mem_used_total(&self) -> u64 {
        Machine::mem_used_total(self)
    }
    fn purge(&mut self, p: ProcId) {
        Machine::purge(self, p);
    }
    fn event(&mut self, msg: &str) {
        Machine::event(self, msg);
    }
    fn take_buffer(&mut self, cap: usize) -> Vec<u32> {
        self.pool.take(cap)
    }
    fn give_buffer(&mut self, buf: Vec<u32>) {
        self.pool.give(buf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(p: usize, cap: u64) -> Machine {
        Machine::new(p, cap, Base::new(16))
    }

    #[test]
    fn alloc_free_ledger() {
        let mut m = mk(2, 10);
        let s = m.alloc(0, vec![1, 2, 3]).unwrap();
        assert_eq!(m.proc(0).mem_used(), 3);
        assert_eq!(m.read(0, s), &[1, 2, 3]);
        let d = m.free(0, s);
        assert_eq!(d, vec![1, 2, 3]);
        assert_eq!(m.proc(0).mem_used(), 0);
        assert_eq!(m.proc(0).mem_peak(), 3);
    }

    #[test]
    fn alloc_respects_capacity() {
        let mut m = mk(1, 4);
        let _a = m.alloc(0, vec![0; 3]).unwrap();
        assert!(m.alloc(0, vec![0; 2]).is_err());
        let _b = m.alloc(0, vec![0; 1]).unwrap();
    }

    #[test]
    fn send_charges_sender_and_joins_receiver() {
        let mut m = mk(2, 100);
        m.compute(0, 10);
        let s = m.send(0, 1, vec![7, 8]).unwrap();
        assert_eq!(m.read(1, s), &[7, 8]);
        // Sender: 2 words, 1 msg, 10 ops.
        assert_eq!(m.proc(0).clock, Clock { ops: 10, words: 2, msgs: 1 });
        // Receiver joined the snapshot.
        assert_eq!(m.proc(1).clock, Clock { ops: 10, words: 2, msgs: 1 });
        // Aggregates.
        assert_eq!(m.stats.total_words, 2);
        assert_eq!(m.stats.total_msgs, 1);
    }

    #[test]
    fn parallel_disjoint_work_counts_once() {
        // Two processors each do 100 ops "in parallel" (disjoint clocks):
        // the critical path is 100, not 200.
        let mut m = mk(2, 100);
        m.compute(0, 100);
        m.compute(1, 100);
        assert_eq!(m.critical().ops, 100);
        assert_eq!(m.stats.total_ops, 200);
    }

    #[test]
    fn sequential_dependent_work_accumulates() {
        // p0 computes, sends to p1, p1 computes: critical path adds up.
        let mut m = mk(2, 100);
        m.compute(0, 50);
        m.send(0, 1, vec![1]).unwrap();
        m.compute(1, 70);
        assert_eq!(m.critical(), Clock { ops: 120, words: 1, msgs: 1 });
    }

    #[test]
    fn send_move_frees_source() {
        let mut m = mk(2, 10);
        let s = m.alloc(0, vec![1, 2]).unwrap();
        let d = m.send_move(0, 1, s).unwrap();
        assert_eq!(m.proc(0).mem_used(), 0);
        assert_eq!(m.read(1, d), &[1, 2]);
    }

    #[test]
    fn local_charges_ops() {
        let mut m = mk(1, 100);
        let v = m.local(0, |base, ops| {
            ops.charge(42);
            base.s()
        });
        assert_eq!(v, 65536);
        assert_eq!(m.proc(0).clock.ops, 42);
    }

    #[test]
    fn barrier_joins_clocks() {
        let mut m = mk(3, 100);
        m.compute(0, 5);
        m.compute(1, 9);
        m.barrier(&[0, 1, 2]);
        assert_eq!(m.proc(2).clock.ops, 9);
    }

    #[test]
    fn purge_resets_ledger_keeps_clock_and_peak() {
        let mut m = mk(2, 10);
        m.compute(0, 7);
        let _a = m.alloc(0, vec![1, 2, 3]).unwrap();
        let _b = m.alloc(0, vec![4]).unwrap();
        m.purge(0);
        let v = MachineApi::proc_view(&m, 0).unwrap();
        assert_eq!(v.mem_used, 0);
        assert_eq!(v.mem_peak, 4);
        assert_eq!(v.clock.ops, 7);
        // The processor is reusable after the purge.
        let s = m.alloc(0, vec![9; 10]).unwrap();
        assert_eq!(m.read(0, s), &[9; 10]);
    }

    #[test]
    fn torus_send_charges_per_hop() {
        use super::super::topology::Torus2D;
        let mut m =
            Machine::with_topology(16, 1000, Base::new(16), Arc::new(Torus2D::for_procs(16)));
        // 0 -> 10 on the 4x4 torus crosses 4 links (2 rows + 2 cols).
        let s = m.send(0, 10, vec![1, 2]).unwrap();
        assert_eq!(m.read(10, s), &[1, 2]);
        assert_eq!(m.stats.total_msgs, 4);
        assert_eq!(m.stats.total_words, 8);
        // The hop chain accumulates on the critical path.
        assert_eq!(m.critical(), Clock { ops: 0, words: 8, msgs: 4 });
        // Relays are wire-only: no ledger charges anywhere but dst.
        assert_eq!(m.mem_used_total(), 2);
    }

    #[test]
    fn hier_send_weights_backbone_links() {
        use super::super::topology::HierCluster;
        let mut m =
            Machine::with_topology(16, 1000, Base::new(16), Arc::new(HierCluster::for_procs(16)));
        // 1 -> 7 routes [1, 0, 4, 7]; the (0,4) link is the
        // half-bandwidth backbone (weight 2).
        let s = m.send(1, 7, vec![9; 3]).unwrap();
        assert_eq!(m.read(7, s), &[9; 3]);
        assert_eq!(m.stats.total_msgs, 3);
        assert_eq!(m.stats.total_words, 3 + 6 + 3);
        assert_eq!(m.mem_used_total(), 3);
    }

    #[test]
    fn slab_recycles_cells_and_keeps_owner_checks() {
        let mut m = mk(2, 100);
        let a = m.alloc(0, vec![1, 2, 3]).unwrap();
        assert_eq!(m.free(0, a), vec![1, 2, 3]);
        // The vacant cell is reused (slot handles are opaque; identity
        // reuse is allowed) and the ledger stays exact.
        let b = m.alloc(0, vec![4]).unwrap();
        assert_eq!(m.read(0, b), &[4]);
        assert_eq!(m.proc(0).mem_used(), 1);
        // Pooled buffers cycle invisibly: a long alloc/free train must
        // not disturb ledger accounting.
        for i in 0..100u32 {
            let s = m.alloc(1, vec![i; 8]).unwrap();
            MachineApi::free(&mut m, 1, s);
        }
        assert_eq!(m.proc(1).mem_used(), 0);
        assert_eq!(m.proc(1).mem_peak(), 8);
    }

    #[test]
    #[should_panic(expected = "read of unknown slot")]
    fn read_of_foreign_slot_panics() {
        let mut m = mk(2, 10);
        let s = m.alloc(0, vec![1]).unwrap();
        let _ = m.read(1, s);
    }

    #[test]
    #[should_panic(expected = "read of unknown slot")]
    fn stale_handle_to_recycled_cell_panics() {
        // Use-after-free must stay a loud failure even though the slab
        // recycles cells: the generation in the handle goes stale.
        let mut m = mk(1, 100);
        let a = m.alloc(0, vec![1, 2]).unwrap();
        m.free(0, a);
        let _b = m.alloc(0, vec![3, 4]).unwrap(); // reuses the cell
        let _ = m.read(0, a);
    }

    #[test]
    fn replace_updates_ledger() {
        let mut m = mk(1, 10);
        let s = m.alloc(0, vec![1, 2, 3]).unwrap();
        m.replace(0, s, vec![9]).unwrap();
        assert_eq!(m.proc(0).mem_used(), 1);
        assert_eq!(m.read(0, s), &[9]);
    }
}

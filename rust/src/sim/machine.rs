//! The machine: processors, memory ledgers, message transport.

use super::api::{MachineApi, ProcView, SlotComputation};
use super::topology::{FullyConnected, TopologyRef};
use super::Clock;
use crate::bignum::{Base, Ops};
use crate::error::{bail, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// Processor identifier: index into the machine's processor table.
pub type ProcId = usize;

/// Handle to a value resident in some processor's local memory.
pub type Slot = u64;

/// One simulated processor: logical clock + memory ledger + store.
#[derive(Debug)]
pub struct Processor {
    pub clock: Clock,
    store: HashMap<Slot, Vec<u32>>,
    mem_used: u64,
    mem_peak: u64,
    mem_cap: u64,
    /// Total ops executed by this processor (aggregate work, not
    /// critical path): used by the speedup/efficiency experiments.
    pub total_ops: u64,
}

impl Processor {
    fn new(mem_cap: u64) -> Self {
        Processor {
            clock: Clock::default(),
            store: HashMap::new(),
            mem_used: 0,
            mem_peak: 0,
            mem_cap,
            total_ops: 0,
        }
    }

    #[inline]
    pub fn mem_used(&self) -> u64 {
        self.mem_used
    }
    #[inline]
    pub fn mem_peak(&self) -> u64 {
        self.mem_peak
    }
}

/// Aggregate (whole-machine) statistics, complementing the critical-path
/// clock: total communicated volume, total messages, total work.
#[derive(Clone, Copy, Debug, Default)]
pub struct MachineStats {
    pub total_words: u64,
    pub total_msgs: u64,
    pub total_ops: u64,
}

/// The distributed-memory machine (see module docs for the model).
#[derive(Debug)]
pub struct Machine {
    procs: Vec<Processor>,
    pub base: Base,
    topo: TopologyRef,
    next_slot: Slot,
    pub stats: MachineStats,
    /// When true, messages passed to [`Machine::event`] are recorded in
    /// `trace_log` (retrievable via [`Machine::trace_log`]). The flag
    /// only gates that recording; it does not change error behaviour —
    /// allocation failures return `Err` either way. Default false.
    pub trace: bool,
    trace_log: Vec<String>,
}

impl Machine {
    /// Create a machine with `p` processors, each with `mem_cap` words of
    /// local memory, computing over digits of `base`, on the default
    /// fully-connected interconnect (the paper's implicit network).
    pub fn new(p: usize, mem_cap: u64, base: Base) -> Self {
        Machine::with_topology(p, mem_cap, base, Arc::new(FullyConnected))
    }

    /// [`Machine::new`] on an explicit network topology: sends are
    /// charged hop by hop along `topo.route(src, dst)` with per-link
    /// bandwidth weights (see the `topology` module docs).
    pub fn with_topology(p: usize, mem_cap: u64, base: Base, topo: TopologyRef) -> Self {
        assert!(p >= 1, "need at least one processor");
        Machine {
            procs: (0..p).map(|_| Processor::new(mem_cap)).collect(),
            base,
            topo,
            next_slot: 1,
            stats: MachineStats::default(),
            trace: false,
            trace_log: Vec::new(),
        }
    }

    /// Convenience: effectively unbounded local memories (for the MI
    /// execution mode, which by definition ignores M).
    pub fn unbounded(p: usize, base: Base) -> Self {
        Machine::new(p, u64::MAX / 2, base)
    }

    #[inline]
    pub fn n_procs(&self) -> usize {
        self.procs.len()
    }

    #[inline]
    pub fn mem_cap(&self) -> u64 {
        self.procs[0].mem_cap
    }

    pub fn proc(&self, p: ProcId) -> &Processor {
        &self.procs[p]
    }

    // ----- memory ledger ---------------------------------------------

    /// Allocate `data` in `p`'s local memory. Fails if the capacity `M`
    /// would be exceeded — this is the mechanism that makes the paper's
    /// memory-requirement statements falsifiable.
    pub fn alloc(&mut self, p: ProcId, data: Vec<u32>) -> Result<Slot> {
        let words = data.len() as u64;
        let proc = &mut self.procs[p];
        if proc.mem_used + words > proc.mem_cap {
            bail!(
                "processor {p}: local memory exceeded (used {} + {} > cap {})",
                proc.mem_used,
                words,
                proc.mem_cap
            );
        }
        proc.mem_used += words;
        proc.mem_peak = proc.mem_peak.max(proc.mem_used);
        let slot = self.next_slot;
        self.next_slot += 1;
        self.procs[p].store.insert(slot, data);
        Ok(slot)
    }

    /// Allocate a single scalar word (flags, carries).
    pub fn alloc_scalar(&mut self, p: ProcId, v: u32) -> Result<Slot> {
        self.alloc(p, vec![v])
    }

    /// Free a slot, returning its contents.
    pub fn free(&mut self, p: ProcId, slot: Slot) -> Vec<u32> {
        let data = self.procs[p]
            .store
            .remove(&slot)
            .unwrap_or_else(|| panic!("processor {p}: free of unknown slot {slot}"));
        self.procs[p].mem_used -= data.len() as u64;
        data
    }

    /// Read a slot's contents.
    pub fn read(&self, p: ProcId, slot: Slot) -> &[u32] {
        self.procs[p]
            .store
            .get(&slot)
            .unwrap_or_else(|| panic!("processor {p}: read of unknown slot {slot}"))
    }

    /// Read a scalar slot.
    pub fn read_scalar(&self, p: ProcId, slot: Slot) -> u32 {
        let d = self.read(p, slot);
        debug_assert_eq!(d.len(), 1);
        d[0]
    }

    /// Overwrite a slot in place (same or different width; ledger updated).
    pub fn replace(&mut self, p: ProcId, slot: Slot, data: Vec<u32>) -> Result<()> {
        let old_len = self
            .procs[p]
            .store
            .get(&slot)
            .unwrap_or_else(|| panic!("processor {p}: replace of unknown slot {slot}"))
            .len() as u64;
        let new_len = data.len() as u64;
        let proc = &mut self.procs[p];
        if proc.mem_used - old_len + new_len > proc.mem_cap {
            bail!(
                "processor {p}: local memory exceeded on replace ({} -> {} words, cap {})",
                old_len,
                new_len,
                proc.mem_cap
            );
        }
        proc.mem_used = proc.mem_used - old_len + new_len;
        proc.mem_peak = proc.mem_peak.max(proc.mem_used);
        proc.store.insert(slot, data);
        Ok(())
    }

    // ----- computation ------------------------------------------------

    /// Charge `ops` digit operations to `p`'s clock.
    pub fn compute(&mut self, p: ProcId, ops: u64) {
        self.procs[p].clock.ops += ops;
        self.procs[p].total_ops += ops;
        self.stats.total_ops += ops;
    }

    /// Run a local computation whose digit-op count is tracked by an
    /// [`Ops`] counter, charging the result to `p`.
    pub fn local<R>(&mut self, p: ProcId, f: impl FnOnce(&Base, &mut Ops) -> R) -> R {
        let mut ops = Ops::default();
        let base = self.base;
        let r = f(&base, &mut ops);
        self.compute(p, ops.get());
        r
    }

    // ----- communication ----------------------------------------------

    /// Send `data` from `src` to `dst` as one logical message;
    /// allocates the payload in `dst`'s memory and returns the new slot.
    ///
    /// Cost semantics (see module docs): each physical hop of the
    /// topology's route is charged to its link sender's clock (payload
    /// words × link weight, plus one message), and the next hop's clock
    /// joins the post-charge snapshot, so every processor on the route
    /// ends at least at the transfer's completion time on every metric.
    /// On the fully-connected default the route is the direct edge and
    /// this degenerates to the paper's charge-once-to-the-sender rule.
    /// Relays never touch their memory ledgers (wire forwarding); only
    /// `dst` allocates — exactly mirroring the threaded engine's
    /// store-and-forward, so the engines stay cost-identical on every
    /// topology.
    pub fn send(&mut self, src: ProcId, dst: ProcId, data: Vec<u32>) -> Result<Slot> {
        assert_ne!(src, dst, "send to self is a local operation");
        // Direct-edge fast path: `hops` is O(1) on every shipped
        // topology, so single-link transfers (ALL transfers on the
        // fully-connected default) never materialize a route vector —
        // the hot path stays allocation-free beyond the payload.
        if self.topo.hops(src, dst) == 1 {
            self.hop_charge(src, dst, data.len() as u64);
            return self.alloc(dst, data);
        }
        let route = self.topo.route(src, dst);
        debug_assert!(route.len() >= 2, "route must span the endpoints");
        let words = data.len() as u64;
        for hop in route.windows(2) {
            self.hop_charge(hop[0], hop[1], words);
        }
        self.alloc(dst, data)
    }

    /// Charge one physical hop `a → b` of `words` payload words: link
    /// sender pays `words × link weight` and one message, `b`'s clock
    /// joins the post-charge snapshot.
    fn hop_charge(&mut self, a: ProcId, b: ProcId, words: u64) {
        let hop_words = words * self.topo.link_bw_weight(a, b);
        self.procs[a].clock.words += hop_words;
        self.procs[a].clock.msgs += 1;
        self.stats.total_words += hop_words;
        self.stats.total_msgs += 1;
        let snapshot = self.procs[a].clock;
        let bclock = &mut self.procs[b].clock;
        *bclock = bclock.join(&snapshot);
    }

    /// Send a copy of an existing slot (source keeps its copy).
    pub fn send_copy(&mut self, src: ProcId, dst: ProcId, slot: Slot) -> Result<Slot> {
        let data = self.read(src, slot).to_vec();
        self.send(src, dst, data)
    }

    /// Send an existing slot and free it at the source ("...and then
    /// removes it from its local memory", as the paper repeatedly does).
    pub fn send_move(&mut self, src: ProcId, dst: ProcId, slot: Slot) -> Result<Slot> {
        let data = self.free(src, slot);
        self.send(src, dst, data)
    }

    /// Send a sub-range of a slot's digits (copy).
    pub fn send_range(
        &mut self,
        src: ProcId,
        dst: ProcId,
        slot: Slot,
        range: std::ops::Range<usize>,
    ) -> Result<Slot> {
        let data = self.read(src, slot)[range].to_vec();
        self.send(src, dst, data)
    }

    /// Drop every slot resident on `p`; the ledger returns to zero used
    /// words (peak is kept — it already happened). Scheduler support:
    /// reclaims a shard whose job failed and leaked its working set.
    pub fn purge(&mut self, p: ProcId) {
        let proc = &mut self.procs[p];
        proc.store.clear();
        proc.mem_used = 0;
    }

    /// Synchronize a set of processors (a barrier): all clocks join.
    /// The paper's algorithms are bulk-synchronous within each phase;
    /// explicit barriers are only used by the experiment harness between
    /// phases, not inside the algorithms (which synchronize via their
    /// actual messages).
    pub fn barrier(&mut self, procs: &[ProcId]) {
        let mut j = Clock::default();
        for &p in procs {
            j = j.join(&self.procs[p].clock);
        }
        for &p in procs {
            self.procs[p].clock = j;
        }
    }

    // ----- reporting ----------------------------------------------------

    /// Critical-path cost: component-wise max over all processors.
    pub fn critical(&self) -> Clock {
        let mut j = Clock::default();
        for p in &self.procs {
            j = j.join(&p.clock);
        }
        j
    }

    /// Peak local-memory usage over all processors (the paper's M(n,P)).
    pub fn mem_peak_max(&self) -> u64 {
        self.procs.iter().map(|p| p.mem_peak).max().unwrap_or(0)
    }

    /// Sum of peak local-memory usage (the "total memory O(n)" claim).
    pub fn mem_peak_total(&self) -> u64 {
        self.procs.iter().map(|p| p.mem_peak).sum()
    }

    /// Current resident words across all processors.
    pub fn mem_used_total(&self) -> u64 {
        self.procs.iter().map(|p| p.mem_used).sum()
    }

    /// Record a trace event (no cost) when tracing is enabled.
    pub fn event(&mut self, msg: impl Into<String>) {
        if self.trace {
            self.trace_log.push(msg.into());
        }
    }

    pub fn trace_log(&self) -> &[String] {
        &self.trace_log
    }
}

/// The cost-model execution engine: [`Machine`]'s inherent operations
/// *are* the [`MachineApi`] contract; this impl adapts the borrowed
/// return types (`read`) and runs `compute_slot` synchronously in
/// program order, which is exactly the deterministic reference
/// semantics the threaded backend is property-tested against.
impl MachineApi for Machine {
    fn n_procs(&self) -> usize {
        Machine::n_procs(self)
    }
    fn mem_cap(&self) -> u64 {
        Machine::mem_cap(self)
    }
    fn base(&self) -> Base {
        self.base
    }
    fn topology(&self) -> TopologyRef {
        Arc::clone(&self.topo)
    }

    fn alloc(&mut self, p: ProcId, data: Vec<u32>) -> Result<Slot> {
        Machine::alloc(self, p, data)
    }
    fn free(&mut self, p: ProcId, slot: Slot) {
        Machine::free(self, p, slot);
    }
    fn read(&self, p: ProcId, slot: Slot) -> Result<Vec<u32>> {
        Ok(Machine::read(self, p, slot).to_vec())
    }
    fn replace(&mut self, p: ProcId, slot: Slot, data: Vec<u32>) -> Result<()> {
        Machine::replace(self, p, slot, data)
    }

    fn compute(&mut self, p: ProcId, ops: u64) {
        Machine::compute(self, p, ops);
    }
    fn local<R, F>(&mut self, p: ProcId, f: F) -> Result<R>
    where
        R: Send + 'static,
        F: FnOnce(&Base, &mut Ops) -> R + Send + 'static,
    {
        Ok(Machine::local(self, p, f))
    }
    fn compute_slot(
        &mut self,
        p: ProcId,
        inputs: &[Slot],
        consume: bool,
        f: SlotComputation,
    ) -> Result<Slot> {
        let data: Vec<Vec<u32>> = inputs
            .iter()
            .map(|&s| Machine::read(self, p, s).to_vec())
            .collect();
        if consume {
            for &s in inputs {
                Machine::free(self, p, s);
            }
        }
        let base = self.base;
        let mut ops = Ops::default();
        let out = f(&data, &base, &mut ops);
        Machine::compute(self, p, ops.get());
        Machine::alloc(self, p, out)
    }

    fn send(&mut self, src: ProcId, dst: ProcId, data: Vec<u32>) -> Result<Slot> {
        Machine::send(self, src, dst, data)
    }
    fn send_copy(&mut self, src: ProcId, dst: ProcId, slot: Slot) -> Result<Slot> {
        Machine::send_copy(self, src, dst, slot)
    }
    fn send_move(&mut self, src: ProcId, dst: ProcId, slot: Slot) -> Result<Slot> {
        Machine::send_move(self, src, dst, slot)
    }
    fn send_range(
        &mut self,
        src: ProcId,
        dst: ProcId,
        slot: Slot,
        range: std::ops::Range<usize>,
    ) -> Result<Slot> {
        Machine::send_range(self, src, dst, slot, range)
    }
    fn barrier(&mut self, procs: &[ProcId]) -> Result<()> {
        Machine::barrier(self, procs);
        Ok(())
    }

    fn proc_view(&self, p: ProcId) -> Result<ProcView> {
        let proc = &self.procs[p];
        Ok(ProcView {
            clock: proc.clock,
            mem_used: proc.mem_used,
            mem_peak: proc.mem_peak,
        })
    }
    fn critical(&self) -> Clock {
        Machine::critical(self)
    }
    fn stats(&self) -> MachineStats {
        self.stats
    }
    fn mem_peak_max(&self) -> u64 {
        Machine::mem_peak_max(self)
    }
    fn mem_peak_total(&self) -> u64 {
        Machine::mem_peak_total(self)
    }
    fn mem_used_total(&self) -> u64 {
        Machine::mem_used_total(self)
    }
    fn purge(&mut self, p: ProcId) {
        Machine::purge(self, p);
    }
    fn event(&mut self, msg: &str) {
        Machine::event(self, msg);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(p: usize, cap: u64) -> Machine {
        Machine::new(p, cap, Base::new(16))
    }

    #[test]
    fn alloc_free_ledger() {
        let mut m = mk(2, 10);
        let s = m.alloc(0, vec![1, 2, 3]).unwrap();
        assert_eq!(m.proc(0).mem_used(), 3);
        assert_eq!(m.read(0, s), &[1, 2, 3]);
        let d = m.free(0, s);
        assert_eq!(d, vec![1, 2, 3]);
        assert_eq!(m.proc(0).mem_used(), 0);
        assert_eq!(m.proc(0).mem_peak(), 3);
    }

    #[test]
    fn alloc_respects_capacity() {
        let mut m = mk(1, 4);
        let _a = m.alloc(0, vec![0; 3]).unwrap();
        assert!(m.alloc(0, vec![0; 2]).is_err());
        let _b = m.alloc(0, vec![0; 1]).unwrap();
    }

    #[test]
    fn send_charges_sender_and_joins_receiver() {
        let mut m = mk(2, 100);
        m.compute(0, 10);
        let s = m.send(0, 1, vec![7, 8]).unwrap();
        assert_eq!(m.read(1, s), &[7, 8]);
        // Sender: 2 words, 1 msg, 10 ops.
        assert_eq!(m.proc(0).clock, Clock { ops: 10, words: 2, msgs: 1 });
        // Receiver joined the snapshot.
        assert_eq!(m.proc(1).clock, Clock { ops: 10, words: 2, msgs: 1 });
        // Aggregates.
        assert_eq!(m.stats.total_words, 2);
        assert_eq!(m.stats.total_msgs, 1);
    }

    #[test]
    fn parallel_disjoint_work_counts_once() {
        // Two processors each do 100 ops "in parallel" (disjoint clocks):
        // the critical path is 100, not 200.
        let mut m = mk(2, 100);
        m.compute(0, 100);
        m.compute(1, 100);
        assert_eq!(m.critical().ops, 100);
        assert_eq!(m.stats.total_ops, 200);
    }

    #[test]
    fn sequential_dependent_work_accumulates() {
        // p0 computes, sends to p1, p1 computes: critical path adds up.
        let mut m = mk(2, 100);
        m.compute(0, 50);
        m.send(0, 1, vec![1]).unwrap();
        m.compute(1, 70);
        assert_eq!(m.critical(), Clock { ops: 120, words: 1, msgs: 1 });
    }

    #[test]
    fn send_move_frees_source() {
        let mut m = mk(2, 10);
        let s = m.alloc(0, vec![1, 2]).unwrap();
        let d = m.send_move(0, 1, s).unwrap();
        assert_eq!(m.proc(0).mem_used(), 0);
        assert_eq!(m.read(1, d), &[1, 2]);
    }

    #[test]
    fn local_charges_ops() {
        let mut m = mk(1, 100);
        let v = m.local(0, |base, ops| {
            ops.charge(42);
            base.s()
        });
        assert_eq!(v, 65536);
        assert_eq!(m.proc(0).clock.ops, 42);
    }

    #[test]
    fn barrier_joins_clocks() {
        let mut m = mk(3, 100);
        m.compute(0, 5);
        m.compute(1, 9);
        m.barrier(&[0, 1, 2]);
        assert_eq!(m.proc(2).clock.ops, 9);
    }

    #[test]
    fn purge_resets_ledger_keeps_clock_and_peak() {
        let mut m = mk(2, 10);
        m.compute(0, 7);
        let _a = m.alloc(0, vec![1, 2, 3]).unwrap();
        let _b = m.alloc(0, vec![4]).unwrap();
        m.purge(0);
        let v = MachineApi::proc_view(&m, 0).unwrap();
        assert_eq!(v.mem_used, 0);
        assert_eq!(v.mem_peak, 4);
        assert_eq!(v.clock.ops, 7);
        // The processor is reusable after the purge.
        let s = m.alloc(0, vec![9; 10]).unwrap();
        assert_eq!(m.read(0, s), &[9; 10]);
    }

    #[test]
    fn torus_send_charges_per_hop() {
        use super::super::topology::Torus2D;
        let mut m =
            Machine::with_topology(16, 1000, Base::new(16), Arc::new(Torus2D::for_procs(16)));
        // 0 -> 10 on the 4x4 torus crosses 4 links (2 rows + 2 cols).
        let s = m.send(0, 10, vec![1, 2]).unwrap();
        assert_eq!(m.read(10, s), &[1, 2]);
        assert_eq!(m.stats.total_msgs, 4);
        assert_eq!(m.stats.total_words, 8);
        // The hop chain accumulates on the critical path.
        assert_eq!(m.critical(), Clock { ops: 0, words: 8, msgs: 4 });
        // Relays are wire-only: no ledger charges anywhere but dst.
        assert_eq!(m.mem_used_total(), 2);
    }

    #[test]
    fn hier_send_weights_backbone_links() {
        use super::super::topology::HierCluster;
        let mut m =
            Machine::with_topology(16, 1000, Base::new(16), Arc::new(HierCluster::for_procs(16)));
        // 1 -> 7 routes [1, 0, 4, 7]; the (0,4) link is the
        // half-bandwidth backbone (weight 2).
        let s = m.send(1, 7, vec![9; 3]).unwrap();
        assert_eq!(m.read(7, s), &[9; 3]);
        assert_eq!(m.stats.total_msgs, 3);
        assert_eq!(m.stats.total_words, 3 + 6 + 3);
        assert_eq!(m.mem_used_total(), 3);
    }

    #[test]
    fn replace_updates_ledger() {
        let mut m = mk(1, 10);
        let s = m.alloc(0, vec![1, 2, 3]).unwrap();
        m.replace(0, s, vec![9]).unwrap();
        assert_eq!(m.proc(0).mem_used(), 1);
        assert_eq!(m.read(0, s), &[9]);
    }
}

//! [`ThreadedMachine`] — the real-threads execution engine.
//!
//! One OS thread per simulated processor. Each worker owns a
//! per-processor arena (dense slot-indexed storage, the threaded twin
//! of the cost model's machine-wide slab), its memory ledger, and its
//! logical [`Clock`]; processors are connected point-to-point by
//! `std::sync::mpsc` channels whose messages carry the payload digits
//! *and* the sender's post-send clock snapshot — the same cost
//! semantics as the cost-model backend, so the two engines produce
//! identical products and identical cost triples (property-tested in
//! `tests/theorem_properties.rs`).
//!
//! Payload movement is **zero-copy**: arena entries are
//! reference-counted, so whole-slot sends, relay forwarding, read
//! replies, and `compute_slot` inputs share or move the digits —
//! the only remaining copies are sub-range sends (which ship different
//! digits) and host reads that need ownership while the slot stays
//! live. None of this is cost-visible: ledgers charge lengths, wires
//! charge words.
//!
//! ## Execution model
//!
//! The algorithm runs on the host thread and issues commands through
//! [`MachineApi`]; each command is enqueued on the owning processor's
//! command channel and the workers drain their queues in program order.
//! Most commands are fire-and-forget (alloc/free/send/recv/
//! `compute_slot`), so independent processors genuinely overlap — in
//! particular the recursion leaves dispatched via `compute_slot`, which
//! dominate the digit work. Only `read` and `local` block the host,
//! because their results feed control flow.
//!
//! ## Why this cannot deadlock
//!
//! A receive executed by worker `d` blocks on the `(s → d)` channel
//! until worker `s` executes the matching send. Matching send/recv
//! command pairs are enqueued by the single host thread at the same
//! program point, so command order across all queues is consistent with
//! one global program order; a worker can only wait on a message whose
//! send command sits at an *earlier* program point in another queue,
//! and queue prefixes always drain, so every wait is eventually
//! satisfied.
//!
//! ## Memory-cap semantics
//!
//! The cost model fails an over-cap `alloc` eagerly. Workers execute
//! asynchronously, so they instead record the first overflow and keep
//! going (the run's products remain correct — the ledger is
//! accounting, not storage); the error surfaces from
//! [`ThreadedMachine::finish`] or [`ThreadedMachine::take_error`].
//! Memory-*bound* checking therefore belongs to the cost-model engine;
//! the threaded engine is for wall-clock execution.

use super::api::{MachineApi, ProcView, SlotComputation};
use super::machine::{MachineStats, ProcId, Slot};
use super::topology::{FullyConnected, TopologyRef};
use super::Clock;
use crate::bignum::{Base, Ops};
use crate::error::{anyhow, bail, Result};
use std::any::Any;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A point-to-point message: payload digits + sender clock snapshot.
/// The payload is reference-counted so relays ([`Cmd::Forward`]) and
/// whole-slot sends move a pointer, never the digits.
type NetMsg = (Arc<Vec<u32>>, Clock);

/// Unwrap a shared payload into an owned vector, copying only when the
/// arena (or another reader) still holds a reference.
pub fn payload_into_vec(a: Arc<Vec<u32>>) -> Vec<u32> {
    Arc::try_unwrap(a).unwrap_or_else(|a| (*a).clone())
}

/// Payload source for a send command executed by the sending worker.
enum Payload {
    /// Data shipped from the host (already materialized).
    Owned(Vec<u32>),
    /// Data taken from the sender's own arena, optionally a sub-range,
    /// optionally freeing the slot afterwards (send_copy / send_move /
    /// send_range run entirely worker-side, no host synchronization).
    FromSlot {
        slot: Slot,
        range: Option<std::ops::Range<usize>>,
        free_after: bool,
    },
}

/// Rendezvous state for one barrier call.
struct BarrierState {
    expected: usize,
    state: Mutex<(usize, Clock)>,
    cv: Condvar,
}

/// Commands processed by a worker in program order.
enum Cmd {
    Alloc {
        slot: Slot,
        data: Vec<u32>,
    },
    Free {
        slot: Slot,
    },
    Replace {
        slot: Slot,
        data: Vec<u32>,
    },
    Read {
        slot: Slot,
        reply: Sender<Arc<Vec<u32>>>,
    },
    Compute {
        ops: u64,
    },
    Local {
        f: Box<dyn FnOnce(&Base, &mut Ops) -> Box<dyn Any + Send> + Send>,
        reply: Sender<Box<dyn Any + Send>>,
    },
    ComputeSlot {
        out: Slot,
        inputs: Vec<Slot>,
        consume: bool,
        f: SlotComputation,
    },
    Send {
        dst: ProcId,
        payload: Payload,
        /// Per-word charge multiplier of the (self, dst) physical link.
        weight: u64,
    },
    /// Relay one in-flight message: receive from `src`, charge this
    /// worker's clock for the onward link, and send to `dst` — without
    /// touching the local ledger (wire forwarding; see the `topology`
    /// module docs). Multi-hop routes are chains of these between the
    /// initial `Send` and the final `Recv`.
    Forward {
        src: ProcId,
        dst: ProcId,
        weight: u64,
    },
    Recv {
        src: ProcId,
        slot: Slot,
    },
    Barrier {
        state: Arc<BarrierState>,
    },
    Purge,
    Query {
        reply: Sender<WorkerSnapshot>,
    },
}

/// Point-in-time view of one worker, returned by `Query`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WorkerSnapshot {
    pub clock: Clock,
    pub mem_used: u64,
    pub mem_peak: u64,
    pub total_ops: u64,
    pub sent_words: u64,
    pub sent_msgs: u64,
    /// Time spent executing digit work (`local`/`compute_slot`).
    pub busy: Duration,
    pub error: Option<String>,
}

/// Final report from [`ThreadedMachine::finish`].
#[derive(Clone, Debug)]
pub struct ThreadedReport {
    /// Wall-clock from machine construction to finish.
    pub wall: Duration,
    /// Critical-path cost (identical to the cost-model engine's).
    pub critical: Clock,
    pub stats: MachineStats,
    pub mem_peak_max: u64,
    pub mem_peak_total: u64,
    /// Per-processor busy time (digit work only) — utilization evidence.
    pub busy: Vec<Duration>,
}

/// One worker's private state: the per-processor arena and ledgers.
struct Worker {
    pid: ProcId,
    base: Base,
    mem_cap: u64,
    /// Dense arena: the handle assigns per-processor sequential slot
    /// ids, so `slot as usize` indexes directly. Entries are
    /// reference-counted so reads, whole-slot sends, and relays share
    /// the payload instead of cloning it.
    arena: Vec<Option<Arc<Vec<u32>>>>,
    clock: Clock,
    mem_used: u64,
    mem_peak: u64,
    total_ops: u64,
    sent_words: u64,
    sent_msgs: u64,
    busy: Duration,
    error: Option<String>,
    /// Outgoing channels, indexed by destination (None on the diagonal).
    net_tx: Vec<Option<Sender<NetMsg>>>,
    /// Incoming channels, indexed by source (None on the diagonal).
    net_rx: Vec<Option<Receiver<NetMsg>>>,
}

impl Worker {
    fn fail(&mut self, msg: String) {
        if self.error.is_none() {
            self.error = Some(msg);
        }
    }

    fn charge_alloc(&mut self, words: u64) {
        if self.mem_used + words > self.mem_cap {
            self.fail(format!(
                "processor {}: local memory exceeded (used {} + {} > cap {})",
                self.pid, self.mem_used, words, self.mem_cap
            ));
        }
        self.mem_used += words;
        self.mem_peak = self.mem_peak.max(self.mem_used);
    }

    fn store(&mut self, slot: Slot, data: Vec<u32>) {
        self.store_shared(slot, Arc::new(data));
    }

    /// Store an already-shared payload (a received message) without
    /// copying its digits.
    fn store_shared(&mut self, slot: Slot, data: Arc<Vec<u32>>) {
        self.charge_alloc(data.len() as u64);
        let idx = slot as usize;
        if idx >= self.arena.len() {
            self.arena.resize_with(idx + 1, || None);
        }
        debug_assert!(self.arena[idx].is_none(), "slot {slot} already in use");
        self.arena[idx] = Some(data);
    }

    fn take(&mut self, slot: Slot) -> Arc<Vec<u32>> {
        let data = self
            .arena
            .get_mut(slot as usize)
            .and_then(Option::take)
            .unwrap_or_else(|| panic!("processor {}: free of unknown slot {slot}", self.pid));
        self.mem_used -= data.len() as u64;
        // Slot ids are handle-assigned and never reused, so reclaim the
        // trailing run of freed entries to keep the arena's footprint
        // proportional to *live* slots (allocation patterns are largely
        // LIFO) rather than to the total historical allocation count.
        while matches!(self.arena.last(), Some(None)) {
            self.arena.pop();
        }
        data
    }

    fn get(&self, slot: Slot) -> &Arc<Vec<u32>> {
        self.arena
            .get(slot as usize)
            .and_then(Option::as_ref)
            .unwrap_or_else(|| panic!("processor {}: read of unknown slot {slot}", self.pid))
    }

    fn snapshot(&self) -> WorkerSnapshot {
        WorkerSnapshot {
            clock: self.clock,
            mem_used: self.mem_used,
            mem_peak: self.mem_peak,
            total_ops: self.total_ops,
            sent_words: self.sent_words,
            sent_msgs: self.sent_msgs,
            busy: self.busy,
            error: self.error.clone(),
        }
    }

    fn run(mut self, rx: Receiver<Cmd>) {
        while let Ok(cmd) = rx.recv() {
            match cmd {
                Cmd::Alloc { slot, data } => self.store(slot, data),
                Cmd::Free { slot } => {
                    self.take(slot);
                }
                Cmd::Replace { slot, data } => {
                    let old = self.take(slot);
                    drop(old);
                    self.store(slot, data);
                }
                Cmd::Read { slot, reply } => {
                    // Share the arena entry — the host copies only if
                    // it truly needs ownership while the slot is live.
                    let _ = reply.send(Arc::clone(self.get(slot)));
                }
                Cmd::Compute { ops } => {
                    self.clock.ops += ops;
                    self.total_ops += ops;
                }
                Cmd::Local { f, reply } => {
                    let t0 = Instant::now();
                    let mut ops = Ops::default();
                    let out = f(&self.base, &mut ops);
                    self.busy += t0.elapsed();
                    self.clock.ops += ops.get();
                    self.total_ops += ops.get();
                    let _ = reply.send(out);
                }
                Cmd::ComputeSlot {
                    out,
                    inputs,
                    consume,
                    f,
                } => {
                    // Consumed inputs are taken (moved), non-consumed
                    // inputs are borrowed through their refcount —
                    // either way the closure sees slices of the arena's
                    // own payloads and no digits are copied. The ledger
                    // sequence is unchanged (free inputs, then alloc
                    // output).
                    let held: Vec<Arc<Vec<u32>>> = if consume {
                        inputs.iter().map(|&s| self.take(s)).collect()
                    } else {
                        inputs.iter().map(|&s| Arc::clone(self.get(s))).collect()
                    };
                    let views: Vec<&[u32]> = held.iter().map(|a| a.as_slice()).collect();
                    let t0 = Instant::now();
                    let mut ops = Ops::default();
                    let produced = f(&views, &self.base, &mut ops);
                    self.busy += t0.elapsed();
                    drop(views);
                    drop(held);
                    self.clock.ops += ops.get();
                    self.total_ops += ops.get();
                    self.store(out, produced);
                }
                Cmd::Send {
                    dst,
                    payload,
                    weight,
                } => {
                    // Whole-slot sends ship the arena's own payload by
                    // reference (move on `free_after`, shared pointer
                    // otherwise); only sub-range sends copy — they
                    // genuinely ship different digits.
                    let data: Arc<Vec<u32>> = match payload {
                        Payload::Owned(d) => Arc::new(d),
                        Payload::FromSlot {
                            slot,
                            range,
                            free_after,
                        } => {
                            if free_after {
                                let d = self.take(slot);
                                match range {
                                    Some(r) => Arc::new(d[r].to_vec()),
                                    None => d,
                                }
                            } else {
                                let d = self.get(slot);
                                match range {
                                    Some(r) => Arc::new(d[r].to_vec()),
                                    None => Arc::clone(d),
                                }
                            }
                        }
                    };
                    let words = data.len() as u64 * weight;
                    self.clock.words += words;
                    self.clock.msgs += 1;
                    self.sent_words += words;
                    self.sent_msgs += 1;
                    let snapshot = self.clock;
                    if let Some(tx) = &self.net_tx[dst] {
                        // A closed peer means the machine is shutting
                        // down; dropping the message is then harmless.
                        let _ = tx.send((data, snapshot));
                    }
                }
                Cmd::Forward { src, dst, weight } => {
                    let chan = self.net_rx[src]
                        .as_ref()
                        .expect("forward from self is meaningless");
                    match chan.recv() {
                        Ok((data, snapshot)) => {
                            // Join the inbound hop, then charge the
                            // outbound link — same order as the
                            // cost-model engine's hop loop, so the
                            // engines stay clock-identical. The ledger
                            // is untouched: relays are wire, not
                            // storage — and the payload moves through
                            // as a shared pointer, never recopied.
                            self.clock = self.clock.join(&snapshot);
                            let words = data.len() as u64 * weight;
                            self.clock.words += words;
                            self.clock.msgs += 1;
                            self.sent_words += words;
                            self.sent_msgs += 1;
                            let snap = self.clock;
                            if let Some(tx) = &self.net_tx[dst] {
                                let _ = tx.send((data, snap));
                            }
                        }
                        Err(_) => self.fail(format!(
                            "processor {}: peer {src} hung up mid-relay",
                            self.pid
                        )),
                    }
                }
                Cmd::Recv { src, slot } => {
                    let chan = self.net_rx[src]
                        .as_ref()
                        .expect("recv from self is a local operation");
                    match chan.recv() {
                        Ok((data, snapshot)) => {
                            // The received allocation IS the arena
                            // entry — no copy on delivery.
                            self.store_shared(slot, data);
                            self.clock = self.clock.join(&snapshot);
                        }
                        Err(_) => self.fail(format!(
                            "processor {}: peer {src} hung up mid-message",
                            self.pid
                        )),
                    }
                }
                Cmd::Barrier { state } => {
                    let mut g = state.state.lock().unwrap();
                    g.0 += 1;
                    g.1 = g.1.join(&self.clock);
                    if g.0 == state.expected {
                        state.cv.notify_all();
                    } else {
                        while g.0 < state.expected {
                            g = state.cv.wait(g).unwrap();
                        }
                    }
                    let joined = g.1;
                    drop(g);
                    self.clock = joined;
                }
                Cmd::Purge => {
                    self.arena.clear();
                    self.mem_used = 0;
                }
                Cmd::Query { reply } => {
                    let _ = reply.send(self.snapshot());
                }
            }
        }
    }
}

/// The real-threads execution engine (see module docs).
pub struct ThreadedMachine {
    base: Base,
    mem_cap: u64,
    topo: TopologyRef,
    /// Per-processor next slot id (dense arena indices).
    next_slot: Vec<Slot>,
    cmd_txs: Vec<Sender<Cmd>>,
    handles: Vec<JoinHandle<()>>,
    started: Instant,
}

impl ThreadedMachine {
    /// Spawn `p` worker threads, each modelling one processor with
    /// `mem_cap` words of local memory, computing over digits of `base`,
    /// on the default fully-connected interconnect.
    pub fn new(p: usize, mem_cap: u64, base: Base) -> Self {
        ThreadedMachine::with_topology(p, mem_cap, base, Arc::new(FullyConnected))
    }

    /// [`ThreadedMachine::new`] on an explicit network topology:
    /// messages are genuinely routed hop by hop through the relay
    /// workers' threads (`Cmd::Forward`), charging each link to its
    /// sender exactly as the cost-model engine does.
    pub fn with_topology(p: usize, mem_cap: u64, base: Base, topo: TopologyRef) -> Self {
        assert!(p >= 1, "need at least one processor");
        // Point-to-point mesh: one channel per ordered pair.
        let mut net_tx: Vec<Vec<Option<Sender<NetMsg>>>> =
            (0..p).map(|_| (0..p).map(|_| None).collect()).collect();
        let mut net_rx: Vec<Vec<Option<Receiver<NetMsg>>>> =
            (0..p).map(|_| (0..p).map(|_| None).collect()).collect();
        for s in 0..p {
            for d in 0..p {
                if s != d {
                    let (tx, rx) = channel();
                    net_tx[s][d] = Some(tx);
                    net_rx[d][s] = Some(rx);
                }
            }
        }
        let mut cmd_txs = Vec::with_capacity(p);
        let mut handles = Vec::with_capacity(p);
        // Pair the mesh rows with their workers (reverse order so
        // remove() is O(1) from the back without index shifting).
        let mut tx_rows: Vec<_> = net_tx.into_iter().collect();
        let mut rx_rows: Vec<_> = net_rx.into_iter().collect();
        for pid in (0..p).rev() {
            let worker = Worker {
                pid,
                base,
                mem_cap,
                arena: Vec::new(),
                clock: Clock::default(),
                mem_used: 0,
                mem_peak: 0,
                total_ops: 0,
                sent_words: 0,
                sent_msgs: 0,
                busy: Duration::ZERO,
                error: None,
                net_tx: tx_rows.pop().expect("mesh row"),
                net_rx: rx_rows.pop().expect("mesh row"),
            };
            let (tx, rx) = channel();
            cmd_txs.push(tx);
            handles.push(std::thread::spawn(move || worker.run(rx)));
        }
        cmd_txs.reverse();
        handles.reverse();
        ThreadedMachine {
            base,
            mem_cap,
            topo,
            next_slot: vec![1; p],
            cmd_txs,
            handles,
            started: Instant::now(),
        }
    }

    /// Enqueue one logical transfer along the topology's route: a
    /// weighted `Send` at the origin, a `Forward` on every relay, and
    /// the final `Recv` (which allocates) at the destination. All
    /// commands are enqueued at this single program point, so the
    /// global-order no-deadlock argument of the module docs covers
    /// relayed messages unchanged.
    fn route_send(&mut self, src: ProcId, dst: ProcId, payload: Payload) -> Result<Slot> {
        assert_ne!(src, dst, "send to self is a local operation");
        // Direct-edge fast path (all transfers on the fully-connected
        // default): no route vector, just the Send/Recv pair.
        if self.topo.hops(src, dst) == 1 {
            let slot = self.fresh_slot(dst);
            self.cmd(
                src,
                Cmd::Send {
                    dst,
                    payload,
                    weight: self.topo.link_bw_weight(src, dst),
                },
            )?;
            self.cmd(dst, Cmd::Recv { src, slot })?;
            return Ok(slot);
        }
        let route = self.topo.route(src, dst);
        debug_assert!(route.len() >= 2, "route must span the endpoints");
        let slot = self.fresh_slot(dst);
        self.cmd(
            src,
            Cmd::Send {
                dst: route[1],
                payload,
                weight: self.topo.link_bw_weight(src, route[1]),
            },
        )?;
        for i in 1..route.len() - 1 {
            self.cmd(
                route[i],
                Cmd::Forward {
                    src: route[i - 1],
                    dst: route[i + 1],
                    weight: self.topo.link_bw_weight(route[i], route[i + 1]),
                },
            )?;
        }
        self.cmd(
            dst,
            Cmd::Recv {
                src: route[route.len() - 2],
                slot,
            },
        )?;
        Ok(slot)
    }

    /// Effectively unbounded local memories (MI execution mode).
    pub fn unbounded(p: usize, base: Base) -> Self {
        ThreadedMachine::new(p, u64::MAX / 2, base)
    }

    /// Enqueue a command on `p`'s queue. Returns an error (instead of
    /// panicking) when the worker thread is gone — a panicked worker
    /// closes its queue, and the death must fail only the callers that
    /// depend on that processor, not the whole machine.
    fn cmd(&self, p: ProcId, c: Cmd) -> Result<()> {
        self.cmd_txs[p]
            .send(c)
            .map_err(|_| anyhow!("processor {p}: worker thread died"))
    }

    fn fresh_slot(&mut self, p: ProcId) -> Slot {
        let s = self.next_slot[p];
        self.next_slot[p] += 1;
        s
    }

    /// Blocking snapshot of one worker (drains its queue first, so the
    /// snapshot reflects every operation issued before this call).
    /// Fails when the worker thread is dead.
    pub fn snapshot(&self, p: ProcId) -> Result<WorkerSnapshot> {
        self.snapshot_request(p)
            .recv()
            .map_err(|_| anyhow!("processor {p}: worker thread died"))
    }

    // ----- two-phase (enqueue now, await later) variants --------------
    //
    // The blocking operations (`read`, `local`, `snapshot`) enqueue a
    // command and wait on its reply channel. A caller that wraps this
    // machine in an outer lock (the scheduler's shared machine) must be
    // able to enqueue under the lock and RELEASE it before blocking —
    // otherwise every concurrent job serializes on one worker's queue
    // drain. Program order is fixed at enqueue time, so awaiting after
    // the lock is dropped observes exactly the same state.

    /// Enqueue a read; the reply channel delivers the slot's contents
    /// — shared with the arena, so the worker never copies; convert
    /// with [`payload_into_vec`] if ownership is needed — once worker
    /// `p` drains its queue to this command. If the worker is dead the
    /// command is dropped and the receiver's `recv` fails — the
    /// awaiting side maps that to a per-call error.
    pub fn read_request(&self, p: ProcId, slot: Slot) -> Receiver<Arc<Vec<u32>>> {
        let (tx, rx) = channel();
        let _ = self.cmd(p, Cmd::Read { slot, reply: tx });
        rx
    }

    /// Enqueue a local computation; the reply channel delivers the
    /// boxed result (downcast to the closure's return type).
    pub fn local_request<R, F>(&self, p: ProcId, f: F) -> Receiver<Box<dyn Any + Send>>
    where
        R: Send + 'static,
        F: FnOnce(&Base, &mut Ops) -> R + Send + 'static,
    {
        let (tx, rx) = channel();
        let boxed = Box::new(move |base: &Base, ops: &mut Ops| -> Box<dyn Any + Send> {
            Box::new(f(base, ops))
        });
        let _ = self.cmd(p, Cmd::Local { f: boxed, reply: tx });
        rx
    }

    /// Enqueue a snapshot query; the reply channel delivers the
    /// worker's state once its queue drains to this command.
    pub fn snapshot_request(&self, p: ProcId) -> Receiver<WorkerSnapshot> {
        let (tx, rx) = channel();
        let _ = self.cmd(p, Cmd::Query { reply: tx });
        rx
    }

    /// Snapshots of every worker that is still alive (dead workers are
    /// skipped; `finish` reports them).
    fn snapshot_all(&self) -> Vec<WorkerSnapshot> {
        (0..self.cmd_txs.len())
            .filter_map(|p| self.snapshot(p).ok())
            .collect()
    }

    /// First recorded worker error (memory overflow, peer loss), if any.
    pub fn take_error(&self) -> Option<String> {
        self.snapshot_all().into_iter().find_map(|s| s.error)
    }

    /// Drain all queues, join the worker threads, and report. Consumes
    /// the engine's usefulness: further [`MachineApi`] calls error or
    /// no-op.
    pub fn finish(&mut self) -> Result<ThreadedReport> {
        let expected = self.cmd_txs.len();
        let snaps = self.snapshot_all();
        self.cmd_txs.clear(); // close the queues
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        let wall = self.started.elapsed();
        if snaps.len() < expected {
            bail!(
                "threaded engine: {} worker thread(s) died",
                expected - snaps.len()
            );
        }
        if let Some(e) = snaps.iter().find_map(|s| s.error.clone()) {
            bail!("threaded engine: {e}");
        }
        let mut critical = Clock::default();
        let mut stats = MachineStats::default();
        let mut mem_peak_max = 0;
        let mut mem_peak_total = 0;
        let mut busy = Vec::with_capacity(snaps.len());
        for s in &snaps {
            critical = critical.join(&s.clock);
            stats.total_ops += s.total_ops;
            stats.total_words += s.sent_words;
            stats.total_msgs += s.sent_msgs;
            mem_peak_max = mem_peak_max.max(s.mem_peak);
            mem_peak_total += s.mem_peak;
            busy.push(s.busy);
        }
        Ok(ThreadedReport {
            wall,
            critical,
            stats,
            mem_peak_max,
            mem_peak_total,
            busy,
        })
    }
}

impl Drop for ThreadedMachine {
    fn drop(&mut self) {
        self.cmd_txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl MachineApi for ThreadedMachine {
    fn n_procs(&self) -> usize {
        self.cmd_txs.len()
    }
    fn mem_cap(&self) -> u64 {
        self.mem_cap
    }
    fn base(&self) -> Base {
        self.base
    }
    fn topology(&self) -> TopologyRef {
        Arc::clone(&self.topo)
    }

    fn alloc(&mut self, p: ProcId, data: Vec<u32>) -> Result<Slot> {
        let slot = self.fresh_slot(p);
        self.cmd(p, Cmd::Alloc { slot, data })?;
        Ok(slot)
    }
    fn free(&mut self, p: ProcId, slot: Slot) {
        let _ = self.cmd(p, Cmd::Free { slot });
    }
    fn read(&self, p: ProcId, slot: Slot) -> Result<Vec<u32>> {
        self.read_request(p, slot)
            .recv()
            .map(payload_into_vec)
            .map_err(|_| anyhow!("processor {p}: worker thread died during read"))
    }
    fn read_into(&self, p: ProcId, slot: Slot, buf: &mut Vec<u32>) -> Result<()> {
        let shared = self
            .read_request(p, slot)
            .recv()
            .map_err(|_| anyhow!("processor {p}: worker thread died during read"))?;
        buf.extend_from_slice(&shared);
        Ok(())
    }
    fn replace(&mut self, p: ProcId, slot: Slot, data: Vec<u32>) -> Result<()> {
        self.cmd(p, Cmd::Replace { slot, data })
    }

    fn compute(&mut self, p: ProcId, ops: u64) {
        let _ = self.cmd(p, Cmd::Compute { ops });
    }
    fn local<R, F>(&mut self, p: ProcId, f: F) -> Result<R>
    where
        R: Send + 'static,
        F: FnOnce(&Base, &mut Ops) -> R + Send + 'static,
    {
        let rx = self.local_request::<R, F>(p, f);
        let out = rx
            .recv()
            .map_err(|_| anyhow!("processor {p}: worker thread died during local"))?;
        Ok(*out.downcast::<R>().expect("local closure result type"))
    }
    fn compute_slot(
        &mut self,
        p: ProcId,
        inputs: &[Slot],
        consume: bool,
        f: SlotComputation,
    ) -> Result<Slot> {
        let out = self.fresh_slot(p);
        self.cmd(
            p,
            Cmd::ComputeSlot {
                out,
                inputs: inputs.to_vec(),
                consume,
                f,
            },
        )?;
        Ok(out)
    }

    fn send(&mut self, src: ProcId, dst: ProcId, data: Vec<u32>) -> Result<Slot> {
        self.route_send(src, dst, Payload::Owned(data))
    }
    fn send_copy(&mut self, src: ProcId, dst: ProcId, slot: Slot) -> Result<Slot> {
        self.route_send(
            src,
            dst,
            Payload::FromSlot {
                slot,
                range: None,
                free_after: false,
            },
        )
    }
    fn send_move(&mut self, src: ProcId, dst: ProcId, slot: Slot) -> Result<Slot> {
        self.route_send(
            src,
            dst,
            Payload::FromSlot {
                slot,
                range: None,
                free_after: true,
            },
        )
    }
    fn send_range(
        &mut self,
        src: ProcId,
        dst: ProcId,
        slot: Slot,
        range: std::ops::Range<usize>,
    ) -> Result<Slot> {
        self.route_send(
            src,
            dst,
            Payload::FromSlot {
                slot,
                range: Some(range),
                free_after: false,
            },
        )
    }
    fn barrier(&mut self, procs: &[ProcId]) -> Result<()> {
        if procs.len() <= 1 {
            return Ok(());
        }
        let state = Arc::new(BarrierState {
            expected: procs.len(),
            state: Mutex::new((0, Clock::default())),
            cv: Condvar::new(),
        });
        let mut dead = 0usize;
        for &p in procs {
            // A dead worker never reaches the rendezvous; lower the
            // expectation so the survivors are not stranded forever,
            // then report the death to the caller.
            if self
                .cmd(
                    p,
                    Cmd::Barrier {
                        state: Arc::clone(&state),
                    },
                )
                .is_err()
            {
                dead += 1;
                let mut g = state.state.lock().unwrap();
                g.0 += 1;
                if g.0 == state.expected {
                    state.cv.notify_all();
                }
            }
        }
        if dead > 0 {
            bail!("barrier: {dead} worker thread(s) dead");
        }
        Ok(())
    }

    fn proc_view(&self, p: ProcId) -> Result<ProcView> {
        let s = self.snapshot(p)?;
        Ok(ProcView {
            clock: s.clock,
            mem_used: s.mem_used,
            mem_peak: s.mem_peak,
        })
    }
    fn critical(&self) -> Clock {
        self.snapshot_all()
            .iter()
            .fold(Clock::default(), |acc, s| acc.join(&s.clock))
    }
    fn stats(&self) -> MachineStats {
        let mut st = MachineStats::default();
        for s in self.snapshot_all() {
            st.total_ops += s.total_ops;
            st.total_words += s.sent_words;
            st.total_msgs += s.sent_msgs;
        }
        st
    }
    fn mem_peak_max(&self) -> u64 {
        self.snapshot_all().iter().map(|s| s.mem_peak).max().unwrap_or(0)
    }
    fn mem_peak_total(&self) -> u64 {
        self.snapshot_all().iter().map(|s| s.mem_peak).sum()
    }
    fn mem_used_total(&self) -> u64 {
        self.snapshot_all().iter().map(|s| s.mem_used).sum()
    }
    fn purge(&mut self, p: ProcId) {
        let _ = self.cmd(p, Cmd::Purge);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(p: usize) -> ThreadedMachine {
        ThreadedMachine::unbounded(p, Base::new(16))
    }

    #[test]
    fn alloc_read_free_roundtrip() {
        let mut m = mk(2);
        let s = m.alloc(0, vec![1, 2, 3]).unwrap();
        assert_eq!(m.read(0, s).unwrap(), vec![1, 2, 3]);
        m.free(0, s);
        let snap = m.snapshot(0).unwrap();
        assert_eq!(snap.mem_used, 0);
        assert_eq!(snap.mem_peak, 3);
    }

    #[test]
    fn send_matches_cost_model_semantics() {
        let mut m = mk(2);
        m.compute(0, 10);
        let s = m.send(0, 1, vec![7, 8]).unwrap();
        assert_eq!(m.read(1, s).unwrap(), vec![7, 8]);
        let c0 = m.snapshot(0).unwrap().clock;
        let c1 = m.snapshot(1).unwrap().clock;
        assert_eq!(c0, Clock { ops: 10, words: 2, msgs: 1 });
        assert_eq!(c1, Clock { ops: 10, words: 2, msgs: 1 });
        let report = m.finish().unwrap();
        assert_eq!(report.stats.total_words, 2);
        assert_eq!(report.stats.total_msgs, 1);
    }

    #[test]
    fn local_runs_on_worker_and_charges() {
        let mut m = mk(1);
        let v = m
            .local(0, |base, ops| {
                ops.charge(42);
                base.s()
            })
            .unwrap();
        assert_eq!(v, 65536);
        assert_eq!(m.snapshot(0).unwrap().clock.ops, 42);
    }

    #[test]
    fn compute_slot_is_asynchronous_but_ordered() {
        let mut m = mk(2);
        let a = m.alloc(0, vec![2, 3]).unwrap();
        let out = m
            .compute_slot(
                0,
                &[a],
                true,
                Box::new(|inputs, _base, ops| {
                    ops.charge(inputs[0].len() as u64);
                    inputs[0].iter().map(|d| d * 10).collect()
                }),
            )
            .unwrap();
        // The read synchronizes with the pending computation.
        assert_eq!(m.read(0, out).unwrap(), vec![20, 30]);
        let snap = m.snapshot(0).unwrap();
        assert_eq!(snap.clock.ops, 2);
        assert_eq!(snap.mem_used, 2, "input consumed, output resident");
    }

    #[test]
    fn send_move_frees_source_worker_side() {
        let mut m = mk(2);
        let s = m.alloc(0, vec![1, 2]).unwrap();
        let d = m.send_move(0, 1, s).unwrap();
        assert_eq!(m.read(1, d).unwrap(), vec![1, 2]);
        assert_eq!(m.snapshot(0).unwrap().mem_used, 0);
    }

    #[test]
    fn barrier_joins_clocks() {
        let mut m = mk(3);
        m.compute(0, 5);
        m.compute(1, 9);
        m.barrier(&[0, 1, 2]).unwrap();
        assert_eq!(m.snapshot(2).unwrap().clock.ops, 9);
    }

    #[test]
    fn routed_send_matches_cost_model_hop_charges() {
        use super::super::topology::Torus2D;
        let mut m = ThreadedMachine::with_topology(
            16,
            u64::MAX / 2,
            Base::new(16),
            Arc::new(Torus2D::for_procs(16)),
        );
        // Same transfer as machine.rs's torus_send_charges_per_hop:
        // 0 -> 10 on the 4x4 torus is 4 wire hops through live relay
        // workers; clocks, stats and ledgers must match the cost model.
        let s = m.send(0, 10, vec![1, 2]).unwrap();
        assert_eq!(m.read(10, s).unwrap(), vec![1, 2]);
        assert_eq!(
            MachineApi::critical(&m),
            Clock { ops: 0, words: 8, msgs: 4 }
        );
        assert_eq!(m.mem_used_total(), 2, "relays must not touch ledgers");
        let report = m.finish().unwrap();
        assert_eq!(report.stats.total_msgs, 4);
        assert_eq!(report.stats.total_words, 8);
    }

    #[test]
    fn purge_resets_ledger_keeps_clock() {
        let mut m = mk(2);
        m.compute(1, 9);
        let _a = m.alloc(1, vec![1, 2, 3]).unwrap();
        MachineApi::purge(&mut m, 1);
        let v = m.proc_view(1).unwrap();
        assert_eq!(v.mem_used, 0);
        assert_eq!(v.mem_peak, 3);
        assert_eq!(v.clock.ops, 9);
        let s = m.alloc(1, vec![5]).unwrap();
        assert_eq!(m.read(1, s).unwrap(), vec![5]);
        m.finish().unwrap();
    }

    #[test]
    fn memory_overflow_surfaces_at_finish() {
        let mut m = ThreadedMachine::new(1, 4, Base::new(16));
        let _a = m.alloc(0, vec![0; 3]).unwrap();
        let _b = m.alloc(0, vec![0; 3]).unwrap(); // over cap, deferred
        assert!(m.finish().is_err());
    }

    #[test]
    fn parallel_compute_slots_overlap() {
        // Two slow leaves on different processors must overlap: the
        // wall-clock of the pair is well under the sum of both. Only
        // meaningful with at least two cores.
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        if cores < 2 {
            eprintln!("skipping: only {cores} core(s) available");
            return;
        }
        let mut m = mk(2);
        let work = |_: &[&[u32]], base: &Base, ops: &mut Ops| -> Vec<u32> {
            let mut acc = 1u64;
            for i in 0..4_000_000u64 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
            }
            ops.charge(1);
            vec![(acc & base.mask()) as u32]
        };
        let a0 = m.alloc(0, vec![1]).unwrap();
        let a1 = m.alloc(1, vec![1]).unwrap();
        let t0 = Instant::now();
        let o0 = m.compute_slot(0, &[a0], true, Box::new(work)).unwrap();
        let o1 = m.compute_slot(1, &[a1], true, Box::new(work)).unwrap();
        let _ = m.read(0, o0).unwrap();
        let _ = m.read(1, o1).unwrap();
        let wall = t0.elapsed();
        let report = m.finish().unwrap();
        let serial: Duration = report.busy.iter().sum();
        assert!(
            wall < serial,
            "no overlap: wall {wall:?} >= serial busy {serial:?}"
        );
    }
}

//! Collective communication over [`MachineApi`]: tree-structured
//! schedules implemented once, shared by every algorithm layer.
//!
//! The paper's `O(log² P)` latency claims come from tree-structured
//! communication; before this module each algorithm emitted its own
//! ad-hoc point-to-point loops, leaving the `log P` structure implicit.
//! Here every collective is a named schedule with an auditable message
//! bound, and the unit tests pin those bounds *exactly*:
//!
//! | collective     | schedule                      | total msgs | critical-path msgs |
//! |----------------|-------------------------------|------------|--------------------|
//! | [`broadcast`]  | binomial tree                 | `P − 1`    | `= ⌈log₂ P⌉`       |
//! | [`reduce`]     | binomial tree (carry-aware)   | `P − 1`    | `≤ ⌈log₂ P⌉` (= max popcount of ranks) |
//! | [`gather`]     | binomial tree (concatenating) | `P − 1`    | `≤ ⌈log₂ P⌉` (= max popcount of ranks) |
//! | [`scatter`]    | recursive halving             | `P − 1`    | `= ⌈log₂ P⌉`       |
//! | [`shift`]      | parallel pairwise exchange    | `≤ P`      | `1`                |
//! | [`fanout`]     | pairwise + doubling tail      | `≤ max(|src|,|dst|)` | `1 + ⌈log₂⌉ of the tail` |
//! | [`all_to_all`] | coalesced personalized runs   | one per maximal run | — |
//!
//! (Same-owner legs move for free and reduce the counts.)
//!
//! Everything is expressed in *logical* edges via the `send*`
//! primitives, so the network [`Topology`](super::topology::Topology)
//! underneath charges (and, on the threaded engine, routes) each edge
//! hop by hop without the collectives knowing; on the default
//! fully-connected topology the schedules charge exactly what the
//! paper's flat-send formulation charged — a zero-diff refactor pinned
//! by `tests/golden/cost_table.tsv`.
//!
//! Costed data movement lives here; [`gather_host`] is the one
//! deliberate exception — the free host-side collection used to
//! extract results and verify products (it reads, it does not
//! communicate).

use super::api::MachineApi;
use super::machine::{ProcId, Slot};
use super::seq::Seq;
use crate::bignum::core::add_with_carry;
use crate::error::Result;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

/// `⌈log₂ p⌉` (0 for p ≤ 1) — the binomial-tree round count.
pub fn ceil_log2(p: usize) -> u64 {
    if p <= 1 {
        0
    } else {
        crate::util::ilog2(crate::util::next_pow2(p as u64)) as u64
    }
}

// ------------------------------------------------------------ broadcast

/// Broadcast a scalar from `seq[root]` to every processor of `seq` with
/// a binomial tree: `P − 1` messages total, `⌈log₂ P⌉` rounds on the
/// critical path. Returns one scalar slot per sequence rank (root's
/// included).
pub fn broadcast<M: MachineApi>(
    m: &mut M,
    seq: &Seq,
    root: usize,
    value: u32,
) -> Result<Vec<Slot>> {
    let p = seq.len();
    let mut slots: Vec<Option<Slot>> = vec![None; p];
    slots[root] = Some(m.alloc_scalar(seq.at(root), value)?);
    // Re-rank so the root is rank 0 (rotation preserves pairings).
    let rerank = |r: usize| (r + root) % p;
    let mut have = 1usize;
    while have < p {
        // Ranks [0, have) send to ranks [have, 2·have) in parallel.
        for r in 0..have.min(p - have) {
            let src_rank = rerank(r);
            let dst_rank = rerank(r + have);
            let src = seq.at(src_rank);
            let dst = seq.at(dst_rank);
            let s = m.send(src, dst, vec![value])?;
            slots[dst_rank] = Some(s);
        }
        have *= 2;
    }
    Ok(slots.into_iter().map(|s| s.unwrap()).collect())
}

// --------------------------------------------------------------- fanout

/// Deliver a small payload (flags/carries) held by every processor of
/// `src_seq` to every processor of `dst_seq` — the SUM/COMPARE/DIFF
/// per-level flag exchange.
///
/// When the sequences have equal length this is the paper's single
/// parallel pairwise exchange (`P'[j] sends to P''[j]`): one message
/// round. With uneven halves (COPSIM recomposes on `3P/4` processors,
/// so one recursion level splits unevenly) the uncovered tail of
/// `dst_seq` is filled by doubling rounds among the receivers —
/// `O(log)` extra latency only at the uneven levels.
pub fn fanout<M: MachineApi>(
    m: &mut M,
    src_seq: &Seq,
    dst_seq: &Seq,
    payload: &[u32],
) -> Result<()> {
    assert!(
        !src_seq.is_empty() || dst_seq.is_empty(),
        "fanout: no source holds the payload (empty src_seq, {} destinations)",
        dst_seq.len()
    );
    let f = src_seq.len().min(dst_seq.len());
    // Round 0: pairwise.
    for j in 0..f {
        let s = m.send(src_seq.at(j), dst_seq.at(j), payload.to_vec())?;
        m.free(dst_seq.at(j), s);
    }
    // Doubling rounds among dst for the uncovered tail.
    let mut have = f;
    while have < dst_seq.len() {
        let take = have.min(dst_seq.len() - have);
        for j in 0..take {
            let s = m.send(dst_seq.at(j), dst_seq.at(have + j), payload.to_vec())?;
            m.free(dst_seq.at(have + j), s);
        }
        have += take;
    }
    Ok(())
}

// ---------------------------------------------------------------- shift

/// Parallel pairwise shift of a chunk vector onto another processor
/// sequence of the same length: entry `j` travels `src[j].owner →
/// dst[j]` as one message (chunks already on their destination copy
/// locally for free). One message round; `DistInt::replicate` and the
/// COPSIM splitting phases 1b/1c are instances.
pub fn shift<M: MachineApi>(
    m: &mut M,
    src: &[(ProcId, Slot)],
    dst: &Seq,
) -> Result<Vec<(ProcId, Slot)>> {
    assert_eq!(src.len(), dst.len(), "shift: length mismatch");
    let mut out = Vec::with_capacity(src.len());
    for (j, &(s, slot)) in src.iter().enumerate() {
        let d = dst.at(j);
        let ns = if s == d {
            let data = m.read(s, slot)?;
            m.alloc(d, data)?
        } else {
            m.send_copy(s, d, slot)?
        };
        out.push((d, ns));
    }
    Ok(out)
}

// --------------------------------------------------------------- gather

/// Collect the chunk contents host-side (verification / result
/// extraction only — reads, no communication, no cost). The costed
/// tree collective is [`gather`].
pub fn gather_host<M: MachineApi>(m: &M, chunks: &[(ProcId, Slot)]) -> Result<Vec<u32>> {
    let mut out = Vec::new();
    for &(p, slot) in chunks {
        m.read_into(p, slot, &mut out)?;
    }
    Ok(out)
}

/// Binomial-tree gather: concatenate the ranks' chunks (rank order,
/// i.e. LSB-first for `DistInt` chunks) onto the owner of `chunks[0]`.
/// Consumes every input slot; returns the gathered slot. `P − 1`
/// messages total, `⌈log₂ P⌉` rounds on the critical path; the words
/// on the wire double each round (the usual gather bandwidth shape).
pub fn gather<M: MachineApi>(m: &mut M, chunks: &[(ProcId, Slot)]) -> Result<(ProcId, Slot)> {
    assert!(!chunks.is_empty(), "gather of nothing");
    let p = chunks.len();
    let mut cur: Vec<(ProcId, Slot)> = chunks.to_vec();
    let mut step = 1usize;
    while step < p {
        let mut r = 0usize;
        while r + step < p {
            let (dp, ds) = cur[r];
            let (sp, ss) = cur[r + step];
            // Rank r+step's accumulated buffer moves to rank r…
            let moved = if sp == dp { ss } else { m.send_move(sp, dp, ss)? };
            // …and is appended (free both halves, allocate the concat
            // into a pooled buffer).
            let mut buf = m.take_buffer(0);
            m.read_into(dp, ds, &mut buf)?;
            m.read_into(dp, moved, &mut buf)?;
            m.free(dp, ds);
            m.free(dp, moved);
            cur[r] = (dp, m.alloc(dp, buf)?);
            r += 2 * step;
        }
        step *= 2;
    }
    Ok(cur[0])
}

// -------------------------------------------------------------- scatter

/// Recursive-halving scatter: `seq[0]` starts holding all
/// `width · |seq|` digits and every rank ends holding its own
/// `width`-digit chunk (rank order, LSB-first). `P − 1` messages total,
/// `⌈log₂ P⌉` rounds on the critical path. (The *free* initial layout
/// of `DistInt::scatter` models the paper's already-balanced input;
/// this collective is the costed redistribution from one owner.)
pub fn scatter<M: MachineApi>(
    m: &mut M,
    seq: &Seq,
    digits: &[u32],
    width: usize,
) -> Result<Vec<Slot>> {
    let p = seq.len();
    assert_eq!(digits.len(), width * p, "scatter: digit count mismatch");
    let root_slot = m.alloc(seq.at(0), digits.to_vec())?;
    let mut out: Vec<Option<Slot>> = vec![None; p];
    // (lo, hi, slot): `slot` holds digits [lo·w, hi·w) on seq[lo].
    let mut stack = vec![(0usize, p, root_slot)];
    while let Some((lo, hi, slot)) = stack.pop() {
        if hi - lo == 1 {
            out[lo] = Some(slot);
            continue;
        }
        // The holder keeps the lower ⌈half⌉ and ships the upper ⌊half⌋.
        let mid = lo + (hi - lo).div_ceil(2);
        let holder = seq.at(lo);
        let target = seq.at(mid);
        let cut = (mid - lo) * width;
        let total = (hi - lo) * width;
        let upper = if holder == target {
            let d = m.read(holder, slot)?[cut..total].to_vec();
            m.alloc(target, d)?
        } else {
            m.send_range(holder, target, slot, cut..total)?
        };
        let lower = m.read(holder, slot)?[..cut].to_vec();
        m.replace(holder, slot, lower)?;
        stack.push((lo, mid, slot));
        stack.push((mid, hi, upper));
    }
    Ok(out.into_iter().map(|s| s.unwrap()).collect())
}

// --------------------------------------------------------------- reduce

/// Carry-aware digit-sum reduce: the ranks' equal-width digit vectors
/// are summed (base-`s`, carries propagated) down a binomial tree onto
/// the owner of `addends[0]`. Consumes every input slot; returns the
/// sum slot plus the total carry out of the top digit (the sum of `P`
/// vectors can carry up to `P − 1`). `P − 1` messages total, each of
/// chunk width **plus one word for the partial's accumulated carry**
/// (the carry is part of the value being reduced — moving it host-side
/// would transfer information for free); `⌈log₂ P⌉` rounds on the
/// critical path; the digit-add work is charged to the combining
/// processors through `local`.
pub fn reduce<M: MachineApi>(
    m: &mut M,
    addends: &[(ProcId, Slot)],
) -> Result<(ProcId, Slot, u64)> {
    assert!(!addends.is_empty(), "reduce of nothing");
    let p = addends.len();
    let mut cur: Vec<(ProcId, Slot)> = addends.to_vec();
    let mut carries = vec![0u64; p];
    let mut step = 1usize;
    while step < p {
        let mut r = 0usize;
        while r + step < p {
            let (dp, ds) = cur[r];
            let (sp, ss) = cur[r + step];
            let (b, sub_carry) = if sp == dp {
                let b = m.read(dp, ss)?;
                m.free(dp, ss);
                (b, carries[r + step])
            } else {
                // The partial's carry count rides the message as one
                // extra word, so the charged bandwidth covers all the
                // information that moves.
                debug_assert!(carries[r + step] <= u32::MAX as u64);
                let mut payload = m.take_buffer(0);
                m.read_into(sp, ss, &mut payload)?;
                payload.push(carries[r + step] as u32);
                m.free(sp, ss);
                let s = m.send(sp, dp, payload)?;
                let mut b = m.read(dp, s)?;
                m.free(dp, s);
                let c = b.pop().expect("carry word") as u64;
                (b, c)
            };
            let a = m.read(dp, ds)?;
            debug_assert_eq!(a.len(), b.len(), "reduce: addend widths differ");
            let (sum, v) =
                m.local(dp, move |base, ops| add_with_carry(&a, &b, 0, *base, ops))?;
            m.free(dp, ds);
            cur[r] = (dp, m.alloc(dp, sum)?);
            carries[r] += sub_carry + v as u64;
            carries[r + step] = 0;
            r += 2 * step;
        }
        step *= 2;
    }
    Ok((cur[0].0, cur[0].1, carries[0]))
}

// ------------------------------------------------------------ all-to-all

/// The pure *shape* of a repartition: chunk widths and counts on both
/// sides. Everything the piece decomposition depends on — and nothing
/// more: owners, slot ids, processor identities, and the network
/// topology are all bound later, at execution. One cached plan
/// therefore serves every machine, every shard, and every topology
/// whose job has this shape (the key strictly subsumes a
/// (shape, P, topology) key).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanShape {
    pub old_width: usize,
    pub old_chunks: usize,
    pub new_width: usize,
    pub new_chunks: usize,
}

/// One piece of a symbolic repartition plan: source *chunk index* (not
/// owner/slot) plus the digit sub-range `[lo, hi)` of that chunk.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PieceTemplate {
    pub chunk: usize,
    pub lo: usize,
    pub hi: usize,
    /// Whole-chunk piece — the executor ships the slot without slicing.
    pub full: bool,
}

/// A compiled repartition: for each destination rank, its source pieces
/// in digit order.
pub type RepartitionPlan = Vec<Vec<PieceTemplate>>;

/// Soft cap on retained plans; the scheduler's workloads cycle through
/// a handful of shapes, so eviction (a full clear, crude but O(1)
/// amortized) is essentially never hit outside adversarial tests.
const PLAN_CACHE_MAX: usize = 256;

fn plan_cache() -> &'static Mutex<HashMap<PlanShape, Arc<RepartitionPlan>>> {
    static CACHE: OnceLock<Mutex<HashMap<PlanShape, Arc<RepartitionPlan>>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Number of compiled plans currently cached (test/introspection hook).
pub fn plan_cache_len() -> usize {
    plan_cache().lock().unwrap().len()
}

/// Compile (or fetch) the symbolic repartition plan for `shape`: per
/// destination chunk, the source pieces covering its digit window.
/// `DistInt::copy_to` binds owners and slots to this template and
/// groups consecutive same-owner pieces into the maximal runs that
/// travel as one message — so the executed plan is *identical* to the
/// one the old per-call compilation produced, it just stops being
/// recomputed for the scheduler's repeated same-shape jobs.
pub fn repartition_plan(shape: PlanShape) -> Arc<RepartitionPlan> {
    debug_assert_eq!(
        shape.old_width * shape.old_chunks,
        shape.new_width * shape.new_chunks,
        "repartition must preserve total width"
    );
    let cache = plan_cache();
    if let Some(plan) = cache.lock().unwrap().get(&shape) {
        return Arc::clone(plan);
    }
    let old_w = shape.old_width;
    let mut plan = Vec::with_capacity(shape.new_chunks);
    for j in 0..shape.new_chunks {
        let lo = j * shape.new_width;
        let hi = lo + shape.new_width;
        let first = lo / old_w;
        let last = (hi - 1) / old_w;
        let mut pieces = Vec::with_capacity(last - first + 1);
        for k in first..=last {
            let r_lo = lo.max(k * old_w) - k * old_w;
            let r_hi = hi.min((k + 1) * old_w) - k * old_w;
            pieces.push(PieceTemplate {
                chunk: k,
                lo: r_lo,
                hi: r_hi,
                full: r_lo == 0 && r_hi == old_w,
            });
        }
        plan.push(pieces);
    }
    let plan = Arc::new(plan);
    let mut g = cache.lock().unwrap();
    if g.len() >= PLAN_CACHE_MAX {
        g.clear();
    }
    g.insert(shape, Arc::clone(&plan));
    plan
}

/// One contiguous sub-range `[lo, hi)` of a source slot feeding a
/// destination chunk; `full` marks the whole-slot case (the executor
/// then ships the slot without slicing).
#[derive(Clone, Copy, Debug)]
pub struct Piece {
    pub slot: Slot,
    pub lo: usize,
    pub hi: usize,
    pub full: bool,
}

/// A maximal run of consecutive pieces living on one owner — the unit
/// that travels as ONE message (DESIGN.md, decision 4).
#[derive(Clone, Debug)]
pub struct Run {
    pub src: ProcId,
    pub pieces: Vec<Piece>,
}

/// Assembly recipe for one destination chunk of a personalized
/// all-to-all: where it lands and the source runs feeding it, in digit
/// order.
#[derive(Clone, Debug)]
pub struct ChunkPlan {
    pub dst: ProcId,
    pub width: usize,
    pub runs: Vec<Run>,
}

/// Read and concatenate a run's pieces on their owner (host-side copy
/// of resident digits — the shared coalescing step both local
/// assembly and remote payloads go through). The buffer is drawn from
/// the engine's pool, so repeated assembly reuses retired backing
/// stores instead of round-tripping the allocator.
fn assemble<M: MachineApi>(
    m: &mut M,
    src: ProcId,
    pieces: &[Piece],
    cap: usize,
) -> Result<Vec<u32>> {
    let mut buf = m.take_buffer(cap);
    for p in pieces {
        append_piece(m, src, p, &mut buf)?;
    }
    Ok(buf)
}

/// Append one piece's digits to `buf` — straight from engine storage
/// where the backend allows it, via a transient otherwise.
fn append_piece<M: MachineApi>(m: &M, src: ProcId, p: &Piece, buf: &mut Vec<u32>) -> Result<()> {
    if p.full {
        m.read_into(src, p.slot, buf)
    } else {
        let data = m.read(src, p.slot)?;
        buf.extend_from_slice(&data[p.lo..p.hi]);
        Ok(())
    }
}

/// Personalized all-to-all: execute a redistribution plan, moving every
/// digit at most once — one message per maximal contiguous
/// source-range → destination pair, runs already on their destination
/// moving for free. When a whole destination chunk arrives as a single
/// message, the received allocation *is* the chunk (the destination's
/// ledger is charged exactly once); a chunk assembled from several runs
/// pays a transient of at most one run on top of its final allocation.
/// `DistInt::copy_to` (and through it every repartition of COPSIM/COPK
/// and the DFS shuffles) compiles to this.
pub fn all_to_all<M: MachineApi>(m: &mut M, plan: &[ChunkPlan]) -> Result<Vec<(ProcId, Slot)>> {
    let mut out = Vec::with_capacity(plan.len());
    for chunk in plan {
        let dst = chunk.dst;
        if chunk.runs.len() == 1 {
            // The whole chunk comes from one owner: a single local
            // copy, or a single message whose received allocation is
            // the final chunk.
            let Run { src, pieces } = &chunk.runs[0];
            let slot = if *src == dst {
                let buf = assemble(m, *src, pieces, chunk.width)?;
                m.alloc(dst, buf)?
            } else if pieces.len() == 1 {
                let p = pieces[0];
                if p.full {
                    m.send_copy(*src, dst, p.slot)?
                } else {
                    m.send_range(*src, dst, p.slot, p.lo..p.hi)?
                }
            } else {
                let payload = assemble(m, *src, pieces, chunk.width)?;
                m.send(*src, dst, payload)?
            };
            out.push((dst, slot));
            continue;
        }
        // Several runs: receive each remote run as one message, append
        // it, and release the transient before the next run arrives, so
        // the destination's overshoot beyond the final chunk is bounded
        // by one run.
        let mut buf = m.take_buffer(chunk.width);
        for Run { src, pieces } in &chunk.runs {
            if *src == dst {
                for p in pieces {
                    append_piece(m, *src, p, &mut buf)?;
                }
            } else {
                let s = if pieces.len() == 1 {
                    let p = pieces[0];
                    m.send_range(*src, dst, p.slot, p.lo..p.hi)?
                } else {
                    let payload = assemble(m, *src, pieces, 0)?;
                    m.send(*src, dst, payload)?
                };
                m.read_into(dst, s, &mut buf)?;
                m.free(dst, s);
            }
        }
        debug_assert_eq!(buf.len(), chunk.width);
        let slot = m.alloc(dst, buf)?;
        out.push((dst, slot));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bignum::Base;
    use crate::sim::Machine;

    fn mk(p: usize) -> Machine {
        Machine::unbounded(p, Base::new(16))
    }

    /// Exact critical-path rounds of the combining binomial tree
    /// (gather/reduce): rank `r` sits at depth `popcount(r)`, so the
    /// longest send chain is the max popcount below `P` — equal to
    /// `⌈log₂P⌉` at powers of two, strictly smaller in between.
    fn combine_tree_depth(p: usize) -> u64 {
        (0..p).map(|r| r.count_ones() as u64).max().unwrap_or(0)
    }

    #[test]
    fn broadcast_message_counts_match_tree_bound_exactly() {
        for &p in &[2usize, 3, 5, 8, 16] {
            let mut m = mk(p);
            let seq = Seq::range(p);
            let slots = broadcast(&mut m, &seq, 0, 42).unwrap();
            for (r, s) in slots.iter().enumerate() {
                assert_eq!(m.read_scalar(seq.at(r), *s), 42);
            }
            assert_eq!(m.stats.total_msgs, p as u64 - 1, "total at P={p}");
            assert_eq!(
                m.critical().msgs,
                ceil_log2(p),
                "critical path at P={p}"
            );
        }
    }

    #[test]
    fn broadcast_from_nonzero_root() {
        let mut m = mk(8);
        let seq = Seq::range(8);
        let slots = broadcast(&mut m, &seq, 3, 77).unwrap();
        for (r, s) in slots.iter().enumerate() {
            assert_eq!(m.read_scalar(seq.at(r), *s), 77);
        }
        assert_eq!(m.stats.total_msgs, 7);
        assert_eq!(m.critical().msgs, 3);
    }

    #[test]
    fn gather_concatenates_with_tree_counts() {
        for &p in &[2usize, 4, 6, 8] {
            let mut m = mk(p);
            let mut chunks = Vec::new();
            for j in 0..p {
                let s = m.alloc(j, vec![j as u32; 2]).unwrap();
                chunks.push((j, s));
            }
            let (root, slot) = gather(&mut m, &chunks).unwrap();
            assert_eq!(root, 0);
            let want: Vec<u32> = (0..p as u32).flat_map(|j| [j, j]).collect();
            assert_eq!(m.read(0, slot), &want[..]);
            assert_eq!(m.stats.total_msgs, p as u64 - 1, "total at P={p}");
            assert_eq!(m.critical().msgs, combine_tree_depth(p), "critical at P={p}");
            assert!(m.critical().msgs <= ceil_log2(p));
            // Everything consumed but the gathered value.
            assert_eq!(m.mem_used_total(), 2 * p as u64);
        }
    }

    #[test]
    fn scatter_distributes_with_tree_counts() {
        for &p in &[2usize, 4, 6, 8] {
            let mut m = mk(p);
            let seq = Seq::range(p);
            let digits: Vec<u32> = (0..(3 * p) as u32).collect();
            let slots = scatter(&mut m, &seq, &digits, 3).unwrap();
            for (j, s) in slots.iter().enumerate() {
                assert_eq!(m.read(seq.at(j), *s), &digits[3 * j..3 * (j + 1)]);
            }
            assert_eq!(m.stats.total_msgs, p as u64 - 1, "total at P={p}");
            assert_eq!(m.critical().msgs, ceil_log2(p), "critical at P={p}");
            assert_eq!(m.mem_used_total(), 3 * p as u64, "no residue at P={p}");
        }
    }

    #[test]
    fn reduce_sums_digits_with_carries_and_tree_counts() {
        let base = Base::new(16);
        for &p in &[2usize, 4, 7, 8] {
            let mut m = Machine::unbounded(p, base);
            // Every rank contributes the all-max vector: the reduced sum
            // is exactly representable only with the carry counter.
            let max = (base.s() - 1) as u32;
            let w = 3usize;
            let mut addends = Vec::new();
            for j in 0..p {
                let s = m.alloc(j, vec![max; w]).unwrap();
                addends.push((j, s));
            }
            let (root, slot, carry) = reduce(&mut m, &addends).unwrap();
            assert_eq!(root, 0);
            // Σ = p·(s^w − 1): digits of (−p mod s^w), carry out ⌊Σ/s^w⌋.
            let got = m.read(0, slot).to_vec();
            let s_u = base.s() as u128;
            let modulus = s_u.pow(w as u32);
            let want_val = p as u128 * (modulus - 1);
            let mut rem = want_val % modulus;
            let mut want_digits = Vec::with_capacity(w);
            for _ in 0..w {
                want_digits.push((rem % s_u) as u32);
                rem /= s_u;
            }
            assert_eq!(got, want_digits, "digits at P={p}");
            assert_eq!(carry, (want_val / modulus) as u64, "carry at P={p}");
            assert_eq!(m.stats.total_msgs, p as u64 - 1, "total at P={p}");
            // Every message is one chunk plus the riding carry word.
            assert_eq!(
                m.stats.total_words,
                (p as u64 - 1) * (w as u64 + 1),
                "words at P={p}"
            );
            assert_eq!(m.critical().msgs, combine_tree_depth(p), "critical at P={p}");
            assert!(m.critical().msgs <= ceil_log2(p));
            assert_eq!(m.mem_used_total(), w as u64);
        }
    }

    #[test]
    fn shift_is_one_parallel_round() {
        let mut m = mk(8);
        let mut src = Vec::new();
        for j in 0..4 {
            let s = m.alloc(j, vec![10 + j as u32]).unwrap();
            src.push((j, s));
        }
        // Shift onto [4,5,2,3]: two remote legs, two local copies.
        let dst = Seq(vec![4, 5, 2, 3]);
        let out = shift(&mut m, &src, &dst).unwrap();
        for (j, &(d, s)) in out.iter().enumerate() {
            assert_eq!(d, dst.at(j));
            assert_eq!(m.read(d, s), &[10 + j as u32]);
        }
        assert_eq!(m.stats.total_msgs, 2, "same-owner legs are free");
        assert_eq!(m.critical().msgs, 1, "one parallel round");
    }

    #[test]
    fn fanout_equal_halves_is_one_round() {
        let mut m = mk(8);
        let lo = Seq(vec![0, 1, 2, 3]);
        let hi = Seq(vec![4, 5, 6, 7]);
        fanout(&mut m, &lo, &hi, &[1, 2]).unwrap();
        assert_eq!(m.stats.total_msgs, 4);
        assert_eq!(m.critical().msgs, 1);
        assert_eq!(m.mem_used_total(), 0, "fanout payloads are transient");
    }

    #[test]
    fn fanout_uneven_tail_doubles() {
        let mut m = mk(8);
        let src = Seq(vec![0, 1]);
        let dst = Seq(vec![2, 3, 4, 5, 6, 7]);
        fanout(&mut m, &src, &dst, &[9]).unwrap();
        // Pairwise round (2 msgs) + doubling among dst: 2 -> 4 -> 6
        // covered in 2 more rounds (2 + 2 msgs).
        assert_eq!(m.stats.total_msgs, 6);
        assert_eq!(m.critical().msgs, 3);
    }

    #[test]
    fn repartition_plan_cache_hits_and_decomposes_exactly() {
        let shape = PlanShape {
            old_width: 4,
            old_chunks: 4,
            new_width: 8,
            new_chunks: 2,
        };
        let p1 = repartition_plan(shape);
        let p2 = repartition_plan(shape);
        assert!(
            std::sync::Arc::ptr_eq(&p1, &p2),
            "same shape must hit the cache"
        );
        assert!(plan_cache_len() >= 1);
        // Hand-derived decomposition: each 8-digit destination chunk is
        // two full 4-digit source chunks.
        assert_eq!(p1.len(), 2);
        assert_eq!(
            p1[0],
            vec![
                PieceTemplate { chunk: 0, lo: 0, hi: 4, full: true },
                PieceTemplate { chunk: 1, lo: 0, hi: 4, full: true },
            ]
        );
        assert_eq!(
            p1[1],
            vec![
                PieceTemplate { chunk: 2, lo: 0, hi: 4, full: true },
                PieceTemplate { chunk: 3, lo: 0, hi: 4, full: true },
            ]
        );
        // A ragged shape splits chunks mid-stream.
        let ragged = repartition_plan(PlanShape {
            old_width: 4,
            old_chunks: 3,
            new_width: 3,
            new_chunks: 4,
        });
        assert_eq!(
            ragged[1],
            vec![
                PieceTemplate { chunk: 0, lo: 3, hi: 4, full: false },
                PieceTemplate { chunk: 1, lo: 0, hi: 2, full: false },
            ]
        );
    }

    #[test]
    fn all_to_all_single_full_piece_is_one_message_charged_once() {
        let mut m = mk(2);
        let s = m.alloc(0, vec![1, 2, 3, 4]).unwrap();
        let plan = vec![ChunkPlan {
            dst: 1,
            width: 4,
            runs: vec![Run {
                src: 0,
                pieces: vec![Piece { slot: s, lo: 0, hi: 4, full: true }],
            }],
        }];
        let out = all_to_all(&mut m, &plan).unwrap();
        assert_eq!(m.read(1, out[0].1), &[1, 2, 3, 4]);
        assert_eq!(m.stats.total_msgs, 1);
        assert_eq!(m.stats.total_words, 4);
        assert_eq!(
            m.proc(1).mem_peak(),
            4,
            "received allocation IS the chunk — charged once"
        );
    }
}

//! Network topologies: the physical interconnect under the machine
//! model's logical point-to-point sends.
//!
//! The paper's model charges every message one unit of latency and its
//! payload once in bandwidth — an implicit *fully-connected* network.
//! Real machines are not fully connected: a message between two
//! processors crosses a route of physical links, each link charging its
//! own bandwidth and latency. The [`Topology`] trait makes that mapping
//! explicit: it turns a logical `(src, dst)` edge into a route of
//! physical hops plus per-link bandwidth weights, and every execution
//! engine charges (and, for the threaded engine, actually performs) the
//! transfer hop by hop. See DESIGN.md, "Collectives & topologies".
//!
//! ## Charging rule (shared by all engines)
//!
//! A logical send of `k` words over route `p₀ → p₁ → … → p_h` performs
//! `h` hop transfers. Hop `i` charges `k · link_bw_weight(p_i, p_{i+1})`
//! words and one message to `p_i`'s clock, and `p_{i+1}`'s clock joins
//! `p_i`'s post-charge snapshot. Relays are pure *wire* forwarders:
//! their memory ledgers are untouched (a switch buffers in network
//! hardware, not in the processor's `M`-word local memory), so the
//! paper's memory-requirement statements are topology-independent. Only
//! the destination allocates the payload. On the fully-connected
//! topology every route is the direct edge `[src, dst]` with weight 1,
//! which reproduces the paper's charging bit for bit — the default
//! topology is a zero-diff path.
//!
//! ## The three shipped topologies
//!
//! * [`FullyConnected`] — the paper's implicit network (default).
//! * [`Torus2D`] — a 2D torus/mesh with wraparound links and
//!   dimension-ordered (row-first) routing; `P` is factored into the
//!   most-square `rows × cols` grid. Worst-case hops (diameter) is
//!   `⌊rows/2⌋ + ⌊cols/2⌋`.
//! * [`HierCluster`] — a two-level cluster: processors are grouped into
//!   clusters of `cluster` consecutive ids; intra-cluster links are
//!   full-speed direct edges, inter-cluster traffic routes through the
//!   clusters' gateway processors over a half-bandwidth backbone
//!   (`link_bw_weight = 2`). Worst-case route is
//!   `src → gateway → gateway → dst`: 3 hops.

use super::machine::ProcId;
use crate::error::bail;
use std::fmt;
use std::sync::Arc;

/// A physical interconnect: maps logical `(src, dst)` edges to hop
/// routes and per-link charge weights (see module docs).
pub trait Topology: Send + Sync + fmt::Debug {
    /// Short stable name (used in tables and CLI echoes).
    fn name(&self) -> &'static str;

    /// The physical route from `src` to `dst`, inclusive of both
    /// endpoints (`len() >= 2` whenever `src != dst`). Deterministic:
    /// the same edge always routes the same way.
    fn route(&self, src: ProcId, dst: ProcId) -> Vec<ProcId>;

    /// Number of physical links one `(src, dst)` message crosses.
    fn hops(&self, src: ProcId, dst: ProcId) -> u64 {
        self.route(src, dst).len() as u64 - 1
    }

    /// Per-word charge multiplier of the physical link `(a, b)`
    /// (1 = full-speed link).
    fn link_bw_weight(&self, a: ProcId, b: ProcId) -> u64;

    /// Worst-case hops between any processor pair (at least 1) — the
    /// latency inflation factor `theory::` predictions use.
    fn diameter(&self) -> u64;

    /// Worst-case per-word link weight — the bandwidth inflation
    /// factor `theory::` predictions use.
    fn max_link_bw_weight(&self) -> u64;
}

/// Shared handle to a topology (engines clone it freely).
pub type TopologyRef = Arc<dyn Topology>;

// ------------------------------------------------------ fully connected

/// The paper's implicit network: every pair joined by a dedicated
/// full-speed link. Routes are the direct edges; charging degenerates
/// to the paper's one-message-one-payload rule.
#[derive(Clone, Copy, Debug, Default)]
pub struct FullyConnected;

impl Topology for FullyConnected {
    fn name(&self) -> &'static str {
        "fully-connected"
    }
    fn route(&self, src: ProcId, dst: ProcId) -> Vec<ProcId> {
        vec![src, dst]
    }
    fn hops(&self, _src: ProcId, _dst: ProcId) -> u64 {
        1
    }
    fn link_bw_weight(&self, _a: ProcId, _b: ProcId) -> u64 {
        1
    }
    fn diameter(&self) -> u64 {
        1
    }
    fn max_link_bw_weight(&self) -> u64 {
        1
    }
}

// ---------------------------------------------------------------- torus

/// 2D torus: `rows × cols` grid with wraparound links in both
/// dimensions, dimension-ordered routing (rows first, then columns,
/// each along the shorter way around; ties go forward). Processor `p`
/// sits at `(p / cols, p % cols)`.
#[derive(Clone, Copy, Debug)]
pub struct Torus2D {
    pub rows: usize,
    pub cols: usize,
}

impl Torus2D {
    /// The most-square torus holding exactly `p` processors: `rows` is
    /// the largest divisor of `p` with `rows ≤ √p` (a prime `p`
    /// degenerates to a 1 × p ring).
    pub fn for_procs(p: usize) -> Self {
        let p = p.max(1);
        let mut rows = 1;
        let mut d = 1;
        while d * d <= p {
            if p % d == 0 {
                rows = d;
            }
            d += 1;
        }
        Torus2D { rows, cols: p / rows }
    }

    #[inline]
    fn coords(&self, p: ProcId) -> (usize, usize) {
        (p / self.cols, p % self.cols)
    }

    /// Shortest circular distance and step (+1 or n-1, additive mod n)
    /// from `a` to `b` on a ring of `n`; ties break forward.
    fn ring_step(a: usize, b: usize, n: usize) -> (usize, usize) {
        let fwd = (b + n - a) % n;
        let bwd = (a + n - b) % n;
        if fwd <= bwd {
            (fwd, 1)
        } else {
            (bwd, n - 1)
        }
    }
}

impl Topology for Torus2D {
    fn name(&self) -> &'static str {
        "torus"
    }

    fn route(&self, src: ProcId, dst: ProcId) -> Vec<ProcId> {
        let (mut r, c0) = self.coords(src);
        let (tr, tc) = self.coords(dst);
        let mut path = vec![src];
        let (dr, rstep) = Self::ring_step(r, tr, self.rows);
        for _ in 0..dr {
            r = (r + rstep) % self.rows;
            path.push(r * self.cols + c0);
        }
        let mut c = c0;
        let (dc, cstep) = Self::ring_step(c, tc, self.cols);
        for _ in 0..dc {
            c = (c + cstep) % self.cols;
            path.push(r * self.cols + c);
        }
        path
    }

    fn hops(&self, src: ProcId, dst: ProcId) -> u64 {
        let (r0, c0) = self.coords(src);
        let (r1, c1) = self.coords(dst);
        let (dr, _) = Self::ring_step(r0, r1, self.rows);
        let (dc, _) = Self::ring_step(c0, c1, self.cols);
        (dr + dc) as u64
    }

    fn link_bw_weight(&self, _a: ProcId, _b: ProcId) -> u64 {
        1
    }

    fn diameter(&self) -> u64 {
        ((self.rows / 2 + self.cols / 2) as u64).max(1)
    }

    fn max_link_bw_weight(&self) -> u64 {
        1
    }
}

// ----------------------------------------------------------- hierarchy

/// Two-level cluster: consecutive blocks of `cluster` processors form a
/// cluster whose first processor is its gateway. Intra-cluster edges
/// are direct full-speed links; inter-cluster traffic routes
/// `src → gateway(src) → gateway(dst) → dst` over a backbone whose
/// links charge `inter_weight` words per word (a half-bandwidth uplink
/// at the default 2).
#[derive(Clone, Copy, Debug)]
pub struct HierCluster {
    pub procs: usize,
    pub cluster: usize,
    pub inter_weight: u64,
}

impl HierCluster {
    /// Near-square clustering (`cluster = ⌈√p⌉`) with the default
    /// half-bandwidth backbone.
    pub fn for_procs(p: usize) -> Self {
        let p = p.max(1);
        let mut c = 1;
        while c * c < p {
            c += 1;
        }
        HierCluster {
            procs: p,
            cluster: c,
            inter_weight: 2,
        }
    }

    #[inline]
    fn cluster_of(&self, p: ProcId) -> usize {
        p / self.cluster
    }

    #[inline]
    fn gateway(&self, cluster: usize) -> ProcId {
        cluster * self.cluster
    }
}

impl Topology for HierCluster {
    fn name(&self) -> &'static str {
        "hier"
    }

    fn route(&self, src: ProcId, dst: ProcId) -> Vec<ProcId> {
        let (cs, cd) = (self.cluster_of(src), self.cluster_of(dst));
        if cs == cd {
            return vec![src, dst];
        }
        let mut path = vec![src];
        let gs = self.gateway(cs);
        if gs != src {
            path.push(gs);
        }
        let gd = self.gateway(cd);
        path.push(gd);
        if gd != dst {
            path.push(dst);
        }
        path
    }

    fn hops(&self, src: ProcId, dst: ProcId) -> u64 {
        // O(1) — the engines call this on every send (the default
        // impl would materialize the route just to count its links).
        let (cs, cd) = (self.cluster_of(src), self.cluster_of(dst));
        if cs == cd {
            1
        } else {
            let mut h = 1; // the backbone link
            if self.gateway(cs) != src {
                h += 1;
            }
            if self.gateway(cd) != dst {
                h += 1;
            }
            h
        }
    }

    fn link_bw_weight(&self, a: ProcId, b: ProcId) -> u64 {
        if self.cluster_of(a) == self.cluster_of(b) {
            1
        } else {
            self.inter_weight
        }
    }

    fn diameter(&self) -> u64 {
        if self.procs <= self.cluster {
            1 // single cluster: all intra
        } else if self.cluster == 1 {
            1 // every processor is a gateway: one backbone hop
        } else {
            3 // src -> gateway -> gateway -> dst
        }
    }

    fn max_link_bw_weight(&self) -> u64 {
        if self.procs <= self.cluster {
            1
        } else {
            self.inter_weight
        }
    }
}

// ------------------------------------------------------- configuration

/// Topology selector carried by configs and [`crate::coordinator::JobSpec`]
/// (`--topology` on the CLI); [`TopologyKind::build`] instantiates the
/// concrete topology for a machine's processor count.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TopologyKind {
    #[default]
    FullyConnected,
    Torus,
    Hier,
}

impl TopologyKind {
    /// Instantiate the topology for a `p`-processor machine.
    pub fn build(self, p: usize) -> TopologyRef {
        match self {
            TopologyKind::FullyConnected => Arc::new(FullyConnected),
            TopologyKind::Torus => Arc::new(Torus2D::for_procs(p)),
            TopologyKind::Hier => Arc::new(HierCluster::for_procs(p)),
        }
    }

    /// All kinds, for matrix-style sweeps (tests, E18).
    pub const ALL: [TopologyKind; 3] = [
        TopologyKind::FullyConnected,
        TopologyKind::Torus,
        TopologyKind::Hier,
    ];
}

impl std::str::FromStr for TopologyKind {
    type Err = crate::error::Error;
    fn from_str(s: &str) -> crate::error::Result<Self> {
        Ok(match s {
            "fully-connected" | "full" | "fc" => TopologyKind::FullyConnected,
            "torus" | "torus2d" | "mesh" => TopologyKind::Torus,
            "hier" | "hierarchical" | "cluster" => TopologyKind::Hier,
            _ => bail!("unknown topology `{s}` (fully-connected|torus|hier)"),
        })
    }
}

impl fmt::Display for TopologyKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TopologyKind::FullyConnected => write!(f, "fully-connected"),
            TopologyKind::Torus => write!(f, "torus"),
            TopologyKind::Hier => write!(f, "hier"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_route(t: &dyn Topology, src: ProcId, dst: ProcId) {
        let r = t.route(src, dst);
        assert_eq!(*r.first().unwrap(), src);
        assert_eq!(*r.last().unwrap(), dst);
        assert_eq!(r.len() as u64 - 1, t.hops(src, dst), "{src}->{dst} on {}", t.name());
        assert!(t.hops(src, dst) <= t.diameter());
        // Simple path: no processor repeats.
        let mut seen = r.clone();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), r.len(), "route revisits a node: {r:?}");
    }

    #[test]
    fn fully_connected_is_direct() {
        let t = FullyConnected;
        for (s, d) in [(0, 1), (3, 7), (15, 0)] {
            assert_eq!(t.route(s, d), vec![s, d]);
            assert_eq!(t.hops(s, d), 1);
        }
        assert_eq!(t.diameter(), 1);
        assert_eq!(t.max_link_bw_weight(), 1);
    }

    #[test]
    fn torus_factorization_is_most_square() {
        assert_eq!((Torus2D::for_procs(16).rows, Torus2D::for_procs(16).cols), (4, 4));
        assert_eq!((Torus2D::for_procs(12).rows, Torus2D::for_procs(12).cols), (3, 4));
        assert_eq!((Torus2D::for_procs(7).rows, Torus2D::for_procs(7).cols), (1, 7));
        assert_eq!((Torus2D::for_procs(1).rows, Torus2D::for_procs(1).cols), (1, 1));
    }

    #[test]
    fn torus_routes_are_shortest_and_wrap() {
        let t = Torus2D::for_procs(16); // 4 x 4
        // Neighbors: one hop.
        assert_eq!(t.hops(0, 1), 1);
        assert_eq!(t.hops(0, 4), 1);
        // Wraparound: (0,0) -> (0,3) is one hop backwards.
        assert_eq!(t.hops(0, 3), 1);
        assert_eq!(t.hops(0, 12), 1);
        // Opposite corner: diameter.
        assert_eq!(t.hops(0, 10), 4);
        assert_eq!(t.diameter(), 4);
        for s in 0..16 {
            for d in 0..16 {
                if s != d {
                    check_route(&t, s, d);
                }
            }
        }
    }

    #[test]
    fn hier_routes_through_gateways() {
        let t = HierCluster::for_procs(16); // clusters of 4, gateways 0,4,8,12
        assert_eq!(t.cluster, 4);
        // Intra-cluster: direct.
        assert_eq!(t.route(1, 3), vec![1, 3]);
        assert_eq!(t.link_bw_weight(1, 3), 1);
        // Full inter-cluster route: src -> gw -> gw -> dst.
        assert_eq!(t.route(1, 7), vec![1, 0, 4, 7]);
        // Gateway endpoints shorten the route.
        assert_eq!(t.route(0, 7), vec![0, 4, 7]);
        assert_eq!(t.route(1, 4), vec![1, 0, 4]);
        assert_eq!(t.route(0, 4), vec![0, 4]);
        // The backbone link is the weighted one.
        assert_eq!(t.link_bw_weight(0, 4), 2);
        assert_eq!(t.diameter(), 3);
        assert_eq!(t.max_link_bw_weight(), 2);
        for s in 0..16 {
            for d in 0..16 {
                if s != d {
                    check_route(&t, s, d);
                }
            }
        }
    }

    #[test]
    fn kind_parses_and_builds() {
        use std::str::FromStr;
        let fc = TopologyKind::from_str("fully-connected").unwrap();
        assert_eq!(fc, TopologyKind::FullyConnected);
        assert_eq!(TopologyKind::from_str("torus").unwrap(), TopologyKind::Torus);
        assert_eq!(TopologyKind::from_str("hierarchical").unwrap(), TopologyKind::Hier);
        assert!(TopologyKind::from_str("ring").is_err());
        for kind in TopologyKind::ALL {
            let t = kind.build(12);
            assert!(t.diameter() >= 1);
            assert_eq!(kind.to_string().parse::<TopologyKind>().unwrap(), kind);
        }
    }
}

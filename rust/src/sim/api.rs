//! [`MachineApi`] — the machine-model surface the algorithms program
//! against (see DESIGN.md, "Execution engines").
//!
//! The paper's COPSIM/COPK are coordination algorithms: they are defined
//! by the sequence of allocations, local computations, and point-to-point
//! messages each processor performs, independently of what actually
//! executes them. This trait captures exactly that surface —
//! alloc/free/read/replace (the per-processor memory ledger),
//! compute/local/compute_slot (digit work), send*/barrier
//! (communication) and the cost/memory reporting — so one algorithm
//! source drives every backend:
//!
//! * [`super::Machine`] — the deterministic cost-model interpreter
//!   (logical clocks, critical-path accounting, single host thread).
//! * [`super::ThreadedMachine`] — real execution: one OS thread per
//!   simulated processor, per-processor arenas, mpsc message channels,
//!   wall-clock timing alongside the same logical clocks.
//!
//! ## Contract
//!
//! Backends must charge costs identically: `compute`/`local`/
//! `compute_slot` add digit ops to the executing processor's clock; a
//! send is charged hop by hop along the topology's route — each link
//! sender pays the payload size times the link's bandwidth weight plus
//! one message, and the next hop joins the post-charge snapshot (on the
//! fully-connected default this is the paper's charge-once-to-the-
//! sender rule); `barrier` joins the clocks of the given processors.
//! Under that contract the two backends produce *bit-identical products
//! and identical cost triples* on every topology — property-tested in
//! `tests/theorem_properties.rs`.
//!
//! ## Asynchrony
//!
//! `compute_slot` is the operation that lets a real-threads backend
//! actually overlap work: it names its inputs and output by slot, so the
//! backend may run the closure on the owning processor *asynchronously*
//! and only synchronize when some later operation reads the produced
//! slot. The recursion leaves of COPSIM/COPK (the dominant O(w²)/
//! O(w^lg3) digit work) go through it, which is where the threaded
//! backend's wall-clock speedup comes from. `local` stays synchronous
//! because its result feeds control flow (carries, flags).
//!
//! ## Fallibility
//!
//! The blocking operations (`read`, `local`, `proc_view`) return
//! `Result`: on a real-threads backend the owning worker thread can be
//! gone (panicked, or crashed by the fault-injection wrapper
//! [`super::FaultyMachine`]), and the failure must surface as an error
//! the caller — one job of many on a shared machine — can recover from,
//! rather than poisoning the whole machine with a panic. The cost-model
//! backend never fails these. `barrier` is fallible for the same
//! reason: a rendezvous that includes a dead or crashed processor must
//! report it to the caller instead of silently completing without the
//! corpse. Purely-accounting operations (`compute`, `free`, `purge`)
//! stay infallible; on a dead processor they become no-ops and the
//! next fallible operation reports the death.
//!
//! ## Topology
//!
//! Every engine carries a [`Topology`] describing the physical
//! interconnect (fully-connected by default). Sends are charged — and,
//! on the threaded backend, actually routed — hop by hop along
//! `topology().route(src, dst)` with per-link bandwidth weights; see
//! the `topology` module docs for the charging rule. The collective
//! schedules in `sim::collectives` are expressed in logical edges and
//! inherit the topology through these send primitives.

use super::machine::{MachineStats, ProcId, Slot};
use super::topology::TopologyRef;
use super::Clock;
use crate::bignum::{Base, Ops};
use crate::error::Result;
use std::ops::Range;

/// A computation shipped to a processor by [`MachineApi::compute_slot`]:
/// receives the input slots' contents as borrowed digit slices (the
/// backend lends its storage — consumed inputs are moved, never cloned,
/// and non-consumed inputs are viewed in place), plus the machine base;
/// charges its digit ops, and returns the output slot's contents.
pub type SlotComputation = Box<dyn FnOnce(&[&[u32]], &Base, &mut Ops) -> Vec<u32> + Send>;

/// Point-in-time view of a single processor: its logical clock and
/// memory ledger. Returned by [`MachineApi::proc_view`]; the scheduler
/// uses it to account per-shard costs (a job's cost triple is the join
/// of its shard's end clocks minus the uniform baseline the shard was
/// barrier'd to at acquisition).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ProcView {
    /// The processor's logical clock.
    pub clock: Clock,
    /// Words currently resident in its local memory.
    pub mem_used: u64,
    /// High-water mark of `mem_used` over the machine's lifetime.
    pub mem_peak: u64,
}

/// The machine-model operation surface (see module docs).
pub trait MachineApi {
    // ----- shape ------------------------------------------------------

    /// Number of processors.
    fn n_procs(&self) -> usize;
    /// Per-processor memory capacity `M` in words.
    fn mem_cap(&self) -> u64;
    /// Digit base.
    fn base(&self) -> Base;
    /// The physical interconnect (see module docs, "Topology").
    fn topology(&self) -> TopologyRef;

    // ----- memory ledger ---------------------------------------------

    /// Allocate `data` in `p`'s local memory, returning a slot handle.
    /// The cost-model backend fails eagerly when the capacity `M` would
    /// be exceeded; asynchronous backends may defer the report (the
    /// overflow then surfaces at the next synchronizing operation or at
    /// finish time).
    fn alloc(&mut self, p: ProcId, data: Vec<u32>) -> Result<Slot>;

    /// Allocate a single scalar word (flags, carries).
    fn alloc_scalar(&mut self, p: ProcId, v: u32) -> Result<Slot> {
        self.alloc(p, vec![v])
    }

    /// Free a slot.
    fn free(&mut self, p: ProcId, slot: Slot);

    /// Read a slot's contents (no cost charged; synchronizes with any
    /// pending asynchronous work on `p`). Fails when `p`'s worker is
    /// dead or crashed (see module docs, "Fallibility").
    fn read(&self, p: ProcId, slot: Slot) -> Result<Vec<u32>>;

    /// Read a scalar slot.
    fn read_scalar(&self, p: ProcId, slot: Slot) -> Result<u32> {
        let d = self.read(p, slot)?;
        debug_assert_eq!(d.len(), 1);
        Ok(d[0])
    }

    /// Append a slot's contents to `buf` (no cost charged; same
    /// synchronization and failure semantics as [`MachineApi::read`]).
    /// Engines whose storage is host-visible append straight from it,
    /// skipping the intermediate vector `read` would materialize — the
    /// collectives' assembly loops go through this.
    fn read_into(&self, p: ProcId, slot: Slot, buf: &mut Vec<u32>) -> Result<()> {
        buf.extend_from_slice(&self.read(p, slot)?);
        Ok(())
    }

    /// Overwrite a slot in place (same or different width; ledger
    /// updated).
    fn replace(&mut self, p: ProcId, slot: Slot, data: Vec<u32>) -> Result<()>;

    // ----- computation ------------------------------------------------

    /// Charge `ops` digit operations to `p`'s clock.
    fn compute(&mut self, p: ProcId, ops: u64);

    /// Run a local computation on `p` whose digit-op count is tracked by
    /// an [`Ops`] counter; blocks until the result is available (results
    /// feed control flow). Executes on `p`'s thread in the threaded
    /// backend; fails when that thread is dead or crashed.
    fn local<R, F>(&mut self, p: ProcId, f: F) -> Result<R>
    where
        R: Send + 'static,
        F: FnOnce(&Base, &mut Ops) -> R + Send + 'static;

    /// Run a local computation on `p` from input slots to a fresh output
    /// slot, possibly asynchronously (see module docs). When `consume`
    /// is true the input slots are freed once their contents have been
    /// captured, *before* the output is allocated — this mirrors the
    /// paper's leaves, which drop their operands as the product
    /// materializes, and keeps the ledger peak at inputs+scratch rather
    /// than inputs+scratch+output.
    fn compute_slot(
        &mut self,
        p: ProcId,
        inputs: &[Slot],
        consume: bool,
        f: SlotComputation,
    ) -> Result<Slot>;

    // ----- communication ----------------------------------------------

    /// Send `data` from `src` to `dst` as one logical message;
    /// allocates the payload in `dst`'s memory and returns the new
    /// slot. On the fully-connected topology this is charged once, to
    /// the sender, and the receiver's clock joins the sender's
    /// post-send snapshot; on other topologies the transfer is charged
    /// (and on the threaded engine performed) hop by hop along
    /// `topology().route(src, dst)`, each relay joining the previous
    /// hop's snapshot before charging its own link. Relays never touch
    /// their memory ledgers (wire forwarding — see `topology` docs).
    fn send(&mut self, src: ProcId, dst: ProcId, data: Vec<u32>) -> Result<Slot>;

    /// Send a copy of an existing slot (source keeps its copy).
    fn send_copy(&mut self, src: ProcId, dst: ProcId, slot: Slot) -> Result<Slot>;

    /// Send an existing slot and free it at the source.
    fn send_move(&mut self, src: ProcId, dst: ProcId, slot: Slot) -> Result<Slot>;

    /// Send a sub-range of a slot's digits (copy).
    fn send_range(
        &mut self,
        src: ProcId,
        dst: ProcId,
        slot: Slot,
        range: Range<usize>,
    ) -> Result<Slot>;

    /// Synchronize a set of processors: all their clocks join. Fails
    /// when any of them is dead or crashed (see module docs,
    /// "Fallibility") — the survivors are still released, never left
    /// waiting on the corpse.
    fn barrier(&mut self, procs: &[ProcId]) -> Result<()>;

    // ----- reporting ----------------------------------------------------

    /// One processor's clock and memory ledger (synchronizes with any
    /// pending asynchronous work on `p`). Sub-machine (shard) costs are
    /// computed from these views; `critical()` only covers the whole
    /// machine. Fails when `p`'s worker is dead or crashed.
    fn proc_view(&self, p: ProcId) -> Result<ProcView>;

    /// Critical-path cost: component-wise max over all processors.
    fn critical(&self) -> Clock;

    /// Aggregate totals (whole-machine work/words/messages).
    fn stats(&self) -> MachineStats;

    /// Peak local-memory usage over all processors (the paper's M(n,P)).
    fn mem_peak_max(&self) -> u64;

    /// Sum of per-processor peaks (the "total memory O(n)" claim).
    fn mem_peak_total(&self) -> u64;

    /// Current resident words across all processors.
    fn mem_used_total(&self) -> u64;

    /// Scheduler support: drop every value resident on `p` (its ledger
    /// returns to zero words used; clocks and peaks are kept). Used to
    /// reclaim a shard whose job failed mid-run and left slots behind —
    /// never call it on a processor another computation still owns.
    fn purge(&mut self, p: ProcId);

    /// Record a trace event (no cost). Backends may ignore it.
    fn event(&mut self, _msg: &str) {}

    // ----- physical buffer recycling -----------------------------------
    //
    // Purely physical, never cost-visible: the ledger charges payload
    // lengths, not capacities, and these hooks move no model data.

    /// Take a scratch/payload buffer with capacity at least `cap`.
    /// Engines with a buffer pool hand out retired backing stores; the
    /// default just allocates. Buffers obtained here typically flow
    /// into `alloc`/`send` (becoming storage) or come back through
    /// [`MachineApi::give_buffer`].
    fn take_buffer(&mut self, cap: usize) -> Vec<u32> {
        Vec::with_capacity(cap)
    }

    /// Return a buffer to the engine's pool (default: drop it).
    fn give_buffer(&mut self, _buf: Vec<u32>) {}
}

//! Runtime — PJRT/XLA execution of the AOT-compiled leaf multiplier.
//!
//! The build path (`make artifacts`) runs Python once: JAX lowers the
//! L2 model (which inlines the L1 Pallas kernel under `interpret=True`)
//! to HLO *text* under `artifacts/`. This module loads those artifacts
//! with the `xla` crate (`PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`), so the
//! Rust hot path executes the compiled kernel with no Python anywhere.
//!
//! * [`artifacts`] — manifest parsing and artifact registry.
//! * [`client`] — the PJRT wrapper: one compiled executable per
//!   (entry, batch, K) shape.
//! * [`leaf`] — [`XlaLeaf`]: a [`LeafMultiplier`] that routes the
//!   simulator's single-processor leaf products through the executable
//!   (with base 2^16 ↔ 2^8 repacking and host-side Karatsuba splitting
//!   for operands wider than the largest compiled K).

pub mod artifacts;
pub mod client;
pub mod leaf;
pub mod xla_stub;

pub use artifacts::{ArtifactInfo, Manifest};
pub use client::XlaRuntime;
pub use leaf::XlaLeaf;

/// Default artifacts directory relative to the repo root.
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

//! Offline stub of the `xla` crate's API surface used by [`super::client`].
//!
//! The real PJRT bindings (`xla` crate + libpjrt) are not vendored in
//! this build environment. This stub keeps the runtime layer compiling
//! and failing *gracefully*: `PjRtClient::cpu()` returns an error, so
//! `XlaRuntime::new` fails, every XLA-dependent test skips, and the
//! `leaf=xla` CLI paths report a clear message instead of linking
//! errors. To enable the real backend, vendor the `xla` crate and swap
//! the `use crate::runtime::xla_stub as xla;` import in `client.rs` for
//! `use xla;`.

use crate::error::{bail, Result};
use std::path::Path;

const UNAVAILABLE: &str =
    "PJRT/XLA backend not available in this offline build (vendor the `xla` crate to enable it)";

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        bail!("{UNAVAILABLE}")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        bail!("{UNAVAILABLE}")
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        bail!("{UNAVAILABLE}")
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        bail!("{UNAVAILABLE}")
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        bail!("{UNAVAILABLE}")
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[i32]) -> Literal {
        Literal
    }

    pub fn reshape(self, _dims: &[i64]) -> Result<Literal> {
        Ok(self)
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        bail!("{UNAVAILABLE}")
    }

    pub fn to_vec<T>(self) -> Result<Vec<T>> {
        bail!("{UNAVAILABLE}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_gracefully() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}

//! PJRT client wrapper: compile HLO-text artifacts once, execute many.

use super::artifacts::{ArtifactInfo, Manifest};
use crate::error::{bail, Context, Result};
// The real `xla` crate is not vendored offline; the stub fails
// gracefully at client construction (see xla_stub docs for enabling
// the real backend).
use crate::runtime::xla_stub as xla;
use std::collections::HashMap;
use std::sync::Mutex;

/// A compiled executable together with its static shape.
struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    batch: usize,
    k: usize,
}

/// The XLA runtime: a CPU PJRT client plus lazily compiled executables
/// for every artifact in the manifest. `execute_*` calls are serialized
/// with an internal mutex (the PJRT CPU client is itself multi-threaded
/// internally; one in-flight execution keeps latency predictable for
/// the batcher on top).
pub struct XlaRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: Mutex<HashMap<String, Compiled>>,
}

// The xla crate wraps thread-safe C++ objects behind raw pointers that
// miss Send/Sync auto-derivation; executions are serialized by the
// mutex above.
unsafe impl Send for XlaRuntime {}
unsafe impl Sync for XlaRuntime {}

impl XlaRuntime {
    /// Create a CPU PJRT client over an artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(XlaRuntime {
            client,
            manifest,
            compiled: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile every artifact of `entry` up front (hides first-call
    /// compile latency from the serving path; used by the coordinator
    /// benches and the e2e example).
    pub fn precompile(&self, entry: &str) -> Result<usize> {
        let infos: Vec<ArtifactInfo> = self
            .manifest
            .artifacts
            .iter()
            .filter(|a| a.entry == entry)
            .cloned()
            .collect();
        for info in &infos {
            let zeros_a = vec![0i32; info.batch * info.k];
            let zeros_b = vec![0i32; info.batch * info.k];
            self.execute(info, &zeros_a, &zeros_b)?;
        }
        Ok(infos.len())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn key(info: &ArtifactInfo) -> String {
        format!("{}:{}:{}", info.entry, info.batch, info.k)
    }

    /// Execute the artifact on a padded batch.
    ///
    /// `a`, `b`: row-major `batch x k` base-256 digits (int32).
    /// Returns `batch x 2k` digits. Compiles the artifact on first use.
    pub fn execute(&self, info: &ArtifactInfo, a: &[i32], b: &[i32]) -> Result<Vec<i32>> {
        let (batch, k) = (info.batch, info.k);
        if a.len() != batch * k || b.len() != batch * k {
            bail!(
                "execute: operand size {} x {} != batch {batch} x k {k}",
                a.len(),
                b.len()
            );
        }
        let mut map = self.compiled.lock().unwrap();
        let key = Self::key(info);
        if !map.contains_key(&key) {
            let proto = xla::HloModuleProto::from_text_file(&info.file)
                .with_context(|| format!("parsing HLO text {:?}", info.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {:?}", info.file))?;
            map.insert(key.clone(), Compiled { exe, batch, k });
        }
        let c = map.get(&key).unwrap();
        let dims = [c.batch as i64, c.k as i64];
        let la = xla::Literal::vec1(a).reshape(&dims)?;
        let lb = xla::Literal::vec1(b).reshape(&dims)?;
        let result = c.exe.execute::<xla::Literal>(&[la, lb])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?; // lowered with return_tuple=True
        Ok(out.to_vec::<i32>()?)
    }

    /// Convenience: multiply one pair of K-digit base-256 vectors using
    /// the best-fitting artifact (padding K and batch as needed).
    pub fn mul_base256(&self, entry: &str, a: &[i32], b: &[i32]) -> Result<Vec<i32>> {
        let k = a.len();
        let info = self
            .manifest
            .select(entry, k, 1)
            .with_context(|| format!("no `{entry}` artifact fits k = {k}"))?
            .clone();
        let mut pa = vec![0i32; info.batch * info.k];
        let mut pb = vec![0i32; info.batch * info.k];
        pa[..k].copy_from_slice(a);
        pb[..k].copy_from_slice(b);
        let out = self.execute(&info, &pa, &pb)?;
        // Row 0, truncated to the true product width 2k. Digits beyond
        // 2k are zero because the operands were zero-padded.
        debug_assert!(out[2 * k..2 * info.k].iter().all(|&d| d == 0));
        Ok(out[..2 * k].to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::DEFAULT_ARTIFACTS_DIR;

    fn runtime() -> Option<XlaRuntime> {
        // Tests are skipped gracefully when `make artifacts` has not run.
        XlaRuntime::new(DEFAULT_ARTIFACTS_DIR).ok()
    }

    #[test]
    fn executes_school_artifact() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        // 0x01FF * 0x0100 = 0x01FF00 in base-256 digits (LSB first).
        let mut a = vec![0i32; 256];
        let mut b = vec![0i32; 256];
        a[0] = 0xFF;
        a[1] = 0x01;
        b[1] = 0x01;
        let c = rt.mul_base256("school", &a, &b).unwrap();
        assert_eq!(c[0], 0);
        assert_eq!(c[1], 0xFF);
        assert_eq!(c[2], 0x01);
        assert!(c[3..].iter().all(|&d| d == 0));
    }

    #[test]
    fn school_and_karatsuba_artifacts_agree() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let mut rng = crate::util::Rng::new(0xA1);
        let a: Vec<i32> = (0..256).map(|_| rng.below(256) as i32).collect();
        let b: Vec<i32> = (0..256).map(|_| rng.below(256) as i32).collect();
        let s = rt.mul_base256("school", &a, &b).unwrap();
        let k = rt.mul_base256("karatsuba", &a, &b).unwrap();
        assert_eq!(s, k);
    }

    #[test]
    fn artifact_matches_rust_reference() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        use crate::bignum::{mul, Base, Ops};
        let base8 = Base::new(8);
        let mut rng = crate::util::Rng::new(0xB2);
        let a: Vec<u32> = (0..256).map(|_| rng.below(256) as u32).collect();
        let b: Vec<u32> = (0..256).map(|_| rng.below(256) as u32).collect();
        let mut ops = Ops::default();
        let want = mul::mul_school(&a, &b, base8, &mut ops);
        let ai: Vec<i32> = a.iter().map(|&x| x as i32).collect();
        let bi: Vec<i32> = b.iter().map(|&x| x as i32).collect();
        let got = rt.mul_base256("school", &ai, &bi).unwrap();
        let got: Vec<u32> = got.iter().map(|&x| x as u32).collect();
        assert_eq!(got, want);
    }
}

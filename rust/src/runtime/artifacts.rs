//! Artifact registry: `artifacts/manifest.json` parsing.

use crate::util::json::Json;
use crate::error::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// One compiled artifact: `int32[batch, k] x int32[batch, k] ->
/// int32[batch, 2k]` over base-`2^base_log2` digits.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub file: PathBuf,
    pub entry: String,
    pub batch: usize,
    pub k: usize,
    pub base_log2: u32,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactInfo>,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts` first)"))?;
        let j = Json::parse(&src).map_err(|e| crate::error::anyhow!("{path:?}: {e}"))?;
        if j.get("format").and_then(Json::as_str) != Some("hlo-text") {
            bail!("{path:?}: unexpected manifest format");
        }
        let mut artifacts = Vec::new();
        for a in j
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest: missing artifacts array")?
        {
            artifacts.push(ArtifactInfo {
                file: dir.join(
                    a.get("file")
                        .and_then(Json::as_str)
                        .context("artifact: missing file")?,
                ),
                entry: a
                    .get("entry")
                    .and_then(Json::as_str)
                    .context("artifact: missing entry")?
                    .to_string(),
                batch: a
                    .get("batch")
                    .and_then(Json::as_u64)
                    .context("artifact: missing batch")? as usize,
                k: a.get("k").and_then(Json::as_u64).context("artifact: missing k")? as usize,
                base_log2: a
                    .get("base_log2")
                    .and_then(Json::as_u64)
                    .unwrap_or(8) as u32,
            });
        }
        if artifacts.is_empty() {
            bail!("{path:?}: no artifacts listed");
        }
        Ok(Manifest { dir, artifacts })
    }

    /// Pick the best artifact for an `entry` handling operands of `k`
    /// base-256 digits with batch `>= want_batch`: the smallest
    /// compiled `K >= k`, preferring an exact batch match.
    pub fn select(&self, entry: &str, k: usize, want_batch: usize) -> Option<&ArtifactInfo> {
        self.artifacts
            .iter()
            .filter(|a| a.entry == entry && a.k >= k)
            .min_by_key(|a| {
                (
                    a.k,
                    if a.batch >= want_batch {
                        a.batch - want_batch
                    } else {
                        usize::MAX - a.batch
                    },
                )
            })
    }

    /// Largest compiled K for an entry (host-side splitting threshold).
    pub fn max_k(&self, entry: &str) -> usize {
        self.artifacts
            .iter()
            .filter(|a| a.entry == entry)
            .map(|a| a.k)
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format":"hlo-text","dtype":"int32","artifacts":[
                {"file":"a.hlo.txt","entry":"school","batch":1,"k":256,"base_log2":8},
                {"file":"b.hlo.txt","entry":"school","batch":8,"k":256,"base_log2":8},
                {"file":"c.hlo.txt","entry":"school","batch":1,"k":1024,"base_log2":8}
            ]}"#,
        )
        .unwrap();
    }

    #[test]
    fn loads_and_selects() {
        let dir = std::env::temp_dir().join("copmul-manifest-test");
        write_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        // Exact fit with batch preference.
        let a = m.select("school", 200, 8).unwrap();
        assert_eq!((a.k, a.batch), (256, 8));
        // Larger-K fallback.
        let a = m.select("school", 512, 1).unwrap();
        assert_eq!(a.k, 1024);
        // Too large: none.
        assert!(m.select("school", 4096, 1).is_none());
        assert_eq!(m.max_k("school"), 1024);
        assert_eq!(m.max_k("karatsuba"), 0);
    }

    #[test]
    fn missing_dir_errors() {
        assert!(Manifest::load("/definitely/not/here").is_err());
    }
}

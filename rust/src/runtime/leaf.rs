//! [`XlaLeaf`]: routes simulator leaf products through the compiled
//! JAX+Pallas artifact.
//!
//! The machine simulator works in base 2^16 (one digit per word); the
//! artifacts work in base 2^8 (int32 lanes, exactness headroom for the
//! in-graph convolution). The leaf repacks 16→8 bits, pads to the
//! artifact's K, executes, and repacks the 2K-digit product back.
//! Operands wider than the largest compiled K are split with host-side
//! Karatsuba until the pieces fit (each piece then runs on the XLA
//! executable, so the compiled kernel still does all the O(K²) work).
//!
//! Digit-op accounting: the artifact performs the same digit
//! convolution the schoolbook leaf would; we charge `2·k8²` ops per
//! executed pair (k8 = base-256 width), identical to `mul_school` on
//! the repacked operands, so simulator cost theorems are unaffected by
//! the backend choice.

use super::client::XlaRuntime;
use crate::algorithms::leaf::LeafMultiplier;
use crate::bignum::convert::repack_base;
use crate::bignum::core::add_into_width;
use crate::bignum::{Base, Ops};
use std::sync::Arc;

/// Leaf multiplier backed by the PJRT runtime.
pub struct XlaLeaf {
    rt: Arc<XlaRuntime>,
    entry: String,
    /// Largest base-256 operand width the compiled artifacts accept.
    max_k: usize,
}

impl XlaLeaf {
    pub fn new(rt: Arc<XlaRuntime>, entry: &str) -> Self {
        let max_k = rt.manifest().max_k(entry);
        assert!(max_k > 0, "no `{entry}` artifacts available");
        XlaLeaf {
            rt,
            entry: entry.to_string(),
            max_k,
        }
    }
}

/// Multiply base-256 digit vectors of equal width: call `fit` directly
/// while they fit `max_k`, otherwise split with host Karatsuba (same
/// scheme as `bignum::mul::skim`) until the pieces fit. Shared by
/// [`XlaLeaf`] and the coordinator's batching leaf.
pub(crate) fn split_mul8(
    fit: &mut dyn FnMut(&[u32], &[u32], &mut Ops) -> Vec<u32>,
    max_k: usize,
    a: &[u32],
    b: &[u32],
    ops: &mut Ops,
) -> Vec<u32> {
    let k = a.len();
    if k <= max_k {
        return fit(a, b, ops);
    }
    let base8 = Base::new(8);
    let h = k / 2;
    let (a0, a1) = (&a[..h], &a[h..]);
    let (b0, b1) = (&b[..h], &b[h..]);
    let (fa, ad) = crate::bignum::mul::abs_diff(a0, a1, base8, ops);
    let (fb, bd) = crate::bignum::mul::abs_diff(b1, b0, base8, ops);
    let c0 = split_mul8(fit, max_k, a0, b0, ops);
    let c2 = split_mul8(fit, max_k, a1, b1, ops);
    let cp = split_mul8(fit, max_k, &ad, &bd, ops);
    let sign = fa * fb;
    let mut out = vec![0u32; 2 * k];
    out[..2 * h].copy_from_slice(&c0);
    add_into_width(&mut out, &c0, h, base8, ops);
    add_into_width(&mut out, &c2, h, base8, ops);
    add_into_width(&mut out, &c2, k, base8, ops);
    match sign {
        1 => add_into_width(&mut out, &cp, h, base8, ops),
        -1 => sub_into(&mut out, &cp, h, ops),
        _ => {}
    }
    out
}

/// Repack machine-base operands to padded base-256 vectors, run `mul8`
/// on them, repack the product back. Shared leaf plumbing.
pub(crate) fn repacked_mul(
    mul8: &mut dyn FnMut(&[u32], &[u32], &mut Ops) -> Vec<u32>,
    a: &[u32],
    b: &[u32],
    base: Base,
    ops: &mut Ops,
) -> Vec<u32> {
    let w = a.len();
    debug_assert_eq!(w, b.len());
    let base8 = Base::new(8);
    let k8_exact = (w * base.log2 as usize).div_ceil(8);
    let k8 = k8_exact.next_power_of_two().max(8);
    let mut a8 = repack_base(a, base, base8);
    let mut b8 = repack_base(b, base, base8);
    a8.resize(k8, 0);
    b8.resize(k8, 0);
    let c8 = mul8(&a8, &b8, ops);
    let mut c = repack_base(&c8, base8, base);
    c.resize(2 * w, 0);
    c
}

fn sub_into(dst: &mut [u32], src: &[u32], off: usize, ops: &mut Ops) {
    let mut borrow = 0i64;
    let mut i = 0;
    while i < src.len() || borrow != 0 {
        let d = off + i;
        let sub = if i < src.len() { src[i] as i64 } else { 0 };
        let mut t = dst[d] as i64 - sub - borrow;
        if t < 0 {
            t += 256;
            borrow = 1;
        } else {
            borrow = 0;
        }
        dst[d] = t as u32;
        ops.charge(1);
        i += 1;
    }
}

impl LeafMultiplier for XlaLeaf {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn mul(&self, a: &[u32], b: &[u32], base: Base, ops: &mut Ops) -> Vec<u32> {
        let mut fit = |x: &[u32], y: &[u32], ops: &mut Ops| -> Vec<u32> {
            let k = x.len();
            let ai: Vec<i32> = x.iter().map(|&d| d as i32).collect();
            let bi: Vec<i32> = y.iter().map(|&d| d as i32).collect();
            let out = self
                .rt
                .mul_base256(&self.entry, &ai, &bi)
                .expect("XLA leaf execution failed");
            ops.charge(2 * (k as u64) * (k as u64));
            out.iter().map(|&d| d as u32).collect()
        };
        let max_k = self.max_k;
        repacked_mul(
            &mut |a8, b8, ops| split_mul8(&mut fit, max_k, a8, b8, ops),
            a,
            b,
            base,
            ops,
        )
    }

    fn scratch_words(&self, w: usize) -> usize {
        // Host-side buffers for repack + artifact I/O (in machine words).
        4 * w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bignum::mul;
    use crate::runtime::DEFAULT_ARTIFACTS_DIR;
    use crate::util::Rng;

    fn leaf() -> Option<XlaLeaf> {
        let rt = XlaRuntime::new(DEFAULT_ARTIFACTS_DIR).ok()?;
        Some(XlaLeaf::new(Arc::new(rt), "school"))
    }

    #[test]
    fn xla_leaf_matches_rust_leaf() {
        let Some(l) = leaf() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let base = Base::new(16);
        let mut rng = Rng::new(0x1EAF);
        for &w in &[8usize, 32, 128] {
            let a = rng.digits(w, 16);
            let b = rng.digits(w, 16);
            let mut o1 = Ops::default();
            let mut o2 = Ops::default();
            let got = l.mul(&a, &b, base, &mut o1);
            let want = mul::mul_school(&a, &b, base, &mut o2);
            assert_eq!(got, want, "w={w}");
            assert!(o1.get() > 0);
        }
    }

    #[test]
    fn xla_leaf_splits_oversized_operands() {
        let Some(l) = leaf() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        // 4096 base-2^16 digits = 8192 base-256 digits > max K (1024):
        // requires host Karatsuba splitting (3 levels).
        let base = Base::new(16);
        let mut rng = Rng::new(0xB16);
        let w = 4096;
        let a = rng.digits(w, 16);
        let b = rng.digits(w, 16);
        let mut o1 = Ops::default();
        let mut o2 = Ops::default();
        let got = l.mul(&a, &b, base, &mut o1);
        let want = mul::skim(&a, &b, base, &mut o2);
        assert_eq!(got, want);
    }
}

//! Related-work baselines, implemented on the same simulator so E12 can
//! compare them with COPSIM/COPK under identical accounting.
//!
//! * [`allgather_schoolbook`] — the folklore distributed schoolbook:
//!   every processor all-gathers both operands (recursive doubling),
//!   computes its slice of the output convolution locally, then a
//!   sequential carry chain crosses the processors. Compute-balanced,
//!   but per-processor memory is Θ(n) (vs the paper's Θ(n/P)), the
//!   critical-path bandwidth is Θ(n) (vs Θ(n/√P)), and the carry chain
//!   costs Θ(P) latency.
//! * [`cesari_maeder`] — a master–slave parallel Karatsuba in the style
//!   of Cesari & Maeder (1996), the closest prior distributed-memory
//!   work the paper cites: a master holds the whole operands, performs
//!   the O(n) additions/differences *sequentially*, and farms the three
//!   subproducts out to slave sub-pools. Its computation time is
//!   Ω(n) regardless of P (the paper's criticism: "long integer
//!   additions and subtractions need to be computed by single
//!   processors"), and the master's memory is Θ(n).

use crate::bignum::mul::abs_diff;
use crate::bignum::{mul, Ops};
use crate::error::{ensure, Result};
use crate::sim::{DistInt, MachineApi, Seq};
use std::cmp::Ordering;

/// All-gather both operands with recursive doubling, multiply slices
/// locally, propagate carries sequentially. Inputs partitioned in `seq`
/// (width `w = n/P`); output partitioned in `seq` (width `2w`).
pub fn allgather_schoolbook<M: MachineApi>(
    m: &mut M,
    seq: &Seq,
    a: DistInt,
    b: DistInt,
) -> Result<DistInt> {
    let p = seq.len();
    let w = a.chunk_width;
    let n = a.total_width();
    ensure!(p.is_power_of_two(), "allgather baseline wants |P| = 2^k");

    if p == 1 {
        let pid = seq.at(0);
        let av = m.read(pid, a.chunks[0].1)?;
        let bv = m.read(pid, b.chunks[0].1)?;
        let c = m.local(pid, move |base, ops| mul::mul_school(&av, &bv, *base, ops))?;
        a.free(m);
        b.free(m);
        let slot = m.alloc(pid, c)?;
        return Ok(DistInt {
            chunk_width: 2 * w,
            chunks: vec![(pid, slot)],
        });
    }

    // --- All-gather of A and B (recursive doubling) --------------------
    // After round r every processor holds the 2^(r+1)·w digits of the
    // aligned block containing its own chunk; log2(P) rounds, with both
    // partners exchanging (two serialized messages per pair, since a
    // processor cannot send and receive simultaneously).
    let full_a = allgather(m, seq, &a)?;
    let full_b = allgather(m, seq, &b)?;
    a.free(m);
    b.free(m);

    // --- Local slice products -------------------------------------------
    // Processor j computes output digits [j·2w, (j+1)·2w) as raw
    // convolution sums, kept as double-precision values (charged as a
    // 4·2w-word scratch: one 64-bit accumulator = 4 base-2^16 words).
    let mut conv_slices: Vec<Vec<u64>> = Vec::with_capacity(p);
    let mut scratch_slots = Vec::with_capacity(p);
    for j in 0..p {
        let pid = seq.at(j);
        let av = m.read(pid, full_a[j])?;
        let bv = m.read(pid, full_b[j])?;
        let lo = j * 2 * w;
        let hi = lo + 2 * w;
        let mut slice = vec![0u64; 2 * w];
        let mut ops = Ops::default();
        for k in lo..hi.min(2 * n - 1) {
            let i_min = k.saturating_sub(n - 1);
            let i_max = k.min(n - 1);
            let mut acc = 0u64;
            for i in i_min..=i_max {
                acc += av[i] as u64 * bv[k - i] as u64;
                ops.charge(2);
            }
            slice[k - lo] = acc;
        }
        m.compute(pid, ops.get());
        conv_slices.push(slice);
        scratch_slots.push(m.alloc(pid, vec![0u32; 8 * w])?);
    }

    // --- Sequential carry chain ----------------------------------------
    // Processor j normalizes its slice given the carry from j-1 and
    // forwards its own carry: P-1 strictly sequential messages.
    let base = m.base();
    let mut out_chunks = Vec::with_capacity(p);
    let mut carry: u64 = 0;
    for j in 0..p {
        let pid = seq.at(j);
        if j > 0 {
            // Receive the (up to 64-bit) carry as 4 base-2^16 words.
            let prev = seq.at(j - 1);
            let payload = vec![
                (carry & 0xFFFF) as u32,
                ((carry >> 16) & 0xFFFF) as u32,
                ((carry >> 32) & 0xFFFF) as u32,
                ((carry >> 48) & 0xFFFF) as u32,
            ];
            let s = m.send(prev, pid, payload)?;
            m.free(pid, s);
        }
        let mut digits = Vec::with_capacity(2 * w);
        let mut ops = Ops::default();
        for v in &conv_slices[j] {
            let t = v + carry;
            digits.push((t & base.mask()) as u32);
            carry = t >> base.log2;
            ops.charge(1);
        }
        m.compute(pid, ops.get());
        out_chunks.push((pid, m.alloc(pid, digits)?));
    }
    ensure!(carry == 0, "allgather baseline: residual carry {carry}");

    // Release gathered operands and scratch.
    for j in 0..p {
        let pid = seq.at(j);
        m.free(pid, full_a[j]);
        m.free(pid, full_b[j]);
        m.free(pid, scratch_slots[j]);
    }

    Ok(DistInt {
        chunk_width: 2 * w,
        chunks: out_chunks,
    })
}

/// Recursive-doubling all-gather: returns, for each sequence rank, a
/// slot holding the FULL n-digit value.
fn allgather<M: MachineApi>(m: &mut M, seq: &Seq, x: &DistInt) -> Result<Vec<crate::sim::Slot>> {
    let p = seq.len();
    let w = x.chunk_width;
    // blocks[j] = digits currently held by rank j (starts as own chunk).
    let mut blocks: Vec<Vec<u32>> = (0..p)
        .map(|j| m.read(x.chunks[j].0, x.chunks[j].1))
        .collect::<Result<_>>()?;
    let mut owned: Vec<usize> = (0..p).collect(); // aligned block index
    let mut size = 1usize; // chunks per block
    while size < p {
        for j in 0..p {
            let partner = j ^ size;
            if partner > j {
                // Exchange blocks: two serialized messages (a processor
                // either sends or receives in a step).
                let (pj, pk) = (seq.at(j), seq.at(partner));
                let s1 = m.send(pj, pk, blocks[j].clone())?;
                let s2 = m.send(pk, pj, blocks[partner].clone())?;
                m.free(pk, s1);
                m.free(pj, s2);
            }
        }
        let mut next = Vec::with_capacity(p);
        for j in 0..p {
            let partner = j ^ size;
            let (lo, hi) = if owned[j] % (2 * size) == 0 {
                (j, partner)
            } else {
                (partner, j)
            };
            let mut merged = blocks[lo].clone();
            merged.extend_from_slice(&blocks[hi]);
            next.push(merged);
        }
        for j in 0..p {
            owned[j] -= owned[j] % (2 * size) / size * 0; // block start index bookkeeping
            owned[j] = owned[j] / (2 * size) * (2 * size);
        }
        blocks = next;
        size *= 2;
    }
    // Materialize the gathered value in each ledger.
    let mut slots = Vec::with_capacity(p);
    for j in 0..p {
        debug_assert_eq!(blocks[j].len(), w * p);
        slots.push(m.alloc(seq.at(j), blocks[j].clone())?);
    }
    Ok(slots)
}

/// Master–slave Karatsuba (Cesari–Maeder style). Inputs partitioned in
/// `seq`; the master (`seq[0]`) first gathers both operands entirely,
/// then recursion farms subproducts to slave sub-pools. Output ends up
/// resident on the master and is finally re-partitioned across `seq`
/// (width `2w`) for comparability.
pub fn cesari_maeder<M: MachineApi>(
    m: &mut M,
    seq: &Seq,
    a: DistInt,
    b: DistInt,
) -> Result<DistInt> {
    let w = a.chunk_width;
    let n = a.total_width();
    let master = Seq(vec![seq.at(0)]);
    // Gather to the master: Θ(n) words into one local memory.
    let a_m = a.repartition(m, &master, n)?;
    let b_m = b.repartition(m, &master, n)?;
    let pool: Vec<usize> = seq.ids().to_vec();
    let c_slot = ms_mul(m, &pool, a_m.chunks[0].1, b_m.chunks[0].1, n)?;
    a_m.free(m);
    b_m.free(m);
    let c = DistInt {
        chunk_width: 2 * n,
        chunks: vec![(seq.at(0), c_slot)],
    };
    c.repartition(m, seq, 2 * w)
}

/// Recursive master-slave step. `pool[0]` is the master holding both
/// `n`-digit operands; returns a slot on the master with the 2n-digit
/// product.
fn ms_mul<M: MachineApi>(
    m: &mut M,
    pool: &[usize],
    sa: crate::sim::Slot,
    sb: crate::sim::Slot,
    n: usize,
) -> Result<crate::sim::Slot> {
    let master = pool[0];
    // A pool too small to farm out three subproblems computes locally —
    // and small operands are not worth shipping either.
    if pool.len() < 4 || n <= 64 {
        let av = m.read(master, sa)?;
        let bv = m.read(master, sb)?;
        let scratch = m.alloc(master, vec![0u32; 4 * n])?;
        let c = m.local(master, move |base, ops| mul::skim(&av, &bv, *base, ops))?;
        m.free(master, scratch);
        return m.alloc(master, c);
    }

    let h = n / 2;
    let (av, bv) = (m.read(master, sa)?, m.read(master, sb)?);
    let (a0, a1) = (av[..h].to_vec(), av[h..].to_vec());
    let (b0, b1) = (bv[..h].to_vec(), bv[h..].to_vec());

    // THE bottleneck the paper calls out: the master computes the long
    // differences sequentially.
    let (a0c, a1c, b0c, b1c) = (a0.clone(), a1.clone(), b0.clone(), b1.clone());
    let ((fa, ad), (fb, bd)) = m.local(master, move |base, ops| {
        (
            abs_diff(&a0c, &a1c, *base, ops),
            abs_diff(&b1c, &b0c, *base, ops),
        )
    })?;
    let sign = fa * fb;

    // Farm out: three slaves pools led by slaves[i][0]; ship operands.
    let slaves = &pool[1..];
    let third = slaves.len() / 3;
    let (p0, rest) = slaves.split_at(third);
    let (p1, p2) = rest.split_at(third);
    let l0 = p0[0];
    let l1 = p1[0];
    let l2 = p2[0];
    let sa0 = m.send(master, l0, a0)?;
    let sb0 = m.send(master, l0, b0)?;
    let sad = m.send(master, l1, ad)?;
    let sbd = m.send(master, l1, bd)?;
    let sa1 = m.send(master, l2, a1)?;
    let sb1 = m.send(master, l2, b1)?;

    // Recurse (slave pools work in parallel — disjoint clocks).
    let c0s = ms_mul(m, p0, sa0, sb0, h)?;
    let cps = ms_mul(m, p1, sad, sbd, h)?;
    let c2s = ms_mul(m, p2, sa1, sb1, h)?;
    for (pid, s) in [(l0, sa0), (l0, sb0), (l1, sad), (l1, sbd), (l2, sa1), (l2, sb1)] {
        m.free(pid, s);
    }

    // Results return to the master: 3 x n digits.
    let rc0 = m.send_move(l0, master, c0s)?;
    let rcp = m.send_move(l1, master, cps)?;
    let rc2 = m.send_move(l2, master, c2s)?;

    // Master combines sequentially: C = C0 + s^h(C0+C2±C') + s^n C2.
    let (c0, cp, c2) = (
        m.read(master, rc0)?,
        m.read(master, rcp)?,
        m.read(master, rc2)?,
    );
    let c = m.local(master, move |base, ops| {
        let mut out = vec![0u32; 2 * n];
        out[..n].copy_from_slice(&c0);
        crate::bignum::core::add_into_width(&mut out, &c0, h, *base, ops);
        crate::bignum::core::add_into_width(&mut out, &c2, h, *base, ops);
        crate::bignum::core::add_into_width(&mut out, &c2, n, *base, ops);
        match sign.cmp(&0) {
            Ordering::Greater => {
                crate::bignum::core::add_into_width(&mut out, &cp, h, *base, ops)
            }
            Ordering::Less => sub_into(&mut out, &cp, h, *base, ops),
            Ordering::Equal => {}
        }
        out
    })?;
    m.free(master, rc0);
    m.free(master, rcp);
    m.free(master, rc2);
    m.alloc(master, c)
}

/// In-place borrow-propagating subtraction at an offset (master-side
/// combine helper; the value stays non-negative by Karatsuba's algebra).
fn sub_into(dst: &mut [u32], src: &[u32], off: usize, base: crate::bignum::Base, ops: &mut Ops) {
    let mut borrow = 0i64;
    let s = base.s() as i64;
    let mut i = 0;
    while i < src.len() || borrow != 0 {
        let d = off + i;
        let sub = if i < src.len() { src[i] as i64 } else { 0 };
        let mut t = dst[d] as i64 - sub - borrow;
        if t < 0 {
            t += s;
            borrow = 1;
        } else {
            borrow = 0;
        }
        dst[d] = t as u32;
        ops.charge(1);
        i += 1;
    }
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::bignum::{mul, Base, Ops};
    use crate::sim::Machine;
    use crate::util::Rng;

    fn setup(p: usize, n: usize, seed: u64) -> (Machine, Seq, Vec<u32>, Vec<u32>) {
        let mut rng = Rng::new(seed);
        let m = Machine::unbounded(p, Base::new(16));
        let seq = Seq::range(p);
        (m, seq, rng.digits(n, 16), rng.digits(n, 16))
    }

    #[test]
    fn allgather_correct() {
        for &(p, n) in &[(1usize, 32usize), (4, 64), (8, 256), (16, 512)] {
            let (mut m, seq, a, b) = setup(p, n, 0xA6 + p as u64);
            let da = DistInt::scatter(&mut m, &seq, &a, n / p).unwrap();
            let db = DistInt::scatter(&mut m, &seq, &b, n / p).unwrap();
            let c = allgather_schoolbook(&mut m, &seq, da, db).unwrap();
            let mut ops = Ops::default();
            let want = mul::mul_school(&a, &b, Base::new(16), &mut ops);
            assert_eq!(c.gather(&m).unwrap(), want, "p={p} n={n}");
        }
    }

    #[test]
    fn cesari_maeder_correct() {
        for &(p, n) in &[(4usize, 128usize), (16, 512), (8, 512)] {
            let (mut m, seq, a, b) = setup(p, n, 0xCE + p as u64);
            let da = DistInt::scatter(&mut m, &seq, &a, n / p).unwrap();
            let db = DistInt::scatter(&mut m, &seq, &b, n / p).unwrap();
            let c = cesari_maeder(&mut m, &seq, da, db).unwrap();
            let mut ops = Ops::default();
            let want = mul::mul_school(&a, &b, Base::new(16), &mut ops);
            assert_eq!(c.gather(&m).unwrap(), want, "p={p} n={n}");
        }
    }

    #[test]
    fn allgather_memory_is_theta_n_per_proc() {
        // The headline weakness: every processor stores the full inputs.
        let (mut m, seq, a, b) = setup(16, 1024, 0xA9);
        let da = DistInt::scatter(&mut m, &seq, &a, 64).unwrap();
        let db = DistInt::scatter(&mut m, &seq, &b, 64).unwrap();
        allgather_schoolbook(&mut m, &seq, da, db).unwrap();
        assert!(
            m.mem_peak_max() >= 2 * 1024,
            "expected >= 2n peak, got {}",
            m.mem_peak_max()
        );
    }

    #[test]
    fn cesari_maeder_master_is_bottleneck() {
        // Master computation time stays Ω(n) even as P grows: compare
        // critical-path ops at P=4 vs P=16; the improvement must be far
        // from the 4x of a strongly-scaling algorithm.
        let n = 2048;
        let mut crit = Vec::new();
        for &p in &[4usize, 16, 64] {
            let (mut m, seq, a, b) = setup(p, n, 7);
            let da = DistInt::scatter(&mut m, &seq, &a, n / p).unwrap();
            let db = DistInt::scatter(&mut m, &seq, &b, n / p).unwrap();
            cesari_maeder(&mut m, &seq, da, db).unwrap();
            crit.push(m.critical().ops);
        }
        // Sub-linear scaling: 16x the processors (P=4 -> P=64) must buy
        // clearly less than 8x the speedup (a strongly scaling algorithm
        // would buy ~16x).
        assert!(
            crit[2] * 16 > crit[0] * 2,
            "master-slave scaled too well: {crit:?}"
        );
    }

    #[test]
    fn copsim_beats_allgather_bandwidth_at_scale() {
        let (p, n) = (64usize, 4096usize);
        let (mut m1, seq1, a, b) = setup(p, n, 0xBB);
        let da = DistInt::scatter(&mut m1, &seq1, &a, n / p).unwrap();
        let db = DistInt::scatter(&mut m1, &seq1, &b, n / p).unwrap();
        allgather_schoolbook(&mut m1, &seq1, da, db).unwrap();

        let mut m2 = Machine::unbounded(p, Base::new(16));
        let da = DistInt::scatter(&mut m2, &seq1, &a, n / p).unwrap();
        let db = DistInt::scatter(&mut m2, &seq1, &b, n / p).unwrap();
        crate::algorithms::copsim_mi(
            &mut m2,
            &seq1,
            da,
            db,
            &crate::algorithms::leaf_ref(crate::algorithms::SlimLeaf),
        )
        .unwrap();
        assert!(
            m2.critical().words < m1.critical().words,
            "COPSIM BW {} !< allgather BW {}",
            m2.critical().words,
            m1.critical().words
        );
    }
}

//! Coordinator — the serving layer: a multi-threaded job router that
//! executes multiplication requests over simulated machines, with leaf
//! products optionally dispatched (and dynamically batched) onto the
//! XLA runtime.
//!
//! Layering (paper terms): the *coordination contribution* of the paper
//! is COPSIM/COPK themselves; this module is the production harness a
//! downstream user drives them with — request intake, per-job machine
//! construction, scheme selection (§7 hybrid), leaf batching, and
//! metrics.
//!
//! * [`job`] — request/response types and input padding rules.
//! * [`router`] — worker pool (std::thread; tokio is not available in
//!   this offline build) with a shared work queue; one dedicated
//!   machine per job.
//! * [`scheduler`] — sharded multi-job scheduling: one shared machine
//!   (either engine) carved into per-job shards sized by the paper's
//!   memory requirements, with admission control, work-stealing of
//!   freed shards, and self-healing capacity: quarantined processors
//!   are probed back into service by verified canary multiplies
//!   (probation), and dead socket worker groups are respawned.
//! * [`batcher`] — dynamic batcher: concurrent leaf products from
//!   different workers are coalesced into one batched artifact
//!   execution (padding the batch dimension), amortizing PJRT dispatch.
//! * [`daemon`] — always-on serving: a persistent scheduler under
//!   seeded open-loop arrivals (Poisson/bursty) with per-job deadlines
//!   and SLO-aware early shedding — scaled by the live processor count
//!   when the machine is degraded, with the recovery story reported
//!   first-class; the layer behind `copmul daemon`.

pub mod batcher;
pub mod daemon;
pub mod job;
pub mod router;
pub mod scheduler;

pub use batcher::{BatchExecutor, BatchingXlaLeaf, SchoolBatchRuntime};
pub use daemon::{
    run_open_loop, ArrivalGen, ArrivalKind, Daemon, DaemonConfig, DaemonStats, OpenLoop, Request,
    ServingReport, ShedReason, Submission, Workload,
};
pub use job::{JobResult, JobSpec};
pub use router::{execute_on, Coordinator, CoordinatorConfig, CoordinatorStats};
pub use scheduler::{plan_shard, RejectKind, Scheduler, SchedulerConfig, SchedulerStats};

//! Worker pool and request routing.

use super::job::{JobResult, JobSpec};
use crate::algorithms::leaf::{LeafMultiplier, LeafRef};
use crate::algorithms::{hybrid, mul_with_mode, resolve_mode, Algorithm, ExecMode};
use crate::bignum::core::normalized_len;
use crate::bignum::Base;
use crate::config::EngineKind;
use crate::error::{Context, Result};
use crate::sim::{DistInt, Machine, MachineApi, Seq, SocketMachine, ThreadedMachine};
use crate::theory::TimeModel;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Coordinator configuration.
#[derive(Clone)]
pub struct CoordinatorConfig {
    /// Worker threads (each runs one simulated machine at a time).
    pub workers: usize,
    /// Machine digit base.
    pub base: Base,
    /// Time model used by the hybrid dispatcher.
    pub time_model: TimeModel,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: std::thread::available_parallelism()
                .map(|n| n.get().min(8))
                .unwrap_or(4),
            base: Base::default(),
            time_model: TimeModel::default(),
        }
    }
}

/// Aggregate serving statistics.
#[derive(Debug, Default)]
pub struct CoordinatorStats {
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    pub total_wall_us: AtomicU64,
}

impl CoordinatorStats {
    /// Completed jobs per summed per-job wall second. Per-job walls
    /// overlap under concurrency (and include queue wait), so this is
    /// an inverse mean latency, NOT an elapsed-time throughput —
    /// measure real throughput with the caller's own clock.
    pub fn throughput_jobs_per_s(&self) -> f64 {
        let jobs = self.jobs_completed.load(Ordering::Relaxed) as f64;
        let us = self.total_wall_us.load(Ordering::Relaxed) as f64;
        if us == 0.0 {
            0.0
        } else {
            jobs / (us / 1e6)
        }
    }
}

type Reply = Sender<Result<JobResult>>;

/// A queued job: spec, reply channel, and the submission instant (so
/// `JobResult::wall` spans submission to completion, matching the
/// scheduler path's semantics).
type Queued = (JobSpec, Reply, Instant);

/// The coordinator: accepts [`JobSpec`]s, runs them on a worker pool,
/// returns [`JobResult`]s through per-job channels.
pub struct Coordinator {
    tx: Option<Sender<Queued>>,
    workers: Vec<JoinHandle<()>>,
    pub stats: Arc<CoordinatorStats>,
}

impl Coordinator {
    /// Start the worker pool. `leaf` is shared by all workers (the
    /// batching XLA leaf coalesces across workers — that is the point).
    pub fn start(cfg: CoordinatorConfig, leaf: Arc<dyn LeafMultiplier + Send + Sync>) -> Self {
        let (tx, rx) = channel::<Queued>();
        let rx = Arc::new(Mutex::new(rx));
        let stats = Arc::new(CoordinatorStats::default());
        let mut workers = Vec::with_capacity(cfg.workers);
        for _ in 0..cfg.workers.max(1) {
            let rx = Arc::clone(&rx);
            let leaf = Arc::clone(&leaf);
            let stats = Arc::clone(&stats);
            let cfg = cfg.clone();
            workers.push(std::thread::spawn(move || loop {
                let msg = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                let Ok((spec, reply, submitted_at)) = msg else { break };
                let mut res = run_job(&cfg, &spec, &leaf);
                match &mut res {
                    Ok(r) => {
                        r.wall = submitted_at.elapsed();
                        stats.jobs_completed.fetch_add(1, Ordering::Relaxed);
                        let us = r.wall.as_micros() as u64;
                        stats.total_wall_us.fetch_add(us, Ordering::Relaxed);
                    }
                    Err(_) => {
                        stats.jobs_failed.fetch_add(1, Ordering::Relaxed);
                    }
                }
                let _ = reply.send(res);
            }));
        }
        Coordinator {
            tx: Some(tx),
            workers,
            stats,
        }
    }

    /// Submit a job; the result arrives on the returned channel.
    pub fn submit(&self, spec: JobSpec) -> Receiver<Result<JobResult>> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .as_ref()
            .expect("coordinator already shut down")
            .send((spec, reply_tx, Instant::now()))
            .expect("worker pool gone");
        reply_rx
    }

    /// Submit and wait.
    pub fn submit_blocking(&self, spec: JobSpec) -> Result<JobResult> {
        self.submit(spec).recv().context("coordinator dropped reply")?
    }

    /// Drain and join the pool.
    pub fn shutdown(mut self) {
        self.tx.take(); // close the queue
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.tx.take();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Run the multiplication itself on any execution engine: scatter the
/// padded operands over `seq` (any disjoint processor set — the
/// scheduler passes shard sub-ranges of a shared machine), dispatch the
/// scheme, gather, trim, and free the product.
///
/// Freeing matters on shared machines: a job must leave its shard's
/// ledgers empty so the next job starts from a clean slate.
pub fn execute_on<M: MachineApi>(
    machine: &mut M,
    time_model: &TimeModel,
    spec: &JobSpec,
    seq: &Seq,
    leaf: &LeafRef,
) -> Result<(Vec<u32>, Algorithm, ExecMode)> {
    let p = seq.len();
    let n = spec.padded_width_for(p);
    let w = n / p;

    let mut a = spec.a.clone();
    let mut b = spec.b.clone();
    a.resize(n, 0);
    b.resize(n, 0);
    let da = DistInt::scatter(machine, seq, &a, w)?;
    let db = DistInt::scatter(machine, seq, &b, w)?;

    // The mode is resolved HERE, at execution time, from data every
    // engine sees identically — (policy, n, p, mem_cap) — so the
    // three-way differential stays bit-for-bit across engines.
    let (c, algo, mode) = match spec.algo {
        Some(algo) => {
            let mode = resolve_mode(spec.exec_mode, algo, n as u64, p as u64, machine.mem_cap());
            (mul_with_mode(machine, seq, da, db, leaf, algo, mode)?, algo, mode)
        }
        None => hybrid::hybrid_mul_with_mode(machine, seq, da, db, leaf, time_model, spec.exec_mode)?,
    };

    let mut product = c.gather(machine)?;
    c.free(machine);
    let keep = normalized_len(&product).max(1);
    product.truncate(keep);
    Ok((product, algo, mode))
}

/// Execute one job on a fresh machine of the engine (and network
/// topology) the spec selects.
fn run_job(cfg: &CoordinatorConfig, spec: &JobSpec, leaf: &LeafRef) -> Result<JobResult> {
    let t0 = Instant::now();
    let mem_cap = spec.mem_cap.unwrap_or(u64::MAX / 2);
    let seq = Seq::range(spec.procs);
    let topo = spec.topology.build(spec.procs);
    match spec.engine {
        EngineKind::Sim => {
            let mut machine = Machine::with_topology(spec.procs, mem_cap, cfg.base, topo);
            let (product, algo, mode) = execute_on(&mut machine, &cfg.time_model, spec, &seq, leaf)?;
            Ok(JobResult {
                id: spec.id,
                product,
                algo,
                exec_mode: mode,
                engine: spec.engine,
                cost: machine.critical(),
                mem_peak: machine.mem_peak_max(),
                wall: t0.elapsed(),
                shard: None,
                attempts: 1,
                faults_survived: 0,
            })
        }
        EngineKind::Threads => {
            let mut machine = ThreadedMachine::with_topology(spec.procs, mem_cap, cfg.base, topo);
            let (product, algo, mode) = execute_on(&mut machine, &cfg.time_model, spec, &seq, leaf)?;
            let report = machine.finish()?;
            Ok(JobResult {
                id: spec.id,
                product,
                algo,
                exec_mode: mode,
                engine: spec.engine,
                cost: report.critical,
                mem_peak: report.mem_peak_max,
                wall: t0.elapsed(),
                shard: None,
                attempts: 1,
                faults_survived: 0,
            })
        }
        EngineKind::Sockets => {
            let mut machine = SocketMachine::with_topology(spec.procs, mem_cap, cfg.base, topo)?;
            let (product, algo, mode) = execute_on(&mut machine, &cfg.time_model, spec, &seq, leaf)?;
            let report = machine.finish()?;
            Ok(JobResult {
                id: spec.id,
                product,
                algo,
                exec_mode: mode,
                engine: spec.engine,
                cost: report.critical,
                mem_peak: report.mem_peak_max,
                wall: t0.elapsed(),
                shard: None,
                attempts: 1,
                faults_survived: 0,
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::leaf::SkimLeaf;
    use crate::bignum::convert::{parse_hex, to_hex};
    use crate::bignum::{mul, Ops};
    use crate::util::Rng;

    fn start_default() -> Coordinator {
        Coordinator::start(
            CoordinatorConfig {
                workers: 4,
                ..Default::default()
            },
            Arc::new(SkimLeaf),
        )
    }

    #[test]
    fn serves_single_job() {
        let coord = start_default();
        let base = Base::default();
        let a = parse_hex("deadbeef12345678", base).unwrap();
        let b = parse_hex("cafebabe87654321", base).unwrap();
        let res = coord
            .submit_blocking(JobSpec::new(1, a.clone(), b.clone()))
            .unwrap();
        let mut ops = Ops::default();
        let mut a4 = a.clone();
        let mut b4 = b.clone();
        a4.resize(4, 0);
        b4.resize(4, 0);
        let want = mul::mul_school(&a4, &b4, base, &mut ops);
        let want_hex = to_hex(&want, base);
        assert_eq!(to_hex(&res.product, base), want_hex);
        coord.shutdown();
    }

    #[test]
    fn serves_many_jobs_concurrently() {
        let coord = start_default();
        let base = Base::default();
        let mut rng = Rng::new(0x10B);
        let mut pending = Vec::new();
        let mut want = Vec::new();
        for id in 0..24u64 {
            let n = 1usize << rng.range(3, 7);
            let a = rng.digits(n, 16);
            let b = rng.digits(n, 16);
            let mut ops = Ops::default();
            let prod = mul::mul_school(&a, &b, base, &mut ops);
            want.push(to_hex(&prod, base));
            let mut spec = JobSpec::new(id, a, b);
            spec.procs = [4usize, 12, 16][id as usize % 3];
            pending.push(coord.submit(spec));
        }
        for (i, rx) in pending.into_iter().enumerate() {
            let res = rx.recv().unwrap().unwrap();
            assert_eq!(to_hex(&res.product, base), want[i], "job {i}");
        }
        assert_eq!(coord.stats.jobs_completed.load(Ordering::Relaxed), 24);
        coord.shutdown();
    }

    #[test]
    fn respects_forced_algorithm() {
        let coord = start_default();
        let mut spec = JobSpec::new(9, vec![7; 64], vec![9; 64]);
        spec.procs = 16;
        spec.algo = Some(Algorithm::Copsim);
        let res = coord.submit_blocking(spec).unwrap();
        assert_eq!(res.algo, Algorithm::Copsim);
        let mut spec = JobSpec::new(10, vec![7; 64], vec![9; 64]);
        spec.procs = 12;
        spec.algo = Some(Algorithm::Copk);
        let res = coord.submit_blocking(spec).unwrap();
        assert_eq!(res.algo, Algorithm::Copk);
        coord.shutdown();
    }

    #[test]
    fn threaded_engine_matches_sim_engine() {
        let coord = start_default();
        let base = Base::default();
        let mut rng = Rng::new(0x7E7);
        let a = rng.digits(128, 16);
        let b = rng.digits(128, 16);
        let mut sim_spec = JobSpec::new(1, a.clone(), b.clone());
        sim_spec.procs = 16;
        sim_spec.algo = Some(Algorithm::Copsim);
        let sim = coord.submit_blocking(sim_spec).unwrap();
        let mut thr_spec = JobSpec::new(2, a, b);
        thr_spec.procs = 16;
        thr_spec.algo = Some(Algorithm::Copsim);
        thr_spec.engine = EngineKind::Threads;
        let thr = coord.submit_blocking(thr_spec).unwrap();
        assert_eq!(thr.engine, EngineKind::Threads);
        assert_eq!(sim.product, thr.product, "engines disagree on product");
        assert_eq!(sim.cost, thr.cost, "engines disagree on cost triple");
        assert_eq!(sim.mem_peak, thr.mem_peak);
        coord.shutdown();
    }

    #[test]
    fn reports_simulated_cost_and_memory() {
        let coord = start_default();
        let mut spec = JobSpec::new(2, vec![1; 256], vec![2; 256]);
        spec.procs = 16;
        let res = coord.submit_blocking(spec).unwrap();
        assert!(res.cost.ops > 0);
        assert!(res.cost.words > 0);
        assert!(res.mem_peak > 0);
        coord.shutdown();
    }
}

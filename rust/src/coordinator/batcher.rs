//! Dynamic batching of leaf products onto the XLA runtime.
//!
//! Multiple coordinator workers reach their recursion leaves
//! concurrently; instead of dispatching one PJRT execution per product,
//! requests that fit the batched artifact (e.g. `B = 8, K = 256`) are
//! coalesced: the request that fills the batch — or the first whose
//! linger timer expires — becomes the *flusher*, executes one batched
//! artifact call (padding missing rows with zeros), and distributes the
//! output rows. This is the vLLM-style continuous-batching idea applied
//! to the leaf kernel.

use crate::algorithms::leaf::LeafMultiplier;
use crate::bignum::{Base, Ops};
use crate::error::Result;
use crate::runtime::artifacts::ArtifactInfo;
use crate::runtime::leaf::{repacked_mul, split_mul8};
use crate::runtime::XlaRuntime;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Backend that executes batched leaf artifacts: the PJRT runtime in
/// production, a mock in tests (the batcher's queueing/flush/routing
/// logic is runtime-agnostic and is unit-tested against a pure-Rust
/// mock so the tests run without compiled artifacts).
pub trait BatchExecutor: Send + Sync {
    /// Artifacts available for `entry` (any order, any batch size).
    fn artifacts(&self, entry: &str) -> Vec<ArtifactInfo>;

    /// Execute `info` on row-major `batch x k` base-256 operands,
    /// returning `batch x 2k` product digits.
    fn execute_batch(&self, info: &ArtifactInfo, a: &[i32], b: &[i32]) -> Result<Vec<i32>>;
}

impl BatchExecutor for XlaRuntime {
    fn artifacts(&self, entry: &str) -> Vec<ArtifactInfo> {
        self.manifest()
            .artifacts
            .iter()
            .filter(|a| a.entry == entry)
            .cloned()
            .collect()
    }

    fn execute_batch(&self, info: &ArtifactInfo, a: &[i32], b: &[i32]) -> Result<Vec<i32>> {
        self.execute(info, a, b)
    }
}

/// Pure-Rust [`BatchExecutor`]: one batched artifact shape whose rows
/// are multiplied with the schoolbook reference in base 256 (the
/// artifact digit contract). This is the daemon's fallback executor for
/// small-job coalescing when no PJRT runtime is loaded — the batching
/// *policy* (queueing, linger, flush, row routing) is identical to the
/// XLA path, only the kernel is host arithmetic. Infallible by
/// construction: `execute_batch` never errors.
pub struct SchoolBatchRuntime {
    batch: usize,
    k: usize,
    /// Batched executions performed (observability for tests/soaks).
    pub executions: AtomicU64,
}

impl SchoolBatchRuntime {
    /// An executor with one `batch × k` bucket (base-256 digits).
    pub fn new(batch: usize, k: usize) -> Self {
        assert!(batch >= 1 && k >= 1, "degenerate batch shape");
        SchoolBatchRuntime {
            batch,
            k,
            executions: AtomicU64::new(0),
        }
    }
}

impl BatchExecutor for SchoolBatchRuntime {
    fn artifacts(&self, entry: &str) -> Vec<ArtifactInfo> {
        vec![ArtifactInfo {
            file: std::path::PathBuf::from("host://school"),
            entry: entry.to_string(),
            batch: self.batch,
            k: self.k,
            base_log2: 8,
        }]
    }

    fn execute_batch(&self, info: &ArtifactInfo, a: &[i32], b: &[i32]) -> Result<Vec<i32>> {
        debug_assert_eq!(a.len(), info.batch * info.k);
        debug_assert_eq!(b.len(), info.batch * info.k);
        self.executions.fetch_add(1, Ordering::Relaxed);
        let base = Base::new(8);
        let mut out = vec![0i32; info.batch * 2 * info.k];
        for row in 0..info.batch {
            let ra: Vec<u32> = a[row * info.k..(row + 1) * info.k]
                .iter()
                .map(|&d| d as u32)
                .collect();
            let rb: Vec<u32> = b[row * info.k..(row + 1) * info.k]
                .iter()
                .map(|&d| d as u32)
                .collect();
            if ra.iter().all(|&d| d == 0) && rb.iter().all(|&d| d == 0) {
                continue; // padding row of a partial batch
            }
            let mut ops = Ops::default();
            let prod = crate::bignum::mul::mul_school(&ra, &rb, base, &mut ops);
            for (i, &d) in prod.iter().take(2 * info.k).enumerate() {
                out[row * 2 * info.k + i] = d as i32;
            }
        }
        Ok(out)
    }
}

/// Result slot a waiting request parks on.
struct Cell {
    out: Mutex<Option<Vec<u32>>>,
    cv: Condvar,
}

struct Pending {
    a: Vec<u32>, // exactly K base-256 digits
    b: Vec<u32>,
    cell: Arc<Cell>,
}

/// Batching statistics (observability for the e2e example / benches).
#[derive(Debug, Default)]
pub struct BatcherStats {
    pub requests: AtomicU64,
    pub executions: AtomicU64,
    pub batched_rows: AtomicU64,
}

impl BatcherStats {
    /// Mean rows per artifact execution (1.0 = no batching win).
    pub fn mean_batch(&self) -> f64 {
        let ex = self.executions.load(Ordering::Relaxed);
        if ex == 0 {
            return 0.0;
        }
        self.batched_rows.load(Ordering::Relaxed) as f64 / ex as f64
    }
}

/// One batch bucket: a batched artifact shape plus its pending queue.
/// Requests are routed to the smallest-K bucket they fit, so narrow
/// leaves don't pay for wide kernels.
struct Bucket {
    info: ArtifactInfo,
    queue: Mutex<VecDeque<Pending>>,
}

/// A [`LeafMultiplier`] that coalesces concurrent leaf products into
/// batched artifact executions.
pub struct BatchingXlaLeaf {
    rt: Arc<dyn BatchExecutor>,
    buckets: Vec<Bucket>,
    max_k: usize,
    /// How long a lone request lingers for company before flushing.
    pub linger: Duration,
    pub stats: BatcherStats,
}

impl BatchingXlaLeaf {
    /// Batch over the PJRT runtime (the production path).
    pub fn new(rt: Arc<XlaRuntime>, entry: &str) -> Self {
        Self::with_executor(rt, entry)
    }

    /// Build one bucket per batched (`batch > 1`) artifact of `entry`,
    /// sorted by K ascending.
    pub fn with_executor(rt: Arc<dyn BatchExecutor>, entry: &str) -> Self {
        let all = rt.artifacts(entry);
        let mut infos: Vec<ArtifactInfo> = all.iter().filter(|a| a.batch > 1).cloned().collect();
        if infos.is_empty() {
            // Fall back to whatever exists (degenerates to batch = 1).
            infos = all;
        }
        assert!(!infos.is_empty(), "no `{entry}` artifacts for batching");
        infos.sort_by_key(|a| a.k);
        let max_k = infos.last().unwrap().k;
        BatchingXlaLeaf {
            rt,
            buckets: infos
                .into_iter()
                .map(|info| Bucket {
                    info,
                    queue: Mutex::new(VecDeque::new()),
                })
                .collect(),
            max_k,
            linger: Duration::from_micros(60),
            stats: BatcherStats::default(),
        }
    }

    /// Enqueue one pair into its K bucket and wait for the product row.
    fn mul_fit(&self, a: &[u32], b: &[u32]) -> Vec<u32> {
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        let bucket = self
            .buckets
            .iter()
            .find(|bk| bk.info.k >= a.len())
            .expect("operand exceeds every bucket (split_mul8 should have split it)");
        let k = bucket.info.k;
        let mut pa = a.to_vec();
        let mut pb = b.to_vec();
        pa.resize(k, 0);
        pb.resize(k, 0);
        let cell = Arc::new(Cell {
            out: Mutex::new(None),
            cv: Condvar::new(),
        });
        {
            let mut q = bucket.queue.lock().unwrap();
            q.push_back(Pending {
                a: pa,
                b: pb,
                cell: Arc::clone(&cell),
            });
            if q.len() >= bucket.info.batch {
                let batch: Vec<Pending> = q.drain(..bucket.info.batch).collect();
                drop(q);
                self.flush(bucket, batch);
            }
        }
        let deadline = Instant::now() + self.linger;
        loop {
            // Parked until filled, with linger timeout for the flusher role.
            {
                let guard = cell.out.lock().unwrap();
                if guard.is_some() {
                    return guard.clone().unwrap();
                }
                let wait = deadline.saturating_duration_since(Instant::now());
                if !wait.is_zero() {
                    let (guard, _timeout) = cell.cv.wait_timeout(guard, wait).unwrap();
                    if guard.is_some() {
                        return guard.clone().unwrap();
                    }
                    continue;
                }
            }
            // Linger expired: flush whatever is queued (including us,
            // unless someone else already took it).
            let batch: Vec<Pending> = {
                let mut q = bucket.queue.lock().unwrap();
                let take = q.len().min(bucket.info.batch);
                q.drain(..take).collect()
            };
            if !batch.is_empty() {
                self.flush(bucket, batch);
            }
            // Either we were in that batch (cell now filled) or another
            // flusher has us; loop re-checks the cell.
            let guard = cell.out.lock().unwrap();
            if let Some(v) = guard.clone() {
                return v;
            }
            let (guard, _timeout) = cell
                .cv
                .wait_timeout(guard, Duration::from_millis(5))
                .unwrap();
            if let Some(v) = guard.clone() {
                return v;
            }
        }
    }

    /// Execute one batched artifact call and distribute the rows.
    fn flush(&self, bucket: &Bucket, batch: Vec<Pending>) {
        let (bsz, k) = (bucket.info.batch, bucket.info.k);
        let mut fa = vec![0i32; bsz * k];
        let mut fb = vec![0i32; bsz * k];
        for (row, p) in batch.iter().enumerate() {
            for (i, &d) in p.a.iter().enumerate() {
                fa[row * k + i] = d as i32;
            }
            for (i, &d) in p.b.iter().enumerate() {
                fb[row * k + i] = d as i32;
            }
        }
        let out = self
            .rt
            .execute_batch(&bucket.info, &fa, &fb)
            .expect("batched XLA execution failed");
        self.stats.executions.fetch_add(1, Ordering::Relaxed);
        self.stats
            .batched_rows
            .fetch_add(batch.len() as u64, Ordering::Relaxed);
        for (row, p) in batch.into_iter().enumerate() {
            let digits: Vec<u32> = out[row * 2 * k..(row + 1) * 2 * k]
                .iter()
                .map(|&d| d as u32)
                .collect();
            *p.cell.out.lock().unwrap() = Some(digits);
            p.cell.cv.notify_all();
        }
    }

    /// Precompile every bucket artifact (hide compile from serving).
    pub fn warmup(&self) -> Result<()> {
        for b in &self.buckets {
            let za = vec![0i32; b.info.batch * b.info.k];
            let zb = vec![0i32; b.info.batch * b.info.k];
            self.rt.execute_batch(&b.info, &za, &zb)?;
        }
        Ok(())
    }
}

impl LeafMultiplier for BatchingXlaLeaf {
    fn name(&self) -> &'static str {
        "xla-batched"
    }

    fn mul(&self, a: &[u32], b: &[u32], base: Base, ops: &mut Ops) -> Vec<u32> {
        let mut fit = |x: &[u32], y: &[u32], ops: &mut Ops| -> Vec<u32> {
            let k = x.len();
            ops.charge(2 * (k as u64) * (k as u64));
            let mut row = self.mul_fit(x, y);
            row.truncate(2 * k);
            row
        };
        let max_k = self.max_k;
        repacked_mul(
            &mut |a8, b8, ops| split_mul8(&mut fit, max_k, a8, b8, ops),
            a,
            b,
            base,
            ops,
        )
    }

    fn scratch_words(&self, w: usize) -> usize {
        4 * w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bignum::mul;
    use crate::runtime::DEFAULT_ARTIFACTS_DIR;
    use crate::util::Rng;
    use std::path::PathBuf;

    // ----- mock runtime: the batcher's queueing/flush/routing logic
    // unit-tested without compiled artifacts ---------------------------

    /// Pure-Rust stand-in for the PJRT runtime: one batched artifact of
    /// configurable shape whose rows are multiplied with the schoolbook
    /// reference in base 256 (the artifact contract).
    struct MockRuntime {
        batch: usize,
        k: usize,
        executions: AtomicU64,
        /// Rows whose operands were entirely zero — the padding rows of
        /// partial batches (real requests force a nonzero digit).
        zero_rows: AtomicU64,
    }

    impl MockRuntime {
        fn new(batch: usize, k: usize) -> Arc<Self> {
            Arc::new(MockRuntime {
                batch,
                k,
                executions: AtomicU64::new(0),
                zero_rows: AtomicU64::new(0),
            })
        }
    }

    impl BatchExecutor for MockRuntime {
        fn artifacts(&self, entry: &str) -> Vec<ArtifactInfo> {
            vec![ArtifactInfo {
                file: PathBuf::from("mock://school"),
                entry: entry.to_string(),
                batch: self.batch,
                k: self.k,
                base_log2: 8,
            }]
        }

        fn execute_batch(&self, info: &ArtifactInfo, a: &[i32], b: &[i32]) -> Result<Vec<i32>> {
            assert_eq!(a.len(), info.batch * info.k, "operand A not padded to shape");
            assert_eq!(b.len(), info.batch * info.k, "operand B not padded to shape");
            self.executions.fetch_add(1, Ordering::Relaxed);
            let base = Base::new(8);
            let mut out = vec![0i32; info.batch * 2 * info.k];
            for row in 0..info.batch {
                let ra: Vec<u32> = a[row * info.k..(row + 1) * info.k]
                    .iter()
                    .map(|&d| d as u32)
                    .collect();
                let rb: Vec<u32> = b[row * info.k..(row + 1) * info.k]
                    .iter()
                    .map(|&d| d as u32)
                    .collect();
                if ra.iter().all(|&d| d == 0) && rb.iter().all(|&d| d == 0) {
                    self.zero_rows.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
                let mut ops = Ops::default();
                let prod = mul::mul_school(&ra, &rb, base, &mut ops);
                for (i, &d) in prod.iter().take(2 * info.k).enumerate() {
                    out[row * 2 * info.k + i] = d as i32;
                }
            }
            Ok(out)
        }
    }

    fn mock_batcher(batch: usize, linger: Duration) -> (Arc<MockRuntime>, Arc<BatchingXlaLeaf>) {
        let rt = MockRuntime::new(batch, 256);
        let mut leaf =
            BatchingXlaLeaf::with_executor(Arc::clone(&rt) as Arc<dyn BatchExecutor>, "school");
        leaf.linger = linger;
        (rt, Arc::new(leaf))
    }

    /// Artifact-backed batcher for the end-to-end tests below; `None`
    /// (skip) when `artifacts/` is not built.
    fn batcher() -> Option<Arc<BatchingXlaLeaf>> {
        let rt = XlaRuntime::new(DEFAULT_ARTIFACTS_DIR).ok()?;
        Some(Arc::new(BatchingXlaLeaf::new(Arc::new(rt), "school")))
    }

    fn reference(x: &[u32], y: &[u32]) -> Vec<u32> {
        let mut ops = Ops::default();
        mul::mul_school(x, y, Base::new(16), &mut ops)
    }

    #[test]
    fn mock_batch_fill_flushes_without_linger() {
        // With linger effectively infinite, only a full batch can
        // trigger a flush: 4 concurrent requests into a B=4 bucket must
        // coalesce into exactly one execution.
        // A generous linger distinguishes fill-flush (instant) from
        // linger-flush (seconds) without risking a hung test.
        let (rt, b) = mock_batcher(4, Duration::from_secs(5));
        let base = Base::new(16);
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let b = Arc::clone(&b);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(t);
                let x = rng.digits(64, 16);
                let y = rng.digits(64, 16);
                let mut ops = Ops::default();
                let got = b.mul(&x, &y, base, &mut ops);
                assert_eq!(got, reference(&x, &y), "thread {t}");
            }));
        }
        let t0 = Instant::now();
        for h in handles {
            h.join().unwrap();
        }
        assert!(
            t0.elapsed() < Duration::from_secs(4),
            "batch-fill flush did not fire; requests waited out the linger"
        );
        assert_eq!(rt.executions.load(Ordering::Relaxed), 1);
        assert_eq!(b.stats.requests.load(Ordering::Relaxed), 4);
        assert_eq!(b.stats.batched_rows.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn mock_lone_request_flushes_after_linger() {
        let (rt, b) = mock_batcher(8, Duration::from_micros(60));
        let base = Base::new(16);
        let mut rng = Rng::new(9);
        let x = rng.digits(32, 16);
        let y = rng.digits(32, 16);
        let mut ops = Ops::default();
        let got = b.mul(&x, &y, base, &mut ops);
        assert_eq!(got, reference(&x, &y));
        // One request, one (partial) execution — the linger timer, not
        // batch fill, flushed it.
        assert_eq!(rt.executions.load(Ordering::Relaxed), 1);
        assert_eq!(b.stats.batched_rows.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn mock_partial_batch_is_zero_padded() {
        // 3 requests into a B=8 bucket: one flush whose remaining 5 rows
        // travel as zeros (the mock counts all-zero rows).
        let (rt, b) = mock_batcher(8, Duration::from_millis(50));
        let base = Base::new(16);
        let mut handles = Vec::new();
        for t in 0..3u64 {
            let b = Arc::clone(&b);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(0x100 + t);
                let x = rng.digits(64, 16);
                let y = rng.digits(64, 16);
                let mut ops = Ops::default();
                let got = b.mul(&x, &y, base, &mut ops);
                assert_eq!(got, reference(&x, &y), "thread {t}");
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let ex = rt.executions.load(Ordering::Relaxed);
        let zeros = rt.zero_rows.load(Ordering::Relaxed);
        // All three coalesce when they enqueue within the linger window
        // (the common case); even under pathological scheduling each
        // flush is zero-padded to the full batch shape.
        assert!(ex >= 1);
        assert_eq!(zeros, ex * 8 - 3, "padding rows must be all-zero");
    }

    #[test]
    fn mock_result_rows_route_back_to_their_cells() {
        // Distinct operands per thread; every caller must receive the
        // product of *its own* pair, not a neighbour's row.
        let (rt, b) = mock_batcher(4, Duration::from_secs(5));
        let base = Base::new(16);
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let b = Arc::clone(&b);
            handles.push(std::thread::spawn(move || {
                // Constant-digit operands make cross-row mixups loud.
                let x = vec![(t as u32) + 1; 64];
                let y = vec![(t as u32) + 11; 64];
                let mut ops = Ops::default();
                let got = b.mul(&x, &y, base, &mut ops);
                assert_eq!(got, reference(&x, &y), "row for thread {t} misrouted");
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(rt.executions.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn single_request_flushes_after_linger() {
        let Some(b) = batcher() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let base = Base::new(16);
        let mut rng = Rng::new(1);
        let x = rng.digits(32, 16);
        let y = rng.digits(32, 16);
        let mut o1 = Ops::default();
        let mut o2 = Ops::default();
        let got = b.mul(&x, &y, base, &mut o1);
        assert_eq!(got, mul::mul_school(&x, &y, base, &mut o2));
        assert_eq!(b.stats.executions.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn concurrent_requests_coalesce() {
        let Some(b) = batcher() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let base = Base::new(16);
        let n_threads = 8;
        let mut handles = Vec::new();
        for t in 0..n_threads {
            let b = Arc::clone(&b);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(t as u64);
                let x = rng.digits(64, 16);
                let y = rng.digits(64, 16);
                let mut o1 = Ops::default();
                let mut o2 = Ops::default();
                let got = b.mul(&x, &y, base, &mut o1);
                let want = mul::mul_school(&x, &y, base, &mut o2);
                assert_eq!(got, want, "thread {t}");
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // 8 concurrent requests into a B=8 artifact: strictly fewer
        // executions than requests proves coalescing happened.
        let ex = b.stats.executions.load(Ordering::Relaxed);
        let rq = b.stats.requests.load(Ordering::Relaxed);
        assert_eq!(rq, 8);
        assert!(ex < rq, "no batching: {ex} executions for {rq} requests");
    }
}

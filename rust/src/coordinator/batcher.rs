//! Dynamic batching of leaf products onto the XLA runtime.
//!
//! Multiple coordinator workers reach their recursion leaves
//! concurrently; instead of dispatching one PJRT execution per product,
//! requests that fit the batched artifact (e.g. `B = 8, K = 256`) are
//! coalesced: the request that fills the batch — or the first whose
//! linger timer expires — becomes the *flusher*, executes one batched
//! artifact call (padding missing rows with zeros), and distributes the
//! output rows. This is the vLLM-style continuous-batching idea applied
//! to the leaf kernel.

use crate::algorithms::leaf::LeafMultiplier;
use crate::bignum::{Base, Ops};
use crate::runtime::artifacts::ArtifactInfo;
use crate::runtime::leaf::{repacked_mul, split_mul8};
use crate::runtime::XlaRuntime;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Result slot a waiting request parks on.
struct Cell {
    out: Mutex<Option<Vec<u32>>>,
    cv: Condvar,
}

struct Pending {
    a: Vec<u32>, // exactly K base-256 digits
    b: Vec<u32>,
    cell: Arc<Cell>,
}

/// Batching statistics (observability for the e2e example / benches).
#[derive(Debug, Default)]
pub struct BatcherStats {
    pub requests: AtomicU64,
    pub executions: AtomicU64,
    pub batched_rows: AtomicU64,
}

impl BatcherStats {
    /// Mean rows per artifact execution (1.0 = no batching win).
    pub fn mean_batch(&self) -> f64 {
        let ex = self.executions.load(Ordering::Relaxed);
        if ex == 0 {
            return 0.0;
        }
        self.batched_rows.load(Ordering::Relaxed) as f64 / ex as f64
    }
}

/// One batch bucket: a batched artifact shape plus its pending queue.
/// Requests are routed to the smallest-K bucket they fit, so narrow
/// leaves don't pay for wide kernels.
struct Bucket {
    info: ArtifactInfo,
    queue: Mutex<VecDeque<Pending>>,
}

/// A [`LeafMultiplier`] that coalesces concurrent leaf products into
/// batched artifact executions.
pub struct BatchingXlaLeaf {
    rt: Arc<XlaRuntime>,
    buckets: Vec<Bucket>,
    max_k: usize,
    /// How long a lone request lingers for company before flushing.
    pub linger: Duration,
    pub stats: BatcherStats,
}

impl BatchingXlaLeaf {
    /// Build one bucket per batched (`batch > 1`) artifact of `entry`,
    /// sorted by K ascending.
    pub fn new(rt: Arc<XlaRuntime>, entry: &str) -> Self {
        let mut infos: Vec<ArtifactInfo> = rt
            .manifest()
            .artifacts
            .iter()
            .filter(|a| a.entry == entry && a.batch > 1)
            .cloned()
            .collect();
        if infos.is_empty() {
            // Fall back to whatever exists (degenerates to batch = 1).
            infos = rt
                .manifest()
                .artifacts
                .iter()
                .filter(|a| a.entry == entry)
                .cloned()
                .collect();
        }
        assert!(!infos.is_empty(), "no `{entry}` artifacts for batching");
        infos.sort_by_key(|a| a.k);
        let max_k = infos.last().unwrap().k;
        BatchingXlaLeaf {
            rt,
            buckets: infos
                .into_iter()
                .map(|info| Bucket {
                    info,
                    queue: Mutex::new(VecDeque::new()),
                })
                .collect(),
            max_k,
            linger: Duration::from_micros(60),
            stats: BatcherStats::default(),
        }
    }

    /// Enqueue one pair into its K bucket and wait for the product row.
    fn mul_fit(&self, a: &[u32], b: &[u32]) -> Vec<u32> {
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        let bucket = self
            .buckets
            .iter()
            .find(|bk| bk.info.k >= a.len())
            .expect("operand exceeds every bucket (split_mul8 should have split it)");
        let k = bucket.info.k;
        let mut pa = a.to_vec();
        let mut pb = b.to_vec();
        pa.resize(k, 0);
        pb.resize(k, 0);
        let cell = Arc::new(Cell {
            out: Mutex::new(None),
            cv: Condvar::new(),
        });
        {
            let mut q = bucket.queue.lock().unwrap();
            q.push_back(Pending {
                a: pa,
                b: pb,
                cell: Arc::clone(&cell),
            });
            if q.len() >= bucket.info.batch {
                let batch: Vec<Pending> = q.drain(..bucket.info.batch).collect();
                drop(q);
                self.flush(bucket, batch);
            }
        }
        let deadline = Instant::now() + self.linger;
        loop {
            // Parked until filled, with linger timeout for the flusher role.
            {
                let guard = cell.out.lock().unwrap();
                if guard.is_some() {
                    return guard.clone().unwrap();
                }
                let wait = deadline.saturating_duration_since(Instant::now());
                if !wait.is_zero() {
                    let (guard, _timeout) = cell.cv.wait_timeout(guard, wait).unwrap();
                    if guard.is_some() {
                        return guard.clone().unwrap();
                    }
                    continue;
                }
            }
            // Linger expired: flush whatever is queued (including us,
            // unless someone else already took it).
            let batch: Vec<Pending> = {
                let mut q = bucket.queue.lock().unwrap();
                let take = q.len().min(bucket.info.batch);
                q.drain(..take).collect()
            };
            if !batch.is_empty() {
                self.flush(bucket, batch);
            }
            // Either we were in that batch (cell now filled) or another
            // flusher has us; loop re-checks the cell.
            let guard = cell.out.lock().unwrap();
            if let Some(v) = guard.clone() {
                return v;
            }
            let (guard, _timeout) = cell
                .cv
                .wait_timeout(guard, Duration::from_millis(5))
                .unwrap();
            if let Some(v) = guard.clone() {
                return v;
            }
        }
    }

    /// Execute one batched artifact call and distribute the rows.
    fn flush(&self, bucket: &Bucket, batch: Vec<Pending>) {
        let (bsz, k) = (bucket.info.batch, bucket.info.k);
        let mut fa = vec![0i32; bsz * k];
        let mut fb = vec![0i32; bsz * k];
        for (row, p) in batch.iter().enumerate() {
            for (i, &d) in p.a.iter().enumerate() {
                fa[row * k + i] = d as i32;
            }
            for (i, &d) in p.b.iter().enumerate() {
                fb[row * k + i] = d as i32;
            }
        }
        let out = self
            .rt
            .execute(&bucket.info, &fa, &fb)
            .expect("batched XLA execution failed");
        self.stats.executions.fetch_add(1, Ordering::Relaxed);
        self.stats
            .batched_rows
            .fetch_add(batch.len() as u64, Ordering::Relaxed);
        for (row, p) in batch.into_iter().enumerate() {
            let digits: Vec<u32> = out[row * 2 * k..(row + 1) * 2 * k]
                .iter()
                .map(|&d| d as u32)
                .collect();
            *p.cell.out.lock().unwrap() = Some(digits);
            p.cell.cv.notify_all();
        }
    }

    /// Precompile every bucket artifact (hide compile from serving).
    pub fn warmup(&self) -> crate::error::Result<()> {
        for b in &self.buckets {
            let za = vec![0i32; b.info.batch * b.info.k];
            let zb = vec![0i32; b.info.batch * b.info.k];
            self.rt.execute(&b.info, &za, &zb)?;
        }
        Ok(())
    }
}

impl LeafMultiplier for BatchingXlaLeaf {
    fn name(&self) -> &'static str {
        "xla-batched"
    }

    fn mul(&self, a: &[u32], b: &[u32], base: Base, ops: &mut Ops) -> Vec<u32> {
        let mut fit = |x: &[u32], y: &[u32], ops: &mut Ops| -> Vec<u32> {
            let k = x.len();
            ops.charge(2 * (k as u64) * (k as u64));
            let mut row = self.mul_fit(x, y);
            row.truncate(2 * k);
            row
        };
        let max_k = self.max_k;
        repacked_mul(
            &mut |a8, b8, ops| split_mul8(&mut fit, max_k, a8, b8, ops),
            a,
            b,
            base,
            ops,
        )
    }

    fn scratch_words(&self, w: usize) -> usize {
        4 * w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bignum::mul;
    use crate::runtime::DEFAULT_ARTIFACTS_DIR;
    use crate::util::Rng;

    fn batcher() -> Option<Arc<BatchingXlaLeaf>> {
        let rt = XlaRuntime::new(DEFAULT_ARTIFACTS_DIR).ok()?;
        Some(Arc::new(BatchingXlaLeaf::new(Arc::new(rt), "school")))
    }

    #[test]
    fn single_request_flushes_after_linger() {
        let Some(b) = batcher() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let base = Base::new(16);
        let mut rng = Rng::new(1);
        let x = rng.digits(32, 16);
        let y = rng.digits(32, 16);
        let mut o1 = Ops::default();
        let mut o2 = Ops::default();
        let got = b.mul(&x, &y, base, &mut o1);
        assert_eq!(got, mul::mul_school(&x, &y, base, &mut o2));
        assert_eq!(b.stats.executions.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn concurrent_requests_coalesce() {
        let Some(b) = batcher() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let base = Base::new(16);
        let n_threads = 8;
        let mut handles = Vec::new();
        for t in 0..n_threads {
            let b = Arc::clone(&b);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(t as u64);
                let x = rng.digits(64, 16);
                let y = rng.digits(64, 16);
                let mut o1 = Ops::default();
                let mut o2 = Ops::default();
                let got = b.mul(&x, &y, base, &mut o1);
                let want = mul::mul_school(&x, &y, base, &mut o2);
                assert_eq!(got, want, "thread {t}");
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // 8 concurrent requests into a B=8 artifact: strictly fewer
        // executions than requests proves coalescing happened.
        let ex = b.stats.executions.load(Ordering::Relaxed);
        let rq = b.stats.requests.load(Ordering::Relaxed);
        assert_eq!(rq, 8);
        assert!(ex < rq, "no batching: {ex} executions for {rq} requests");
    }
}

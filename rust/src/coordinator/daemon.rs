//! Always-on serving: open-loop load over the sharded scheduler.
//!
//! `copmul serve` runs a fixed batch and exits; this module is the
//! persistent-service layer behind `copmul daemon`. A [`Daemon`] wraps
//! a long-lived [`Scheduler`] and accepts concurrent submissions from
//! any thread; [`run_open_loop`] drives it with a seeded **open-loop**
//! arrival process — arrivals follow the generator's schedule and
//! never wait for completions, so offered load is the independent
//! variable and the system's only defenses are its admission and
//! shedding policies (the closed-loop batch of `serve` can never
//! overload itself; an open-loop client can and does).
//!
//! ## Arrival processes
//!
//! [`ArrivalGen`] produces deterministic, seeded inter-arrival gaps:
//!
//! * **Poisson** — exponential gaps via inverse-CDF over the seeded
//!   [`Rng`]'s `[0, 1)` doubles: `-ln(1 − u) / rate`. Memoryless, the
//!   standard open-loop reference load.
//! * **Bursty (on/off)** — `burst` arrivals with exponential gaps at
//!   the on-rate, then a fixed idle gap, repeated. Stresses admission
//!   with queue spikes a Poisson stream of equal mean rarely produces.
//!
//! Same seed + parameters → the same schedule, byte for byte; the soak
//! suite replays schedules to pin determinism.
//!
//! ## Shedding policy (reject early, never queue forever)
//!
//! Under open-loop overload a plain FIFO queue grows without bound and
//! *every* job's latency diverges. The daemon instead sheds at three
//! rungs, earliest first:
//!
//! 1. **SLO estimate, before queueing** — `submit` estimates queue
//!    delay as `in_flight × EWMA(service time) / runners` and sheds a
//!    deadlined job immediately when the estimate already exceeds its
//!    deadline × [`DaemonConfig::shed_headroom`]. Costs the client a
//!    round-trip of nothing: no queue slot, no shard, no work.
//! 2. **Queue bound** — the scheduler's existing `max_queue`
//!    reservation path ([`RejectKind::QueueFull`]).
//! 3. **Deadline at dequeue** — a queued job whose budget expired
//!    before a shard freed up is dropped by the runner
//!    (`SchedulerStats::shed_expired`), bounding the work wasted on
//!    jobs that already missed their SLO.
//!
//! Shedding is *load regulation*, not failure: shed jobs are counted
//! separately from `failed` everywhere, and [`ServingReport`] exposes
//! `check_shed_budget` so soaks can assert the shed fraction stays
//! below a configured limit.
//!
//! ## Framing
//!
//! [`Request::encode`]/[`Request::decode`] define a little-endian
//! length-explicit frame for submissions, parsed with the shared
//! [`FrameCursor`] (`util::frame`) — the same bounds-checked reader the
//! socket engine's command/reply/net frames go through (`sim::socket`,
//! ROADMAP item 1), so both codecs inherit one hardening discipline:
//! every length field is capped against the remaining buffer before
//! anything is allocated (fuzzed in `tests/wire_fuzz.rs`). A daemon
//! front-end reading frames off a stream decodes straight into
//! [`Request`] and calls [`Daemon::submit`].
//!
//! ## Cost identity under load
//!
//! Scheduling pressure moves *wall-clock* latency only: a job's
//! reported `(T, BW, L)` cost triple comes from its shard's logical
//! clocks relative to a uniform baseline, which queue waits and
//! concurrent neighbors do not perturb (scheduler module docs). The
//! serving experiment (E19) re-runs completed jobs on dedicated
//! machines and asserts zero-fault triples stay bit-identical at every
//! offered load.

use super::batcher::{BatchExecutor, BatchingXlaLeaf, SchoolBatchRuntime};
use super::job::{JobResult, JobSpec};
use super::scheduler::{RejectKind, Scheduler, SchedulerConfig};
use crate::algorithms::leaf::{LeafMultiplier, LeafRef};
use crate::algorithms::{Algorithm, ExecMode, ExecPolicy};
use crate::bignum::{Base, Ops};
use crate::error::{anyhow, bail, ensure, Error, Result};
use crate::metrics::{fmt_u64, latency_summary, percentile};
use crate::sim::Clock;
use crate::util::frame::FrameCursor;
use crate::util::Rng;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

// ------------------------------------------------------------- arrivals

/// Which open-loop arrival process a generator produces.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrivalKind {
    Poisson,
    Bursty,
}

/// Seeded deterministic inter-arrival generator (module docs,
/// "Arrival processes"). Clone it to replay the schedule.
#[derive(Clone, Debug)]
pub struct ArrivalGen {
    kind: ArrivalKind,
    rng: Rng,
    /// Mean exponential gap while "on", seconds (`1 / rate`).
    mean_gap_s: f64,
    /// Bursty only: arrivals per on-phase.
    burst: u64,
    /// Bursty only: fixed off-phase gap appended between bursts.
    idle: Duration,
    left_in_burst: u64,
}

impl ArrivalGen {
    /// Poisson arrivals at `rate_per_s` (exponential gaps).
    pub fn poisson(seed: u64, rate_per_s: f64) -> Result<ArrivalGen> {
        ensure!(
            rate_per_s > 0.0 && rate_per_s.is_finite(),
            "arrival rate must be a positive finite number (got {rate_per_s})"
        );
        Ok(ArrivalGen {
            kind: ArrivalKind::Poisson,
            rng: Rng::new(seed),
            mean_gap_s: 1.0 / rate_per_s,
            burst: 0,
            idle: Duration::ZERO,
            left_in_burst: 0,
        })
    }

    /// On/off arrivals: `burst` exponential-gap arrivals at
    /// `on_rate_per_s`, then a fixed `idle` gap, repeated.
    pub fn bursty(seed: u64, on_rate_per_s: f64, burst: u64, idle: Duration) -> Result<ArrivalGen> {
        ensure!(
            on_rate_per_s > 0.0 && on_rate_per_s.is_finite(),
            "on-rate must be a positive finite number (got {on_rate_per_s})"
        );
        ensure!(burst >= 1, "burst must be >= 1 (got {burst})");
        Ok(ArrivalGen {
            kind: ArrivalKind::Bursty,
            rng: Rng::new(seed),
            mean_gap_s: 1.0 / on_rate_per_s,
            burst,
            idle,
            left_in_burst: burst,
        })
    }

    pub fn kind(&self) -> ArrivalKind {
        self.kind
    }

    /// Gap before the next arrival. Exponential via inverse CDF
    /// (`u ∈ [0, 1)` keeps `1 − u > 0`, so the log is finite); bursty
    /// generators splice the fixed idle gap in front of each new burst.
    pub fn next_gap(&mut self) -> Duration {
        let u = self.rng.f64();
        let exp_s = -(1.0 - u).ln() * self.mean_gap_s;
        let mut gap = Duration::from_secs_f64(exp_s);
        if self.kind == ArrivalKind::Bursty {
            if self.left_in_burst == 0 {
                gap += self.idle;
                self.left_in_burst = self.burst;
            }
            self.left_in_burst -= 1;
        }
        gap
    }

    /// Cumulative arrival offsets for `jobs` arrivals (first arrival at
    /// `next_gap()`, not at zero). Consumes generator state; replay by
    /// cloning or re-seeding.
    pub fn schedule(&mut self, jobs: u64) -> Vec<Duration> {
        let mut t = Duration::ZERO;
        (0..jobs)
            .map(|_| {
                t += self.next_gap();
                t
            })
            .collect()
    }
}

// ------------------------------------------------------------- requests

/// A client submission: the operands plus per-job knobs. The daemon
/// assigns the job id.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Request {
    /// Operand digits, LSB-first in the daemon machine's base.
    pub a: Vec<u32>,
    pub b: Vec<u32>,
    /// Requested processors (shard sizing rounds up the ladder).
    pub procs: usize,
    /// Force a scheme; `None` = hybrid dispatch.
    pub algo: Option<Algorithm>,
    /// The job's own memory bound (enforced at admission).
    pub mem_cap: Option<u64>,
    /// Relative deadline; `None` falls back to the daemon default.
    pub deadline: Option<Duration>,
    /// Execution-mode policy (DFS default / auto / BFS). Rides the
    /// frame's previously-reserved `u16` — old frames carry 0 there,
    /// which decodes to `Dfs`, so version 1 stays wire-compatible.
    pub exec_mode: ExecPolicy,
}

/// Sentinel for "no value" in the fixed-width frame fields.
const FRAME_NONE: u64 = u64::MAX;

impl Request {
    /// Frame magic, `"COPM"` big-endian-readable in a hex dump.
    pub const MAGIC: u32 = 0x434F_504D;
    /// Frame format version.
    pub const VERSION: u8 = 1;

    /// Serialize to the daemon's little-endian wire frame:
    ///
    /// ```text
    /// u32 magic  u8 version  u8 algo(0 hybrid|1 copsim|2 copk)
    /// u16 exec_mode(0 dfs|1 auto|2 bfs)  u32 procs
    /// u64 mem_cap(MAX=none)  u64 deadline_µs(MAX=none)
    /// u32 a_len  u32 b_len  a_len×u32 digits  b_len×u32 digits
    /// ```
    ///
    /// The in-process API never serializes; this is the socket contract
    /// the future `SocketMachine` listener reuses (module docs).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(36 + 4 * (self.a.len() + self.b.len()));
        out.extend_from_slice(&Self::MAGIC.to_le_bytes());
        out.push(Self::VERSION);
        out.push(match self.algo {
            None => 0,
            Some(Algorithm::Copsim) => 1,
            Some(Algorithm::Copk) => 2,
        });
        out.extend_from_slice(&self.exec_mode.tag().to_le_bytes());
        out.extend_from_slice(&(self.procs as u32).to_le_bytes());
        out.extend_from_slice(&self.mem_cap.unwrap_or(FRAME_NONE).to_le_bytes());
        let dl = self
            .deadline
            .map(|d| d.as_micros() as u64)
            .unwrap_or(FRAME_NONE);
        out.extend_from_slice(&dl.to_le_bytes());
        out.extend_from_slice(&(self.a.len() as u32).to_le_bytes());
        out.extend_from_slice(&(self.b.len() as u32).to_le_bytes());
        for d in self.a.iter().chain(self.b.iter()) {
            out.extend_from_slice(&d.to_le_bytes());
        }
        out
    }

    /// Parse one frame produced by [`Request::encode`], rejecting bad
    /// magic, unknown versions, truncated payloads, trailing garbage,
    /// and hostile length fields (see [`FrameCursor::digits`]).
    pub fn decode(buf: &[u8]) -> Result<Request> {
        let mut f = FrameCursor::new(buf);
        let magic = f.u32()?;
        ensure!(
            magic == Self::MAGIC,
            "bad frame magic {magic:#010x} (want {:#010x})",
            Self::MAGIC
        );
        let version = f.u8()?;
        ensure!(
            version == Self::VERSION,
            "unsupported frame version {version} (speak {})",
            Self::VERSION
        );
        let algo = match f.u8()? {
            0 => None,
            1 => Some(Algorithm::Copsim),
            2 => Some(Algorithm::Copk),
            x => bail!("bad algo tag {x} (0 hybrid, 1 copsim, 2 copk)"),
        };
        let mode_tag = u16::from_le_bytes(f.take(2)?.try_into().expect("two bytes"));
        let exec_mode = ExecPolicy::from_tag(mode_tag)?;
        let procs = f.u32()? as usize;
        let mem_cap = match f.u64()? {
            FRAME_NONE => None,
            m => Some(m),
        };
        let deadline = match f.u64()? {
            FRAME_NONE => None,
            us => Some(Duration::from_micros(us)),
        };
        let a_len = f.u32()? as usize;
        let b_len = f.u32()? as usize;
        let a = f.digits(a_len)?;
        let b = f.digits(b_len)?;
        f.expect_end()?;
        Ok(Request {
            a,
            b,
            procs,
            algo,
            mem_cap,
            deadline,
            exec_mode,
        })
    }
}

// ------------------------------------------------------------ the daemon

/// Why a submission was shed (client-visible taxonomy; module docs,
/// "Shedding policy").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// Estimated queue delay already exceeds the job's deadline.
    SloEstimate,
    /// The scheduler's `max_queue` bound is full.
    QueueFull,
    /// No shape of the machine fits the job (machine-wide cap).
    Unfittable,
    /// The job's own `mem_cap` is the binding constraint.
    JobCap,
    /// The job demanded `exec-mode=bfs` but no BFS level fits its
    /// memory cap (request `auto` to fall back to DFS instead).
    BfsCap,
}

/// Outcome of [`Daemon::submit`]: admitted with a reply channel, or
/// shed synchronously (reject-early — the caller learns immediately).
#[derive(Debug)]
pub enum Submission {
    Admitted(Receiver<Result<JobResult>>),
    Shed { reason: ShedReason, error: Error },
}

/// Daemon configuration: the scheduler it wraps plus the SLO policy.
#[derive(Clone)]
pub struct DaemonConfig {
    pub sched: SchedulerConfig,
    /// Deadline applied to requests that carry none (`None` = jobs
    /// without their own deadline never expire and are never
    /// SLO-shed).
    pub default_deadline: Option<Duration>,
    /// SLO shed threshold multiplier: shed a deadlined job up front
    /// when `estimated_queue_delay > deadline × shed_headroom`. `1.0`
    /// sheds exactly at the estimate; `< 1.0` sheds earlier
    /// (conservative); `0.0` disables the estimate rung entirely
    /// (queue-bound and dequeue-expiry rungs still apply).
    pub shed_headroom: f64,
    /// Seed for the service-time EWMA before the first completion, µs.
    /// Start it near the expected per-job wall so the estimate rung is
    /// neither blind (0 would never shed until a completion lands) nor
    /// trigger-happy at cold start.
    pub init_service_us: u64,
    /// Small-job coalescing: requests whose operand width (digits per
    /// side) is at most this threshold bypass the simulated machine
    /// entirely and run on the dynamic batcher (`coordinator::batcher`),
    /// which coalesces concurrent products into batched kernel
    /// executions. `0` (the default) disables the path — every request
    /// goes through the scheduler unchanged. Batched results carry a
    /// **zero cost triple** and `mem_peak = 0`: no machine ran, so
    /// there is no paper cost to report (the product is still verified
    /// by the soak suites).
    pub batch_threshold: usize,
    /// Worker threads draining the batch queue (used only when
    /// `batch_threshold > 0`). At least 2, so concurrent requests can
    /// actually coalesce instead of serializing on one flusher.
    pub batch_runners: usize,
    /// Executor behind the batch path; `None` falls back to the
    /// pure-Rust [`SchoolBatchRuntime`] (always available — the PJRT
    /// runtime needs compiled artifacts).
    pub batch_executor: Option<Arc<dyn BatchExecutor>>,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        DaemonConfig {
            sched: SchedulerConfig::default(),
            default_deadline: None,
            shed_headroom: 1.0,
            init_service_us: 200,
            batch_threshold: 0,
            batch_runners: 2,
            batch_executor: None,
        }
    }
}

/// Daemon-level counters ([`Scheduler`] keeps its own; a
/// [`ServingReport`] merges both).
#[derive(Debug, Default)]
pub struct DaemonStats {
    /// Every `submit` call.
    pub offered: AtomicU64,
    /// Submissions the scheduler accepted.
    pub admitted: AtomicU64,
    /// Shed by the SLO estimate before queueing.
    pub shed_slo: AtomicU64,
    /// Shed by the scheduler's queue bound.
    pub shed_queue_full: AtomicU64,
    /// Rejected as unfittable (machine-wide or the job's own cap) —
    /// malformed work, not load.
    pub rejected_unfittable: AtomicU64,
    /// Small jobs completed on the batch path (no machine ran; their
    /// results carry zero cost triples). Folded into the serving
    /// report's `completed`, so the accounting identity holds with
    /// batching on.
    pub batched_completed: AtomicU64,
    /// Batch-path jobs whose execution panicked (a broken executor) —
    /// folded into the report's `failed`.
    pub batched_failed: AtomicU64,
    /// EWMA of completed jobs' end-to-end wall time, µs (α = 1/8).
    pub ewma_service_us: AtomicU64,
}

/// One queued small job on the batch path: id, operands, reply
/// channel, and the submission instant (wall spans submit→complete,
/// matching the scheduler path).
type BatchJob = (u64, Vec<u32>, Vec<u32>, Sender<Result<JobResult>>, Instant);

/// The small-job coalescing lane (`DaemonConfig::batch_threshold`): a
/// bounded queue drained by a couple of worker threads that push every
/// product through one shared [`BatchingXlaLeaf`] — concurrent small
/// requests coalesce into batched kernel executions instead of each
/// paying a machine build + scatter + gather. Dropping it closes the
/// queue and joins the workers.
struct BatchPath {
    tx: Option<SyncSender<BatchJob>>,
    workers: Vec<JoinHandle<()>>,
}

impl Drop for BatchPath {
    fn drop(&mut self) {
        self.tx.take();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// The always-on serving daemon: a long-lived [`Scheduler`] plus the
/// SLO shedding policy. `submit` is `&self` and thread-safe — clients
/// on any thread submit concurrently; replies arrive on per-job
/// channels.
pub struct Daemon {
    sched: Scheduler,
    cfg: DaemonConfig,
    next_id: AtomicU64,
    batch: Option<BatchPath>,
    pub stats: Arc<DaemonStats>,
}

impl Daemon {
    /// Build the shared machine and start serving. Only the socket
    /// engine can fail construction (worker processes must spawn and
    /// finish their wiring handshake).
    pub fn start(cfg: DaemonConfig, leaf: LeafRef) -> Result<Daemon> {
        let sched = Scheduler::start(cfg.sched.clone(), leaf)?;
        let stats = Arc::new(DaemonStats::default());
        stats
            .ewma_service_us
            .store(cfg.init_service_us.max(1), Ordering::Relaxed);
        let batch = (cfg.batch_threshold > 0).then(|| {
            let executor = cfg
                .batch_executor
                .clone()
                .unwrap_or_else(|| Arc::new(SchoolBatchRuntime::new(8, 256)));
            let batcher = Arc::new(BatchingXlaLeaf::with_executor(executor, "school"));
            let (tx, rx) = sync_channel::<BatchJob>(cfg.sched.max_queue.max(1));
            let rx = Arc::new(Mutex::new(rx));
            let base = cfg.sched.base;
            let engine = cfg.sched.engine;
            let workers = (0..cfg.batch_runners.max(2))
                .map(|_| {
                    let rx = Arc::clone(&rx);
                    let batcher = Arc::clone(&batcher);
                    let stats = Arc::clone(&stats);
                    std::thread::spawn(move || loop {
                        let msg = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        let Ok((id, a, b, reply, submitted_at)) = msg else {
                            break;
                        };
                        // The batcher's flush path panics on a broken
                        // executor; contain that to the one job so a
                        // bad batch cannot take the worker down.
                        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            let mut ops = Ops::default();
                            let mut prod = batcher.mul(&a, &b, base, &mut ops);
                            let keep = crate::bignum::core::normalized_len(&prod).max(1);
                            prod.truncate(keep);
                            prod
                        }));
                        let res = match out {
                            Ok(product) => {
                                stats.batched_completed.fetch_add(1, Ordering::Relaxed);
                                Ok(JobResult {
                                    id,
                                    product,
                                    // No parallel scheme ran — the lane is a
                                    // sequential batched leaf. Report the
                                    // DFS default and a zero cost triple.
                                    algo: Algorithm::Copsim,
                                    exec_mode: ExecMode::Dfs,
                                    engine,
                                    cost: Clock::default(),
                                    mem_peak: 0,
                                    wall: submitted_at.elapsed(),
                                    shard: None,
                                    attempts: 1,
                                    faults_survived: 0,
                                })
                            }
                            Err(_) => {
                                stats.batched_failed.fetch_add(1, Ordering::Relaxed);
                                Err(anyhow!("job {id}: batched execution panicked"))
                            }
                        };
                        let _ = reply.send(res);
                    })
                })
                .collect();
            BatchPath {
                tx: Some(tx),
                workers,
            }
        });
        Ok(Daemon {
            sched,
            cfg,
            next_id: AtomicU64::new(0),
            batch,
            stats,
        })
    }

    /// The wrapped scheduler (stats, fault counters).
    pub fn scheduler(&self) -> &Scheduler {
        &self.sched
    }

    /// Machine digit base (for clients generating operands).
    pub fn base(&self) -> Base {
        self.sched.config().base
    }

    /// Queue-delay estimate behind the SLO rung: jobs ahead of this one
    /// × mean service time ÷ runner parallelism. Deliberately crude —
    /// it uses end-to-end wall (queue wait included) as the service
    /// EWMA, which over-estimates under backlog and so sheds
    /// *conservatively* exactly when the queue is deepest (decision
    /// entry in DESIGN.md).
    pub fn estimated_queue_delay(&self) -> Duration {
        let waiting = self.sched.stats.in_flight.load(Ordering::Relaxed);
        let ewma = self.stats.ewma_service_us.load(Ordering::Relaxed);
        let runners = self.sched.config().runners.max(1) as u64;
        let est = waiting.saturating_mul(ewma) / runners;
        // Degraded mode: with only `live` of `total` processors in
        // service the same backlog drains proportionally slower, so the
        // estimate (and the SLO rung behind it) scales by total/live —
        // a degraded machine sheds honestly instead of queueing jobs to
        // expiry. At full health this is exactly the undegraded
        // estimate, so the zero-fault path is unchanged.
        let total = self.sched.config().procs.max(1) as u64;
        let live = self.sched.live_procs().max(1) as u64;
        Duration::from_micros(est.saturating_mul(total) / live)
    }

    /// Fold a completed job's end-to-end wall into the service EWMA
    /// (α = 1/8). [`run_open_loop`] calls this per completion; external
    /// clients should too, or the estimate goes stale at `init`.
    pub fn note_service(&self, wall: Duration) {
        let us = (wall.as_micros() as u64).max(1);
        let _ = self
            .stats
            .ewma_service_us
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |old| {
                Some((old.saturating_mul(7).saturating_add(us)) / 8)
            });
    }

    /// Submit one request: shed early (SLO estimate) or hand it to the
    /// scheduler, mapping typed rejections to [`ShedReason`]s. Never
    /// blocks on job execution.
    pub fn submit(&self, req: Request) -> Submission {
        self.stats.offered.fetch_add(1, Ordering::Relaxed);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        // Small-job lane: below the threshold the request skips the
        // simulated machine entirely and coalesces on the batcher. The
        // lane's queue bound is the same `max_queue`, shed as
        // QueueFull; deadlines don't apply (there is no queue-wait
        // problem a sub-threshold schoolbook product can have).
        if let Some(bp) = &self.batch {
            if req.a.len().max(req.b.len()) <= self.cfg.batch_threshold {
                let (reply_tx, reply_rx) = channel();
                let job = (id, req.a, req.b, reply_tx, Instant::now());
                return match bp.tx.as_ref().expect("batch path live").try_send(job) {
                    Ok(()) => {
                        self.stats.admitted.fetch_add(1, Ordering::Relaxed);
                        Submission::Admitted(reply_rx)
                    }
                    Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => {
                        self.stats.shed_queue_full.fetch_add(1, Ordering::Relaxed);
                        Submission::Shed {
                            reason: ShedReason::QueueFull,
                            error: anyhow!(
                                "job {id} shed: batch queue full ({} slots)",
                                self.cfg.sched.max_queue.max(1)
                            ),
                        }
                    }
                };
            }
        }
        let deadline = req.deadline.or(self.cfg.default_deadline);
        if let (Some(dl), true) = (deadline, self.cfg.shed_headroom > 0.0) {
            let est = self.estimated_queue_delay();
            if est.as_secs_f64() > dl.as_secs_f64() * self.cfg.shed_headroom {
                self.stats.shed_slo.fetch_add(1, Ordering::Relaxed);
                return Submission::Shed {
                    reason: ShedReason::SloEstimate,
                    error: anyhow!(
                        "job {id} shed before queueing: estimated queue delay {est:?} \
                         exceeds deadline {dl:?} × headroom {}",
                        self.cfg.shed_headroom
                    ),
                };
            }
        }
        let mut spec = JobSpec::new(id, req.a, req.b);
        spec.procs = req.procs;
        spec.algo = req.algo;
        spec.mem_cap = req.mem_cap;
        spec.deadline = deadline;
        spec.exec_mode = req.exec_mode;
        match self.sched.try_submit(spec) {
            Ok(rx) => {
                self.stats.admitted.fetch_add(1, Ordering::Relaxed);
                Submission::Admitted(rx)
            }
            Err(rej) => {
                let reason = match rej.kind {
                    RejectKind::QueueFull => {
                        self.stats.shed_queue_full.fetch_add(1, Ordering::Relaxed);
                        ShedReason::QueueFull
                    }
                    RejectKind::Unfittable => {
                        self.stats.rejected_unfittable.fetch_add(1, Ordering::Relaxed);
                        ShedReason::Unfittable
                    }
                    RejectKind::JobCapUnfittable => {
                        self.stats.rejected_unfittable.fetch_add(1, Ordering::Relaxed);
                        ShedReason::JobCap
                    }
                    RejectKind::BfsUnfittable => {
                        self.stats.rejected_unfittable.fetch_add(1, Ordering::Relaxed);
                        ShedReason::BfsCap
                    }
                };
                Submission::Shed {
                    reason,
                    error: rej.error,
                }
            }
        }
    }

    /// Drain in-flight jobs and tear down the scheduler (closing the
    /// batch lane first, so queued small jobs finish their replies).
    pub fn shutdown(mut self) -> Result<()> {
        self.batch.take();
        self.sched.shutdown()
    }
}

// ------------------------------------------------------------- workload

/// Deterministic per-index request generation: request `i`'s operands
/// come from `Rng::new(seed ⊻ mix(i))`, so any request regenerates from
/// its index alone — no shared stream to replay from the start. On a
/// fresh daemon driven by [`run_open_loop`], daemon job ids equal
/// workload indices (one driver, ids assigned in submission order), so
/// [`Workload::spec`] rebuilds the exact `JobSpec` of a collected
/// [`JobResult`] for dedicated-machine verification.
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    pub seed: u64,
    /// Operand digits per side.
    pub n: usize,
    /// Machine base exponent (digits are in `[0, 2^base_log2)`).
    pub base_log2: u32,
    /// Requested processors per job.
    pub procs: usize,
    pub algo: Option<Algorithm>,
    /// Execution-mode policy stamped on every request (`Dfs` default).
    pub exec_mode: ExecPolicy,
}

impl Default for Workload {
    fn default() -> Self {
        Workload {
            seed: 0xDAE0,
            n: 256,
            base_log2: 16,
            procs: 4,
            algo: Some(Algorithm::Copsim),
            exec_mode: ExecPolicy::Dfs,
        }
    }
}

impl Workload {
    fn rng_for(&self, i: u64) -> Rng {
        Rng::new(self.seed ^ i.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// The `i`-th request (no deadline — the daemon default applies).
    pub fn request(&self, i: u64) -> Request {
        let mut rng = self.rng_for(i);
        Request {
            a: rng.digits(self.n, self.base_log2),
            b: rng.digits(self.n, self.base_log2),
            procs: self.procs,
            algo: self.algo,
            mem_cap: None,
            deadline: None,
            exec_mode: self.exec_mode,
        }
    }

    /// The `JobSpec` the daemon built for job `id` (fresh-daemon id ==
    /// workload index; see type docs) — for replaying a collected job
    /// on a dedicated machine.
    pub fn spec(&self, id: u64) -> JobSpec {
        let req = self.request(id);
        let mut spec = JobSpec::new(id, req.a, req.b);
        spec.procs = req.procs;
        spec.algo = req.algo;
        spec.exec_mode = req.exec_mode;
        spec
    }
}

// ------------------------------------------------------- open-loop runs

/// One open-loop run: the arrival schedule, how many jobs, and what to
/// do with completions.
#[derive(Clone, Debug)]
pub struct OpenLoop {
    pub arrivals: ArrivalGen,
    pub jobs: u64,
    pub workload: Workload,
    /// Bignum-verify every completed product against a school-method
    /// reference (the soak suites' correctness leg).
    pub verify: bool,
    /// Keep completed [`JobResult`]s in the report (for cost-identity
    /// checks; off for big soaks to bound memory).
    pub collect: bool,
}

/// Outcome of [`run_open_loop`]: merged daemon + scheduler counter
/// deltas, sorted latencies, and (if collected) the results.
#[derive(Debug)]
pub struct ServingReport {
    pub offered: u64,
    pub completed: u64,
    /// Jobs that ran and errored (retry budget exhausted, machine
    /// degraded) — NOT shed jobs.
    pub failed: u64,
    pub shed_slo: u64,
    pub shed_queue_full: u64,
    /// Shed at dequeue by deadline expiry.
    pub shed_expired: u64,
    pub rejected_unfittable: u64,
    pub retries: u64,
    /// Quarantine events during the run (processors pulled from
    /// service; monotone-counter delta).
    pub quarantined: u64,
    /// Processors re-admitted to the pool by probation during the run.
    pub dequarantined: u64,
    /// Probation canary probes executed during the run.
    pub probes_sent: u64,
    /// Socket worker-process groups respawned during the run.
    pub respawns: u64,
    pub wall: Duration,
    /// Completed jobs' end-to-end latency, µs, ascending.
    pub lat_us: Vec<u64>,
    /// Completed results (empty unless `OpenLoop::collect`).
    pub results: Vec<JobResult>,
}

impl ServingReport {
    /// Load-regulation sheds (SLO + queue + expiry). Unfittable
    /// rejections are excluded: they are malformed work, not load.
    pub fn shed_total(&self) -> u64 {
        self.shed_slo + self.shed_queue_full + self.shed_expired
    }

    /// Completions per second of run wall time (0 for a ~zero wall).
    pub fn goodput_per_s(&self) -> f64 {
        let secs = self.wall.as_secs_f64();
        if secs < 1e-9 {
            0.0
        } else {
            self.completed as f64 / secs
        }
    }

    /// Latency percentile in µs (0 when nothing completed — pair with
    /// `completed` when reading).
    pub fn percentile_us(&self, q: f64) -> u64 {
        percentile(&self.lat_us, q).unwrap_or(0)
    }

    /// Error when load-regulation sheds exceed `max_frac` of offered
    /// jobs — the SLO budget gate the soak legs assert.
    pub fn check_shed_budget(&self, max_frac: f64) -> Result<()> {
        let frac = if self.offered == 0 {
            0.0
        } else {
            self.shed_total() as f64 / self.offered as f64
        };
        ensure!(
            frac <= max_frac,
            "shed budget exceeded: {}/{} jobs shed ({frac:.3} > {max_frac:.3} allowed; \
             {} slo-early, {} queue-full, {} deadline-expired)",
            self.shed_total(),
            self.offered,
            self.shed_slo,
            self.shed_queue_full,
            self.shed_expired
        );
        Ok(())
    }

    /// Two-line human summary (never panics on an all-shed run), plus a
    /// recovery line whenever the self-healing machinery fired.
    pub fn summary(&self) -> String {
        let mut lat = self.lat_us.clone();
        let head = latency_summary(self.offered as usize, self.wall, &mut lat);
        let mut out = format!(
            "{head}\n  p999={}µs goodput={:.1} jobs/s | shed: {} slo-early, {} queue-full, \
             {} deadline-expired | {} unfittable, {} failed, {} retried",
            fmt_u64(self.percentile_us(0.999)),
            self.goodput_per_s(),
            self.shed_slo,
            self.shed_queue_full,
            self.shed_expired,
            self.rejected_unfittable,
            self.failed,
            self.retries,
        );
        if self.quarantined + self.dequarantined + self.probes_sent + self.respawns > 0 {
            out.push_str(&format!(
                "\n  recovery: {} quarantined, {} probed back, {} probes, {} respawns",
                self.quarantined, self.dequarantined, self.probes_sent, self.respawns
            ));
        }
        out
    }
}

/// Counter snapshot for delta-based reporting (the daemon may serve
/// several runs back to back).
struct Counters {
    offered: u64,
    completed: u64,
    failed: u64,
    shed_slo: u64,
    shed_queue_full: u64,
    shed_expired: u64,
    rejected_unfittable: u64,
    retries: u64,
    quarantined: u64,
    dequarantined: u64,
    probes_sent: u64,
    respawns: u64,
}

fn snapshot(d: &Daemon) -> Counters {
    let s = &d.stats;
    let ss = &d.scheduler().stats;
    // The batch lane bypasses the scheduler, so its completions and
    // failures fold in here to keep the accounting identity
    // offered == completed + failed + shed + rejected.
    Counters {
        offered: s.offered.load(Ordering::Relaxed),
        completed: ss.completed.load(Ordering::Relaxed)
            + s.batched_completed.load(Ordering::Relaxed),
        failed: ss.failed.load(Ordering::Relaxed) + s.batched_failed.load(Ordering::Relaxed),
        shed_slo: s.shed_slo.load(Ordering::Relaxed),
        shed_queue_full: s.shed_queue_full.load(Ordering::Relaxed),
        shed_expired: ss.shed_expired.load(Ordering::Relaxed),
        rejected_unfittable: s.rejected_unfittable.load(Ordering::Relaxed),
        retries: ss.retries.load(Ordering::Relaxed),
        quarantined: ss.procs_quarantined.load(Ordering::Relaxed),
        dequarantined: ss.procs_dequarantined.load(Ordering::Relaxed),
        probes_sent: ss.probes_sent.load(Ordering::Relaxed),
        respawns: ss.respawns.load(Ordering::Relaxed),
    }
}

/// School-method reference product, trimmed like [`JobResult::product`].
fn reference_product(a: &[u32], b: &[u32], base: Base) -> Vec<u32> {
    let mut ops = Ops::default();
    let mut prod = crate::bignum::mul::mul_school(a, b, base, &mut ops);
    let keep = crate::bignum::core::normalized_len(&prod).max(1);
    prod.truncate(keep);
    prod
}

/// Drive the daemon with one open-loop run: submit on the arrival
/// schedule (never waiting for completions — when the driver falls
/// behind it submits immediately to catch up, preserving offered
/// count), collect replies on a separate thread, and report merged
/// counter deltas. Errors on a product-verification mismatch.
pub fn run_open_loop(daemon: &Daemon, load: &OpenLoop) -> Result<ServingReport> {
    let schedule = load.arrivals.clone().schedule(load.jobs);
    let before = snapshot(daemon);
    let base = daemon.base();
    let collect = load.collect;
    let (tx, rx) = channel::<(u64, Option<Vec<u32>>, Receiver<Result<JobResult>>)>();
    let stop_probation = AtomicBool::new(false);
    let t0 = Instant::now();
    let (mut lat_us, results, verify_err) = std::thread::scope(|s| {
        // Probation pump: periodically walk quarantined processors back
        // into service while the run is live. With an empty quarantine
        // ledger `probe_quarantined` returns without touching the
        // machine, so fault-free runs execute zero probe machinery.
        let prober = s.spawn(|| {
            while !stop_probation.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(10));
                daemon.scheduler().probe_quarantined();
            }
        });
        let collector = s.spawn(move || {
            let mut lat = Vec::new();
            let mut out = Vec::new();
            let mut verr: Option<String> = None;
            while let Ok((i, want, job_rx)) = rx.recv() {
                match job_rx.recv() {
                    Ok(Ok(res)) => {
                        daemon.note_service(res.wall);
                        lat.push(res.wall.as_micros() as u64);
                        if let Some(w) = want {
                            if res.product != w && verr.is_none() {
                                verr = Some(format!(
                                    "request {i} (job {}): product mismatch vs school reference",
                                    res.id
                                ));
                            }
                        }
                        if collect {
                            out.push(res);
                        }
                    }
                    // Failed or deadline-expired: counted via scheduler
                    // stats; the reply error itself is not a run error.
                    Ok(Err(_)) => {}
                    Err(_) => {}
                }
            }
            (lat, out, verr)
        });
        for (i, offset) in schedule.iter().enumerate() {
            let target = t0 + *offset;
            let now = Instant::now();
            if target > now {
                std::thread::sleep(target - now);
            }
            let req = load.workload.request(i as u64);
            let want = load
                .verify
                .then(|| reference_product(&req.a, &req.b, base));
            if let Submission::Admitted(job_rx) = daemon.submit(req) {
                tx.send((i as u64, want, job_rx))
                    .expect("collector outlives the driver");
            }
        }
        drop(tx);
        let joined = collector.join().expect("collector thread panicked");
        stop_probation.store(true, Ordering::Relaxed);
        prober.join().expect("probation thread panicked");
        joined
    });
    let wall = t0.elapsed();
    if let Some(msg) = verify_err {
        bail!("open-loop verification failed: {msg}");
    }
    let after = snapshot(daemon);
    lat_us.sort_unstable();
    Ok(ServingReport {
        offered: after.offered - before.offered,
        completed: after.completed - before.completed,
        failed: after.failed - before.failed,
        shed_slo: after.shed_slo - before.shed_slo,
        shed_queue_full: after.shed_queue_full - before.shed_queue_full,
        shed_expired: after.shed_expired - before.shed_expired,
        rejected_unfittable: after.rejected_unfittable - before.rejected_unfittable,
        retries: after.retries - before.retries,
        quarantined: after.quarantined - before.quarantined,
        dequarantined: after.dequarantined - before.dequarantined,
        probes_sent: after.probes_sent - before.probes_sent,
        respawns: after.respawns - before.respawns,
        wall,
        lat_us,
        results,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::leaf::{leaf_ref, SchoolLeaf};

    #[test]
    fn arrival_replay_is_deterministic() {
        let s1 = ArrivalGen::poisson(7, 800.0).unwrap().schedule(64);
        let s2 = ArrivalGen::poisson(7, 800.0).unwrap().schedule(64);
        assert_eq!(s1, s2, "same seed must replay the same schedule");
        let s3 = ArrivalGen::poisson(8, 800.0).unwrap().schedule(64);
        assert_ne!(s1, s3, "different seeds must differ");
        // Mean-gap sanity: 4096 arrivals at 800/s land near 5.12 s.
        let last = *ArrivalGen::poisson(9, 800.0)
            .unwrap()
            .schedule(4096)
            .last()
            .unwrap();
        assert!(
            (2.5..10.0).contains(&last.as_secs_f64()),
            "poisson mean off: 4096 arrivals at 800/s ended at {last:?}"
        );
    }

    #[test]
    fn bursty_schedule_shows_idle_gaps() {
        let idle = Duration::from_millis(50);
        let sched = ArrivalGen::bursty(7, 1000.0, 8, idle).unwrap().schedule(24);
        assert_eq!(
            sched,
            ArrivalGen::bursty(7, 1000.0, 8, idle).unwrap().schedule(24),
            "bursty replay"
        );
        // Arrival 8 opens the second burst: its gap carries the idle.
        let burst_gap = sched[8] - sched[7];
        assert!(burst_gap >= idle, "inter-burst gap {burst_gap:?} < idle");
        // Intra-burst gaps at 1000/s are far below the idle gap.
        let intra = sched[7] - sched[6];
        assert!(intra < idle, "intra-burst gap {intra:?} not < idle");
    }

    #[test]
    fn request_frame_round_trips_and_rejects_corruption() {
        let req = Request {
            a: vec![1, 2, 3],
            b: vec![4, 5],
            procs: 12,
            algo: Some(Algorithm::Copk),
            mem_cap: Some(4096),
            deadline: Some(Duration::from_millis(250)),
            exec_mode: ExecPolicy::Bfs,
        };
        let buf = req.encode();
        assert_eq!(Request::decode(&buf).unwrap(), req);
        // None fields round-trip through the MAX sentinels.
        let bare = Request {
            algo: None,
            mem_cap: None,
            deadline: None,
            ..req.clone()
        };
        assert_eq!(Request::decode(&bare.encode()).unwrap(), bare);
        // Every exec-mode policy survives the previously-reserved u16.
        for pol in [ExecPolicy::Dfs, ExecPolicy::Auto, ExecPolicy::Bfs] {
            let r = Request {
                exec_mode: pol,
                ..req.clone()
            };
            assert_eq!(Request::decode(&r.encode()).unwrap().exec_mode, pol);
        }
        // Corrupt magic, truncation, and trailing garbage all reject.
        let mut bad = buf.clone();
        bad[0] ^= 0xFF;
        assert!(Request::decode(&bad).is_err(), "bad magic");
        assert!(Request::decode(&buf[..10]).is_err(), "truncated");
        let mut long = buf.clone();
        long.push(0);
        assert!(Request::decode(&long).is_err(), "trailing garbage");
        // An unknown exec-mode tag (the reserved u16 at offset 6)
        // rejects rather than silently downgrading.
        let mut badmode = buf.clone();
        badmode[6] = 0xFF;
        let err = Request::decode(&badmode).unwrap_err().to_string();
        assert!(err.contains("exec-mode"), "want exec-mode error, got: {err}");
    }

    #[test]
    fn slo_estimate_sheds_before_queueing() {
        // A pessimistic service EWMA (60 s/job) plus one occupied
        // runner makes the estimate dwarf any deadline: the deadlined
        // submission must shed synchronously, before queueing.
        let daemon = Daemon::start(
            DaemonConfig {
                sched: SchedulerConfig {
                    procs: 4,
                    runners: 1,
                    ..Default::default()
                },
                init_service_us: 60_000_000,
                ..Default::default()
            },
            leaf_ref(SchoolLeaf),
        )
        .unwrap();
        // Occupy the runner with a big no-deadline job (no deadline →
        // the SLO rung never sheds it).
        let wl = Workload {
            n: 4096,
            ..Workload::default()
        };
        let Submission::Admitted(rx) = daemon.submit(wl.request(0)) else {
            panic!("no-deadline job must be admitted");
        };
        let mut tight = wl.request(1);
        tight.deadline = Some(Duration::from_millis(10));
        match daemon.submit(tight) {
            Submission::Shed { reason, error } => {
                assert_eq!(reason, ShedReason::SloEstimate);
                assert!(error.to_string().contains("estimated queue delay"));
            }
            Submission::Admitted(_) => panic!("estimate rung must shed"),
        }
        rx.recv().unwrap().unwrap();
        assert_eq!(daemon.stats.offered.load(Ordering::Relaxed), 2);
        assert_eq!(daemon.stats.admitted.load(Ordering::Relaxed), 1);
        assert_eq!(daemon.stats.shed_slo.load(Ordering::Relaxed), 1);
        daemon.shutdown().unwrap();
    }

    #[test]
    fn scheduler_rejections_map_to_shed_reasons() {
        // Queue bound → QueueFull.
        let daemon = Daemon::start(
            DaemonConfig {
                sched: SchedulerConfig {
                    max_queue: 0,
                    ..Default::default()
                },
                ..Default::default()
            },
            leaf_ref(SchoolLeaf),
        )
        .unwrap();
        let wl = Workload {
            n: 16,
            ..Workload::default()
        };
        let Submission::Shed { reason, .. } = daemon.submit(wl.request(0)) else {
            panic!("max_queue = 0 must shed");
        };
        assert_eq!(reason, ShedReason::QueueFull);
        assert_eq!(daemon.stats.shed_queue_full.load(Ordering::Relaxed), 1);
        daemon.shutdown().unwrap();

        // Machine too small → Unfittable; own cap binding → JobCap.
        let daemon = Daemon::start(
            DaemonConfig {
                sched: SchedulerConfig {
                    procs: 16,
                    ..Default::default()
                },
                ..Default::default()
            },
            leaf_ref(SchoolLeaf),
        )
        .unwrap();
        let mut wide = wl.request(1);
        wide.procs = 64;
        let Submission::Shed { reason, .. } = daemon.submit(wide) else {
            panic!("64-proc job on a 16-proc machine must reject");
        };
        assert_eq!(reason, ShedReason::Unfittable);
        let mut capped = Workload {
            n: 1024,
            ..Workload::default()
        }
        .request(2);
        capped.mem_cap = Some(64);
        let Submission::Shed { reason, .. } = daemon.submit(capped) else {
            panic!("64-word own cap at n = 1024 must reject");
        };
        assert_eq!(reason, ShedReason::JobCap);
        assert_eq!(daemon.stats.rejected_unfittable.load(Ordering::Relaxed), 2);
        daemon.shutdown().unwrap();
    }

    #[test]
    fn open_loop_accounting_balances() {
        let daemon = Daemon::start(
            DaemonConfig {
                sched: SchedulerConfig {
                    procs: 8,
                    runners: 2,
                    ..Default::default()
                },
                ..Default::default()
            },
            leaf_ref(SchoolLeaf),
        )
        .unwrap();
        let load = OpenLoop {
            arrivals: ArrivalGen::poisson(3, 50_000.0).unwrap(),
            jobs: 16,
            workload: Workload {
                n: 64,
                ..Workload::default()
            },
            verify: true,
            collect: true,
        };
        let rep = run_open_loop(&daemon, &load).unwrap();
        assert_eq!(rep.offered, 16);
        assert_eq!(
            rep.completed + rep.failed + rep.shed_total() + rep.rejected_unfittable,
            rep.offered,
            "every offered job must be accounted exactly once"
        );
        // No deadline, queue 1024, fitting jobs: all complete.
        assert_eq!(rep.completed, 16);
        assert_eq!(rep.lat_us.len(), 16);
        assert_eq!(rep.results.len(), 16);
        assert!(rep.summary().contains("p50="), "got: {}", rep.summary());
        assert!(rep.check_shed_budget(0.0).is_ok());
        daemon.shutdown().unwrap();
    }

    #[test]
    fn batch_lane_coalesces_small_jobs_and_balances() {
        // Threshold above the workload width: every submission routes
        // through the batch lane, never touching the scheduler queue.
        let daemon = Daemon::start(
            DaemonConfig {
                sched: SchedulerConfig {
                    procs: 4,
                    runners: 1,
                    ..Default::default()
                },
                batch_threshold: 64,
                ..Default::default()
            },
            leaf_ref(SchoolLeaf),
        )
        .unwrap();
        let load = OpenLoop {
            arrivals: ArrivalGen::poisson(11, 50_000.0).unwrap(),
            jobs: 24,
            workload: Workload {
                n: 32,
                ..Workload::default()
            },
            verify: true,
            collect: true,
        };
        let rep = run_open_loop(&daemon, &load).unwrap();
        assert_eq!(rep.offered, 24);
        assert_eq!(
            rep.completed + rep.failed + rep.shed_total() + rep.rejected_unfittable,
            rep.offered,
            "batched jobs must fold into the same accounting identity"
        );
        assert_eq!(rep.completed, 24);
        assert_eq!(
            daemon.stats.batched_completed.load(Ordering::Relaxed),
            24,
            "all jobs sit under the threshold, so all must batch"
        );
        assert_eq!(
            daemon.scheduler().stats.completed.load(Ordering::Relaxed),
            0,
            "the scheduler must never see a batched job"
        );
        // Batched results bypass the machine model: zero cost triple.
        for res in &rep.results {
            assert_eq!(res.cost, Clock::default());
            assert_eq!(res.mem_peak, 0);
            assert_eq!(res.exec_mode, ExecMode::Dfs);
        }
        // Above-threshold jobs still take the scheduler path.
        let big = Workload {
            n: 128,
            ..Workload::default()
        };
        let Submission::Admitted(rx) = daemon.submit(big.request(99)) else {
            panic!("above-threshold job must take the scheduler path");
        };
        let res = rx.recv().unwrap().unwrap();
        assert!(res.cost.ops > 0, "scheduler path must charge real cost");
        assert_eq!(daemon.stats.batched_completed.load(Ordering::Relaxed), 24);
        daemon.shutdown().unwrap();
    }
}

//! Job types and input normalization.

use crate::algorithms::{Algorithm, ExecMode, ExecPolicy};
use crate::config::EngineKind;
use crate::sim::{Clock, ProcId, TopologyKind};
use crate::util::{copk_bfs_levels, is_copk_procs, next_pow2};
use std::time::Duration;

/// A multiplication request. Operand digits are LSB-first in the
/// machine base (2^16 by default); widths may be arbitrary — the
/// coordinator pads to the algorithm's layout requirements.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub id: u64,
    pub a: Vec<u32>,
    pub b: Vec<u32>,
    /// Simulated processors (4^k for COPSIM, 4·3^i for COPK; 4 fits
    /// both). Defaults to 4.
    pub procs: usize,
    /// Per-processor memory cap in words (None = unbounded → MI mode).
    pub mem_cap: Option<u64>,
    /// Force a scheme; None lets the §7 hybrid dispatcher choose.
    pub algo: Option<Algorithm>,
    /// Execution engine: the deterministic cost-model simulator
    /// (default) or one OS thread per simulated processor.
    pub engine: EngineKind,
    /// Network topology of the job's machine (`--topology` on the
    /// CLI). Per-job on the one-machine-per-job coordinator path; the
    /// sharded scheduler fixes the topology per shared machine instead
    /// (like the engine).
    pub topology: TopologyKind,
    /// Relative deadline, measured from submission. A job still queued
    /// when the budget expires is shed at dequeue instead of run (the
    /// serving daemon's SLO path — see `coordinator::daemon`). `None`
    /// (the default) never expires.
    pub deadline: Option<Duration>,
    /// Execution-mode policy (`--exec-mode=`): `Dfs` (the default, the
    /// paper schedule — bit-identical to pre-mode builds), `Auto`
    /// (spend surplus shard memory on the BFS variants whenever
    /// `theory::best_mode` predicts a BW win), or `Bfs` (request BFS;
    /// the scheduler rejects it distinctly when no level fits).
    pub exec_mode: ExecPolicy,
}

impl JobSpec {
    pub fn new(id: u64, a: Vec<u32>, b: Vec<u32>) -> Self {
        JobSpec {
            id,
            a,
            b,
            procs: 4,
            mem_cap: None,
            algo: None,
            engine: EngineKind::Sim,
            topology: TopologyKind::FullyConnected,
            deadline: None,
            exec_mode: ExecPolicy::Dfs,
        }
    }

    /// Padded working width: `n = w·P` with `w` a power of two large
    /// enough for both operands, so every divisibility constraint of
    /// both schemes (halving in DFS, 3/2-scaling in COPK's BFS — powers
    /// of two are divisible by `2^levels` whenever `w >= 2^levels`)
    /// holds.
    pub fn padded_width(&self) -> usize {
        self.padded_width_for(self.procs)
    }

    /// [`JobSpec::padded_width`] for an explicit processor count: the
    /// scheduler may run a job on a shard larger than `self.procs` (to
    /// meet its `theory::*_mem` footprint), and the layout constraints
    /// depend on the count that actually runs.
    pub fn padded_width_for(&self, p: usize) -> usize {
        let len = self.a.len().max(self.b.len()).max(1);
        let mut w = next_pow2(len.div_ceil(p) as u64) as usize;
        if is_copk_procs(p as u64) {
            let lv = copk_bfs_levels(p as u64);
            while (w as u64) < (1u64 << lv) {
                w *= 2;
            }
        }
        w * p
    }
}

/// A completed multiplication.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub id: u64,
    /// Product digits, LSB-first, trimmed of leading zeros.
    pub product: Vec<u32>,
    /// Scheme that ran.
    pub algo: Algorithm,
    /// Execution mode the run resolved to (`Dfs`, or `Bfs { levels }`
    /// when the policy and the machine's memory allowed it).
    pub exec_mode: ExecMode,
    /// Engine that executed the machine model.
    pub engine: EngineKind,
    /// Critical-path cost (identical across engines by construction).
    pub cost: Clock,
    /// Peak per-processor memory words. For sharded execution this is
    /// the shard's high-water mark over the shared machine's lifetime,
    /// which may include earlier jobs that ran on the same shard.
    pub mem_peak: u64,
    /// Host wallclock for the whole job, submission to completion
    /// (queue and shard waits included for scheduler jobs).
    pub wall: Duration,
    /// Processors the job ran on: `None` for a dedicated per-job
    /// machine (the [`super::Coordinator`] path), the shard's ids for
    /// sharded execution (the [`super::Scheduler`] path).
    pub shard: Option<Vec<ProcId>>,
    /// How many executions it took (1 = first try; >1 means earlier
    /// attempts failed and the scheduler requeued the job).
    pub attempts: u32,
    /// Injected faults that hit the job's shard during the *successful*
    /// attempt without killing it (stalls, duplicated messages). Zero
    /// means the reported cost triple is bit-identical to a fault-free
    /// dedicated run — the invariant the chaos suite asserts.
    pub faults_survived: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn padding_rules() {
        let j = JobSpec {
            procs: 16,
            ..JobSpec::new(0, vec![1; 100], vec![1; 90])
        };
        let n = j.padded_width();
        assert_eq!(n % 16, 0);
        assert!(n >= 100);
        assert!((n / 16).is_power_of_two());

        // COPK shape: w must also cover 2^levels.
        let j = JobSpec {
            procs: 108, // 4·3^3 -> levels = 3
            ..JobSpec::new(1, vec![1; 10], vec![1; 10])
        };
        let n = j.padded_width();
        assert_eq!(n % 108, 0);
        assert!((n / 108) >= 8);

        // Explicit-count variant: a larger shard re-derives the layout.
        let j = JobSpec::new(2, vec![1; 100], vec![1; 90]);
        assert_eq!(j.padded_width(), j.padded_width_for(j.procs));
        let n = j.padded_width_for(16);
        assert_eq!(n % 16, 0);
        assert!((n / 16).is_power_of_two());
    }
}

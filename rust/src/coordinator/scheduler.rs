//! Sharded multi-job scheduling over one shared machine.
//!
//! The paper's cost bounds are per-multiplication; a serving system runs
//! *many* multiplications at once. Instead of building one machine per
//! job (the [`super::Coordinator`] path), the scheduler owns a single
//! `P`-processor machine — either execution engine — and carves it into
//! **shards**: disjoint [`Seq`] sub-ranges sized so each job's
//! `theory::*_mem` footprint fits the per-processor capacity `M`. Jobs
//! stream through a queue; runners acquire shards from a free pool, run
//! their job's scheme on the shard, and release the processors for the
//! next job to steal. This mirrors the resource-partitioning move of
//! communication-optimal Strassen's BFS/DFS processor splitting
//! (Ballard et al.), applied across *independent* jobs rather than
//! recursive subproblems.
//!
//! ## Exact per-job cost accounting on a shared machine
//!
//! Logical clocks evolve in a max-plus algebra: operations add constants
//! to one processor's clock, and message delivery / barriers join clocks
//! by component-wise max. Both operations commute with adding a uniform
//! constant to every clock involved. The scheduler therefore barriers a
//! shard to a **uniform baseline** `B` at acquisition; the job's clocks
//! then evolve exactly as on a fresh machine shifted by `B`, and the
//! reported cost triple `join(end clocks).since(B)` is *bit-identical*
//! to running the job alone. `tests/engine_differential.rs` asserts
//! this against single-job reference runs on both engines.
//!
//! ## Concurrency model
//!
//! The shared machine sits behind a mutex taken per [`MachineApi`]
//! call. Worker threads of the threaded engine never take that mutex,
//! so a runner blocking inside `read`/`local` (waiting for a worker to
//! drain its queue) cannot deadlock: worker progress needs only its own
//! command queue and its peers' — never the host lock. Shards are
//! disjoint, so jobs exchange no messages and share no barrier, and the
//! mutex-acquisition order provides the single global program order the
//! threaded engine's no-deadlock argument requires.
//!
//! ## Admission control
//!
//! `submit` rejects immediately when the queue is full
//! (`max_queue`), when no processor-count shape the job's scheme
//! accepts fits the machine, or when even the largest shard leaves the
//! job's theory memory footprint above `M`. A job carrying its *own*
//! `JobSpec::mem_cap` is additionally rejected when no shape fits that
//! tighter bound — with a distinct error so callers can tell "this job
//! asked for less memory than it needs" from "this machine is too
//! small" ([`try_submit`](Scheduler::try_submit) exposes the
//! distinction as a typed [`RejectKind`]; `submit` flattens it to the
//! error message). A job that fails mid-run has its shard purged
//! (every resident slot dropped) before the processors return to the
//! pool, so one bad job cannot poison the machine for its successors.
//!
//! Jobs may also carry a relative [`JobSpec::deadline`]: a job still
//! queued when its budget expires is **shed at dequeue** — counted in
//! `SchedulerStats::shed_expired`, replied to with an error, never run.
//! Running jobs are not preempted (a shard mid-multiplication cannot be
//! safely unwound), so the deadline bounds *queue wait*, which is the
//! unbounded quantity under open-loop load. The serving daemon
//! ([`super::daemon`]) layers SLO-aware early shedding on top of these
//! hooks.
//!
//! ## Fault recovery
//!
//! Failures — injected by a [`FaultyMachine`] plan (`cfg.fault`), a
//! dead worker thread of the threaded engine, or any mid-run error —
//! are **per-job** events:
//!
//! * the failed attempt's shard is healed (crashed processors restart)
//!   and purged, then returned to the pool;
//! * the job is retried up to `cfg.max_attempts` times, with
//!   **exponential shard-size backoff**: each retry requests the next
//!   shape up the `plan_shard` ladder (4^k / 4·3^i are geometric), so a
//!   retried job lands with a *smaller* per-processor footprint — the
//!   re-admission ladder the MI-mode memory requirements provide;
//! * the final attempt runs with injection suppressed on its shard (the
//!   safe-mode escape hatch), so a job admitted under an injection plan
//!   always completes unless the hardware itself is gone;
//! * processors that kill `cfg.quarantine_after` consecutive jobs are
//!   quarantined — removed from the free pool — so a genuinely dead
//!   worker stops eating retry budgets. Jobs wider than the surviving
//!   capacity fail with a "machine degraded" error instead of waiting
//!   forever.
//!
//! Quarantine is **probation**, not a death sentence
//! ([`Scheduler::probe_quarantined`]): each cycle health-probes every
//! quarantined processor with a tiny canary multiply on a dedicated
//! one-processor shard, and `cfg.probation_successes` consecutive
//! passes re-admit the processor to the free pool with its strike
//! ledger reset. Probes run with injection suppressed (they judge the
//! machine, not the fault plan — the same escape hatch as the
//! safe-mode final attempt) and verify the canary's product, so a
//! genuinely dead worker keeps failing them. On the socket engine a
//! probation cycle first respawns dead worker-process groups
//! ([`crate::sim::SocketMachine::respawn_group`]) so the canaries have
//! live processes to land on. Canaries are **cost-invisible to
//! clients**: a probe touches only its own quarantined processor's
//! clock, and every client job barriers its shard to a uniform
//! baseline at acquisition — max-plus clock evolution commutes with
//! the uniform shift, so client cost triples are bit-identical whether
//! or not probes ever ran (asserted in `tests/chaos_soak.rs`). With an
//! empty quarantine ledger the cycle is a no-op, so zero-fault runs
//! never execute probe machinery at all.
//!
//! Each shard's fault-plan op indices are rewound at acquisition
//! ([`FaultyMachine::reset_op_index`]), so a job's fault pattern depends
//! on the seed, its shard, and its own operation stream — not on queue
//! history. Jobs whose shard saw **zero** injected faults report cost
//! triples bit-identical to a dedicated fault-free run (asserted in
//! `tests/chaos_soak.rs` and `tests/engine_differential.rs`).

use super::job::{JobResult, JobSpec};
use super::router::execute_on;
use crate::algorithms::copsim::is_pow4;
use crate::algorithms::leaf::LeafRef;
use crate::algorithms::{hybrid, Algorithm, ExecPolicy};
use crate::bignum::{Base, Ops};
use crate::config::EngineKind;
use crate::error::{anyhow, bail, Context, Error, Result};
use crate::sim::{
    Clock, FaultConfig, FaultyMachine, Machine, MachineApi, MachineStats, ProcId, ProcView, Seq,
    Slot, SlotComputation, SocketConfig, SocketMachine, ThreadedMachine, TopologyKind, TopologyRef,
};
use crate::theory::{self, TimeModel};
use crate::util::is_copk_procs;
use std::collections::HashMap;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------- shards

/// Per-processor memory words the theory requires to run an `n`-digit
/// product on `p` processors under the job's scheme: the MI-mode
/// memory-requirement expressions (Theorem 11's `12n/√P`, Theorem 14's
/// `10n/P^(log₃2)`). The hybrid dispatcher may choose either scheme, so
/// `None` takes the max of both.
pub fn theory_mem_footprint(n: u64, p: u64, algo: Option<Algorithm>) -> u64 {
    match algo {
        Some(Algorithm::Copsim) => theory::thm11_copsim_mi_mem(n, p),
        Some(Algorithm::Copk) => theory::thm14_copk_mi_mem(n, p),
        None => theory::thm11_copsim_mi_mem(n, p).max(theory::thm14_copk_mi_mem(n, p)),
    }
}

/// Processor counts (ascending) the job's scheme can run on, up to the
/// machine size: powers of four for COPSIM, `4·3^i` for COPK, the union
/// for hybrid dispatch.
fn shape_ladder(algo: Option<Algorithm>, total: usize) -> impl Iterator<Item = usize> {
    (1..=total).filter(move |&s| match algo {
        Some(Algorithm::Copsim) => is_pow4(s),
        Some(Algorithm::Copk) => s == 1 || is_copk_procs(s as u64),
        None => is_pow4(s) || is_copk_procs(s as u64),
    })
}

/// Shard sizing: the smallest shape `≥ spec.procs` whose theory memory
/// footprint fits the per-processor cap. Growing the shard *shrinks*
/// the per-processor footprint (the paper's memory requirements fall
/// with `P`), which is what keeps total memory O(n) per job: a job is
/// given exactly as many processors as its footprint demands, no more.
/// Errors when no shard of this machine can satisfy the job.
pub fn plan_shard(spec: &JobSpec, total_procs: usize, mem_cap: u64) -> Result<usize> {
    for p in shape_ladder(spec.algo, total_procs) {
        if p < spec.procs {
            continue;
        }
        let n = spec.padded_width_for(p) as u64;
        if theory_mem_footprint(n, p as u64, spec.algo) <= mem_cap {
            return Ok(p);
        }
    }
    bail!(
        "job {} not admissible: no processor shape in [{}..{}] fits its \
         memory footprint under M = {} words/proc",
        spec.id,
        spec.procs,
        total_procs,
        mem_cap
    )
}

// ---------------------------------------------------- the shared machine

/// The engine actually executing the shared machine. Every variant sits
/// behind a [`FaultyMachine`] wrapper; without a fault plan the wrapper
/// is a transparent delegate, so the fault-free path is unchanged.
enum EngineMachine {
    Sim(FaultyMachine<Machine>),
    Threads(FaultyMachine<ThreadedMachine>),
    Sockets(FaultyMachine<SocketMachine>),
}

/// Dispatch one expression over whichever engine backs the guard.
/// Arms call through `MachineApi` explicitly so `Machine`'s inherent
/// methods (different signatures) cannot shadow the trait surface.
macro_rules! on_engine {
    ($g:expr, $m:ident => $e:expr) => {
        match &mut *$g {
            EngineMachine::Sim($m) => $e,
            EngineMachine::Threads($m) => $e,
            EngineMachine::Sockets($m) => $e,
        }
    };
}

/// An in-flight payload reply from a two-phase call on a real-execution
/// engine: the threaded engine ships the arena's shared reference over
/// a channel, the socket engine decodes an owned copy off the wire. The
/// socket wait is bounded by the machine's reply timeout (captured
/// while the lock was held) so a worker process that dies in the window
/// between the liveness check and the reply surfaces as an error, never
/// a hang.
enum PendingPayload {
    Threads(Receiver<Arc<Vec<u32>>>),
    Sockets(Receiver<Vec<u32>>, Duration),
}

impl PendingPayload {
    fn wait(self, p: ProcId, what: &str) -> Result<Vec<u32>> {
        match self {
            PendingPayload::Threads(rx) => rx
                .recv()
                .map(crate::sim::payload_into_vec)
                .map_err(|_| anyhow!("processor {p}: worker thread died during {what}")),
            PendingPayload::Sockets(rx, timeout) => rx
                .recv_timeout(timeout)
                .map_err(|_| anyhow!("processor {p}: worker process died during {what}")),
        }
    }

    /// Append the payload to `buf` without the extra owned conversion
    /// `wait` would pay on the threaded engine (the arena still holds
    /// its shared reference there, so `payload_into_vec` would clone
    /// the digits only for us to copy them again).
    fn wait_into(self, p: ProcId, buf: &mut Vec<u32>) -> Result<()> {
        match self {
            PendingPayload::Threads(rx) => {
                let shared = rx
                    .recv()
                    .map_err(|_| anyhow!("processor {p}: worker thread died during read"))?;
                buf.extend_from_slice(&shared);
            }
            PendingPayload::Sockets(rx, timeout) => {
                let owned = rx
                    .recv_timeout(timeout)
                    .map_err(|_| anyhow!("processor {p}: worker process died during read"))?;
                buf.extend_from_slice(&owned);
            }
        }
        Ok(())
    }
}

/// Per-job memory ledger: mirrors the shared machine's per-processor
/// slot accounting for ONE job's slots, so a job carrying its own
/// `JobSpec::mem_cap` (tighter than the machine-wide cap) is enforced
/// *mid-run* on the shared machine — not just at admission. This is
/// what makes the memory-adaptive execution modes safe on shards: a
/// BFS schedule's replicated operands charge this ledger, so a mode
/// that would blow the job's cap errors (and retries up the shard
/// ladder) instead of silently borrowing machine-wide headroom.
///
/// Slot sizes are tracked exactly for every op the algorithms issue;
/// the one estimate is `compute_slot` output, charged as the sum of
/// its *consumed* inputs — exact for the only algorithm-level caller
/// (`leaf_multiply`: inputs `2w`, output `2w`, consume = true).
struct JobLedger {
    /// The job's effective per-processor cap in words.
    cap: u64,
    /// Live slot sizes, keyed by owning processor and slot id.
    sizes: HashMap<(ProcId, Slot), u64>,
    /// Words currently resident per shard processor.
    used: HashMap<ProcId, u64>,
    /// High-water mark of `used` over the job, max across processors.
    peak: u64,
}

impl JobLedger {
    fn new(cap: u64) -> Self {
        JobLedger {
            cap,
            sizes: HashMap::new(),
            used: HashMap::new(),
            peak: 0,
        }
    }

    /// Would `add` more words on `p` exceed the job's own cap?
    fn check(&self, p: ProcId, add: u64) -> Result<()> {
        let used = self.used.get(&p).copied().unwrap_or(0);
        if used.saturating_add(add) > self.cap {
            bail!(
                "processor {p}: job mem_cap exceeded ({used} + {add} > {} words \
                 — the job's own cap; the machine-wide ledger may have room)",
                self.cap
            );
        }
        Ok(())
    }

    fn charge(&mut self, p: ProcId, slot: Slot, size: u64) {
        self.sizes.insert((p, slot), size);
        let u = self.used.entry(p).or_insert(0);
        *u += size;
        self.peak = self.peak.max(*u);
    }

    fn release(&mut self, p: ProcId, slot: Slot) {
        let size = self.sizes.remove(&(p, slot)).unwrap_or(0);
        if let Some(u) = self.used.get_mut(&p) {
            *u = u.saturating_sub(size);
        }
    }

    fn size_of(&self, p: ProcId, slot: Slot) -> u64 {
        self.sizes.get(&(p, slot)).copied().unwrap_or(0)
    }

    fn purge(&mut self, p: ProcId) {
        self.sizes.retain(|&(q, _), _| q != p);
        self.used.insert(p, 0);
    }
}

/// A job's handle onto the shared machine: every [`MachineApi`] call
/// locks the machine for exactly that call. Runners hold one each; the
/// shard discipline (disjoint `Seq`s) is what keeps jobs independent,
/// not the lock — the lock only serializes the command stream, giving
/// the threaded engine its consistent global program order.
struct ShardView {
    machine: Arc<Mutex<EngineMachine>>,
    /// Present exactly when the job's own `mem_cap` is *tighter* than
    /// the machine-wide cap; `None` leaves every call a transparent
    /// forward (the pre-ledger behavior, bit for bit). When present,
    /// [`MachineApi::mem_cap`] reports the job's cap — so the
    /// algorithms' MI gates and the execution-mode resolution see the
    /// same memory bound a dedicated machine built at the job's cap
    /// would report.
    ledger: Option<JobLedger>,
}

impl ShardView {
    fn lock(&self) -> MutexGuard<'_, EngineMachine> {
        self.machine.lock().unwrap()
    }
}

impl MachineApi for ShardView {
    fn n_procs(&self) -> usize {
        let mut g = self.lock();
        on_engine!(g, m => MachineApi::n_procs(m))
    }
    fn mem_cap(&self) -> u64 {
        if let Some(l) = &self.ledger {
            return l.cap;
        }
        let mut g = self.lock();
        on_engine!(g, m => MachineApi::mem_cap(m))
    }
    fn base(&self) -> Base {
        let mut g = self.lock();
        on_engine!(g, m => MachineApi::base(m))
    }
    fn topology(&self) -> TopologyRef {
        let mut g = self.lock();
        on_engine!(g, m => MachineApi::topology(m))
    }

    fn alloc(&mut self, p: ProcId, data: Vec<u32>) -> Result<Slot> {
        let size = data.len() as u64;
        if let Some(l) = &self.ledger {
            l.check(p, size)?;
        }
        let slot = {
            let mut g = self.lock();
            on_engine!(g, m => MachineApi::alloc(m, p, data))
        }?;
        if let Some(l) = &mut self.ledger {
            l.charge(p, slot, size);
        }
        Ok(slot)
    }
    fn free(&mut self, p: ProcId, slot: Slot) {
        if let Some(l) = &mut self.ledger {
            l.release(p, slot);
        }
        let mut g = self.lock();
        on_engine!(g, m => MachineApi::free(m, p, slot))
    }
    fn read(&self, p: ProcId, slot: Slot) -> Result<Vec<u32>> {
        // Two-phase on the real-execution engines: enqueue under the
        // lock, await after releasing it — otherwise every concurrent
        // job serializes behind this worker's queue drain. Program
        // order is fixed at enqueue time, so the result is identical.
        // A dead worker surfaces as a per-call error (failing this job
        // only), never as a panic that would poison the shared machine.
        let pending = {
            let mut g = self.lock();
            match &mut *g {
                EngineMachine::Sim(m) => return MachineApi::read(m, p, slot),
                EngineMachine::Threads(m) => {
                    m.check_alive(p)?;
                    PendingPayload::Threads(m.inner().read_request(p, slot))
                }
                EngineMachine::Sockets(m) => {
                    m.check_alive(p)?;
                    let timeout = m.inner().reply_timeout();
                    PendingPayload::Sockets(m.inner().read_request(p, slot), timeout)
                }
            }
        };
        pending.wait(p, "read")
    }
    fn read_into(&self, p: ProcId, slot: Slot, buf: &mut Vec<u32>) -> Result<()> {
        // Two-phase as in `read`. On the threaded engine this extends
        // straight from the shared payload: the arena still holds its
        // reference, so converting to an owned Vec first would clone
        // the digits only to copy them again — this path (the
        // collectives' assembly loops on sharded jobs) pays exactly one
        // copy instead. The socket payload is already an owned wire
        // copy, so the generic append is the same cost.
        let pending = {
            let mut g = self.lock();
            match &mut *g {
                EngineMachine::Sim(m) => return MachineApi::read_into(m, p, slot, buf),
                EngineMachine::Threads(m) => {
                    m.check_alive(p)?;
                    PendingPayload::Threads(m.inner().read_request(p, slot))
                }
                EngineMachine::Sockets(m) => {
                    m.check_alive(p)?;
                    let timeout = m.inner().reply_timeout();
                    PendingPayload::Sockets(m.inner().read_request(p, slot), timeout)
                }
            }
        };
        pending.wait_into(p, buf)
    }
    fn replace(&mut self, p: ProcId, slot: Slot, data: Vec<u32>) -> Result<()> {
        let size = data.len() as u64;
        if let Some(l) = &self.ledger {
            l.check(p, size.saturating_sub(l.size_of(p, slot)))?;
        }
        {
            let mut g = self.lock();
            on_engine!(g, m => MachineApi::replace(m, p, slot, data))
        }?;
        if let Some(l) = &mut self.ledger {
            l.release(p, slot);
            l.charge(p, slot, size);
        }
        Ok(())
    }

    fn compute(&mut self, p: ProcId, ops: u64) {
        let mut g = self.lock();
        on_engine!(g, m => MachineApi::compute(m, p, ops))
    }
    fn local<R, F>(&mut self, p: ProcId, f: F) -> Result<R>
    where
        R: Send + 'static,
        F: FnOnce(&Base, &mut Ops) -> R + Send + 'static,
    {
        // Two-phase, as in `read`. The socket engine runs the closure
        // host-side (it cannot cross a process boundary) and its
        // worker acknowledges the op charge, so the same enqueue/await
        // split applies.
        let (pending, timeout) = {
            let mut g = self.lock();
            match &mut *g {
                EngineMachine::Sim(m) => return MachineApi::local(m, p, f),
                EngineMachine::Threads(m) => {
                    m.precheck_local(p)?;
                    (m.inner().local_request::<R, F>(p, f), None)
                }
                EngineMachine::Sockets(m) => {
                    m.precheck_local(p)?;
                    let timeout = m.inner().reply_timeout();
                    (m.inner().local_request::<R, F>(p, f), Some(timeout))
                }
            }
        };
        let out = match timeout {
            None => pending
                .recv()
                .map_err(|_| anyhow!("processor {p}: worker thread died during local"))?,
            Some(t) => pending
                .recv_timeout(t)
                .map_err(|_| anyhow!("processor {p}: worker process died during local"))?,
        };
        Ok(*out.downcast::<R>().expect("local closure result type"))
    }
    fn compute_slot(
        &mut self,
        p: ProcId,
        inputs: &[Slot],
        consume: bool,
        f: SlotComputation,
    ) -> Result<Slot> {
        // Output charged as the sum of the inputs (exact for the leaf
        // multiplier, the only algorithm-level caller); a consuming
        // call frees as the output materializes, so the net check is
        // the difference.
        let out_est = if let Some(l) = &self.ledger {
            let sum: u64 = inputs.iter().map(|&s| l.size_of(p, s)).sum();
            l.check(p, if consume { 0 } else { sum })?;
            sum
        } else {
            0
        };
        let slot = {
            let mut g = self.lock();
            on_engine!(g, m => MachineApi::compute_slot(m, p, inputs, consume, f))
        }?;
        if let Some(l) = &mut self.ledger {
            if consume {
                for &s in inputs {
                    l.release(p, s);
                }
            }
            l.charge(p, slot, out_est);
        }
        Ok(slot)
    }

    fn send(&mut self, src: ProcId, dst: ProcId, data: Vec<u32>) -> Result<Slot> {
        let size = data.len() as u64;
        if let Some(l) = &self.ledger {
            l.check(dst, size)?;
        }
        let slot = {
            let mut g = self.lock();
            on_engine!(g, m => MachineApi::send(m, src, dst, data))
        }?;
        if let Some(l) = &mut self.ledger {
            l.charge(dst, slot, size);
        }
        Ok(slot)
    }
    fn send_copy(&mut self, src: ProcId, dst: ProcId, slot: Slot) -> Result<Slot> {
        let size = self.ledger.as_ref().map_or(0, |l| l.size_of(src, slot));
        if let Some(l) = &self.ledger {
            l.check(dst, size)?;
        }
        let out = {
            let mut g = self.lock();
            on_engine!(g, m => MachineApi::send_copy(m, src, dst, slot))
        }?;
        if let Some(l) = &mut self.ledger {
            l.charge(dst, out, size);
        }
        Ok(out)
    }
    fn send_move(&mut self, src: ProcId, dst: ProcId, slot: Slot) -> Result<Slot> {
        let size = self.ledger.as_ref().map_or(0, |l| l.size_of(src, slot));
        if let Some(l) = &self.ledger {
            l.check(dst, size)?;
        }
        let out = {
            let mut g = self.lock();
            on_engine!(g, m => MachineApi::send_move(m, src, dst, slot))
        }?;
        if let Some(l) = &mut self.ledger {
            l.release(src, slot);
            l.charge(dst, out, size);
        }
        Ok(out)
    }
    fn send_range(
        &mut self,
        src: ProcId,
        dst: ProcId,
        slot: Slot,
        range: Range<usize>,
    ) -> Result<Slot> {
        let size = range.len() as u64;
        if let Some(l) = &self.ledger {
            l.check(dst, size)?;
        }
        let out = {
            let mut g = self.lock();
            on_engine!(g, m => MachineApi::send_range(m, src, dst, slot, range))
        }?;
        if let Some(l) = &mut self.ledger {
            l.charge(dst, out, size);
        }
        Ok(out)
    }
    fn barrier(&mut self, procs: &[ProcId]) -> Result<()> {
        let mut g = self.lock();
        on_engine!(g, m => MachineApi::barrier(m, procs))
    }

    fn proc_view(&self, p: ProcId) -> Result<ProcView> {
        // Two-phase, as in `read`.
        let (pending, timeout) = {
            let mut g = self.lock();
            match &mut *g {
                EngineMachine::Sim(m) => return MachineApi::proc_view(m, p),
                EngineMachine::Threads(m) => {
                    m.check_alive(p)?;
                    (m.inner().snapshot_request(p), None)
                }
                EngineMachine::Sockets(m) => {
                    m.check_alive(p)?;
                    let timeout = m.inner().reply_timeout();
                    (m.inner().snapshot_request(p), Some(timeout))
                }
            }
        };
        let s = match timeout {
            None => pending
                .recv()
                .map_err(|_| anyhow!("processor {p}: worker thread died during proc_view"))?,
            Some(t) => pending
                .recv_timeout(t)
                .map_err(|_| anyhow!("processor {p}: worker process died during proc_view"))?,
        };
        Ok(ProcView {
            clock: s.clock,
            mem_used: s.mem_used,
            mem_peak: s.mem_peak,
        })
    }
    fn critical(&self) -> Clock {
        let mut g = self.lock();
        on_engine!(g, m => MachineApi::critical(m))
    }
    fn stats(&self) -> MachineStats {
        let mut g = self.lock();
        on_engine!(g, m => MachineApi::stats(m))
    }
    fn mem_peak_max(&self) -> u64 {
        let mut g = self.lock();
        on_engine!(g, m => MachineApi::mem_peak_max(m))
    }
    fn mem_peak_total(&self) -> u64 {
        let mut g = self.lock();
        on_engine!(g, m => MachineApi::mem_peak_total(m))
    }
    fn mem_used_total(&self) -> u64 {
        let mut g = self.lock();
        on_engine!(g, m => MachineApi::mem_used_total(m))
    }
    fn purge(&mut self, p: ProcId) {
        if let Some(l) = &mut self.ledger {
            l.purge(p);
        }
        let mut g = self.lock();
        on_engine!(g, m => MachineApi::purge(m, p))
    }
    // take_buffer/give_buffer deliberately keep their defaults (plain
    // allocation): routing scratch buffers through the shared machine
    // lock would add cross-shard contention on the collectives' hot
    // assembly path to save a malloc — a bad trade under concurrent
    // runners. The pool still serves every dedicated-machine path.
}

// ------------------------------------------------------------- the pool

/// Free processors of the shared machine plus the running-job count,
/// the FIFO ticket counters (see [`Pool::acquire`]), and the health
/// ledger behind the quarantine policy.
struct PoolState {
    free: Vec<ProcId>,
    /// Processors pulled from service after killing too many jobs in a
    /// row (a genuinely dead worker otherwise eats every retry budget).
    quarantined: Vec<ProcId>,
    running: usize,
    /// Next ticket to hand out.
    next_ticket: u64,
    /// Ticket currently allowed to take processors.
    serving: u64,
    /// Consecutive job-killing failures per processor; any success on
    /// the processor resets it.
    strikes: Vec<u32>,
    /// Consecutive probation-probe passes per processor; reaching
    /// `SchedulerConfig::probation_successes` de-quarantines it.
    probe_streak: Vec<u32>,
}

struct Pool {
    total: usize,
    state: Mutex<PoolState>,
    freed: Condvar,
}

impl Pool {
    fn new(total: usize) -> Self {
        Pool {
            total,
            state: Mutex::new(PoolState {
                free: (0..total).collect(),
                quarantined: Vec::new(),
                running: 0,
                next_ticket: 0,
                serving: 0,
                strikes: vec![0; total],
                probe_streak: vec![0; total],
            }),
            freed: Condvar::new(),
        }
    }

    /// Take `size` free processors, waiting for running jobs to release
    /// theirs if needed (the work-stealing path: freed processors go
    /// straight to the oldest waiter). Acquisition is FIFO-ticketed:
    /// a large job at the head of the line is never starved by
    /// later-arriving small jobs draining every release before it can
    /// accumulate its shard (admission guarantees `size` fits the
    /// machine, so the head always makes progress once running jobs
    /// finish). Errors — instead of waiting forever — when quarantine
    /// has shrunk the live capacity below `size`.
    fn acquire(&self, size: usize, stats: &SchedulerStats) -> Result<Vec<ProcId>> {
        let mut st = self.state.lock().unwrap();
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        let mut waited = false;
        loop {
            if st.serving == ticket {
                let live = self.total - st.quarantined.len();
                if size > live {
                    // Advance the line so jobs that still fit proceed.
                    st.serving += 1;
                    drop(st);
                    self.freed.notify_all();
                    bail!(
                        "machine degraded: shard of {size} requested but only \
                         {live} live processor(s) remain after quarantine"
                    );
                }
                if st.free.len() >= size {
                    break;
                }
            }
            waited = true;
            st = self.freed.wait(st).unwrap();
        }
        // Lowest ids first, for reproducible shard composition.
        st.free.sort_unstable();
        let shard: Vec<ProcId> = st.free.drain(..size).collect();
        st.serving += 1;
        st.running += 1;
        stats.shards_acquired.fetch_add(1, Ordering::Relaxed);
        if waited {
            stats.shards_stolen.fetch_add(1, Ordering::Relaxed);
        }
        stats
            .peak_concurrent
            .fetch_max(st.running as u64, Ordering::Relaxed);
        drop(st);
        // Wake the next ticket (it may already have enough processors).
        self.freed.notify_all();
        Ok(shard)
    }

    /// Return a shard. `failed` updates the strike ledger; processors
    /// reaching `quarantine_after` consecutive kills are quarantined
    /// (never below one live processor) instead of refreed.
    fn release(
        &self,
        shard: Vec<ProcId>,
        failed: bool,
        quarantine_after: u32,
        stats: &SchedulerStats,
    ) {
        let mut st = self.state.lock().unwrap();
        st.running -= 1;
        for p in shard {
            if failed {
                st.strikes[p] = st.strikes[p].saturating_add(1);
                let live = self.total - st.quarantined.len();
                if quarantine_after > 0 && st.strikes[p] >= quarantine_after && live > 1 {
                    st.quarantined.push(p);
                    stats.procs_quarantined.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
            } else {
                st.strikes[p] = 0;
            }
            st.free.push(p);
        }
        drop(st);
        self.freed.notify_all();
    }

    /// Record one probation-probe outcome for a quarantined processor.
    /// `k` consecutive passes de-quarantine it: the strike ledger and
    /// streak reset, the processor rejoins the free pool, and waiters
    /// are woken (a degraded-blocked acquire may now fit). Returns true
    /// when the processor was re-admitted by this call.
    fn record_probe(&self, p: ProcId, ok: bool, k: u32, stats: &SchedulerStats) -> bool {
        let mut st = self.state.lock().unwrap();
        if !st.quarantined.contains(&p) {
            return false; // no longer quarantined — nothing to record
        }
        if !ok {
            st.probe_streak[p] = 0;
            return false;
        }
        st.probe_streak[p] = st.probe_streak[p].saturating_add(1);
        if st.probe_streak[p] < k {
            return false;
        }
        st.quarantined.retain(|&q| q != p);
        st.probe_streak[p] = 0;
        st.strikes[p] = 0;
        st.free.push(p);
        stats.procs_dequarantined.fetch_add(1, Ordering::Relaxed);
        drop(st);
        self.freed.notify_all();
        true
    }
}

// -------------------------------------------------------- the scheduler

/// Scheduler configuration.
#[derive(Clone)]
pub struct SchedulerConfig {
    /// Total simulated processors in the shared machine.
    pub procs: usize,
    /// Per-processor memory capacity `M` in words (`u64::MAX / 2` for
    /// effectively unbounded, i.e. the MI setting).
    pub mem_cap: u64,
    /// Machine digit base.
    pub base: Base,
    /// Execution engine backing the shared machine. Per-job
    /// `JobSpec::engine` is ignored here — there is one machine.
    /// Per-job `JobSpec::mem_cap` is enforced **at admission**: the
    /// shard plan must satisfy the stricter of the job's cap and this
    /// machine-wide cap, and a job whose own cap no shape can meet is
    /// rejected with a distinct error ([`RejectKind::JobCapUnfittable`])
    /// even when the machine cap alone would admit it. Mid-run *ledger*
    /// enforcement stays machine-wide (one memory ledger per
    /// processor); use the [`super::Coordinator`] for a dedicated
    /// machine built at exactly the job's cap.
    pub engine: EngineKind,
    /// Network topology of the shared machine (per-machine, like the
    /// engine; per-job `JobSpec::topology` is ignored here). NOTE: the
    /// bit-exact sharded-equals-dedicated cost identity holds on the
    /// fully-connected default, whose routes never leave a shard; on
    /// torus/hier topologies inter-shard relays carry other jobs'
    /// traffic, so per-job cost triples become machine-shaped rather
    /// than job-isolated — realistic, but not comparable to a
    /// dedicated run bit for bit.
    pub topology: TopologyKind,
    /// Time model used by the hybrid dispatcher.
    pub time_model: TimeModel,
    /// Runner threads = maximum concurrently running jobs.
    pub runners: usize,
    /// Admission control: maximum jobs queued or running at once.
    pub max_queue: usize,
    /// Seeded deterministic fault injection (None = faults off; the
    /// [`FaultyMachine`] wrapper is then fully transparent).
    pub fault: Option<FaultConfig>,
    /// Retry budget: maximum executions per admitted job (>= 1). The
    /// final attempt runs with injection suppressed on its shard, so
    /// under a pure injection plan every admitted job completes.
    pub max_attempts: u32,
    /// Quarantine a processor after this many *consecutive* job-killing
    /// failures (0 disables quarantine).
    pub quarantine_after: u32,
    /// Consecutive probation-probe passes required before a quarantined
    /// processor is re-admitted to the free pool (clamped to >= 1; see
    /// [`Scheduler::probe_quarantined`]).
    pub probation_successes: u32,
    /// Socket-engine wiring (`engine == EngineKind::Sockets` only):
    /// worker-process grouping, transport, reply timeout, worker
    /// binary. Ignored by the other engines.
    pub socket: SocketConfig,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            procs: 16,
            mem_cap: u64::MAX / 2,
            base: Base::default(),
            engine: EngineKind::Sim,
            topology: TopologyKind::FullyConnected,
            time_model: TimeModel::default(),
            runners: 4,
            max_queue: 1024,
            fault: None,
            max_attempts: 3,
            quarantine_after: 4,
            probation_successes: 2,
            socket: SocketConfig::default(),
        }
    }
}

/// Aggregate scheduler statistics.
#[derive(Debug, Default)]
pub struct SchedulerStats {
    pub admitted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    /// Jobs submitted but not yet replied to.
    pub in_flight: AtomicU64,
    pub shards_acquired: AtomicU64,
    /// Acquisitions that had to wait for another job to free processors.
    pub shards_stolen: AtomicU64,
    /// Failed attempts that were requeued (completed jobs with
    /// `attempts > 1` contribute `attempts - 1` each).
    pub retries: AtomicU64,
    /// Jobs shed at dequeue because their [`JobSpec::deadline`] expired
    /// while they waited in the queue (counted in neither `completed`
    /// nor `failed` — shedding is the admission policy working, not a
    /// job failing).
    pub shed_expired: AtomicU64,
    /// Quarantine *events*: processors pulled from service by the
    /// quarantine policy, counted monotonically (de-quarantine does not
    /// decrement — the live count is [`Scheduler::quarantined_procs`]).
    pub procs_quarantined: AtomicU64,
    /// Processors re-admitted to the free pool by probation (monotone).
    pub procs_dequarantined: AtomicU64,
    /// Probation canary probes executed.
    pub probes_sent: AtomicU64,
    /// Socket worker-process groups successfully respawned by
    /// probation cycles.
    pub respawns: AtomicU64,
    /// High-water mark of concurrently running jobs.
    pub peak_concurrent: AtomicU64,
    /// Sum of per-job end-to-end wall times (they overlap under
    /// concurrency — divide by completed jobs for a mean latency, NOT
    /// by elapsed time for a throughput; throughput comes from the
    /// caller's own elapsed clock, e.g. `FleetOutcome::jobs_per_s`).
    pub total_wall_us: AtomicU64,
}

type Reply = Sender<Result<JobResult>>;

/// Why [`Scheduler::try_submit`] turned a job away. The daemon's
/// shedding policy maps these to client-visible shed reasons; plain
/// [`Scheduler::submit`] callers get the flattened error message.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectKind {
    /// `max_queue` jobs already queued or running.
    QueueFull,
    /// No shape of this machine fits the job under the machine-wide
    /// memory cap — the machine is too small for the job.
    Unfittable,
    /// The job's *own* `JobSpec::mem_cap` is the binding constraint:
    /// the machine-wide cap alone would admit it, but every accepted
    /// shape's MI footprint exceeds the job's cap.
    JobCapUnfittable,
    /// The job explicitly requested `ExecPolicy::Bfs`, but no BFS
    /// level fits its effective memory cap on the planned shard.
    /// `ExecPolicy::Auto` jobs are never rejected for this — they
    /// downgrade to DFS silently at mode resolution.
    BfsUnfittable,
}

/// A typed admission rejection: the kind plus the human-readable error
/// `submit` would have returned.
#[derive(Debug)]
pub struct Rejection {
    pub kind: RejectKind,
    pub error: Error,
}

/// The sharded scheduler (see module docs).
/// A queued job: spec, planned shard size, reply channel, and the
/// submission instant (so reported wall times include queue wait).
type Queued = (JobSpec, usize, Reply, Instant);

pub struct Scheduler {
    cfg: SchedulerConfig,
    shared: Arc<Mutex<EngineMachine>>,
    pool: Arc<Pool>,
    tx: Option<Sender<Queued>>,
    runners: Vec<JoinHandle<()>>,
    /// Kept for probation canaries (runners hold their own clones).
    leaf: LeafRef,
    pub stats: Arc<SchedulerStats>,
}

impl Scheduler {
    /// Build the shared machine and start the runner pool. Only the
    /// socket engine can actually fail here (worker processes must
    /// spawn and complete their wiring handshake); the in-process
    /// engines always construct.
    pub fn start(cfg: SchedulerConfig, leaf: LeafRef) -> Result<Scheduler> {
        assert!(cfg.procs >= 1, "need at least one processor");
        let plan = cfg.fault.clone();
        let topo = cfg.topology.build(cfg.procs);
        let machine = match cfg.engine {
            EngineKind::Sim => EngineMachine::Sim(FaultyMachine::with(
                Machine::with_topology(cfg.procs, cfg.mem_cap, cfg.base, topo),
                plan,
            )),
            EngineKind::Threads => EngineMachine::Threads(FaultyMachine::with(
                ThreadedMachine::with_topology(cfg.procs, cfg.mem_cap, cfg.base, topo),
                plan,
            )),
            EngineKind::Sockets => EngineMachine::Sockets(FaultyMachine::with(
                SocketMachine::with_config(
                    cfg.procs,
                    cfg.mem_cap,
                    cfg.base,
                    topo,
                    cfg.socket.clone(),
                )?,
                plan,
            )),
        };
        let shared = Arc::new(Mutex::new(machine));
        let pool = Arc::new(Pool::new(cfg.procs));
        let stats = Arc::new(SchedulerStats::default());
        let (tx, rx) = channel::<Queued>();
        let rx = Arc::new(Mutex::new(rx));
        let mut runners = Vec::with_capacity(cfg.runners);
        for _ in 0..cfg.runners.max(1) {
            let rx = Arc::clone(&rx);
            let shared = Arc::clone(&shared);
            let pool = Arc::clone(&pool);
            let stats = Arc::clone(&stats);
            let leaf = Arc::clone(&leaf);
            let cfg = cfg.clone();
            runners.push(std::thread::spawn(move || loop {
                let msg = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                let Ok((spec, shard_size, reply, submitted_at)) = msg else {
                    break;
                };
                // Deadline-aware dequeue: a job whose budget expired
                // while it waited is shed here — never run, never
                // counted completed or failed.
                if let Some(dl) = spec.deadline {
                    let queued = submitted_at.elapsed();
                    if queued > dl {
                        stats.shed_expired.fetch_add(1, Ordering::Relaxed);
                        stats.in_flight.fetch_sub(1, Ordering::Relaxed);
                        let _ = reply.send(Err(anyhow!(
                            "job {} shed: deadline {:?} expired before a shard \
                             was free (queued {:?})",
                            spec.id,
                            dl,
                            queued
                        )));
                        continue;
                    }
                }
                let t0 = submitted_at;
                let mut res =
                    run_with_recovery(&shared, &cfg, &pool, &stats, &spec, shard_size, &leaf);
                match &mut res {
                    Ok(r) => {
                        r.wall = t0.elapsed();
                        stats.completed.fetch_add(1, Ordering::Relaxed);
                        let us = r.wall.as_micros() as u64;
                        stats.total_wall_us.fetch_add(us, Ordering::Relaxed);
                    }
                    Err(_) => {
                        stats.failed.fetch_add(1, Ordering::Relaxed);
                    }
                }
                stats.in_flight.fetch_sub(1, Ordering::Relaxed);
                let _ = reply.send(res);
            }));
        }
        Ok(Scheduler {
            cfg,
            shared,
            pool,
            tx: Some(tx),
            runners,
            leaf,
            stats,
        })
    }

    /// The configuration this scheduler was started with.
    pub fn config(&self) -> &SchedulerConfig {
        &self.cfg
    }

    /// Total injected faults recorded by the shared machine's plan
    /// (zero without a plan).
    pub fn faults_injected(&self) -> u64 {
        let mut g = self.shared.lock().unwrap();
        on_engine!(g, m => m.total_injected())
    }

    /// Processors *currently* quarantined — the live ledger, so
    /// de-quarantine decrements it. Live (non-quarantined) processors
    /// are `cfg.procs` minus this. (Historically this read the
    /// monotone event counter, which skewed from
    /// [`Scheduler::quarantined_proc_ids`] the moment probation
    /// re-admitted anything; the event counter is now
    /// [`Scheduler::total_quarantine_events`].)
    pub fn quarantined_procs(&self) -> u64 {
        self.pool.state.lock().unwrap().quarantined.len() as u64
    }

    /// Monotone count of quarantine events over the scheduler's life
    /// (a processor quarantined, probed back, and quarantined again
    /// counts twice).
    pub fn total_quarantine_events(&self) -> u64 {
        self.stats.procs_quarantined.load(Ordering::Relaxed)
    }

    /// Ids of the processors currently pulled from service, sorted.
    /// The kill-chaos tests use this to assert a real worker death
    /// quarantines exactly the dead group's processors.
    pub fn quarantined_proc_ids(&self) -> Vec<ProcId> {
        let st = self.pool.state.lock().unwrap();
        let mut q = st.quarantined.clone();
        q.sort_unstable();
        q
    }

    /// Processors currently in service: the machine size minus the
    /// live quarantine ledger. The daemon's degraded-mode shed estimate
    /// scales by `total / live`.
    pub fn live_procs(&self) -> usize {
        self.cfg.procs.saturating_sub(self.quarantined_procs() as usize)
    }

    /// Socket engine only: OS pids of the live worker processes by
    /// group (`None` for a group already reaped). Empty on the
    /// in-process engines.
    pub fn socket_worker_pids(&self) -> Vec<Option<u32>> {
        let g = self.shared.lock().unwrap();
        match &*g {
            EngineMachine::Sockets(m) => m.inner().worker_pids(),
            _ => Vec::new(),
        }
    }

    /// Socket engine only: SIGKILL worker-process group `g` — the
    /// kill-chaos tests use this to turn a real process death into the
    /// per-job failure / quarantine path. Errors on the in-process
    /// engines and on an already-dead group.
    pub fn kill_socket_worker(&self, group: usize) -> Result<()> {
        let guard = self.shared.lock().unwrap();
        match &*guard {
            EngineMachine::Sockets(m) => m.inner().kill_worker(group),
            _ => bail!("kill_socket_worker: scheduler is not on the socket engine"),
        }
    }

    /// One probation cycle (module docs, "Fault recovery"): health-probe
    /// every quarantined processor with a canary multiply on a dedicated
    /// one-processor shard; [`SchedulerConfig::probation_successes`]
    /// consecutive passes re-admit the processor. On the socket engine,
    /// dead worker-process groups are respawned first so the canaries
    /// have live processes to land on. Probes run with injection
    /// suppressed (they judge the machine, not the fault plan) and
    /// verify the canary product digit for digit. Returns the number of
    /// processors de-quarantined this cycle; a no-op (and no probe ever
    /// runs) while the quarantine ledger is empty.
    pub fn probe_quarantined(&self) -> usize {
        let ids = self.quarantined_proc_ids();
        if ids.is_empty() {
            return 0;
        }
        // Socket engine: a quarantined processor usually means its
        // whole worker-process group died — respawn dead groups so the
        // canaries have somewhere to run. A failed respawn is not
        // terminal: the probe fails and the next cycle retries with the
        // machine's jittered backoff.
        {
            let mut g = self.shared.lock().unwrap();
            if let EngineMachine::Sockets(m) = &mut *g {
                for group in m.inner().dead_groups() {
                    if m.inner_mut().respawn_group(group).is_ok() {
                        self.stats.respawns.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }
        let k = self.cfg.probation_successes.max(1);
        let mut readmitted = 0;
        for p in ids {
            // Heal + purge first, so the probe judges the processor as
            // the next client job would find it; suppress injection for
            // the probe's duration (the safe-mode escape hatch).
            {
                let mut g = self.shared.lock().unwrap();
                on_engine!(g, m => {
                    m.heal(p);
                    MachineApi::purge(m, p);
                    m.set_suppressed(p, true);
                });
            }
            self.stats.probes_sent.fetch_add(1, Ordering::Relaxed);
            let ok = self.run_canary(p);
            {
                let mut g = self.shared.lock().unwrap();
                on_engine!(g, m => m.set_suppressed(p, false));
            }
            if self.pool.record_probe(p, ok, k, &self.stats) {
                readmitted += 1;
            }
        }
        readmitted
    }

    /// Run the canary multiply on the one-processor shard `[p]` and
    /// verify its product. Any error — dead worker, timeout, wrong
    /// digits — fails the probe. The canary never touches the job
    /// queue or the completed/failed counters: probation is machine
    /// maintenance, not serving traffic.
    fn run_canary(&self, p: ProcId) -> bool {
        let mut spec = JobSpec::new(u64::MAX, CANARY_A.to_vec(), CANARY_B.to_vec());
        spec.procs = 1;
        spec.algo = Some(Algorithm::Copsim);
        match run_sharded(&self.shared, &self.cfg, &spec, &[p], &self.leaf) {
            Ok(r) => r.product == canary_product(self.cfg.base),
            Err(_) => false,
        }
    }

    /// Admit a job (or reject it — see module docs); the result arrives
    /// on the returned channel once a shard has run it. Like
    /// [`Scheduler::try_submit`] with the rejection flattened to its
    /// error message.
    pub fn submit(&self, spec: JobSpec) -> Result<Receiver<Result<JobResult>>> {
        self.try_submit(spec).map_err(|r| r.error)
    }

    /// Book-keep a rejection: release the reserved queue slot, bump the
    /// counter, and wrap the error with its kind.
    fn rejected(&self, kind: RejectKind, error: Error) -> Rejection {
        self.stats.in_flight.fetch_sub(1, Ordering::Relaxed);
        self.stats.rejected.fetch_add(1, Ordering::Relaxed);
        Rejection { kind, error }
    }

    /// [`Scheduler::submit`] with a typed rejection, so callers (the
    /// serving daemon's shedding policy) can distinguish a full queue
    /// from an unfittable job without string-matching.
    pub fn try_submit(
        &self,
        spec: JobSpec,
    ) -> std::result::Result<Receiver<Result<JobResult>>, Rejection> {
        // Reserve the queue slot first (fetch_add, not check-then-act:
        // concurrent submitters must not over-admit past max_queue),
        // releasing it on every rejection path.
        let prior = self.stats.in_flight.fetch_add(1, Ordering::Relaxed);
        if prior >= self.cfg.max_queue as u64 {
            let e = anyhow!(
                "scheduler queue full ({prior} jobs in flight, max {})",
                self.cfg.max_queue
            );
            return Err(self.rejected(RejectKind::QueueFull, e));
        }
        // A job's own memory bound tightens its shard plan (the shard
        // grows until the footprint fits the stricter of the two caps),
        // and is *enforced* here at admission: a job whose own cap no
        // shape can meet is rejected distinctly, even when the machine
        // cap alone would admit it. Mid-run ledger enforcement stays
        // machine-wide (one ledger per processor — the Coordinator path
        // enforces per-job caps exactly at runtime too).
        let cap = effective_cap(&spec, self.cfg.mem_cap);
        let shard_size = match plan_shard(&spec, self.cfg.procs, cap) {
            Ok(s) => s,
            Err(e) => {
                let own_cap_binding = cap < self.cfg.mem_cap
                    && plan_shard(&spec, self.cfg.procs, self.cfg.mem_cap).is_ok();
                return Err(if own_cap_binding {
                    let e = anyhow!(
                        "job {} not admissible under its own mem_cap = {} words/proc: \
                         every accepted shape's MI footprint exceeds the job's cap \
                         (the machine-wide cap {} alone would admit it)",
                        spec.id,
                        cap,
                        self.cfg.mem_cap
                    );
                    self.rejected(RejectKind::JobCapUnfittable, e)
                } else {
                    self.rejected(RejectKind::Unfittable, e)
                });
            }
        };
        // Explicit-BFS admission: the job *demands* the memory-hungry
        // schedule, so turn it away (distinctly) when no BFS level fits
        // the planned shard under its effective cap. `Auto` never hits
        // this — it resolves to DFS at execution time instead.
        if spec.exec_mode == ExecPolicy::Bfs {
            let n = spec.padded_width_for(shard_size) as u64;
            let p = shard_size as u64;
            let algo = match spec.algo {
                Some(a) => Some(a),
                None => hybrid::choose_algorithm(n, p, cap, &self.cfg.time_model).ok(),
            };
            let levels = algo.map_or(0, |a| theory::bfs_levels(a, n, p, cap));
            if levels == 0 {
                let e = anyhow!(
                    "job {} requested exec-mode=bfs but no BFS level fits its \
                     cap of {} words/proc on a {}-processor shard (n = {n} \
                     padded); request exec-mode=auto to fall back to DFS",
                    spec.id,
                    cap,
                    shard_size
                );
                return Err(self.rejected(RejectKind::BfsUnfittable, e));
            }
        }
        self.stats.admitted.fetch_add(1, Ordering::Relaxed);
        let (reply_tx, reply_rx) = channel();
        self.tx
            .as_ref()
            .expect("scheduler already shut down")
            .send((spec, shard_size, reply_tx, Instant::now()))
            .expect("runner pool gone");
        Ok(reply_rx)
    }

    /// Submit and wait.
    pub fn submit_blocking(&self, spec: JobSpec) -> Result<JobResult> {
        self.submit(spec)?
            .recv()
            .context("scheduler dropped reply")?
    }

    /// Drain the queue, join the runners, and tear down the shared
    /// machine — surfacing any deferred real-execution error (the
    /// threaded backend reports memory overflows at finish time; the
    /// socket backend additionally reaps its worker processes).
    pub fn shutdown(mut self) -> Result<()> {
        self.tx.take();
        for h in self.runners.drain(..) {
            let _ = h.join();
        }
        let mut g = self.shared.lock().unwrap();
        match &mut *g {
            EngineMachine::Threads(m) => {
                m.inner_mut().finish()?;
            }
            EngineMachine::Sockets(m) => {
                m.inner_mut().finish()?;
            }
            EngineMachine::Sim(_) => {}
        }
        Ok(())
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        self.tx.take();
        for h in self.runners.drain(..) {
            let _ = h.join();
        }
    }
}

/// Exponential shard-size backoff for retries: the next shape up the
/// job's ladder whose memory footprint still fits, or `cur` when the
/// machine has nothing bigger. The ladders are geometric (4^k, 4·3^i),
/// so each step multiplies the shard size and *shrinks* the retried
/// job's per-processor footprint — the re-admission ladder the MI-mode
/// memory requirements provide.
fn grow_shard(spec: &JobSpec, cur: usize, total_procs: usize, mem_cap: u64) -> usize {
    for p in shape_ladder(spec.algo, total_procs) {
        if p <= cur {
            continue;
        }
        let n = spec.padded_width_for(p) as u64;
        if theory_mem_footprint(n, p as u64, spec.algo) <= mem_cap {
            return p;
        }
    }
    cur
}

/// Injected faults recorded against any of the shard's processors.
fn shard_fault_count(shared: &Arc<Mutex<EngineMachine>>, shard: &[ProcId]) -> u64 {
    let mut g = shared.lock().unwrap();
    on_engine!(g, m => shard.iter().map(|&p| m.fault_count(p)).sum())
}

/// The per-job memory cap that drives shard sizing: the stricter of the
/// job's own bound and the machine-wide cap (admission and retry
/// backoff must agree on this rule — see `Scheduler::submit`).
fn effective_cap(spec: &JobSpec, machine_cap: u64) -> u64 {
    spec.mem_cap.unwrap_or(u64::MAX / 2).min(machine_cap)
}

/// Fixed probation-canary operands: digits valid in every machine base
/// (all < 4), small enough that a probe is microseconds of work.
const CANARY_A: [u32; 8] = [1, 2, 3, 1, 2, 3, 1, 2];
const CANARY_B: [u32; 8] = [3, 2, 1, 3, 2, 1, 3, 2];

/// The canary's expected product in `base`, normalized exactly like a
/// [`JobResult::product`].
fn canary_product(base: Base) -> Vec<u32> {
    let mut ops = Ops::default();
    let mut prod = crate::bignum::mul::mul_school(&CANARY_A, &CANARY_B, base, &mut ops);
    let keep = crate::bignum::core::normalized_len(&prod).max(1);
    prod.truncate(keep);
    prod
}

/// Execute one job with the scheduler's recovery policy (module docs,
/// "Fault recovery"): acquire a shard, run, and on failure heal + purge
/// the shard, requeue with exponential shard-size backoff, quarantine
/// repeat-offender processors, and suppress injection on the final
/// attempt.
fn run_with_recovery(
    shared: &Arc<Mutex<EngineMachine>>,
    cfg: &SchedulerConfig,
    pool: &Pool,
    stats: &SchedulerStats,
    spec: &JobSpec,
    first_shard_size: usize,
    leaf: &LeafRef,
) -> Result<JobResult> {
    let max_attempts = cfg.max_attempts.max(1);
    let cap = effective_cap(spec, cfg.mem_cap);
    let mut size = first_shard_size;
    // Backoff never grows past this; lowered when an acquire shows the
    // machine can no longer host a grown size.
    let mut grow_limit = cfg.procs;
    let mut attempt = 0u32;
    loop {
        attempt += 1;
        let shard = match pool.acquire(size, stats) {
            Ok(s) => s,
            Err(e) => {
                // Quarantine may have shrunk the machine below a grown
                // backoff size while the originally admitted shard
                // still fits: fall back instead of failing a job that
                // has retry budget left. Only the admitted size failing
                // is terminal.
                if size > first_shard_size {
                    grow_limit = size - 1;
                    size = first_shard_size;
                    attempt -= 1; // nothing was executed
                    continue;
                }
                return Err(e);
            }
        };
        // Safe mode on the final attempt: a job admitted under an
        // injection plan must not be killable by the plan alone.
        let safe_mode = attempt >= max_attempts && cfg.fault.is_some();
        {
            let mut g = shared.lock().unwrap();
            on_engine!(g, m => {
                m.reset_op_index(&shard);
                if safe_mode {
                    for &p in &shard {
                        m.set_suppressed(p, true);
                    }
                }
            });
        }
        let faults_before = shard_fault_count(shared, &shard);
        let res = run_sharded(shared, cfg, spec, &shard, leaf);
        let faults_after = shard_fault_count(shared, &shard);
        if safe_mode {
            let mut g = shared.lock().unwrap();
            on_engine!(g, m => {
                for &p in &shard {
                    m.set_suppressed(p, false);
                }
            });
        }
        match res {
            Ok(mut r) => {
                r.attempts = attempt;
                r.faults_survived = faults_after.saturating_sub(faults_before);
                pool.release(shard, false, cfg.quarantine_after, stats);
                return Ok(r);
            }
            Err(e) => {
                // Heal crashed processors and drop whatever the failed
                // attempt left resident, so the shard returns clean.
                {
                    let mut g = shared.lock().unwrap();
                    on_engine!(g, m => {
                        for &p in &shard {
                            m.heal(p);
                            MachineApi::purge(m, p);
                        }
                    });
                }
                pool.release(shard, true, cfg.quarantine_after, stats);
                if attempt >= max_attempts {
                    return Err(e.wrap(format!(
                        "job {} failed after {attempt} attempt(s)",
                        spec.id
                    )));
                }
                stats.retries.fetch_add(1, Ordering::Relaxed);
                size = grow_shard(spec, size, grow_limit, cap);
            }
        }
    }
}

/// Run one job on its shard of the shared machine (see module docs for
/// the uniform-baseline cost argument).
fn run_sharded(
    shared: &Arc<Mutex<EngineMachine>>,
    cfg: &SchedulerConfig,
    spec: &JobSpec,
    shard: &[ProcId],
    leaf: &LeafRef,
) -> Result<JobResult> {
    // The job ledger engages only when the job's own cap is tighter
    // than the machine's — otherwise every call forwards untouched and
    // sharded execution stays bit-identical to the pre-ledger path.
    let cap = effective_cap(spec, cfg.mem_cap);
    let mut view = ShardView {
        machine: Arc::clone(shared),
        ledger: (cap < cfg.mem_cap).then(|| JobLedger::new(cap)),
    };
    // Uniform clock baseline: max-plus clock evolution commutes with a
    // uniform shift, so everything after this barrier is exactly a
    // fresh-machine run of the job shifted by `baseline`. A crashed or
    // dead shard processor surfaces here, before any work is issued.
    view.barrier(shard)?;
    let baseline = view.proc_view(shard[0])?.clock;
    let seq = Seq(shard.to_vec());
    let (product, algo, mode) = execute_on(&mut view, &cfg.time_model, spec, &seq, leaf)?;
    let mut end = Clock::default();
    let mut mem_peak = 0u64;
    for &p in shard {
        let v = view.proc_view(p)?;
        end = end.join(&v.clock);
        mem_peak = mem_peak.max(v.mem_peak);
    }
    // A capped job's ledger knows its OWN high-water mark — report
    // that instead of the shared machine's lifetime peak (which may
    // include earlier jobs on the same shard).
    if let Some(l) = &view.ledger {
        mem_peak = l.peak;
    }
    Ok(JobResult {
        id: spec.id,
        product,
        algo,
        exec_mode: mode,
        engine: cfg.engine,
        cost: end.since(&baseline),
        mem_peak,
        wall: std::time::Duration::ZERO, // filled by the runner
        shard: Some(shard.to_vec()),
        attempts: 1,          // filled by the recovery driver
        faults_survived: 0,   // filled by the recovery driver
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::leaf::{leaf_ref, SchoolLeaf};
    use crate::algorithms::ExecMode;
    use crate::bignum::mul;
    use crate::util::Rng;

    fn base() -> Base {
        Base::new(16)
    }

    fn reference_product(a: &[u32], b: &[u32]) -> Vec<u32> {
        let mut ops = Ops::default();
        let mut prod = mul::mul_school(a, b, base(), &mut ops);
        let keep = crate::bignum::core::normalized_len(&prod).max(1);
        prod.truncate(keep);
        prod
    }

    #[test]
    fn plan_shard_picks_smallest_fitting_shape() {
        // Unbounded memory: the requested count wins when it is a valid
        // shape, and invalid counts round up to the next shape.
        let mut spec = JobSpec::new(0, vec![1; 64], vec![1; 64]);
        spec.algo = Some(Algorithm::Copsim);
        assert_eq!(plan_shard(&spec, 64, u64::MAX / 2).unwrap(), 4);
        spec.procs = 8; // not 4^k -> next power of four
        assert_eq!(plan_shard(&spec, 64, u64::MAX / 2).unwrap(), 16);
        spec.procs = 8;
        spec.algo = Some(Algorithm::Copk);
        assert_eq!(plan_shard(&spec, 64, u64::MAX / 2).unwrap(), 12);
        // Hybrid: union ladder, 12 is the smallest shape >= 8.
        spec.algo = None;
        assert_eq!(plan_shard(&spec, 64, u64::MAX / 2).unwrap(), 12);
        // No shape fits the machine at all.
        spec.procs = 32;
        assert!(plan_shard(&spec, 8, u64::MAX / 2).is_err());
    }

    #[test]
    fn plan_shard_grows_for_memory() {
        // n = 1024 on 4 procs needs 12n/sqrt(4) = 6144 words/proc
        // (Theorem 11); a 4000-word cap forces the 16-proc shape
        // (12n/4 = 3072).
        let mut spec = JobSpec::new(0, vec![1; 1024], vec![1; 1024]);
        spec.algo = Some(Algorithm::Copsim);
        assert_eq!(plan_shard(&spec, 64, 4000).unwrap(), 16);
        // And a cap too small for every shape rejects.
        assert!(plan_shard(&spec, 16, 64).is_err());
    }

    #[test]
    fn sharded_jobs_match_dedicated_machine() {
        let cfg = SchedulerConfig {
            procs: 8,
            runners: 2,
            ..Default::default()
        };
        let sched = Scheduler::start(cfg.clone(), leaf_ref(SchoolLeaf)).unwrap();
        let mut rng = Rng::new(0x5EAD);
        let mut pending = Vec::new();
        let mut want = Vec::new();
        for id in 0..6u64 {
            let n = 1usize << rng.range(4, 7);
            let a = rng.digits(n, 16);
            let b = rng.digits(n, 16);
            want.push(reference_product(&a, &b));
            let mut spec = JobSpec::new(id, a, b);
            spec.procs = 4;
            spec.algo = Some(Algorithm::Copsim);
            pending.push((spec.clone(), sched.submit(spec).unwrap()));
        }
        for (i, (spec, rx)) in pending.into_iter().enumerate() {
            let res = rx.recv().unwrap().unwrap();
            assert_eq!(res.product, want[i], "job {i} product");
            let shard = res.shard.clone().expect("scheduler jobs carry shards");
            assert_eq!(shard.len(), 4);
            // The sharded cost triple equals a dedicated-machine run.
            let mut solo = Machine::new(shard.len(), cfg.mem_cap, cfg.base);
            let seq = Seq::range(shard.len());
            let leaf = leaf_ref(SchoolLeaf);
            execute_on(&mut solo, &cfg.time_model, &spec, &seq, &leaf).unwrap();
            assert_eq!(res.cost, solo.critical(), "job {i} cost triple");
        }
        assert_eq!(sched.stats.completed.load(Ordering::Relaxed), 6);
        assert!(sched.stats.peak_concurrent.load(Ordering::Relaxed) <= 2);
        sched.shutdown().unwrap();
    }

    #[test]
    fn threaded_engine_shares_one_machine() {
        let cfg = SchedulerConfig {
            procs: 8,
            runners: 2,
            engine: EngineKind::Threads,
            ..Default::default()
        };
        let sched = Scheduler::start(cfg, leaf_ref(SchoolLeaf)).unwrap();
        let mut rng = Rng::new(0xBEEF);
        let mut pending = Vec::new();
        let mut want = Vec::new();
        for id in 0..4u64 {
            let a = rng.digits(128, 16);
            let b = rng.digits(128, 16);
            want.push(reference_product(&a, &b));
            let mut spec = JobSpec::new(id, a, b);
            spec.procs = 4;
            pending.push(sched.submit(spec).unwrap());
        }
        for (i, rx) in pending.into_iter().enumerate() {
            let res = rx.recv().unwrap().unwrap();
            assert_eq!(res.product, want[i], "job {i}");
            assert_eq!(res.engine, EngineKind::Threads);
        }
        sched.shutdown().unwrap();
    }

    #[test]
    fn admission_rejects_impossible_and_queue_full() {
        // A job wider than the whole machine is rejected up front.
        let sched = Scheduler::start(
            SchedulerConfig {
                procs: 4,
                ..Default::default()
            },
            leaf_ref(SchoolLeaf),
        )
        .unwrap();
        let mut spec = JobSpec::new(0, vec![1; 32], vec![1; 32]);
        spec.procs = 16;
        assert!(sched.submit(spec).is_err());
        assert_eq!(sched.stats.rejected.load(Ordering::Relaxed), 1);
        sched.shutdown().unwrap();

        // max_queue = 0 rejects every submission deterministically.
        let sched = Scheduler::start(
            SchedulerConfig {
                max_queue: 0,
                ..Default::default()
            },
            leaf_ref(SchoolLeaf),
        )
        .unwrap();
        assert!(sched.submit(JobSpec::new(1, vec![1; 8], vec![2; 8])).is_err());
        sched.shutdown().unwrap();
    }

    #[test]
    fn per_job_mem_cap_rejected_distinctly_at_admission() {
        // A machine with effectively unbounded memory admits the job —
        // unless the job's OWN cap is the binding constraint, which must
        // reject with the distinct JobCapUnfittable kind. Footprints at
        // n = 1024 (Theorem 11, 12n/√P): P=4 → 6144, P=16 → 3072 — both
        // far above the job's 64-word cap.
        let sched = Scheduler::start(
            SchedulerConfig {
                procs: 16,
                ..Default::default()
            },
            leaf_ref(SchoolLeaf),
        )
        .unwrap();
        let mut spec = JobSpec::new(0, vec![1; 1024], vec![1; 1024]);
        spec.algo = Some(Algorithm::Copsim);
        spec.mem_cap = Some(64);
        let rej = sched.try_submit(spec.clone()).unwrap_err();
        assert_eq!(rej.kind, RejectKind::JobCapUnfittable);
        assert!(
            rej.error.to_string().contains("own mem_cap"),
            "distinct message, got: {}",
            rej.error
        );
        assert_eq!(sched.stats.rejected.load(Ordering::Relaxed), 1);
        assert_eq!(sched.stats.in_flight.load(Ordering::Relaxed), 0);
        // The same job without its own cap is admitted and completes.
        spec.mem_cap = None;
        spec.id = 1;
        assert!(sched.submit_blocking(spec).is_ok());
        sched.shutdown().unwrap();

        // When the MACHINE cap is what rejects, the kind is Unfittable.
        let sched = Scheduler::start(
            SchedulerConfig {
                procs: 4,
                mem_cap: 64,
                ..Default::default()
            },
            leaf_ref(SchoolLeaf),
        )
        .unwrap();
        let mut spec = JobSpec::new(2, vec![1; 1024], vec![1; 1024]);
        spec.algo = Some(Algorithm::Copsim);
        let rej = sched.try_submit(spec).unwrap_err();
        assert_eq!(rej.kind, RejectKind::Unfittable);
        sched.shutdown().unwrap();
    }

    #[test]
    fn job_ledger_charges_checks_and_peaks() {
        let mut l = JobLedger::new(100);
        assert!(l.check(0, 60).is_ok());
        l.charge(0, 1, 60);
        assert_eq!(l.peak, 60);
        // Over the cap: the check names the job's own cap.
        let e = l.check(0, 50).unwrap_err().to_string();
        assert!(e.contains("job mem_cap exceeded"), "got: {e}");
        // Another processor has its own budget.
        assert!(l.check(1, 100).is_ok());
        l.charge(1, 1, 100);
        assert_eq!(l.peak, 100);
        // Release frees the headroom; peak is a high-water mark.
        l.release(0, 1);
        assert!(l.check(0, 100).is_ok());
        assert_eq!(l.peak, 100);
        l.purge(1);
        assert!(l.check(1, 100).is_ok());
    }

    #[test]
    fn explicit_bfs_rejected_distinctly_when_no_level_fits() {
        // COPSIM n = 1024 on a 4-processor shard: the MI footprint
        // 12n/√4 = 6144 fits an 8192-word cap (DFS runs fine), but the
        // fused-BFS gate needs 24n/√4 = 12288 — no BFS level fits.
        let sched = Scheduler::start(
            SchedulerConfig {
                procs: 4,
                mem_cap: 8192,
                ..Default::default()
            },
            leaf_ref(SchoolLeaf),
        )
        .unwrap();
        let mut spec = JobSpec::new(0, vec![1; 1024], vec![1; 1024]);
        spec.algo = Some(Algorithm::Copsim);
        spec.exec_mode = ExecPolicy::Bfs;
        let rej = sched.try_submit(spec.clone()).unwrap_err();
        assert_eq!(rej.kind, RejectKind::BfsUnfittable);
        assert!(
            rej.error.to_string().contains("exec-mode=bfs"),
            "distinct message, got: {}",
            rej.error
        );
        // The same job under Auto is admitted and silently downgrades
        // to the DFS schedule at mode resolution.
        spec.id = 1;
        spec.exec_mode = ExecPolicy::Auto;
        let res = sched.submit_blocking(spec).unwrap();
        assert_eq!(res.exec_mode, ExecMode::Dfs);
        sched.shutdown().unwrap();
    }

    #[test]
    fn auto_mode_spends_memory_to_cut_bandwidth() {
        // The roomy COPSIM cell: P = 16, n = 1024, cap = 8192 — over 2×
        // the MI footprint (12n/√16 = 3072) and past the fused gate
        // (24n/√16 = 6144). Auto must resolve Bfs{2} (log₄ 16 levels),
        // keep the product and T identical to DFS, and charge strictly
        // fewer words.
        let cfg = SchedulerConfig {
            procs: 16,
            mem_cap: 8192,
            ..Default::default()
        };
        let sched = Scheduler::start(cfg.clone(), leaf_ref(SchoolLeaf)).unwrap();
        let mut rng = Rng::new(0xBF5);
        let a = rng.digits(1024, 16);
        let b = rng.digits(1024, 16);
        let want = reference_product(&a, &b);
        let mut dfs = JobSpec::new(0, a.clone(), b.clone());
        dfs.procs = 16;
        dfs.algo = Some(Algorithm::Copsim);
        let mut auto = dfs.clone();
        auto.id = 1;
        auto.exec_mode = ExecPolicy::Auto;
        let dfs_res = sched.submit_blocking(dfs).unwrap();
        let auto_res = sched.submit_blocking(auto.clone()).unwrap();
        sched.shutdown().unwrap();
        assert_eq!(dfs_res.exec_mode, ExecMode::Dfs);
        assert_eq!(auto_res.exec_mode, ExecMode::Bfs { levels: 2 });
        assert_eq!(dfs_res.product, want);
        assert_eq!(auto_res.product, want);
        // Same local op schedule, strictly less communication.
        assert_eq!(auto_res.cost.ops, dfs_res.cost.ops, "T must not move");
        assert!(
            auto_res.cost.words < dfs_res.cost.words,
            "BFS must charge strictly fewer words ({} vs {})",
            auto_res.cost.words,
            dfs_res.cost.words
        );
        // And the sharded BFS triple equals a dedicated capped machine.
        let mut solo = Machine::new(16, cfg.mem_cap, cfg.base);
        let seq = Seq::range(16);
        let leaf = leaf_ref(SchoolLeaf);
        execute_on(&mut solo, &cfg.time_model, &auto, &seq, &leaf).unwrap();
        assert_eq!(auto_res.cost, solo.critical(), "BFS cost identity");
    }

    #[test]
    fn job_own_cap_gates_mode_resolution_like_a_dedicated_machine() {
        // Machine cap is roomy (would give Bfs{2}); the job's OWN cap
        // of 4096 sits between the MI footprint (3072) and the fused
        // gate (6144), so the ledgered shard must report 4096 and Auto
        // must resolve Dfs — exactly what a dedicated 4096-cap machine
        // would do.
        let sched = Scheduler::start(
            SchedulerConfig {
                procs: 16,
                mem_cap: 1 << 20,
                ..Default::default()
            },
            leaf_ref(SchoolLeaf),
        )
        .unwrap();
        let mut rng = Rng::new(0xCA9);
        let a = rng.digits(1024, 16);
        let b = rng.digits(1024, 16);
        let want = reference_product(&a, &b);
        let mut spec = JobSpec::new(0, a, b);
        spec.procs = 16;
        spec.algo = Some(Algorithm::Copsim);
        spec.exec_mode = ExecPolicy::Auto;
        spec.mem_cap = Some(4096);
        let res = sched.submit_blocking(spec).unwrap();
        sched.shutdown().unwrap();
        assert_eq!(res.product, want);
        assert_eq!(
            res.exec_mode,
            ExecMode::Dfs,
            "the job's own cap must gate the upgrade"
        );
        // The ledger reports the job's own high-water mark, within cap.
        assert!(res.mem_peak > 0, "ledgered peak must be recorded");
        assert!(
            res.mem_peak <= 4096,
            "peak {} must respect the job's own cap",
            res.mem_peak
        );
    }

    #[test]
    fn expired_deadline_is_shed_at_dequeue_not_run() {
        use std::time::Duration;
        // One runner: the slow job occupies it while the deadlined job
        // waits in the queue past its (zero) budget. The waiter must be
        // shed at dequeue — counted in shed_expired, not failed — and
        // its reply must carry a deadline error.
        let sched = Scheduler::start(
            SchedulerConfig {
                procs: 4,
                runners: 1,
                ..Default::default()
            },
            leaf_ref(SchoolLeaf),
        )
        .unwrap();
        let mut slow = JobSpec::new(0, vec![1; 2048], vec![1; 2048]);
        slow.algo = Some(Algorithm::Copsim);
        let slow_rx = sched.submit(slow).unwrap();
        let mut tight = JobSpec::new(1, vec![1; 8], vec![2; 8]);
        tight.algo = Some(Algorithm::Copsim);
        tight.deadline = Some(Duration::ZERO);
        let tight_rx = sched.submit(tight).unwrap();
        let err = tight_rx.recv().unwrap().unwrap_err();
        assert!(
            err.to_string().contains("deadline"),
            "expected a deadline-shed error, got: {err}"
        );
        slow_rx.recv().unwrap().unwrap();
        assert_eq!(sched.stats.shed_expired.load(Ordering::Relaxed), 1);
        assert_eq!(sched.stats.completed.load(Ordering::Relaxed), 1);
        assert_eq!(sched.stats.failed.load(Ordering::Relaxed), 0);
        assert_eq!(sched.stats.in_flight.load(Ordering::Relaxed), 0);
        sched.shutdown().unwrap();
    }

    #[test]
    fn purged_shard_serves_later_jobs_with_identical_costs() {
        // The failure path purges a shard before releasing it; this
        // checks the invariant that path relies on — a purge between two
        // identical jobs on the same shard changes neither the product
        // nor the cost triple (clocks survive, slots do not).
        let cfg = SchedulerConfig {
            procs: 4,
            runners: 1,
            ..Default::default()
        };
        let sched = Scheduler::start(cfg, leaf_ref(SchoolLeaf)).unwrap();
        let a = vec![3u32; 64];
        let b = vec![5u32; 64];
        let r1 = sched.submit_blocking(JobSpec::new(0, a.clone(), b.clone())).unwrap();
        // Purge the shard out-of-band, then the next job must still run
        // correctly on the same processors.
        {
            let mut view = ShardView {
                machine: Arc::clone(&sched.shared),
                ledger: None,
            };
            for p in 0..4 {
                view.purge(p);
            }
        }
        let r2 = sched.submit_blocking(JobSpec::new(1, a, b)).unwrap();
        assert_eq!(r1.product, r2.product);
        assert_eq!(r1.cost, r2.cost, "purge must not disturb cost isolation");
        sched.shutdown().unwrap();
    }

    #[test]
    fn grow_shard_walks_the_ladder() {
        let mut spec = JobSpec::new(0, vec![1; 64], vec![1; 64]);
        spec.algo = Some(Algorithm::Copsim);
        // 4 -> 16 -> 64 -> capped.
        assert_eq!(grow_shard(&spec, 4, 64, u64::MAX / 2), 16);
        assert_eq!(grow_shard(&spec, 16, 64, u64::MAX / 2), 64);
        assert_eq!(grow_shard(&spec, 64, 64, u64::MAX / 2), 64);
        // COPK ladder: 4 -> 12 -> 36.
        spec.algo = Some(Algorithm::Copk);
        assert_eq!(grow_shard(&spec, 4, 36, u64::MAX / 2), 12);
        assert_eq!(grow_shard(&spec, 12, 36, u64::MAX / 2), 36);
    }

    #[test]
    fn injected_faults_recover_per_job() {
        // A drop-heavy plan: first attempts fail, retries (with the
        // final attempt running in safe mode) finish every job with the
        // right product.
        use crate::sim::{FaultConfig, FaultKind};
        let cfg = SchedulerConfig {
            procs: 8,
            runners: 2,
            fault: Some(FaultConfig::new(0xBAD, 0.02).only(&[FaultKind::DropMsg])),
            max_attempts: 4,
            quarantine_after: 0, // keep every proc in service here
            ..Default::default()
        };
        let sched = Scheduler::start(cfg, leaf_ref(SchoolLeaf)).unwrap();
        let mut rng = Rng::new(0xFA);
        let mut pending = Vec::new();
        let mut want = Vec::new();
        for id in 0..6u64 {
            let a = rng.digits(128, 16);
            let b = rng.digits(128, 16);
            want.push(reference_product(&a, &b));
            let mut spec = JobSpec::new(id, a, b);
            spec.procs = 4;
            spec.algo = Some(Algorithm::Copsim);
            pending.push(sched.submit(spec).unwrap());
        }
        let mut attempts_total = 0u32;
        for (i, rx) in pending.into_iter().enumerate() {
            let res = rx.recv().unwrap().unwrap();
            assert_eq!(res.product, want[i], "job {i} product after recovery");
            attempts_total += res.attempts;
        }
        // The 2% drop rate over thousands of sends virtually guarantees
        // at least one retry across six 128-digit jobs; the seeded plan
        // makes the outcome reproducible for a given schedule and the
        // invariant (all complete, verified) holds for every schedule.
        assert_eq!(sched.stats.completed.load(Ordering::Relaxed), 6);
        assert_eq!(sched.stats.failed.load(Ordering::Relaxed), 0);
        assert!(
            attempts_total > 6,
            "the 2% drop plan must force at least one retry (got {attempts_total})"
        );
        assert!(sched.stats.retries.load(Ordering::Relaxed) > 0);
        sched.shutdown().unwrap();
    }

    #[test]
    fn zero_fault_shards_report_identical_costs_under_injection() {
        // Stall-only plan at a low rate: no attempt ever fails, and any
        // job whose shard saw zero injected events must report the
        // dedicated-machine cost triple bit for bit.
        use crate::sim::{FaultConfig, FaultKind};
        let cfg = SchedulerConfig {
            procs: 8,
            runners: 2,
            fault: Some(FaultConfig::new(0x57A, 0.001).only(&[FaultKind::Stall])),
            ..Default::default()
        };
        let sched = Scheduler::start(cfg.clone(), leaf_ref(SchoolLeaf)).unwrap();
        let mut rng = Rng::new(0x1D);
        let mut pending = Vec::new();
        for id in 0..8u64 {
            let a = rng.digits(64, 16);
            let b = rng.digits(64, 16);
            let mut spec = JobSpec::new(id, a, b);
            spec.procs = 4;
            spec.algo = Some(Algorithm::Copsim);
            pending.push((spec.clone(), sched.submit(spec).unwrap()));
        }
        for (spec, rx) in pending {
            let res = rx.recv().unwrap().unwrap();
            if res.faults_survived > 0 {
                continue; // stalls legitimately inflate this job's cost
            }
            let shard = res.shard.clone().unwrap();
            let mut solo = Machine::new(shard.len(), cfg.mem_cap, cfg.base);
            let seq = Seq::range(shard.len());
            let leaf = leaf_ref(SchoolLeaf);
            execute_on(&mut solo, &cfg.time_model, &spec, &seq, &leaf).unwrap();
            assert_eq!(
                res.cost,
                solo.critical(),
                "zero-fault job {} must match the fault-free cost",
                spec.id
            );
        }
        sched.shutdown().unwrap();
    }

    #[test]
    fn safe_mode_final_attempt_completes_every_job() {
        // Crash-always plan: every first attempt dies at its first
        // allocation; the final attempt runs with injection suppressed
        // and completes. Successes reset the strike ledger, so the
        // machine's only shard is never quarantined away.
        use crate::sim::{FaultConfig, FaultKind};
        let cfg = SchedulerConfig {
            procs: 4,
            runners: 1,
            fault: Some(FaultConfig::new(0x0A11, 1.0).only(&[FaultKind::Crash])),
            max_attempts: 2,
            quarantine_after: 2,
            ..Default::default()
        };
        let sched = Scheduler::start(cfg, leaf_ref(SchoolLeaf)).unwrap();
        for id in 0..3u64 {
            let mut spec = JobSpec::new(id, vec![1; 32], vec![2; 32]);
            spec.procs = 4;
            spec.algo = Some(Algorithm::Copsim);
            let res = sched.submit_blocking(spec).unwrap();
            assert_eq!(res.attempts, 2, "job {id} must recover on the safe attempt");
        }
        assert_eq!(sched.quarantined_procs(), 0);
        assert!(sched.faults_injected() >= 3);
        sched.shutdown().unwrap();
    }

    #[test]
    fn quarantine_degrades_the_machine_instead_of_hanging() {
        // quarantine_after = 1 pulls three of the four processors after
        // the first crashed attempt (never below one live processor);
        // the retry then needs a 4-wide shard that no longer exists and
        // must fail with a degraded-machine error — not wait forever.
        use crate::sim::{FaultConfig, FaultKind};
        let cfg = SchedulerConfig {
            procs: 4,
            runners: 1,
            fault: Some(FaultConfig::new(0xDE6, 1.0).only(&[FaultKind::Crash])),
            max_attempts: 3,
            quarantine_after: 1,
            ..Default::default()
        };
        let sched = Scheduler::start(cfg, leaf_ref(SchoolLeaf)).unwrap();
        let mut spec = JobSpec::new(0, vec![1; 32], vec![2; 32]);
        spec.procs = 4;
        spec.algo = Some(Algorithm::Copsim);
        let err = sched.submit_blocking(spec).unwrap_err();
        assert!(
            err.to_string().contains("degraded"),
            "expected a degraded-machine error, got: {err}"
        );
        assert_eq!(sched.quarantined_procs(), 3);
        assert_eq!(sched.stats.failed.load(Ordering::Relaxed), 1);
        sched.shutdown().unwrap();
    }

    #[test]
    fn probation_dequarantines_and_counters_agree() {
        // Crash-always plan with quarantine_after = 1: the first 4-wide
        // job's failed attempts pull three of the four processors (never
        // below one live) and the job dies degraded. Probation must then
        // walk them back: K = 2 cycles of passing canaries re-admit all
        // three, the live ledger returns to zero, and the monotone event
        // counter keeps the history.
        use crate::sim::{FaultConfig, FaultKind};
        let cfg = SchedulerConfig {
            procs: 4,
            runners: 1,
            fault: Some(FaultConfig::new(0xDE6, 1.0).only(&[FaultKind::Crash])),
            max_attempts: 3,
            quarantine_after: 1,
            probation_successes: 2,
            ..Default::default()
        };
        let sched = Scheduler::start(cfg, leaf_ref(SchoolLeaf)).unwrap();
        let mut spec = JobSpec::new(0, vec![1; 32], vec![2; 32]);
        spec.procs = 4;
        spec.algo = Some(Algorithm::Copsim);
        sched.submit_blocking(spec.clone()).unwrap_err();
        // The skew the accounting fix closes: live ledger and event
        // counter agree while nothing has recovered yet...
        assert_eq!(sched.quarantined_procs(), 3);
        assert_eq!(sched.total_quarantine_events(), 3);
        assert_eq!(
            sched.quarantined_proc_ids().len() as u64,
            sched.quarantined_procs()
        );
        // First cycle: streak 1 of 2, nothing re-admitted yet.
        assert_eq!(sched.probe_quarantined(), 0);
        assert_eq!(sched.quarantined_procs(), 3);
        // Second cycle reaches the streak: all three return.
        assert_eq!(sched.probe_quarantined(), 3);
        assert_eq!(sched.quarantined_procs(), 0);
        assert!(sched.quarantined_proc_ids().is_empty());
        // ...and after the full quarantine -> probation -> recovery
        // cycle the live count reflects recovery while the monotone
        // event counter does not move.
        assert_eq!(sched.total_quarantine_events(), 3);
        assert_eq!(sched.stats.procs_dequarantined.load(Ordering::Relaxed), 3);
        assert_eq!(sched.stats.probes_sent.load(Ordering::Relaxed), 6);
        // An empty ledger makes further cycles a strict no-op.
        assert_eq!(sched.probe_quarantined(), 0);
        assert_eq!(sched.stats.probes_sent.load(Ordering::Relaxed), 6);
        // The recovered machine serves again (safe-mode final attempt
        // beats the still-armed crash plan on a 1-wide job).
        spec.id = 1;
        spec.procs = 1;
        sched.submit_blocking(spec).unwrap();
        sched.shutdown().unwrap();
    }

    #[test]
    fn work_stealing_reuses_freed_shards() {
        // 8 jobs over a 2-shard machine with 4 runners: every shard is
        // released and re-acquired; peak concurrency is capped by the
        // processor pool, not the runner count.
        let sched = Scheduler::start(
            SchedulerConfig {
                procs: 8,
                runners: 4,
                ..Default::default()
            },
            leaf_ref(SchoolLeaf),
        )
        .unwrap();
        let mut rng = Rng::new(0x57EA);
        let mut pending = Vec::new();
        for id in 0..8u64 {
            let a = rng.digits(256, 16);
            let b = rng.digits(256, 16);
            let mut spec = JobSpec::new(id, a, b);
            spec.procs = 4;
            spec.algo = Some(Algorithm::Copsim);
            pending.push(sched.submit(spec).unwrap());
        }
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        assert_eq!(sched.stats.shards_acquired.load(Ordering::Relaxed), 8);
        assert!(sched.stats.peak_concurrent.load(Ordering::Relaxed) <= 2);
        assert_eq!(sched.stats.completed.load(Ordering::Relaxed), 8);
        sched.shutdown().unwrap();
    }
}

//! Parallel DIFF with distributed memory (paper §4.3).
//!
//! `DIFF(P, A, B)` computes `C = |A - B|` partitioned like the inputs,
//! plus a flag `f ∈ {-1,0,1}` (sign of `A - B`) known to every
//! processor. After an initial `COMPARE` decides the orientation, the
//! subtraction runs with `DIFFL` on the lower half (the actual result
//! digits) and `DIFFR` on the upper half, which *speculatively* computes
//! both `A₁ - B₁` and `A₁ - B₁ - 1` so the borrow of the lower half can
//! be resolved with a single flag exchange per recursion level.
//!
//! Lemma 9: `T ≤ 7n/|P| + 5·log₂|P|`, `BW ≤ 5·log₂|P|`,
//! `L ≤ 3·log₂|P|`, memory ≤ `4n/|P| + 5`.
//!
//! Borrow convention: we track `b_i = 1` iff `A - B - i < 0` (a borrow
//! propagates out). The paper's `b''_i = 1(A₁ ≥ B₁ - i)` indicator is
//! the complement; the recurrences are isomorphic.

use super::{check_layout, dup_dist, fanout, select_consume};
use crate::bignum::core::sub_with_borrow;
use crate::error::Result;
use crate::primitives::compare::compare;
use crate::sim::{DistInt, MachineApi, Seq};

/// Output of the speculative branch `DIFFR`.
struct DiffrOut {
    /// `(A - B) mod s^w` and its borrow-out.
    c0: DistInt,
    b0: u32,
    /// `(A - B - 1) mod s^w` and its borrow-out.
    c1: DistInt,
    b1: u32,
}

fn diffr<M: MachineApi>(m: &mut M, seq: &Seq, a: &DistInt, b: &DistInt) -> Result<DiffrOut> {
    let p = seq.len();
    if p == 1 {
        let pid = seq.at(0);
        let (sa, sb) = (a.chunks[0].1, b.chunks[0].1);
        let (av, bv) = (m.read(pid, sa)?, m.read(pid, sb)?);
        let ((d0, b0), (d1, b1)) = m.local(pid, move |base, ops| {
            (
                sub_with_borrow(&av, &bv, 0, *base, ops),
                sub_with_borrow(&av, &bv, 1, *base, ops),
            )
        })?;
        return Ok(DiffrOut {
            c0: DistInt {
                chunk_width: a.chunk_width,
                chunks: vec![(pid, m.alloc(pid, d0)?)],
            },
            b0,
            c1: DistInt {
                chunk_width: a.chunk_width,
                chunks: vec![(pid, m.alloc(pid, d1)?)],
            },
            b1,
        });
    }

    let (lo_seq, hi_seq) = (seq.lower_half(), seq.upper_half());
    let (a0, a1) = a.split_half();
    let (b0d, b1d) = b.split_half();
    let lo = diffr(m, &lo_seq, &a0, &b0d)?;
    let hi = diffr(m, &hi_seq, &a1, &b1d)?;

    // Step 3: P'[j] sends (b0', b1') to P''[j].
    fanout(m, &lo_seq, &hi_seq, &[lo.b0, lo.b1])?;
    // Step 4: selection, up to 4 comparisons per receiving processor.
    for j in 0..hi_seq.len() {
        m.compute(hi_seq.at(j), 4);
    }
    let (c0_hi, c1_hi, b0, b1);
    if lo.b0 == lo.b1 {
        let chosen = select_consume(m, lo.b0 == 1, hi.c0, hi.c1);
        let dup = dup_dist(m, &chosen)?;
        c0_hi = chosen;
        c1_hi = dup;
        b0 = if lo.b0 == 1 { hi.b1 } else { hi.b0 };
        b1 = b0;
    } else {
        // Borrows are monotone: b0' = 0, b1' = 1.
        debug_assert!(lo.b0 == 0 && lo.b1 == 1);
        c0_hi = hi.c0;
        c1_hi = hi.c1;
        b0 = hi.b0;
        b1 = hi.b1;
    }
    // Step 5: send (b0, b1) back.
    fanout(m, &hi_seq, &lo_seq, &[b0, b1])?;
    Ok(DiffrOut {
        c0: DistInt::concat(lo.c0, c0_hi),
        b0,
        c1: DistInt::concat(lo.c1, c1_hi),
        b1,
    })
}

/// `DIFFL`: `(A - B) mod s^w` plus its borrow-out, for `A, B`
/// partitioned in `seq`. Internally the upper half speculates via
/// [`diffr`].
fn diffl<M: MachineApi>(
    m: &mut M,
    seq: &Seq,
    a: &DistInt,
    b: &DistInt,
) -> Result<(DistInt, u32)> {
    let p = seq.len();
    if p == 1 {
        let pid = seq.at(0);
        let (sa, sb) = (a.chunks[0].1, b.chunks[0].1);
        let (av, bv) = (m.read(pid, sa)?, m.read(pid, sb)?);
        let (d, bo) = m.local(pid, move |base, ops| sub_with_borrow(&av, &bv, 0, *base, ops))?;
        return Ok((
            DistInt {
                chunk_width: a.chunk_width,
                chunks: vec![(pid, m.alloc(pid, d)?)],
            },
            bo,
        ));
    }
    let (lo_seq, hi_seq) = (seq.lower_half(), seq.upper_half());
    let (a0, a1) = a.split_half();
    let (b0d, b1d) = b.split_half();
    let (c_lo, b_lo) = diffl(m, &lo_seq, &a0, &b0d)?;
    let hi = diffr(m, &hi_seq, &a1, &b1d)?;

    // Forward the lower borrow; select the matching speculative branch.
    fanout(m, &lo_seq, &hi_seq, &[b_lo])?;
    for j in 0..hi_seq.len() {
        m.compute(hi_seq.at(j), 2);
    }
    let c_hi = select_consume(m, b_lo == 1, hi.c0, hi.c1);
    let bo = if b_lo == 1 { hi.b1 } else { hi.b0 };
    fanout(m, &hi_seq, &lo_seq, &[bo])?;
    Ok((DistInt::concat(c_lo, c_hi), bo))
}

/// `DIFF(P, A, B)` — `C = |A - B|` and the sign flag `f` (see module
/// docs). The zero case materializes an all-zero `C` as the paper does.
pub fn diff<M: MachineApi>(
    m: &mut M,
    seq: &Seq,
    a: &DistInt,
    b: &DistInt,
) -> Result<(DistInt, i32)> {
    check_layout(seq, a, "DIFF a");
    check_layout(seq, b, "DIFF b");
    assert_eq!(a.chunk_width, b.chunk_width);

    let f = compare(m, seq, a, b)?;
    if f == 0 {
        let w = a.chunk_width;
        let mut chunks = Vec::with_capacity(seq.len());
        for j in 0..seq.len() {
            let pid = seq.at(j);
            m.compute(pid, w as u64); // "sets C(P[i]) = 0"
            chunks.push((pid, m.alloc(pid, vec![0u32; w])?));
        }
        return Ok((
            DistInt {
                chunk_width: w,
                chunks,
            },
            0,
        ));
    }
    let (x, y) = if f == 1 { (a, b) } else { (b, a) };
    if seq.len() == 1 {
        let pid = seq.at(0);
        let (sx, sy) = (x.chunks[0].1, y.chunks[0].1);
        let (xv, yv) = (m.read(pid, sx)?, m.read(pid, sy)?);
        let (d, bo) = m.local(pid, move |base, ops| sub_with_borrow(&xv, &yv, 0, *base, ops))?;
        debug_assert_eq!(bo, 0);
        return Ok((
            DistInt {
                chunk_width: x.chunk_width,
                chunks: vec![(pid, m.alloc(pid, d)?)],
            },
            f,
        ));
    }
    let (c, borrow) = diffl(m, seq, x, y)?;
    debug_assert_eq!(borrow, 0, "|A-B| with A >= B cannot borrow out");
    Ok((c, f))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bignum::convert::to_u128;
    use crate::bignum::Base;
    use crate::sim::Machine;
    use crate::theory;
    use crate::util::Rng;

    fn dist(m: &mut Machine, seq: &Seq, digits: &[u32]) -> DistInt {
        DistInt::scatter(m, seq, digits, digits.len() / seq.len()).unwrap()
    }

    #[test]
    fn diff_correct_small() {
        let base = Base::new(16);
        let mut m = Machine::unbounded(4, Base::new(16));
        let seq = Seq::range(4);
        let a = crate::bignum::convert::from_u128(0x1234_5678_9ABC_DEF0, 8, base);
        let b = crate::bignum::convert::from_u128(0x0FED_CBA9_8765_4321, 8, base);
        let (da, db) = (dist(&mut m, &seq, &a), dist(&mut m, &seq, &b));
        let (c, f) = diff(&mut m, &seq, &da, &db).unwrap();
        assert_eq!(f, 1);
        assert_eq!(
            to_u128(&c.gather(&m).unwrap(), base),
            0x1234_5678_9ABC_DEF0 - 0x0FED_CBA9_8765_4321
        );
        // Reversed: |B - A| with f = -1.
        let (c2, f2) = diff(&mut m, &seq, &db, &da).unwrap();
        assert_eq!(f2, -1);
        assert_eq!(c2.gather(&m).unwrap(), c.gather(&m).unwrap());
    }

    #[test]
    fn diff_zero_case() {
        let mut m = Machine::unbounded(2, Base::new(16));
        let seq = Seq::range(2);
        let a = vec![5, 6, 7, 8];
        let (da, db) = (dist(&mut m, &seq, &a), dist(&mut m, &seq, &a));
        let (c, f) = diff(&mut m, &seq, &da, &db).unwrap();
        assert_eq!(f, 0);
        assert_eq!(c.gather(&m).unwrap(), vec![0, 0, 0, 0]);
    }

    #[test]
    fn diff_randomized_vs_reference() {
        let base = Base::new(16);
        crate::util::prop::check("diff-vs-ref", 40, |rng| {
            let p = 1usize << rng.range(0, 4); // 1..16 procs
            let chunks = rng.range(1, 4) as usize;
            let n = p * chunks;
            let a = rng.digits(n, 16);
            let b = rng.digits(n, 16);
            let mut m = Machine::unbounded(p, base);
            let seq = Seq::range(p);
            let (da, db) = (dist(&mut m, &seq, &a), dist(&mut m, &seq, &b));
            let (c, f) = diff(&mut m, &seq, &da, &db).unwrap();
            let mut ops = crate::bignum::Ops::default();
            let (want_f, want) = crate::bignum::mul::abs_diff(&a, &b, base, &mut ops);
            crate::prop_assert_eq!(f, want_f);
            crate::prop_assert_eq!(c.gather(&m).unwrap(), want);
            Ok(())
        });
    }

    #[test]
    fn diff_cost_within_lemma9() {
        for &(p, n) in &[(2usize, 64usize), (8, 256), (32, 1024), (64, 4096)] {
            let mut rng = Rng::new(p as u64 ^ 0xD1FF);
            let mut m = Machine::unbounded(p, Base::new(16));
            let seq = Seq::range(p);
            let a = rng.digits(n, 16);
            let b = rng.digits(n, 16);
            let (da, db) = (dist(&mut m, &seq, &a), dist(&mut m, &seq, &b));
            diff(&mut m, &seq, &da, &db).unwrap();
            let c = m.critical();
            let bound = theory::lemma9_diff(n as u64, p as u64);
            assert!(c.ops <= bound.ops, "T p={p} n={n}: {} > {}", c.ops, bound.ops);
            // Lemma 9's BW ≤ 5logP / L ≤ 3logP inherit Lemma 8's
            // one-directional COMPARE count (see compare.rs); with the
            // flag return-broadcasts the prose specifies, the per-level
            // charge is ≤ 8 words / 6 messages. Assert those corrected
            // constants (+small additive slack for the final level) and
            // report the paper-vs-measured ratio in E3.
            let lp = (p as f64).log2().ceil() as u64;
            assert!(
                c.words <= 8 * lp + 4,
                "BW p={p} n={n}: {} > {}",
                c.words,
                8 * lp + 4
            );
            assert!(
                c.msgs <= 6 * lp + 4,
                "L p={p} n={n}: {} > {}",
                c.msgs,
                6 * lp + 4
            );
            let _ = bound;
            assert!(
                m.mem_peak_max() <= 4 * (n as u64 / p as u64) + 5,
                "M p={p} n={n}: {} > {}",
                m.mem_peak_max(),
                4 * (n as u64 / p as u64) + 5
            );
        }
    }
}

//! Parallel COMPARE with distributed memory (paper §4.2).
//!
//! `COMPARE(P, A, B)` leaves every processor holding a flag
//! `f ∈ {-1, 0, 1}`: 0 if `A = B`, 1 if `A > B`, -1 if `B > A`.
//!
//! Lemma 8: `T ≤ n/|P| + log₂|P|`, `BW ≤ log₂|P|`, `L ≤ log₂|P|`,
//! memory ≤ `2n/|P| + 2`.
//!
//! Note on the paper's step (4): the prose combines the half-flags as
//! `f = f'` if `f' ≠ 0` else `f''`, with `f'` the *lower*-half flag —
//! which would let less-significant digits override more-significant
//! ones. Positional comparison requires the opposite precedence
//! (`f = f''` if `f'' ≠ 0` else `f'`); we implement that and treat the
//! paper's formula as a prime-swap typo. Cost structure is identical.

use super::{check_layout, fanout};
use crate::bignum::core::cmp_digits;
use crate::error::Result;
use crate::sim::{DistInt, MachineApi, Seq};
use std::cmp::Ordering;

fn ord_to_flag(o: Ordering) -> i32 {
    match o {
        Ordering::Less => -1,
        Ordering::Equal => 0,
        Ordering::Greater => 1,
    }
}

fn compare_rec<M: MachineApi>(m: &mut M, seq: &Seq, a: &DistInt, b: &DistInt) -> Result<i32> {
    let p = seq.len();
    if p == 1 {
        let pid = seq.at(0);
        let (sa, sb) = (a.chunks[0].1, b.chunks[0].1);
        let (av, bv) = (m.read(pid, sa)?, m.read(pid, sb)?);
        let f = m.local(pid, move |_base, ops| ord_to_flag(cmp_digits(&av, &bv, ops)))?;
        return Ok(f);
    }
    let (lo_seq, hi_seq) = (seq.lower_half(), seq.upper_half());
    let (a0, a1) = a.split_half();
    let (b0, b1) = b.split_half();
    // Parallel recursion on disjoint halves.
    let f_lo = compare_rec(m, &lo_seq, &a0, &b0)?;
    let f_hi = compare_rec(m, &hi_seq, &a1, &b1)?;

    // Step 3: P'[i] sends f' to P''[i] (transient 1-word storage).
    fanout(m, &lo_seq, &hi_seq, &[f_lo as u32])?;
    // Step 4: combine (1 comparison per receiving processor; the more
    // significant half dominates — see module docs).
    for i in 0..hi_seq.len() {
        m.compute(hi_seq.at(i), 1);
    }
    let f = if f_hi != 0 { f_hi } else { f_lo };
    // Step 5: P''[i] sends f back so all of P holds the flag.
    fanout(m, &hi_seq, &lo_seq, &[f as u32])?;
    Ok(f)
}

/// `COMPARE(P, A, B)` — see module docs.
pub fn compare<M: MachineApi>(m: &mut M, seq: &Seq, a: &DistInt, b: &DistInt) -> Result<i32> {
    check_layout(seq, a, "COMPARE a");
    check_layout(seq, b, "COMPARE b");
    assert_eq!(a.chunk_width, b.chunk_width);
    compare_rec(m, seq, a, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bignum::Base;
    use crate::sim::Machine;
    use crate::theory;
    use crate::util::Rng;

    fn dist(m: &mut Machine, seq: &Seq, digits: &[u32]) -> DistInt {
        DistInt::scatter(m, seq, digits, digits.len() / seq.len()).unwrap()
    }

    #[test]
    fn compare_all_outcomes() {
        let mut m = Machine::unbounded(4, Base::new(16));
        let seq = Seq::range(4);
        let x = vec![1, 2, 3, 4, 5, 6, 7, 8];
        let mut y = x.clone();
        let (da, db) = (dist(&mut m, &seq, &x), dist(&mut m, &seq, &y));
        assert_eq!(compare(&mut m, &seq, &da, &db).unwrap(), 0);
        // Bump a high digit of y: y > x.
        y[7] += 1;
        let dy = dist(&mut m, &seq, &y);
        assert_eq!(compare(&mut m, &seq, &da, &dy).unwrap(), -1);
        assert_eq!(compare(&mut m, &seq, &dy, &da).unwrap(), 1);
    }

    #[test]
    fn high_digits_dominate_low() {
        // Regression for the paper's prime-swap typo: A has a larger
        // LOW half but smaller HIGH half; B must win.
        let mut m = Machine::unbounded(2, Base::new(16));
        let seq = Seq::range(2);
        let a = vec![9, 9, 1, 0]; // low chunk [9,9], high chunk [1,0]
        let b = vec![0, 0, 2, 0];
        let (da, db) = (dist(&mut m, &seq, &a), dist(&mut m, &seq, &b));
        assert_eq!(compare(&mut m, &seq, &da, &db).unwrap(), -1);
    }

    #[test]
    fn compare_cost_within_lemma8() {
        for &(p, n) in &[(2usize, 64usize), (8, 256), (32, 1024)] {
            let mut rng = Rng::new(p as u64);
            let mut m = Machine::unbounded(p, Base::new(16));
            let seq = Seq::range(p);
            let a = rng.digits(n, 16);
            let b = rng.digits(n, 16);
            let (da, db) = (dist(&mut m, &seq, &a), dist(&mut m, &seq, &b));
            compare(&mut m, &seq, &da, &db).unwrap();
            let c = m.critical();
            let bound = theory::lemma8_compare(n as u64, p as u64);
            assert!(c.ops <= bound.ops, "T: {} > {}", c.ops, bound.ops);
            // Lemma 8 states BW, L <= log2 P, but the algorithm's own
            // step (5) sends the resolved flag *back* each level, which
            // costs another log2 P words/messages (Lemma 7 for SUM does
            // count both directions: 4 log P). We assert the corrected
            // constant 2·log2 P and report the discrepancy in E2.
            assert!(c.words <= 2 * bound.words, "BW: {} > {}", c.words, 2 * bound.words);
            assert!(c.msgs <= 2 * bound.msgs, "L: {} > {}", c.msgs, 2 * bound.msgs);
            assert!(m.mem_peak_max() <= 2 * (n as u64 / p as u64) + 2);
        }
    }
}

//! Parallel algorithmic components (paper §4): distributed-memory
//! big-integer SUM, COMPARE and DIFF over processor sequences.
//!
//! All three follow the same recursive pattern: split the processor
//! sequence into the lower half `P'` (least-significant digits) and the
//! upper half `P''`; recurse in parallel; the upper half *speculatively
//! pre-computes* every possible continuation (both carry values for SUM,
//! both borrow values for DIFF) so that the only cross-half dependency
//! is a single flag exchange per level. This is the paper's key device
//! for breaking the apparently sequential carry/borrow chain, and it is
//! what bounds the critical-path communication by `O(log P)` words
//! (Lemmas 7-9).
//!
//! Layout conventions: operands are [`DistInt`]s whose chunk owners are
//! exactly the processors of the sequence, in order (chunk `j` on
//! `seq[j]`). Results come back in the same layout.

pub mod compare;
pub mod diff;
pub mod sum;

pub use compare::compare;
pub use diff::diff;
pub use sum::{sum, sum_many};

use crate::sim::{DistInt, MachineApi, Seq};

/// Deliver a small payload (flags/carries) held by every processor of
/// `src_seq` to every processor of `dst_seq`.
///
/// When the sequences have equal length this is the paper's single
/// parallel pairwise exchange (`P'[j] sends to P''[j]`): one message
/// round. With uneven halves (COPSIM recomposes on `3P/4` processors,
/// so one recursion level splits unevenly) the uncovered tail of
/// `dst_seq` is filled by doubling rounds among the receivers —
/// `O(log)` extra latency only at the uneven levels.
pub(crate) fn fanout<M: MachineApi>(
    m: &mut M,
    src_seq: &Seq,
    dst_seq: &Seq,
    payload: &[u32],
) -> crate::error::Result<()> {
    let f = src_seq.len().min(dst_seq.len());
    // Round 0: pairwise.
    for j in 0..f {
        let s = m.send(src_seq.at(j), dst_seq.at(j), payload.to_vec())?;
        m.free(dst_seq.at(j), s);
    }
    // Doubling rounds among dst for the uncovered tail.
    let mut have = f;
    while have < dst_seq.len() {
        let take = have.min(dst_seq.len() - have);
        for j in 0..take {
            let s = m.send(dst_seq.at(j), dst_seq.at(have + j), payload.to_vec())?;
            m.free(dst_seq.at(have + j), s);
        }
        have += take;
    }
    Ok(())
}

/// Check the operand layout invariant shared by all primitives.
pub(crate) fn check_layout(seq: &Seq, x: &DistInt, what: &str) {
    assert_eq!(
        x.chunks.len(),
        seq.len(),
        "{what}: operand has {} chunks for |P| = {}",
        x.chunks.len(),
        seq.len()
    );
    for (j, &(p, _)) in x.chunks.iter().enumerate() {
        assert_eq!(
            p,
            seq.at(j),
            "{what}: chunk {j} owned by {p}, expected {}",
            seq.at(j)
        );
    }
}

/// Duplicate a distributed value chunk-by-chunk on the same owners
/// (memory charged; no communication, no digit ops — an in-memory copy).
pub(crate) fn dup_dist<M: MachineApi>(m: &mut M, x: &DistInt) -> crate::error::Result<DistInt> {
    let mut chunks = Vec::with_capacity(x.chunks.len());
    for &(p, slot) in &x.chunks {
        let data = m.read(p, slot)?;
        let s = m.alloc(p, data)?;
        chunks.push((p, s));
    }
    Ok(DistInt {
        chunk_width: x.chunk_width,
        chunks,
    })
}

/// Select between two speculative distributed values: keep `c1` if
/// `take_one`, else `c0`; free the other. If both outputs of a caller
/// need the *same* branch, use [`dup_dist`] first.
pub(crate) fn select_consume<M: MachineApi>(
    m: &mut M,
    take_one: bool,
    c0: DistInt,
    c1: DistInt,
) -> DistInt {
    if take_one {
        c0.free(m);
        c1
    } else {
        c1.free(m);
        c0
    }
}

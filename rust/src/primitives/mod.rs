//! Parallel algorithmic components (paper §4): distributed-memory
//! big-integer SUM, COMPARE and DIFF over processor sequences.
//!
//! All three follow the same recursive pattern: split the processor
//! sequence into the lower half `P'` (least-significant digits) and the
//! upper half `P''`; recurse in parallel; the upper half *speculatively
//! pre-computes* every possible continuation (both carry values for SUM,
//! both borrow values for DIFF) so that the only cross-half dependency
//! is a single flag exchange per level. This is the paper's key device
//! for breaking the apparently sequential carry/borrow chain, and it is
//! what bounds the critical-path communication by `O(log P)` words
//! (Lemmas 7-9).
//!
//! Layout conventions: operands are [`DistInt`]s whose chunk owners are
//! exactly the processors of the sequence, in order (chunk `j` on
//! `seq[j]`). Results come back in the same layout.

pub mod compare;
pub mod diff;
pub mod sum;

pub use compare::compare;
pub use diff::diff;
pub use sum::{sum, sum_many};

use crate::sim::{DistInt, MachineApi, Seq};

// The per-level flag exchange of SUM/COMPARE/DIFF is the shared
// `fanout` collective (pairwise round + doubling tail); it lives in
// `sim::collectives` with the other tree schedules so its message
// bound is pinned once, next to broadcast/gather/scatter/reduce.
pub(crate) use crate::sim::collectives::fanout;

/// Check the operand layout invariant shared by all primitives.
pub(crate) fn check_layout(seq: &Seq, x: &DistInt, what: &str) {
    assert_eq!(
        x.chunks.len(),
        seq.len(),
        "{what}: operand has {} chunks for |P| = {}",
        x.chunks.len(),
        seq.len()
    );
    for (j, &(p, _)) in x.chunks.iter().enumerate() {
        assert_eq!(
            p,
            seq.at(j),
            "{what}: chunk {j} owned by {p}, expected {}",
            seq.at(j)
        );
    }
}

/// Duplicate a distributed value chunk-by-chunk on the same owners
/// (memory charged; no communication, no digit ops — an in-memory copy).
pub(crate) fn dup_dist<M: MachineApi>(m: &mut M, x: &DistInt) -> crate::error::Result<DistInt> {
    let mut chunks = Vec::with_capacity(x.chunks.len());
    for &(p, slot) in &x.chunks {
        let data = m.read(p, slot)?;
        let s = m.alloc(p, data)?;
        chunks.push((p, s));
    }
    Ok(DistInt {
        chunk_width: x.chunk_width,
        chunks,
    })
}

/// Select between two speculative distributed values: keep `c1` if
/// `take_one`, else `c0`; free the other. If both outputs of a caller
/// need the *same* branch, use [`dup_dist`] first.
pub(crate) fn select_consume<M: MachineApi>(
    m: &mut M,
    take_one: bool,
    c0: DistInt,
    c1: DistInt,
) -> DistInt {
    if take_one {
        c0.free(m);
        c1
    } else {
        c1.free(m);
        c0
    }
}

//! Parallel SUM with distributed memory (paper §4.1).
//!
//! `SUM(P, A, B)` computes `C = A + B` with `C mod s^n` partitioned in
//! `P` like the inputs and the final carry `v ∈ {0,1}` known to every
//! processor. The auxiliary `SUMA` run by the upper half speculatively
//! computes both `(A'+B'+i) mod s^(n/2)` and carries `u_i` for
//! `i ∈ {0,1}`, so each recursion level only exchanges the pair
//! `(u_0, u_1)` (and the resolved carry on the way back).
//!
//! Lemma 7: with chunk width `w = n/|P|`,
//! `T ≤ 6n/|P| + 4·log₂|P|`, `BW ≤ 4·log₂|P|`, `L ≤ 2·log₂|P|`,
//! memory per processor ≤ `4(n/|P| + 1)`.

use super::{check_layout, dup_dist, fanout, select_consume};
use crate::bignum::core::add_with_carry;
use crate::error::Result;
use crate::sim::{DistInt, MachineApi, Seq};

/// Output of the speculative branch: both possible sums and carries.
struct SumaOut {
    c0: DistInt,
    c1: DistInt,
    u0: u32,
    u1: u32,
}

/// `SUMA(P, A, B)` (see module docs). Both inputs partitioned in `seq`.
fn suma<M: MachineApi>(m: &mut M, seq: &Seq, a: &DistInt, b: &DistInt) -> Result<SumaOut> {
    let p = seq.len();
    if p == 1 {
        let pid = seq.at(0);
        let (&(_, sa), &(_, sb)) = (&a.chunks[0], &b.chunks[0]);
        let (av, bv) = (m.read(pid, sa)?, m.read(pid, sb)?);
        let ((d0, u0), (d1, u1)) = m.local(pid, move |base, ops| {
            (
                add_with_carry(&av, &bv, 0, *base, ops),
                add_with_carry(&av, &bv, 1, *base, ops),
            )
        })?;
        let c0 = DistInt {
            chunk_width: a.chunk_width,
            chunks: vec![(pid, m.alloc(pid, d0)?)],
        };
        let c1 = DistInt {
            chunk_width: a.chunk_width,
            chunks: vec![(pid, m.alloc(pid, d1)?)],
        };
        return Ok(SumaOut { c0, c1, u0, u1 });
    }

    let (lo_seq, hi_seq) = (seq.lower_half(), seq.upper_half());
    let (a0, a1) = a.split_half();
    let (b0, b1) = b.split_half();
    // Parallel recursion on disjoint processor halves (costs land on
    // disjoint clocks; see sim module docs).
    let lo = suma(m, &lo_seq, &a0, &b0)?;
    let hi = suma(m, &hi_seq, &a1, &b1)?;

    // Step 3: each P'[j] sends (u0', u1') to P''[j] (transient storage
    // charged inside fanout), then selects (≤ 4 comparisons each).
    fanout(m, &lo_seq, &hi_seq, &[lo.u0, lo.u1])?;
    for j in 0..hi_seq.len() {
        m.compute(hi_seq.at(j), 4);
    }
    // C0 continues with carry u0' into the high half; C1 with u1'.
    let (c0_hi, c1_hi, u0, u1);
    if lo.u0 == lo.u1 {
        // Both continuations select the same speculative branch.
        let chosen = select_consume(m, lo.u0 == 1, hi.c0, hi.c1);
        let dup = dup_dist(m, &chosen)?;
        c0_hi = chosen;
        c1_hi = dup;
        u0 = if lo.u0 == 1 { hi.u1 } else { hi.u0 };
        u1 = u0;
    } else {
        // u0' = 0, u1' = 1 (carries are monotone): C0 takes the i=0
        // branch, C1 the i=1 branch.
        debug_assert!(lo.u0 == 0 && lo.u1 == 1);
        c0_hi = hi.c0;
        c1_hi = hi.c1;
        u0 = hi.u0;
        u1 = hi.u1;
    }
    // Step 4: P''[j] sends (u0, u1) back to P'[j].
    fanout(m, &hi_seq, &lo_seq, &[u0, u1])?;
    Ok(SumaOut {
        c0: DistInt::concat(lo.c0, c0_hi),
        c1: DistInt::concat(lo.c1, c1_hi),
        u0,
        u1,
    })
}

/// `SUM(P, A, B)` — parallel addition. Returns `(C, v)` with
/// `C = (A + B) mod s^n` partitioned in `seq` like the inputs and
/// `v = ⌊(A+B)/s^n⌋ ∈ {0,1}` the most-significant (carry) digit.
pub fn sum<M: MachineApi>(
    m: &mut M,
    seq: &Seq,
    a: &DistInt,
    b: &DistInt,
) -> Result<(DistInt, u32)> {
    check_layout(seq, a, "SUM a");
    check_layout(seq, b, "SUM b");
    assert_eq!(a.chunk_width, b.chunk_width, "SUM operand widths differ");
    let p = seq.len();

    if p == 1 {
        let pid = seq.at(0);
        let (sa, sb) = (a.chunks[0].1, b.chunks[0].1);
        let (av, bv) = (m.read(pid, sa)?, m.read(pid, sb)?);
        let (d, v) = m.local(pid, move |base, ops| add_with_carry(&av, &bv, 0, *base, ops))?;
        let c = DistInt {
            chunk_width: a.chunk_width,
            chunks: vec![(pid, m.alloc(pid, d)?)],
        };
        return Ok((c, v));
    }

    let (lo_seq, hi_seq) = (seq.lower_half(), seq.upper_half());
    let (a0, a1) = a.split_half();
    let (b0, b1) = b.split_half();
    // SUM on the low half and SUMA on the high half run in parallel.
    let (c_lo, v_lo) = sum(m, &lo_seq, &a0, &b0)?;
    let hi = suma(m, &hi_seq, &a1, &b1)?;

    // Step 3: P'[j] sends v' to P''[j].
    fanout(m, &lo_seq, &hi_seq, &[v_lo])?;
    // Step 4: selection at the high half (≤ 2 comparisons each).
    for j in 0..hi_seq.len() {
        m.compute(hi_seq.at(j), 2);
    }
    let c_hi = select_consume(m, v_lo == 1, hi.c0, hi.c1);
    let v = if v_lo == 1 { hi.u1 } else { hi.u0 };
    // Step 5: P''[j] sends v back to P'[j] so every processor knows the
    // most significant digit of C.
    fanout(m, &hi_seq, &lo_seq, &[v])?;
    Ok((DistInt::concat(c_lo, c_hi), v))
}

/// Sum of `k >= 2` addends by chained applications of [`sum`] (the paper:
/// "the procedure can be easily extended to more addends; the cost
/// scales linearly"). Carries of intermediate sums are folded into the
/// running carry count, which is returned alongside
/// `C = (Σ X_i) mod s^n`. The caller arranges widths so the total fits
/// (as COPSIM's recomposition does); `carry` reports the overflow.
pub fn sum_many<M: MachineApi>(m: &mut M, seq: &Seq, xs: &[&DistInt]) -> Result<(DistInt, u32)> {
    assert!(xs.len() >= 2);
    let (mut acc, mut carry) = sum(m, seq, xs[0], xs[1])?;
    for x in &xs[2..] {
        let (next, v) = sum(m, seq, &acc, x)?;
        acc.free(m);
        acc = next;
        carry += v;
    }
    Ok((acc, carry))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bignum::convert::{from_u128, to_u128};
    use crate::bignum::Base;
    use crate::sim::Machine;
    use crate::theory;
    use crate::util::Rng;

    fn setup(p: usize, n: usize, seed: u64) -> (Machine, Seq, Vec<u32>, Vec<u32>) {
        let mut rng = Rng::new(seed);
        let m = Machine::unbounded(p, Base::new(16));
        let seq = Seq::range(p);
        let a = rng.digits(n, 16);
        let b = rng.digits(n, 16);
        (m, seq, a, b)
    }

    fn run_sum(p: usize, n: usize, seed: u64) -> (Machine, Vec<u32>, u32, Vec<u32>, Vec<u32>) {
        let (mut m, seq, a, b) = setup(p, n, seed);
        let w = n / p;
        let da = DistInt::scatter(&mut m, &seq, &a, w).unwrap();
        let db = DistInt::scatter(&mut m, &seq, &b, w).unwrap();
        let (c, v) = sum(&mut m, &seq, &da, &db).unwrap();
        let digits = c.gather(&m).unwrap();
        (m, digits, v, a, b)
    }

    #[test]
    fn sum_correct_various() {
        for &(p, n) in &[(1usize, 8usize), (2, 8), (4, 16), (8, 64), (16, 64), (32, 256)] {
            let (_, c, v, a, b) = run_sum(p, n, 42 + p as u64);
            let base = Base::new(16);
            if n <= 7 {
                let want = to_u128(&a, base) + to_u128(&b, base);
                let mut full = c.clone();
                full.push(v);
                assert_eq!(to_u128(&full, base), want, "p={p} n={n}");
            } else {
                // Verify via digit-wise reference addition.
                let mut ops = crate::bignum::Ops::default();
                let (want, carry) =
                    add_with_carry(&a, &b, 0, base, &mut ops);
                assert_eq!(c, want, "p={p} n={n}");
                assert_eq!(v, carry);
            }
        }
    }

    #[test]
    fn sum_cost_within_lemma7() {
        for &(p, n) in &[(2usize, 64usize), (4, 64), (8, 64), (16, 256), (64, 1024)] {
            let (m, ..) = run_sum(p, n, 7);
            let c = m.critical();
            let b = theory::lemma7_sum(n as u64, p as u64);
            assert!(c.ops <= b.ops, "T p={p} n={n}: {} > {}", c.ops, b.ops);
            assert!(c.words <= b.words, "BW p={p} n={n}: {} > {}", c.words, b.words);
            assert!(c.msgs <= b.msgs, "L p={p} n={n}: {} > {}", c.msgs, b.msgs);
            // Memory requirement from Lemma 7: 4(n/|P| + 1).
            assert!(
                m.mem_peak_max() <= 4 * (n as u64 / p as u64 + 1),
                "M p={p} n={n}: {} > {}",
                m.mem_peak_max(),
                4 * (n as u64 / p as u64 + 1)
            );
        }
    }

    #[test]
    fn sum_many_correct() {
        let mut m = Machine::unbounded(4, Base::new(16));
        let seq = Seq::range(4);
        let base = Base::new(16);
        let xs: Vec<u128> = vec![0xFFFF_FFFF_FFFF, 0x1234_5678, 0xFEDC_BA98_7654_3210];
        let dists: Vec<DistInt> = xs
            .iter()
            .map(|&v| {
                let d = from_u128(v, 16, base);
                DistInt::scatter(&mut m, &seq, &d, 4).unwrap()
            })
            .collect();
        let refs: Vec<&DistInt> = dists.iter().collect();
        let (c, carry) = sum_many(&mut m, &seq, &refs).unwrap();
        let got = to_u128(&c.gather(&m).unwrap(), base) + ((carry as u128) << 64);
        assert_eq!(got, xs.iter().sum::<u128>());
    }

    #[test]
    fn sum_critical_path_scales() {
        // Strong scaling of the compute term: quadrupling P with fixed n
        // must cut the ops term roughly in proportion (plus log terms).
        let (m4, ..) = run_sum(4, 4096, 9);
        let (m64, ..) = run_sum(64, 4096, 9);
        assert!(
            m64.critical().ops * 8 < m4.critical().ops * 16,
            "no speedup: P=4 {} vs P=64 {}",
            m4.critical().ops,
            m64.critical().ops
        );
    }
}

//! Sequential integer multiplication: the recursion leaves of COPSIM/COPK.
//!
//! * [`mul_school`] — iterative schoolbook. Physically it dispatches to
//!   the active rung of the kernel ladder ([`super::arch`]) — packed
//!   limbs, u128 columns, or SIMD columns, selected once per process —
//!   while charging the model's exact digit-at-a-time count in closed
//!   form (`2·|a|·|b|`), so the ledger never sees the representation.
//!   The digit-at-a-time loop survives as [`mul_school_reference`], the
//!   correctness-and-cost oracle every rung is pinned against.
//! * [`slim`] — the paper's recursive long multiplication `SLIM` (§5):
//!   four half-size subproducts combined by shifted additions. Fact 10
//!   bounds it by `8n²` digit ops and `8n` words of space.
//! * [`skim`] — the paper's Karatsuba `SKIM` (§6): three subproducts
//!   `A0·B0`, `|A0−A1|·|B1−B0|` (with sign), `A1·B1`. Fact 13 bounds it by
//!   `16·n^(log₂3)` digit ops and `8n` words of space.
//!
//! All functions return the full `len(a) + len(b)`-digit product
//! (LSB-first, not trimmed) and charge exact digit-operation counts.

use super::core::{add_into_width, add_with_carry, cmp_digits, sub_with_borrow};
use super::{arch, Base, Ops};
use std::cmp::Ordering;

/// Iterative schoolbook product. Exact for any widths. Charges one op
/// per digit-multiply and one per digit-add of the accumulation —
/// `2·|a|·|b|` in closed form (identical to the per-row total the
/// digit-at-a-time loop accrues, zero rows included: the model counts
/// the worst case). Physically runs whichever rung of the kernel
/// ladder ([`arch::active`]) this process selected at startup.
pub fn mul_school(a: &[u32], b: &[u32], base: Base, ops: &mut Ops) -> Vec<u32> {
    let (na, nb) = (a.len(), b.len());
    ops.charge(2 * na as u64 * nb as u64);
    if na == 0 || nb == 0 {
        return vec![0u32; na + nb];
    }
    (arch::active().mul)(a, b, base)
}

/// The digit-at-a-time schoolbook loop with its original per-row
/// charging — kept as the oracle `tests/packed_kernels.rs` pins every
/// ladder rung against (products AND exact op totals), and as the
/// scalar baseline of the `copmul bench` kernel table. The loop itself
/// lives in [`arch::reference`], rung 0 of the ladder.
pub fn mul_school_reference(a: &[u32], b: &[u32], base: Base, ops: &mut Ops) -> Vec<u32> {
    let (na, nb) = (a.len(), b.len());
    if na == 0 || nb == 0 {
        return vec![0u32; na + nb];
    }
    // One digit-multiply and one digit-add per column, charged row by
    // row as the original loop did; zero rows are skipped physically
    // but charged all the same (the model's worst case).
    for _ in 0..na {
        ops.charge(2 * nb as u64);
    }
    arch::reference::mul(a, b, base)
}

/// The per-base leaf widths of the recursive multipliers — the applied
/// PR-6 re-tune of what used to be a single `LEAF_WIDTH = 64`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LeafWidths {
    /// Direct-multiply threshold for [`slim`] (and SLIM-shaped leaves).
    pub slim: usize,
    /// Direct-multiply threshold for [`skim`] and [`mul_hybrid`].
    pub skim: usize,
}

/// Width below which the recursive algorithms multiply directly, per
/// base and per scheme. A width of 1 reproduces the paper's recursions
/// exactly; larger leaves trade recursion overhead for direct-multiply
/// work at the full speed of the active kernel rung.
///
/// **Re-tune (PR 6), applied** — PR 5 recorded but deferred this. The
/// kernel ladder makes a direct leaf multiply `m²`-fold cheaper in
/// hardware (`m = ⌊64/k⌋` digits per limb on the u128 rung), moving the
/// wall-clock crossover far above 64, so the leaf scales with `m`:
///
/// * `slim = min(64·m, 1024)` → 256 / 512 / 1024 at bases 2^16 / 2^8 /
///   2^4. SLIM's direct leaf charges `2w² ≤ 8w²` (Fact 10's own leaf
///   constant), so a bigger slim leaf strictly *lowers* charged T; the
///   1024 cap only bounds leaf scratch.
/// * `skim = min(64·m, 128)` → 128 at every base. Karatsuba is capped
///   by Fact 13's pinned constant: the direct leaf must satisfy
///   `2w² ≤ 16·w^(log₂3)`, i.e. `w ≤ 150`, so 128 is the largest
///   power-of-two leaf that keeps the `16·n^(log₂3)` bound intact.
///   (The wall-clock optimum from `leaf_width_sweep` is higher; the
///   paper constant, not the hardware, binds here — documented cap.)
///
/// Changing these values changes charged T (recursion depth is
/// cost-visible), which is why this re-tune came with the repo's first
/// deliberate golden re-bless — before/after triples and the exact
/// sweep evidence are recorded in DESIGN.md ("Leaf-width re-tune",
/// reproducible via `python/tools/leaf_tune_model.py` and
/// `copmul bench --json`'s `leaf_width_sweep` table).
pub fn leaf_widths(base: Base) -> LeafWidths {
    let m = (64 / base.log2).max(1) as usize;
    LeafWidths {
        slim: (64 * m).min(1024),
        skim: (64 * m).min(128),
    }
}

/// `SLIM` — recursive long multiplication (paper §5, Fact 10).
///
/// Requires `a.len() == b.len() == n` with `n` a power of two (the paper
/// pads otherwise; callers pad via [`super::convert::pad_pow2`]).
/// Returns the `2n`-digit product.
pub fn slim(a: &[u32], b: &[u32], base: Base, ops: &mut Ops) -> Vec<u32> {
    slim_with_leaf(a, b, base, ops, leaf_widths(base).slim)
}

/// [`slim`] with an explicit leaf width — the bench harness's
/// leaf-width sweep. The shipped entry point is `slim_with_leaf(...,
/// leaf_widths(base).slim)`; any other width changes the charged T
/// (see [`leaf_widths`]).
pub fn slim_with_leaf(
    a: &[u32],
    b: &[u32],
    base: Base,
    ops: &mut Ops,
    leaf_width: usize,
) -> Vec<u32> {
    let n = a.len();
    assert_eq!(n, b.len(), "SLIM requires equal widths");
    assert!(n.is_power_of_two(), "SLIM requires power-of-two width");
    if n <= leaf_width.max(1) {
        return mul_school(a, b, base, ops);
    }
    let h = n / 2;
    let (a0, a1) = (&a[..h], &a[h..]);
    let (b0, b1) = (&b[..h], &b[h..]);
    // Four recursive subproducts (each n digits wide).
    let c0 = slim_with_leaf(a0, b0, base, ops, leaf_width);
    let c1 = slim_with_leaf(a0, b1, base, ops, leaf_width);
    let c2 = slim_with_leaf(a1, b0, base, ops, leaf_width);
    let c3 = slim_with_leaf(a1, b1, base, ops, leaf_width);
    // C = C0 + s^h (C1 + C2) + s^n C3, assembled into 2n digits.
    let mut out = vec![0u32; 2 * n];
    out[..2 * h].copy_from_slice(&c0);
    add_into_width(&mut out, &c1, h, base, ops);
    add_into_width(&mut out, &c2, h, base, ops);
    add_into_width(&mut out, &c3, n, base, ops);
    out
}

/// `SKIM` — recursive Karatsuba multiplication (paper §6, Fact 13).
///
/// Same width requirements as [`slim`]. Returns the `2n`-digit product.
///
/// Recursion per the paper: `C0 = A0·B0`, `C' = |A0−A1|·|B1−B0|` with sign
/// `f_A·f_B`, `C2 = A1·B1`; then `C1 = f_A·f_B·C' + C0 + C2` and
/// `C = C0 + s^(n/2)·C1 + s^n·C2`.
pub fn skim(a: &[u32], b: &[u32], base: Base, ops: &mut Ops) -> Vec<u32> {
    skim_with_leaf(a, b, base, ops, leaf_widths(base).skim)
}

/// [`skim`] with an explicit leaf width — the bench harness's
/// leaf-width sweep (see [`slim_with_leaf`]).
pub fn skim_with_leaf(
    a: &[u32],
    b: &[u32],
    base: Base,
    ops: &mut Ops,
    leaf_width: usize,
) -> Vec<u32> {
    let n = a.len();
    assert_eq!(n, b.len(), "SKIM requires equal widths");
    assert!(n.is_power_of_two(), "SKIM requires power-of-two width");
    if n <= leaf_width.max(1) {
        return mul_school(a, b, base, ops);
    }
    let h = n / 2;
    let (a0, a1) = (&a[..h], &a[h..]);
    let (b0, b1) = (&b[..h], &b[h..]);

    // |A0 - A1| with sign f_A, |B1 - B0| with sign f_B.
    let (fa, ad) = abs_diff(a0, a1, base, ops);
    let (fb, bd) = abs_diff(b1, b0, base, ops);

    let c0 = skim_with_leaf(a0, b0, base, ops, leaf_width);
    let c2 = skim_with_leaf(a1, b1, base, ops, leaf_width);
    let cp = skim_with_leaf(&ad, &bd, base, ops, leaf_width);
    let sign = fa * fb; // sign of (A0-A1)(B1-B0)

    // C = C0 + s^h (C0 + C2 ± C') + s^n C2
    let mut out = vec![0u32; 2 * n];
    out[..2 * h].copy_from_slice(&c0);
    add_into_width(&mut out, &c0, h, base, ops);
    add_into_width(&mut out, &c2, h, base, ops);
    add_into_width(&mut out, &c2, n, base, ops);
    match sign.cmp(&0) {
        Ordering::Greater => add_into_width(&mut out, &cp, h, base, ops),
        Ordering::Less => sub_into_width(&mut out, &cp, h, base, ops),
        Ordering::Equal => {}
    }
    out
}

/// `|x - y|` plus a sign flag in {-1, 0, 1} (1 if x > y).
/// Both operands must share a width; the result has that width.
pub fn abs_diff(x: &[u32], y: &[u32], base: Base, ops: &mut Ops) -> (i32, Vec<u32>) {
    match cmp_digits(x, y, ops) {
        Ordering::Equal => (0, vec![0u32; x.len()]),
        Ordering::Greater => {
            let (d, bo) = sub_with_borrow(x, y, 0, base, ops);
            debug_assert_eq!(bo, 0);
            (1, d)
        }
        Ordering::Less => {
            let (d, bo) = sub_with_borrow(y, x, 0, base, ops);
            debug_assert_eq!(bo, 0);
            (-1, d)
        }
    }
}

/// Subtract `src` from `dst` at digit offset `off`, borrowing through
/// `dst`. The overall value must stay non-negative (guaranteed when
/// subtracting C' in Karatsuba). Charges one op per touched digit —
/// batched into a single counter update at the end (the touched-digit
/// count is data-dependent through the borrow chain, so it is counted,
/// not closed-form; the total is identical to per-digit charging).
fn sub_into_width(dst: &mut [u32], src: &[u32], off: usize, base: Base, ops: &mut Ops) {
    let mut borrow = 0i64;
    let mut i = 0;
    let s = base.s() as i64;
    while i < src.len() || borrow != 0 {
        let d = off + i;
        assert!(d < dst.len(), "sub_into_width underflow past top digit");
        let sub = if i < src.len() { src[i] as i64 } else { 0 };
        let mut t = dst[d] as i64 - sub - borrow;
        if t < 0 {
            t += s;
            borrow = 1;
        } else {
            borrow = 0;
        }
        dst[d] = t as u32;
        i += 1;
    }
    ops.charge(i as u64);
}

/// Hybrid leaf multiplier (§7): Karatsuba above `threshold` digits,
/// schoolbook below — the classical crossover mirroring the paper's
/// COPSIM/COPK hybridization at the sequential level.
pub fn mul_hybrid(a: &[u32], b: &[u32], threshold: usize, base: Base, ops: &mut Ops) -> Vec<u32> {
    let n = a.len();
    assert_eq!(n, b.len());
    assert!(n.is_power_of_two());
    if n <= threshold || n <= leaf_widths(base).skim {
        return mul_school(a, b, base, ops);
    }
    // One Karatsuba level, then recurse hybrid.
    let h = n / 2;
    let (a0, a1) = (&a[..h], &a[h..]);
    let (b0, b1) = (&b[..h], &b[h..]);
    let (fa, ad) = abs_diff(a0, a1, base, ops);
    let (fb, bd) = abs_diff(b1, b0, base, ops);
    let c0 = mul_hybrid(a0, b0, threshold, base, ops);
    let c2 = mul_hybrid(a1, b1, threshold, base, ops);
    let cp = mul_hybrid(&ad, &bd, threshold, base, ops);
    let sign = fa * fb;
    let mut out = vec![0u32; 2 * n];
    out[..2 * h].copy_from_slice(&c0);
    add_into_width(&mut out, &c0, h, base, ops);
    add_into_width(&mut out, &c2, h, base, ops);
    add_into_width(&mut out, &c2, n, base, ops);
    match sign.cmp(&0) {
        Ordering::Greater => add_into_width(&mut out, &cp, h, base, ops),
        Ordering::Less => sub_into_width(&mut out, &cp, h, base, ops),
        Ordering::Equal => {}
    }
    out
}

/// Fixed-width addition used by tests: `(a + b) mod s^w` with carry out.
pub fn checked_add(a: &[u32], b: &[u32], base: Base) -> (Vec<u32>, u32) {
    let mut ops = Ops::default();
    add_with_carry(a, b, 0, base, &mut ops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bignum::convert::{from_u128, to_u128};
    use crate::util::Rng;

    fn b16() -> Base {
        Base::new(16)
    }

    #[test]
    fn school_small() {
        let mut ops = Ops::default();
        let a = from_u128(0x1234_5678, 4, b16());
        let b = from_u128(0x9ABC_DEF0, 4, b16());
        let c = mul_school(&a, &b, b16(), &mut ops);
        assert_eq!(to_u128(&c, b16()), 0x1234_5678u128 * 0x9ABC_DEF0u128);
        assert_eq!(ops.get(), 2 * 4 * 4);
    }

    #[test]
    fn slim_matches_school() {
        let mut rng = Rng::new(0xC0DE);
        for &n in &[1usize, 2, 4, 8, 16, 32, 64] {
            let a = rng.digits(n, 16);
            let b = rng.digits(n, 16);
            let mut o1 = Ops::default();
            let mut o2 = Ops::default();
            let c1 = mul_school(&a, &b, b16(), &mut o1);
            let c2 = slim(&a, &b, b16(), &mut o2);
            assert_eq!(c1, c2, "n={n}");
        }
    }

    #[test]
    fn skim_matches_school() {
        let mut rng = Rng::new(0xBEEF);
        for &n in &[1usize, 2, 4, 8, 16, 32, 64, 128] {
            let a = rng.digits(n, 16);
            let b = rng.digits(n, 16);
            let mut o1 = Ops::default();
            let mut o2 = Ops::default();
            let c1 = mul_school(&a, &b, b16(), &mut o1);
            let c2 = skim(&a, &b, b16(), &mut o2);
            assert_eq!(c1, c2, "n={n}");
        }
    }

    #[test]
    fn hybrid_matches_school() {
        let mut rng = Rng::new(0xFACE);
        for &n in &[16usize, 32, 64, 128] {
            let a = rng.digits(n, 16);
            let b = rng.digits(n, 16);
            let mut o1 = Ops::default();
            let mut o2 = Ops::default();
            let c1 = mul_school(&a, &b, b16(), &mut o1);
            let c2 = mul_hybrid(&a, &b, 32, b16(), &mut o2);
            assert_eq!(c1, c2, "n={n}");
        }
    }

    /// Fact 10: SLIM uses at most 8n² digit ops.
    #[test]
    fn slim_op_bound_fact10() {
        let mut rng = Rng::new(0x510);
        for &n in &[16usize, 64, 256] {
            let a = rng.digits(n, 16);
            let b = rng.digits(n, 16);
            let mut ops = Ops::default();
            slim(&a, &b, b16(), &mut ops);
            let bound = 8 * (n as u64) * (n as u64);
            assert!(
                ops.get() <= bound,
                "SLIM n={n}: {} > 8n² = {bound}",
                ops.get()
            );
        }
    }

    /// Fact 13: SKIM uses at most 16·n^(log₂3) digit ops.
    #[test]
    fn skim_op_bound_fact13() {
        let mut rng = Rng::new(0x513);
        for &n in &[16usize, 64, 256, 1024] {
            let a = rng.digits(n, 16);
            let b = rng.digits(n, 16);
            let mut ops = Ops::default();
            skim(&a, &b, b16(), &mut ops);
            let bound = (16.0 * crate::util::pow_log2_3(n as f64)).ceil() as u64;
            assert!(
                ops.get() <= bound,
                "SKIM n={n}: {} > 16·n^lg3 = {bound}",
                ops.get()
            );
        }
    }

    /// SKIM asymptotically beats SLIM in ops (the motivation for COPK).
    #[test]
    fn skim_cheaper_than_slim_at_scale() {
        let mut rng = Rng::new(0x333);
        let n = 1024;
        let a = rng.digits(n, 16);
        let b = rng.digits(n, 16);
        let mut o_slim = Ops::default();
        let mut o_skim = Ops::default();
        slim(&a, &b, b16(), &mut o_slim);
        skim(&a, &b, b16(), &mut o_skim);
        assert!(
            o_skim.get() < o_slim.get(),
            "karatsuba {} !< schoolbook {}",
            o_skim.get(),
            o_slim.get()
        );
    }

    #[test]
    fn abs_diff_signs() {
        let mut ops = Ops::default();
        let (f, d) = abs_diff(&[5, 0], &[3, 0], b16(), &mut ops);
        assert_eq!((f, d), (1, vec![2, 0]));
        let (f, d) = abs_diff(&[3, 0], &[5, 0], b16(), &mut ops);
        assert_eq!((f, d), (-1, vec![2, 0]));
        let (f, d) = abs_diff(&[7, 7], &[7, 7], b16(), &mut ops);
        assert_eq!((f, d), (0, vec![0, 0]));
    }

    #[test]
    fn base256_products() {
        // Exactness in the XLA-leaf base (2^8).
        let b8 = Base::new(8);
        let mut rng = Rng::new(0x888);
        for &n in &[8usize, 32] {
            let a = rng.digits(n, 8);
            let b = rng.digits(n, 8);
            let mut o1 = Ops::default();
            let mut o2 = Ops::default();
            assert_eq!(
                mul_school(&a, &b, b8, &mut o1),
                skim(&a, &b, b8, &mut o2)
            );
        }
    }
}

//! Exact base-`s` big-integer arithmetic — the digit model of §2.1.
//!
//! Integers are LSB-first vectors of `u32` digits in base `s = 2^log2_base`
//! with `1 <= log2_base <= 16` (so a digit-by-digit product plus carries
//! fits comfortably in `u64`). One digit occupies one memory word of the
//! simulated machine, exactly as the paper assumes ("each digit in the
//! base-s expansion of a value to be stored in a different memory word").
//!
//! Every arithmetic routine counts the number of *digit-wise elementary
//! operations* it performs (additions/subtractions/comparisons/products of
//! single digits), which is the quantity the paper's computation-cost
//! metric `T(n, P, M)` counts. The sequential multipliers [`mul::slim`]
//! (Fact 10: ≤ 8n² ops) and [`mul::skim`] (Fact 13: ≤ 16·n^(log₂3) ops)
//! are the recursion leaves of COPSIM/COPK.
//!
//! The digit model is the *currency*, not the *representation*: wide
//! kernels execute over packed limbs and SIMD lanes (the kernel ladder
//! in [`arch`], dispatched once per process) while charging the
//! digit-at-a-time counts exactly, so the physical layout is never
//! visible in any ledger (DESIGN.md, decisions 11–12).

pub mod arch;
pub mod convert;
pub mod core;
pub mod mul;
pub mod packed;

pub use self::core::{
    add_into_width, add_with_carry, cmp_digits, normalized_len, sub_with_borrow, trim,
};
pub use self::mul::{
    leaf_widths, mul_school, mul_school_reference, skim, skim_with_leaf, slim, slim_with_leaf,
    LeafWidths,
};
pub use convert::{from_u128, parse_hex, repack_base, to_hex, to_u128};

/// Number base descriptor: `s = 2^log2`, one digit per memory word.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Base {
    pub log2: u32,
}

impl Base {
    pub fn new(log2: u32) -> Self {
        assert!(
            (1..=16).contains(&log2),
            "base must be 2^k with 1 <= k <= 16 (got 2^{log2})"
        );
        Base { log2 }
    }

    /// The base value `s`.
    #[inline]
    pub fn s(&self) -> u64 {
        1u64 << self.log2
    }

    /// Digit mask `s - 1`.
    #[inline]
    pub fn mask(&self) -> u64 {
        self.s() - 1
    }

    /// Largest digit value.
    #[inline]
    pub fn max_digit(&self) -> u32 {
        (self.s() - 1) as u32
    }
}

impl Default for Base {
    /// Default machine base: 2^16 (largest base whose digit products fit
    /// in u64 with very wide margins).
    fn default() -> Self {
        Base { log2: 16 }
    }
}

/// Operation counter threaded through all digit arithmetic.
///
/// `T(n, P, M)` in the paper counts digit-wise computations; every
/// routine in this module adds its exact count here.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Ops(pub u64);

impl Ops {
    #[inline]
    pub fn charge(&mut self, n: u64) {
        self.0 += n;
    }
    #[inline]
    pub fn get(&self) -> u64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_values() {
        let b = Base::new(8);
        assert_eq!(b.s(), 256);
        assert_eq!(b.mask(), 255);
        assert_eq!(b.max_digit(), 255);
        assert_eq!(Base::default().s(), 65536);
    }

    #[test]
    #[should_panic(expected = "base must be")]
    fn base_rejects_wide() {
        Base::new(17);
    }

    #[test]
    fn ops_counter() {
        let mut o = Ops::default();
        o.charge(5);
        o.charge(7);
        assert_eq!(o.get(), 12);
    }
}

//! Packed-limb kernels: several base-`2^k` digits per `u64` limb.
//!
//! These are *physical* fast paths only. The machine model's currency —
//! digit operations, memory words, messages — is charged by the callers
//! in `bignum::{core, mul}` in closed form, never by this module: a
//! packed kernel that multiplies two digits per hardware multiply still
//! charges exactly the digit-at-a-time count, so skipping physical work
//! can never change a ledger (DESIGN.md, decision 11). Every kernel
//! here is *exact* — it computes the same integer as the scalar loop —
//! so products are bit-identical by construction and pinned against the
//! scalar oracles by `tests/packed_kernels.rs`.
//!
//! Two limb layouts are used:
//!
//! * **Multiplication layout** — `m = ⌊32 / k⌋` digits per limb, limb
//!   base `B = 2^(m·k) ≤ 2^32`. A limb-by-limb product plus the running
//!   column value and carry is at most `B² − 1 ≤ u64::MAX`, so the
//!   whole operand-scanning inner loop runs in plain `u64` arithmetic
//!   with `m²` fewer hardware multiplies than the digit loop (4× at
//!   the default base 2^16, 16× at 2^8, 64× at 2^4).
//! * **Additive layout** — `m = ⌊62 / k⌋` digits per limb (`B ≤ 2^62`),
//!   leaving headroom for one carry bit on add and for the borrow
//!   wrap-around trick on subtract.
//!
//! Ragged widths are handled by giving the most-significant limb its
//! true bit width, so carries out of a `w`-digit window are detected
//! exactly where the scalar loop detects them.
//!
//! Since PR 6 this module is the `packed64` rung of the kernel ladder
//! ([`super::arch`]); its additive kernels also back the faster rungs
//! (carry chains gain nothing from wider columns). The pack/unpack
//! helpers are shared with the u128 and SIMD rungs.

use super::Base;
use std::cmp::Ordering;

/// How digits map onto limbs for one kernel family.
#[derive(Clone, Copy, Debug)]
pub struct Layout {
    /// Digits per full limb (`m`).
    pub digits_per_limb: usize,
    /// Bits of a full limb (`m · k`).
    pub limb_bits: u32,
}

impl Layout {
    /// Multiplication layout: limb values below `2^32` so the
    /// schoolbook column update `out + a·b + carry` fits `u64` exactly.
    pub fn for_mul(base: Base) -> Layout {
        let m = (32 / base.log2).max(1) as usize;
        Layout {
            digits_per_limb: m,
            limb_bits: m as u32 * base.log2,
        }
    }

    /// Additive layout: limb values below `2^62` (add needs one carry
    /// bit of headroom; subtract detects the borrow in bit 63).
    pub fn for_add(base: Base) -> Layout {
        let m = (62 / base.log2).max(1) as usize;
        Layout {
            digits_per_limb: m,
            limb_bits: m as u32 * base.log2,
        }
    }

    /// Full-limb value mask `2^(m·k) − 1`.
    #[inline]
    pub fn mask(&self) -> u64 {
        if self.limb_bits == 64 {
            u64::MAX
        } else {
            (1u64 << self.limb_bits) - 1
        }
    }
}

/// Whether the packed multiply path pays off for these operand widths.
/// Any `m ≥ 2` layout is exact; the threshold only gates overhead.
#[inline]
pub fn mul_viable(base: Base, min_len: usize) -> bool {
    base.log2 <= 16 && min_len >= PACKED_MUL_MIN
}

/// Whether the packed add/sub path pays off at width `w`.
#[inline]
pub fn add_viable(base: Base, w: usize) -> bool {
    Layout::for_add(base).digits_per_limb >= 2 && w >= PACKED_ADD_MIN
}

/// Minimum `min(|a|, |b|)` before `mul_school` dispatches to the packed
/// kernel (below this the pack/unpack passes dominate the saved
/// multiplies).
pub const PACKED_MUL_MIN: usize = 8;

/// Minimum width before the additive helpers dispatch to their packed
/// kernels.
pub const PACKED_ADD_MIN: usize = 32;

/// Fold up to `digits_per_limb` digits (LSB-first) into one limb.
#[inline]
pub(crate) fn pack_limb(digits: &[u32], k: u32) -> u64 {
    let mut limb = 0u64;
    for (j, &d) in digits.iter().enumerate() {
        limb |= (d as u64) << (j as u32 * k);
    }
    limb
}

/// Append `count` base-`2^k` digits of `limb` (LSB-first) to `out`.
#[inline]
pub(crate) fn unpack_limb(limb: u64, k: u32, count: usize, out: &mut Vec<u32>) {
    let digit_mask = (1u64 << k) - 1;
    for j in 0..count {
        out.push(((limb >> (j as u32 * k)) & digit_mask) as u32);
    }
}

/// Pack a digit vector into `m`-digit limbs (top limb zero-padded —
/// harmless for multiplication, where the window width is implicit in
/// the output truncation). Shared by every packing rung of the kernel
/// ladder (`m · k ≤ 64` required).
pub(crate) fn pack_digits(digits: &[u32], m: usize, k: u32) -> Vec<u64> {
    debug_assert!(m as u32 * k <= 64);
    let mut limbs = Vec::with_capacity(digits.len().div_ceil(m));
    for chunk in digits.chunks(m) {
        limbs.push(pack_limb(chunk, k));
    }
    limbs
}

/// Unpack `m`-digit limbs back to exactly `len` digits, asserting (in
/// debug builds) that nothing beyond the window carries value. Shared
/// by every packing rung of the kernel ladder.
pub(crate) fn unpack_digits(limbs: &[u64], m: usize, k: u32, len: usize) -> Vec<u32> {
    let mut digits = Vec::with_capacity(len);
    for &limb in limbs {
        if digits.len() >= len {
            debug_assert_eq!(limb, 0, "product overflows its digit window");
            break;
        }
        let take = m.min(len - digits.len());
        unpack_limb(limb, k, take, &mut digits);
        debug_assert!(
            take == m || limb >> (take as u32 * k) == 0,
            "truncated limb must carry no value"
        );
    }
    digits.resize(len, 0);
    digits
}

/// Exact schoolbook product via packed limbs. Returns the full
/// `|a| + |b|`-digit product (LSB-first, untrimmed) — bit-identical to
/// the digit-at-a-time loop. Charges nothing: the caller charges the
/// model's closed-form count.
pub fn mul_packed(a: &[u32], b: &[u32], base: Base) -> Vec<u32> {
    let (na, nb) = (a.len(), b.len());
    debug_assert!(na > 0 && nb > 0);
    let k = base.log2;
    let lay = Layout::for_mul(base);
    let la = pack_digits(a, lay.digits_per_limb, k);
    let lb = pack_digits(b, lay.digits_per_limb, k);
    let mask = lay.mask();
    let bits = lay.limb_bits;
    let mut out = vec![0u64; la.len() + lb.len()];
    for (i, &ai) in la.iter().enumerate() {
        if ai == 0 {
            // Physical skip only: the model charge is closed-form at
            // the call site, so a zero row costs the same either way.
            continue;
        }
        let mut carry = 0u64;
        for (j, &bj) in lb.iter().enumerate() {
            // All of out[i+j], ai, bj, carry are < B ≤ 2^32, so
            // t ≤ B² − 1 ≤ u64::MAX: no overflow, exact arithmetic.
            let t = out[i + j] + ai * bj + carry;
            out[i + j] = t & mask;
            carry = t >> bits;
        }
        let mut idx = i + lb.len();
        while carry != 0 {
            let t = out[idx] + carry;
            out[idx] = t & mask;
            carry = t >> bits;
            idx += 1;
        }
    }
    // Unpack and truncate: the product value is < s^(na+nb), so every
    // digit beyond the window is provably zero.
    unpack_digits(&out, lay.digits_per_limb, k, na + nb)
}

/// Exact fixed-width addition via packed limbs:
/// `(A + B + carry_in) mod s^w` plus the outgoing carry — bit-identical
/// to the scalar digit loop. `carry_in` must be 0 or 1 (the callers'
/// contract; the dispatcher falls back to scalar otherwise).
pub fn add_packed(a: &[u32], b: &[u32], carry_in: u32, base: Base) -> (Vec<u32>, u32) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert!(carry_in <= 1);
    let w = a.len();
    let k = base.log2;
    let lay = Layout::for_add(base);
    let m = lay.digits_per_limb;
    let mask = lay.mask();
    let mut out = Vec::with_capacity(w);
    let mut carry = carry_in as u64;
    let mut ca = a.chunks_exact(m);
    let mut cb = b.chunks_exact(m);
    for (la, lb) in ca.by_ref().zip(cb.by_ref()) {
        // Limb values < 2^62: the sum plus carry fits u64 with room.
        let s = pack_limb(la, k) + pack_limb(lb, k) + carry;
        carry = s >> lay.limb_bits;
        unpack_limb(s & mask, k, m, &mut out);
    }
    let (ra, rb) = (ca.remainder(), cb.remainder());
    if !ra.is_empty() {
        // The top limb keeps its true width so the carry out of the
        // w-digit window lands in `carry`, not in padding bits.
        let bits = ra.len() as u32 * k;
        let s = pack_limb(ra, k) + pack_limb(rb, k) + carry;
        carry = s >> bits;
        unpack_limb(s & ((1u64 << bits) - 1), k, ra.len(), &mut out);
    }
    debug_assert!(carry <= 1);
    (out, carry as u32)
}

/// Exact fixed-width subtraction via packed limbs:
/// `(A − B − borrow_in) mod s^w` plus the outgoing borrow —
/// bit-identical to the scalar digit loop. `borrow_in` must be 0 or 1.
pub fn sub_packed(a: &[u32], b: &[u32], borrow_in: u32, base: Base) -> (Vec<u32>, u32) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert!(borrow_in <= 1);
    let w = a.len();
    let k = base.log2;
    let lay = Layout::for_add(base);
    let m = lay.digits_per_limb;
    let mut out = Vec::with_capacity(w);
    let mut borrow = borrow_in as u64;
    let limb_sub = |la: u64, lb: u64, bits: u32, borrow: u64| -> (u64, u64) {
        // Limb values are < 2^62, so a negative difference shows up in
        // bit 63 of the wrapped u64; adding back 2^bits restores the
        // modular limb exactly.
        let t = la.wrapping_sub(lb).wrapping_sub(borrow);
        let bo = t >> 63;
        (t.wrapping_add(bo << bits), bo)
    };
    let mut ca = a.chunks_exact(m);
    let mut cb = b.chunks_exact(m);
    for (la, lb) in ca.by_ref().zip(cb.by_ref()) {
        let (limb, bo) = limb_sub(pack_limb(la, k), pack_limb(lb, k), lay.limb_bits, borrow);
        borrow = bo;
        unpack_limb(limb, k, m, &mut out);
    }
    let (ra, rb) = (ca.remainder(), cb.remainder());
    if !ra.is_empty() {
        let bits = ra.len() as u32 * k;
        let (limb, bo) = limb_sub(pack_limb(ra, k), pack_limb(rb, k), bits, borrow);
        borrow = bo;
        unpack_limb(limb, k, ra.len(), &mut out);
    }
    (out, borrow as u32)
}

/// Compare two equal-width digit vectors from the most significant end,
/// two digits per probe (base-agnostic: `u32` digit pairs packed into a
/// `u64` compare lexicographically). Returns the ordering plus the
/// exact number of digit comparisons the scalar top-down scan performs
/// — `w − i` where `i` is the most significant differing index, `w`
/// when equal — so the caller's charge is bit-identical.
pub fn cmp_packed(a: &[u32], b: &[u32]) -> (Ordering, u64) {
    debug_assert_eq!(a.len(), b.len());
    let w = a.len();
    let mut i = w;
    while i >= 2 {
        let pa = ((a[i - 1] as u64) << 32) | a[i - 2] as u64;
        let pb = ((b[i - 1] as u64) << 32) | b[i - 2] as u64;
        if pa != pb {
            if a[i - 1] != b[i - 1] {
                return (a[i - 1].cmp(&b[i - 1]), (w - (i - 1)) as u64);
            }
            return (a[i - 2].cmp(&b[i - 2]), (w - (i - 2)) as u64);
        }
        i -= 2;
    }
    if i == 1 && a[0] != b[0] {
        return (a[0].cmp(&b[0]), w as u64);
    }
    (Ordering::Equal, w as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layouts_match_base_widths() {
        let m16 = Layout::for_mul(Base::new(16));
        assert_eq!((m16.digits_per_limb, m16.limb_bits), (2, 32));
        let m8 = Layout::for_mul(Base::new(8));
        assert_eq!((m8.digits_per_limb, m8.limb_bits), (4, 32));
        let m5 = Layout::for_mul(Base::new(5));
        assert_eq!((m5.digits_per_limb, m5.limb_bits), (6, 30));
        let a16 = Layout::for_add(Base::new(16));
        assert_eq!((a16.digits_per_limb, a16.limb_bits), (3, 48));
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let base = Base::new(16);
        let lay = Layout::for_mul(base);
        let digits = vec![0xFFFF, 1, 2, 0xABCD, 7];
        let limbs = pack_digits(&digits, lay.digits_per_limb, base.log2);
        let back = unpack_digits(&limbs, lay.digits_per_limb, base.log2, digits.len());
        assert_eq!(back, digits);
    }

    #[test]
    fn mul_packed_max_operands_exact() {
        // The adversarial all-max shape exercises every carry path.
        let base = Base::new(16);
        let a = vec![0xFFFFu32; 9];
        let b = vec![0xFFFFu32; 5];
        // The 14-digit product cannot be checked through u128, so use
        // the identity A·B + A + B = s^14 − 1 for A = s^9−1, B = s^5−1:
        // adding the operands digit-wise into the product must yield
        // the all-max vector with no carry out.
        let mut acc = mul_packed(&a, &b, base);
        let mut carry = 0u64;
        for (i, d) in acc.iter_mut().enumerate() {
            let mut add = 0u64;
            if i < 9 {
                add += 0xFFFF;
            }
            if i < 5 {
                add += 0xFFFF;
            }
            let t = *d as u64 + add + carry;
            *d = (t & 0xFFFF) as u32;
            carry = t >> 16;
        }
        assert_eq!(carry, 0);
        assert!(acc.iter().all(|&d| d == 0xFFFF), "A·B + A + B != s^14 − 1");
    }

    #[test]
    fn add_sub_packed_small_window() {
        let base = Base::new(16);
        // Width below a single additive limb (ragged top limb only).
        let a = vec![0xFFFF, 0xFFFF];
        let b = vec![1, 0];
        let (sum, c) = add_packed(&a, &b, 0, base);
        assert_eq!((sum, c), (vec![0, 0], 1));
        let (diff, bo) = sub_packed(&b, &a, 0, base);
        assert_eq!((diff, bo), (vec![2, 0], 1));
    }

    #[test]
    fn cmp_packed_charges_match_scan_depth() {
        let a = vec![1, 2, 3, 4, 5];
        let mut b = a.clone();
        assert_eq!(cmp_packed(&a, &b), (Ordering::Equal, 5));
        b[0] = 0; // difference at the very bottom: full scan
        assert_eq!(cmp_packed(&a, &b), (Ordering::Greater, 5));
        b = a.clone();
        b[4] = 9; // difference at the top: one comparison
        assert_eq!(cmp_packed(&a, &b), (Ordering::Less, 1));
        b = a.clone();
        b[3] = 0; // second-from-top: two comparisons
        assert_eq!(cmp_packed(&a, &b), (Ordering::Greater, 2));
    }
}

//! Rung 0 of the kernel ladder: digit-at-a-time scalar loops.
//!
//! These are the *oracles* every other rung is pinned against in
//! `tests/packed_kernels.rs` — one digit per iteration, no packing, no
//! intrinsics, the loops a direct reading of the paper's §2.1 digit
//! model produces. They are deliberately boring: any divergence between
//! a faster rung and this module is a bug in the faster rung.
//!
//! Like every rung, these functions charge nothing — the model's
//! closed-form digit counts are charged by the callers in
//! `bignum::{core, mul}` (DESIGN.md, decision 11).

use crate::bignum::Base;

/// Schoolbook product, one digit-multiply at a time. Returns the full
/// `|a| + |b|`-digit product (LSB-first, untrimmed).
pub fn mul(a: &[u32], b: &[u32], base: Base) -> Vec<u32> {
    let (na, nb) = (a.len(), b.len());
    let mut out = vec![0u32; na + nb];
    let mask = base.mask();
    let log2 = base.log2;
    for (i, &ai) in a.iter().enumerate() {
        if ai == 0 {
            continue;
        }
        let ai = ai as u64;
        let mut carry = 0u64;
        for (j, &bj) in b.iter().enumerate() {
            let t = out[i + j] as u64 + ai * bj as u64 + carry;
            out[i + j] = (t & mask) as u32;
            carry = t >> log2;
        }
        let mut k = i + nb;
        while carry != 0 {
            let t = out[k] as u64 + (carry & mask);
            out[k] = (t & mask) as u32;
            carry = (carry >> log2) + (t >> log2);
            k += 1;
        }
    }
    out
}

/// Fixed-width sum with incoming carry, one digit per iteration:
/// `(A + B + carry_in) mod s^w` plus the outgoing carry.
pub fn add(a: &[u32], b: &[u32], carry_in: u32, base: Base) -> (Vec<u32>, u32) {
    debug_assert_eq!(a.len(), b.len());
    let s = base.s();
    let mut out = Vec::with_capacity(a.len());
    let mut carry = carry_in as u64;
    for i in 0..a.len() {
        let t = a[i] as u64 + b[i] as u64 + carry;
        carry = t >> base.log2;
        debug_assert!(carry <= 1);
        out.push((t & base.mask()) as u32);
    }
    debug_assert!(carry < s);
    (out, carry as u32)
}

/// Fixed-width difference with incoming borrow, one digit per
/// iteration: `(A - B - borrow_in) mod s^w` plus the outgoing borrow.
pub fn sub(a: &[u32], b: &[u32], borrow_in: u32, base: Base) -> (Vec<u32>, u32) {
    debug_assert_eq!(a.len(), b.len());
    let mut out = Vec::with_capacity(a.len());
    let mut borrow = borrow_in as i64;
    for i in 0..a.len() {
        let mut t = a[i] as i64 - b[i] as i64 - borrow;
        if t < 0 {
            t += base.s() as i64;
            borrow = 1;
        } else {
            borrow = 0;
        }
        out.push(t as u32);
    }
    (out, borrow as u32)
}

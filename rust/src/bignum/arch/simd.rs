//! Rung 3 of the kernel ladder: feature-gated SIMD column
//! accumulation — AVX2 on `x86_64`, NEON on `aarch64` — behind runtime
//! CPU-feature detection.
//!
//! Shape: operands are packed into 32-bit limbs (`m = ⌊32 / k⌋` digits
//! each, the same `Layout::for_mul` as the u64 packed rung), and every
//! limb-product is accumulated *positionally* into per-column lanes —
//! no carry propagation inside the hot loop at all. A 32×32→64 lane
//! product does not leave headroom to sum even two products in a u64
//! lane, so each product is split into its 32-bit halves and summed
//! into two parallel column arrays (`acc_lo`, `acc_hi`); with fewer
//! than 2^31 limbs per operand neither array can overflow. One scalar
//! pass then normalizes columns to limbs in base `2^(m·k)` (u128
//! intermediate) and unpacks to digits.
//!
//! Both ISA bodies are the same loop; only the lane width differs
//! (AVX2: 4 limb-products per multiply, NEON: 2). Hosts with neither
//! feature degrade to the generic u128 rung — `mul` is total on every
//! target, which is what lets `COPMUL_KERNEL=simd` pin this rung in CI
//! without a hardware matrix.
//!
//! Charges nothing; callers charge closed form (DESIGN.md, decision 11).

use super::{generic, reference};
use crate::bignum::packed::{pack_digits, unpack_digits, Layout, PACKED_MUL_MIN};
use crate::bignum::Base;

#[cfg(target_arch = "x86_64")]
fn detect() -> bool {
    is_x86_feature_detected!("avx2")
}
#[cfg(target_arch = "aarch64")]
fn detect() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect() -> bool {
    false
}

/// Whether this host has a real SIMD rung (checked once per call site;
/// the stdlib caches the cpuid/auxval probe).
pub fn available() -> bool {
    detect()
}

/// The instruction set the SIMD rung would run on this host.
pub fn isa() -> &'static str {
    if !available() {
        return "none";
    }
    #[cfg(target_arch = "x86_64")]
    {
        "avx2"
    }
    #[cfg(target_arch = "aarch64")]
    {
        "neon"
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        "none"
    }
}

/// Exact schoolbook product via SIMD column accumulation. Bit-identical
/// to [`reference::mul`]; degrades to [`generic::mul`] when the host
/// has no detected SIMD feature, and to the reference loop below the
/// packing threshold.
pub fn mul(a: &[u32], b: &[u32], base: Base) -> Vec<u32> {
    if a.len().min(b.len()) < PACKED_MUL_MIN {
        return reference::mul(a, b, base);
    }
    #[cfg(target_arch = "x86_64")]
    if detect() {
        // SAFETY: AVX2 presence was just verified at runtime.
        return mul_columns(a, b, base, |la, lb, lo, hi| unsafe {
            x86::accumulate(la, lb, lo, hi)
        });
    }
    #[cfg(target_arch = "aarch64")]
    if detect() {
        // SAFETY: NEON presence was just verified at runtime.
        return mul_columns(a, b, base, |la, lb, lo, hi| unsafe {
            neon::accumulate(la, lb, lo, hi)
        });
    }
    generic::mul(a, b, base)
}

/// The ISA-independent harness: pack to 32-bit limbs, let `accumulate`
/// fill the split column arrays, normalize, unpack. `accumulate` must
/// add, for every limb pair `(i, j)`, the low and high 32-bit halves of
/// `la[i]·lb[j]` into `acc_lo[i+j]` / `acc_hi[i+j]` — nothing more; the
/// harness owns all carry logic, so lane width is unobservable.
#[allow(dead_code)] // unused only on targets with neither SIMD ISA
fn mul_columns<F>(a: &[u32], b: &[u32], base: Base, accumulate: F) -> Vec<u32>
where
    F: FnOnce(&[u32], &[u32], &mut [u64], &mut [u64]),
{
    let (na, nb) = (a.len(), b.len());
    let k = base.log2;
    let lay = Layout::for_mul(base);
    let m = lay.digits_per_limb;
    let bits = lay.limb_bits; // ≤ 32
    debug_assert!(
        na.min(nb) < (1usize << 31),
        "split column accumulators require < 2^31 terms per column"
    );
    // Mul-layout limb values are < 2^32: lossless as u32 lanes.
    let la: Vec<u32> = pack_digits(a, m, k).iter().map(|&l| l as u32).collect();
    let lb: Vec<u32> = pack_digits(b, m, k).iter().map(|&l| l as u32).collect();
    let cols = la.len() + lb.len();
    let mut acc_lo = vec![0u64; cols];
    let mut acc_hi = vec![0u64; cols];
    accumulate(&la, &lb, &mut acc_lo, &mut acc_hi);
    // Normalize columns to base-2^bits limbs. Column c's true value is
    // acc_lo[c] + 2^32·acc_hi[c] (each ≤ 2^63), so the running total
    // fits u128 with room to spare.
    let mask: u128 = (1u128 << bits) - 1;
    let mut limbs = Vec::with_capacity(cols);
    let mut carry: u128 = 0;
    for (&lo, &hi) in acc_lo.iter().zip(&acc_hi) {
        let t = carry + lo as u128 + ((hi as u128) << 32);
        limbs.push((t & mask) as u64);
        carry = t >> bits;
    }
    debug_assert_eq!(carry, 0, "product overflows its column window");
    unpack_digits(&limbs, m, k, na + nb)
}

/// Scalar lane body — the exact arithmetic each SIMD lane performs, one
/// limb-product at a time. Used by both ISA modules for ragged tails
/// and by unit tests as the any-host oracle for `mul_columns`.
#[allow(dead_code)] // unused only on targets with neither SIMD ISA
#[inline]
fn accumulate_tail(ai: u32, lb: &[u32], from: usize, col0: &mut [u64], col1: &mut [u64]) {
    for (j, &bj) in lb.iter().enumerate().skip(from) {
        let p = ai as u64 * bj as u64;
        col0[j] += p & 0xFFFF_FFFF;
        col1[j] += p >> 32;
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::{
        __m128i, __m256i, _mm256_add_epi64, _mm256_and_si256, _mm256_cvtepu32_epi64,
        _mm256_loadu_si256, _mm256_mul_epu32, _mm256_set1_epi64x, _mm256_srli_epi64,
        _mm256_storeu_si256, _mm_loadu_si128,
    };

    /// AVX2 column accumulation: four limb-products per `vpmuludq`,
    /// split into halves and added lane-wise into the column arrays.
    ///
    /// # Safety
    /// Caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    pub unsafe fn accumulate(la: &[u32], lb: &[u32], acc_lo: &mut [u64], acc_hi: &mut [u64]) {
        let mask32 = _mm256_set1_epi64x(0xFFFF_FFFF);
        let lanes = lb.len() & !3;
        for (i, &ai) in la.iter().enumerate() {
            if ai == 0 {
                // Physical skip only; charges are closed-form upstream.
                continue;
            }
            let av = _mm256_set1_epi64x(ai as i64);
            let mut j = 0;
            while j < lanes {
                // Zero-extend four u32 limbs to u64 lanes; vpmuludq
                // multiplies the low 32 bits of each lane: exact
                // 32×32→64 products.
                let bv =
                    _mm256_cvtepu32_epi64(_mm_loadu_si128(lb.as_ptr().add(j) as *const __m128i));
                let prod = _mm256_mul_epu32(av, bv);
                let lo = _mm256_and_si256(prod, mask32);
                let hi = _mm256_srli_epi64::<32>(prod);
                let p_lo = acc_lo.as_mut_ptr().add(i + j) as *mut __m256i;
                let lo_sum = _mm256_add_epi64(_mm256_loadu_si256(p_lo as *const _), lo);
                _mm256_storeu_si256(p_lo, lo_sum);
                let p_hi = acc_hi.as_mut_ptr().add(i + j) as *mut __m256i;
                let hi_sum = _mm256_add_epi64(_mm256_loadu_si256(p_hi as *const _), hi);
                _mm256_storeu_si256(p_hi, hi_sum);
                j += 4;
            }
            super::accumulate_tail(ai, lb, lanes, &mut acc_lo[i..], &mut acc_hi[i..]);
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::{
        vaddq_u64, vandq_u64, vdup_n_u32, vdupq_n_u64, vld1_u32, vld1q_u64, vmull_u32,
        vshrq_n_u64, vst1q_u64,
    };

    /// NEON column accumulation: two limb-products per `umull`, split
    /// into halves and added lane-wise into the column arrays.
    ///
    /// # Safety
    /// Caller must have verified NEON support at runtime.
    #[target_feature(enable = "neon")]
    pub unsafe fn accumulate(la: &[u32], lb: &[u32], acc_lo: &mut [u64], acc_hi: &mut [u64]) {
        let mask32 = vdupq_n_u64(0xFFFF_FFFF);
        let lanes = lb.len() & !1;
        for (i, &ai) in la.iter().enumerate() {
            if ai == 0 {
                // Physical skip only; charges are closed-form upstream.
                continue;
            }
            let av = vdup_n_u32(ai);
            let mut j = 0;
            while j < lanes {
                let prod = vmull_u32(av, vld1_u32(lb.as_ptr().add(j)));
                let lo = vandq_u64(prod, mask32);
                let hi = vshrq_n_u64::<32>(prod);
                let p_lo = acc_lo.as_mut_ptr().add(i + j);
                vst1q_u64(p_lo, vaddq_u64(vld1q_u64(p_lo as *const u64), lo));
                let p_hi = acc_hi.as_mut_ptr().add(i + j);
                vst1q_u64(p_hi, vaddq_u64(vld1q_u64(p_hi as *const u64), hi));
                j += 2;
            }
            super::accumulate_tail(ai, lb, lanes, &mut acc_lo[i..], &mut acc_hi[i..]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Any-host check of the harness + lane arithmetic: the scalar lane
    /// body drives `mul_columns` and must reproduce the reference
    /// product exactly (the real ISA lanes perform the same split).
    #[test]
    fn column_harness_matches_reference() {
        let mut rng = Rng::new(0x51D0);
        for &log2 in &[4u32, 8, 16] {
            let base = Base::new(log2);
            for &(na, nb) in &[(8usize, 8usize), (33, 17), (64, 9)] {
                let a = rng.digits(na, log2);
                let b = rng.digits(nb, log2);
                let got = mul_columns(&a, &b, base, |la, lb, lo, hi| {
                    for (i, &ai) in la.iter().enumerate() {
                        accumulate_tail(ai, lb, 0, &mut lo[i..], &mut hi[i..]);
                    }
                });
                assert_eq!(got, reference::mul(&a, &b, base), "na={na} nb={nb} k={log2}");
            }
        }
    }

    /// The dispatching entry point must be exact on whatever host runs
    /// the tests — SIMD lanes where detected, generic degrade elsewhere.
    #[test]
    fn simd_mul_matches_reference_on_this_host() {
        let mut rng = Rng::new(0x51D1);
        for &log2 in &[4u32, 8, 16] {
            let base = Base::new(log2);
            for &(na, nb) in &[(8usize, 8usize), (40, 23), (129, 64), (300, 5)] {
                let a = rng.digits(na, log2);
                let b = rng.digits(nb, log2);
                assert_eq!(
                    mul(&a, &b, base),
                    reference::mul(&a, &b, base),
                    "isa={} na={na} nb={nb} k={log2}",
                    isa()
                );
            }
        }
    }

    #[test]
    fn all_max_operands_exact_through_columns() {
        // A = s^9 − 1, B = s^5 − 1 at base 2^16: A·B + A + B = s^14 − 1.
        let base = Base::new(16);
        let a = vec![0xFFFFu32; 9];
        let b = vec![0xFFFFu32; 5];
        let mut acc = mul_columns(&a, &b, base, |la, lb, lo, hi| {
            for (i, &ai) in la.iter().enumerate() {
                accumulate_tail(ai, lb, 0, &mut lo[i..], &mut hi[i..]);
            }
        });
        let mut carry = 0u64;
        for (i, d) in acc.iter_mut().enumerate() {
            let mut add = 0u64;
            if i < 9 {
                add += 0xFFFF;
            }
            if i < 5 {
                add += 0xFFFF;
            }
            let t = *d as u64 + add + carry;
            *d = (t & 0xFFFF) as u32;
            carry = t >> 16;
        }
        assert_eq!(carry, 0);
        assert!(acc.iter().all(|&d| d == 0xFFFF));
    }
}

//! The kernel ladder: one dispatch table routing the sequential digit
//! kernels (`mul_school` / `add_with_carry` / `sub_with_borrow`) to the
//! fastest exact implementation the host supports.
//!
//! Rungs, slowest to fastest:
//!
//! | rung        | layout                | hw multiplies (base 2^16) |
//! |-------------|-----------------------|---------------------------|
//! | `reference` | one digit at a time   | n²                        |
//! | `packed64`  | 32-bit limbs, u64 cols| n²/4                      |
//! | `generic`   | 64-bit limbs, u128 cols| n²/16                    |
//! | `simd`      | 32-bit limbs, SIMD cols| n²/4, 4 per instruction  |
//!
//! Every rung computes the *same integers* — each is pinned
//! bit-identical to the `reference` oracle by the ladder-parity suite
//! (`tests/packed_kernels.rs`). None of them touch the cost ledger: the
//! model's digit-op counts are charged in closed form by the callers in
//! `bignum::{core, mul}`, so which rung runs is invisible to every
//! (T, BW, L, M) triple — the zero-diff invariant of DESIGN.md
//! decision 11, now extended to the whole ladder (decision 12).
//!
//! Selection happens **once**, at first use, via [`active`]:
//! `COPMUL_KERNEL={reference,packed64,generic,simd}` forces a rung
//! (CI's `kernels` matrix pins each one); otherwise runtime CPU-feature
//! detection picks `simd` where AVX2/NEON is present and `generic`
//! elsewhere. Runtime detection (not compile-time `target_feature`
//! cfg) keeps one binary correct and fast across a heterogeneous
//! cluster — the deployment model the paper's machine abstraction
//! assumes — at the cost of a single predictable branch per leaf call,
//! amortized over entire leaf multiplications.

pub mod generic;
pub mod reference;
pub mod simd;

use super::{packed, Base};
use std::sync::OnceLock;

/// Identity of a ladder rung.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// Digit-at-a-time scalar loops (the oracle).
    Reference,
    /// PR 5's 32-bit packed limbs with u64 column arithmetic.
    Packed64,
    /// Full 64-bit limbs with u128 column arithmetic.
    Generic,
    /// AVX2/NEON split-column accumulation (degrades to generic).
    Simd,
}

/// One rung of the ladder: exact, charge-free kernels for the three
/// dispatched digit operations. `add`/`sub` take the incoming
/// carry/borrow (0 or 1) as their third argument.
pub struct MulKernel {
    pub kind: KernelKind,
    pub name: &'static str,
    pub mul: fn(&[u32], &[u32], Base) -> Vec<u32>,
    pub add: fn(&[u32], &[u32], u32, Base) -> (Vec<u32>, u32),
    pub sub: fn(&[u32], &[u32], u32, Base) -> (Vec<u32>, u32),
}

/// PR 5's packed kernel as a rung: viability-gated exactly as the old
/// `mul_school` dispatch was, falling back to the oracle loop.
fn packed64_mul(a: &[u32], b: &[u32], base: Base) -> Vec<u32> {
    if packed::mul_viable(base, a.len().min(b.len())) {
        packed::mul_packed(a, b, base)
    } else {
        reference::mul(a, b, base)
    }
}

static REFERENCE: MulKernel = MulKernel {
    kind: KernelKind::Reference,
    name: "reference",
    mul: reference::mul,
    add: reference::add,
    sub: reference::sub,
};

static PACKED64: MulKernel = MulKernel {
    kind: KernelKind::Packed64,
    name: "packed64",
    mul: packed64_mul,
    add: generic::add,
    sub: generic::sub,
};

static GENERIC: MulKernel = MulKernel {
    kind: KernelKind::Generic,
    name: "generic",
    mul: generic::mul,
    add: generic::add,
    sub: generic::sub,
};

static SIMD: MulKernel = MulKernel {
    kind: KernelKind::Simd,
    name: "simd",
    mul: simd::mul,
    add: generic::add,
    sub: generic::sub,
};

/// Every rung this host can actually exercise, slowest first. The
/// `simd` rung is listed only where a SIMD feature is detected (its
/// entry points still *work* elsewhere — they degrade to `generic` —
/// but listing them would make the parity suite silently re-test the
/// generic rung and report coverage it does not have).
pub fn ladder() -> Vec<&'static MulKernel> {
    let mut rungs = vec![&REFERENCE, &PACKED64, &GENERIC];
    if simd::available() {
        rungs.push(&SIMD);
    }
    rungs
}

/// Resolve a rung by forced name (`COPMUL_KERNEL`), or `None` for the
/// auto policy: `simd` where detected, `generic` otherwise. Forcing
/// `simd` on a host without the feature is allowed — the rung degrades
/// per call — so CI can pin every matrix value on any runner.
pub fn select(forced: Option<&str>) -> Result<&'static MulKernel, String> {
    match forced {
        None => Ok(if simd::available() { &SIMD } else { &GENERIC }),
        Some("reference") => Ok(&REFERENCE),
        Some("packed64") => Ok(&PACKED64),
        Some("generic") => Ok(&GENERIC),
        Some("simd") => Ok(&SIMD),
        Some(other) => Err(format!(
            "COPMUL_KERNEL=`{other}` is not a ladder rung \
             (expected reference, packed64, generic, or simd)"
        )),
    }
}

/// The process-wide active rung, chosen once at first use from the
/// `COPMUL_KERNEL` environment variable (unset ⇒ auto detection). An
/// invalid name panics loudly — a silently ignored pin would defeat the
/// CI kernel matrix.
pub fn active() -> &'static MulKernel {
    static ACTIVE: OnceLock<&'static MulKernel> = OnceLock::new();
    ACTIVE.get_or_init(|| {
        let forced = std::env::var("COPMUL_KERNEL").ok();
        select(forced.as_deref()).unwrap_or_else(|e| panic!("{e}"))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_always_contains_the_portable_rungs() {
        let names: Vec<&str> = ladder().iter().map(|k| k.name).collect();
        assert_eq!(&names[..3], &["reference", "packed64", "generic"]);
        assert_eq!(names.len() == 4, simd::available());
    }

    #[test]
    fn select_resolves_every_documented_name() {
        for name in ["reference", "packed64", "generic", "simd"] {
            assert_eq!(select(Some(name)).unwrap().name, name);
        }
        let auto = select(None).unwrap();
        assert_eq!(
            auto.kind,
            if simd::available() {
                KernelKind::Simd
            } else {
                KernelKind::Generic
            }
        );
        assert!(select(Some("avx9000")).is_err());
    }

    #[test]
    fn active_is_a_valid_rung() {
        // Whatever COPMUL_KERNEL says (the CI matrix sets it), the
        // process-wide rung must be one of the four statics.
        let a = active();
        assert!(["reference", "packed64", "generic", "simd"].contains(&a.name));
    }
}

//! Rung 2 of the kernel ladder: u128 carry-save column accumulation
//! over *full* 64-bit limbs — the portable fast kernel, selected by
//! default on hosts without a SIMD rung.
//!
//! PR 5's packed kernel (`bignum::packed`, rung 1) caps limbs at 32
//! bits so the schoolbook column update fits `u64`. Widening the column
//! update to `u128` removes that cap: `m = ⌊64 / k⌋` digits per limb,
//! limb base `B = 2^(m·k) ≤ 2^64`, and the update
//! `out[i+j] + ai·bj + carry ≤ B² − 1 ≤ u128::MAX` stays exact. That is
//! 4× fewer hardware multiplies than the 32-bit packed layout at every
//! base (16× fewer than the digit loop at base 2^16, 256× at 2^4), for
//! one widening `u64×u64→u128` multiply each — the carry-save shape of
//! SNIPPETS 1–2.
//!
//! Add/sub have no analogous win over the 62-bit packed layout (carry
//! chains are serial either way), so this rung reuses `packed`'s
//! additive kernels and only replaces the multiplier.
//!
//! Charges nothing; callers charge closed form (DESIGN.md, decision 11).

use super::reference;
use crate::bignum::packed::{self, pack_digits, unpack_digits, PACKED_MUL_MIN};
use crate::bignum::Base;

/// Digits per limb in the u128-column layout: `⌊64 / k⌋`.
#[inline]
pub fn digits_per_limb(base: Base) -> usize {
    (64 / base.log2).max(1) as usize
}

/// Exact schoolbook product via full 64-bit limbs and u128 columns.
/// Bit-identical to [`reference::mul`]; falls back to it below the
/// pack/unpack amortization threshold.
pub fn mul(a: &[u32], b: &[u32], base: Base) -> Vec<u32> {
    let (na, nb) = (a.len(), b.len());
    if na.min(nb) < PACKED_MUL_MIN {
        return reference::mul(a, b, base);
    }
    let k = base.log2;
    let m = digits_per_limb(base);
    let bits = m as u32 * k;
    let mask: u128 = if bits == 64 {
        u64::MAX as u128
    } else {
        (1u128 << bits) - 1
    };
    let la = pack_digits(a, m, k);
    let lb = pack_digits(b, m, k);
    let mut out = vec![0u64; la.len() + lb.len()];
    for (i, &ai) in la.iter().enumerate() {
        if ai == 0 {
            // Physical skip only — the model charge is closed-form at
            // the call site, so a zero row costs the same either way.
            continue;
        }
        let ai = ai as u128;
        let mut carry: u128 = 0;
        for (j, &bj) in lb.iter().enumerate() {
            // out[i+j], carry < B and ai, bj ≤ B − 1 with B ≤ 2^64, so
            // t ≤ B² − 1 ≤ u128::MAX: exact, no overflow.
            let t = out[i + j] as u128 + ai * bj as u128 + carry;
            out[i + j] = (t & mask) as u64;
            carry = t >> bits;
        }
        let mut idx = i + lb.len();
        // carry < B, so each step adds at most one bit of spill.
        while carry != 0 {
            let t = out[idx] as u128 + carry;
            out[idx] = (t & mask) as u64;
            carry = t >> bits;
            idx += 1;
        }
    }
    unpack_digits(&out, m, k, na + nb)
}

/// Fixed-width add for the fast rungs: the 62-bit packed adder when the
/// width amortizes packing, the scalar loop otherwise. `carry_in` must
/// be 0 or 1 (the dispatcher's contract; `bignum::core` routes larger
/// carries straight to the reference loop).
pub fn add(a: &[u32], b: &[u32], carry_in: u32, base: Base) -> (Vec<u32>, u32) {
    debug_assert!(carry_in <= 1);
    if packed::add_viable(base, a.len()) {
        packed::add_packed(a, b, carry_in, base)
    } else {
        reference::add(a, b, carry_in, base)
    }
}

/// Fixed-width sub for the fast rungs; see [`add`].
pub fn sub(a: &[u32], b: &[u32], borrow_in: u32, base: Base) -> (Vec<u32>, u32) {
    debug_assert!(borrow_in <= 1);
    if packed::add_viable(base, a.len()) {
        packed::sub_packed(a, b, borrow_in, base)
    } else {
        reference::sub(a, b, borrow_in, base)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_fills_the_limb() {
        assert_eq!(digits_per_limb(Base::new(16)), 4);
        assert_eq!(digits_per_limb(Base::new(8)), 8);
        assert_eq!(digits_per_limb(Base::new(4)), 16);
        assert_eq!(digits_per_limb(Base::new(5)), 12);
    }

    #[test]
    fn all_max_operands_exact() {
        // A = s^12 − 1, B = s^9 − 1: A·B + A + B = s^21 − 1, so adding
        // the operands digit-wise into the product must give all-max
        // digits with no carry out (checks every u128 carry path).
        let base = Base::new(16);
        let a = vec![0xFFFFu32; 12];
        let b = vec![0xFFFFu32; 9];
        let mut acc = mul(&a, &b, base);
        let mut carry = 0u64;
        for (i, d) in acc.iter_mut().enumerate() {
            let mut add = 0u64;
            if i < 12 {
                add += 0xFFFF;
            }
            if i < 9 {
                add += 0xFFFF;
            }
            let t = *d as u64 + add + carry;
            *d = (t & 0xFFFF) as u32;
            carry = t >> 16;
        }
        assert_eq!(carry, 0);
        assert!(acc.iter().all(|&d| d == 0xFFFF), "A·B + A + B != s^21 − 1");
    }
}

//! Conversions: u128 ↔ digits, hex ↔ digits, base repacking, padding.
//!
//! Both the machine base (default 2^16) and the XLA-leaf base (2^8) are
//! powers of two, so repacking is exact bit surgery.

use super::Base;

/// Encode `v` as exactly `width` digits (panics if it does not fit).
pub fn from_u128(v: u128, width: usize, base: Base) -> Vec<u32> {
    let mut out = Vec::with_capacity(width);
    let mut x = v;
    for _ in 0..width {
        out.push((x & base.mask() as u128) as u32);
        x >>= base.log2;
    }
    assert_eq!(x, 0, "value does not fit in {width} digits of base 2^{}", base.log2);
    out
}

/// Decode digits to u128 (panics on overflow).
pub fn to_u128(digits: &[u32], base: Base) -> u128 {
    let mut v: u128 = 0;
    for &d in digits.iter().rev() {
        assert!(
            v.leading_zeros() >= base.log2,
            "to_u128 overflow: more than 128 bits"
        );
        v = (v << base.log2) | d as u128;
    }
    v
}

/// Repack an LSB-first digit vector from base `2^from.log2` to base
/// `2^to.log2`, preserving the value exactly. Output is trimmed to the
/// minimal width that holds the value (at least 1 digit).
pub fn repack_base(digits: &[u32], from: Base, to: Base) -> Vec<u32> {
    let total_bits = digits.len() * from.log2 as usize;
    let out_len = std::cmp::max(1, (total_bits + to.log2 as usize - 1) / to.log2 as usize);
    let mut out = vec![0u32; out_len];
    // Bit-copy: digit i of `digits` occupies bits [i*f, (i+1)*f).
    let f = from.log2 as usize;
    let t = to.log2 as usize;
    for (i, &d) in digits.iter().enumerate() {
        let mut bit = i * f;
        let mut rem = d as u64;
        let mut left = f;
        while left > 0 {
            let slot = bit / t;
            let off = bit % t;
            let take = std::cmp::min(left, t - off);
            let chunk = rem & ((1u64 << take) - 1);
            out[slot] |= (chunk << off) as u32;
            rem >>= take;
            bit += take;
            left -= take;
        }
    }
    out
}

/// Pad (or keep) a digit vector to the next power-of-two width >= `min`.
pub fn pad_pow2(digits: &[u32], min: usize) -> Vec<u32> {
    let want = std::cmp::max(digits.len(), std::cmp::max(1, min));
    let width = want.next_power_of_two();
    let mut out = digits.to_vec();
    out.resize(width, 0);
    out
}

/// Parse a hex string (no prefix) into LSB-first digits of `base`.
pub fn parse_hex(s: &str, base: Base) -> Result<Vec<u32>, String> {
    let s = s.trim().trim_start_matches("0x").trim_start_matches("0X");
    if s.is_empty() {
        return Err("empty hex string".into());
    }
    // Parse to a bit vector via 4-bit nibbles (LSB-first).
    let mut nibbles = Vec::with_capacity(s.len());
    for c in s.chars().rev() {
        let v = c
            .to_digit(16)
            .ok_or_else(|| format!("invalid hex character {c:?}"))?;
        nibbles.push(v as u32);
    }
    let nib_base = Base::new(4);
    Ok(repack_base(&nibbles, nib_base, base))
}

/// Render digits as a hex string (no prefix, no leading zeros).
pub fn to_hex(digits: &[u32], base: Base) -> String {
    let nibs = repack_base(digits, base, Base::new(4));
    let mut top = nibs.len();
    while top > 1 && nibs[top - 1] == 0 {
        top -= 1;
    }
    nibs[..top]
        .iter()
        .rev()
        .map(|&n| char::from_digit(n, 16).unwrap())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn u128_roundtrip() {
        let b = Base::new(16);
        let v = 0x1234_5678_9ABC_DEF0_1122u128;
        let d = from_u128(v, 8, b);
        assert_eq!(to_u128(&d, b), v);
    }

    #[test]
    fn repack_16_to_8_roundtrip() {
        let b16 = Base::new(16);
        let b8 = Base::new(8);
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let d = rng.digits(7, 16);
            let r = repack_base(&d, b16, b8);
            assert!(r.iter().all(|&x| x < 256));
            let back = repack_base(&r, b8, b16);
            let v1 = to_u128(&d, b16);
            let v2 = to_u128(&back, b16);
            assert_eq!(v1, v2);
        }
    }

    #[test]
    fn repack_odd_bases() {
        // 2^13 -> 2^5 and back: value-preserving even for non-nesting bases.
        let a = Base::new(13);
        let b = Base::new(5);
        let mut rng = Rng::new(2);
        let d = rng.digits(6, 13);
        let r = repack_base(&d, a, b);
        assert!(r.iter().all(|&x| x < 32));
        assert_eq!(to_u128(&d, a), to_u128(&r, b));
    }

    #[test]
    fn hex_roundtrip() {
        let b = Base::new(16);
        let d = parse_hex("deadbeefcafe1234", b).unwrap();
        assert_eq!(to_hex(&d, b), "deadbeefcafe1234");
        assert_eq!(to_u128(&d, b), 0xdeadbeefcafe1234u128);
    }

    #[test]
    fn hex_rejects_garbage() {
        assert!(parse_hex("xyz", Base::new(16)).is_err());
        assert!(parse_hex("", Base::new(16)).is_err());
    }

    #[test]
    fn pad_pow2_widths() {
        assert_eq!(pad_pow2(&[1, 2, 3], 0).len(), 4);
        assert_eq!(pad_pow2(&[1], 6).len(), 8);
        assert_eq!(pad_pow2(&[1, 2, 3, 4], 0).len(), 4);
    }
}

//! Fixed-width digit arithmetic: addition, subtraction, comparison.
//!
//! These are the *sequential* building blocks the paper's single-processor
//! base cases use (e.g. the local computations of `SUMA`, `DIFFR`, and the
//! leaf multipliers). All routines operate on LSB-first digit slices and
//! count digit operations.
//!
//! Add/sub dispatch physically to the active rung of the kernel ladder
//! ([`super::arch`] — packed `u64` limbs on every fast rung; carry
//! chains gain nothing from wider columns) while charging the model's
//! digit-at-a-time counts — closed form where the count is
//! data-independent (`add`/`sub`: one op per position), counted exactly
//! where it is not (`cmp`: scan depth; `add_into_width`: carry-chain
//! length). The representation is never cost-visible; see DESIGN.md,
//! decisions 11–12, and the ladder-parity suite in
//! `tests/packed_kernels.rs`.

use super::{arch, packed, Base, Ops};
use std::cmp::Ordering;

/// Strip trailing (most-significant) zero digits; never shrinks below 1
/// digit for a zero value represented with `len >= 1`.
pub fn trim(digits: &mut Vec<u32>) {
    while digits.len() > 1 && *digits.last().unwrap() == 0 {
        digits.pop();
    }
}

/// Length of `digits` ignoring most-significant zeros (0 for all-zero).
pub fn normalized_len(digits: &[u32]) -> usize {
    let mut n = digits.len();
    while n > 0 && digits[n - 1] == 0 {
        n -= 1;
    }
    n
}

/// Fixed-width sum with incoming carry:
/// returns `(A + B + carry_in) mod s^w` as a `w`-digit vector plus the
/// outgoing carry (0 or 1). `A`, `B` must have exactly `w` digits.
///
/// This is the single-processor kernel of `SUMA` (§4.1): the two
/// speculative results `C_0/u_0` and `C_1/u_1` are two calls with
/// `carry_in` 0 and 1.
pub fn add_with_carry(
    a: &[u32],
    b: &[u32],
    carry_in: u32,
    base: Base,
    ops: &mut Ops,
) -> (Vec<u32>, u32) {
    assert_eq!(a.len(), b.len(), "fixed-width add requires equal widths");
    // One digit-add (+ carry fold) per position — closed form, so the
    // kernel rung below never touches the ledger.
    ops.charge(a.len() as u64);
    if carry_in <= 1 {
        return (arch::active().add)(a, b, carry_in, base);
    }
    arch::reference::add(a, b, carry_in, base)
}

/// Fixed-width difference with incoming borrow:
/// returns `(A - B - borrow_in) mod s^w` as a `w`-digit vector plus the
/// outgoing borrow (1 iff `A < B + borrow_in`).
///
/// Single-processor kernel of `DIFFR` (§4.3): speculative values
/// `C_0/b_0` and `C_1/b_1` are the calls with `borrow_in` 0 and 1.
pub fn sub_with_borrow(
    a: &[u32],
    b: &[u32],
    borrow_in: u32,
    base: Base,
    ops: &mut Ops,
) -> (Vec<u32>, u32) {
    assert_eq!(a.len(), b.len(), "fixed-width sub requires equal widths");
    // One digit-subtract (+ borrow fold) per position — closed form.
    ops.charge(a.len() as u64);
    if borrow_in <= 1 {
        return (arch::active().sub)(a, b, borrow_in, base);
    }
    arch::reference::sub(a, b, borrow_in, base)
}

/// Compare two equal-width digit vectors as integers.
///
/// The model scans from the most significant digit and charges one
/// comparison per inspected pair, stopping at the first difference
/// (worst case w comparisons, matching Lemma 8's n/|P| local term).
/// Physically the scan probes two digits per `u64` compare
/// ([`packed::cmp_packed`]), which also reports the exact scalar scan
/// depth — the charge stays bit-identical to the digit loop's.
pub fn cmp_digits(a: &[u32], b: &[u32], ops: &mut Ops) -> Ordering {
    assert_eq!(a.len(), b.len(), "fixed-width cmp requires equal widths");
    let (ord, inspected) = packed::cmp_packed(a, b);
    ops.charge(inspected);
    ord
}

/// The digit-at-a-time scan kept as the oracle [`cmp_digits`] is
/// pinned against — for ordering *and* charge depth — in
/// `tests/packed_kernels.rs`.
pub fn cmp_digits_reference(a: &[u32], b: &[u32], ops: &mut Ops) -> Ordering {
    assert_eq!(a.len(), b.len(), "fixed-width cmp requires equal widths");
    for i in (0..a.len()).rev() {
        ops.charge(1);
        match a[i].cmp(&b[i]) {
            Ordering::Equal => continue,
            ord => return ord,
        }
    }
    Ordering::Equal
}

/// Add `src` (any width) into `dst` starting at digit offset `off`,
/// propagating carries through `dst`; `dst` must be wide enough that the
/// final carry is absorbed (panics otherwise). Returns nothing; charges
/// one op per touched digit — batched into a single counter update (the
/// touched count is data-dependent through the carry chain, so it is
/// counted, not closed-form; the total equals per-digit charging,
/// asserted exactly in `tests/packed_kernels.rs`).
///
/// Used by the sequential multipliers to accumulate partial products
/// (`C = C0 + s^(n/2)(C1+C2) + s^n C3`).
pub fn add_into_width(dst: &mut [u32], src: &[u32], off: usize, base: Base, ops: &mut Ops) {
    let mut carry = 0u64;
    let mut i = 0;
    while i < src.len() || carry != 0 {
        let d = off + i;
        assert!(
            d < dst.len(),
            "add_into_width overflow: dst width {} offset {} src len {}",
            dst.len(),
            off,
            src.len()
        );
        let add = if i < src.len() { src[i] as u64 } else { 0 };
        let t = dst[d] as u64 + add + carry;
        dst[d] = (t & base.mask()) as u32;
        carry = t >> base.log2;
        i += 1;
    }
    ops.charge(i as u64);
}

/// Value of a short digit vector as u128 (panics if it doesn't fit).
pub fn digits_value_u128(digits: &[u32], base: Base) -> u128 {
    let mut v: u128 = 0;
    for &d in digits.iter().rev() {
        v = v
            .checked_shl(base.log2)
            .expect("digits_value_u128: value exceeds 128 bits");
        v |= d as u128;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    fn b16() -> Base {
        Base::new(16)
    }

    #[test]
    fn add_basic() {
        let mut ops = Ops::default();
        // 0xFFFF + 1 = 0x1_0000 -> ([0, 1], carry 0) at width 2
        let (c, carry) = add_with_carry(&[0xFFFF, 0], &[1, 0], 0, b16(), &mut ops);
        assert_eq!(c, vec![0, 1]);
        assert_eq!(carry, 0);
        assert_eq!(ops.get(), 2);
    }

    #[test]
    fn add_carry_out() {
        let mut ops = Ops::default();
        let (c, carry) = add_with_carry(&[0xFFFF], &[0xFFFF], 1, b16(), &mut ops);
        // 0xFFFF + 0xFFFF + 1 = 0x1_FFFF -> digit 0xFFFF, carry 1
        assert_eq!(c, vec![0xFFFF]);
        assert_eq!(carry, 1);
    }

    #[test]
    fn sub_basic() {
        let mut ops = Ops::default();
        let (c, borrow) = sub_with_borrow(&[0, 1], &[1, 0], 0, b16(), &mut ops);
        // 0x1_0000 - 1 = 0xFFFF
        assert_eq!(c, vec![0xFFFF, 0]);
        assert_eq!(borrow, 0);
    }

    #[test]
    fn sub_underflow_borrows() {
        let mut ops = Ops::default();
        let (c, borrow) = sub_with_borrow(&[0], &[1], 0, b16(), &mut ops);
        assert_eq!(c, vec![0xFFFF]);
        assert_eq!(borrow, 1);
    }

    #[test]
    fn sub_with_incoming_borrow() {
        let mut ops = Ops::default();
        let (c, borrow) = sub_with_borrow(&[5], &[5], 1, b16(), &mut ops);
        assert_eq!(c, vec![0xFFFF]);
        assert_eq!(borrow, 1);
    }

    #[test]
    fn cmp_works() {
        let mut ops = Ops::default();
        assert_eq!(cmp_digits(&[1, 2], &[1, 2], &mut ops), Ordering::Equal);
        assert_eq!(cmp_digits(&[0, 3], &[9, 2], &mut ops), Ordering::Greater);
        assert_eq!(cmp_digits(&[9, 2], &[0, 3], &mut ops), Ordering::Less);
    }

    #[test]
    fn add_into_width_accumulates() {
        let mut ops = Ops::default();
        let mut dst = vec![0u32; 4];
        add_into_width(&mut dst, &[0xFFFF, 0xFFFF], 1, b16(), &mut ops);
        assert_eq!(dst, vec![0, 0xFFFF, 0xFFFF, 0]);
        add_into_width(&mut dst, &[1], 1, b16(), &mut ops);
        assert_eq!(dst, vec![0, 0, 0, 1]);
    }

    #[test]
    fn trim_and_len() {
        let mut v = vec![1, 0, 2, 0, 0];
        trim(&mut v);
        assert_eq!(v, vec![1, 0, 2]);
        assert_eq!(normalized_len(&[0, 0]), 0);
        assert_eq!(normalized_len(&[1, 0]), 1);
    }

    #[test]
    fn value_u128() {
        assert_eq!(digits_value_u128(&[0x34, 0x12], Base::new(8)), 0x1234);
    }
}

//! E15 — execution-engine comparison: the cost-model simulator vs the
//! real-threads executor vs the real-network socket executor, all
//! running the *same* algorithm source through [`MachineApi`].
//!
//! For each (algorithm, n, P) cell every engine multiplies identical
//! random operands. The table reports
//!
//! * the critical-path cost triple (identical across engines — checked),
//! * the §2.2 model's predicted time `α·T + β·L + γ·BW` from the
//!   cost-model clocks,
//! * measured wall-clock of the single-threaded cost-model interpreter,
//! * measured wall-clock of the threaded engine (one OS thread per
//!   simulated processor),
//! * measured wall-clock of the socket engine (worker processes over
//!   Unix-domain sockets — real serialization and kernel socket
//!   buffers behind every message; `-` when no worker binary is
//!   resolvable on this host), and
//! * the threaded engine's speedup over the interpreter — the
//!   "coordination algorithms actually parallelize" evidence the
//!   simulator alone cannot provide.

use crate::algorithms::leaf::{leaf_ref, LeafRef, SchoolLeaf, SkimLeaf};
use crate::algorithms::{copk_mi, copsim_mi};
use crate::bignum::Base;
use crate::error::{ensure, Result};
use crate::metrics::{fmt_f64, fmt_u64, Table};
use crate::sim::{
    socket_available, Clock, DistInt, Machine, MachineApi, Seq, SocketMachine, ThreadedMachine,
};
use crate::theory::TimeModel;
use crate::util::Rng;
use std::time::{Duration, Instant};

/// Which scheme a comparison cell runs (MI mode on an unbounded
/// machine; the engines execute identical operation streams either way).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    Copsim,
    Copk,
}

impl Scheme {
    fn name(self) -> &'static str {
        match self {
            Scheme::Copsim => "COPSIM",
            Scheme::Copk => "COPK",
        }
    }

    fn leaf(self) -> LeafRef {
        match self {
            // Schoolbook has the smallest wall-clock constant, which
            // makes the engine comparison about execution, not leaf
            // choice; COPK keeps its natural Karatsuba leaf.
            Scheme::Copsim => leaf_ref(SchoolLeaf),
            Scheme::Copk => leaf_ref(SkimLeaf),
        }
    }
}

/// One engine-comparison cell.
#[derive(Clone, Debug)]
pub struct EngineComparison {
    pub scheme: Scheme,
    pub p: usize,
    pub n: usize,
    /// Critical-path triple (asserted identical across engines).
    pub clock: Clock,
    /// §2.2 predicted time from the cost-model clocks, in ms.
    pub predicted_ms: f64,
    /// Wall-clock of the cost-model interpreter (single host thread).
    pub sim_wall: Duration,
    /// Wall-clock of the threaded engine (P OS threads).
    pub threaded_wall: Duration,
    /// Wall-clock of the socket engine (worker processes over UDS);
    /// `None` when no worker binary is resolvable on this host.
    pub socket_wall: Option<Duration>,
}

impl EngineComparison {
    /// Threaded-engine speedup over the single-threaded interpreter.
    pub fn speedup(&self) -> f64 {
        self.sim_wall.as_secs_f64() / self.threaded_wall.as_secs_f64().max(1e-9)
    }
}

fn run_on<M: MachineApi>(
    m: &mut M,
    scheme: Scheme,
    seq: &Seq,
    a: &[u32],
    b: &[u32],
    leaf: &LeafRef,
) -> Result<(Vec<u32>, Duration)> {
    let n = a.len();
    let w = n / seq.len();
    let t0 = Instant::now();
    let da = DistInt::scatter(m, seq, a, w)?;
    let db = DistInt::scatter(m, seq, b, w)?;
    let c = match scheme {
        Scheme::Copsim => copsim_mi(m, seq, da, db, leaf)?,
        Scheme::Copk => copk_mi(m, seq, da, db, leaf)?,
    };
    // The gather synchronizes with all in-flight worker activity, so
    // the measured span covers the complete multiplication on both
    // engines.
    let product = c.gather(m)?;
    let wall = t0.elapsed();
    Ok((product, wall))
}

/// Run one (scheme, n, P) cell on both engines and cross-check them.
pub fn compare_engines(scheme: Scheme, n: usize, p: usize, seed: u64) -> Result<EngineComparison> {
    let base = Base::new(16);
    let leaf = scheme.leaf();
    let mut rng = Rng::new(seed);
    let a = rng.digits(n, 16);
    let b = rng.digits(n, 16);
    let seq = Seq::range(p);

    let mut sim = Machine::unbounded(p, base);
    let (sim_prod, sim_wall) = run_on(&mut sim, scheme, &seq, &a, &b, &leaf)?;
    let sim_clock = sim.critical();

    let mut thr = ThreadedMachine::unbounded(p, base);
    let (thr_prod, threaded_wall) = run_on(&mut thr, scheme, &seq, &a, &b, &leaf)?;
    let report = thr.finish()?;

    ensure!(
        sim_prod == thr_prod,
        "engines disagree on the product at {} n={n} P={p}",
        scheme.name()
    );
    ensure!(
        sim_clock == report.critical,
        "engines disagree on the cost triple at {} n={n} P={p}: sim {} vs threads {}",
        scheme.name(),
        sim_clock,
        report.critical
    );

    let socket_wall = if socket_available() {
        let mut sock = SocketMachine::unbounded(p, base)?;
        let (sock_prod, wall) = run_on(&mut sock, scheme, &seq, &a, &b, &leaf)?;
        let sock_report = sock.finish()?;
        ensure!(
            sim_prod == sock_prod,
            "socket engine disagrees on the product at {} n={n} P={p}",
            scheme.name()
        );
        ensure!(
            sim_clock == sock_report.critical,
            "socket engine disagrees on the cost triple at {} n={n} P={p}: sim {} vs sockets {}",
            scheme.name(),
            sim_clock,
            sock_report.critical
        );
        Some(wall)
    } else {
        None
    };

    let predicted_ms = TimeModel::default().time_ns(&sim_clock) / 1e6;
    Ok(EngineComparison {
        scheme,
        p,
        n,
        clock: sim_clock,
        predicted_ms,
        sim_wall,
        threaded_wall,
        socket_wall,
    })
}

/// The default E15 sweep: COPSIM over P ∈ {4, 16, 64} and COPK over its
/// P = 4·3^i shapes, n up to 2^14 (the bench target `engines` runs the
/// larger sizes).
pub fn e15_engines() -> Result<Vec<Table>> {
    let cells: &[(Scheme, usize, usize)] = &[
        (Scheme::Copsim, 4, 1 << 10),
        (Scheme::Copsim, 4, 1 << 12),
        (Scheme::Copsim, 4, 1 << 14),
        (Scheme::Copsim, 16, 1 << 12),
        (Scheme::Copsim, 16, 1 << 14),
        (Scheme::Copsim, 64, 1 << 14),
        (Scheme::Copk, 4, 1 << 10),
        (Scheme::Copk, 4, 1 << 12),
        (Scheme::Copk, 12, 3072),
        (Scheme::Copk, 36, 4608),
    ];
    let mut t = Table::new(
        "E15: cost-model predicted critical path vs measured threaded and socket wall-clock \
         (predicted = α·T + β·L + γ·BW on the cost-model clocks; speedup = sim wall / threaded \
         wall; sockets = worker processes over UDS, `-` if no worker binary resolves)",
        &[
            "scheme",
            "P",
            "n",
            "T",
            "BW",
            "L",
            "predicted ms",
            "sim wall ms",
            "threads wall ms",
            "sockets wall ms",
            "speedup",
        ],
    );
    for &(scheme, p, n) in cells {
        let c = compare_engines(scheme, n, p, 0xE15)?;
        t.row(vec![
            scheme.name().into(),
            p.to_string(),
            fmt_u64(n as u64),
            fmt_u64(c.clock.ops),
            fmt_u64(c.clock.words),
            fmt_u64(c.clock.msgs),
            fmt_f64(c.predicted_ms),
            fmt_f64(c.sim_wall.as_secs_f64() * 1e3),
            fmt_f64(c.threaded_wall.as_secs_f64() * 1e3),
            c.socket_wall
                .map(|w| fmt_f64(w.as_secs_f64() * 1e3))
                .unwrap_or_else(|| "-".into()),
            format!("{:.2}", c.speedup()),
        ]);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engines_agree_on_small_cells() {
        for &(scheme, p, n) in &[
            (Scheme::Copsim, 4usize, 256usize),
            (Scheme::Copsim, 16, 512),
            (Scheme::Copk, 4, 256),
            (Scheme::Copk, 12, 384),
        ] {
            let c = compare_engines(scheme, n, p, 0x515).unwrap();
            assert!(c.clock.ops > 0);
            assert!(c.predicted_ms > 0.0);
        }
    }

    fn cores() -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }

    #[test]
    fn threaded_speedup_materializes() {
        // The threaded engine must beat the single-threaded interpreter
        // on a multi-core host once the leaf products dominate. Sized at
        // n = 2^13 so the suite stays fast in debug builds; the full
        // n = 2^14 acceptance cell runs in E15 and the ignored release
        // test below. Skipped on hosts without enough cores, where no
        // engine can manufacture parallelism.
        if cores() < 4 {
            eprintln!("skipping: only {} core(s) available", cores());
            return;
        }
        // Wall-clock under a concurrently-running test suite is noisy;
        // accept the first of three attempts that shows a speedup.
        let mut last = None;
        for attempt in 0..3 {
            let c = compare_engines(Scheme::Copsim, 1 << 13, 4, 0x5EED + attempt).unwrap();
            if c.speedup() > 1.0 {
                return;
            }
            last = Some(c);
        }
        let c = last.unwrap();
        panic!(
            "threaded engine never faster over 3 attempts: sim {:?} vs threads {:?}",
            c.sim_wall, c.threaded_wall
        );
    }

    #[test]
    #[ignore = "release-mode acceptance check: cargo test --release -- --ignored"]
    fn threaded_speedup_at_n14_p4() {
        if cores() < 4 {
            eprintln!("skipping: only {} core(s) available", cores());
            return;
        }
        let c = compare_engines(Scheme::Copsim, 1 << 14, 4, 0x5EED).unwrap();
        assert!(
            c.speedup() > 1.0,
            "threaded engine not faster at n=2^14 P=4: sim {:?} vs threads {:?}",
            c.sim_wall,
            c.threaded_wall
        );
    }
}

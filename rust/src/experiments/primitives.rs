//! E1-E3: the §4 primitives vs Lemmas 7-9.

use crate::bignum::Base;
use crate::metrics::{fmt_ratio, fmt_u64, Table};
use crate::primitives::{compare, diff, sum};
use crate::sim::{Clock, DistInt, Machine, Seq};
use crate::theory;
use crate::util::Rng;
use crate::error::Result;

const SWEEP: &[(usize, usize)] = &[
    (2, 1 << 10),
    (4, 1 << 12),
    (8, 1 << 12),
    (16, 1 << 14),
    (32, 1 << 14),
    (64, 1 << 16),
    (128, 1 << 16),
    (256, 1 << 18),
];

fn run_primitive(
    which: &str,
    p: usize,
    n: usize,
) -> Result<(Clock, u64)> {
    let base = Base::new(16);
    let mut rng = Rng::new(0xE0 + p as u64);
    let mut m = Machine::unbounded(p, base);
    let seq = Seq::range(p);
    let a = rng.digits(n, 16);
    let b = rng.digits(n, 16);
    let da = DistInt::scatter(&mut m, &seq, &a, n / p)?;
    let db = DistInt::scatter(&mut m, &seq, &b, n / p)?;
    match which {
        "sum" => {
            sum(&mut m, &seq, &da, &db)?;
        }
        "compare" => {
            compare(&mut m, &seq, &da, &db)?;
        }
        "diff" => {
            diff(&mut m, &seq, &da, &db)?;
        }
        _ => unreachable!(),
    }
    Ok((m.critical(), m.mem_peak_max()))
}

fn bound_table(
    title: &str,
    which: &str,
    bound_fn: fn(u64, u64) -> Clock,
    mem_bound: fn(u64, u64) -> u64,
) -> Result<Vec<Table>> {
    let mut t = Table::new(
        title,
        &[
            "P", "n", "T meas", "T bound", "T r", "BW meas", "BW bound", "BW r", "L meas",
            "L bound", "L r", "M meas", "M bound", "M r",
        ],
    );
    for &(p, n) in SWEEP {
        let (c, mem) = run_primitive(which, p, n)?;
        let b = bound_fn(n as u64, p as u64);
        let mb = mem_bound(n as u64, p as u64);
        t.row(vec![
            p.to_string(),
            fmt_u64(n as u64),
            fmt_u64(c.ops),
            fmt_u64(b.ops),
            fmt_ratio(c.ops as f64, b.ops as f64),
            fmt_u64(c.words),
            fmt_u64(b.words),
            fmt_ratio(c.words as f64, b.words as f64),
            fmt_u64(c.msgs),
            fmt_u64(b.msgs),
            fmt_ratio(c.msgs as f64, b.msgs as f64),
            fmt_u64(mem),
            fmt_u64(mb),
            fmt_ratio(mem as f64, mb as f64),
        ]);
    }
    Ok(vec![t])
}

/// E1 — Lemma 7 (SUM).
pub fn e01_sum() -> Result<Vec<Table>> {
    bound_table(
        "E1: SUM vs Lemma 7 (T <= 6n/P + 4lgP, BW <= 4lgP, L <= 2lgP, M <= 4(n/P+1))",
        "sum",
        theory::lemma7_sum,
        theory::lemma7_sum_mem,
    )
}

/// E2 — Lemma 8 (COMPARE). Ratios above 1.0 for BW/L reflect the
/// return-broadcast step the lemma's stated constant omits (see
/// primitives::compare docs); the corrected constant is 2·log₂P.
pub fn e02_compare() -> Result<Vec<Table>> {
    bound_table(
        "E2: COMPARE vs Lemma 8 (T <= n/P + lgP, BW,L <= lgP [paper]; impl sends the flag back: 2lgP)",
        "compare",
        theory::lemma8_compare,
        |n, p| 2 * (n / p) + 2,
    )
}

/// E3 — Lemma 9 (DIFF). Same BW/L caveat as E2, inherited via COMPARE.
pub fn e03_diff() -> Result<Vec<Table>> {
    bound_table(
        "E3: DIFF vs Lemma 9 (T <= 7n/P + 5lgP, BW <= 5lgP, L <= 3lgP [paper]; impl: <= 8lgP / 6lgP)",
        "diff",
        theory::lemma9_diff,
        |n, p| 4 * (n / p) + 5,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitives_experiments_produce_rows() {
        for f in [e01_sum, e02_compare, e03_diff] {
            let tables = f().unwrap();
            assert_eq!(tables.len(), 1);
            assert_eq!(tables[0].rows.len(), SWEEP.len());
        }
    }

    #[test]
    fn sum_ratios_below_one() {
        // The SUM lemma's constants are self-consistent: every measured
        // metric must be under the paper bound.
        let t = &e01_sum().unwrap()[0];
        for row in &t.rows {
            for idx in [4usize, 7, 10, 13] {
                let r: f64 = row[idx].parse().unwrap();
                assert!(r <= 1.0, "ratio {} at col {idx} exceeds 1", row[idx]);
            }
        }
    }
}

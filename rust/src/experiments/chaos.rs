//! E17 — serving under faults: throughput and per-job cost inflation vs
//! injected fault rate, on both execution engines.
//!
//! The same seeded fleet runs through the sharded scheduler at
//! escalating [`FaultConfig`] rates (rate 0 is the reference run).
//! Reported per (engine, rate) cell:
//!
//! * **jobs/s** and its ratio to the fault-free run — what recovery
//!   (retries, shard-size backoff, safe-mode final attempts) costs in
//!   throughput;
//! * **mean attempts** — how many executions an admitted job needed;
//! * **injected / survived** — total faults the plan fired vs the
//!   faults completed jobs absorbed without failing (stalls, duplicated
//!   messages);
//! * **cost inflation** — mean per-job modeled-time ratio against the
//!   fault-free run. Jobs whose shards saw zero faults contribute
//!   exactly 1.00 (the zero-fault identity invariant asserted by
//!   `tests/chaos_soak.rs`); the excess is the stall/duplication skew.
//!
//! Every job's product is verified against the bignum oracle before it
//! counts — a chaos experiment that silently returned wrong products
//! would measure nothing.

use crate::bignum::{mul, Base, Ops};
use crate::config::EngineKind;
use crate::error::{ensure, Result};
use crate::experiments::scheduler::{run_fleet, FleetOutcome};
use crate::metrics::{fmt_f64, Table};
use crate::sim::FaultConfig;
use crate::theory::TimeModel;
use crate::util::Rng;

/// Regenerate the fleet's operands (same seed as `run_fleet`) and
/// verify every product against the sequential oracle.
fn verify_fleet(outcome: &FleetOutcome, jobs: usize, n: usize) -> Result<()> {
    let base = Base::new(16);
    let mut rng = Rng::new(0xE16);
    for id in 0..jobs {
        let a = rng.digits(n, 16);
        let b = rng.digits(n, 16);
        let mut ops = Ops::default();
        let mut want = mul::mul_school(&a, &b, base, &mut ops);
        let keep = crate::bignum::core::normalized_len(&want).max(1);
        want.truncate(keep);
        ensure!(
            outcome.results[id].product == want,
            "job {id} product corrupted under faults"
        );
    }
    Ok(())
}

pub fn e17_chaos() -> Result<Vec<Table>> {
    const JOBS: usize = 10;
    const N: usize = 512;
    const RATES: [f64; 3] = [0.0, 5e-4, 2e-3];
    let tm = TimeModel::default();
    let mut t = Table::new(
        "E17: serving under deterministic fault injection (10 jobs, n = 512, \
         16 procs / 4 shards; inflation and throughput ratios are against the \
         rate-0 run on the same engine)",
        &[
            "engine",
            "fault rate",
            "injected",
            "survived",
            "retries",
            "mean attempts",
            "jobs/s",
            "throughput vs clean",
            "cost inflation",
        ],
    );
    for engine in [EngineKind::Sim, EngineKind::Threads] {
        let mut clean: Option<FleetOutcome> = None;
        for &rate in &RATES {
            let fault = if rate > 0.0 {
                Some(FaultConfig::new(0xE17, rate))
            } else {
                None
            };
            let outcome = run_fleet(engine, 16, 4, JOBS, N, fault)?;
            verify_fleet(&outcome, JOBS, N)?;
            let reference = clean.as_ref().unwrap_or(&outcome);
            let mean_attempts = outcome
                .results
                .iter()
                .map(|r| r.attempts as f64)
                .sum::<f64>()
                / JOBS as f64;
            let survived: u64 = outcome.results.iter().map(|r| r.faults_survived).sum();
            let cost_inflation = outcome
                .results
                .iter()
                .zip(reference.results.iter())
                .map(|(f, c)| tm.time_ns(&f.cost) / tm.time_ns(&c.cost).max(1e-12))
                .sum::<f64>()
                / JOBS as f64;
            let throughput_ratio = outcome.jobs_per_s() / reference.jobs_per_s().max(1e-9);
            t.row(vec![
                engine.to_string(),
                format!("{rate:.0e}"),
                outcome.faults_injected.to_string(),
                survived.to_string(),
                outcome.retries.to_string(),
                format!("{mean_attempts:.2}"),
                fmt_f64(outcome.jobs_per_s()),
                format!("{throughput_ratio:.2}"),
                format!("{cost_inflation:.2}"),
            ]);
            if rate == 0.0 {
                clean = Some(outcome);
            }
        }
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn faulty_fleet_completes_and_verifies() {
        // Small debug-mode cell: a nonzero rate, every product verified,
        // nothing lost. (The full sweep runs via `copmul experiment E17`
        // and the chaos_soak suite.)
        let outcome = run_fleet(
            EngineKind::Sim,
            16,
            4,
            4,
            256,
            Some(FaultConfig::new(0xE17, 1e-3)),
        )
        .unwrap();
        assert_eq!(outcome.results.len(), 4);
        verify_fleet(&outcome, 4, 256).unwrap();
    }

    #[test]
    fn clean_run_reports_no_faults() {
        let outcome = run_fleet(EngineKind::Sim, 16, 4, 4, 256, None).unwrap();
        assert_eq!(outcome.faults_injected, 0);
        assert_eq!(outcome.retries, 0);
        assert!(outcome.results.iter().all(|r| r.attempts == 1));
        assert!(outcome.results.iter().all(|r| r.faults_survived == 0));
    }
}

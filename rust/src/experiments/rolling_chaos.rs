//! E21 — rolling-kill soak: self-healing capacity under sustained
//! processor loss, on the in-process engines (FaultyMachine crash +
//! heal + probation) and the socket engine (real SIGKILL + respawn).
//!
//! The same seeded open-loop workload runs twice per engine:
//!
//! * **clean** — faults off, kills off. The reference goodput, and a
//!   standing guard that the probation machinery is a strict no-op on
//!   a healthy machine (zero probes, zero quarantines — the zero-fault
//!   cost-identity invariant's serving-side face; the bit-exact half
//!   lives in `tests/chaos_soak.rs`).
//! * **chaos** — in-process: a seeded always-on `Crash` plan keeps
//!   killing shard processors, the quarantine policy pulls them, and
//!   the daemon's probation pump heals + canary-probes them back.
//!   Sockets: worker-process groups are SIGKILL'd on a schedule while
//!   jobs run; the pump respawns the dead groups
//!   ([`SocketMachine::respawn_group`]) and probation re-admits their
//!   processors.
//!
//! Reported per engine: both goodputs, their ratio, and the recovery
//! counters `{kills, quarantine events, de-quarantined, probes,
//! respawns}`. The experiment *asserts* the self-healing claims: every
//! chaos leg must de-quarantine capacity back (in-process) or respawn
//! the killed groups (sockets), the ledger must drain to empty once
//! the storm stops, and steady-state goodput must stay within
//! [`RECOVERY_FACTOR`] of the clean run — capacity loss is transient,
//! not a permanent strong-scaling downgrade (cf. ROADMAP item 1).
//!
//! [`SocketMachine::respawn_group`]: crate::sim::SocketMachine::respawn_group

use crate::algorithms::leaf::{leaf_ref, SchoolLeaf};
use crate::algorithms::{Algorithm, ExecPolicy};
use crate::config::EngineKind;
use crate::coordinator::{
    run_open_loop, ArrivalGen, Daemon, DaemonConfig, OpenLoop, SchedulerConfig, ServingReport,
    Workload,
};
use crate::error::{ensure, Result};
use crate::metrics::Table;
use crate::sim::{socket_available, FaultConfig, FaultKind, SocketConfig};
use std::time::Duration;

/// Documented recovery bound: chaos-leg goodput must stay within this
/// factor of the clean run on the same engine. The bound is loose on
/// purpose — it has to hold under debug builds and loaded CI hosts —
/// but it is the difference between "goodput dips and recovers" and
/// "one fault burst permanently downgrades the machine".
pub const RECOVERY_FACTOR: f64 = 8.0;

/// One (engine, clean-vs-chaos) soak outcome — the `recovery[]`
/// section of the schema-10 bench JSON.
#[derive(Clone, Debug)]
pub struct RecoveryCell {
    pub engine: &'static str,
    pub offered: u64,
    pub completed: u64,
    pub shed: u64,
    /// Kill events: worker SIGKILLs (sockets) or injected crash faults
    /// (in-process; the plan's total).
    pub kills: u64,
    /// Monotone quarantine events during the chaos leg.
    pub quarantine_events: u64,
    /// Processors probation re-admitted during the chaos leg.
    pub dequarantined: u64,
    pub probes_sent: u64,
    pub respawns: u64,
    pub clean_goodput_per_s: f64,
    pub chaos_goodput_per_s: f64,
    /// `chaos / clean` goodput (the number [`RECOVERY_FACTOR`] bounds).
    pub recovery_ratio: f64,
}

/// Soak sizing: `smoke` keeps CI's debug tier fast; the full size runs
/// in `copmul bench` / the release `rolling-chaos` job.
fn sizes(smoke: bool) -> (u64, f64) {
    if smoke {
        (48, 400.0)
    } else {
        (160, 800.0)
    }
}

fn daemon_for(engine: EngineKind, fault: Option<FaultConfig>) -> Result<Daemon> {
    Daemon::start(
        DaemonConfig {
            sched: SchedulerConfig {
                procs: 16,
                runners: 4,
                engine,
                max_queue: 4096,
                fault,
                max_attempts: 4,
                // Quarantine fast and probe back fast: the soak is
                // about the *churn*, not about tuning the thresholds.
                quarantine_after: 2,
                probation_successes: 2,
                ..Default::default()
            },
            ..Default::default()
        },
        leaf_ref(SchoolLeaf),
    )
}

fn open_loop(seed: u64, jobs: u64, rate: f64, procs: usize, n: usize) -> Result<OpenLoop> {
    Ok(OpenLoop {
        arrivals: ArrivalGen::poisson(seed ^ 0xE21, rate)?,
        jobs,
        workload: Workload {
            seed: seed ^ 0x50AC,
            n,
            base_log2: 16,
            procs,
            algo: Some(Algorithm::Copsim),
            exec_mode: ExecPolicy::Dfs,
        },
        verify: false,
        collect: false,
    })
}

/// The clean leg doubles as the no-op guard: a healthy machine must
/// never see a probe, a quarantine, or a respawn.
fn check_clean(engine: &str, rep: &ServingReport) -> Result<()> {
    ensure!(rep.completed > 0, "E21 {engine}: clean run completed nothing");
    ensure!(
        rep.quarantined == 0 && rep.dequarantined == 0 && rep.probes_sent == 0
            && rep.respawns == 0,
        "E21 {engine}: probation machinery fired on a zero-fault run \
         ({} quarantined, {} probes) — the no-op invariant is broken",
        rep.quarantined,
        rep.probes_sent
    );
    Ok(())
}

/// Drain the quarantine ledger after the storm: keep probing until
/// empty (bounded), then assert full capacity is back. `run_open_loop`'s
/// pump does most of this during the run; the tail covers processors
/// quarantined by the last few jobs.
fn drain_ledger(daemon: &Daemon, engine: &str) -> Result<()> {
    for _ in 0..64 {
        if daemon.scheduler().quarantined_procs() == 0 {
            break;
        }
        daemon.scheduler().probe_quarantined();
    }
    let left = daemon.scheduler().quarantined_procs();
    ensure!(
        left == 0,
        "E21 {engine}: {left} processors still quarantined after the storm \
         stopped and 64 probation cycles — capacity loss is not reversible"
    );
    Ok(())
}

/// In-process leg: a seeded `Crash`-only plan rolls over the shard
/// processors for the whole run while the daemon's probation pump
/// heals and re-admits them.
fn in_process_leg(engine: EngineKind, name: &'static str, smoke: bool) -> Result<RecoveryCell> {
    let (jobs, rate) = sizes(smoke);
    let clean = {
        let daemon = daemon_for(engine, None)?;
        let rep = run_open_loop(&daemon, &open_loop(11, jobs, rate, 4, 256)?)?;
        check_clean(name, &rep)?;
        daemon.shutdown()?;
        rep
    };
    let daemon = daemon_for(
        engine,
        Some(FaultConfig::new(0xE21, 1e-3).only(&[FaultKind::Crash])),
    )?;
    let rep = run_open_loop(&daemon, &open_loop(11, jobs, rate, 4, 256)?)?;
    drain_ledger(&daemon, name)?;
    let kills = daemon.scheduler().faults_injected();
    daemon.shutdown()?;
    ensure!(rep.completed > 0, "E21 {name}: chaos run completed nothing");
    ensure!(kills > 0, "E21 {name}: the crash plan injected nothing");
    ensure!(
        rep.quarantined > 0 && rep.dequarantined > 0,
        "E21 {name}: no quarantine churn ({} quarantined, {} back) — the soak \
         exercised nothing",
        rep.quarantined,
        rep.dequarantined
    );
    Ok(cell(name, kills, &clean, rep))
}

/// Socket leg: real SIGKILLs on a deterministic schedule (kill a
/// group, give the pump a beat to respawn + probe, kill the next),
/// while the open-loop workload runs from this thread.
fn socket_leg(smoke: bool) -> Result<RecoveryCell> {
    let (jobs, rate) = {
        let (j, r) = sizes(smoke);
        (j / 2, r / 2.0) // socket jobs are process-crossing; keep the soak bounded
    };
    let clean = {
        let daemon = daemon_for(EngineKind::Sockets, None)?;
        let rep = run_open_loop(&daemon, &open_loop(13, jobs, rate, 2, 128)?)?;
        check_clean("sockets", &rep)?;
        daemon.shutdown()?;
        rep
    };
    let daemon = daemon_for(EngineKind::Sockets, None)?;
    let groups = daemon.scheduler().socket_worker_pids().len();
    ensure!(groups >= 2, "E21 sockets: expected >= 2 worker groups, got {groups}");
    let mut kills = 0u64;
    let rep = std::thread::scope(|scope| -> Result<ServingReport> {
        let sched = daemon.scheduler();
        let killer = scope.spawn(move || -> u64 {
            let mut killed = 0;
            // Rolling schedule: one group at a time, never the whole
            // fleet at once — the liveness wall (chaos_soak) covers
            // the all-dead edge; this soak measures recovery.
            for (delay_ms, g) in [(120u64, 1usize), (350, 0), (600, 1)] {
                std::thread::sleep(Duration::from_millis(delay_ms));
                if sched.kill_socket_worker(g % groups).is_ok() {
                    killed += 1;
                }
            }
            killed
        });
        let rep = run_open_loop(&daemon, &open_loop(13, jobs, rate, 2, 128)?);
        kills = killer.join().expect("E21 kill thread panicked");
        rep
    })?;
    drain_ledger(&daemon, "sockets")?;
    ensure!(
        daemon.scheduler().socket_worker_pids().iter().all(Option::is_some),
        "E21 sockets: a worker group is still dead after the drain"
    );
    daemon.shutdown()?;
    ensure!(kills > 0, "E21 sockets: the kill schedule killed nothing");
    ensure!(
        rep.respawns > 0,
        "E21 sockets: {kills} kills but zero respawns — the elastic pool never fired"
    );
    Ok(cell("sockets", kills, &clean, rep))
}

fn cell(
    engine: &'static str,
    kills: u64,
    clean: &ServingReport,
    rep: ServingReport,
) -> RecoveryCell {
    let clean_gp = clean.goodput_per_s();
    RecoveryCell {
        engine,
        offered: rep.offered,
        completed: rep.completed,
        shed: rep.shed_total(),
        kills,
        quarantine_events: rep.quarantined,
        dequarantined: rep.dequarantined,
        probes_sent: rep.probes_sent,
        respawns: rep.respawns,
        clean_goodput_per_s: clean_gp,
        chaos_goodput_per_s: rep.goodput_per_s(),
        recovery_ratio: rep.goodput_per_s() / clean_gp.max(1e-9),
    }
}

/// The full soak: both in-process engines, plus the socket engine when
/// a worker binary resolves. Feeds both `copmul experiment E21` and
/// the bench report's `recovery[]` section.
pub fn soak_cells(smoke: bool) -> Result<Vec<RecoveryCell>> {
    let mut cells = vec![
        in_process_leg(EngineKind::Sim, "sim", smoke)?,
        in_process_leg(EngineKind::Threads, "threads", smoke)?,
    ];
    if socket_available() {
        cells.push(socket_leg(smoke)?);
    }
    for c in &cells {
        ensure!(
            c.recovery_ratio >= 1.0 / RECOVERY_FACTOR,
            "E21 {}: chaos goodput {:.1}/s is below clean {:.1}/s by more than \
             the documented {RECOVERY_FACTOR}x recovery bound",
            c.engine,
            c.chaos_goodput_per_s,
            c.clean_goodput_per_s
        );
    }
    Ok(cells)
}

pub fn e21_rolling_chaos() -> Result<Vec<Table>> {
    let smoke = std::env::var("COPMUL_E21_FULL").as_deref() != Ok("1");
    let cells = soak_cells(smoke)?;
    let sock_note = if socket_available() {
        "socket leg: real SIGKILL + respawn"
    } else {
        "socket leg skipped: no worker binary"
    };
    let mut t = Table::new(
        format!(
            "E21: rolling-kill soak — goodput under sustained processor loss vs \
             the clean run (bound: within {RECOVERY_FACTOR}x; {sock_note})"
        ),
        &[
            "engine",
            "offered",
            "done",
            "shed",
            "kills",
            "quarantined",
            "probed back",
            "probes",
            "respawns",
            "clean gp/s",
            "chaos gp/s",
            "ratio",
        ],
    );
    for c in &cells {
        t.row(vec![
            c.engine.into(),
            c.offered.to_string(),
            c.completed.to_string(),
            c.shed.to_string(),
            c.kills.to_string(),
            c.quarantine_events.to_string(),
            c.dequarantined.to_string(),
            c.probes_sent.to_string(),
            c.respawns.to_string(),
            format!("{:.1}", c.clean_goodput_per_s),
            format!("{:.1}", c.chaos_goodput_per_s),
            format!("{:.2}", c.recovery_ratio),
        ]);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_leg_recovers_capacity() {
        // One smoke-sized in-process leg end to end: crash churn,
        // probation re-admission, ledger drained, goodput within the
        // bound. (Threads + sockets run via `copmul experiment E21`
        // and the rolling-chaos CI job.)
        let c = in_process_leg(EngineKind::Sim, "sim", true).unwrap();
        assert!(c.completed > 0);
        assert!(c.quarantine_events > 0, "no quarantine churn");
        assert!(c.dequarantined > 0, "probation never re-admitted");
        assert!(c.probes_sent >= c.dequarantined);
        assert!(c.recovery_ratio >= 1.0 / RECOVERY_FACTOR);
    }

    #[test]
    fn clean_leg_is_a_probation_no_op() {
        let daemon = daemon_for(EngineKind::Sim, None).unwrap();
        let rep = run_open_loop(&daemon, &open_loop(11, 16, 800.0, 4, 128).unwrap()).unwrap();
        check_clean("sim", &rep).unwrap();
        assert_eq!(daemon.scheduler().total_quarantine_events(), 0);
        daemon.shutdown().unwrap();
    }
}

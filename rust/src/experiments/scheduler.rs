//! E16 — sharded scheduler vs serial execution: jobs/sec and per-job
//! critical-path inflation, on both execution engines.
//!
//! The same fleet of jobs runs twice per engine: **serial** (the shared
//! machine is exactly one shard, so jobs queue behind each other) and
//! **sharded** (the machine holds several shards and jobs run
//! concurrently). Two claims are measured:
//!
//! * **Throughput scales** — jobs/sec of the sharded run over the
//!   serial run.
//! * **Per-job costs do not inflate** — the scheduler barriers each
//!   shard to a uniform clock baseline, so a job's critical-path cost
//!   triple is bit-identical whether it shared the machine or had it
//!   alone (`cost inflation = 1.00` by construction; the table prints
//!   the measured ratio so a regression is visible, and the
//!   differential suite asserts the equality case by case). Per-job
//!   wall time is end-to-end (queue wait included), so the sharded
//!   run's wall ratio also shows the *latency* win: serial jobs queue
//!   behind each other, sharded jobs don't.

use crate::algorithms::leaf::{leaf_ref, SchoolLeaf};
use crate::algorithms::Algorithm;
use crate::config::EngineKind;
use crate::coordinator::{JobResult, JobSpec, Scheduler, SchedulerConfig};
use crate::error::{ensure, Result};
use crate::metrics::{fmt_f64, fmt_u64, Table};
use crate::theory::TimeModel;
use crate::util::Rng;
use std::time::Duration;

/// One scheduler run over a fixed fleet of jobs.
pub struct FleetOutcome {
    /// Wall-clock from first submission to last completion.
    pub wall: Duration,
    /// Per-job results, in submission (id) order.
    pub results: Vec<JobResult>,
    /// High-water mark of concurrently running jobs.
    pub peak_concurrent: u64,
    /// Total faults the machine's plan injected (0 without a plan).
    pub faults_injected: u64,
    /// Failed attempts that were requeued.
    pub retries: u64,
}

impl FleetOutcome {
    pub fn jobs_per_s(&self) -> f64 {
        self.results.len() as f64 / self.wall.as_secs_f64().max(1e-9)
    }
}

/// Run `jobs` identical-distribution jobs (seeded; the fleet is the
/// same across calls) through a scheduler of `procs` processors with
/// `runners` concurrent shards. `fault` optionally arms the shared
/// machine's deterministic injection plan (E17); `None` is the
/// fault-free configuration every other experiment uses.
pub fn run_fleet(
    engine: EngineKind,
    procs: usize,
    runners: usize,
    jobs: usize,
    n: usize,
    fault: Option<crate::sim::FaultConfig>,
) -> Result<FleetOutcome> {
    let sched = Scheduler::start(
        SchedulerConfig {
            procs,
            runners,
            engine,
            fault,
            max_attempts: 5,
            // Uniform injection would quarantine arbitrary processors
            // and turn throughput runs into capacity races; the policy
            // has its own tests (see tests/chaos_soak.rs rationale).
            quarantine_after: 0,
            ..Default::default()
        },
        leaf_ref(SchoolLeaf),
    )?;
    let mut rng = Rng::new(0xE16);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::with_capacity(jobs);
    for id in 0..jobs as u64 {
        let a = rng.digits(n, 16);
        let b = rng.digits(n, 16);
        let mut spec = JobSpec::new(id, a, b);
        spec.procs = 4;
        spec.algo = Some(Algorithm::Copsim);
        pending.push(sched.submit(spec)?);
    }
    let mut results = Vec::with_capacity(jobs);
    for rx in pending {
        results.push(rx.recv().expect("scheduler dropped reply")?);
    }
    let wall = t0.elapsed();
    let peak_concurrent = sched
        .stats
        .peak_concurrent
        .load(std::sync::atomic::Ordering::Relaxed);
    let faults_injected = sched.faults_injected();
    let retries = sched.stats.retries.load(std::sync::atomic::Ordering::Relaxed);
    sched.shutdown()?;
    Ok(FleetOutcome {
        wall,
        results,
        peak_concurrent,
        faults_injected,
        retries,
    })
}

/// Mean over jobs of `num[i] / den[i]`.
fn mean_ratio(num: impl Iterator<Item = f64>, den: impl Iterator<Item = f64>) -> f64 {
    let (mut acc, mut count) = (0.0, 0usize);
    for (x, y) in num.zip(den) {
        acc += x / y.max(1e-12);
        count += 1;
    }
    acc / count.max(1) as f64
}

pub fn e16_scheduler() -> Result<Vec<Table>> {
    const JOBS: usize = 8;
    const N: usize = 1024;
    let tm = TimeModel::default();
    let mut t = Table::new(
        "E16: sharded scheduler vs serial execution (8 jobs, n = 1024, 4 procs/job; \
         cost inflation 1.00 = sharding does not distort the paper's per-job metrics)",
        &[
            "engine",
            "mode",
            "P",
            "shards",
            "peak conc.",
            "jobs/s",
            "mean job T",
            "cost inflation",
            "mean wall ms",
            "wall inflation",
            "throughput speedup",
        ],
    );
    for engine in [EngineKind::Sim, EngineKind::Threads] {
        let serial = run_fleet(engine, 4, 1, JOBS, N, None)?;
        let sharded = run_fleet(engine, 16, 4, JOBS, N, None)?;
        ensure!(
            serial.results.len() == sharded.results.len(),
            "fleet size mismatch"
        );
        for (s, h) in serial.results.iter().zip(sharded.results.iter()) {
            ensure!(
                s.product == h.product,
                "sharded product diverged from serial at job {}",
                s.id
            );
        }
        let cost_inflation = mean_ratio(
            sharded.results.iter().map(|r| tm.time_ns(&r.cost)),
            serial.results.iter().map(|r| tm.time_ns(&r.cost)),
        );
        let wall_inflation = mean_ratio(
            sharded.results.iter().map(|r| r.wall.as_secs_f64()),
            serial.results.iter().map(|r| r.wall.as_secs_f64()),
        );
        let mean_ops = |rs: &[JobResult]| {
            rs.iter().map(|r| r.cost.ops).sum::<u64>() / rs.len() as u64
        };
        let mean_wall_ms = |o: &FleetOutcome| {
            o.results.iter().map(|r| r.wall.as_secs_f64()).sum::<f64>() * 1e3
                / o.results.len() as f64
        };
        for (mode, outcome, shards) in [("serial", &serial, 1usize), ("sharded", &sharded, 4)] {
            t.row(vec![
                engine.to_string(),
                mode.into(),
                if shards == 1 { "4".into() } else { "16".into() },
                shards.to_string(),
                outcome.peak_concurrent.to_string(),
                fmt_f64(outcome.jobs_per_s()),
                fmt_u64(mean_ops(&outcome.results)),
                if mode == "serial" {
                    "1.00".into()
                } else {
                    format!("{cost_inflation:.2}")
                },
                fmt_f64(mean_wall_ms(outcome)),
                if mode == "serial" {
                    "1.00".into()
                } else {
                    format!("{wall_inflation:.2}")
                },
                if mode == "serial" {
                    "1.00".into()
                } else {
                    format!("{:.2}", sharded.jobs_per_s() / serial.jobs_per_s().max(1e-9))
                },
            ]);
        }
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_costs_identical_serial_vs_sharded() {
        // Small fleet so the debug-mode suite stays fast; the full E16
        // cell runs in release via `copmul experiment E16`.
        let serial = run_fleet(EngineKind::Sim, 4, 1, 4, 256, None).unwrap();
        let sharded = run_fleet(EngineKind::Sim, 16, 4, 4, 256, None).unwrap();
        for (s, h) in serial.results.iter().zip(sharded.results.iter()) {
            assert_eq!(s.product, h.product, "job {}", s.id);
            assert_eq!(s.cost, h.cost, "sharding distorted job {}'s cost", s.id);
        }
        assert_eq!(serial.peak_concurrent, 1);
    }

    #[test]
    fn fleet_runs_on_threaded_engine() {
        let sharded = run_fleet(EngineKind::Threads, 16, 4, 4, 256, None).unwrap();
        assert_eq!(sharded.results.len(), 4);
        assert!(sharded.results.iter().all(|r| r.cost.ops > 0));
    }
}

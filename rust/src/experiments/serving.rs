//! E19 — always-on serving under open-loop load: latency and goodput
//! vs offered arrival rate on both execution engines, plus the
//! zero-fault cost identity *under load*.
//!
//! Unlike E16's closed-loop fleet (submit everything, wait), the
//! [`crate::coordinator::Daemon`] is driven open-loop: arrivals follow
//! a seeded Poisson/bursty schedule and never wait for completions, so
//! offered load can exceed capacity. The first table sweeps offered
//! rate per engine and reports admitted-job percentiles, goodput, and
//! the shed breakdown — past saturation, goodput should plateau near
//! capacity while sheds absorb the excess instead of the queue (and
//! p99) growing without bound.
//!
//! The second table replays every completed job of a verify+collect
//! run on a dedicated machine ([`Workload::spec`] regenerates the
//! exact `JobSpec` from the job id) and asserts its `(T, BW, L)`
//! triple is **bit-identical** to the dedicated run: on the
//! fully-connected topology, concurrency and shedding change *when* a
//! job runs, never what it costs — the paper's per-multiplication
//! bounds hold per job under serving load.

use std::time::Duration;

use crate::algorithms::leaf::{leaf_ref, SchoolLeaf};
use crate::config::EngineKind;
use crate::coordinator::{
    execute_on, run_open_loop, ArrivalGen, Daemon, DaemonConfig, OpenLoop, SchedulerConfig,
    Workload,
};
use crate::error::{ensure, Result};
use crate::metrics::{fmt_f64, fmt_u64, Table};
use crate::sim::{Machine, Seq};

const SEED: u64 = 0xE19;

fn daemon_for(engine: EngineKind) -> Result<Daemon> {
    Daemon::start(
        DaemonConfig {
            sched: SchedulerConfig {
                procs: 16,
                engine,
                runners: 4,
                max_queue: 64,
                ..Default::default()
            },
            default_deadline: Some(Duration::from_millis(250)),
            ..Default::default()
        },
        leaf_ref(SchoolLeaf),
    )
}

fn workload() -> Workload {
    Workload {
        seed: SEED,
        n: 256,
        base_log2: 16,
        procs: 4,
        algo: Some(crate::algorithms::Algorithm::Copsim),
        exec_mode: crate::algorithms::ExecPolicy::Dfs,
    }
}

pub fn e19_serving() -> Result<Vec<Table>> {
    const JOBS: u64 = 96;
    const RATES: [f64; 3] = [400.0, 1600.0, 6400.0];
    let mut t1 = Table::new(
        "E19: open-loop serving curve (96 jobs/cell, n = 256, 16 procs / 4 shards, \
         250 ms deadline; percentiles over admitted completions)",
        &[
            "engine",
            "offered/s",
            "offered",
            "completed",
            "shed",
            "p50 µs",
            "p99 µs",
            "p999 µs",
            "goodput/s",
        ],
    );
    for engine in [EngineKind::Sim, EngineKind::Threads] {
        for (i, &rate) in RATES.iter().enumerate() {
            let daemon = daemon_for(engine)?;
            let load = OpenLoop {
                arrivals: ArrivalGen::poisson(SEED ^ i as u64, rate)?,
                jobs: JOBS,
                workload: workload(),
                verify: false,
                collect: false,
            };
            let rep = run_open_loop(&daemon, &load)?;
            daemon.shutdown()?;
            ensure!(rep.failed == 0, "E19 jobs must not fail on {engine}");
            t1.row(vec![
                engine.to_string(),
                format!("{rate:.0}"),
                rep.offered.to_string(),
                rep.completed.to_string(),
                rep.shed_total().to_string(),
                fmt_u64(rep.percentile_us(0.50)),
                fmt_u64(rep.percentile_us(0.99)),
                fmt_u64(rep.percentile_us(0.999)),
                fmt_f64(rep.goodput_per_s()),
            ]);
        }
    }

    let mut t2 = Table::new(
        "E19: zero-fault cost identity under load (verify+collect run; every \
         completed job's (T, BW, L) replayed on a dedicated machine)",
        &["engine", "completed", "identical triples", "verdict"],
    );
    for engine in [EngineKind::Sim, EngineKind::Threads] {
        let daemon = daemon_for(engine)?;
        let load = OpenLoop {
            arrivals: ArrivalGen::poisson(SEED ^ 0x1D, 1600.0)?,
            jobs: 32,
            workload: workload(),
            verify: true,
            collect: true,
        };
        let rep = run_open_loop(&daemon, &load)?;
        let cfg = daemon.scheduler().config().clone();
        daemon.shutdown()?;
        let leaf = leaf_ref(SchoolLeaf);
        for res in &rep.results {
            let spec = load.workload.spec(res.id);
            let shard = res.shard.as_ref().expect("scheduler results carry shards");
            let mut solo = Machine::new(shard.len(), cfg.mem_cap, cfg.base);
            let seq = Seq::range(shard.len());
            execute_on(&mut solo, &cfg.time_model, &spec, &seq, &leaf)?;
            ensure!(
                res.cost == solo.critical(),
                "job {} cost under load differs from dedicated run on {engine}",
                res.id
            );
        }
        t2.row(vec![
            engine.to_string(),
            rep.results.len().to_string(),
            rep.results.len().to_string(),
            "bit-identical".to_string(),
        ]);
    }
    Ok(vec![t1, t2])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serving_cell_completes_and_sheds_are_accounted() {
        // One small cell: accounting balances and nothing fails.
        let daemon = daemon_for(EngineKind::Sim).unwrap();
        let load = OpenLoop {
            arrivals: ArrivalGen::poisson(SEED, 2000.0).unwrap(),
            jobs: 12,
            workload: workload(),
            verify: false,
            collect: false,
        };
        let rep = run_open_loop(&daemon, &load).unwrap();
        daemon.shutdown().unwrap();
        assert_eq!(rep.failed, 0);
        assert_eq!(
            rep.completed + rep.shed_total() + rep.rejected_unfittable,
            rep.offered
        );
    }

    #[test]
    fn cost_identity_holds_for_a_collected_job() {
        let daemon = daemon_for(EngineKind::Sim).unwrap();
        let load = OpenLoop {
            arrivals: ArrivalGen::poisson(SEED ^ 7, 2000.0).unwrap(),
            jobs: 4,
            workload: workload(),
            verify: true,
            collect: true,
        };
        let rep = run_open_loop(&daemon, &load).unwrap();
        let cfg = daemon.scheduler().config().clone();
        daemon.shutdown().unwrap();
        assert!(!rep.results.is_empty());
        let leaf = leaf_ref(SchoolLeaf);
        let res = &rep.results[0];
        let spec = load.workload.spec(res.id);
        let shard = res.shard.as_ref().unwrap();
        let mut solo = Machine::new(shard.len(), cfg.mem_cap, cfg.base);
        let seq = Seq::range(shard.len());
        execute_on(&mut solo, &cfg.time_model, &spec, &seq, &leaf).unwrap();
        assert_eq!(res.cost, solo.critical());
    }
}

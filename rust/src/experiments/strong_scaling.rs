//! E20 — strong scaling under a fixed per-processor memory budget,
//! with memory-adaptive BFS/DFS execution (ISSUE 9).
//!
//! Classic strong scaling (E10) grants every cell the memory the
//! theorems assume (`M = Θ(n/P)` with the theorem's own constant).
//! This experiment asks the operational question instead: with `n`
//! **fixed** and every processor owning the **same** `M` words, what
//! happens as `P` grows?
//!
//! * **Memory-bound cliff** — below a critical `P`, no schedule fits:
//!   the MI footprint `12n/√P` exceeds `M` and the stepping fallback
//!   needs `80n/P`, which is even larger at small `P`. Those cells are
//!   *infeasible*, reported as the cliff edge rather than silently
//!   skipped.
//! * **Perfect-scaling range** — once `12n/√P ≤ M`, the MI schedule
//!   runs and per-processor bandwidth tracks `Θ(n/√P)`: the normalized
//!   column `BW·√P/n` stays flat across the range.
//! * **BFS range** — once the surplus reaches the fused-distribution
//!   gate (`24n/√P ≤ M`), `--exec-mode=auto` spends it: the
//!   breadth-first variants elide repartition rounds and charged BW
//!   drops strictly below DFS at bit-equal `T` (`theory::best_mode`).
//!
//! Every feasible cell is executed on the cost-model simulator and the
//! threaded engine (plus the socket engine when a worker binary
//! resolves), on every topology, in both modes; products and cost
//! triples are asserted bit-identical across engines before a row is
//! reported. The second table pins the measured-vs-predicted BW story
//! per (algorithm, regime): BFS strictly beats DFS exactly where
//! `theory::bfs_levels` says the memory allows it, and COPK's MI
//! regime is mode-invariant (DESIGN.md decision 15).

use crate::algorithms::leaf::{leaf_ref, LeafRef, SchoolLeaf, SkimLeaf};
use crate::algorithms::{mul_with_mode, Algorithm, ExecMode};
use crate::bignum::Base;
use crate::config::EngineKind;
use crate::error::{ensure, Result};
use crate::metrics::{fmt_f64, fmt_u64, Table};
use crate::sim::{
    socket_available, Clock, DistInt, Machine, MachineApi, Seq, SocketMachine, ThreadedMachine,
    TopologyKind,
};
use crate::theory;
use crate::util::Rng;

/// The fixed-(n, M) COPSIM sweep: P ladder crossing the cliff, the
/// perfect-scaling range, and the BFS range (module docs).
const SWEEP_N: usize = 1024;
const SWEEP_CAP: u64 = 2048;
const SWEEP_P: [usize; 4] = [4, 16, 64, 256];

fn leaf_for(algo: Algorithm) -> LeafRef {
    match algo {
        Algorithm::Copsim => leaf_ref(SchoolLeaf),
        Algorithm::Copk => leaf_ref(SkimLeaf),
    }
}

fn run_on<M: MachineApi>(
    m: &mut M,
    algo: Algorithm,
    mode: ExecMode,
    seq: &Seq,
    a: &[u32],
    b: &[u32],
    leaf: &LeafRef,
) -> Result<Vec<u32>> {
    let w = a.len() / seq.len();
    let da = DistInt::scatter(m, seq, a, w)?;
    let db = DistInt::scatter(m, seq, b, w)?;
    let c = mul_with_mode(m, seq, da, db, leaf, algo, mode)?;
    let product = c.gather(m)?;
    c.free(m);
    Ok(product)
}

/// One (algo, mode, n, P, M, topology) cell on one engine.
fn measure(
    algo: Algorithm,
    mode: ExecMode,
    n: usize,
    p: usize,
    cap: u64,
    kind: TopologyKind,
    engine: EngineKind,
    seed: u64,
) -> Result<(Vec<u32>, Clock)> {
    let base = Base::new(16);
    let leaf = leaf_for(algo);
    let mut rng = Rng::new(seed);
    let a = rng.digits(n, 16);
    let b = rng.digits(n, 16);
    let seq = Seq::range(p);
    let topo = kind.build(p);
    match engine {
        EngineKind::Sim => {
            let mut m = Machine::with_topology(p, cap, base, topo);
            let prod = run_on(&mut m, algo, mode, &seq, &a, &b, &leaf)?;
            Ok((prod, m.critical()))
        }
        EngineKind::Threads => {
            let mut m = ThreadedMachine::with_topology(p, cap, base, topo);
            let prod = run_on(&mut m, algo, mode, &seq, &a, &b, &leaf)?;
            let report = m.finish()?;
            Ok((prod, report.critical))
        }
        EngineKind::Sockets => {
            let mut m = SocketMachine::with_topology(p, cap, base, topo)?;
            let prod = run_on(&mut m, algo, mode, &seq, &a, &b, &leaf)?;
            let report = m.finish()?;
            Ok((prod, report.critical))
        }
    }
}

/// Run one cell on every available engine, assert products and cost
/// triples bit-identical, and return the shared triple.
pub fn cross_engine_cell(
    algo: Algorithm,
    mode: ExecMode,
    n: usize,
    p: usize,
    cap: u64,
    kind: TopologyKind,
    seed: u64,
) -> Result<Clock> {
    let (sim_prod, sim_cost) = measure(algo, mode, n, p, cap, kind, EngineKind::Sim, seed)?;
    let (thr_prod, thr_cost) = measure(algo, mode, n, p, cap, kind, EngineKind::Threads, seed)?;
    ensure!(
        sim_prod == thr_prod && sim_cost == thr_cost,
        "engines disagree at {algo} {mode} n={n} P={p} {kind}: \
         sim {sim_cost} vs threads {thr_cost}"
    );
    if socket_available() {
        let (sock_prod, sock_cost) =
            measure(algo, mode, n, p, cap, kind, EngineKind::Sockets, seed)?;
        ensure!(
            sim_prod == sock_prod && sim_cost == sock_cost,
            "socket engine disagrees at {algo} {mode} n={n} P={p} {kind}: \
             sim {sim_cost} vs sockets {sock_cost}"
        );
    }
    Ok(sim_cost)
}

/// One strong-scaling data point for the JSON artifact (`perf`'s
/// `strong_scaling[]` section mirrors these fields).
#[derive(Clone, Debug)]
pub struct ScalingCell {
    pub algo: Algorithm,
    pub topology: TopologyKind,
    pub p: usize,
    pub n: usize,
    pub mem_cap: u64,
    /// `None` = the cell is memory-bound (no schedule fits the cap).
    pub mode: Option<ExecMode>,
    pub dfs_bw: Option<u64>,
    pub auto_bw: Option<u64>,
    pub predicted_bw: Option<u64>,
    pub ops: Option<u64>,
}

/// The sweep behind both the E20 table and the bench artifact: every
/// feasible (P, topology) cell of the fixed-(n, M) ladder, in DFS and
/// auto modes, cross-checked on all engines.
pub fn sweep_cells(seed: u64) -> Result<Vec<ScalingCell>> {
    let algo = Algorithm::Copsim;
    let mut out = Vec::new();
    for &p in &SWEEP_P {
        let (n64, p64) = (SWEEP_N as u64, p as u64);
        let (_, dfs_mem) = theory::exec_mode_bounds(algo, n64, p64, SWEEP_CAP, ExecMode::Dfs);
        let auto_mode = theory::best_mode(algo, n64, p64, SWEEP_CAP);
        for kind in TopologyKind::ALL {
            if dfs_mem > SWEEP_CAP {
                // The memory-bound cliff: no schedule fits this cell.
                out.push(ScalingCell {
                    algo,
                    topology: kind,
                    p,
                    n: SWEEP_N,
                    mem_cap: SWEEP_CAP,
                    mode: None,
                    dfs_bw: None,
                    auto_bw: None,
                    predicted_bw: None,
                    ops: None,
                });
                continue;
            }
            let dfs = cross_engine_cell(algo, ExecMode::Dfs, SWEEP_N, p, SWEEP_CAP, kind, seed)?;
            let auto = cross_engine_cell(algo, auto_mode, SWEEP_N, p, SWEEP_CAP, kind, seed)?;
            ensure!(
                auto.ops == dfs.ops,
                "T must be mode-invariant at P={p} {kind}: auto {} vs dfs {}",
                auto.ops,
                dfs.ops
            );
            if auto_mode != ExecMode::Dfs {
                ensure!(
                    auto.words < dfs.words,
                    "BFS must charge strictly fewer words at P={p} {kind}: \
                     {} !< {}",
                    auto.words,
                    dfs.words
                );
            }
            let (bound, _) = theory::exec_mode_bounds(algo, n64, p64, SWEEP_CAP, auto_mode);
            let predicted = theory::predicted_for_topology(bound, kind.build(p).as_ref());
            out.push(ScalingCell {
                algo,
                topology: kind,
                p,
                n: SWEEP_N,
                mem_cap: SWEEP_CAP,
                mode: Some(auto_mode),
                dfs_bw: Some(dfs.words),
                auto_bw: Some(auto.words),
                predicted_bw: Some(predicted.words),
                ops: Some(auto.ops),
            });
        }
    }
    Ok(out)
}

/// The per-regime mode-economics cells of the second table:
/// (algo, P, n, cap, label). Caps are the verified cells of
/// `algorithms::exec` — roomy (fused MI), stepping (clone-elided
/// steps), and COPK's mode-invariant MI regime.
const MODE_CELLS: &[(Algorithm, usize, usize, u64, &str)] = &[
    (Algorithm::Copsim, 16, 1024, 8192, "roomy (fused MI)"),
    (Algorithm::Copsim, 256, 4096, 2048, "stepping (elided clones)"),
    (Algorithm::Copk, 108, 5184, 2304, "stepping (elided clones)"),
    (Algorithm::Copk, 12, 384, u64::MAX / 4, "MI (mode-invariant)"),
];

pub fn e20_strong_scaling() -> Result<Vec<Table>> {
    let seed = 0xE20;
    let mut t1 = Table::new(
        "E20: strong scaling at fixed n and fixed per-processor memory \
         (COPSIM, n = 1024, M = 2048 words/proc; every feasible cell \
         cross-checked on all engines, auto mode; `memory-bound` rows \
         are the cliff where no schedule fits; BW·√P/n flat = perfect \
         scaling)",
        &[
            "P",
            "topology",
            "mode",
            "T",
            "BW (dfs)",
            "BW (auto)",
            "pred BW",
            "BW ratio",
            "BW·√P/n",
        ],
    );
    for cell in sweep_cells(seed)? {
        match cell.mode {
            None => t1.row(vec![
                cell.p.to_string(),
                cell.topology.to_string(),
                "memory-bound".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ]),
            Some(mode) => {
                let (bw, dfs_bw, pred) = (
                    cell.auto_bw.unwrap(),
                    cell.dfs_bw.unwrap(),
                    cell.predicted_bw.unwrap(),
                );
                t1.row(vec![
                    cell.p.to_string(),
                    cell.topology.to_string(),
                    mode.to_string(),
                    fmt_u64(cell.ops.unwrap()),
                    fmt_u64(dfs_bw),
                    fmt_u64(bw),
                    fmt_u64(pred),
                    fmt_f64(bw as f64 / pred.max(1) as f64),
                    fmt_f64(bw as f64 * (cell.p as f64).sqrt() / cell.n as f64),
                ]);
            }
        }
    }

    let mut t2 = Table::new(
        "E20: measured vs predicted BW per execution mode (fully \
         connected; BFS strictly beats DFS exactly where theory says \
         the memory allows it, at bit-equal T; COPK's MI regime is \
         mode-invariant — decision 15)",
        &[
            "algo",
            "regime",
            "P",
            "n",
            "M",
            "mode",
            "T",
            "BW (dfs)",
            "BW (bfs)",
            "pred dfs",
            "pred bfs",
        ],
    );
    for &(algo, p, n, cap, label) in MODE_CELLS {
        let (n64, p64) = (n as u64, p as u64);
        let mode = theory::best_mode(algo, n64, p64, cap);
        let kind = TopologyKind::FullyConnected;
        let dfs = cross_engine_cell(algo, ExecMode::Dfs, n, p, cap, kind, seed)?;
        let bfs = cross_engine_cell(algo, mode, n, p, cap, kind, seed)?;
        ensure!(bfs.ops == dfs.ops, "{algo} {label}: T moved across modes");
        let (dp, _) = theory::exec_mode_bounds(algo, n64, p64, cap, ExecMode::Dfs);
        let (bp, bfs_mem) = theory::exec_mode_bounds(algo, n64, p64, cap, mode);
        if mode == ExecMode::Dfs {
            ensure!(bfs == dfs, "{algo} {label}: DFS resolution must be invariant");
        } else {
            ensure!(bfs_mem <= cap, "{algo} {label}: selected mode must fit");
            ensure!(
                bfs.words < dfs.words && bp.words < dp.words,
                "{algo} {label}: BFS must beat DFS measured and predicted"
            );
        }
        t2.row(vec![
            algo.to_string(),
            label.into(),
            p.to_string(),
            fmt_u64(n as u64),
            if cap > (1 << 40) {
                "unbounded".into()
            } else {
                fmt_u64(cap)
            },
            mode.to_string(),
            fmt_u64(bfs.ops),
            fmt_u64(dfs.words),
            fmt_u64(bfs.words),
            fmt_u64(dp.words),
            fmt_u64(bp.words),
        ]);
    }
    Ok(vec![t1, t2])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_has_a_cliff_a_scaling_range_and_a_bfs_range() {
        // Mode selection is pure theory — no machines needed to pin the
        // sweep's three ranges.
        let (n, cap) = (SWEEP_N as u64, SWEEP_CAP);
        let (_, m4) = theory::exec_mode_bounds(Algorithm::Copsim, n, 4, cap, ExecMode::Dfs);
        let (_, m16) = theory::exec_mode_bounds(Algorithm::Copsim, n, 16, cap, ExecMode::Dfs);
        assert!(m4 > cap && m16 > cap, "P = 4, 16 must be memory-bound");
        assert_eq!(theory::best_mode(Algorithm::Copsim, n, 64, cap), ExecMode::Dfs);
        assert_eq!(
            theory::best_mode(Algorithm::Copsim, n, 256, cap),
            ExecMode::Bfs { levels: 4 }
        );
    }

    #[test]
    fn small_cells_agree_across_engines_in_both_modes() {
        for kind in TopologyKind::ALL {
            let dfs = cross_engine_cell(
                Algorithm::Copsim,
                ExecMode::Dfs,
                1024,
                16,
                8192,
                kind,
                0x720,
            )
            .unwrap();
            let bfs = cross_engine_cell(
                Algorithm::Copsim,
                ExecMode::Bfs { levels: 2 },
                1024,
                16,
                8192,
                kind,
                0x720,
            )
            .unwrap();
            assert_eq!(bfs.ops, dfs.ops, "{kind}: T moved");
            assert!(bfs.words < dfs.words, "{kind}: BFS must cut BW");
        }
    }
}

//! E10-E14: system-level claims — strong scaling, hybridization
//! crossover, baseline comparison, total-memory optimality, and the
//! §2.2 execution-time model.

use super::{run_algo, Algo};
use crate::metrics::{fmt_f64, fmt_ratio, fmt_u64, Table};
use crate::theory::TimeModel;
use crate::error::Result;

/// E10 — strong scaling: fixed n, growing P, M = Θ(n/P).
/// Perfect strong scaling ⇒ `T·P/n²` and `BW·M·P/n²` stay flat.
pub fn e10_strong_scaling() -> Result<Vec<Table>> {
    let n = 1usize << 12;
    let mut ts = Table::new(
        format!("E10a: COPSIM strong scaling at n={n} (M = 80n/P)"),
        &["P", "M", "T", "T·P/n²", "BW", "BW·M·P/n²", "L"],
    );
    for &p in &[4usize, 16, 64, 256] {
        let m = (80 * n / p) as u64;
        let s = run_algo(Algo::CopsimMain, n, p, Some(m), 0x10)?;
        ts.row(vec![
            p.to_string(),
            fmt_u64(m),
            fmt_u64(s.clock.ops),
            fmt_f64(s.clock.ops as f64 * p as f64 / (n * n) as f64),
            fmt_u64(s.clock.words),
            fmt_f64(s.clock.words as f64 * m as f64 * p as f64 / (n * n) as f64),
            fmt_u64(s.clock.msgs),
        ]);
    }
    let nk = 10368usize;
    let mut tk = Table::new(
        format!("E10b: COPK strong scaling at n={nk} (M = 40n/P)"),
        &["P", "M", "T", "T·P/n^lg3", "BW", "BW·P/(n/M)^lg3·M", "L"],
    );
    for &p in &[4usize, 12, 36, 108] {
        let m = (40 * nk / p) as u64;
        let s = run_algo(Algo::CopkMain, nk, p, Some(m), 0x10)?;
        let nlg3 = crate::util::pow_log2_3(nk as f64);
        let bw_scale = crate::util::pow_log2_3(nk as f64 / m as f64) * m as f64 / p as f64;
        tk.row(vec![
            p.to_string(),
            fmt_u64(m),
            fmt_u64(s.clock.ops),
            fmt_f64(s.clock.ops as f64 * p as f64 / nlg3),
            fmt_u64(s.clock.words),
            fmt_ratio(s.clock.words as f64, bw_scale),
            fmt_u64(s.clock.msgs),
        ]);
    }
    Ok(vec![ts, tk])
}

/// E11 — §7 crossover: modeled time of COPSIM vs COPK at P = 4 across
/// n; the crossover point is where COPK wins.
pub fn e11_crossover() -> Result<Vec<Table>> {
    let tm = TimeModel::default();
    let mut t = Table::new(
        "E11: COPSIM vs COPK modeled execution time at P=4 (α=1ns/op, β=1µs/msg, γ=10ns/word)",
        &[
            "n", "COPSIM T", "COPK T", "COPSIM time(µs)", "COPK time(µs)", "winner",
        ],
    );
    let mut crossover: Option<usize> = None;
    for k in 6..=13 {
        let n = 1usize << k;
        let ss = run_algo(Algo::CopsimMi, n, 4, None, 0x11)?;
        let sk = run_algo(Algo::CopkMi, n, 4, None, 0x11)?;
        let t_s = tm.time_ns(&ss.clock) / 1000.0;
        let t_k = tm.time_ns(&sk.clock) / 1000.0;
        let winner = if t_k < t_s { "COPK" } else { "COPSIM" };
        if t_k < t_s && crossover.is_none() {
            crossover = Some(n);
        }
        t.row(vec![
            fmt_u64(n as u64),
            fmt_u64(ss.clock.ops),
            fmt_u64(sk.clock.ops),
            fmt_f64(t_s),
            fmt_f64(t_k),
            winner.into(),
        ]);
    }
    let mut note = Table::new(
        format!(
            "E11 note: measured crossover at n = {} (paper §7: COPK wins for large n, COPSIM for small)",
            crossover.map(|c| c.to_string()).unwrap_or("not reached".into())
        ),
        &["-"],
    );
    note.row(vec!["-".into()]);
    Ok(vec![t, note])
}

/// E12 — baseline comparison at matched (n, P).
pub fn e12_baselines() -> Result<Vec<Table>> {
    let mut t = Table::new(
        "E12: COPSIM/COPK vs baselines (n=4096, P=64 | COPK at P=108, n=5184)",
        &[
            "algorithm", "P", "n", "T", "BW", "L", "peak M/proc", "total M", "total M / n",
        ],
    );
    let (p, n) = (64usize, 4096usize);
    for (name, algo) in [
        ("COPSIM_MI", Algo::CopsimMi),
        ("allgather-schoolbook", Algo::Allgather),
        ("Cesari-Maeder", Algo::CesariMaeder),
    ] {
        let s = run_algo(algo, n, p, None, 0x12)?;
        t.row(vec![
            name.into(),
            p.to_string(),
            fmt_u64(n as u64),
            fmt_u64(s.clock.ops),
            fmt_u64(s.clock.words),
            fmt_u64(s.clock.msgs),
            fmt_u64(s.mem_peak),
            fmt_u64(s.mem_total),
            fmt_ratio(s.mem_total as f64, n as f64),
        ]);
    }
    let (p, n) = (108usize, 5184usize);
    let s = run_algo(Algo::CopkMi, n, p, None, 0x12)?;
    t.row(vec![
        "COPK_MI".into(),
        p.to_string(),
        fmt_u64(n as u64),
        fmt_u64(s.clock.ops),
        fmt_u64(s.clock.words),
        fmt_u64(s.clock.msgs),
        fmt_u64(s.mem_peak),
        fmt_u64(s.mem_total),
        fmt_ratio(s.mem_total as f64, n as f64),
    ]);
    Ok(vec![t])
}

/// E13 — total memory across processors stays O(n) for the paper's
/// algorithms in the LIMITED-memory (main) mode, and Θ(nP) for the
/// all-gather baseline.
pub fn e13_memory() -> Result<Vec<Table>> {
    let mut t = Table::new(
        "E13: total peak memory / n (O(1) = the paper's O(n) total-space claim; main mode, M set to the theorem minimum)",
        &["algorithm", "P", "n", "M cap", "total peak", "total/n"],
    );
    for &(name, algo, p, n) in &[
        ("COPSIM", Algo::CopsimMain, 64usize, 4096usize),
        ("COPSIM", Algo::CopsimMain, 256, 8192),
        ("COPK", Algo::CopkMain, 108, 5184),
        ("COPK", Algo::CopkMain, 108, 10368),
    ] {
        let m = match algo {
            Algo::CopsimMain => (80 * n / p) as u64,
            _ => (40 * n / p) as u64,
        };
        let s = run_algo(algo, n, p, Some(m), 0x13)?;
        t.row(vec![
            name.into(),
            p.to_string(),
            fmt_u64(n as u64),
            fmt_u64(m),
            fmt_u64(s.mem_total),
            fmt_ratio(s.mem_total as f64, n as f64),
        ]);
    }
    // Baseline contrast.
    let (p, n) = (64usize, 4096usize);
    let s = run_algo(Algo::Allgather, n, p, None, 0x13)?;
    t.row(vec![
        "allgather (baseline)".into(),
        p.to_string(),
        fmt_u64(n as u64),
        "inf".into(),
        fmt_u64(s.mem_total),
        fmt_ratio(s.mem_total as f64, n as f64),
    ]);
    Ok(vec![t])
}

/// E14 — §2.2 model: α·T + β·L + γ·BW for all algorithms at matched
/// sizes, under three hardware-like parameter sets.
pub fn e14_time_model() -> Result<Vec<Table>> {
    let models = [
        ("cluster (1ns,1µs,10ns)", TimeModel::default()),
        (
            "fast-net (1ns,100ns,2ns)",
            TimeModel {
                alpha_ns: 1.0,
                beta_ns: 100.0,
                gamma_ns: 2.0,
            },
        ),
        (
            "wan (1ns,100µs,100ns)",
            TimeModel {
                alpha_ns: 1.0,
                beta_ns: 100_000.0,
                gamma_ns: 100.0,
            },
        ),
    ];
    let mut t = Table::new(
        "E14: modeled execution time (ms) at n=4096, P=64 (COPK: P=108, n=5184)",
        &["algorithm", "model", "T", "BW", "L", "time (ms)"],
    );
    let runs = [
        ("COPSIM_MI", run_algo(Algo::CopsimMi, 4096, 64, None, 0x14)?),
        ("COPK_MI", run_algo(Algo::CopkMi, 5184, 108, None, 0x14)?),
        ("allgather", run_algo(Algo::Allgather, 4096, 64, None, 0x14)?),
        (
            "Cesari-Maeder",
            run_algo(Algo::CesariMaeder, 4096, 64, None, 0x14)?,
        ),
    ];
    for (name, s) in &runs {
        for (mname, tm) in &models {
            t.row(vec![
                (*name).into(),
                (*mname).into(),
                fmt_u64(s.clock.ops),
                fmt_u64(s.clock.words),
                fmt_u64(s.clock.msgs),
                fmt_f64(tm.time_ns(&s.clock) / 1e6),
            ]);
        }
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strong_scaling_flat() {
        let tables = e10_strong_scaling().unwrap();
        // COPSIM: T·P/n² across P must vary by < 4x (constant-ish).
        let vals: Vec<f64> = tables[0]
            .rows
            .iter()
            .map(|r| r[3].parse().unwrap())
            .collect();
        let (mn, mx) = vals
            .iter()
            .fold((f64::MAX, 0f64), |(a, b), &v| (a.min(v), b.max(v)));
        assert!(mx / mn < 4.0, "T·P/n² not flat: {vals:?}");
    }

    #[test]
    fn crossover_found() {
        let tables = e11_crossover().unwrap();
        // COPK must win by the largest n in the sweep.
        let last = tables[0].rows.last().unwrap();
        assert_eq!(last[5], "COPK");
        // And COPSIM must win at the smallest.
        assert_eq!(tables[0].rows[0][5], "COPSIM");
    }

    #[test]
    fn memory_claim_holds() {
        let t = &e13_memory().unwrap()[0];
        for row in &t.rows {
            let ratio: f64 = row[5].parse().unwrap();
            if row[0].starts_with("COPSIM") || row[0].starts_with("COPK") {
                assert!(ratio <= 60.0, "{}: total/n = {ratio}", row[0]);
            } else {
                // The baseline really is Θ(nP): ratio ~ 2P.
                assert!(ratio > 60.0, "baseline unexpectedly frugal: {ratio}");
            }
        }
    }
}

//! E4-E9: COPSIM/COPK vs Theorems 11/12/14/15 and the optimality
//! ratios of Theorems 1/2 (vs the lower bounds of Theorems 3-6).

use super::{run_algo, Algo};
use crate::metrics::{fmt_f64, fmt_ratio, fmt_u64, Table};
use crate::theory;
use crate::error::Result;

/// E4 — Theorem 11: COPSIM_MI sweep.
pub fn e04_copsim_mi() -> Result<Vec<Table>> {
    let mut t = Table::new(
        "E4: COPSIM_MI vs Theorem 11 (T <= 38n²/P + 3lg²P, BW <= 14n/√P + 6lg²P, L <= 3lg²P, M <= 12n/√P)",
        &[
            "P", "n", "T meas", "T bound", "T r", "BW meas", "BW bound", "BW r", "L meas",
            "L bound", "L r", "M meas", "M bound", "M r",
        ],
    );
    for &(p, n) in &[
        (4usize, 1usize << 10),
        (16, 1 << 10),
        (16, 1 << 12),
        (64, 1 << 12),
        (64, 1 << 14),
        (256, 1 << 14),
    ] {
        let s = run_algo(Algo::CopsimMi, n, p, None, 0xE4)?;
        let b = theory::thm11_copsim_mi(n as u64, p as u64);
        let mb = theory::thm11_copsim_mi_mem(n as u64, p as u64);
        t.row(vec![
            p.to_string(),
            fmt_u64(n as u64),
            fmt_u64(s.clock.ops),
            fmt_u64(b.ops),
            fmt_ratio(s.clock.ops as f64, b.ops as f64),
            fmt_u64(s.clock.words),
            fmt_u64(b.words),
            fmt_ratio(s.clock.words as f64, b.words as f64),
            fmt_u64(s.clock.msgs),
            fmt_u64(b.msgs),
            fmt_ratio(s.clock.msgs as f64, b.msgs as f64),
            fmt_u64(s.mem_peak),
            fmt_u64(mb),
            fmt_ratio(s.mem_peak as f64, mb as f64),
        ]);
    }
    Ok(vec![t])
}

/// E5 — Theorem 12: COPSIM main mode across a memory sweep at fixed
/// (n, P); M from the minimum 80n/P upward until the MI mode takes over.
pub fn e05_copsim_main() -> Result<Vec<Table>> {
    let (p, n) = (64usize, 1usize << 12);
    let mut t = Table::new(
        format!(
            "E5: COPSIM main mode vs Theorem 12 at n={n}, P={p} \
             (T <= 196n²/P, BW <= 3530n²/(MP), L <= 7012 n²lg²P/(M²P))"
        ),
        &[
            "M", "mode", "T meas", "T bound", "T r", "BW meas", "BW bound", "BW r", "L meas",
            "L bound", "L r", "M peak",
        ],
    );
    let m_min = (80 * n / p) as u64;
    let mi_need = theory::thm11_copsim_mi_mem(n as u64, p as u64);
    for mult in [1u64, 2, 4, 8] {
        let m = m_min * mult;
        let s = run_algo(Algo::CopsimMain, n, p, Some(m), 0xE5)?;
        let b = theory::thm12_copsim(n as u64, p as u64, m);
        let mode = if m >= mi_need { "MI" } else { "DFS" };
        t.row(vec![
            fmt_u64(m),
            mode.into(),
            fmt_u64(s.clock.ops),
            fmt_u64(b.ops),
            fmt_ratio(s.clock.ops as f64, b.ops as f64),
            fmt_u64(s.clock.words),
            fmt_u64(b.words),
            fmt_ratio(s.clock.words as f64, b.words as f64),
            fmt_u64(s.clock.msgs),
            fmt_u64(b.msgs),
            fmt_ratio(s.clock.msgs as f64, b.msgs as f64),
            fmt_u64(s.mem_peak),
        ]);
    }
    Ok(vec![t])
}

/// E6 — Theorem 14: COPK_MI sweep.
pub fn e06_copk_mi() -> Result<Vec<Table>> {
    let mut t = Table::new(
        "E6: COPK_MI vs Theorem 14 (T <= 173 n^lg3/P, BW <= 174 n/P^(log3 2), L <= 25lg²P, M <= 10n/P^(log3 2))",
        &[
            "P", "n", "T meas", "T bound", "T r", "BW meas", "BW bound", "BW r", "L meas",
            "L bound", "L r", "M meas", "M bound", "M r",
        ],
    );
    for &(p, n) in &[
        (4usize, 1024usize),
        (12, 768),
        (12, 3072),
        (36, 4608),
        (108, 5184),
        (108, 20736),
    ] {
        let s = run_algo(Algo::CopkMi, n, p, None, 0xE6)?;
        let b = theory::thm14_copk_mi(n as u64, p as u64);
        let mb = theory::thm14_copk_mi_mem(n as u64, p as u64);
        t.row(vec![
            p.to_string(),
            fmt_u64(n as u64),
            fmt_u64(s.clock.ops),
            fmt_u64(b.ops),
            fmt_ratio(s.clock.ops as f64, b.ops as f64),
            fmt_u64(s.clock.words),
            fmt_u64(b.words),
            fmt_ratio(s.clock.words as f64, b.words as f64),
            fmt_u64(s.clock.msgs),
            fmt_u64(b.msgs),
            fmt_ratio(s.clock.msgs as f64, b.msgs as f64),
            fmt_u64(s.mem_peak),
            fmt_u64(mb),
            fmt_ratio(s.mem_peak as f64, mb as f64),
        ]);
    }
    Ok(vec![t])
}

/// E7 — Theorem 15: COPK main mode, memory sweep at (n, P) = (5184, 108).
pub fn e07_copk_main() -> Result<Vec<Table>> {
    let (p, n) = (108usize, 5184usize);
    let mut t = Table::new(
        format!(
            "E7: COPK main mode vs Theorem 15 at n={n}, P={p} \
             (T <= 675 n^lg3/P, BW <= 1708 (n/M)^lg3 M/P, L <= 8728 n^lg3 lg²P/(P M^lg3))"
        ),
        &[
            "M", "mode", "T meas", "T bound", "T r", "BW meas", "BW bound", "BW r", "L meas",
            "L bound", "L r", "M peak",
        ],
    );
    let m_min = (40 * n / p) as u64;
    let mi_need = theory::thm14_copk_mi_mem(n as u64, p as u64);
    for mult in [1u64, 2, 4] {
        let m = m_min * mult;
        let s = run_algo(Algo::CopkMain, n, p, Some(m), 0xE7)?;
        let b = theory::thm15_copk(n as u64, p as u64, m);
        let mode = if m >= mi_need { "MI" } else { "DFS" };
        t.row(vec![
            fmt_u64(m),
            mode.into(),
            fmt_u64(s.clock.ops),
            fmt_u64(b.ops),
            fmt_ratio(s.clock.ops as f64, b.ops as f64),
            fmt_u64(s.clock.words),
            fmt_u64(b.words),
            fmt_ratio(s.clock.words as f64, b.words as f64),
            fmt_u64(s.clock.msgs),
            fmt_u64(b.msgs),
            fmt_ratio(s.clock.msgs as f64, b.msgs as f64),
            fmt_u64(s.mem_peak),
        ]);
    }
    Ok(vec![t])
}

/// E8 — Theorem 1: COPSIM measured BW/L over the Theorem 3/4 lower
/// bounds. Optimality = the ratio stays bounded by a constant across
/// the sweep (and L/lower stays within O(log²P)).
pub fn e08_copsim_optimality() -> Result<Vec<Table>> {
    let mut t = Table::new(
        "E8: COPSIM optimality — measured / lower bound (Thm 3 memory-dependent, Thm 4 memory-independent)",
        &[
            "P", "n", "M", "BW meas", "BW lower", "BW/lower", "L meas", "L lower",
            "L/(lower·lg²P)",
        ],
    );
    // Limited-memory regime: M = 80n/P (DFS mode). The binding lower
    // bound is the max of the memory-dependent (Thm 3) and
    // memory-independent (Thm 4) expressions — the paper notes which
    // regime dominates for a given M.
    for &(p, n) in &[(64usize, 1usize << 12), (64, 1 << 13), (256, 1 << 13)] {
        let m = (80 * n / p) as u64;
        let s = run_algo(Algo::CopsimMain, n, p, Some(m), 0xE8)?;
        let (bw_dep, l_low) = theory::thm3_lower_standard(n as u64, p as u64, m);
        let bw_low = bw_dep.max(theory::thm4_lower_standard_mi(n as u64, p as u64));
        let l_low = l_low.max(1.0);
        let lg = (p as f64).log2();
        t.row(vec![
            p.to_string(),
            fmt_u64(n as u64),
            fmt_u64(m),
            fmt_u64(s.clock.words),
            fmt_f64(bw_low),
            fmt_ratio(s.clock.words as f64, bw_low),
            fmt_u64(s.clock.msgs),
            fmt_f64(l_low),
            fmt_ratio(s.clock.msgs as f64, l_low.max(1.0) * lg * lg),
        ]);
    }
    // Memory-independent regime: unbounded M (MI mode) vs Thm 4.
    for &(p, n) in &[(16usize, 1usize << 12), (64, 1 << 13), (256, 1 << 14)] {
        let s = run_algo(Algo::CopsimMi, n, p, None, 0xE8)?;
        let bw_low = theory::thm4_lower_standard_mi(n as u64, p as u64);
        let lg = (p as f64).log2();
        t.row(vec![
            p.to_string(),
            fmt_u64(n as u64),
            "inf".into(),
            fmt_u64(s.clock.words),
            fmt_f64(bw_low),
            fmt_ratio(s.clock.words as f64, bw_low),
            fmt_u64(s.clock.msgs),
            "1".into(),
            fmt_ratio(s.clock.msgs as f64, lg * lg),
        ]);
    }
    Ok(vec![t])
}

/// E9 — Theorem 2: COPK vs the Theorem 5/6 lower bounds.
pub fn e09_copk_optimality() -> Result<Vec<Table>> {
    let mut t = Table::new(
        "E9: COPK optimality — measured / lower bound (Thm 5 memory-dependent, Thm 6 memory-independent)",
        &[
            "P", "n", "M", "BW meas", "BW lower", "BW/lower", "L meas", "L lower",
            "L/(lower·lg²P)",
        ],
    );
    for &(p, n) in &[(108usize, 5184usize), (108, 10368)] {
        let m = (40 * n / p) as u64;
        let s = run_algo(Algo::CopkMain, n, p, Some(m), 0xE9)?;
        let (bw_dep, l_low) = theory::thm5_lower_karatsuba(n as u64, p as u64, m);
        let bw_low = bw_dep.max(theory::thm6_lower_karatsuba_mi(n as u64, p as u64));
        let l_low = l_low.max(1.0);
        let lg = (p as f64).log2();
        t.row(vec![
            p.to_string(),
            fmt_u64(n as u64),
            fmt_u64(m),
            fmt_u64(s.clock.words),
            fmt_f64(bw_low),
            fmt_ratio(s.clock.words as f64, bw_low),
            fmt_u64(s.clock.msgs),
            fmt_f64(l_low),
            fmt_ratio(s.clock.msgs as f64, l_low.max(1.0) * lg * lg),
        ]);
    }
    for &(p, n) in &[(12usize, 3072usize), (36, 4608), (108, 10368)] {
        let s = run_algo(Algo::CopkMi, n, p, None, 0xE9)?;
        let bw_low = theory::thm6_lower_karatsuba_mi(n as u64, p as u64);
        let lg = (p as f64).log2();
        t.row(vec![
            p.to_string(),
            fmt_u64(n as u64),
            "inf".into(),
            fmt_u64(s.clock.words),
            fmt_f64(bw_low),
            fmt_ratio(s.clock.words as f64, bw_low),
            fmt_u64(s.clock.msgs),
            "1".into(),
            fmt_ratio(s.clock.msgs as f64, lg * lg),
        ]);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mi_experiments_ratios_sane() {
        // T ratio under 1 everywhere (paper bounds hold for compute).
        for f in [e04_copsim_mi, e06_copk_mi] {
            let t = &f().unwrap()[0];
            for row in &t.rows {
                let r: f64 = row[4].parse().unwrap();
                assert!(r <= 1.0, "T ratio {r} > 1 in {}", t.title);
            }
        }
    }

    #[test]
    fn optimality_ratio_bounded() {
        // Theorem 1/2's content is asymptotic: measured BW / lower bound
        // must stay below a FIXED constant across the sweep (the
        // constant itself combines the algorithms' upper-bound constants
        // with the constant-1 lower-bound expressions, so it is large —
        // what matters is that it does not grow with n or P).
        for f in [e08_copsim_optimality, e09_copk_optimality] {
            let t = &f().unwrap()[0];
            let ratios: Vec<f64> = t.rows.iter().map(|r| r[5].parse().unwrap()).collect();
            let mx = ratios.iter().cloned().fold(0.0, f64::max);
            let mn = ratios.iter().cloned().fold(f64::MAX, f64::min);
            assert!(mx < 150.0, "BW/lower = {mx} in {}", t.title);
            assert!(
                mx / mn < 12.0,
                "BW/lower spread {mn}..{mx} suggests growth in {}",
                t.title
            );
        }
    }
}
